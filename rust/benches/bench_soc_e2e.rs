//! End-to-end driver benchmarks: the Anomaly-Detection app and the
//! saturated matmul, as wall-time + simulated-cycle rate. Iterations use
//! a fresh `SweepSession` each (the cache must stay cold so every rep
//! simulates), going through the same session path the harness uses.
use nmc::apps::anomaly;
use nmc::benchlib::{bench, sink, throughput};
use nmc::isa::Sew;
use nmc::kernels::{Kernel, Target};
use nmc::sweep::SweepSession;

fn main() {
    let cycles = SweepSession::new().anomaly(Target::Carus, 2).cycles;
    let m = bench("e2e_ad_carus", || {
        sink(SweepSession::new().anomaly(Target::Carus, 2).cycles);
    });
    throughput(&m, cycles as f64, "sim-cycles");

    let cycles = SweepSession::new().anomaly(Target::Cpu, 2).cycles;
    let m = bench("e2e_ad_cpu", || {
        sink(SweepSession::new().anomaly(Target::Cpu, 2).cycles);
    });
    throughput(&m, cycles as f64, "sim-cycles");

    let c = SweepSession::new().run(Target::Carus, Kernel::Matmul { p: 1024 }, Sew::E8, 1).cycles;
    let m = bench("e2e_matmul_carus_e8", || {
        sink(SweepSession::new().run(Target::Carus, Kernel::Matmul { p: 1024 }, Sew::E8, 1).cycles);
    });
    throughput(&m, c as f64, "sim-cycles");

    // The model-build + golden-forward setup cost on its own (no SoC
    // simulation). Note this is NOT the session cache-hit path — a warm
    // `SweepSession` hit is just a map lookup + Arc clone (see
    // `fig12_sweep_quick_cached` in bench_tables for that).
    let m = bench("ad_model_golden_forward", || {
        let m0 = anomaly::model(2);
        sink(anomaly::golden_forward(&m0).len());
    });
    throughput(&m, anomaly::total_macs() as f64, "MACs");
}
