//! End-to-end driver benchmarks: the Anomaly-Detection app and the
//! saturated matmul, as wall-time + simulated-cycle rate.
use nmc::apps::anomaly;
use nmc::benchlib::{bench, sink, throughput};
use nmc::isa::Sew;
use nmc::kernels::{run, Kernel, Target};

fn main() {
    let m0 = anomaly::model(2);
    let cycles = anomaly::run_carus(&m0).cycles;
    let m = bench("e2e_ad_carus", || {
        sink(anomaly::run_carus(&m0).cycles);
    });
    throughput(&m, cycles as f64, "sim-cycles");

    let cycles = anomaly::run_cpu(&m0).cycles;
    let m = bench("e2e_ad_cpu", || {
        sink(anomaly::run_cpu(&m0).cycles);
    });
    throughput(&m, cycles as f64, "sim-cycles");

    let r = run(Target::Carus, Kernel::Matmul { p: 1024 }, Sew::E8, 1);
    let c = r.cycles;
    let m = bench("e2e_matmul_carus_e8", || {
        sink(run(Target::Carus, Kernel::Matmul { p: 1024 }, Sew::E8, 1).cycles);
    });
    throughput(&m, c as f64, "sim-cycles");
}
