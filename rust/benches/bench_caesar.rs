//! NM-Caesar model hot path: micro-op decode/execute rate.
use nmc::benchlib::{bench, sink, throughput};
use nmc::caesar::isa::{encode, MicroOp, Op};
use nmc::caesar::Caesar;
use nmc::isa::Sew;

fn main() {
    let ops = 100_000u64;
    for (name, op) in [("caesar_xor_stream", Op::Xor), ("caesar_mac_stream", Op::Mac)] {
        let m = bench(name, || {
            let mut c = Caesar::new();
            c.sew = Sew::E8;
            let w = encode(&MicroOp { op, src1: 5, src2: 4200 });
            for i in 0..ops {
                while !c.ready() {
                    c.step();
                }
                c.issue((2048 + (i & 1023)) as u32, w);
                c.step();
            }
            sink(c.stats.instrs);
        });
        throughput(&m, ops as f64, "micro-ops");
    }
}
