//! Table-regeneration benchmarks: wall time to reproduce each paper
//! table/figure (the deliverable-(d) harness itself). Each iteration
//! drains through a *fresh* `SweepSession` so the measurement covers real
//! simulations, not cache hits; one extra benchmark measures the warmed
//! cache-hit path itself.
use nmc::benchlib::{bench, sink};
use nmc::harness;
use nmc::sweep::SweepSession;

fn main() {
    let m = bench("table5_full_grid", || {
        let session = SweepSession::new();
        sink(harness::run_table5(&session, false).len());
    });
    println!("table5 full grid: {:.2} s", m.median_ns / 1e9);
    let m = bench("table6_anomaly_detection", || {
        let session = SweepSession::new();
        sink(harness::table6(&session).text.len());
    });
    println!("table6: {:.2} s", m.median_ns / 1e9);
    let m = bench("fig12_sweep_quick", || {
        let session = SweepSession::new();
        sink(harness::fig12(&session, true).text.len());
    });
    println!("fig12 quick: {:.2} s", m.median_ns / 1e9);
    // The cache-hit path: a warmed session re-serving the quick Fig. 12
    // sweep without simulating.
    let warm = SweepSession::new();
    sink(harness::fig12(&warm, true).text.len());
    let sims = warm.simulations();
    let m = bench("fig12_sweep_quick_cached", || {
        sink(harness::fig12(&warm, true).text.len());
    });
    assert_eq!(warm.simulations(), sims, "warm reps must not simulate");
    println!("fig12 quick (cached): {:.2} ms", m.median_ns / 1e6);
    let m = bench("static_tables", || {
        sink((harness::table4().text.len(), harness::table7().text.len(), harness::table8().text.len()));
    });
    println!("static tables: {:.2} ms", m.median_ns / 1e6);
}
