//! Table-regeneration benchmarks: wall time to reproduce each paper
//! table/figure (the deliverable-(d) harness itself).
use nmc::benchlib::{bench, sink};
use nmc::harness;

fn main() {
    let m = bench("table5_full_grid", || {
        sink(harness::run_table5(false).len());
    });
    println!("table5 full grid: {:.2} s", m.median_ns / 1e9);
    let m = bench("table6_anomaly_detection", || {
        sink(harness::table6().text.len());
    });
    println!("table6: {:.2} s", m.median_ns / 1e9);
    let m = bench("fig12_sweep_quick", || {
        sink(harness::fig12(true).text.len());
    });
    println!("fig12 quick: {:.2} s", m.median_ns / 1e9);
    let m = bench("static_tables", || {
        sink((harness::table4().text.len(), harness::table7().text.len(), harness::table8().text.len()));
    });
    println!("static tables: {:.2} ms", m.median_ns / 1e6);
}
