//! NM-Carus VPU hot path: vmacc element throughput of the functional model.
use nmc::benchlib::{bench, sink, throughput};
use nmc::carus::vpu::{Operand, VecCmd, Vpu};
use nmc::carus::vrf::Vrf;
use nmc::isa::xvnmc::VOp;
use nmc::isa::Sew;

fn main() {
    for (name, sew, vl) in [
        ("vpu_vmacc_e8_vl1024", Sew::E8, 1024u32),
        ("vpu_vmacc_e32_vl256", Sew::E32, 256),
    ] {
        let reps = 200u64;
        let m = bench(name, || {
            let mut vrf = Vrf::new(4);
            let mut vpu = Vpu::new(4);
            vpu.set_vtype(vl, sew);
            for _ in 0..reps {
                while !vpu.can_accept() {
                    vpu.step(&mut vrf);
                }
                vpu.issue(VecCmd::Op { op: VOp::Macc, vd: 8, vs2: 1, src: Operand::X(3) }, &mut vrf);
                vpu.step(&mut vrf);
            }
            while vpu.busy() {
                vpu.step(&mut vrf);
            }
            sink(vpu.stats.instrs);
        });
        throughput(&m, (reps * vl as u64) as f64, "elements");
    }
}
