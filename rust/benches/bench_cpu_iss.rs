//! Simulator hot path: the RV32 ISS + SoC step loop.
//! Reports simulated cycles per second of host wall time.
use nmc::asm::Asm;
use nmc::benchlib::{bench, sink, throughput};
use nmc::bus::BANK_SIZE;
use nmc::isa::reg::*;
use nmc::soc::Soc;

fn main() {
    // A tight arithmetic loop: the pure-ISS rate.
    let iters = 50_000u64;
    let m = bench("cpu_iss_arith_loop", || {
        let mut soc = Soc::heeperator();
        let mut a = Asm::new(0);
        a.li(A0, iters as i32)
            .label("l")
            .addi(A1, A1, 3)
            .xor(A2, A2, A1)
            .slli(A3, A2, 1)
            .addi(A0, A0, -1)
            .bne(A0, ZERO, "l")
            .ebreak();
        soc.load_firmware(&a.assemble().unwrap(), 0);
        let (h, c) = soc.run(10_000_000);
        sink((h, c));
    });
    throughput(&m, (iters * 7) as f64, "sim-cycles");

    // Memory-heavy loop: bus dispatch + bank accounting.
    let n = 4096u64;
    let m = bench("cpu_iss_memcpy", || {
        let mut soc = Soc::heeperator();
        soc.load_data(BANK_SIZE, &vec![0xa5u8; (n * 4) as usize]);
        let mut a = Asm::new(0);
        a.li(A0, BANK_SIZE as i32)
            .li(A1, (2 * BANK_SIZE) as i32)
            .li(A2, n as i32)
            .label("l")
            .lw(T0, 0, A0)
            .sw(T0, 0, A1)
            .addi(A0, A0, 4)
            .addi(A1, A1, 4)
            .addi(A2, A2, -1)
            .bne(A2, ZERO, "l")
            .ebreak();
        soc.load_firmware(&a.assemble().unwrap(), 0);
        sink(soc.run(10_000_000));
    });
    throughput(&m, (n * 8) as f64, "sim-cycles");
}
