//! Analytical post-layout area model (65 nm low-power CMOS), calibrated to
//! Table IV, Fig. 7 (post-synthesis breakdown), and Table VI.
//!
//! SRAM macro area follows the classic periphery+array affine model
//! `A(c) = A0 + k·c` fitted to the paper's 32 KiB reference macro
//! (200·10³ µm²) with a sub-linear small-capacity penalty that makes
//! NM-Carus's 4 × 8 KiB data memory larger than NM-Caesar's 2 × 16 KiB one
//! (visible in Fig. 7) despite identical capacity.
//!
//! Logic-block areas come from the paper (Fig. 7 proportions, Table IV
//! totals, Table VI system areas) and public data for the OpenHW cores.
//! All figures in µm².

/// SRAM macro area (single-port, foundry compiler) for a capacity in KiB.
///
/// Fit: periphery/overhead term grows as capacity shrinks relative to the
/// array — matching the paper's observation of "sublinear scaling of the
/// footprint of an SRAM with its reduction in size".
pub fn sram_area_um2(kib: f64) -> f64 {
    // 32 KiB → 200e3, 16 KiB → ~110e3, 8 KiB → ~65e3, 4 KiB → ~42e3.
    const PERIPHERY: f64 = 19.0e3;
    const PER_KIB: f64 = 5.656e3;
    PERIPHERY + PER_KIB * kib
}

/// 512 B latch/RF macro (NM-Carus eMEM).
pub const EMEM_AREA: f64 = 8.0e3;

/// NM-Caesar logic (controller + SIMD ALU + CSR), post-layout.
pub const CAESAR_LOGIC_AREA: f64 = 30.0e3;

/// NM-Carus eCPU (CV32E40X, RV32EC config) incl. XIF.
pub const CARUS_ECPU_AREA: f64 = 45.0e3;

/// NM-Carus VPU logic per lane (ALU + slice of permutation network).
pub const CARUS_VPU_LANE_AREA: f64 = 18.0e3;

/// NM-Carus shared VPU control (decode, commit, loop unit, CSR unit) +
/// top-level bus multiplexing.
pub const CARUS_VPU_SHARED_AREA: f64 = 20.0e3;

/// CV32E40P core (RV32IMC, no FPU), post-layout.
pub const CV32E40P_AREA: f64 = 110.0e3;

/// CV32E40P DSP extension increment (Xcv datapath).
pub const XCV_AREA: f64 = 15.0e3;

/// CV32E20 ("micro-riscy", RV32E) core.
pub const CV32E20_AREA: f64 = 30.0e3;

/// Always-there MCU glue counted in the Table VI "system" areas:
/// bus/crossbar + DMA + peripheral subsystem.
pub const SYSTEM_GLUE_AREA: f64 = 40.0e3;

/// Area report for one NMC macro in the style of Fig. 7.
#[derive(Debug, Clone, PartialEq)]
pub struct MacroArea {
    pub name: &'static str,
    /// (component label, µm²) pairs, logic and memory.
    pub parts: Vec<(&'static str, f64)>,
}

impl MacroArea {
    pub fn total(&self) -> f64 {
        self.parts.iter().map(|p| p.1).sum()
    }
    /// Overhead vs. the 32 KiB reference SRAM (Table IV row 1).
    pub fn overhead_vs_sram32k(&self) -> f64 {
        self.total() / sram_area_um2(32.0) - 1.0
    }
    /// Memory fraction (bitcell-macro area / total).
    pub fn memory_fraction(&self) -> f64 {
        let mem: f64 = self
            .parts
            .iter()
            .filter(|(n, _)| n.contains("SRAM") || n.contains("eMEM"))
            .map(|p| p.1)
            .sum();
        mem / self.total()
    }
}

/// Reference 32 KiB SRAM (Table IV column 1).
pub fn sram32k() -> MacroArea {
    MacroArea { name: "SRAM 32 KiB", parts: vec![("SRAM array", sram_area_um2(32.0))] }
}

/// NM-Caesar, 32 KiB configuration (2 × 16 KiB banks).
pub fn caesar() -> MacroArea {
    MacroArea {
        name: "NM-Caesar",
        parts: vec![
            ("SRAM 16 KiB ×2", 2.0 * sram_area_um2(16.0)),
            ("controller+ALU logic", CAESAR_LOGIC_AREA),
        ],
    }
}

/// NM-Carus, 32 KiB configuration with `lanes` VRF banks of equal size.
pub fn carus(lanes: u32) -> MacroArea {
    let bank_kib = 32.0 / lanes as f64;
    MacroArea {
        name: "NM-Carus",
        parts: vec![
            ("SRAM VRF banks", lanes as f64 * sram_area_um2(bank_kib)),
            ("eMEM 512 B", EMEM_AREA),
            ("eCPU (CV32E40X)", CARUS_ECPU_AREA),
            ("VPU lanes", lanes as f64 * CARUS_VPU_LANE_AREA),
            ("VPU shared + mux", CARUS_VPU_SHARED_AREA),
        ],
    }
}

/// Table VI system areas.
pub fn system_cpu_cluster(cores: u32) -> f64 {
    // The paper assumes ideal linear area scaling for multi-core CPUs and a
    // single 32 KiB L1 data bank.
    cores as f64 * (CV32E40P_AREA + XCV_AREA) + sram_area_um2(32.0) + SYSTEM_GLUE_AREA
}

/// Table VI NMC system: CV32E20 + one NMC macro replacing the L1 bank.
pub fn system_nmc(nmc: &MacroArea) -> f64 {
    CV32E20_AREA + nmc.total() + SYSTEM_GLUE_AREA
}

/// Timing characteristics (Table IV) — modeled, not simulated: the NMC
/// macros were constrained to the reference SRAM's clock and I/O delays.
#[derive(Debug, Clone, Copy)]
pub struct TimingSpec {
    pub fmax_mhz: f64,
    pub input_delay_ns: f64,
    pub output_delay_ns: f64,
}

pub fn timing_sram32k() -> TimingSpec {
    TimingSpec { fmax_mhz: 330.0, input_delay_ns: 0.69, output_delay_ns: 2.28 }
}
pub fn timing_caesar() -> TimingSpec {
    // +2 % input delay (mode mux on the write path), unchanged output.
    TimingSpec { fmax_mhz: 330.0, input_delay_ns: 0.70, output_delay_ns: 2.28 }
}
pub fn timing_carus() -> TimingSpec {
    // +2 % input, +9 % output (VRF-bank/controller bus mux on the read path).
    TimingSpec { fmax_mhz: 330.0, input_delay_ns: 0.70, output_delay_ns: 2.48 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_area_totals() {
        // SRAM 200e3; Caesar 256e3 (+28 %); Carus 419e3 (+110 %), ±6 %.
        let sram = sram32k().total();
        assert!((sram - 200.0e3).abs() / 200.0e3 < 0.01, "sram = {sram}");
        let c = caesar();
        assert!(
            (c.total() - 256.0e3).abs() / 256.0e3 < 0.06,
            "caesar = {:.1}e3 ({:+.0} %)",
            c.total() / 1e3,
            c.overhead_vs_sram32k() * 100.0
        );
        let k = carus(4);
        assert!(
            (k.total() - 419.0e3).abs() / 419.0e3 < 0.06,
            "carus = {:.1}e3 ({:+.0} %)",
            k.total() / 1e3,
            k.overhead_vs_sram32k() * 100.0
        );
    }

    #[test]
    fn carus_meets_memory_to_logic_target() {
        // §IV-B: NM-Carus meets "the target 50 % memory to logic ratio".
        let frac = carus(4).memory_fraction();
        assert!((0.48..0.70).contains(&frac), "memory fraction = {frac:.2}");
    }

    #[test]
    fn sublinear_sram_scaling_visible() {
        // Fig. 7: Carus's 4×8 KiB banks out-area Caesar's 2×16 KiB.
        assert!(4.0 * sram_area_um2(8.0) > 2.0 * sram_area_um2(16.0));
        // And 2×16 KiB > 1×32 KiB.
        assert!(2.0 * sram_area_um2(16.0) > sram_area_um2(32.0));
    }

    #[test]
    fn table6_system_areas() {
        // Single-core CV32E40P system ≈ 350e3 µm².
        let single = system_cpu_cluster(1);
        assert!((single - 350.0e3).abs() / 350.0e3 < 0.06, "single-core = {single}");
        // NM-Caesar + CV32E20 ≈ 0.90× single-core.
        let caesar_sys = system_nmc(&caesar());
        let ratio = caesar_sys / single;
        assert!((0.84..0.97).contains(&ratio), "caesar system ratio = {ratio:.2}");
        // NM-Carus + CV32E20 ≈ 1.36× single-core, and < dual-core (1.43×).
        let carus_sys = system_nmc(&carus(4));
        let ratio = carus_sys / single;
        assert!((1.25..1.43).contains(&ratio), "carus system ratio = {ratio:.2}");
        assert!(carus_sys < system_cpu_cluster(2));
    }

    #[test]
    fn timing_overheads_match_table4() {
        let s = timing_sram32k();
        let c = timing_caesar();
        let k = timing_carus();
        assert_eq!(s.fmax_mhz, c.fmax_mhz);
        assert_eq!(s.fmax_mhz, k.fmax_mhz);
        assert!((c.input_delay_ns / s.input_delay_ns - 1.015).abs() < 0.02);
        assert!((k.output_delay_ns / s.output_delay_ns - 1.09).abs() < 0.02);
    }
}
