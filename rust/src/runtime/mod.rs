//! PJRT golden-model runtime: loads the AOT-compiled HLO artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them on the XLA CPU client.
//!
//! This is the bridge that closes the three-layer loop: the JAX/Pallas
//! kernels (Layers 1–2) are the bit-exact functional oracles for the
//! simulated hardware (Layer 3). Python never runs at simulation time —
//! only the serialized HLO does.
//!
//! Interchange conventions (see `python/compile/aot.py`):
//! - HLO **text**, parsed with `HloModuleProto::from_text_file` (jax ≥ 0.5
//!   emits 64-bit instruction ids that xla_extension 0.5.1 rejects in
//!   proto form; the text parser reassigns ids).
//! - All artifact interfaces are int32 tensors; results are 1-tuples.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Where the artifacts live: `$NMC_ARTIFACTS` or `<repo>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("NMC_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // Relative to the crate root (tests/benches run from there).
    let candidates = [Path::new("artifacts"), Path::new("../artifacts")];
    for c in candidates {
        if c.exists() {
            return c.to_path_buf();
        }
    }
    PathBuf::from("artifacts")
}

/// True if the artifact set has been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// An int32 tensor argument.
#[derive(Debug, Clone)]
pub struct TensorI32 {
    pub data: Vec<i32>,
    pub shape: Vec<i64>,
}

impl TensorI32 {
    pub fn new(data: Vec<i32>, shape: &[i64]) -> Self {
        assert_eq!(data.len() as i64, shape.iter().product::<i64>());
        TensorI32 { data, shape: shape.to_vec() }
    }
    /// From sign-extended kernel elements (the simulator's canonical form).
    pub fn from_elems(elems: &[i64], shape: &[i64]) -> Self {
        Self::new(elems.iter().map(|&v| v as i32).collect(), shape)
    }
}

/// The PJRT CPU runtime with a compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl Runtime {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, cache: HashMap::new(), dir: artifacts_dir() })
    }

    /// Number of PJRT devices (sanity/introspection).
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute artifact `name` with int32 inputs; returns the flattened
    /// int32 output of the 1-tuple result.
    pub fn execute(&mut self, name: &str, inputs: &[TensorI32]) -> Result<Vec<i32>> {
        self.load(name)?;
        let exe = &self.cache[name];
        let mut lits = Vec::with_capacity(inputs.len());
        for t in inputs {
            let lit = xla::Literal::vec1(&t.data)
                .reshape(&t.shape)
                .map_err(|e| anyhow!("reshape {:?}: {e:?}", t.shape))?;
            lits.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_resolves() {
        let d = artifacts_dir();
        assert!(d.ends_with("artifacts"));
    }

    // Execution tests live in rust/tests/golden_runtime.rs (they require
    // `make artifacts` to have run).
}
