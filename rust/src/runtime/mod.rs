//! Golden-model runtime interface: the bridge to the AOT-compiled HLO
//! artifacts (`artifacts/*.hlo.txt`, produced once by `make artifacts`).
//!
//! This is the seam that closes the three-layer loop: the JAX/Pallas
//! kernels (Layers 1–2) are the bit-exact functional oracles for the
//! simulated hardware (Layer 3). Python never runs at simulation time —
//! only the serialized HLO does, executed by a PJRT CPU client.
//!
//! # Offline builds
//!
//! The PJRT/XLA bindings (`xla_extension`) are **not** in the offline
//! vendor set, so this module is std-only: it keeps the artifact
//! discovery, the tensor interchange type and the [`Runtime`] API, but
//! [`Runtime::new`] reports [`RuntimeError::BackendUnavailable`] unless a
//! real backend is wired in behind the (dependency-less) `pjrt` cargo
//! feature. Callers — `rust/tests/golden_runtime.rs`, the examples —
//! treat both "artifacts not built" and "backend unavailable" as a
//! graceful skip: the simulator's own golden references
//! ([`crate::kernels::golden`]) remain authoritative either way.
//!
//! Interchange conventions (see `python/compile/aot.py`):
//! - HLO **text** (jax ≥ 0.5 emits 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects in proto form; the text parser
//!   reassigns ids).
//! - All artifact interfaces are int32 tensors; results are 1-tuples.

use std::path::{Path, PathBuf};

/// Where the artifacts live: `$NMC_ARTIFACTS` or `<repo>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("NMC_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // Relative to the crate root (tests/benches run from there).
    let candidates = [Path::new("artifacts"), Path::new("../artifacts")];
    for c in candidates {
        if c.exists() {
            return c.to_path_buf();
        }
    }
    PathBuf::from("artifacts")
}

/// True if the artifact set has been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Errors surfaced by the golden runtime. All of them are *skippable*
/// from the test suite's point of view: they mean the golden cross-check
/// cannot run here, not that the simulator is wrong.
#[derive(Debug)]
pub enum RuntimeError {
    /// No execution backend compiled in (the offline, std-only build).
    BackendUnavailable(&'static str),
    /// The artifact file does not exist (run `make artifacts`).
    MissingArtifact(PathBuf),
    /// Backend-reported failure (load/compile/execute).
    Execution(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::BackendUnavailable(why) => {
                write!(f, "PJRT backend unavailable: {why}")
            }
            RuntimeError::MissingArtifact(p) => {
                write!(f, "artifact {} not found (run `make artifacts`)", p.display())
            }
            RuntimeError::Execution(e) => write!(f, "golden runtime failure: {e}"),
        }
    }
}
impl std::error::Error for RuntimeError {}

/// Local result alias (anyhow is not in the offline vendor set).
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// An int32 tensor argument.
#[derive(Debug, Clone)]
pub struct TensorI32 {
    pub data: Vec<i32>,
    pub shape: Vec<i64>,
}

impl TensorI32 {
    pub fn new(data: Vec<i32>, shape: &[i64]) -> Self {
        assert_eq!(data.len() as i64, shape.iter().product::<i64>());
        TensorI32 { data, shape: shape.to_vec() }
    }
    /// From sign-extended kernel elements (the simulator's canonical form).
    pub fn from_elems(elems: &[i64], shape: &[i64]) -> Self {
        Self::new(elems.iter().map(|&v| v as i32).collect(), shape)
    }
    /// Element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// The golden-model runtime. A real backend adds its client handle and
/// a name → compiled-executable cache here.
///
/// In the offline build this is a shell: construction fails with
/// [`RuntimeError::BackendUnavailable`], so no caller can reach
/// [`Runtime::execute`] without a real backend.
pub struct Runtime {
    dir: PathBuf,
}

impl Runtime {
    /// Connect to the PJRT CPU client.
    ///
    /// Fails with [`RuntimeError::BackendUnavailable`] when the crate was
    /// built without an execution backend (the default offline build).
    pub fn new() -> Result<Self> {
        if cfg!(feature = "pjrt") {
            // The feature only reserves the plumbing; the bindings still
            // have to be vendored before this can become a live client.
            return Err(RuntimeError::BackendUnavailable(
                "the `pjrt` feature is a stub until the xla_extension bindings are vendored",
            ));
        }
        Err(RuntimeError::BackendUnavailable(
            "built without the `pjrt` feature (offline, std-only vendor set)",
        ))
    }

    /// Number of PJRT devices (sanity/introspection). Always 0 until a
    /// real backend is wired in — do not conflate with the executable
    /// cache size.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Path of a named artifact, checked for existence.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(RuntimeError::MissingArtifact(path));
        }
        Ok(path)
    }

    /// Execute artifact `name` with int32 inputs; returns the flattened
    /// int32 output of the 1-tuple result.
    pub fn execute(&mut self, name: &str, inputs: &[TensorI32]) -> Result<Vec<i32>> {
        // Construction is impossible without a backend, so this is
        // unreachable today; keep the checks so a future backend slots in
        // without touching the call sites.
        self.artifact_path(name)?;
        let _ = inputs;
        Err(RuntimeError::BackendUnavailable("no execution backend compiled in"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_resolves() {
        let d = artifacts_dir();
        assert!(d.ends_with("artifacts"));
    }

    #[test]
    fn offline_build_reports_backend_unavailable() {
        // The graceful-skip contract: no panic, a descriptive error.
        match Runtime::new() {
            Ok(_) => panic!("offline build must not produce a live runtime"),
            Err(e) => {
                let msg = e.to_string();
                assert!(msg.contains("PJRT backend unavailable"), "{msg}");
            }
        }
    }

    #[test]
    fn tensor_shape_checked() {
        let t = TensorI32::new(vec![1, 2, 3, 4, 5, 6], &[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(!t.is_empty());
        let t = TensorI32::from_elems(&[-1i64, 2], &[2]);
        assert_eq!(t.data, vec![-1, 2]);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        TensorI32::new(vec![1, 2, 3], &[2, 2]);
    }

    // Execution tests live in rust/tests/golden_runtime.rs (they skip
    // unless `make artifacts` has run *and* a backend is compiled in).
}
