//! The NM-Caesar domain-specific compiler (§III-A1, §V-A2).
//!
//! The paper: "an in-house domain-specific compiler can be used to assemble
//! predefined sequences of NM-Caesar instructions that implement specific
//! kernels. These are compiled and embedded into the host system and sent
//! to NM-Caesar by the host CPU or DMA controller during execution."
//!
//! [`CaesarProgram`] is that compiler's output representation: an ordered
//! list of `(destination word, instruction word)` pairs. It can be
//! serialized into the in-memory stream format consumed by the DMA's
//! [`crate::dma::DmaMode::CaesarStream`] mode (absolute destination address
//! followed by the instruction word), or issued directly by the host CPU
//! (the online `*(BASE + DEST << 2) = …` pattern).

use super::isa::{self, MicroOp, Op};
use crate::isa::Sew;

/// One stream entry: destination word offset + encoded instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Entry {
    pub dest_word: u32,
    pub data: u32,
}

/// A compiled NM-Caesar kernel.
#[derive(Debug, Clone, Default)]
pub struct CaesarProgram {
    pub entries: Vec<Entry>,
}

impl CaesarProgram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of micro-ops.
    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn push(&mut self, dest_word: u32, m: MicroOp) -> &mut Self {
        self.entries.push(Entry { dest_word, data: isa::encode(&m) });
        self
    }

    /// Generic three-operand op on word offsets.
    pub fn op(&mut self, op: Op, dest: u32, src1: u32, src2: u32) -> &mut Self {
        self.push(dest, MicroOp { op, src1: src1 as u16, src2: src2 as u16 })
    }

    /// Configure the element width.
    pub fn csrw(&mut self, sew: Sew) -> &mut Self {
        self.push(0, MicroOp { op: Op::Csrw, src1: sew.code() as u16, src2: 0 })
    }

    pub fn and(&mut self, d: u32, a: u32, b: u32) -> &mut Self {
        self.op(Op::And, d, a, b)
    }
    pub fn or(&mut self, d: u32, a: u32, b: u32) -> &mut Self {
        self.op(Op::Or, d, a, b)
    }
    pub fn xor(&mut self, d: u32, a: u32, b: u32) -> &mut Self {
        self.op(Op::Xor, d, a, b)
    }
    pub fn add(&mut self, d: u32, a: u32, b: u32) -> &mut Self {
        self.op(Op::Add, d, a, b)
    }
    pub fn sub(&mut self, d: u32, a: u32, b: u32) -> &mut Self {
        self.op(Op::Sub, d, a, b)
    }
    pub fn mul(&mut self, d: u32, a: u32, b: u32) -> &mut Self {
        self.op(Op::Mul, d, a, b)
    }
    pub fn min(&mut self, d: u32, a: u32, b: u32) -> &mut Self {
        self.op(Op::Min, d, a, b)
    }
    pub fn max(&mut self, d: u32, a: u32, b: u32) -> &mut Self {
        self.op(Op::Max, d, a, b)
    }
    pub fn sll(&mut self, d: u32, a: u32, b: u32) -> &mut Self {
        self.op(Op::Sll, d, a, b)
    }
    pub fn slr(&mut self, d: u32, a: u32, b: u32) -> &mut Self {
        self.op(Op::Slr, d, a, b)
    }
    pub fn sra(&mut self, d: u32, a: u32, b: u32) -> &mut Self {
        self.op(Op::Sra, d, a, b)
    }
    /// MAC family (dest ignored for non-store ops).
    pub fn mac_init(&mut self, a: u32, b: u32) -> &mut Self {
        self.op(Op::MacInit, 0, a, b)
    }
    pub fn mac(&mut self, a: u32, b: u32) -> &mut Self {
        self.op(Op::Mac, 0, a, b)
    }
    pub fn mac_store(&mut self, d: u32, a: u32, b: u32) -> &mut Self {
        self.op(Op::MacStore, d, a, b)
    }
    /// Dot-product family.
    pub fn dot_init(&mut self, a: u32, b: u32) -> &mut Self {
        self.op(Op::DotInit, 0, a, b)
    }
    pub fn dot(&mut self, a: u32, b: u32) -> &mut Self {
        self.op(Op::Dot, 0, a, b)
    }
    pub fn dot_store(&mut self, d: u32, a: u32, b: u32) -> &mut Self {
        self.op(Op::DotStore, d, a, b)
    }

    /// Serialize to the DMA stream format: little-endian
    /// `(absolute destination address, instruction word)` pairs, ready to be
    /// placed in a system SRAM bank and streamed with
    /// [`crate::dma::DmaMode::CaesarStream`].
    pub fn to_stream(&self, caesar_base: u32) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.entries.len() * 8);
        for e in &self.entries {
            out.extend_from_slice(&(caesar_base + e.dest_word * 4).to_le_bytes());
            out.extend_from_slice(&e.data.to_le_bytes());
        }
        out
    }

    /// Stream size in bytes (what the DMA_LEN register receives).
    pub fn stream_len(&self) -> u32 {
        (self.entries.len() * 8) as u32
    }

    /// Code-size metric for comparisons: bytes of host memory occupied.
    pub fn code_bytes(&self) -> u32 {
        self.stream_len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::caesar::Caesar;

    #[test]
    fn stream_roundtrip_executes() {
        let mut p = CaesarProgram::new();
        p.csrw(Sew::E32).add(100, 0, 4096).xor(101, 0, 4096);
        assert_eq!(p.len(), 3);
        let stream = p.to_stream(0x3_0000);
        assert_eq!(stream.len(), 24);

        // Decode the stream as the DMA would and feed a Caesar model.
        let mut c = Caesar::new();
        c.poke_word(0, 6);
        c.poke_word(4096, 3);
        for pair in stream.chunks(8) {
            let addr = u32::from_le_bytes(pair[0..4].try_into().unwrap());
            let data = u32::from_le_bytes(pair[4..8].try_into().unwrap());
            assert!(addr >= 0x3_0000);
            while !c.ready() {
                c.step();
            }
            c.issue((addr - 0x3_0000) / 4, data);
            c.step();
        }
        while !c.ready() {
            c.step();
        }
        assert_eq!(c.peek_word(100), 9);
        assert_eq!(c.peek_word(101), 5);
    }

    #[test]
    fn builder_chains() {
        let mut p = CaesarProgram::new();
        p.dot_init(0, 4096).dot(1, 4097).dot_store(200, 2, 4098);
        assert_eq!(p.len(), 3);
        assert_eq!(p.entries[2].dest_word, 200);
    }
}
