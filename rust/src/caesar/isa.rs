//! NM-Caesar micro-instruction set (Table I).
//!
//! NM-Caesar instructions are not RISC-V: in *computing* mode, every bus
//! **write** transaction is interpreted as one micro-op. The 32-bit write
//! *data* word carries the opcode and the two source operands; the write
//! *address* carries the destination operand, exactly as in normal memory
//! accesses (§III-A1):
//!
//! ```text
//!   data[31:26] = opcode
//!   data[25:13] = src2 word offset   (13 bits → 32 KiB addressable)
//!   data[12:0]  = src1 word offset
//!   addr        = dest (ordinary bus address; word offset within the macro)
//! ```
//!
//! The paper's example encodes an addition as
//! `*(BASE + DEST << 2) = ADD << 26 | SRC2 << 13 | SRC1;` — [`encode`] and
//! [`decode`] implement exactly this layout. The element bitwidth is *not*
//! per-instruction: it is statically configured in a CSR by [`Op::Csrw`]
//! ("to avoid repeated instruction encodings").

use crate::isa::{bits, Sew};

/// NM-Caesar opcodes (Table I). All data ops are packed-SIMD element-wise
/// except the word-wise dot-product family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    And = 0,
    Or = 1,
    Xor = 2,
    Add = 3,
    Sub = 4,
    Mul = 5,
    /// Multiply-add initialization: `acc ← src1 ⊙ src2` (clears first).
    MacInit = 6,
    /// Multiply-add: `acc += src1 ⊙ src2` element-wise.
    Mac = 7,
    /// Multiply-add + writeback of the packed accumulator.
    MacStore = 8,
    /// Word-wise dot-product init: `dacc ← Σ src1[i]·src2[i]`.
    DotInit = 9,
    /// `dacc += Σ src1[i]·src2[i]`.
    Dot = 10,
    /// Dot + writeback of the 32-bit scalar accumulator.
    DotStore = 11,
    /// Logic shift left (per-element amounts from src2).
    Sll = 12,
    /// Logic shift right.
    Slr = 13,
    Min = 14,
    Max = 15,
    /// Set operand bitwidth in the CSR; src1[1:0] = SEW code.
    Csrw = 16,
    /// Arithmetic shift right. Not in Table I's listing, but the paper's
    /// measured leaky-ReLU throughput (one shift + one max per word at
    /// every width, footnote f: "negative slope coefficient implemented as
    /// right shift") requires a sign-preserving shift; we expose it as an
    /// additional opcode of the same shifter datapath.
    Sra = 17,
}

impl Op {
    /// All opcodes (iteration helper).
    pub const ALL: [Op; 18] = [
        Op::And,
        Op::Or,
        Op::Xor,
        Op::Add,
        Op::Sub,
        Op::Mul,
        Op::MacInit,
        Op::Mac,
        Op::MacStore,
        Op::DotInit,
        Op::Dot,
        Op::DotStore,
        Op::Sll,
        Op::Slr,
        Op::Min,
        Op::Max,
        Op::Csrw,
        Op::Sra,
    ];

    pub fn from_code(c: u32) -> Option<Op> {
        Op::ALL.get(c as usize).copied()
    }

    /// Does this op write a result word to the destination address?
    pub fn writes_dest(self) -> bool {
        !matches!(self, Op::MacInit | Op::Mac | Op::DotInit | Op::Dot | Op::Csrw)
    }

    /// Does this op use the multiplier datapath (energy class)?
    pub fn is_mul_class(self) -> bool {
        matches!(self, Op::Mul | Op::MacInit | Op::Mac | Op::MacStore | Op::DotInit | Op::Dot | Op::DotStore)
    }

    /// Does this op use the partitioned adder (energy class)?
    pub fn is_add_class(self) -> bool {
        matches!(self, Op::Add | Op::Sub | Op::Min | Op::Max)
    }

    pub fn mnemonic(self) -> &'static str {
        match self {
            Op::And => "AND",
            Op::Or => "OR",
            Op::Xor => "XOR",
            Op::Add => "ADD",
            Op::Sub => "SUB",
            Op::Mul => "MUL",
            Op::MacInit => "MAC_INIT",
            Op::Mac => "MAC",
            Op::MacStore => "MAC_STORE",
            Op::DotInit => "DOT_INIT",
            Op::Dot => "DOT",
            Op::DotStore => "DOT_STORE",
            Op::Sll => "SLL",
            Op::Slr => "SLR",
            Op::Min => "MIN",
            Op::Max => "MAX",
            Op::Csrw => "CSRW",
            Op::Sra => "SRA",
        }
    }
}

/// A decoded micro-op: opcode + word offsets of the two sources. The
/// destination comes from the bus address and is carried separately.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MicroOp {
    pub op: Op,
    /// Source word offsets (word index within the 32 KiB macro).
    pub src1: u16,
    pub src2: u16,
}

/// Encode the data word of a micro-op.
pub fn encode(m: &MicroOp) -> u32 {
    debug_assert!(m.src1 < 8192 && m.src2 < 8192, "13-bit word offsets");
    ((m.op as u32) << 26) | ((m.src2 as u32) << 13) | (m.src1 as u32)
}

/// Decode a data word written in computing mode.
pub fn decode(w: u32) -> Option<MicroOp> {
    let op = Op::from_code(bits(w, 31, 26))?;
    Some(MicroOp { op, src2: bits(w, 25, 13) as u16, src1: bits(w, 12, 0) as u16 })
}

/// Encode the CSRW micro-op configuring the element width.
pub fn encode_csrw(sew: Sew) -> u32 {
    encode(&MicroOp { op: Op::Csrw, src1: sew.code() as u16, src2: 0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_opcodes() {
        for op in Op::ALL {
            let m = MicroOp { op, src1: 0x1abc & 0x1fff, src2: 0x0123 };
            assert_eq!(decode(encode(&m)), Some(m), "{}", op.mnemonic());
        }
    }

    #[test]
    fn paper_example_layout() {
        // *(BASE + DEST<<2) = ADD << 26 | SRC2 << 13 | SRC1
        let m = MicroOp { op: Op::Add, src1: 7, src2: 9 };
        assert_eq!(encode(&m), (3 << 26) | (9 << 13) | 7);
    }

    #[test]
    fn writeback_classification() {
        assert!(Op::Add.writes_dest());
        assert!(Op::DotStore.writes_dest());
        assert!(Op::MacStore.writes_dest());
        assert!(!Op::Dot.writes_dest());
        assert!(!Op::MacInit.writes_dest());
        assert!(!Op::Csrw.writes_dest());
    }

    #[test]
    fn invalid_opcode_rejected() {
        assert_eq!(decode(0xffff_ffff), None); // opcode 63
        assert_eq!(decode(18 << 26), None);
    }
}
