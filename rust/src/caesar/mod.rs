//! NM-Caesar: the area-efficient, host-microcontrolled NMC macro (§III-A).
//!
//! Microarchitecture model (Fig. 2 / Fig. 3): two single-port 16 KiB SRAM
//! banks, a multi-cycle 32-bit packed-SIMD integer ALU, and a controller
//! that decodes bus writes into micro-ops through a 2-stage pipeline
//! (decode → fetch → execute → writeback, overlapped so a new instruction
//! is accepted **every 2 cycles**; 3 cycles when both source operands live
//! in the same bank and must be fetched sequentially).
//!
//! Functionally the macro is a drop-in 32 KiB SRAM: in *memory* mode
//! ([`Caesar::imc`] = false) reads and writes behave exactly like the
//! reference bank. In *computing* mode, writes become instructions and the
//! data is processed in place.

pub mod compiler;
pub mod isa;

use crate::isa::Sew;
use crate::mem::{Bank, MacroKind};
use crate::simd::{elem, swar};
use isa::{MicroOp, Op};

/// Address space of the macro (32 KiB).
pub const CAPACITY: u32 = 32 * 1024;
/// Words per internal bank (16 KiB each, low/high split).
const BANK_WORDS: u32 = CAPACITY / 4 / 2;

/// Activity counters for the energy model.
#[derive(Debug, Clone, Copy, Default)]
pub struct CaesarStats {
    /// Cycles with at least one instruction in the pipeline.
    pub busy_cycles: u64,
    /// Element-operations by datapath class.
    pub alu_light_elems: u64,
    pub alu_add_elems: u64,
    pub alu_mul_elems: u64,
    /// Instructions executed.
    pub instrs: u64,
    /// Instructions that paid the same-bank sequential-fetch penalty.
    pub same_bank_conflicts: u64,
}

/// The NM-Caesar macro model.
#[derive(Debug, Clone)]
pub struct Caesar {
    /// Two 16 KiB single-port banks: bank 0 = words 0..4095, bank 1 = rest.
    pub banks: [Bank; 2],
    /// `imc` pin: computing mode when true (driven by the host's
    /// configuration register, §III).
    pub imc: bool,
    /// Element width CSR (set by the CSRW micro-op).
    pub sew: Sew,
    /// Packed element-wise MAC accumulator.
    acc_mac: u32,
    /// Word-wise dot-product accumulator (32-bit).
    acc_dot: i32,
    /// Cycle (local time) until which the pipeline is busy.
    busy_until: u64,
    /// Local cycle counter (advanced by [`Caesar::step`]).
    now: u64,
    pub stats: CaesarStats,
}

impl Default for Caesar {
    fn default() -> Self {
        Self::new()
    }
}

impl Caesar {
    pub fn new() -> Self {
        Caesar {
            banks: [Bank::new(MacroKind::Sram16k), Bank::new(MacroKind::Sram16k)],
            imc: false,
            sew: Sew::E32,
            acc_mac: 0,
            acc_dot: 0,
            busy_until: 0,
            now: 0,
            stats: CaesarStats::default(),
        }
    }

    /// Advance one cycle of local time.
    pub fn step(&mut self) {
        self.now += 1;
        if self.now <= self.busy_until {
            self.stats.busy_cycles += 1;
        }
    }

    /// Is the controller ready to accept a new instruction this cycle?
    /// (Backpressures the bus/DMA when the pipeline is full.)
    pub fn ready(&self) -> bool {
        self.now >= self.busy_until
    }

    /// Skip-ahead support (`--timing=event`): advance local time by `k`
    /// cycles in closed form — exactly equivalent to `k` [`Caesar::step`]
    /// calls for *any* `k` (the pipeline countdown is pure counter work;
    /// NM-Caesar raises no interrupts and schedules no events of its
    /// own). Returns the number of those cycles on which the macro was
    /// still busy *after* stepping, i.e. the per-cycle `!ready()`
    /// observations the SoC sums into its utilization counters.
    pub fn skip(&mut self, k: u64) -> u64 {
        self.stats.busy_cycles += self.busy_until.saturating_sub(self.now).min(k);
        let busy_after = self.busy_until.saturating_sub(self.now + 1).min(k);
        self.now += k;
        busy_after
    }

    #[inline]
    fn bank_of(word: u32) -> usize {
        (word >= BANK_WORDS) as usize
    }

    /// Raw word read at a word offset (counts a bank access).
    fn read_word(&mut self, word: u32) -> u32 {
        let b = Self::bank_of(word);
        self.banks[b].read((word % BANK_WORDS) * 4, 4)
    }

    fn write_word(&mut self, word: u32, val: u32) {
        let b = Self::bank_of(word);
        self.banks[b].write((word % BANK_WORDS) * 4, 4, val);
    }

    /// Memory-mode (or computing-mode read) access: behaves like SRAM.
    pub fn mem_read(&mut self, off: u32, size: u32) -> u32 {
        let b = Self::bank_of(off / 4);
        self.banks[b].read(off % (BANK_WORDS * 4), size)
    }

    /// Memory-mode write.
    pub fn mem_write(&mut self, off: u32, size: u32, val: u32) {
        let b = Self::bank_of(off / 4);
        self.banks[b].write(off % (BANK_WORDS * 4), size, val);
    }

    /// Non-counting accessors for test/driver setup and verification.
    pub fn peek_word(&self, word: u32) -> u32 {
        let b = Self::bank_of(word);
        self.banks[b].peek((word % BANK_WORDS) * 4, 4)
    }
    pub fn poke_word(&mut self, word: u32, val: u32) {
        let b = Self::bank_of(word);
        self.banks[b].poke((word % BANK_WORDS) * 4, 4, val);
    }
    /// Bulk load (driver populating inputs; not counted).
    pub fn load(&mut self, byte_off: u32, bytes: &[u8]) {
        // Split across the bank boundary if needed.
        let boundary = BANK_WORDS * 4;
        if byte_off < boundary && byte_off + bytes.len() as u32 > boundary {
            let split = (boundary - byte_off) as usize;
            self.banks[0].load(byte_off, &bytes[..split]);
            self.banks[1].load(0, &bytes[split..]);
        } else {
            let b = Self::bank_of(byte_off / 4);
            self.banks[b].load(byte_off % boundary, bytes);
        }
    }

    /// A bus write arriving in computing mode: decode and execute one
    /// micro-op. `dest_word` is the word offset carried by the bus address.
    ///
    /// The caller must have checked [`Caesar::ready`]; the pipeline then
    /// occupies 2 cycles (3 on a same-bank source conflict, §III-A2).
    pub fn issue(&mut self, dest_word: u32, data: u32) {
        debug_assert!(self.ready(), "issued while pipeline busy");
        let Some(m) = isa::decode(data) else {
            // Undefined opcodes are ignored by the controller (writes in
            // computing mode with reserved opcodes are dropped).
            return;
        };
        let cycles = self.exec(dest_word, &m);
        self.stats.instrs += 1;
        self.busy_until = self.now + cycles as u64;
    }

    /// Execute a micro-op functionally; returns its pipeline occupancy.
    fn exec(&mut self, dest_word: u32, m: &MicroOp) -> u32 {
        if m.op == Op::Csrw {
            self.sew = Sew::from_code(m.src1 as u32).unwrap_or(Sew::E32);
            return 2;
        }
        let same_bank = Self::bank_of(m.src1 as u32) == Self::bank_of(m.src2 as u32);
        let a = self.read_word(m.src1 as u32);
        let b = self.read_word(m.src2 as u32);
        let sew = self.sew;
        let lanes = sew.lanes() as u64;
        let result = match m.op {
            Op::And => {
                self.stats.alu_light_elems += lanes;
                Some(a & b)
            }
            Op::Or => {
                self.stats.alu_light_elems += lanes;
                Some(a | b)
            }
            Op::Xor => {
                self.stats.alu_light_elems += lanes;
                Some(a ^ b)
            }
            Op::Add => {
                self.stats.alu_add_elems += lanes;
                Some(swar::add(a, b, sew))
            }
            Op::Sub => {
                self.stats.alu_add_elems += lanes;
                Some(swar::sub(a, b, sew))
            }
            Op::Mul => {
                self.stats.alu_mul_elems += lanes;
                Some(swar::mul(a, b, sew))
            }
            Op::MacInit => {
                self.stats.alu_mul_elems += lanes;
                self.acc_mac = swar::mul(a, b, sew);
                None
            }
            Op::Mac => {
                self.stats.alu_mul_elems += lanes;
                self.acc_mac = swar::mac(self.acc_mac, a, b, sew);
                None
            }
            Op::MacStore => {
                self.stats.alu_mul_elems += lanes;
                self.acc_mac = swar::mac(self.acc_mac, a, b, sew);
                Some(self.acc_mac)
            }
            Op::DotInit => {
                self.stats.alu_mul_elems += lanes;
                self.acc_dot = swar::dotp_signed(a, b, sew);
                None
            }
            Op::Dot => {
                self.stats.alu_mul_elems += lanes;
                self.acc_dot = self.acc_dot.wrapping_add(swar::dotp_signed(a, b, sew));
                None
            }
            Op::DotStore => {
                self.stats.alu_mul_elems += lanes;
                self.acc_dot = self.acc_dot.wrapping_add(swar::dotp_signed(a, b, sew));
                Some(self.acc_dot as u32)
            }
            Op::Sll => {
                self.stats.alu_light_elems += lanes;
                Some(swar::sll(a, b, sew))
            }
            Op::Slr => {
                self.stats.alu_light_elems += lanes;
                Some(swar::srl(a, b, sew))
            }
            Op::Sra => {
                self.stats.alu_light_elems += lanes;
                Some(swar::sra(a, b, sew))
            }
            Op::Min => {
                self.stats.alu_add_elems += lanes;
                Some(swar::min_signed(a, b, sew))
            }
            Op::Max => {
                self.stats.alu_add_elems += lanes;
                Some(swar::max_signed(a, b, sew))
            }
            Op::Csrw => unreachable!(),
        };
        if let Some(v) = result {
            self.write_word(dest_word, v);
        }
        if same_bank {
            self.stats.same_bank_conflicts += 1;
            3
        } else {
            2
        }
    }

    /// Splat helper: fill a word region with an element value (driver-side
    /// constant setup, e.g. a zero vector for ReLU). Not cycle-counted.
    pub fn splat_word(&mut self, word: u32, value: u32) {
        let w = elem::splat(value, self.sew);
        self.poke_word(word, w);
    }

    pub fn reset_stats(&mut self) {
        self.stats = CaesarStats::default();
        self.banks[0].reset_stats();
        self.banks[1].reset_stats();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive Caesar like the DMA does: wait for ready, issue, step.
    fn run_ops(c: &mut Caesar, ops: &[(u32, u32)]) -> u64 {
        let start = c.now;
        for &(dest, data) in ops {
            while !c.ready() {
                c.step();
            }
            c.issue(dest, data);
            c.step();
        }
        while !c.ready() {
            c.step();
        }
        c.now - start
    }

    #[test]
    fn add_xor_roundtrip() {
        let mut c = Caesar::new();
        c.poke_word(0, 10);
        c.poke_word(4096, 32); // bank 1
        let add = isa::encode(&isa::MicroOp { op: Op::Add, src1: 0, src2: 4096 });
        run_ops(&mut c, &[(100, add)]);
        assert_eq!(c.peek_word(100), 42);
        let xor = isa::encode(&isa::MicroOp { op: Op::Xor, src1: 0, src2: 4096 });
        run_ops(&mut c, &[(101, xor)]);
        assert_eq!(c.peek_word(101), 10 ^ 32);
    }

    #[test]
    fn two_cycles_per_instr_cross_bank() {
        let mut c = Caesar::new();
        let add = isa::encode(&isa::MicroOp { op: Op::Add, src1: 0, src2: 4096 });
        let ops: Vec<_> = (0..32).map(|i| (200 + i, add)).collect();
        let cycles = run_ops(&mut c, &ops);
        assert_eq!(cycles, 64, "expected 2 cycles/instr");
        assert_eq!(c.stats.same_bank_conflicts, 0);
    }

    #[test]
    fn three_cycles_same_bank() {
        let mut c = Caesar::new();
        let add = isa::encode(&isa::MicroOp { op: Op::Add, src1: 0, src2: 1 }); // both bank 0
        let ops: Vec<_> = (0..16).map(|i| (200 + i, add)).collect();
        let cycles = run_ops(&mut c, &ops);
        assert_eq!(cycles, 48, "expected 3 cycles/instr on same-bank sources");
        assert_eq!(c.stats.same_bank_conflicts, 16);
    }

    #[test]
    fn dot_product_family() {
        let mut c = Caesar::new();
        // 8-bit mode: words hold 4 elements each.
        let csrw = isa::encode_csrw(Sew::E8);
        c.poke_word(0, u32::from_le_bytes([1, 2, 3, 4]));
        c.poke_word(1, u32::from_le_bytes([5, 6, 7, 8]));
        c.poke_word(4096, u32::from_le_bytes([1, 1, 1, 1]));
        c.poke_word(4097, u32::from_le_bytes([2, 2, 2, 2]));
        let init = isa::encode(&isa::MicroOp { op: Op::DotInit, src1: 0, src2: 4096 });
        let store = isa::encode(&isa::MicroOp { op: Op::DotStore, src1: 1, src2: 4097 });
        run_ops(&mut c, &[(500, csrw), (500, init), (500, store)]);
        // (1+2+3+4) + 2*(5+6+7+8) = 10 + 52 = 62
        assert_eq!(c.peek_word(500) as i32, 62);
        assert_eq!(c.sew, Sew::E8);
    }

    #[test]
    fn mac_family_packed() {
        let mut c = Caesar::new();
        run_ops(&mut c, &[(0, isa::encode_csrw(Sew::E16))]);
        c.poke_word(0, 0x0003_0002); // elements [2, 3]
        c.poke_word(4096, 0x0005_0004); // elements [4, 5]
        let init = isa::encode(&isa::MicroOp { op: Op::MacInit, src1: 0, src2: 4096 });
        let store = isa::encode(&isa::MicroOp { op: Op::MacStore, src1: 0, src2: 4096 });
        run_ops(&mut c, &[(300, init), (300, store)]);
        // per element: 2*4*2 = 16 ; 3*5*2 = 30
        assert_eq!(c.peek_word(300), 0x001e_0010);
    }

    #[test]
    fn memory_mode_is_transparent() {
        let mut c = Caesar::new();
        c.mem_write(0x100, 4, 0xcafe_f00d);
        assert_eq!(c.mem_read(0x100, 4), 0xcafe_f00d);
        c.mem_write(0x102, 1, 0xaa);
        assert_eq!(c.mem_read(0x100, 4), 0xcaaa_f00d);
        // Crossing into bank 1.
        c.mem_write(16 * 1024 + 8, 4, 77);
        assert_eq!(c.mem_read(16 * 1024 + 8, 4), 77);
        assert_eq!(c.banks[1].stats.writes, 1);
    }

    #[test]
    fn load_across_bank_boundary() {
        let mut c = Caesar::new();
        let bytes: Vec<u8> = (0..16).collect();
        c.load(16 * 1024 - 8, &bytes);
        assert_eq!(c.mem_read(16 * 1024 - 8, 4), 0x0302_0100);
        assert_eq!(c.mem_read(16 * 1024 + 4, 4), 0x0f0e_0d0c);
    }

    #[test]
    fn relu_via_max_against_zero_splat() {
        let mut c = Caesar::new();
        run_ops(&mut c, &[(0, isa::encode_csrw(Sew::E8))]);
        c.splat_word(4096, 0); // zero vector in bank 1
        c.poke_word(0, u32::from_le_bytes([0x80, 5, 0xff, 0x7f])); // [-128, 5, -1, 127]
        let max = isa::encode(&isa::MicroOp { op: Op::Max, src1: 0, src2: 4096 });
        run_ops(&mut c, &[(100, max)]);
        assert_eq!(c.peek_word(100).to_le_bytes(), [0, 5, 0, 0x7f]);
    }

    #[test]
    fn undefined_opcode_ignored() {
        let mut c = Caesar::new();
        c.issue(0, 63 << 26);
        assert_eq!(c.stats.instrs, 0);
        assert!(c.ready());
    }
}
