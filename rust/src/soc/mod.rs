//! The HEEPerator system: X-HEEP host MCU with NMC **tiles** in its
//! memory subsystem (Fig. 1 / Fig. 10), co-simulated cycle by cycle.
//!
//! Topology: one host CPU (CV32E40P-class, configurable), six conventional
//! 32 KiB SRAM banks, `tiles.len()` NMC macros in bank slots 6 and up
//! (each an NM-Caesar or NM-Carus instance behind its own 32 KiB bus
//! window — the paper's drop-in memory-tile property, scaled out), a DMA
//! engine with independent read/write crossbar ports, a flash/ROM for
//! large constant data (AD weights), and the peripheral registers that
//! drive the per-tile mode pins and the DMA.
//!
//! The default [`Soc::heeperator`] configuration is the paper's: tile 0 =
//! NM-Caesar, tile 1 = NM-Carus. [`Soc::with_tiles`] instantiates any mix
//! of up to [`bus::MAX_TILES`] macros — the substrate for the batch
//! scheduler in [`crate::sched`].
//!
//! Per-cycle protocol (the crossbar grants at most one transaction per
//! slave per cycle; DMA ports first, then the CPU data port):
//! 1. internal devices advance ([`crate::caesar::Caesar::step`],
//!    [`crate::carus::Carus::step`] — every tile, every cycle);
//! 2. the DMA write port retires one staged word (NM-Caesar exerts
//!    backpressure through [`crate::caesar::Caesar::ready`]);
//! 3. the DMA read port fetches one stream word;
//! 4. the CPU executes: instruction fetches use the dedicated fetch port
//!    (counted for energy, never arbitrated); data accesses wait while the
//!    target slave was used by the DMA this cycle.
//!
//! Firmware conventions: programs end with `ebreak`; `wfi` sleeps until
//! an *enabled* NM-Carus done interrupt (the [`periph::IRQ_MASK`]
//! register, reset all-ones) or DMA completion.
//!
//! Time advances under one of two disciplines ([`crate::clock`]): the
//! per-cycle reference above, or the default event-driven mode in which
//! [`Soc::run`] skips over strictly quiet spans — cycles that provably
//! only decrement countdowns — updating every counter in closed form
//! and executing all state transitions through the same per-cycle
//! [`Soc::step`] at span boundaries. The two are counter-identical by
//! construction (DESIGN.md §10).

use crate::bus::{self, periph, Master, Slave};
use crate::caesar::Caesar;
use crate::carus::Carus;
use crate::clock::{self, EventKind, EventQueue, TimingMode};
use crate::cpu::{CpuConfig, CpuCore, MemIf};
use crate::dma::{Dma, DmaMode};
use crate::energy::{self, Activity, Breakdown, HostKind};
use crate::isa::rv32::{decode, Instr};
use crate::mem::{Bank, MacroKind};

/// Simulation halt reasons.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Halt {
    /// Firmware executed `ebreak`.
    Done,
    /// Cycle limit exceeded (likely a firmware bug).
    Timeout,
    /// CPU trapped (illegal instruction / register / alignment).
    Trap,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CpuState {
    /// Ready to execute the next instruction.
    Ready,
    /// Multi-cycle instruction in progress.
    Stall(u32),
    /// Waiting for a free slave to perform a data access.
    WaitBus,
    /// Sleeping until an interrupt.
    Wfi,
    Halted,
}

/// The kind of NMC macro populating a tile window.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TileKind {
    Caesar,
    Carus,
}

impl TileKind {
    pub fn name(self) -> &'static str {
        match self {
            TileKind::Caesar => "NM-Caesar",
            TileKind::Carus => "NM-Carus",
        }
    }
}

/// One populated NMC tile window: an NM-Caesar or NM-Carus instance.
pub enum Tile {
    Caesar(Caesar),
    Carus(Carus),
}

impl Tile {
    pub fn kind(&self) -> TileKind {
        match self {
            Tile::Caesar(_) => TileKind::Caesar,
            Tile::Carus(_) => TileKind::Carus,
        }
    }

    /// Advance the macro's internal state by one cycle.
    pub fn step(&mut self) {
        match self {
            Tile::Caesar(c) => c.step(),
            Tile::Carus(c) => c.step(),
        }
    }

    /// The tile is doing work this cycle (utilization accounting).
    pub fn busy(&self) -> bool {
        match self {
            Tile::Caesar(c) => !c.ready(),
            Tile::Carus(c) => c.busy(),
        }
    }

    /// An *autonomous* computation is in flight: the simulation must not
    /// halt while this holds. NM-Caesar is passive (its 2-cycle pipeline
    /// drains in-line with the issuing transfer), so only NM-Carus
    /// kernels keep the system alive past the host's `ebreak`.
    pub fn autonomous_busy(&self) -> bool {
        match self {
            Tile::Caesar(_) => false,
            Tile::Carus(c) => c.busy(),
        }
    }

    /// Skip-ahead support: upcoming strictly-quiet cycles for this tile
    /// (`u64::MAX` = no self-scheduled event). NM-Caesar is passive —
    /// its pipeline countdown is pure counter work with no externally
    /// visible event, so it never bounds the horizon; NM-Carus defers to
    /// [`Carus::quiet_horizon`].
    pub fn quiet_horizon(&self) -> u64 {
        match self {
            Tile::Caesar(_) => u64::MAX,
            Tile::Carus(c) => c.quiet_horizon(),
        }
    }

    /// Advance the tile by `k` quiet cycles in closed form; returns the
    /// number of those cycles the tile counts as busy (the per-cycle
    /// [`Tile::busy`] observations the SoC sums into `tile_busy`).
    pub fn skip(&mut self, k: u64) -> u64 {
        match self {
            Tile::Caesar(c) => c.skip(k),
            Tile::Carus(c) => {
                // Within a quiet span `busy()` is constant: `running`
                // cannot change and the VPU horizon keeps the pipeline
                // state (busy/idle) fixed.
                let busy = c.busy();
                c.skip(k);
                if busy {
                    k
                } else {
                    0
                }
            }
        }
    }

    /// Interrupt pin (NM-Carus completion; NM-Caesar has none).
    pub fn irq(&self) -> bool {
        match self {
            Tile::Caesar(_) => false,
            Tile::Carus(c) => c.irq(),
        }
    }

    /// The tile's mode pin: `imc` (NM-Caesar) / configuration mode
    /// (NM-Carus).
    pub fn mode(&self) -> bool {
        match self {
            Tile::Caesar(c) => c.imc,
            Tile::Carus(c) => c.config_mode,
        }
    }

    pub fn set_mode(&mut self, on: bool) {
        match self {
            Tile::Caesar(c) => c.imc = on,
            Tile::Carus(c) => c.config_mode = on,
        }
    }

    /// Load raw bytes into the tile's storage (initialization; uncounted).
    pub fn load(&mut self, off: u32, bytes: &[u8]) {
        match self {
            Tile::Caesar(c) => c.load(off, bytes),
            Tile::Carus(c) => c.vrf.load(off, bytes),
        }
    }

    /// Read back a byte range for verification (uncounted).
    pub fn dump(&self, off: u32, len: u32) -> Vec<u8> {
        match self {
            Tile::Caesar(c) => (0..len)
                .map(|i| c.banks[((off + i) / 16384) as usize].peek((off + i) % 16384, 1) as u8)
                .collect(),
            Tile::Carus(c) => c.vrf.dump(off, len),
        }
    }

    pub fn reset_stats(&mut self) {
        match self {
            Tile::Caesar(c) => c.reset_stats(),
            Tile::Carus(c) => c.reset_stats(),
        }
    }
}

/// Host-side cycle/energy counters (rolled into [`Activity`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct SocCounters {
    pub cpu_active: u64,
    pub cpu_sleep: u64,
    pub cpu_fetches: u64,
    pub bus_txns: u64,
    pub cpu_wait_cycles: u64,
    pub slave_stall_cycles: u64,
}

/// The full system.
pub struct Soc {
    pub cycle: u64,
    pub cpu: CpuCore,
    pub srams: Vec<Bank>,
    pub rom: Bank,
    /// Populated NMC tile windows (bank slots 6 onward).
    pub tiles: Vec<Tile>,
    /// Per-tile busy cycles since the last [`Soc::reset_stats`]
    /// (utilization accounting for the scale-out reports).
    pub tile_busy: Vec<u64>,
    pub dma: Dma,
    pub counters: SocCounters,
    state: CpuState,
    /// Timing discipline (see [`crate::clock`]); fixed at construction
    /// from the thread's mode, overridable via [`Soc::set_timing`].
    timing: TimingMode,
    /// [`periph::IRQ_MASK`]: bit `i` lets tile `i`'s IRQ wake a `wfi`.
    irq_mask: u32,
    /// Pre-decoded host program (indexed from `code_base`).
    code_base: u32,
    code: Vec<Instr>,
    /// DMA completion interrupt (level; cleared on DMA_STATUS read).
    dma_irq: bool,
    /// Edge detector for DMA completion.
    dma_was_busy: bool,
    /// Slaves used by the DMA ports this cycle (CPU must wait).
    dma_rd_slave: Option<Slave>,
    dma_wr_slave: Option<Slave>,
    /// NM-Carus lane count this instance was built with (kept so
    /// [`Soc::recycle`] can rebuild the tiles identically).
    lanes: u32,
}

impl Soc {
    /// Build a HEEPerator instance with the paper's tile set (tile 0 =
    /// NM-Caesar, tile 1 = NM-Carus). `host` selects the CPU (Table V uses
    /// CV32E40P; Table VI NMC rows use CV32E20). `lanes` configures
    /// NM-Carus.
    pub fn new(host: CpuConfig, lanes: u32) -> Self {
        Self::with_tiles(host, lanes, &[TileKind::Caesar, TileKind::Carus])
    }

    /// Build a system with an arbitrary tile mix: `kinds[i]` populates
    /// bus window `i` ([`bus::tile_base`]). This is the scale-out
    /// constructor behind `heeperator scale`.
    pub fn with_tiles(host: CpuConfig, lanes: u32, kinds: &[TileKind]) -> Self {
        assert!(
            !kinds.is_empty() && kinds.len() <= bus::MAX_TILES,
            "1..={} tiles, got {}",
            bus::MAX_TILES,
            kinds.len()
        );
        let tiles: Vec<Tile> = kinds
            .iter()
            .map(|k| match k {
                TileKind::Caesar => Tile::Caesar(Caesar::new()),
                TileKind::Carus => Tile::Carus(Carus::new(lanes)),
            })
            .collect();
        let tile_busy = vec![0; tiles.len()];
        Soc {
            cycle: 0,
            cpu: CpuCore::new(host, 0),
            srams: (0..bus::NUM_SRAM_BANKS).map(|_| Bank::new(MacroKind::Sram32k)).collect(),
            rom: Bank::rom(Vec::new()),
            tiles,
            tile_busy,
            dma: Dma::new(),
            counters: SocCounters::default(),
            state: CpuState::Ready,
            timing: clock::mode(),
            irq_mask: u32::MAX,
            code_base: 0,
            code: Vec::new(),
            dma_irq: false,
            dma_was_busy: false,
            dma_rd_slave: None,
            dma_wr_slave: None,
            lanes,
        }
    }

    /// Restore this instance to the state [`Soc::with_tiles`] builds — a
    /// worker that owns a long-lived replica calls this between batches
    /// instead of constructing a new system. Implemented as an in-place
    /// rebuild from the recorded construction parameters (host config,
    /// lane count, tile mix), so a recycled SoC is *definitionally*
    /// indistinguishable from a fresh one: the simulated timing and
    /// energy of whatever runs next are bit-identical either way.
    pub fn recycle(&mut self) {
        let kinds: Vec<TileKind> = self.tiles.iter().map(|t| t.kind()).collect();
        *self = Soc::with_tiles(self.cpu.cfg, self.lanes, &kinds);
    }

    /// Default paper configuration: CV32E40P host, 4-lane NM-Carus.
    pub fn heeperator() -> Self {
        Self::new(CpuConfig::CV32E40P, 4)
    }

    /// Homogeneous scale-out configuration: `count` tiles of one kind
    /// behind the CV32E40P host.
    pub fn scale_out(kind: TileKind, count: usize, lanes: u32) -> Self {
        Self::with_tiles(CpuConfig::CV32E40P, lanes, &vec![kind; count])
    }

    /// First tile of `kind`, if any.
    pub fn first_tile(&self, kind: TileKind) -> Option<usize> {
        self.tiles.iter().position(|t| t.kind() == kind)
    }

    /// The first NM-Caesar tile (panics if the config has none — callers
    /// of the legacy single-tile API run on [`Soc::heeperator`]).
    pub fn caesar(&self) -> &Caesar {
        self.tiles
            .iter()
            .find_map(|t| match t {
                Tile::Caesar(c) => Some(c),
                _ => None,
            })
            .expect("no NM-Caesar tile in this configuration")
    }

    pub fn caesar_mut(&mut self) -> &mut Caesar {
        self.tiles
            .iter_mut()
            .find_map(|t| match t {
                Tile::Caesar(c) => Some(c),
                _ => None,
            })
            .expect("no NM-Caesar tile in this configuration")
    }

    /// The first NM-Carus tile (panics if the config has none).
    pub fn carus(&self) -> &Carus {
        self.tiles
            .iter()
            .find_map(|t| match t {
                Tile::Carus(c) => Some(c),
                _ => None,
            })
            .expect("no NM-Carus tile in this configuration")
    }

    pub fn carus_mut(&mut self) -> &mut Carus {
        self.tiles
            .iter_mut()
            .find_map(|t| match t {
                Tile::Carus(c) => Some(c),
                _ => None,
            })
            .expect("no NM-Carus tile in this configuration")
    }

    /// Load the host firmware into SRAM bank `bank` and point the CPU at it.
    /// The program is pre-decoded (the model's I-cache stand-in; fetches are
    /// still charged as code-bank reads for energy).
    pub fn load_firmware(&mut self, prog: &crate::asm::Program, bank: usize) {
        let base = bus::SRAM_BASE + bank as u32 * bus::BANK_SIZE;
        assert!(prog.base >= base && prog.base + prog.size() <= base + bus::BANK_SIZE,
            "firmware must sit in bank {bank}");
        self.srams[bank].load(prog.base - base, &prog.bytes());
        self.code_base = prog.base;
        self.code = prog.words.iter().map(|w| decode(*w).expect("firmware decodes")).collect();
        self.cpu.pc = prog.base;
        // A previous program's ebreak leaves the core Halted; loading new
        // firmware un-halts it so multi-phase drivers (the per-layer model
        // pipeline) can run successive programs without a full recycle.
        self.state = CpuState::Ready;
    }

    /// Load raw data at an absolute bus address (initialization; uncounted).
    pub fn load_data(&mut self, addr: u32, bytes: &[u8]) {
        match bus::decode(addr).expect("mapped address") {
            (Slave::Sram(b), off) => self.srams[b].load(off, bytes),
            (Slave::Tile(i), off) => {
                let n = self.tiles.len();
                self.tiles
                    .get_mut(i)
                    .unwrap_or_else(|| panic!("tile window {i} unpopulated ({n} tiles)"))
                    .load(off, bytes)
            }
            (Slave::Rom, off) => {
                // ROM contents are set via `set_rom`; allow appending here.
                let _ = off;
                panic!("load ROM via set_rom()");
            }
            (Slave::Periph, _) => panic!("cannot load data into peripherals"),
        }
    }

    /// Load a byte region that may span multiple banks / tile windows
    /// (initialization; uncounted).
    pub fn load_region(&mut self, addr: u32, bytes: &[u8]) {
        let mut off = 0usize;
        while off < bytes.len() {
            let a = addr + off as u32;
            let room = (bus::BANK_SIZE - a % bus::BANK_SIZE) as usize;
            let chunk = room.min(bytes.len() - off);
            self.load_data(a, &bytes[off..off + chunk]);
            off += chunk;
        }
    }

    /// Install flash/ROM contents (AD weights etc.).
    pub fn set_rom(&mut self, contents: Vec<u8>) {
        self.rom = Bank::rom(contents);
    }

    /// Read back a byte range for verification (uncounted).
    pub fn dump(&self, addr: u32, len: u32) -> Vec<u8> {
        match bus::decode(addr).expect("mapped address") {
            (Slave::Sram(b), off) => self.srams[b].dump(off, len),
            (Slave::Tile(i), off) => {
                let n = self.tiles.len();
                self.tiles
                    .get(i)
                    .unwrap_or_else(|| panic!("tile window {i} unpopulated ({n} tiles)"))
                    .dump(off, len)
            }
            (Slave::Rom, off) => self.rom.dump(off, len),
            (Slave::Periph, _) => panic!("cannot dump peripherals"),
        }
    }

    /// [`Soc::dump`] across bank boundaries (verification; uncounted).
    pub fn dump_region(&self, addr: u32, len: u32) -> Vec<u8> {
        let mut out = Vec::with_capacity(len as usize);
        let mut off = 0u32;
        while off < len {
            let a = addr + off;
            let room = bus::BANK_SIZE - a % bus::BANK_SIZE;
            let chunk = room.min(len - off);
            out.extend(self.dump(a, chunk));
            off += chunk;
        }
        out
    }

    /// The active timing discipline.
    pub fn timing(&self) -> TimingMode {
        self.timing
    }

    /// Override the timing discipline (tests / differential harnesses).
    pub fn set_timing(&mut self, mode: TimingMode) {
        self.timing = mode;
    }

    /// Every halt condition is quiescent: firmware done, DMA drained,
    /// no autonomous tile computation in flight.
    fn halted(&self) -> bool {
        self.state == CpuState::Halted
            && !self.dma.busy()
            && !self.tiles.iter().any(Tile::autonomous_busy)
    }

    /// An interrupt that would wake a `wfi`-sleeping CPU is pending:
    /// DMA completion (always enabled) or a masked-in tile IRQ.
    fn irq_pending(&self) -> bool {
        self.dma_irq
            || self
                .tiles
                .iter()
                .enumerate()
                .any(|(i, t)| self.irq_mask & (1 << i) != 0 && t.irq())
    }

    /// Run until the firmware halts. Returns (halt reason, cycles run).
    ///
    /// How simulated time advances depends on the [`TimingMode`]: the
    /// per-cycle reference steps every cycle; the default event-driven
    /// mode skips strictly quiet spans in closed form. Outputs, halt
    /// reason, cycle counts and every activity/energy counter are
    /// identical between the two (locked by
    /// `rust/tests/timing_equivalence.rs`).
    pub fn run(&mut self, max_cycles: u64) -> (Halt, u64) {
        match self.timing {
            TimingMode::Cycle => self.run_cycle(max_cycles),
            TimingMode::Event => self.run_event(max_cycles),
        }
    }

    /// Legacy per-cycle loop: the differential reference.
    fn run_cycle(&mut self, max_cycles: u64) -> (Halt, u64) {
        let start = self.cycle;
        loop {
            if self.halted() {
                return (Halt::Done, self.cycle - start);
            }
            if self.cycle - start >= max_cycles {
                return (Halt::Timeout, self.cycle - start);
            }
            if self.step() {
                return (Halt::Trap, self.cycle - start);
            }
        }
    }

    /// Event-driven loop: between steps, derive the next interesting
    /// cycle from component state and jump there in one closed-form
    /// update. Clamping the jump to the remaining cycle budget keeps
    /// even `Halt::Timeout` counter-identical to per-cycle stepping.
    fn run_event(&mut self, max_cycles: u64) -> (Halt, u64) {
        let start = self.cycle;
        loop {
            if self.halted() {
                return (Halt::Done, self.cycle - start);
            }
            let elapsed = self.cycle - start;
            if elapsed >= max_cycles {
                return (Halt::Timeout, elapsed);
            }
            let k = self.quiet_horizon().min(max_cycles - elapsed);
            if k == 0 {
                if self.step() {
                    return (Halt::Trap, self.cycle - start);
                }
            } else {
                self.skip_quiet(k);
            }
        }
    }

    /// Number of upcoming cycles that are *strictly quiet* — every one
    /// of them would only decrement countdowns (tile pipelines, the CPU
    /// stall counter) and bump cycle counters, with no state transition
    /// and no externally visible change. The earliest entry of the
    /// derived event queue is the first cycle that must run through
    /// [`Soc::step`]; `u64::MAX` means nothing is scheduled at all (the
    /// run can only end by exhausting its cycle budget).
    fn quiet_horizon(&self) -> u64 {
        // Degenerate immediate events, checked without building a queue:
        // an executing CPU ([`EventKind::PollRetry`]) and an active DMA
        // or pending completion edge ([`EventKind::DmaDone`]) make the
        // very next cycle interesting — as does a pending wake IRQ.
        match self.state {
            CpuState::Ready | CpuState::WaitBus => return 0,
            CpuState::Wfi if self.irq_pending() => return 0,
            _ => {}
        }
        if self.dma.busy() || self.dma_was_busy {
            return 0;
        }
        let mut q = EventQueue::new();
        if let CpuState::Stall(n) = self.state {
            q.push(self.cycle + u64::from(n), EventKind::CpuStallRelease);
        }
        for (i, t) in self.tiles.iter().enumerate() {
            let h = t.quiet_horizon();
            if h != u64::MAX {
                q.push(self.cycle + h + 1, EventKind::TileDone(i));
            }
        }
        match q.pop() {
            Some(ev) => ev.at - self.cycle - 1,
            None => u64::MAX,
        }
    }

    /// Advance `k` strictly quiet cycles in closed form; exactly
    /// equivalent to `k` calls of [`Soc::step`] provided
    /// `k <= self.quiet_horizon()`.
    fn skip_quiet(&mut self, k: u64) {
        self.cycle += k;
        for (i, t) in self.tiles.iter_mut().enumerate() {
            self.tile_busy[i] += t.skip(k);
        }
        // The DMA is idle in a quiet span; per-cycle stepping would
        // clear the port-arbitration markers every cycle.
        self.dma_rd_slave = None;
        self.dma_wr_slave = None;
        match self.state {
            CpuState::Halted | CpuState::Wfi => self.counters.cpu_sleep += k,
            CpuState::Stall(n) => {
                self.counters.cpu_active += k;
                self.state = CpuState::Stall(n - k as u32);
            }
            CpuState::Ready | CpuState::WaitBus => {
                unreachable!("quiet span with an executing CPU")
            }
        }
    }

    /// One system cycle. Returns true on a CPU trap (modeling bug).
    pub fn step(&mut self) -> bool {
        self.cycle += 1;
        for (i, t) in self.tiles.iter_mut().enumerate() {
            t.step();
            if t.busy() {
                self.tile_busy[i] += 1;
            }
        }
        self.dma_rd_slave = None;
        self.dma_wr_slave = None;
        if self.dma.busy() {
            self.dma.tick_active();
            self.step_dma_ports();
        } else if self.dma_was_busy {
            self.dma_irq = true; // completion edge
            self.dma_was_busy = false;
        }
        self.step_cpu_phase()
    }

    /// DMA read/write crossbar ports for this cycle.
    fn step_dma_ports(&mut self) {
        // --- DMA write port ------------------------------------------------
        if let Some(w) = self.dma.want_write() {
            if let Some((slave, off)) = bus::decode(w.addr) {
                let ok = match slave {
                    Slave::Tile(i) => match self.tiles.get_mut(i) {
                        Some(Tile::Caesar(c)) if c.imc => {
                            if c.ready() {
                                c.issue(off / 4, w.data);
                                true
                            } else {
                                self.counters.slave_stall_cycles += 1;
                                false
                            }
                        }
                        Some(Tile::Caesar(c)) => {
                            c.mem_write(off, 4, w.data);
                            true
                        }
                        Some(Tile::Carus(c)) => {
                            c.bus_write(off, 4, w.data);
                            true
                        }
                        None => true, // unpopulated window: dropped
                    },
                    Slave::Sram(b) => {
                        self.srams[b].write(off, 4, w.data);
                        true
                    }
                    Slave::Periph | Slave::Rom => true, // dropped
                };
                if ok {
                    self.dma.complete_write();
                    self.counters.bus_txns += 1;
                    self.dma_wr_slave = Some(slave);
                }
            } else {
                self.dma.complete_write(); // unmapped: dropped
            }
        }

        // --- DMA read port --------------------------------------------------
        if let Some(addr) = self.dma.want_read() {
            if let Some((slave, off)) = bus::decode(addr) {
                // The read port may not hit the slave the write port used
                // this cycle (single port per slave).
                if Some(slave) != self.dma_wr_slave {
                    let data = match slave {
                        Slave::Sram(b) => self.srams[b].read(off, 4),
                        Slave::Rom => self.rom.read(off, 4),
                        Slave::Tile(i) => match self.tiles.get_mut(i) {
                            Some(Tile::Caesar(c)) => c.mem_read(off, 4),
                            Some(Tile::Carus(c)) => c.bus_read(off, 4).0,
                            None => 0,
                        },
                        Slave::Periph => 0,
                    };
                    self.dma.complete_read(data);
                    self.counters.bus_txns += 1;
                    self.dma_rd_slave = Some(slave);
                }
            }
        }
        self.dma_was_busy = true;
    }

    /// CPU phase of the cycle. Returns true on a trap.
    fn step_cpu_phase(&mut self) -> bool {
        // --- CPU -------------------------------------------------------------
        match self.state {
            CpuState::Halted => {
                self.counters.cpu_sleep += 1;
                false
            }
            CpuState::Wfi => {
                if self.irq_pending() {
                    self.state = CpuState::Ready;
                    self.counters.cpu_active += 1;
                } else {
                    self.counters.cpu_sleep += 1;
                }
                false
            }
            CpuState::Stall(n) => {
                self.counters.cpu_active += 1;
                self.state = if n > 1 { CpuState::Stall(n - 1) } else { CpuState::Ready };
                false
            }
            CpuState::Ready | CpuState::WaitBus => {
                self.counters.cpu_active += 1;
                self.exec_cpu()
            }
        }
    }

    /// Fetch, arbitrate, execute one host instruction.
    fn exec_cpu(&mut self) -> bool {
        let idx = (self.cpu.pc.wrapping_sub(self.code_base) / 4) as usize;
        let Some(&instr) = self.code.get(idx) else {
            // Fell off the program: treat as a trap.
            return true;
        };

        // Data-access arbitration: the target slave must be free.
        if let Instr::Load { rs1, off, .. } | Instr::Store { rs1, off, .. } = instr {
            let addr = self.cpu.regs[(rs1 & 31) as usize].wrapping_add(off as u32);
            if let Some((slave, soff)) = bus::decode(addr) {
                let dma_holds = Some(slave) == self.dma_rd_slave || Some(slave) == self.dma_wr_slave;
                // A computing NM-Caesar tile backpressures host stores the
                // same way it backpressures the DMA write port.
                let caesar_busy = match slave {
                    Slave::Tile(i) => matches!(
                        self.tiles.get(i),
                        Some(Tile::Caesar(c))
                            if c.imc && matches!(instr, Instr::Store { .. }) && !c.ready()
                    ),
                    _ => false,
                };
                if dma_holds || caesar_busy {
                    self.counters.cpu_wait_cycles += 1;
                    self.state = CpuState::WaitBus;
                    return false;
                }
                let _ = soff;
            }
        }

        self.counters.cpu_fetches += 1;
        // Fast path: non-memory instructions never touch the bus — skip
        // the split-borrow port construction (hot-loop win, see
        // EXPERIMENTS.md §Perf).
        if !matches!(instr, Instr::Load { .. } | Instr::Store { .. }) {
            struct NoMem;
            impl MemIf for NoMem {
                fn read(&mut self, _a: u32, _s: u32) -> u32 {
                    unreachable!("non-memory instruction accessed the bus")
                }
                fn write(&mut self, _a: u32, _s: u32, _v: u32) {}
            }
            return match self.cpu.exec(&instr, &mut NoMem) {
                Ok(eff) => {
                    if eff.halted {
                        self.state = CpuState::Halted;
                    } else if eff.wfi {
                        self.state = CpuState::Wfi;
                    } else {
                        self.state =
                            if eff.cycles > 1 { CpuState::Stall(eff.cycles - 1) } else { CpuState::Ready };
                    }
                    false
                }
                Err(_) => true,
            };
        }
        // Split-borrow the slave side for the MemIf.
        let mut port = HostPort {
            srams: &mut self.srams,
            rom: &mut self.rom,
            tiles: &mut self.tiles,
            dma: &mut self.dma,
            dma_irq: &mut self.dma_irq,
            irq_mask: &mut self.irq_mask,
            cycle: self.cycle,
            extra_cycles: 0,
        };
        match self.cpu.exec(&instr, &mut port) {
            Ok(eff) => {
                let extra = port.extra_cycles;
                if eff.mem.is_some() {
                    self.counters.bus_txns += 1;
                }
                if eff.halted {
                    self.state = CpuState::Halted;
                } else if eff.wfi {
                    self.state = CpuState::Wfi;
                } else {
                    let total = eff.cycles + extra;
                    self.state = if total > 1 { CpuState::Stall(total - 1) } else { CpuState::Ready };
                }
                false
            }
            Err(_) => true,
        }
    }

    /// Reset all activity counters (start of the measured region).
    pub fn reset_stats(&mut self) {
        self.counters = SocCounters::default();
        for b in &mut self.srams {
            b.reset_stats();
        }
        self.rom.reset_stats();
        for t in &mut self.tiles {
            t.reset_stats();
        }
        for b in &mut self.tile_busy {
            *b = 0;
        }
        self.dma.stats = Default::default();
        self.cycle = 0;
    }

    /// Roll up the activity record for the energy model, summing
    /// same-kind event counts across every tile.
    pub fn activity(&self) -> Activity {
        let mut mem_reads: Vec<(MacroKind, u64)> = Vec::new();
        let mut mem_writes: Vec<(MacroKind, u64)> = Vec::new();
        let add = |v: &mut Vec<(MacroKind, u64)>, k: MacroKind, n: u64| {
            if n > 0 {
                v.push((k, n));
            }
        };
        let mut sram_r = 0;
        let mut sram_w = 0;
        for b in &self.srams {
            sram_r += b.stats.reads;
            sram_w += b.stats.writes;
        }
        add(&mut mem_reads, MacroKind::Sram32k, sram_r);
        add(&mut mem_writes, MacroKind::Sram32k, sram_w);
        add(&mut mem_reads, MacroKind::Rom, self.rom.stats.reads);

        let mut act = Activity {
            cycles: self.cycle,
            cpu_active: self.counters.cpu_active,
            cpu_sleep: self.counters.cpu_sleep,
            cpu_fetches: self.counters.cpu_fetches,
            bus_txns: self.counters.bus_txns,
            dma_active: self.dma.stats.active_cycles,
            nmc_tiles: self.tiles.len() as u32,
            host_kind: if self.cpu.cfg.rv32e { HostKind::Cv32e20 } else { HostKind::Cv32e40p },
            ..Activity::default()
        };
        let (mut c16_r, mut c16_w, mut v8_r, mut v8_w) = (0u64, 0u64, 0u64, 0u64);
        for t in &self.tiles {
            match t {
                Tile::Caesar(c) => {
                    // NM-Caesar internal banks.
                    c16_r += c.banks[0].stats.reads + c.banks[1].stats.reads;
                    c16_w += c.banks[0].stats.writes + c.banks[1].stats.writes;
                    act.caesar_busy += c.stats.busy_cycles;
                    act.caesar_alu_light += c.stats.alu_light_elems;
                    act.caesar_alu_add += c.stats.alu_add_elems;
                    act.caesar_alu_mul += c.stats.alu_mul_elems;
                }
                Tile::Carus(c) => {
                    // NM-Carus VRF: host accesses (bank counters) + VPU
                    // word accesses.
                    let (vr, vw) = c.vrf.host_accesses();
                    v8_r += vr + c.vpu.stats.vrf_reads;
                    v8_w += vw + c.vpu.stats.vrf_writes;
                    act.carus_ecpu_active += c.stats.ecpu_active_cycles;
                    act.carus_ecpu_sleep += c.stats.ecpu_sleep_cycles;
                    act.carus_emem_accesses += c.stats.emem_accesses;
                    act.carus_vpu_busy += c.vpu.stats.busy_cycles;
                    act.carus_vpu_idle += c.vpu.stats.idle_cycles;
                    act.carus_alu_light += c.vpu.stats.alu_light_elems;
                    act.carus_alu_add += c.vpu.stats.alu_add_elems;
                    act.carus_alu_mul += c.vpu.stats.alu_mul_elems;
                }
            }
        }
        add(&mut mem_reads, MacroKind::Sram16k, c16_r);
        add(&mut mem_writes, MacroKind::Sram16k, c16_w);
        add(&mut mem_reads, MacroKind::Sram8k, v8_r);
        add(&mut mem_writes, MacroKind::Sram8k, v8_w);
        act.mem_reads = mem_reads;
        act.mem_writes = mem_writes;
        act
    }

    /// Energy breakdown of the run so far.
    pub fn energy(&self) -> Breakdown {
        energy::energy(&self.activity())
    }
}

/// The CPU's view of the system (data port + peripherals).
struct HostPort<'a> {
    srams: &'a mut Vec<Bank>,
    rom: &'a mut Bank,
    tiles: &'a mut Vec<Tile>,
    dma: &'a mut Dma,
    dma_irq: &'a mut bool,
    irq_mask: &'a mut u32,
    cycle: u64,
    /// Slave-imposed extra cycles for this access (e.g. Carus bank conflict).
    extra_cycles: u32,
}

impl HostPort<'_> {
    fn first_mut(&mut self, kind: TileKind) -> Option<&mut Tile> {
        self.tiles.iter_mut().find(|t| t.kind() == kind)
    }

    fn periph_read(&mut self, off: u32) -> u32 {
        match off {
            periph::CAESAR_IMC => {
                self.first_mut(TileKind::Caesar).map_or(0, |t| t.mode() as u32)
            }
            periph::CARUS_MODE => {
                self.first_mut(TileKind::Carus).map_or(0, |t| t.mode() as u32)
            }
            periph::DMA_STATUS => {
                let v = self.dma.busy() as u32;
                *self.dma_irq = false; // reading status acknowledges
                v
            }
            periph::MCYCLE => self.cycle as u32,
            periph::IRQ_MASK => *self.irq_mask,
            _ if (periph::TILE_MODE_BASE..periph::tile_mode(bus::MAX_TILES)).contains(&off) => {
                let i = ((off - periph::TILE_MODE_BASE) / 4) as usize;
                self.tiles.get(i).map_or(0, |t| t.mode() as u32)
            }
            _ if (periph::TILE_STATUS_BASE..periph::tile_status(bus::MAX_TILES)).contains(&off) => {
                let i = ((off - periph::TILE_STATUS_BASE) / 4) as usize;
                self.tiles.get(i).map_or(0, |t| t.busy() as u32)
            }
            _ => 0,
        }
    }

    fn periph_write(&mut self, off: u32, val: u32) {
        match off {
            periph::CAESAR_IMC => {
                if let Some(t) = self.first_mut(TileKind::Caesar) {
                    t.set_mode(val & 1 != 0);
                }
            }
            periph::CARUS_MODE => {
                if let Some(t) = self.first_mut(TileKind::Carus) {
                    t.set_mode(val & 1 != 0);
                }
            }
            periph::DMA_SRC => self.dma.staging.0 = val,
            periph::DMA_DST => self.dma.staging.1 = val,
            periph::DMA_LEN => self.dma.staging.2 = val,
            periph::DMA_CTL => {
                let mode = if val & 2 != 0 { DmaMode::CaesarStream } else { DmaMode::Copy };
                let (s, d, l) = self.dma.staging;
                self.dma.start(mode, s, d, l);
                *self.dma_irq = false;
            }
            periph::IRQ_MASK => *self.irq_mask = val,
            _ if (periph::TILE_MODE_BASE..periph::tile_mode(bus::MAX_TILES)).contains(&off) => {
                let i = ((off - periph::TILE_MODE_BASE) / 4) as usize;
                if let Some(t) = self.tiles.get_mut(i) {
                    t.set_mode(val & 1 != 0);
                }
            }
            _ => {}
        }
    }
}

impl MemIf for HostPort<'_> {
    fn read(&mut self, addr: u32, size: u32) -> u32 {
        match bus::decode(addr) {
            Some((Slave::Sram(b), off)) => self.srams[b].read(off, size),
            Some((Slave::Rom, off)) => self.rom.read(off, size),
            Some((Slave::Tile(i), off)) => match self.tiles.get_mut(i) {
                Some(Tile::Caesar(c)) => c.mem_read(off, size),
                Some(Tile::Carus(c)) => {
                    let (v, p) = c.bus_read(off, size);
                    self.extra_cycles += p;
                    v
                }
                None => 0,
            },
            Some((Slave::Periph, off)) => self.periph_read(off),
            None => 0,
        }
    }

    fn write(&mut self, addr: u32, size: u32, val: u32) {
        match bus::decode(addr) {
            Some((Slave::Sram(b), off)) => self.srams[b].write(off, size, val),
            Some((Slave::Rom, _)) => {}
            Some((Slave::Tile(i), off)) => match self.tiles.get_mut(i) {
                Some(Tile::Caesar(c)) => {
                    if c.imc {
                        // Host-driven compute: the online `*(BASE+DEST<<2)=op`
                        // pattern. Readiness was checked before exec.
                        c.issue(off / 4, val);
                    } else {
                        c.mem_write(off, size, val);
                    }
                }
                Some(Tile::Carus(c)) => {
                    let p = c.bus_write(off, size, val);
                    self.extra_cycles += p;
                }
                None => {}
            },
            Some((Slave::Periph, off)) => self.periph_write(off, val),
            None => {}
        }
        let _ = Master::Cpu;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::bus::{CAESAR_BASE, CARUS_BASE, PERIPH_BASE};
    use crate::isa::reg::*;
    use crate::isa::Sew;

    const CODE_BASE: u32 = bus::SRAM_BASE; // bank 0

    fn firmware(build: impl FnOnce(&mut Asm)) -> crate::asm::Program {
        let mut a = Asm::new(CODE_BASE);
        build(&mut a);
        a.assemble().unwrap()
    }

    #[test]
    fn cpu_memcpy_between_banks() {
        let mut soc = Soc::heeperator();
        let src = bus::BANK_SIZE; // bank 1
        let dst = 2 * bus::BANK_SIZE; // bank 2
        soc.load_data(src, &(0..64u8).collect::<Vec<_>>());
        let fw = firmware(|a| {
            a.li(A0, src as i32)
                .li(A1, dst as i32)
                .li(A2, 16)
                .label("loop")
                .lw(T0, 0, A0)
                .sw(T0, 0, A1)
                .addi(A0, A0, 4)
                .addi(A1, A1, 4)
                .addi(A2, A2, -1)
                .bne(A2, ZERO, "loop")
                .ebreak();
        });
        soc.load_firmware(&fw, 0);
        let (halt, cycles) = soc.run(100_000);
        assert_eq!(halt, Halt::Done);
        assert_eq!(soc.dump(dst, 64), (0..64u8).collect::<Vec<_>>());
        // 8 instr/iter: 6×1 + bne(3) ... ≈ 10/iter (+setup).
        assert!(cycles < 16 * 12 + 20, "cycles = {cycles}");
    }

    #[test]
    fn caesar_host_driven_compute() {
        use crate::caesar::isa as cisa;
        let mut soc = Soc::heeperator();
        // Data: word 0 = 5 (bank 0), word 4096 = 7 (bank 1).
        soc.caesar_mut().poke_word(0, 5);
        soc.caesar_mut().poke_word(4096, 7);
        let add_word = cisa::encode(&cisa::MicroOp { op: cisa::Op::Add, src1: 0, src2: 4096 });
        let fw = firmware(|a| {
            a.li(T0, (PERIPH_BASE + periph::CAESAR_IMC) as i32)
                .li(T1, 1)
                .sw(T1, 0, T0) // imc = 1
                .li(A0, CAESAR_BASE as i32)
                .li(A1, add_word as i32)
                .sw(A1, 100 * 4, A0) // ADD → dest word 100
                .li(T1, 0)
                .sw(T1, 0, T0) // imc = 0
                .lw(A2, 100 * 4, A0) // read back
                .ebreak();
        });
        soc.load_firmware(&fw, 0);
        let (halt, _) = soc.run(10_000);
        assert_eq!(halt, Halt::Done);
        assert_eq!(soc.cpu.regs[A2 as usize], 12);
    }

    #[test]
    fn dma_streams_caesar_microops() {
        use crate::caesar::compiler::CaesarProgram;
        let mut soc = Soc::heeperator();
        // 64 element-wise ADDs on 32-bit data.
        for i in 0..64 {
            soc.caesar_mut().poke_word(i, i);
            soc.caesar_mut().poke_word(4096 + i, 1000);
        }
        let mut p = CaesarProgram::new();
        p.csrw(Sew::E32);
        for i in 0..64 {
            p.add(2048 + i, i, 4096 + i);
        }
        let stream = p.to_stream(CAESAR_BASE);
        let stream_addr = bus::BANK_SIZE; // bank 1
        soc.load_data(stream_addr, &stream);
        let fw = firmware(|a| {
            a.li(T0, (PERIPH_BASE + periph::CAESAR_IMC) as i32)
                .li(T1, 1)
                .sw(T1, 0, T0)
                // Program DMA: src, dst(unused), len, ctl(start|stream).
                .li(T0, (PERIPH_BASE + periph::DMA_SRC) as i32)
                .li(T1, stream_addr as i32)
                .sw(T1, 0, T0)
                .li(T0, (PERIPH_BASE + periph::DMA_LEN) as i32)
                .li(T1, p.stream_len() as i32)
                .sw(T1, 0, T0)
                .li(T0, (PERIPH_BASE + periph::DMA_CTL) as i32)
                .li(T1, 0b11)
                .sw(T1, 0, T0)
                // Poll DMA status.
                .li(T0, (PERIPH_BASE + periph::DMA_STATUS) as i32)
                .label("wait")
                .lw(T1, 0, T0)
                .bne(T1, ZERO, "wait")
                .ebreak();
        });
        soc.load_firmware(&fw, 0);
        soc.reset_stats();
        let (halt, cycles) = soc.run(100_000);
        assert_eq!(halt, Halt::Done);
        for i in 0..64 {
            assert_eq!(soc.caesar().peek_word(2048 + i), 1000 + i, "word {i}");
        }
        // 65 micro-ops at 2 cycles sustained ≈ 130 cycles + setup.
        assert!(cycles < 230, "cycles = {cycles}");
        assert_eq!(soc.caesar().stats.instrs, 65);
    }

    #[test]
    fn carus_offload_with_wfi() {
        let mut soc = Soc::heeperator();
        // Inputs in the Carus VRF (as the host would have placed them).
        let vl = 64u32;
        for j in 0..vl {
            soc.carus_mut().vrf.set_elem(0, j, vl, Sew::E32, j);
            soc.carus_mut().vrf.set_elem(1, j, vl, Sew::E32, 2 * j);
        }
        // Carus kernel: v2 = v0 + v1.
        let mut k = Asm::new(0);
        k.li(A0, vl as i32).vsetvli(T0, A0, Sew::E32).vadd_vv(2, 0, 1).ebreak();
        let kprog = k.assemble().unwrap();
        soc.carus_mut().load_kernel(&kprog.words);
        // Host: config mode → start → wfi → check done → ack.
        let fw = firmware(|a| {
            a.li(T0, (PERIPH_BASE + periph::CARUS_MODE) as i32)
                .li(T1, 1)
                .sw(T1, 0, T0) // config mode
                .li(A0, (CARUS_BASE + crate::carus::CTL_OFFSET) as i32)
                .li(T1, crate::carus::CTL_START as i32)
                .sw(T1, 0, A0) // start kernel
                .wfi()
                .lw(A1, 0, A0) // status
                .sw(ZERO, 0, A0) // ack done
                .li(T1, 0)
                .sw(T1, 0, T0) // back to memory mode
                .ebreak();
        });
        soc.load_firmware(&fw, 0);
        let (halt, _) = soc.run(100_000);
        assert_eq!(halt, Halt::Done);
        assert_eq!(soc.cpu.regs[A1 as usize] & crate::carus::STATUS_DONE, crate::carus::STATUS_DONE);
        for j in 0..vl {
            assert_eq!(soc.carus().vrf.elem_unsigned(2, j, vl, Sew::E32), 3 * j);
        }
        // The host slept during the kernel.
        assert!(soc.counters.cpu_sleep > 10);
    }

    #[test]
    fn mcycle_counter_readable() {
        let mut soc = Soc::heeperator();
        let fw = firmware(|a| {
            a.li(T0, (PERIPH_BASE + periph::MCYCLE) as i32)
                .lw(A0, 0, T0)
                .nop()
                .nop()
                .lw(A1, 0, T0)
                .ebreak();
        });
        soc.load_firmware(&fw, 0);
        soc.run(1000).0;
        let d = soc.cpu.regs[A1 as usize] - soc.cpu.regs[A0 as usize];
        assert!(d >= 3 && d <= 6, "delta = {d}");
    }

    #[test]
    fn energy_rollup_nonzero_and_consistent() {
        let mut soc = Soc::heeperator();
        let fw = firmware(|a| {
            a.li(A0, 100)
                .label("l")
                .addi(A0, A0, -1)
                .bne(A0, ZERO, "l")
                .ebreak();
        });
        soc.load_firmware(&fw, 0);
        soc.reset_stats();
        soc.run(10_000);
        let act = soc.activity();
        assert_eq!(act.cycles, soc.cycle);
        assert_eq!(act.nmc_tiles, 2);
        let e = soc.energy();
        assert!(e.total() > 0.0);
        assert!(e.cpu > 0.0);
        assert!(e.memory > 0.0, "fetch energy counted");
        let shares = e.shares();
        assert!((shares.iter().sum::<f64>() - 100.0).abs() < 1e-6);
    }

    #[test]
    fn two_carus_tiles_compute_concurrently() {
        // The scale-out property in one test: two NM-Carus tiles behind
        // their own bus windows run kernels at the same time, driven by
        // the generic per-tile mode/status peripheral registers.
        let mut soc = Soc::with_tiles(CpuConfig::CV32E40P, 4, &[TileKind::Carus, TileKind::Carus]);
        let vl = 256u32;
        // Distinct data per tile so cross-wiring would be caught.
        for (ti, bias) in [(0u32, 0u32), (1, 1000)] {
            for j in 0..vl {
                let c = match &mut soc.tiles[ti as usize] {
                    Tile::Carus(c) => c,
                    _ => unreachable!(),
                };
                c.vrf.set_elem(0, j, vl, Sew::E32, bias + j);
                c.vrf.set_elem(1, j, vl, Sew::E32, 2 * j);
            }
        }
        // Same kernel on both tiles: v2 = v0 + v1.
        let mut k = Asm::new(0);
        k.li(A0, vl as i32).vsetvli(T0, A0, Sew::E32).vadd_vv(2, 0, 1).ebreak();
        let kprog = k.assemble().unwrap();
        for t in &mut soc.tiles {
            match t {
                Tile::Carus(c) => c.load_kernel(&kprog.words),
                _ => unreachable!(),
            }
        }
        // Host: start tile 0, start tile 1, then poll both status regs.
        let fw = firmware(|a| {
            for t in 0..2usize {
                a.li(T0, (PERIPH_BASE + periph::tile_mode(t)) as i32)
                    .li(T1, 1)
                    .sw(T1, 0, T0) // config mode
                    .li(A0, (bus::tile_base(t) + crate::carus::CTL_OFFSET) as i32)
                    .li(T1, crate::carus::CTL_START as i32)
                    .sw(T1, 0, A0) // start
                    .sw(ZERO, 0, T0); // back to memory mode
            }
            for t in 0..2usize {
                let lbl = format!("wait{t}");
                a.li(T0, (PERIPH_BASE + periph::tile_status(t)) as i32)
                    .label(&lbl)
                    .lw(T1, 0, T0)
                    .bne(T1, ZERO, &lbl);
            }
            a.ebreak();
        });
        soc.load_firmware(&fw, 0);
        soc.reset_stats();
        let (halt, cycles) = soc.run(1_000_000);
        assert_eq!(halt, Halt::Done);
        for (ti, bias) in [(0u32, 0u32), (1, 1000)] {
            let c = match &soc.tiles[ti as usize] {
                Tile::Carus(c) => c,
                _ => unreachable!(),
            };
            for j in 0..vl {
                assert_eq!(c.vrf.elem_unsigned(2, j, vl, Sew::E32), bias + 3 * j, "tile {ti} j {j}");
            }
        }
        // Both tiles were busy, and their busy windows overlapped (the
        // sum of busy cycles exceeds the wall clock).
        assert!(soc.tile_busy[0] > 0 && soc.tile_busy[1] > 0);
        assert!(
            soc.tile_busy[0] + soc.tile_busy[1] > cycles,
            "no overlap: busy = {:?}, cycles = {cycles}",
            soc.tile_busy
        );
    }

    #[test]
    fn unpopulated_tile_windows_read_zero() {
        // Only two tiles populated; window 5 decodes but is empty.
        let mut soc = Soc::heeperator();
        let hole = bus::tile_base(5);
        let fw = firmware(|a| {
            a.li(T0, hole as i32)
                .lw(A0, 0, T0) // reads 0
                .li(T1, 42)
                .sw(T1, 0, T0) // dropped
                .lw(A1, 0, T0) // still 0
                .ebreak();
        });
        soc.load_firmware(&fw, 0);
        let (halt, _) = soc.run(10_000);
        assert_eq!(halt, Halt::Done);
        assert_eq!(soc.cpu.regs[A0 as usize], 0);
        assert_eq!(soc.cpu.regs[A1 as usize], 0);
    }
}
