//! Cached sweep sessions: one simulation per workload point.
//!
//! A [`SweepSession`] memoizes completed [`RunResult`]s behind a
//! thread-safe cache keyed by the full workload identity
//! `(target, kernel, sew, seed)`. Every consumer — the `harness` reports,
//! the ablations, the `heeperator sweep` CLI, the examples — asks the
//! session instead of [`kernels::run`] directly, so a grid point that
//! several reports share (Table V and Fig. 11 read the same 81 points;
//! `heeperator all` fans both out as independent jobs) is simulated
//! exactly once per invocation no matter how many threads consume it.
//!
//! Two contracts, locked by `rust/tests/sweep_session.rs`:
//!
//! 1. **Transparency** — a session result is byte-identical to an uncached
//!    [`kernels::run`] of the same point (the cache stores, it never
//!    alters).
//! 2. **At-most-once** — concurrent consumers of one point block on a
//!    per-point [`OnceLock`] rather than racing duplicate simulations;
//!    [`SweepSession::simulations`] counts real runs for the tests.
//!
//! The session caches *results* per invocation; the assembled programs
//! underneath are cached process-wide by [`kernels::prepared`], so even
//! cache-miss points skip firmware reassembly.

use crate::apps::anomaly::{self, AdResult};
use crate::isa::Sew;
use crate::kernels::{self, Kernel, RunResult, Target};
use crate::sched::{self, BatchRunResult, BatchSpec};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Full identity of one kernel-grid simulation.
pub type Point = (Target, Kernel, Sew, u64);

type Slot<T> = Arc<OnceLock<Arc<T>>>;

/// A memoizing simulation session shared by every report of one
/// invocation. Cheap to construct; share via `Arc` across worker threads.
#[derive(Default)]
pub struct SweepSession {
    kernel_slots: Mutex<HashMap<Point, Slot<RunResult>>>,
    /// Anomaly-Detection app runs, keyed by (target system, model seed).
    ad_slots: Mutex<HashMap<(Target, u64), Slot<AdResult>>>,
    /// Multi-tile schedule co-simulations, keyed by (spec, tile count).
    scale_slots: Mutex<HashMap<(BatchSpec, u32), Slot<BatchRunResult>>>,
    simulations: AtomicU64,
}

impl SweepSession {
    pub fn new() -> Self {
        Self::default()
    }

    /// Memoized [`kernels::run`]: the first consumer of a point simulates
    /// it, every later (or concurrently blocked) consumer shares the same
    /// `Arc`'d result.
    pub fn run(&self, target: Target, kernel: Kernel, sew: Sew, seed: u64) -> Arc<RunResult> {
        let slot = Arc::clone(
            self.kernel_slots
                .lock()
                .expect("sweep cache poisoned")
                .entry((target, kernel, sew, seed))
                .or_default(),
        );
        // Simulate outside the map lock: only consumers of *this* point
        // wait, the rest of the grid proceeds in parallel.
        Arc::clone(slot.get_or_init(|| {
            self.simulations.fetch_add(1, Ordering::Relaxed);
            Arc::new(kernels::run(target, kernel, sew, seed))
        }))
    }

    /// Memoized Anomaly-Detection run (Table VI systems): `target` selects
    /// the CV32E40P baseline, NM-Caesar + CV32E20, or NM-Carus + CV32E20
    /// configuration; the multicore rows are derived projections and need
    /// no cache of their own (see [`anomaly::scale_multicore`]).
    pub fn anomaly(&self, target: Target, model_seed: u64) -> Arc<AdResult> {
        let slot = Arc::clone(
            self.ad_slots
                .lock()
                .expect("sweep cache poisoned")
                .entry((target, model_seed))
                .or_default(),
        );
        Arc::clone(slot.get_or_init(|| {
            self.simulations.fetch_add(1, Ordering::Relaxed);
            let m = anomaly::model(model_seed);
            Arc::new(anomaly::run_target(&m, target))
        }))
    }

    /// Memoized multi-tile schedule run (`heeperator scale`): one
    /// co-simulation per `(spec, tiles)` point per invocation, no matter
    /// how many report threads sweep overlapping tile lists. Planning
    /// errors (untileable kernel, capacity, bad shard) surface as `Err`
    /// without occupying a slot.
    pub fn scale(&self, spec: &BatchSpec, tiles: u32) -> Result<Arc<BatchRunResult>, String> {
        let slot = Arc::clone(
            self.scale_slots
                .lock()
                .expect("sweep cache poisoned")
                .entry((*spec, tiles))
                .or_default(),
        );
        if let Some(r) = slot.get() {
            return Ok(Arc::clone(r));
        }
        // Plan outside the slot so a planning error never wedges it; a
        // racing thread may plan once more, the first init wins.
        let plan = sched::plan(spec, tiles as usize).map_err(|e| e.to_string())?;
        Ok(Arc::clone(slot.get_or_init(|| {
            self.simulations.fetch_add(1, Ordering::Relaxed);
            Arc::new(sched::run_planned(&plan))
        })))
    }

    /// Number of simulations actually executed (cache misses) so far —
    /// the observable behind the at-most-once contract.
    pub fn simulations(&self) -> u64 {
        self.simulations.load(Ordering::Relaxed)
    }

    /// Number of distinct points the session has been asked for.
    pub fn len(&self) -> usize {
        self.kernel_slots.lock().expect("sweep cache poisoned").len()
            + self.ad_slots.lock().expect("sweep cache poisoned").len()
            + self.scale_slots.lock().expect("sweep cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_points_share_one_simulation() {
        let s = SweepSession::new();
        let a = s.run(Target::Cpu, Kernel::Mul { n: 64 }, Sew::E32, 1);
        let b = s.run(Target::Cpu, Kernel::Mul { n: 64 }, Sew::E32, 1);
        assert!(Arc::ptr_eq(&a, &b), "second consumer must share the first result");
        assert_eq!(s.simulations(), 1);
        assert_eq!(s.len(), 1);
        // A different seed is a different workload, not a cache hit.
        let c = s.run(Target::Cpu, Kernel::Mul { n: 64 }, Sew::E32, 2);
        assert_eq!(s.simulations(), 2);
        assert_ne!(c.output, a.output, "seeded inputs differ");
    }

    #[test]
    fn scale_points_are_memoized() {
        let s = SweepSession::new();
        let spec = BatchSpec {
            target: Target::Carus,
            kernel: Kernel::Add { n: 128 },
            sew: Sew::E32,
            seed: 1,
            batch: 2,
            shard: false,
        };
        let a = s.scale(&spec, 2).unwrap();
        let b = s.scale(&spec, 2).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second consumer shares the first co-simulation");
        assert_eq!(s.simulations(), 1);
        assert_eq!(s.len(), 1);
        // A different tile count is a different point.
        let c = s.scale(&spec, 1).unwrap();
        assert_eq!(c.tiles, 1);
        assert_eq!(s.simulations(), 2);
        // Planning errors surface without occupying a slot.
        assert!(s.scale(&BatchSpec { target: Target::Cpu, ..spec }, 2).is_err());
        assert_eq!(s.simulations(), 2);
    }

    #[test]
    fn results_carry_the_requested_identity() {
        let s = SweepSession::new();
        let r = s.run(Target::Caesar, Kernel::Relu { n: 128 }, Sew::E16, 9);
        assert_eq!(r.target, Target::Caesar);
        assert_eq!(r.kernel, Kernel::Relu { n: 128 });
        assert_eq!(r.sew, Sew::E16);
    }
}
