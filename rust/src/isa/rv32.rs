//! RV32I/M instruction definitions, encoder, and decoder.
//!
//! The decoder also dispatches into the [`super::xcv`] (Custom-0/Custom-1)
//! and [`super::xvnmc`] (Custom-2) spaces so that a single [`decode`] call
//! handles every instruction the simulated CPUs can fetch.
//!
//! Encodings follow the RISC-V unprivileged spec v20191213. Only 32-bit
//! encodings are produced (see [`crate::isa`] module docs for how the C
//! extension is accounted for).

use super::xcv::XcvInstr;
use super::xvnmc::VInstr;
use super::{bits, reg, sext, Reg};

/// ALU operations shared by register-register and register-immediate forms.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AluOp {
    Add,
    Sub,
    Sll,
    Slt,
    Sltu,
    Xor,
    Srl,
    Sra,
    Or,
    And,
}

/// M-extension multiply/divide operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MulOp {
    Mul,
    Mulh,
    Mulhsu,
    Mulhu,
    Div,
    Divu,
    Rem,
    Remu,
}

/// Conditional branch comparisons.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BranchOp {
    Beq,
    Bne,
    Blt,
    Bge,
    Bltu,
    Bgeu,
}

/// Load widths/signedness.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoadOp {
    Lb,
    Lh,
    Lw,
    Lbu,
    Lhu,
}

impl LoadOp {
    /// Access size in bytes.
    pub fn size(self) -> u32 {
        match self {
            LoadOp::Lb | LoadOp::Lbu => 1,
            LoadOp::Lh | LoadOp::Lhu => 2,
            LoadOp::Lw => 4,
        }
    }
}

/// Store widths.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StoreOp {
    Sb,
    Sh,
    Sw,
}

impl StoreOp {
    /// Access size in bytes.
    pub fn size(self) -> u32 {
        match self {
            StoreOp::Sb => 1,
            StoreOp::Sh => 2,
            StoreOp::Sw => 4,
        }
    }
}

/// Zicsr operations (subset: we model `csrrw`/`csrrs` with register source,
/// which is all the firmware needs for mstatus/mie and custom NMC CSRs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CsrOp {
    Csrrw,
    Csrrs,
    Csrrc,
}

/// A decoded RV32 instruction (including the custom extension spaces).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Instr {
    Lui { rd: Reg, imm: i32 },
    Auipc { rd: Reg, imm: i32 },
    Jal { rd: Reg, off: i32 },
    Jalr { rd: Reg, rs1: Reg, off: i32 },
    Branch { op: BranchOp, rs1: Reg, rs2: Reg, off: i32 },
    Load { op: LoadOp, rd: Reg, rs1: Reg, off: i32 },
    Store { op: StoreOp, rs2: Reg, rs1: Reg, off: i32 },
    AluImm { op: AluOp, rd: Reg, rs1: Reg, imm: i32 },
    Alu { op: AluOp, rd: Reg, rs1: Reg, rs2: Reg },
    MulDiv { op: MulOp, rd: Reg, rs1: Reg, rs2: Reg },
    Csr { op: CsrOp, rd: Reg, rs1: Reg, csr: u16 },
    Ecall,
    Ebreak,
    Wfi,
    Fence,
    /// CV32E40P DSP extension (Custom-0/1 spaces).
    Xcv(XcvInstr),
    /// NM-Carus `xvnmc` vector extension (Custom-2 space, opcode 0x5b).
    Xvnmc(VInstr),
}

const OP_LUI: u32 = 0b0110111;
const OP_AUIPC: u32 = 0b0010111;
const OP_JAL: u32 = 0b1101111;
const OP_JALR: u32 = 0b1100111;
const OP_BRANCH: u32 = 0b1100011;
const OP_LOAD: u32 = 0b0000011;
const OP_STORE: u32 = 0b0100011;
const OP_IMM: u32 = 0b0010011;
const OP_REG: u32 = 0b0110011;
const OP_SYSTEM: u32 = 0b1110011;
const OP_FENCE: u32 = 0b0001111;
pub const OP_CUSTOM0: u32 = 0b0001011; // 0x0b — Xcv ALU/SIMD
pub const OP_CUSTOM1: u32 = 0b0101011; // 0x2b — Xcv dot products
pub const OP_CUSTOM2: u32 = 0b1011011; // 0x5b — xvnmc (Table III)

#[inline]
fn r_type(funct7: u32, rs2: Reg, rs1: Reg, funct3: u32, rd: Reg, opcode: u32) -> u32 {
    (funct7 << 25)
        | ((rs2 as u32 & 31) << 20)
        | ((rs1 as u32 & 31) << 15)
        | (funct3 << 12)
        | ((rd as u32 & 31) << 7)
        | opcode
}

#[inline]
fn i_type(imm: i32, rs1: Reg, funct3: u32, rd: Reg, opcode: u32) -> u32 {
    ((imm as u32 & 0xfff) << 20)
        | ((rs1 as u32 & 31) << 15)
        | (funct3 << 12)
        | ((rd as u32 & 31) << 7)
        | opcode
}

#[inline]
fn s_type(imm: i32, rs2: Reg, rs1: Reg, funct3: u32, opcode: u32) -> u32 {
    let imm = imm as u32;
    (bits(imm, 11, 5) << 25)
        | ((rs2 as u32 & 31) << 20)
        | ((rs1 as u32 & 31) << 15)
        | (funct3 << 12)
        | (bits(imm, 4, 0) << 7)
        | opcode
}

#[inline]
fn b_type(off: i32, rs2: Reg, rs1: Reg, funct3: u32, opcode: u32) -> u32 {
    let imm = off as u32;
    (bits(imm, 12, 12) << 31)
        | (bits(imm, 10, 5) << 25)
        | ((rs2 as u32 & 31) << 20)
        | ((rs1 as u32 & 31) << 15)
        | (funct3 << 12)
        | (bits(imm, 4, 1) << 8)
        | (bits(imm, 11, 11) << 7)
        | opcode
}

#[inline]
fn u_type(imm: i32, rd: Reg, opcode: u32) -> u32 {
    ((imm as u32) & 0xffff_f000) | ((rd as u32 & 31) << 7) | opcode
}

#[inline]
fn j_type(off: i32, rd: Reg, opcode: u32) -> u32 {
    let imm = off as u32;
    (bits(imm, 20, 20) << 31)
        | (bits(imm, 10, 1) << 21)
        | (bits(imm, 11, 11) << 20)
        | (bits(imm, 19, 12) << 12)
        | ((rd as u32 & 31) << 7)
        | opcode
}

impl AluOp {
    fn funct3(self) -> u32 {
        match self {
            AluOp::Add | AluOp::Sub => 0b000,
            AluOp::Sll => 0b001,
            AluOp::Slt => 0b010,
            AluOp::Sltu => 0b011,
            AluOp::Xor => 0b100,
            AluOp::Srl | AluOp::Sra => 0b101,
            AluOp::Or => 0b110,
            AluOp::And => 0b111,
        }
    }
    fn funct7(self) -> u32 {
        match self {
            AluOp::Sub | AluOp::Sra => 0b0100000,
            _ => 0,
        }
    }
}

/// Encode an instruction into its 32-bit machine form.
pub fn encode(i: &Instr) -> u32 {
    match *i {
        Instr::Lui { rd, imm } => u_type(imm, rd, OP_LUI),
        Instr::Auipc { rd, imm } => u_type(imm, rd, OP_AUIPC),
        Instr::Jal { rd, off } => j_type(off, rd, OP_JAL),
        Instr::Jalr { rd, rs1, off } => i_type(off, rs1, 0b000, rd, OP_JALR),
        Instr::Branch { op, rs1, rs2, off } => {
            let f3 = match op {
                BranchOp::Beq => 0b000,
                BranchOp::Bne => 0b001,
                BranchOp::Blt => 0b100,
                BranchOp::Bge => 0b101,
                BranchOp::Bltu => 0b110,
                BranchOp::Bgeu => 0b111,
            };
            b_type(off, rs2, rs1, f3, OP_BRANCH)
        }
        Instr::Load { op, rd, rs1, off } => {
            let f3 = match op {
                LoadOp::Lb => 0b000,
                LoadOp::Lh => 0b001,
                LoadOp::Lw => 0b010,
                LoadOp::Lbu => 0b100,
                LoadOp::Lhu => 0b101,
            };
            i_type(off, rs1, f3, rd, OP_LOAD)
        }
        Instr::Store { op, rs2, rs1, off } => {
            let f3 = match op {
                StoreOp::Sb => 0b000,
                StoreOp::Sh => 0b001,
                StoreOp::Sw => 0b010,
            };
            s_type(off, rs2, rs1, f3, OP_STORE)
        }
        Instr::AluImm { op, rd, rs1, imm } => match op {
            AluOp::Sll | AluOp::Srl | AluOp::Sra => {
                let shamt = (imm as u32 & 31) as i32;
                i_type(((op.funct7() << 5) as i32) | shamt, rs1, op.funct3(), rd, OP_IMM)
            }
            AluOp::Sub => panic!("subi does not exist; use addi with negated imm"),
            _ => i_type(imm, rs1, op.funct3(), rd, OP_IMM),
        },
        Instr::Alu { op, rd, rs1, rs2 } => r_type(op.funct7(), rs2, rs1, op.funct3(), rd, OP_REG),
        Instr::MulDiv { op, rd, rs1, rs2 } => {
            let f3 = match op {
                MulOp::Mul => 0b000,
                MulOp::Mulh => 0b001,
                MulOp::Mulhsu => 0b010,
                MulOp::Mulhu => 0b011,
                MulOp::Div => 0b100,
                MulOp::Divu => 0b101,
                MulOp::Rem => 0b110,
                MulOp::Remu => 0b111,
            };
            r_type(0b0000001, rs2, rs1, f3, rd, OP_REG)
        }
        Instr::Csr { op, rd, rs1, csr } => {
            let f3 = match op {
                CsrOp::Csrrw => 0b001,
                CsrOp::Csrrs => 0b010,
                CsrOp::Csrrc => 0b011,
            };
            ((csr as u32) << 20) | ((rs1 as u32 & 31) << 15) | (f3 << 12) | ((rd as u32 & 31) << 7) | OP_SYSTEM
        }
        Instr::Ecall => OP_SYSTEM,
        Instr::Ebreak => (1 << 20) | OP_SYSTEM,
        Instr::Wfi => (0b0001000_00101 << 20) | OP_SYSTEM,
        Instr::Fence => OP_FENCE,
        Instr::Xcv(x) => super::xcv::encode(&x),
        Instr::Xvnmc(v) => super::xvnmc::encode(&v),
    }
}

/// Decode error: the word is not a recognized instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IllegalInstr(pub u32);

impl std::fmt::Display for IllegalInstr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "illegal instruction {:#010x}", self.0)
    }
}
impl std::error::Error for IllegalInstr {}

/// Decode a 32-bit machine word.
pub fn decode(w: u32) -> Result<Instr, IllegalInstr> {
    let opcode = bits(w, 6, 0);
    let rd = bits(w, 11, 7) as Reg;
    let rs1 = bits(w, 19, 15) as Reg;
    let rs2 = bits(w, 24, 20) as Reg;
    let funct3 = bits(w, 14, 12);
    let funct7 = bits(w, 31, 25);
    let imm_i = sext(bits(w, 31, 20), 12);
    match opcode {
        OP_LUI => Ok(Instr::Lui { rd, imm: (w & 0xffff_f000) as i32 }),
        OP_AUIPC => Ok(Instr::Auipc { rd, imm: (w & 0xffff_f000) as i32 }),
        OP_JAL => {
            let off = (bits(w, 31, 31) << 20)
                | (bits(w, 19, 12) << 12)
                | (bits(w, 20, 20) << 11)
                | (bits(w, 30, 21) << 1);
            Ok(Instr::Jal { rd, off: sext(off, 21) })
        }
        OP_JALR if funct3 == 0 => Ok(Instr::Jalr { rd, rs1, off: imm_i }),
        OP_BRANCH => {
            let op = match funct3 {
                0b000 => BranchOp::Beq,
                0b001 => BranchOp::Bne,
                0b100 => BranchOp::Blt,
                0b101 => BranchOp::Bge,
                0b110 => BranchOp::Bltu,
                0b111 => BranchOp::Bgeu,
                _ => return Err(IllegalInstr(w)),
            };
            let off = (bits(w, 31, 31) << 12)
                | (bits(w, 7, 7) << 11)
                | (bits(w, 30, 25) << 5)
                | (bits(w, 11, 8) << 1);
            Ok(Instr::Branch { op, rs1, rs2, off: sext(off, 13) })
        }
        OP_LOAD => {
            let op = match funct3 {
                0b000 => LoadOp::Lb,
                0b001 => LoadOp::Lh,
                0b010 => LoadOp::Lw,
                0b100 => LoadOp::Lbu,
                0b101 => LoadOp::Lhu,
                _ => return Err(IllegalInstr(w)),
            };
            Ok(Instr::Load { op, rd, rs1, off: imm_i })
        }
        OP_STORE => {
            let op = match funct3 {
                0b000 => StoreOp::Sb,
                0b001 => StoreOp::Sh,
                0b010 => StoreOp::Sw,
                _ => return Err(IllegalInstr(w)),
            };
            let off = sext((bits(w, 31, 25) << 5) | bits(w, 11, 7), 12);
            Ok(Instr::Store { op, rs2, rs1, off })
        }
        OP_IMM => {
            let op = match funct3 {
                0b000 => AluOp::Add,
                0b001 => AluOp::Sll,
                0b010 => AluOp::Slt,
                0b011 => AluOp::Sltu,
                0b100 => AluOp::Xor,
                0b101 => {
                    if funct7 == 0b0100000 {
                        AluOp::Sra
                    } else if funct7 == 0 {
                        AluOp::Srl
                    } else {
                        return Err(IllegalInstr(w));
                    }
                }
                0b110 => AluOp::Or,
                0b111 => AluOp::And,
                _ => return Err(IllegalInstr(w)),
            };
            let imm = match op {
                AluOp::Sll | AluOp::Srl | AluOp::Sra => {
                    if op == AluOp::Sll && funct7 != 0 {
                        return Err(IllegalInstr(w));
                    }
                    bits(w, 24, 20) as i32
                }
                _ => imm_i,
            };
            Ok(Instr::AluImm { op, rd, rs1, imm })
        }
        OP_REG => {
            if funct7 == 0b0000001 {
                let op = match funct3 {
                    0b000 => MulOp::Mul,
                    0b001 => MulOp::Mulh,
                    0b010 => MulOp::Mulhsu,
                    0b011 => MulOp::Mulhu,
                    0b100 => MulOp::Div,
                    0b101 => MulOp::Divu,
                    0b110 => MulOp::Rem,
                    _ => MulOp::Remu,
                };
                return Ok(Instr::MulDiv { op, rd, rs1, rs2 });
            }
            let op = match funct3 {
                0b000 => {
                    if funct7 == 0b0100000 {
                        AluOp::Sub
                    } else if funct7 == 0 {
                        AluOp::Add
                    } else {
                        return Err(IllegalInstr(w));
                    }
                }
                0b001 => AluOp::Sll,
                0b010 => AluOp::Slt,
                0b011 => AluOp::Sltu,
                0b100 => AluOp::Xor,
                0b101 => {
                    if funct7 == 0b0100000 {
                        AluOp::Sra
                    } else if funct7 == 0 {
                        AluOp::Srl
                    } else {
                        return Err(IllegalInstr(w));
                    }
                }
                0b110 => AluOp::Or,
                0b111 => AluOp::And,
                _ => return Err(IllegalInstr(w)),
            };
            if op != AluOp::Sub && op != AluOp::Sra && funct7 != 0 {
                return Err(IllegalInstr(w));
            }
            Ok(Instr::Alu { op, rd, rs1, rs2 })
        }
        OP_SYSTEM => match funct3 {
            0b000 => match bits(w, 31, 20) {
                0 => Ok(Instr::Ecall),
                1 => Ok(Instr::Ebreak),
                0b0001000_00101 => Ok(Instr::Wfi),
                _ => Err(IllegalInstr(w)),
            },
            0b001 => Ok(Instr::Csr { op: CsrOp::Csrrw, rd, rs1, csr: bits(w, 31, 20) as u16 }),
            0b010 => Ok(Instr::Csr { op: CsrOp::Csrrs, rd, rs1, csr: bits(w, 31, 20) as u16 }),
            0b011 => Ok(Instr::Csr { op: CsrOp::Csrrc, rd, rs1, csr: bits(w, 31, 20) as u16 }),
            _ => Err(IllegalInstr(w)),
        },
        OP_FENCE => Ok(Instr::Fence),
        OP_CUSTOM0 | OP_CUSTOM1 => super::xcv::decode(w).map(Instr::Xcv).ok_or(IllegalInstr(w)),
        OP_CUSTOM2 => super::xvnmc::decode(w).map(Instr::Xvnmc).ok_or(IllegalInstr(w)),
        _ => Err(IllegalInstr(w)),
    }
}

/// Render an instruction in assembly-like form (debug/tracing aid).
pub fn disasm(i: &Instr) -> String {
    use reg::name as n;
    match *i {
        Instr::Lui { rd, imm } => format!("lui {}, {:#x}", n(rd), (imm as u32) >> 12),
        Instr::Auipc { rd, imm } => format!("auipc {}, {:#x}", n(rd), (imm as u32) >> 12),
        Instr::Jal { rd, off } => format!("jal {}, {}", n(rd), off),
        Instr::Jalr { rd, rs1, off } => format!("jalr {}, {}({})", n(rd), off, n(rs1)),
        Instr::Branch { op, rs1, rs2, off } => {
            let m = match op {
                BranchOp::Beq => "beq",
                BranchOp::Bne => "bne",
                BranchOp::Blt => "blt",
                BranchOp::Bge => "bge",
                BranchOp::Bltu => "bltu",
                BranchOp::Bgeu => "bgeu",
            };
            format!("{} {}, {}, {}", m, n(rs1), n(rs2), off)
        }
        Instr::Load { op, rd, rs1, off } => {
            let m = match op {
                LoadOp::Lb => "lb",
                LoadOp::Lh => "lh",
                LoadOp::Lw => "lw",
                LoadOp::Lbu => "lbu",
                LoadOp::Lhu => "lhu",
            };
            format!("{} {}, {}({})", m, n(rd), off, n(rs1))
        }
        Instr::Store { op, rs2, rs1, off } => {
            let m = match op {
                StoreOp::Sb => "sb",
                StoreOp::Sh => "sh",
                StoreOp::Sw => "sw",
            };
            format!("{} {}, {}({})", m, n(rs2), off, n(rs1))
        }
        Instr::AluImm { op, rd, rs1, imm } => {
            let m = match op {
                AluOp::Add => "addi",
                AluOp::Sll => "slli",
                AluOp::Slt => "slti",
                AluOp::Sltu => "sltiu",
                AluOp::Xor => "xori",
                AluOp::Srl => "srli",
                AluOp::Sra => "srai",
                AluOp::Or => "ori",
                AluOp::And => "andi",
                AluOp::Sub => "subi?",
            };
            format!("{} {}, {}, {}", m, n(rd), n(rs1), imm)
        }
        Instr::Alu { op, rd, rs1, rs2 } => {
            let m = match op {
                AluOp::Add => "add",
                AluOp::Sub => "sub",
                AluOp::Sll => "sll",
                AluOp::Slt => "slt",
                AluOp::Sltu => "sltu",
                AluOp::Xor => "xor",
                AluOp::Srl => "srl",
                AluOp::Sra => "sra",
                AluOp::Or => "or",
                AluOp::And => "and",
            };
            format!("{} {}, {}, {}", m, n(rd), n(rs1), n(rs2))
        }
        Instr::MulDiv { op, rd, rs1, rs2 } => {
            let m = match op {
                MulOp::Mul => "mul",
                MulOp::Mulh => "mulh",
                MulOp::Mulhsu => "mulhsu",
                MulOp::Mulhu => "mulhu",
                MulOp::Div => "div",
                MulOp::Divu => "divu",
                MulOp::Rem => "rem",
                MulOp::Remu => "remu",
            };
            format!("{} {}, {}, {}", m, n(rd), n(rs1), n(rs2))
        }
        Instr::Csr { op, rd, rs1, csr } => {
            let m = match op {
                CsrOp::Csrrw => "csrrw",
                CsrOp::Csrrs => "csrrs",
                CsrOp::Csrrc => "csrrc",
            };
            format!("{} {}, {:#x}, {}", m, n(rd), csr, n(rs1))
        }
        Instr::Ecall => "ecall".into(),
        Instr::Ebreak => "ebreak".into(),
        Instr::Wfi => "wfi".into(),
        Instr::Fence => "fence".into(),
        Instr::Xcv(x) => super::xcv::disasm(&x),
        Instr::Xvnmc(v) => super::xvnmc::disasm(&v),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(i: Instr) {
        let w = encode(&i);
        let back = decode(w).unwrap_or_else(|e| panic!("{e} while decoding {i:?}"));
        assert_eq!(back, i, "round-trip failed for {i:?} ({w:#010x})");
    }

    #[test]
    fn roundtrip_ui_types() {
        rt(Instr::Lui { rd: 5, imm: 0x12345 << 12 });
        rt(Instr::Auipc { rd: 1, imm: (-1i32 << 12) & (0xfffff << 12) as i32 as i32 });
        rt(Instr::Jal { rd: 1, off: 2048 });
        rt(Instr::Jal { rd: 0, off: -4 });
        rt(Instr::Jalr { rd: 0, rs1: 1, off: 0 });
    }

    #[test]
    fn roundtrip_branches() {
        for op in [BranchOp::Beq, BranchOp::Bne, BranchOp::Blt, BranchOp::Bge, BranchOp::Bltu, BranchOp::Bgeu] {
            rt(Instr::Branch { op, rs1: 3, rs2: 4, off: -8 });
            rt(Instr::Branch { op, rs1: 31, rs2: 0, off: 4094 });
        }
    }

    #[test]
    fn roundtrip_mem() {
        for op in [LoadOp::Lb, LoadOp::Lh, LoadOp::Lw, LoadOp::Lbu, LoadOp::Lhu] {
            rt(Instr::Load { op, rd: 10, rs1: 2, off: -2048 });
            rt(Instr::Load { op, rd: 10, rs1: 2, off: 2047 });
        }
        for op in [StoreOp::Sb, StoreOp::Sh, StoreOp::Sw] {
            rt(Instr::Store { op, rs2: 7, rs1: 8, off: -1 });
        }
    }

    #[test]
    fn roundtrip_alu() {
        for op in [
            AluOp::Add,
            AluOp::Sub,
            AluOp::Sll,
            AluOp::Slt,
            AluOp::Sltu,
            AluOp::Xor,
            AluOp::Srl,
            AluOp::Sra,
            AluOp::Or,
            AluOp::And,
        ] {
            rt(Instr::Alu { op, rd: 1, rs1: 2, rs2: 3 });
            if op != AluOp::Sub {
                let imm = match op {
                    AluOp::Sll | AluOp::Srl | AluOp::Sra => 31,
                    _ => -7,
                };
                rt(Instr::AluImm { op, rd: 1, rs1: 2, imm });
            }
        }
    }

    #[test]
    fn roundtrip_muldiv_csr_sys() {
        for op in [
            MulOp::Mul,
            MulOp::Mulh,
            MulOp::Mulhsu,
            MulOp::Mulhu,
            MulOp::Div,
            MulOp::Divu,
            MulOp::Rem,
            MulOp::Remu,
        ] {
            rt(Instr::MulDiv { op, rd: 4, rs1: 5, rs2: 6 });
        }
        rt(Instr::Csr { op: CsrOp::Csrrw, rd: 1, rs1: 2, csr: 0x300 });
        rt(Instr::Csr { op: CsrOp::Csrrs, rd: 0, rs1: 0, csr: 0x344 });
        rt(Instr::Ecall);
        rt(Instr::Ebreak);
        rt(Instr::Wfi);
        rt(Instr::Fence);
    }

    #[test]
    fn known_encodings() {
        // Cross-checked against riscv-tests / gnu as output.
        assert_eq!(encode(&Instr::AluImm { op: AluOp::Add, rd: 0, rs1: 0, imm: 0 }), 0x0000_0013); // nop
        assert_eq!(
            encode(&Instr::Alu { op: AluOp::Add, rd: 10, rs1: 11, rs2: 12 }),
            0x00c5_8533
        ); // add a0,a1,a2
        assert_eq!(
            encode(&Instr::Load { op: LoadOp::Lw, rd: 10, rs1: 2, off: 8 }),
            0x0081_2503
        ); // lw a0,8(sp)
        assert_eq!(encode(&Instr::Ecall), 0x0000_0073);
    }

    #[test]
    fn illegal_rejected() {
        assert!(decode(0xffff_ffff).is_err());
        assert!(decode(0x0000_0000).is_err());
    }
}
