//! Instruction-set definitions for every ISA in the HEEPerator system.
//!
//! Four instruction families coexist in the simulated SoC:
//! - **RV32I/M** ([`rv32`]): the host CPU (CV32E40P, RV32IMC) and, in its
//!   RV32E subset, the CV32E20 host used in Table VI and the NM-Carus
//!   embedded CPU (eCPU, RV32EC).
//! - **Xcv** ([`xcv`]): the small CV32E40P DSP-extension subset (packed-SIMD
//!   dot products, min/max) used by the RV32IMCXcv baselines of Table VI.
//! - **xvnmc** ([`xvnmc`]): the paper's custom RISC-V vector extension for
//!   near-memory computing (Tables II/III), encoded in the *Custom-2* space
//!   (major opcode `0x5b`), including the indirect-register-addressing
//!   variants that are the paper's key code-size contribution.
//! - **NM-Caesar micro-ops**: *not* RISC-V — they are encoded in bus write
//!   transactions and live in [`crate::caesar::isa`].
//!
//! Compressed (C) encodings are handled at the cost-model level: the
//! assembler emits 32-bit encodings and the cycle/energy model charges
//! fetches per instruction, which is what determines the paper's numbers
//! (CV32E40P fetches through a prefetch buffer; code size is not a measured
//! quantity in the paper's evaluation).

pub mod rv32;
pub mod xcv;
pub mod xvnmc;

/// A RISC-V integer register index (`x0`..`x31`).
///
/// RV32E configurations restrict usage to `x0`..`x15`; this is enforced by
/// the CPU model (illegal-instruction trap), not by the type.
pub type Reg = u8;

/// ABI register names, for the assembler DSL and disassembly.
pub mod reg {
    use super::Reg;
    pub const ZERO: Reg = 0;
    pub const RA: Reg = 1;
    pub const SP: Reg = 2;
    pub const GP: Reg = 3;
    pub const TP: Reg = 4;
    pub const T0: Reg = 5;
    pub const T1: Reg = 6;
    pub const T2: Reg = 7;
    pub const S0: Reg = 8;
    pub const FP: Reg = 8;
    pub const S1: Reg = 9;
    pub const A0: Reg = 10;
    pub const A1: Reg = 11;
    pub const A2: Reg = 12;
    pub const A3: Reg = 13;
    pub const A4: Reg = 14;
    pub const A5: Reg = 15;
    // Registers below are unavailable on RV32E (x16..x31).
    pub const A6: Reg = 16;
    pub const A7: Reg = 17;
    pub const S2: Reg = 18;
    pub const S3: Reg = 19;
    pub const S4: Reg = 20;
    pub const S5: Reg = 21;
    pub const S6: Reg = 22;
    pub const S7: Reg = 23;
    pub const S8: Reg = 24;
    pub const S9: Reg = 25;
    pub const S10: Reg = 26;
    pub const S11: Reg = 27;
    pub const T3: Reg = 28;
    pub const T4: Reg = 29;
    pub const T5: Reg = 30;
    pub const T6: Reg = 31;

    /// ABI name of a register, for disassembly.
    pub fn name(r: Reg) -> &'static str {
        const NAMES: [&str; 32] = [
            "zero", "ra", "sp", "gp", "tp", "t0", "t1", "t2", "s0", "s1", "a0", "a1", "a2", "a3",
            "a4", "a5", "a6", "a7", "s2", "s3", "s4", "s5", "s6", "s7", "s8", "s9", "s10", "s11",
            "t3", "t4", "t5", "t6",
        ];
        NAMES[(r & 31) as usize]
    }
}

/// Element width selector shared by every SIMD/vector datapath in the
/// system (NM-Caesar CSR, NM-Carus `vtype.sew`, Xcv packed ops).
///
/// The paper deliberately supports only the standard 8/16/32-bit integer
/// types (§III, "support for application-specific lower-precision data
/// types was considered but not implemented").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Sew {
    /// 8-bit elements (4 per 32-bit word).
    E8,
    /// 16-bit elements (2 per 32-bit word).
    E16,
    /// 32-bit elements (1 per 32-bit word).
    E32,
}

impl Sew {
    /// Element size in bytes.
    pub fn bytes(self) -> u32 {
        match self {
            Sew::E8 => 1,
            Sew::E16 => 2,
            Sew::E32 => 4,
        }
    }
    /// Elements per 32-bit word.
    pub fn lanes(self) -> u32 {
        4 / self.bytes()
    }
    /// Element size in bits.
    pub fn bits(self) -> u32 {
        8 * self.bytes()
    }
    /// vtype/CSR encoding (0, 1, 2) as in RVV.
    pub fn code(self) -> u32 {
        match self {
            Sew::E8 => 0,
            Sew::E16 => 1,
            Sew::E32 => 2,
        }
    }
    /// Decode from a vtype/CSR field.
    pub fn from_code(c: u32) -> Option<Sew> {
        match c & 0x7 {
            0 => Some(Sew::E8),
            1 => Some(Sew::E16),
            2 => Some(Sew::E32),
            _ => None,
        }
    }
    /// All supported widths, for parameter sweeps.
    pub const ALL: [Sew; 3] = [Sew::E8, Sew::E16, Sew::E32];

    /// Parse a CLI spelling (`8`, `e8`, `16`, `e16`, `32`, `e32`).
    pub fn parse(s: &str) -> Option<Sew> {
        match s.to_ascii_lowercase().as_str() {
            "8" | "e8" => Some(Sew::E8),
            "16" | "e16" => Some(Sew::E16),
            "32" | "e32" => Some(Sew::E32),
            _ => None,
        }
    }
}

impl std::fmt::Display for Sew {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}-bit", self.bits())
    }
}

/// Sign-extend the low `bits` of `v`.
#[inline]
pub fn sext(v: u32, bits: u32) -> i32 {
    debug_assert!((1..=32).contains(&bits));
    let shift = 32 - bits;
    ((v << shift) as i32) >> shift
}

/// Extract bit field `[hi:lo]` of `v`.
#[inline]
pub fn bits(v: u32, hi: u32, lo: u32) -> u32 {
    (v >> lo) & ((1u64 << (hi - lo + 1)) - 1) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sew_geometry() {
        assert_eq!(Sew::E8.lanes(), 4);
        assert_eq!(Sew::E16.lanes(), 2);
        assert_eq!(Sew::E32.lanes(), 1);
        for s in Sew::ALL {
            assert_eq!(Sew::from_code(s.code()), Some(s));
            assert_eq!(s.bits(), s.bytes() * 8);
        }
        assert_eq!(Sew::from_code(3), None);
    }

    #[test]
    fn sext_works() {
        assert_eq!(sext(0xfff, 12), -1);
        assert_eq!(sext(0x7ff, 12), 2047);
        assert_eq!(sext(0x800, 12), -2048);
        assert_eq!(sext(0xffff_ffff, 32), -1);
        assert_eq!(sext(1, 1), -1);
    }

    #[test]
    fn bits_extract() {
        assert_eq!(bits(0xdead_beef, 31, 28), 0xd);
        assert_eq!(bits(0xdead_beef, 3, 0), 0xf);
        assert_eq!(bits(0xdead_beef, 31, 0), 0xdead_beef);
    }

    #[test]
    fn reg_names() {
        assert_eq!(reg::name(reg::ZERO), "zero");
        assert_eq!(reg::name(reg::A0), "a0");
        assert_eq!(reg::name(reg::T6), "t6");
    }
}
