//! `xvnmc` — the paper's custom RISC-V vector extension for NMC devices.
//!
//! This is the ISA contribution of §III-B1 (Tables II and III): an
//! RVV-inspired integer vector extension encoded in the *Custom-2* 25-bit
//! space (major opcode `0x5b`), with three distinctive features:
//!
//! 1. **No vector loads/stores.** The VRF *is* the host-visible memory; the
//!    host populates it through the bus, so the extension is independent of
//!    the data bus width and needs no address-generation hardware.
//! 2. **Indirect register addressing** (`[r]` variants): the indexes of
//!    `vd`, `vs2` and `vs1` are taken from the three least-significant
//!    bytes of a scalar GPR instead of the instruction's immediate fields,
//!    so one vector instruction can be reused across loop iterations with a
//!    single scalar `add` updating the index GPR — the paper's answer to
//!    the code-size explosion of hardcoded register numbers (up to 256
//!    logical vectors). We map the indirect flag onto the RVV `vm` bit
//!    (bit 25, `vm=0` ⇒ indirect) and the index GPR onto the `rs2/vs2`
//!    field, consistent with the paper's description ("encode the index of
//!    the source and destination vector registers in the three
//!    least-significant bytes of a scalar GPR (rs2)").
//! 3. **Scalar↔vector element moves** (`emvv`/`emvx`): the only channel
//!    between eCPU GPRs and VRF elements (OPMVX format).
//!
//! Instruction formats follow RVV 1.0: `funct6 | vm | vs2 | vs1 | funct3 |
//! vd | opcode`, with `funct3` selecting OPIVV/OPIVX/OPIVI/OPMVV/OPMVX/
//! OPCFG. `funct6` assignments reuse the RVV values for the shared
//! mnemonics so the extension reads naturally to an RVV-literate toolchain.

use super::{bits, reg, sext, Reg};

/// funct3 minor-opcode spaces (RVV names).
const OPIVV: u32 = 0b000;
const OPMVV: u32 = 0b010;
const OPIVI: u32 = 0b011;
const OPIVX: u32 = 0b100;
const OPMVX: u32 = 0b110;
const OPCFG: u32 = 0b111;

pub use super::rv32::OP_CUSTOM2;

/// Vector arithmetic/logic/permutation operations (Table II).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VOp {
    Add,
    Sub,
    Mul,
    Macc,
    And,
    Or,
    Xor,
    Min,
    Minu,
    Max,
    Maxu,
    Sll,
    Srl,
    Sra,
    /// `xvnmc.vmv` — copy a vector (`vv`) or splat a scalar/immediate.
    Mv,
    SlideUp,
    SlideDown,
    Slide1Up,
    Slide1Down,
}

impl VOp {
    /// Which source variants exist for this op (Table II columns).
    pub fn allows(self, src: VSrcKind) -> bool {
        use VSrcKind::*;
        match self {
            VOp::Add | VOp::And | VOp::Or | VOp::Xor | VOp::Sll | VOp::Srl | VOp::Sra | VOp::Mv => {
                matches!(src, Vv | Vx | Vi)
            }
            VOp::Sub | VOp::Mul | VOp::Macc | VOp::Min | VOp::Minu | VOp::Max | VOp::Maxu => {
                matches!(src, Vv | Vx)
            }
            VOp::SlideUp | VOp::SlideDown => matches!(src, Vx | Vi),
            VOp::Slide1Up | VOp::Slide1Down => matches!(src, Vx),
        }
    }

    /// True for ops executed by the move/slide (permutation) unit rather
    /// than the arithmetic unit (§III-B2 execution engine split).
    pub fn is_permutation(self) -> bool {
        matches!(
            self,
            VOp::Mv | VOp::SlideUp | VOp::SlideDown | VOp::Slide1Up | VOp::Slide1Down
        )
    }

    /// Number of *vector* register operands read per element-wise step,
    /// used by the VPU timing model to bound VRF port pressure.
    pub fn vector_reads(self, src: VSrcKind) -> u32 {
        let from_src = matches!(src, VSrcKind::Vv) as u32;
        match self {
            // vmacc additionally reads the accumulator vd.
            VOp::Macc => 1 + from_src + 1,
            // vmv.vv reads only vs1 (vs2 unused); vmv.vx/vi reads nothing.
            VOp::Mv => from_src,
            _ => 1 + from_src,
        }
    }
}

/// The three source-operand kinds of Table III.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VSrcKind {
    Vv,
    Vx,
    Vi,
}

/// Second source operand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VSrc {
    /// Vector register `vs1`.
    V(u8),
    /// Scalar GPR `rs1`.
    X(Reg),
    /// 5-bit sign-extended immediate.
    I(i8),
}

impl VSrc {
    pub fn kind(self) -> VSrcKind {
        match self {
            VSrc::V(_) => VSrcKind::Vv,
            VSrc::X(_) => VSrcKind::Vx,
            VSrc::I(_) => VSrcKind::Vi,
        }
    }
}

/// A decoded xvnmc instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VInstr {
    /// Vector arithmetic / logic / permutation (Table II top blocks).
    ///
    /// With `indirect = true`, `idx_gpr` names the scalar GPR whose bytes
    /// `{[23:16]=vs1, [15:8]=vs2, [7:0]=vd}` provide the *logical* register
    /// indexes at execution time; the `vd`/`vs2` fields here are ignored
    /// (and `VSrc::V` values are overridden).
    Op {
        op: VOp,
        vd: u8,
        vs2: u8,
        src: VSrc,
        indirect: bool,
        /// Only meaningful when `indirect`.
        idx_gpr: Reg,
    },
    /// `xvnmc.emvv vd, x[rs2], x[rs1]` — v\[vd\]\[x\[rs2\]\] = x\[rs1\].
    Emvv { vd: u8, idx: Reg, rs1: Reg },
    /// `xvnmc.emvx rd, vs2, x[rs1]` — x\[rd\] = v\[vs2\]\[x\[rs1\]\].
    Emvx { rd: Reg, vs2: u8, idx: Reg },
    /// `xvnmc.vsetvli rd, rs1, vtypei` — set VL from AVL in rs1 + vtype imm.
    VsetVli { rd: Reg, rs1: Reg, vtype: u16 },
    /// `xvnmc.vsetivli rd, uimm, vtypei` — immediate AVL form.
    VsetIVli { rd: Reg, avl: u8, vtype: u16 },
    /// `xvnmc.vsetvl rd, rs1, rs2` — fully register form.
    VsetVl { rd: Reg, rs1: Reg, rs2: Reg },
}

fn funct6(op: VOp) -> u32 {
    match op {
        VOp::Add => 0b000000,
        VOp::Sub => 0b000010,
        VOp::Minu => 0b000100,
        VOp::Min => 0b000101,
        VOp::Maxu => 0b000110,
        VOp::Max => 0b000111,
        VOp::And => 0b001001,
        VOp::Or => 0b001010,
        VOp::Xor => 0b001011,
        VOp::SlideUp | VOp::Slide1Up => 0b001110,
        VOp::SlideDown | VOp::Slide1Down => 0b001111,
        VOp::Mv => 0b010111,
        VOp::Sll => 0b100101,
        VOp::Srl => 0b101000,
        VOp::Sra => 0b101001,
        VOp::Mul => 0b100111,
        VOp::Macc => 0b101101,
    }
}

fn arith_op_from(f6: u32, minor: u32) -> Option<VOp> {
    Some(match (f6, minor) {
        (0b000000, OPIVV | OPIVX | OPIVI) => VOp::Add,
        (0b000010, OPIVV | OPIVX) => VOp::Sub,
        (0b000100, OPIVV | OPIVX) => VOp::Minu,
        (0b000101, OPIVV | OPIVX) => VOp::Min,
        (0b000110, OPIVV | OPIVX) => VOp::Maxu,
        (0b000111, OPIVV | OPIVX) => VOp::Max,
        (0b001001, OPIVV | OPIVX | OPIVI) => VOp::And,
        (0b001010, OPIVV | OPIVX | OPIVI) => VOp::Or,
        (0b001011, OPIVV | OPIVX | OPIVI) => VOp::Xor,
        (0b001110, OPIVX | OPIVI) => VOp::SlideUp,
        (0b001110, OPMVX) => VOp::Slide1Up,
        (0b001111, OPIVX | OPIVI) => VOp::SlideDown,
        (0b001111, OPMVX) => VOp::Slide1Down,
        (0b010111, OPIVV | OPIVX | OPIVI) => VOp::Mv,
        (0b100101, OPIVV | OPIVX | OPIVI) => VOp::Sll,
        (0b101000, OPIVV | OPIVX | OPIVI) => VOp::Srl,
        (0b101001, OPIVV | OPIVX | OPIVI) => VOp::Sra,
        (0b100111, OPMVV | OPMVX) => VOp::Mul,
        (0b101101, OPMVV | OPMVX) => VOp::Macc,
        _ => return None,
    })
}

const F6_EMVV: u32 = 0b010000;
const F6_EMVX: u32 = 0b010001;

/// Encode an xvnmc instruction (opcode 0x5b).
pub fn encode(v: &VInstr) -> u32 {
    let enc = |f6: u32, vm: u32, vs2f: u32, vs1f: u32, minor: u32, vdf: u32| {
        (f6 << 26) | (vm << 25) | ((vs2f & 31) << 20) | ((vs1f & 31) << 15) | (minor << 12) | ((vdf & 31) << 7) | OP_CUSTOM2
    };
    match *v {
        VInstr::Op { op, vd, vs2, src, indirect, idx_gpr } => {
            assert!(op.allows(src.kind()), "{op:?} does not allow {:?}", src.kind());
            assert!(!indirect || op != VOp::Mv || src.kind() != VSrcKind::Vv || true);
            let vm = if indirect { 0 } else { 1 };
            // In indirect mode the vs2 field carries the index GPR.
            let vs2f = if indirect { idx_gpr as u32 } else { vs2 as u32 };
            let (minor, vs1f) = match (src, op) {
                (VSrc::V(vs1), VOp::Mul | VOp::Macc) => (OPMVV, vs1 as u32),
                (VSrc::X(rs1), VOp::Mul | VOp::Macc) => (OPMVX, rs1 as u32),
                (VSrc::X(rs1), VOp::Slide1Up | VOp::Slide1Down) => (OPMVX, rs1 as u32),
                (VSrc::V(vs1), _) => (OPIVV, vs1 as u32),
                (VSrc::X(rs1), _) => (OPIVX, rs1 as u32),
                (VSrc::I(imm), _) => (OPIVI, (imm as u32) & 31),
            };
            enc(funct6(op), vm, vs2f, vs1f, minor, vd as u32)
        }
        VInstr::Emvv { vd, idx, rs1 } => enc(F6_EMVV, 1, idx as u32, rs1 as u32, OPMVX, vd as u32),
        VInstr::Emvx { rd, vs2, idx } => enc(F6_EMVX, 1, vs2 as u32, idx as u32, OPMVX, rd as u32),
        VInstr::VsetVli { rd, rs1, vtype } => {
            // bit31 = 0, zimm[10:0] in bits 30:20.
            ((vtype as u32 & 0x7ff) << 20) | ((rs1 as u32 & 31) << 15) | (OPCFG << 12) | ((rd as u32 & 31) << 7) | OP_CUSTOM2
        }
        VInstr::VsetIVli { rd, avl, vtype } => {
            // bits 31:30 = 0b11, zimm[9:0] in 29:20, uimm[4:0] in 19:15.
            (0b11 << 30)
                | ((vtype as u32 & 0x3ff) << 20)
                | ((avl as u32 & 31) << 15)
                | (OPCFG << 12)
                | ((rd as u32 & 31) << 7)
                | OP_CUSTOM2
        }
        VInstr::VsetVl { rd, rs1, rs2 } => {
            // bit31 = 1, bits 30:25 = 0.
            (1 << 31) | ((rs2 as u32 & 31) << 20) | ((rs1 as u32 & 31) << 15) | (OPCFG << 12) | ((rd as u32 & 31) << 7) | OP_CUSTOM2
        }
    }
}

/// Decode a word from the Custom-2 space. Returns `None` if not xvnmc.
pub fn decode(w: u32) -> Option<VInstr> {
    if bits(w, 6, 0) != OP_CUSTOM2 {
        return None;
    }
    let minor = bits(w, 14, 12);
    let rd = bits(w, 11, 7) as Reg;
    let rs1 = bits(w, 19, 15) as Reg;
    let rs2f = bits(w, 24, 20);
    if minor == OPCFG {
        if bits(w, 31, 31) == 0 {
            return Some(VInstr::VsetVli { rd, rs1, vtype: bits(w, 30, 20) as u16 });
        }
        if bits(w, 31, 30) == 0b11 {
            return Some(VInstr::VsetIVli { rd, avl: rs1, vtype: bits(w, 29, 20) as u16 });
        }
        if bits(w, 30, 25) == 0 {
            return Some(VInstr::VsetVl { rd, rs1, rs2: rs2f as Reg });
        }
        return None;
    }
    let f6 = bits(w, 31, 26);
    let vm = bits(w, 25, 25);
    if minor == OPMVX && f6 == F6_EMVV {
        return Some(VInstr::Emvv { vd: rd, idx: rs2f as Reg, rs1 });
    }
    if minor == OPMVX && f6 == F6_EMVX {
        return Some(VInstr::Emvx { rd, vs2: rs2f as u8, idx: rs1 });
    }
    let op = arith_op_from(f6, minor)?;
    let src = match minor {
        OPIVV | OPMVV => VSrc::V(rs1),
        OPIVX | OPMVX => VSrc::X(rs1),
        OPIVI => VSrc::I(sext(rs1 as u32, 5) as i8),
        _ => return None,
    };
    if !op.allows(src.kind()) {
        return None;
    }
    let indirect = vm == 0;
    Some(VInstr::Op {
        op,
        vd: rd,
        vs2: if indirect { 0 } else { rs2f as u8 },
        src,
        indirect,
        idx_gpr: if indirect { rs2f as Reg } else { 0 },
    })
}

/// Mnemonic of an op (without the `xvnmc.` prefix or variant suffix).
pub fn mnemonic(op: VOp) -> &'static str {
    match op {
        VOp::Add => "vadd",
        VOp::Sub => "vsub",
        VOp::Mul => "vmul",
        VOp::Macc => "vmacc",
        VOp::And => "vand",
        VOp::Or => "vor",
        VOp::Xor => "vxor",
        VOp::Min => "vmin",
        VOp::Minu => "vminu",
        VOp::Max => "vmax",
        VOp::Maxu => "vmaxu",
        VOp::Sll => "vsll",
        VOp::Srl => "vsrl",
        VOp::Sra => "vsra",
        VOp::Mv => "vmv",
        VOp::SlideUp => "vslideup",
        VOp::SlideDown => "vslidedown",
        VOp::Slide1Up => "vslide1up",
        VOp::Slide1Down => "vslide1down",
    }
}

/// Assembly-like rendering.
pub fn disasm(v: &VInstr) -> String {
    match *v {
        VInstr::Op { op, vd, vs2, src, indirect, idx_gpr } => {
            let r = if indirect { "r" } else { "" };
            let (suffix, srcs) = match src {
                VSrc::V(v1) => ("vv", format!("v{v1}")),
                VSrc::X(r1) => ("vx", reg::name(r1).to_string()),
                VSrc::I(i) => ("vi", format!("{i}")),
            };
            if indirect {
                format!("xvnmc.{}{r}.{suffix} [{}], {srcs}", mnemonic(op), reg::name(idx_gpr))
            } else {
                format!("xvnmc.{}.{suffix} v{vd}, v{vs2}, {srcs}", mnemonic(op))
            }
        }
        VInstr::Emvv { vd, idx, rs1 } => {
            format!("xvnmc.emvv v{vd}[{}], {}", reg::name(idx), reg::name(rs1))
        }
        VInstr::Emvx { rd, vs2, idx } => {
            format!("xvnmc.emvx {}, v{vs2}[{}]", reg::name(rd), reg::name(idx))
        }
        VInstr::VsetVli { rd, rs1, vtype } => {
            format!("xvnmc.vsetvli {}, {}, {:#x}", reg::name(rd), reg::name(rs1), vtype)
        }
        VInstr::VsetIVli { rd, avl, vtype } => {
            format!("xvnmc.vsetivli {}, {avl}, {vtype:#x}", reg::name(rd))
        }
        VInstr::VsetVl { rd, rs1, rs2 } => {
            format!("xvnmc.vsetvl {}, {}, {}", reg::name(rd), reg::name(rs1), reg::name(rs2))
        }
    }
}

/// Pack logical register indexes for indirect addressing, as the kernel
/// code does at runtime: `{vs1[23:16], vs2[15:8], vd[7:0]}`.
#[inline]
pub fn pack_indexes(vd: u8, vs2: u8, vs1: u8) -> u32 {
    (vd as u32) | ((vs2 as u32) << 8) | ((vs1 as u32) << 16)
}

/// Unpack the indirect index GPR value.
#[inline]
pub fn unpack_indexes(x: u32) -> (u8, u8, u8) {
    (x as u8, (x >> 8) as u8, (x >> 16) as u8)
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_OPS: [VOp; 19] = [
        VOp::Add,
        VOp::Sub,
        VOp::Mul,
        VOp::Macc,
        VOp::And,
        VOp::Or,
        VOp::Xor,
        VOp::Min,
        VOp::Minu,
        VOp::Max,
        VOp::Maxu,
        VOp::Sll,
        VOp::Srl,
        VOp::Sra,
        VOp::Mv,
        VOp::SlideUp,
        VOp::SlideDown,
        VOp::Slide1Up,
        VOp::Slide1Down,
    ];

    #[test]
    fn roundtrip_all_variants() {
        for op in ALL_OPS {
            for src in [VSrc::V(3), VSrc::X(9), VSrc::I(-5)] {
                if !op.allows(src.kind()) {
                    continue;
                }
                for indirect in [false, true] {
                    let i = VInstr::Op {
                        op,
                        vd: if indirect { 0 } else { 17 },
                        vs2: if indirect { 0 } else { 11 },
                        src,
                        indirect,
                        idx_gpr: if indirect { 12 } else { 0 },
                    };
                    let w = encode(&i);
                    assert_eq!(decode(w), Some(i), "{}", disasm(&i));
                }
            }
        }
    }

    #[test]
    fn roundtrip_moves_and_config() {
        for i in [
            VInstr::Emvv { vd: 5, idx: 4, rs1: 6 },
            VInstr::Emvx { rd: 5, vs2: 30, idx: 4 },
            VInstr::VsetVli { rd: 1, rs1: 2, vtype: 0x10 },
            VInstr::VsetIVli { rd: 1, avl: 16, vtype: 0x8 },
            VInstr::VsetVl { rd: 1, rs1: 2, rs2: 3 },
        ] {
            let w = encode(&i);
            assert_eq!(decode(w), Some(i), "{}", disasm(&i));
        }
    }

    #[test]
    fn table2_variant_matrix() {
        // Spot-check the variant availability matrix of Table II.
        assert!(VOp::Add.allows(VSrcKind::Vi));
        assert!(!VOp::Sub.allows(VSrcKind::Vi));
        assert!(!VOp::Macc.allows(VSrcKind::Vi));
        assert!(VOp::SlideUp.allows(VSrcKind::Vi));
        assert!(!VOp::SlideUp.allows(VSrcKind::Vv));
        assert!(VOp::Slide1Up.allows(VSrcKind::Vx));
        assert!(!VOp::Slide1Up.allows(VSrcKind::Vi));
    }

    #[test]
    fn index_packing() {
        let x = pack_indexes(200, 100, 50);
        assert_eq!(unpack_indexes(x), (200, 100, 50));
    }

    #[test]
    fn vector_read_counts() {
        // Timing-model inputs: vmacc.vv reads 3 vectors, vadd.vx reads 1.
        assert_eq!(VOp::Macc.vector_reads(VSrcKind::Vv), 3);
        assert_eq!(VOp::Macc.vector_reads(VSrcKind::Vx), 2);
        assert_eq!(VOp::Add.vector_reads(VSrcKind::Vx), 1);
        assert_eq!(VOp::Add.vector_reads(VSrcKind::Vv), 2);
        assert_eq!(VOp::Mv.vector_reads(VSrcKind::Vx), 0);
    }

    #[test]
    fn opcode_space_is_custom2() {
        let w = encode(&VInstr::Emvv { vd: 0, idx: 1, rs1: 2 });
        assert_eq!(w & 0x7f, 0x5b);
    }
}
