//! Xcv — the CV32E40P DSP-extension subset used by the paper's baselines.
//!
//! Table VI compares the NMC devices against CV32E40P cores running the
//! `RV32IMCXcv` ISA (the PULP DSP extension of [38]). The Anomaly-Detection
//! matvec inner loop and ReLU only need a small slice of Xpulpv2: packed
//! SIMD add/sub/min/max/shift and the sum-of-dot-products accumulators.
//!
//! Encodings are self-assigned within the RISC-V *Custom-0* space (opcode
//! `0x0b`, R-type; `funct7` selects the operation, `funct3` the element
//! width). The real Xpulpv2 bit patterns differ, but only the semantics and
//! the cycle/energy cost matter to the simulation; the encodings here are
//! internally consistent (encode ∘ decode = id, enforced by proptest).

use super::rv32::OP_CUSTOM0;
use super::{bits, reg, Reg, Sew};

/// Xcv operation kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum XcvOp {
    /// `cv.sdotsp.{b,h} rd, rs1, rs2` — rd += Σ signed products of packed
    /// elements. The workhorse of int8 matvec on CV32E40P (2 ops/elem).
    SdotSp,
    /// `cv.add.{b,h}` — packed addition.
    Add,
    /// `cv.sub.{b,h}` — packed subtraction.
    Sub,
    /// `cv.min.{b,h,w}` — packed / scalar minimum (signed).
    Min,
    /// `cv.max.{b,h,w}` — packed / scalar maximum (signed). `cv.max.b`
    /// against a zero register implements packed ReLU in one instruction.
    Max,
    /// `cv.sra.{b,h}` — packed arithmetic shift right (leaky-ReLU slope).
    Sra,
}

/// A decoded Xcv instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct XcvInstr {
    pub op: XcvOp,
    /// Element width: `E8`/`E16` packed; `E32` = scalar (min/max only).
    pub sew: Sew,
    pub rd: Reg,
    pub rs1: Reg,
    pub rs2: Reg,
}

fn funct7(op: XcvOp) -> u32 {
    match op {
        XcvOp::SdotSp => 0b0000001,
        XcvOp::Add => 0b0000010,
        XcvOp::Sub => 0b0000011,
        XcvOp::Min => 0b0000100,
        XcvOp::Max => 0b0000101,
        XcvOp::Sra => 0b0000110,
    }
}

fn op_from_funct7(f: u32) -> Option<XcvOp> {
    Some(match f {
        0b0000001 => XcvOp::SdotSp,
        0b0000010 => XcvOp::Add,
        0b0000011 => XcvOp::Sub,
        0b0000100 => XcvOp::Min,
        0b0000101 => XcvOp::Max,
        0b0000110 => XcvOp::Sra,
        _ => return None,
    })
}

/// True if the (op, sew) pair is an instruction that exists.
pub fn valid(op: XcvOp, sew: Sew) -> bool {
    match op {
        // Scalar (E32) form exists only for min/max (cv.min/cv.max).
        XcvOp::Min | XcvOp::Max => true,
        XcvOp::SdotSp | XcvOp::Add | XcvOp::Sub | XcvOp::Sra => sew != Sew::E32,
    }
}

/// Encode into the Custom-0 space.
pub fn encode(i: &XcvInstr) -> u32 {
    assert!(valid(i.op, i.sew), "invalid Xcv combination {:?}.{:?}", i.op, i.sew);
    (funct7(i.op) << 25)
        | ((i.rs2 as u32 & 31) << 20)
        | ((i.rs1 as u32 & 31) << 15)
        | (i.sew.code() << 12)
        | ((i.rd as u32 & 31) << 7)
        | OP_CUSTOM0
}

/// Decode from the Custom-0/Custom-1 spaces. Returns `None` if the word is
/// not a recognized Xcv instruction.
pub fn decode(w: u32) -> Option<XcvInstr> {
    if bits(w, 6, 0) != OP_CUSTOM0 {
        return None;
    }
    let op = op_from_funct7(bits(w, 31, 25))?;
    let sew = Sew::from_code(bits(w, 14, 12))?;
    if !valid(op, sew) {
        return None;
    }
    Some(XcvInstr {
        op,
        sew,
        rd: bits(w, 11, 7) as Reg,
        rs1: bits(w, 19, 15) as Reg,
        rs2: bits(w, 24, 20) as Reg,
    })
}

/// Assembly-like rendering.
pub fn disasm(i: &XcvInstr) -> String {
    let m = match i.op {
        XcvOp::SdotSp => "cv.sdotsp",
        XcvOp::Add => "cv.add",
        XcvOp::Sub => "cv.sub",
        XcvOp::Min => "cv.min",
        XcvOp::Max => "cv.max",
        XcvOp::Sra => "cv.sra",
    };
    let suffix = match i.sew {
        Sew::E8 => ".b",
        Sew::E16 => ".h",
        Sew::E32 => "",
    };
    format!(
        "{}{} {}, {}, {}",
        m,
        suffix,
        reg::name(i.rd),
        reg::name(i.rs1),
        reg::name(i.rs2)
    )
}

/// Functional semantics, shared by the CPU model and the tests.
///
/// `acc` is the old value of `rd` (used by the accumulating `SdotSp`).
pub fn exec(op: XcvOp, sew: Sew, rs1: u32, rs2: u32, acc: u32) -> u32 {
    use crate::simd::swar;
    match (op, sew) {
        (XcvOp::SdotSp, s) => acc.wrapping_add(swar::dotp_signed(rs1, rs2, s) as u32),
        (XcvOp::Add, s) => swar::add(rs1, rs2, s),
        (XcvOp::Sub, s) => swar::sub(rs1, rs2, s),
        (XcvOp::Min, s) => swar::min_signed(rs1, rs2, s),
        (XcvOp::Max, s) => swar::max_signed(rs1, rs2, s),
        (XcvOp::Sra, s) => swar::sra(rs1, rs2, s),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all() {
        for op in [XcvOp::SdotSp, XcvOp::Add, XcvOp::Sub, XcvOp::Min, XcvOp::Max, XcvOp::Sra] {
            for sew in Sew::ALL {
                if !valid(op, sew) {
                    continue;
                }
                let i = XcvInstr { op, sew, rd: 7, rs1: 13, rs2: 28 };
                let w = encode(&i);
                assert_eq!(decode(w), Some(i), "{}", disasm(&i));
            }
        }
    }

    #[test]
    fn invalid_combos_rejected() {
        assert!(!valid(XcvOp::SdotSp, Sew::E32));
        assert!(!valid(XcvOp::Add, Sew::E32));
        assert!(valid(XcvOp::Max, Sew::E32));
    }

    #[test]
    fn sdotsp_b_semantics() {
        // 4 int8 pairs: (1,2) (3,4) (-1,5) (2,-3) → 2+12-5-6 = 3, + acc 10
        let rs1 = u32::from_le_bytes([1, 3, (-1i8) as u8, 2]);
        let rs2 = u32::from_le_bytes([2, 4, 5, (-3i8) as u8]);
        assert_eq!(exec(XcvOp::SdotSp, Sew::E8, rs1, rs2, 10), 13);
    }

    #[test]
    fn max_b_is_relu() {
        let x = u32::from_le_bytes([(-5i8) as u8, 7, (-128i8) as u8, 0]);
        let r = exec(XcvOp::Max, Sew::E8, x, 0, 0);
        assert_eq!(r.to_le_bytes(), [0, 7, 0, 0]);
    }
}
