//! Linear graph IR for multi-layer INT8 inference on NM-Carus tiles.
//!
//! A [`Graph`] is a chain of the existing benchmark kernels — e.g.
//! `matmul:p=32,add,relu,maxpool` — executed at one element width with a
//! quantize/dequantize boundary: wide sensor values are scaled and
//! saturated to the graph SEW on entry ([`quantize`]), flow through the
//! chain in fixed point, and leave sign-extended ([`dequantize`]). This is
//! the integer-NPU convention (cf. the EdgeNPU lowering mirrored in
//! `python/compile/`): all inter-layer tensors are narrow integers, which
//! is what makes keeping them *resident in tile SRAM* between layers
//! worthwhile.
//!
//! [`compile`] lowers a graph to a [`Schedule`]: per-layer tile
//! assignment under a [`Pipeline`] mode plus the inter-layer
//! [`Boundary`] decision —
//!
//! - [`Boundary::Resident`]: the producer's output is one contiguous,
//!   word-aligned span in its tile window, so the consumer's activation
//!   arrives via a single tile-to-tile DMA (or no DMA at all when source
//!   and destination coincide), never touching host RAM.
//! - [`Boundary::Staged`]: the producer's output interleaves valid
//!   per-row prefixes with stale bytes (maxpool, conv2d), so the chunks
//!   are repacked through the host staging pool — the fallback path the
//!   cycle report quantifies against.
//!
//! The schedule is deterministic arithmetic over the layer shapes — no
//! RNG — and [`Schedule::render`] is byte-mirrored by
//! `python/compile/graph.py` against `ci/golden/model_schedule.txt`, so a
//! model defined in Python provably compiles to the same schedule. The
//! executor lives in [`crate::sched::pipeline`]; the CPU-golden
//! reference semantics ([`Graph::golden_item`]) reuse
//! [`golden::compute`] layer by layer.

use crate::isa::Sew;
use crate::kernels::carus::output_chunks;
use crate::kernels::{golden, Family, Kernel, Target};
use crate::spec::{family_slug, shape_of};

/// Typed graph-layer error: everything that can be wrong with a graph
/// spec or its lowering, attributed to a layer index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphError {
    /// The spec names no layers.
    Empty,
    /// A layer clause does not parse.
    Parse { layer: usize, reason: String },
    /// Operand-transforming kernels (matmul/gemm/conv2d) need host-side
    /// input packing, so they are only legal as the entry layer.
    MidChainTransform { layer: usize, family: Family },
    /// An explicit `n=` contradicts the shape inferred from the producer.
    ShapeMismatch { layer: usize, given: u32, inferred: u32 },
    /// A maxpool consumer needs its input to factor into 16 rows.
    NotPoolable { layer: usize, elems: u32 },
    /// The shape fails the NM-Carus staging envelope.
    InvalidShape { layer: usize, reason: String },
    /// An output chunk is not word-aligned, so no DMA can move it.
    Unaligned { layer: usize, off: u32, len: u32 },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, fm: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::Empty => write!(fm, "empty graph"),
            GraphError::Parse { layer, reason } => write!(fm, "layer {layer}: {reason}"),
            GraphError::MidChainTransform { layer, family } => write!(
                fm,
                "layer {layer}: {} transforms its operands host-side and is only legal as \
                 the entry layer",
                family_slug(*family)
            ),
            GraphError::ShapeMismatch { layer, given, inferred } => write!(
                fm,
                "layer {layer}: explicit n={given} contradicts the inferred shape n={inferred}"
            ),
            GraphError::NotPoolable { layer, elems } => write!(
                fm,
                "layer {layer}: maxpool needs a 16-row input, got {elems} elements"
            ),
            GraphError::InvalidShape { layer, reason } => {
                write!(fm, "layer {layer}: invalid shape: {reason}")
            }
            GraphError::Unaligned { layer, off, len } => write!(
                fm,
                "layer {layer}: output chunk ({off}, {len}) is not word-aligned"
            ),
        }
    }
}

impl std::error::Error for GraphError {}

/// A validated linear kernel chain at one element width.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    /// The layers, entry first; shapes fully resolved.
    pub layers: Vec<Kernel>,
    /// Element width of every inter-layer tensor.
    pub sew: Sew,
    /// Base seed for inputs and per-layer weights.
    pub seed: u64,
}

/// Elements of the activation operand a kernel consumes.
pub fn in_elems(kernel: Kernel) -> u32 {
    match kernel {
        Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n } => n,
        Kernel::Relu { n } | Kernel::LeakyRelu { n } => n,
        Kernel::Matmul { .. } | Kernel::Gemm { .. } => 64,
        Kernel::Conv2d { n, .. } => 8 * n,
        Kernel::Maxpool { n } => 16 * n,
    }
}

/// Elements of the output tensor a kernel produces.
pub fn out_elems(kernel: Kernel) -> u32 {
    match kernel {
        Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n } => n,
        Kernel::Relu { n } | Kernel::LeakyRelu { n } => n,
        Kernel::Matmul { p } | Kernel::Gemm { p } => 8 * p,
        Kernel::Conv2d { n, f } => (8 - f + 1) * (n - f + 1),
        Kernel::Maxpool { n } => 8 * (n / 2),
    }
}

/// Quantize one wide (int32-range) value to the graph SEW: scale by the
/// width difference, then saturate — the EdgeNPU-style entry boundary.
pub fn quantize(v: i64, sew: Sew) -> i64 {
    let scaled = v >> (32 - sew.bits());
    let hi = (1i64 << (sew.bits() - 1)) - 1;
    scaled.clamp(-hi - 1, hi)
}

/// Dequantize one output element: the chain's fixed-point value,
/// sign-extended back to the host's integer width.
pub fn dequantize(v: i64) -> i32 {
    v as i32
}

const ITEM_SALT: u64 = 0x9e37_79b9_7f4a_7c15;
const LAYER_SALT: u64 = 0xc2b2_ae3d_27d4_eb4f;

impl Graph {
    /// Parse a graph spec: comma-separated layer clauses, each a family
    /// name optionally followed by `:`-separated `dim=value` pairs
    /// (`matmul:p=32,add,relu,maxpool`). The entry layer falls back to
    /// the paper's Table V shape for dimensions not given; every later
    /// layer's shape is inferred from its producer.
    pub fn parse(spec: &str, sew: Sew, seed: u64) -> Result<Graph, GraphError> {
        let mut layers: Vec<Kernel> = Vec::new();
        let clauses: Vec<&str> = spec.split(',').map(str::trim).collect();
        if clauses.iter().all(|c| c.is_empty()) {
            return Err(GraphError::Empty);
        }
        for (layer, clause) in clauses.iter().enumerate() {
            let mut fields = clause.split(':');
            let name = fields.next().unwrap_or("").trim();
            let family = Family::parse(name).ok_or_else(|| GraphError::Parse {
                layer,
                reason: format!("unknown kernel `{name}`"),
            })?;
            let (mut n, mut p, mut f) = (None, None, None);
            for kv in fields {
                let (k, v) = kv.split_once('=').ok_or_else(|| GraphError::Parse {
                    layer,
                    reason: format!("expected dim=value, got `{kv}`"),
                })?;
                let v: u32 = v.trim().parse().map_err(|_| GraphError::Parse {
                    layer,
                    reason: format!("bad value in `{kv}`"),
                })?;
                match k.trim() {
                    "n" => n = Some(v),
                    "p" => p = Some(v),
                    "f" => f = Some(v),
                    other => {
                        return Err(GraphError::Parse {
                            layer,
                            reason: format!("unknown dimension `{other}` (n, p, f)"),
                        })
                    }
                }
            }
            let kernel = if layer == 0 {
                Kernel::with_shape(family, Target::Carus, sew, n, p, f)
            } else {
                // Mid-chain layers consume the producer's activation in
                // place (tile offset 0); kernels that need transformed
                // operand images cannot.
                if matches!(family, Family::Matmul | Family::Gemm | Family::Conv2d) {
                    return Err(GraphError::MidChainTransform { layer, family });
                }
                if p.is_some() || f.is_some() {
                    return Err(GraphError::Parse {
                        layer,
                        reason: "only the entry layer takes p/f dimensions".into(),
                    });
                }
                let elems = out_elems(layers[layer - 1]);
                let inferred = if family == Family::Maxpool {
                    if elems % 16 != 0 {
                        return Err(GraphError::NotPoolable { layer, elems });
                    }
                    elems / 16
                } else {
                    elems
                };
                if let Some(given) = n {
                    if given != inferred {
                        return Err(GraphError::ShapeMismatch { layer, given, inferred });
                    }
                }
                crate::spec::kernel_from(family, inferred, 0, 0)
            };
            kernel
                .validate(Target::Carus, sew)
                .map_err(|reason| GraphError::InvalidShape { layer, reason })?;
            layers.push(kernel);
        }
        Ok(Graph { layers, sew, seed })
    }

    /// Canonical spec string (round-trips through [`Graph::parse`]).
    pub fn spec_string(&self) -> String {
        let clauses: Vec<String> = self
            .layers
            .iter()
            .enumerate()
            .map(|(i, &k)| {
                let slug = family_slug(k.family());
                if i > 0 {
                    return slug.to_string(); // inferred shapes stay implicit
                }
                let (n, p, f) = shape_of(k);
                let mut s = slug.to_string();
                for (key, v) in [("n", n), ("p", p), ("f", f)] {
                    if v != 0 {
                        s.push_str(&format!(":{key}={v}"));
                    }
                }
                s
            })
            .collect();
        clauses.join(",")
    }

    /// Elements the graph consumes / produces per item.
    pub fn input_elems(&self) -> u32 {
        in_elems(self.layers[0])
    }
    pub fn output_elems(&self) -> u32 {
        out_elems(*self.layers.last().unwrap())
    }

    fn item_seed(&self, item: u32) -> u64 {
        self.seed ^ ITEM_SALT.wrapping_mul(item as u64 + 1)
    }

    fn layer_seed(&self, layer: usize) -> u64 {
        self.seed ^ LAYER_SALT.wrapping_mul(layer as u64 + 1)
    }

    /// One item's quantized entry activation: wide sensor draws pushed
    /// through [`quantize`].
    pub fn item_input(&self, item: u32) -> Vec<i64> {
        let mut rng = golden::Rng(self.item_seed(item));
        (0..self.input_elems()).map(|_| quantize(rng.elem(Sew::E32), self.sew)).collect()
    }

    /// A layer's weight operands `(b, c)` — shared by every batch item,
    /// derived from the layer seed through the same generator the
    /// single-kernel golden path uses.
    pub fn layer_operands(&self, layer: usize) -> (Vec<i64>, Vec<i64>) {
        let d = golden::generate(self.layers[layer], self.sew, self.layer_seed(layer));
        (golden::unpack(&d.b, self.sew), golden::unpack(&d.c, self.sew))
    }

    /// The CPU-golden reference execution of one item: per-layer
    /// [`golden::WorkloadData`] where `a` is the incoming activation,
    /// `b`/`c` the layer weights, and `expect` the layer output — each
    /// layer's `expect` feeding the next layer's `a`. The tiled executor
    /// stages exactly these bytes and must reproduce every `expect`
    /// byte-identically.
    pub fn golden_item(&self, item: u32) -> Vec<golden::WorkloadData> {
        let sew = self.sew;
        let mut act = self.item_input(item);
        let mut out = Vec::with_capacity(self.layers.len());
        for (layer, &kernel) in self.layers.iter().enumerate() {
            let (b, c) = self.layer_operands(layer);
            let expect = golden::compute(kernel, sew, &act, &b, &c);
            out.push(golden::WorkloadData {
                a: golden::pack(&act, sew),
                b: golden::pack(&b, sew),
                c: golden::pack(&c, sew),
                expect: golden::pack(&expect, sew),
            });
            act = expect;
        }
        out
    }

    /// One item's dequantized final output.
    pub fn golden_output(&self, item: u32) -> Vec<i32> {
        let layers = self.golden_item(item);
        golden::unpack(&layers.last().unwrap().expect, self.sew)
            .into_iter()
            .map(dequantize)
            .collect()
    }
}

/// How a batch of items maps onto the tiles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pipeline {
    /// Layers spread across tiles (layer *L* on tile *L* mod *T*);
    /// activations hand tile-to-tile.
    Layer,
    /// The whole graph replicated per tile; item *i* runs on tile *i*.
    Batch,
}

impl Pipeline {
    pub const ALL: [Pipeline; 2] = [Pipeline::Layer, Pipeline::Batch];

    pub fn name(self) -> &'static str {
        match self {
            Pipeline::Layer => "layer",
            Pipeline::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Option<Pipeline> {
        match s {
            "layer" => Some(Pipeline::Layer),
            "batch" => Some(Pipeline::Batch),
            _ => None,
        }
    }
}

/// How a layer's activation arrives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Boundary {
    /// Entry activation, staged from the host pool.
    Entry,
    /// Single contiguous producer span: direct tile-to-tile DMA (elided
    /// entirely when source and destination spans coincide).
    Resident,
    /// Multi-chunk producer output: repacked through the host pool.
    Staged,
}

impl Boundary {
    pub fn name(self) -> &'static str {
        match self {
            Boundary::Entry => "entry",
            Boundary::Resident => "resident",
            Boundary::Staged => "staged",
        }
    }
}

/// One layer of a lowered schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerPlan {
    pub kernel: Kernel,
    /// How this layer's activation arrives.
    pub boundary: Boundary,
    /// Fixed tile (layer pipeline) or `None` for "the item's own tile"
    /// (batch pipeline).
    pub tile: Option<u32>,
    pub elems_in: u32,
    pub elems_out: u32,
}

/// A graph lowered onto a tile configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    pub graph: Graph,
    pub tiles: u32,
    pub pipeline: Pipeline,
    pub layers: Vec<LayerPlan>,
}

/// Lower a graph onto `tiles` NM-Carus tiles under a pipeline mode:
/// assign tiles, decide every inter-layer [`Boundary`], and verify that
/// each layer's output chunks are DMA-movable.
pub fn compile(graph: &Graph, tiles: u32, pipeline: Pipeline) -> Result<Schedule, GraphError> {
    assert!(tiles >= 1, "need at least one tile");
    let mut layers = Vec::with_capacity(graph.layers.len());
    for (layer, &kernel) in graph.layers.iter().enumerate() {
        // Every layer's output moves by DMA at least once (inter-layer
        // boundary or the final drain), so every chunk must be
        // word-aligned.
        for (off, len) in output_chunks(kernel, graph.sew) {
            if off % 4 != 0 || len % 4 != 0 || len == 0 {
                return Err(GraphError::Unaligned { layer, off, len });
            }
        }
        let boundary = if layer == 0 {
            Boundary::Entry
        } else if output_chunks(graph.layers[layer - 1], graph.sew).len() == 1 {
            Boundary::Resident
        } else {
            Boundary::Staged
        };
        layers.push(LayerPlan {
            kernel,
            boundary,
            tile: match pipeline {
                Pipeline::Layer => Some(layer as u32 % tiles),
                Pipeline::Batch => None,
            },
            elems_in: in_elems(kernel),
            elems_out: out_elems(kernel),
        });
    }
    Ok(Schedule { graph: graph.clone(), tiles, pipeline, layers })
}

impl Schedule {
    /// Canonical textual rendering — the cross-language parity surface.
    /// `python/compile/graph.py` produces this byte-for-byte for the same
    /// inputs, locked by `ci/golden/model_schedule.txt`.
    pub fn render(&self) -> String {
        let mut s = String::from("# heeperator model schedule v1\n");
        s.push_str(&format!(
            "graph {} sew={} tiles={} pipeline={}\n",
            self.graph.spec_string(),
            self.graph.sew.bits(),
            self.tiles,
            self.pipeline.name()
        ));
        for (i, l) in self.layers.iter().enumerate() {
            let (n, p, f) = shape_of(l.kernel);
            let tile = match l.tile {
                Some(t) => t.to_string(),
                None => "item".to_string(),
            };
            s.push_str(&format!(
                "layer {i} {} n={n} p={p} f={f} tile={tile} in={} elems_in={} elems_out={}\n",
                family_slug(l.kernel.family()),
                l.boundary.name(),
                l.elems_in,
                l.elems_out
            ));
        }
        s
    }

    /// Count of (resident, staged) inter-layer boundaries.
    pub fn boundary_counts(&self) -> (u32, u32) {
        let mut resident = 0;
        let mut staged = 0;
        for l in &self.layers {
            match l.boundary {
                Boundary::Resident => resident += 1,
                Boundary::Staged => staged += 1,
                Boundary::Entry => {}
            }
        }
        (resident, staged)
    }
}

/// The canonical demo chain: the paper's Table V matmul feeding a
/// bias-add, ReLU, and 2×2 maxpool — every inter-layer tensor resident.
pub const CANONICAL: &str = "matmul:p=32,add,relu,maxpool";

#[cfg(test)]
mod tests {
    use super::*;

    fn canonical() -> Graph {
        Graph::parse(CANONICAL, Sew::E8, 7).expect("canonical parses")
    }

    #[test]
    fn parse_infers_shapes() {
        let g = canonical();
        assert_eq!(
            g.layers,
            vec![
                Kernel::Matmul { p: 32 },
                Kernel::Add { n: 256 },
                Kernel::Relu { n: 256 },
                Kernel::Maxpool { n: 16 },
            ]
        );
        assert_eq!(g.input_elems(), 64);
        assert_eq!(g.output_elems(), 64);
        assert_eq!(Graph::parse(&g.spec_string(), Sew::E8, 7).unwrap(), g);
    }

    #[test]
    fn parse_rejects_typed() {
        let e = Graph::parse("", Sew::E8, 0).unwrap_err();
        assert_eq!(e, GraphError::Empty);
        let e = Graph::parse("blur", Sew::E8, 0).unwrap_err();
        assert!(matches!(e, GraphError::Parse { layer: 0, .. }), "{e}");
        let e = Graph::parse("relu:n=256,matmul:p=8", Sew::E8, 0).unwrap_err();
        assert!(matches!(e, GraphError::MidChainTransform { layer: 1, .. }), "{e}");
        let e = Graph::parse("matmul:p=32,add:n=100", Sew::E8, 0).unwrap_err();
        assert_eq!(e, GraphError::ShapeMismatch { layer: 1, given: 100, inferred: 256 });
        // 24 elements does not factor into 16 rows.
        let e = Graph::parse("relu:n=24,maxpool", Sew::E8, 0).unwrap_err();
        assert_eq!(e, GraphError::NotPoolable { layer: 1, elems: 24 });
        let e = Graph::parse("add:n=6", Sew::E8, 0).unwrap_err();
        assert!(matches!(e, GraphError::InvalidShape { layer: 0, .. }), "{e}");
    }

    #[test]
    fn compile_assigns_boundaries_and_tiles() {
        let g = canonical();
        let s = compile(&g, 2, Pipeline::Layer).unwrap();
        let kinds: Vec<Boundary> = s.layers.iter().map(|l| l.boundary).collect();
        assert_eq!(
            kinds,
            vec![Boundary::Entry, Boundary::Resident, Boundary::Resident, Boundary::Resident]
        );
        let tiles: Vec<Option<u32>> = s.layers.iter().map(|l| l.tile).collect();
        assert_eq!(tiles, vec![Some(0), Some(1), Some(0), Some(1)]);
        assert_eq!(s.boundary_counts(), (3, 0));

        let s = compile(&g, 2, Pipeline::Batch).unwrap();
        assert!(s.layers.iter().all(|l| l.tile.is_none()));

        // A maxpool producer forces the staged fallback for its consumer.
        let g = Graph::parse("matmul:p=32,maxpool,relu", Sew::E8, 7).unwrap();
        let s = compile(&g, 2, Pipeline::Layer).unwrap();
        assert_eq!(s.layers[2].boundary, Boundary::Staged);
        assert_eq!(s.boundary_counts(), (1, 1));
    }

    #[test]
    fn compile_rejects_unaligned_chunks() {
        // maxpool n=12 at E8: rows are word-aligned but the valid half-row
        // prefix (6 bytes) is not DMA-movable.
        let g = Graph::parse("maxpool:n=12", Sew::E8, 0).unwrap();
        let e = compile(&g, 1, Pipeline::Layer).unwrap_err();
        assert_eq!(e, GraphError::Unaligned { layer: 0, off: 0, len: 6 });
    }

    #[test]
    fn golden_chain_feeds_forward() {
        let g = canonical();
        let items = g.golden_item(0);
        assert_eq!(items.len(), 4);
        for w in items.windows(2) {
            assert_eq!(w[0].expect, w[1].a, "layer output feeds next layer's activation");
        }
        // Weights are shared across items; activations are not.
        let other = g.golden_item(1);
        assert_eq!(items[0].b, other[0].b);
        assert_ne!(items[0].a, other[0].a);
        // Entry activations are genuinely quantized into the E8 range.
        let input = g.item_input(0);
        assert!(input.iter().all(|&v| (-128..=127).contains(&v)));
        assert_eq!(g.golden_output(0).len(), 64);
    }

    #[test]
    fn quantize_saturates() {
        assert_eq!(quantize(i32::MAX as i64, Sew::E8), 127);
        assert_eq!(quantize(i32::MIN as i64, Sew::E8), -128);
        assert_eq!(quantize(0, Sew::E8), 0);
        assert_eq!(quantize(3 << 24, Sew::E8), 3);
        assert_eq!(dequantize(-5), -5);
    }

    #[test]
    fn schedule_render_matches_fixture() {
        let g = canonical();
        let rendered = compile(&g, 2, Pipeline::Layer).unwrap().render();
        let fixture = include_str!(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../ci/golden/model_schedule.txt"
        ));
        assert_eq!(rendered, fixture, "re-generate ci/golden/model_schedule.txt");
    }
}
