//! Minimal benchmarking harness (criterion is not in the offline vendor
//! set). Adaptive iteration count, median-of-runs reporting, and a
//! machine-readable summary line per benchmark:
//!
//! ```text
//! BENCH <name> median_ns=<t> runs=<n> [throughput=<v> <unit>]
//! ```
//!
//! Used by the `rust/benches/*.rs` binaries (harness = false), which
//! measure the *simulator's* performance — the Layer-3 hot path of this
//! project (see EXPERIMENTS.md §Perf).

use std::time::Instant;

/// Measurement of one benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub median_ns: f64,
    pub runs: usize,
}

/// Run `f` repeatedly and report the median wall time.
///
/// `f` receives nothing and should perform one complete unit of work;
/// return values should be black-boxed by the caller via [`sink`].
pub fn bench(name: &str, mut f: impl FnMut()) -> Measurement {
    // Warm-up.
    for _ in 0..2 {
        f();
    }
    // Calibrate: aim for ≥ 300 ms total or ≥ 30 runs, whichever first.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64();
    let runs = ((0.3 / once.max(1e-9)) as usize).clamp(5, 30);
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_ns = samples[samples.len() / 2];
    println!("BENCH {name} median_ns={median_ns:.0} runs={runs}");
    Measurement { name: name.to_string(), median_ns, runs }
}

/// Report a throughput figure derived from a measurement.
pub fn throughput(m: &Measurement, units: f64, unit_name: &str) {
    let per_sec = units / (m.median_ns / 1e9);
    println!(
        "BENCH {} throughput={:.2}M {unit_name}/s",
        m.name,
        per_sec / 1e6
    );
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn sink<T>(v: T) -> T {
    std::hint::black_box(v)
}
