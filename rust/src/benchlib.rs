//! Minimal benchmarking harness (criterion is not in the offline vendor
//! set). Adaptive iteration count, median-of-runs reporting, and a
//! machine-readable summary line per benchmark:
//!
//! ```text
//! BENCH <name> median_ns=<t> runs=<n> [throughput=<v> <unit>]
//! ```
//!
//! Used by the `rust/benches/*.rs` binaries (harness = false), which
//! measure the *simulator's* performance — the Layer-3 hot path of this
//! project (see EXPERIMENTS.md §Perf).

use std::time::Instant;

/// Measurement of one benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub median_ns: f64,
    pub runs: usize,
}

/// Run `f` repeatedly and report the median wall time.
///
/// `f` receives nothing and should perform one complete unit of work;
/// return values should be black-boxed by the caller via [`sink`].
pub fn bench(name: &str, mut f: impl FnMut()) -> Measurement {
    // Warm-up.
    for _ in 0..2 {
        f();
    }
    // Calibrate: aim for ≥ 300 ms total or ≥ 30 runs, whichever first.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64();
    let runs = ((0.3 / once.max(1e-9)) as usize).clamp(5, 30);
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_ns = samples[samples.len() / 2];
    println!("BENCH {name} median_ns={median_ns:.0} runs={runs}");
    let m = Measurement { name: name.to_string(), median_ns, runs };
    json_sink(&m);
    m
}

/// Machine-readable feed for CI perf tracking: when `BENCHLIB_JSON`
/// names a file, every measurement appends one JSON line
/// (`{"id": ..., "median_ns": ..., "runs": ...}`) that the perf-smoke
/// job folds into `BENCH_6.json`.
fn json_sink(m: &Measurement) {
    let Ok(path) = std::env::var("BENCHLIB_JSON") else { return };
    if path.is_empty() {
        return;
    }
    append_line(&path, &json_line(m));
}

/// One measurement as a JSON object (the `BENCHLIB_JSON` line format).
fn json_line(m: &Measurement) -> String {
    format!(
        "{{\"id\": \"{}\", \"median_ns\": {:.0}, \"runs\": {}}}",
        m.name, m.median_ns, m.runs
    )
}

/// A throughput report as a JSON object. `median_ns` is deliberately
/// absent — consumers (ci/check_bench.py) treat such lines as rate
/// reports, not wall-time measurements.
fn throughput_line(m: &Measurement, per_sec: f64, unit_name: &str) -> String {
    format!(
        "{{\"id\": \"{}_throughput\", \"throughput_per_s\": {:.0}, \"unit\": \"{}/s\", \"runs\": {}}}",
        m.name, per_sec, unit_name, m.runs
    )
}

/// Append one line to `path` (best effort — a benchmark must never fail
/// because the summary file is unwritable).
fn append_line(path: &str, line: &str) {
    use std::io::Write as _;
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        let _ = writeln!(f, "{line}");
    }
}

/// Report a throughput figure derived from a measurement — printed, and
/// (like [`bench`]) appended to the `BENCHLIB_JSON` feed, so CI perf
/// tracking records rates such as simulated cycles per host second next
/// to the raw wall times.
pub fn throughput(m: &Measurement, units: f64, unit_name: &str) {
    let per_sec = units / (m.median_ns / 1e9);
    println!(
        "BENCH {} throughput={:.2}M {unit_name}/s",
        m.name,
        per_sec / 1e6
    );
    if let Ok(path) = std::env::var("BENCHLIB_JSON") {
        if !path.is_empty() {
            append_line(&path, &throughput_line(m, per_sec, unit_name));
        }
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn sink<T>(v: T) -> T {
    std::hint::black_box(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurements_render_and_append_as_json_lines() {
        // No env mutation here: `bench()` reads BENCHLIB_JSON once and
        // delegates to `append_json_line`, which is what we exercise
        // (set_var would race concurrently-running tests' getenv calls).
        let m = Measurement { name: "unit_test_probe".into(), median_ns: 1234.0, runs: 7 };
        let line = json_line(&m);
        assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        assert!(line.contains("\"id\": \"unit_test_probe\""), "{line}");
        assert!(line.contains("\"median_ns\": 1234"), "{line}");
        assert!(line.contains("\"runs\": 7"), "{line}");

        let path = std::env::temp_dir().join(format!("benchlib_json_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let p = path.to_str().expect("utf-8 temp path");
        append_line(p, &json_line(&m));
        append_line(p, &json_line(&m));
        let text = std::fs::read_to_string(&path).expect("json lines written");
        let _ = std::fs::remove_file(&path);
        assert_eq!(text.lines().count(), 2, "append, not truncate");
        assert_eq!(text.lines().next().unwrap(), json_line(&m));
    }

    #[test]
    fn throughput_lines_are_rate_reports_without_median_ns() {
        let m = Measurement { name: "e2e_probe".into(), median_ns: 2e9, runs: 5 };
        // 10 M units over a 2 s median → 5 M units/s.
        let line = throughput_line(&m, 10.0e6 / 2.0, "sim-cycles");
        assert!(line.contains("\"id\": \"e2e_probe_throughput\""), "{line}");
        assert!(line.contains("\"throughput_per_s\": 5000000"), "{line}");
        assert!(line.contains("\"unit\": \"sim-cycles/s\""), "{line}");
        assert!(!line.contains("median_ns"), "rate lines must not look like wall-time lines");
    }
}
