//! `heeperator` — CLI for the NM-Caesar / NM-Carus reproduction.
//!
//! Regenerates every table and figure of the paper's evaluation:
//!
//! ```text
//! heeperator all [--quick] [--out DIR] [--jobs N]   # everything (Tables IV–VIII, Figs 7/11/12/13)
//! heeperator table4|fig7|table5|fig11|fig12|fig13|table6|table7|table8 [--quick] [--out DIR]
//! heeperator ablations [--out DIR]                  # the four ablation studies
//! heeperator ad                                     # Anomaly-Detection end-to-end summary
//! heeperator sweep --target T --family F --sew W [--n N] [--p P] [--f F] [--seed S] [--out DIR]
//! ```
//!
//! `all` fans the independent reports out over a `std::thread` worker
//! pool (`harness::executor`); `--jobs N` bounds the pool, `--jobs 1` is
//! the sequential baseline and produces byte-identical report text. All
//! simulations drain through one shared `sweep::SweepSession`, so each
//! `(target, kernel, sew, seed)` grid point runs at most once per
//! invocation no matter how many reports consume it.
//!
//! `sweep` runs arbitrary workload shapes: `--target`/`--family`/`--sew`
//! accept a name or `all`; `--n`/`--p`/`--f` override the free
//! dimensions (anything omitted falls back to the paper's Table V shape
//! for that target/width).
//!
//! (Hand-rolled argument parsing: clap is not in the offline vendor set.)

use nmc::harness::{self, executor, Report};
use nmc::isa::Sew;
use nmc::kernels::{Family, Kernel, Target};
use nmc::sweep::SweepSession;
use std::io::Write;

/// Parsed command line. Kept dumb (no behavior) so tests can assert on
/// exactly what the hand-rolled parser extracted.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Cli {
    cmd: String,
    quick: bool,
    out: Option<String>,
    jobs: Option<usize>,
    /// `sweep` selectors: target/family/sew name or "all" (default).
    target: Option<String>,
    family: Option<String>,
    sew: Option<String>,
    /// `sweep` free dimensions; absent = paper default per (target, sew).
    n: Option<u32>,
    p: Option<u32>,
    f: Option<u32>,
    seed: Option<u64>,
}

impl Cli {
    fn new(cmd: &str) -> Cli {
        Cli {
            cmd: cmd.to_string(),
            quick: false,
            out: None,
            jobs: None,
            target: None,
            family: None,
            sew: None,
            n: None,
            p: None,
            f: None,
            seed: None,
        }
    }
}

/// Parse a `--flag value` string argument; a following flag is not a
/// value (left for the loop), a missing value leaves the option unset.
fn parse_str(args: &[String], i: &mut usize) -> Option<String> {
    let v = args.get(*i + 1).filter(|v| !v.starts_with("--")).cloned();
    if v.is_some() {
        *i += 1; // consume the value
    }
    v
}

/// Parse a `--flag value` numeric argument; a present, unparsable value is
/// an error (silently ignoring it would run the wrong workload), a missing
/// value leaves the option unset.
fn parse_num<T: std::str::FromStr>(
    args: &[String],
    i: &mut usize,
    flag: &str,
) -> Result<Option<T>, String> {
    if let Some(v) = args.get(*i + 1).filter(|v| !v.starts_with("--")) {
        match v.parse::<T>() {
            Ok(n) => {
                *i += 1; // consume the value
                Ok(Some(n))
            }
            Err(_) => Err(format!("{flag} expects a number, got `{v}`")),
        }
    } else {
        Ok(None)
    }
}

/// Parse `args` (everything after argv[0]). Unknown flags are ignored —
/// the subcommand dispatcher prints usage for unknown commands — but a
/// present, unparsable numeric value is an error: silently falling back
/// to a default would do the opposite of what the user asked for.
fn parse_args(args: &[String]) -> Result<Cli, String> {
    let mut cli = Cli::new("help");
    let mut cmd: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--quick" => cli.quick = true,
            "--out" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.out = Some(v);
                }
            }
            "--jobs" => {
                cli.jobs = parse_num::<usize>(args, &mut i, "--jobs")?.map(|n| n.max(1));
            }
            "--target" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.target = Some(v);
                }
            }
            "--family" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.family = Some(v);
                }
            }
            "--sew" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.sew = Some(v);
                }
            }
            "--n" => cli.n = parse_num::<u32>(args, &mut i, "--n")?,
            "--p" => cli.p = parse_num::<u32>(args, &mut i, "--p")?,
            "--f" => cli.f = parse_num::<u32>(args, &mut i, "--f")?,
            "--seed" => cli.seed = parse_num::<u64>(args, &mut i, "--seed")?,
            a if !a.starts_with("--") => {
                // First free-standing word is the subcommand.
                if cmd.is_none() {
                    cmd = Some(a.to_string());
                }
            }
            _ => {} // unknown flag: ignored
        }
        i += 1;
    }
    cli.cmd = cmd.unwrap_or_else(|| "help".to_string());
    Ok(cli)
}

/// Resolve the `sweep` selectors into a concrete scenario point list.
/// `all` (or an absent selector) expands over every target / family /
/// width; explicit dimensions are applied per point with paper-default
/// fallback, and every point is shape-validated so an impossible request
/// becomes a CLI error rather than a panic inside an engine.
fn sweep_points(cli: &Cli) -> Result<Vec<(Target, Kernel, Sew)>, String> {
    fn select<T: Copy>(
        spec: Option<&str>,
        what: &str,
        all: &[T],
        parse: impl Fn(&str) -> Option<T>,
        names: &str,
    ) -> Result<Vec<T>, String> {
        match spec {
            None => Ok(all.to_vec()),
            Some(s) if s.eq_ignore_ascii_case("all") => Ok(all.to_vec()),
            Some(s) => parse(s)
                .map(|t| vec![t])
                .ok_or_else(|| format!("unknown {what} `{s}` (use one of {names} or `all`)")),
        }
    }
    let targets =
        select(cli.target.as_deref(), "--target", &Target::ALL, Target::parse, "cpu|caesar|carus")?;
    let families = select(
        cli.family.as_deref(),
        "--family",
        &Family::ALL,
        Family::parse,
        "xor|add|mul|matmul|gemm|conv2d|relu|leakyrelu|maxpool",
    )?;
    let sews = select(cli.sew.as_deref(), "--sew", &Sew::ALL, Sew::parse, "8|16|32")?;

    let mut points = Vec::new();
    for &target in &targets {
        for &family in &families {
            for &sew in &sews {
                let kernel = Kernel::with_shape(family, target, sew, cli.n, cli.p, cli.f);
                kernel
                    .validate(target, sew)
                    .map_err(|e| format!("{target:?} {family:?} {sew}: {e}"))?;
                points.push((target, kernel, sew));
            }
        }
    }
    Ok(points)
}

fn write_reports(reports: &[Report], out: Option<&str>) {
    for r in reports {
        println!("== {} — {} ==", r.id, r.title);
        println!("{}", r.text);
        if let Some(dir) = out {
            std::fs::create_dir_all(dir).expect("create results dir");
            let mut path = std::path::PathBuf::from(dir);
            path.push(format!("{}.txt", r.id));
            std::fs::write(&path, &r.text).expect("write report");
            for (name, csv) in &r.csv {
                let mut p = std::path::PathBuf::from(dir);
                p.push(name);
                std::fs::write(&p, csv).expect("write csv");
            }
            println!("(written to {dir}/{}.txt)", r.id);
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = match parse_args(&args) {
        Ok(cli) => cli,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let out = cli.out.as_deref();
    let jobs = cli.jobs.unwrap_or_else(executor::default_jobs);
    // One memoizing session per invocation: every subcommand that
    // simulates drains through it.
    let session = SweepSession::new();

    match cli.cmd.as_str() {
        "all" => {
            let reports = harness::all_with_jobs(cli.quick, jobs);
            write_reports(&reports, out.or(Some("results")));
        }
        "table4" => write_reports(&[harness::table4()], out),
        "fig7" => write_reports(&[harness::fig7()], out),
        "table5" | "fig11" => {
            let rows = harness::run_table5(&session, cli.quick);
            let reps = vec![harness::table5(&rows), harness::fig11(&rows)];
            write_reports(&reps, out);
        }
        "fig12" => write_reports(&[harness::fig12(&session, cli.quick)], out),
        "fig13" => write_reports(&[harness::fig13(&session)], out),
        "table6" => write_reports(&[harness::table6(&session)], out),
        "table7" => write_reports(&[harness::table7()], out),
        "table8" => write_reports(&[harness::table8()], out),
        "ablations" => write_reports(&harness::ablations::all(&session), out),
        "sweep" => {
            let points = match sweep_points(&cli) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("error: {e}");
                    std::process::exit(2);
                }
            };
            let rep = harness::sweep_report(&session, &points, cli.seed.unwrap_or(1));
            write_reports(&[rep], out);
        }
        "ad" => {
            let golden = nmc::apps::anomaly::golden_forward(&nmc::apps::anomaly::model(2));
            for target in Target::ALL {
                let res = session.anomaly(target, 2);
                let ok = res.output == golden;
                println!(
                    "{:<22} {:>9} cycles  {:>8.2} uJ  output {}",
                    res.name,
                    res.cycles,
                    res.energy_uj,
                    if ok { "OK (matches golden)" } else { "MISMATCH" }
                );
            }
        }
        _ => {
            let mut o = std::io::stdout();
            writeln!(o, "usage: heeperator <all|table4|fig7|table5|fig11|fig12|fig13|table6|table7|table8|ablations|ad|sweep> [--quick] [--out DIR]").unwrap();
            writeln!(o, "       `all` additionally accepts --jobs N (worker pool bound; 1 = sequential)").unwrap();
            writeln!(o, "       `sweep` selects scenarios: --target cpu|caesar|carus|all --family xor|add|mul|matmul|gemm|conv2d|relu|leakyrelu|maxpool|all").unwrap();
            writeln!(o, "               --sew 8|16|32|all, free dims --n N --p P --f F (default: paper Table V shapes), --seed S").unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    /// Parse a known-good command line.
    fn p(list: &[&str]) -> Cli {
        parse_args(&argv(list)).expect("valid command line")
    }

    #[test]
    fn subcommand_selection() {
        assert_eq!(p(&["all"]).cmd, "all");
        assert_eq!(p(&["table5", "--quick"]).cmd, "table5");
        // No positional argument → help.
        assert_eq!(p(&[]).cmd, "help");
        assert_eq!(p(&["--quick"]).cmd, "help");
        // Flags before the subcommand still find it.
        assert_eq!(p(&["--quick", "fig12"]).cmd, "fig12");
    }

    #[test]
    fn quick_flag() {
        assert!(p(&["all", "--quick"]).quick);
        assert!(!p(&["all"]).quick);
    }

    #[test]
    fn out_dir_parsing() {
        assert_eq!(p(&["all", "--out", "results/x"]).out.as_deref(), Some("results/x"));
        // Dangling --out without a value is tolerated as no-out.
        assert_eq!(p(&["all", "--out"]).out, None);
        assert_eq!(p(&["all"]).out, None);
        // A following flag is not swallowed as the value.
        let cli = p(&["all", "--out", "--quick"]);
        assert_eq!(cli.out, None);
        assert!(cli.quick);
    }

    #[test]
    fn jobs_parsing_and_clamping() {
        assert_eq!(p(&["all", "--jobs", "4"]).jobs, Some(4));
        // 0 clamps to the sequential minimum of 1.
        assert_eq!(p(&["all", "--jobs", "0"]).jobs, Some(1));
        // Missing value means "default worker count".
        assert_eq!(p(&["all", "--jobs"]).jobs, None);
        assert_eq!(p(&["all"]).jobs, None);
        // A following flag is not swallowed as the value.
        let cli = p(&["all", "--jobs", "--quick"]);
        assert_eq!(cli.jobs, None);
        assert!(cli.quick);
    }

    #[test]
    fn garbage_jobs_value_is_an_error() {
        // Falling back to max parallelism would invert the user's intent.
        let err = parse_args(&argv(&["all", "--jobs", "lots"])).unwrap_err();
        assert!(err.contains("--jobs"), "{err}");
        assert!(err.contains("lots"), "{err}");
    }

    #[test]
    fn combined_flags_any_order() {
        let cli = p(&["--jobs", "2", "all", "--quick", "--out", "r"]);
        assert_eq!(cli.cmd, "all");
        assert!(cli.quick);
        assert_eq!(cli.out.as_deref(), Some("r"));
        assert_eq!(cli.jobs, Some(2));
    }

    #[test]
    fn sweep_flags_parse() {
        let cli = p(&[
            "sweep", "--target", "carus", "--family", "matmul", "--sew", "8", "--p", "96",
            "--seed", "7",
        ]);
        assert_eq!(cli.cmd, "sweep");
        assert_eq!(cli.target.as_deref(), Some("carus"));
        assert_eq!(cli.family.as_deref(), Some("matmul"));
        assert_eq!(cli.sew.as_deref(), Some("8"));
        assert_eq!(cli.p, Some(96));
        assert_eq!(cli.n, None);
        assert_eq!(cli.f, None);
        assert_eq!(cli.seed, Some(7));
    }

    #[test]
    fn garbage_dim_value_is_an_error() {
        let err = parse_args(&argv(&["sweep", "--n", "many"])).unwrap_err();
        assert!(err.contains("--n"), "{err}");
        assert!(err.contains("many"), "{err}");
    }

    #[test]
    fn sweep_points_expand_and_validate() {
        // Single explicit point.
        let cli = p(&["sweep", "--target", "carus", "--family", "matmul", "--sew", "8", "--p", "96"]);
        let pts = sweep_points(&cli).unwrap();
        assert_eq!(pts, vec![(Target::Carus, Kernel::Matmul { p: 96 }, Sew::E8)]);
        // `all` selectors expand the full cross product.
        let cli = p(&["sweep"]);
        let pts = sweep_points(&cli).unwrap();
        assert_eq!(pts.len(), 3 * 9 * 3);
        // Unknown names are reported, not ignored.
        let cli = p(&["sweep", "--family", "fft"]);
        let err = sweep_points(&cli).unwrap_err();
        assert!(err.contains("fft"), "{err}");
        // Paper-default dimensions apply when no dim flag is given.
        let cli = p(&["sweep", "--target", "cpu", "--family", "add", "--sew", "8"]);
        let pts = sweep_points(&cli).unwrap();
        assert_eq!(pts, vec![(Target::Cpu, Kernel::Add { n: 5120 }, Sew::E8)]);
        // The parse functions' aliases work here too (one source of truth).
        let cli = p(&["sweep", "--target", "nm-carus", "--family", "conv", "--sew", "e8"]);
        let pts = sweep_points(&cli).unwrap();
        assert_eq!(pts, vec![(Target::Carus, Kernel::Conv2d { n: 1024, f: 3 }, Sew::E8)]);
    }

    #[test]
    fn sweep_points_reject_impossible_shapes() {
        // A filter larger than the 8-row image would underflow `8-f+1`
        // inside the engines; the CLI reports it instead.
        let cli = p(&["sweep", "--family", "conv2d", "--f", "12"]);
        let err = sweep_points(&cli).unwrap_err();
        assert!(err.contains("f ≤ 8") || err.contains("f = 12"), "{err}");
        // An NM-Carus B row must fit one 1 KiB logical register.
        let cli = p(&["sweep", "--target", "carus", "--family", "matmul", "--sew", "32", "--p", "1024"]);
        let err = sweep_points(&cli).unwrap_err();
        assert!(err.contains("NM-Carus"), "{err}");
    }

    #[test]
    fn table4_smoke_nonempty_text_and_csv() {
        let rep = harness::table4();
        assert_eq!(rep.id, "table4");
        assert!(rep.text.contains("NM-Caesar"));
        assert!(rep.text.contains("NM-Carus"));
        assert!(!rep.csv.is_empty());
        let (name, csv) = &rep.csv[0];
        assert_eq!(name, "table4.csv");
        assert!(csv.lines().count() >= 4, "header + three rows");
        assert!(csv.starts_with("macro,area_um2"));
    }
}
