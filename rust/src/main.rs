//! `heeperator` — CLI for the NM-Caesar / NM-Carus reproduction.
//!
//! Regenerates every table and figure of the paper's evaluation:
//!
//! ```text
//! heeperator all [--quick] [--out DIR] [--jobs N]   # everything (Tables IV–VIII, Figs 7/11/12/13)
//! heeperator table4|fig7|table5|fig11|fig12|fig13|table6|table7|table8 [--quick] [--out DIR]
//! heeperator ablations [--out DIR]                  # the four ablation studies
//! heeperator ad                                     # Anomaly-Detection end-to-end summary
//! heeperator sweep --target T --family F --sew W [--n N] [--p P] [--f F] [--seed S] [--out DIR]
//! heeperator scale --tiles 1,2,4 [--batch B] [--shard] [--target caesar|carus] [--family F]
//!                  [--sew W] [--n/--p/--f dims] [--quick] [--json FILE] [--out DIR] [--jobs N]
//! heeperator fuzz [--seed S] [--budget N] [--max-insns K] [--replay FILE] [--out DIR]
//! heeperator serve [--listen stdin|PORT] [--tiles N] [--queue N] [--max-batch N] [--linger CYC]
//!                  [--selftest [--trace poisson|bursty|mixed] [--requests N] [--seed S] [--json FILE]]
//! heeperator model [--graph SPEC] [--tiles N] [--pipeline layer|batch] [--sew W] [--seed S]
//!                  [--json FILE] [--out DIR]
//! ```
//!
//! `all` fans the independent reports out over a `std::thread` worker
//! pool (`harness::executor`); `--jobs N` bounds the pool, `--jobs 1` is
//! the sequential baseline and produces byte-identical report text. All
//! simulations drain through one shared `sweep::SweepSession`, so each
//! `(target, kernel, sew, seed)` grid point runs at most once per
//! invocation no matter how many reports consume it.
//!
//! `sweep` runs arbitrary workload shapes: `--target`/`--family`/`--sew`
//! accept a name or `all`; `--n`/`--p`/`--f` override the free
//! dimensions (anything omitted falls back to the paper's Table V shape
//! for that target/width).
//!
//! `scale` co-simulates a batched (or `--shard`ed) workload across every
//! tile count in `--tiles` and reports the scaling curve (speedup,
//! per-tile utilization, DMA/bus contention, energy); `--json FILE`
//! additionally emits the machine-readable cycles + wall-time summary
//! the CI perf-smoke job diffs against `bench-baseline.json`.
//!
//! `fuzz` runs the differential fuzzer (DESIGN.md §11): `--budget` seeded
//! random cases checked across every execution axis; a divergence is
//! shrunk and written to a replayable `fuzz-repro-<seed>.json`, and
//! `--replay FILE` re-checks exactly that case. Exit code 0 = clean,
//! 1 = divergence, 2 = bad invocation.
//!
//! `serve` runs the long-running batch-inference service (DESIGN.md §12):
//! JSONL requests over stdin or TCP through admission control and a
//! coalescing batcher onto the multi-tile scheduler. `--selftest` replays
//! a deterministic seeded load trace on a virtual clock instead and
//! reports latency percentiles / queue depth / per-tile utilization;
//! `--json FILE` writes the machine-readable summary CI gates on.
//!
//! `model` compiles a multi-layer graph spec (DESIGN.md §14) onto NM-Carus
//! tiles and runs it twice — inter-layer tensors resident in tile SRAM,
//! then forced through the host staging pool — reporting the per-layer
//! cycle breakdown and the resident-tensor DMA savings; `--json FILE`
//! writes the `heeperator-model-v1` summary the CI model-smoke job gates
//! on. Every selector surface (sweep/scale/model flags, serve requests,
//! fuzz repro files) resolves through the one `nmc::spec` module.
//!
//! Every subcommand accepts `--timing cycle|event` to pick the simulation
//! timing discipline: `event` (the default) runs the skip-ahead
//! event-driven core, `cycle` forces the per-cycle reference loop. Both
//! produce identical outputs and counters — see
//! `tests/timing_equivalence.rs` — differing only in wall-clock speed.
//!
//! (Hand-rolled argument parsing: clap is not in the offline vendor set.
//! Every flag accepts both the `--flag value` and `--flag=value`
//! spellings — a normalization pre-pass splits the latter.)

use nmc::harness::{self, executor, Report, ScalePoint};
use nmc::isa::Sew;
use nmc::kernels::{Family, Kernel, Target};
use nmc::sched::BatchSpec;
use nmc::spec::JobSpec;
use nmc::sweep::SweepSession;
use std::sync::Arc;

/// Parsed command line. Kept dumb (no behavior) so tests can assert on
/// exactly what the hand-rolled parser extracted.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Cli {
    cmd: String,
    quick: bool,
    out: Option<String>,
    jobs: Option<usize>,
    /// `sweep` selectors: target/family/sew name or "all" (default).
    target: Option<String>,
    family: Option<String>,
    sew: Option<String>,
    /// `sweep` free dimensions; absent = paper default per (target, sew).
    n: Option<u32>,
    p: Option<u32>,
    f: Option<u32>,
    seed: Option<u64>,
    /// `scale` selectors: tile-count list, batch size, shard mode, and
    /// the machine-readable bench-summary path.
    tiles: Option<String>,
    batch: Option<u32>,
    shard: bool,
    json: Option<String>,
    /// Timing discipline: `cycle` (per-cycle reference) or `event`
    /// (skip-ahead, the default). Accepted as `--timing event` or
    /// `--timing=event`; also settable via the `SOC_TIMING` env var.
    timing: Option<String>,
    /// `fuzz` selectors: case budget, instructions per ISA surface, and
    /// the repro file to re-check instead of generating fresh cases.
    budget: Option<u32>,
    max_insns: Option<u32>,
    replay: Option<String>,
    /// `serve` selectors: listen endpoint (`stdin` or a TCP port),
    /// selftest mode with its trace kind and request count, and the
    /// admission/batching policy knobs.
    listen: Option<String>,
    selftest: bool,
    trace: Option<String>,
    requests: Option<u32>,
    queue: Option<usize>,
    max_batch: Option<usize>,
    linger: Option<u64>,
    /// `serve` concurrency: worker pool size, simultaneous-connection
    /// cap, load-generator mode, and the self-contained throughput smoke.
    workers: Option<usize>,
    conns: Option<usize>,
    load: Option<String>,
    throughput: bool,
    /// `model` selectors: the graph spec string and the pipeline mode.
    graph: Option<String>,
    pipeline: Option<String>,
}

impl Cli {
    fn new(cmd: &str) -> Cli {
        Cli {
            cmd: cmd.to_string(),
            quick: false,
            out: None,
            jobs: None,
            target: None,
            family: None,
            sew: None,
            n: None,
            p: None,
            f: None,
            seed: None,
            tiles: None,
            batch: None,
            shard: false,
            json: None,
            timing: None,
            budget: None,
            max_insns: None,
            replay: None,
            listen: None,
            selftest: false,
            trace: None,
            requests: None,
            queue: None,
            max_batch: None,
            linger: None,
            workers: None,
            conns: None,
            load: None,
            throughput: false,
            graph: None,
            pipeline: None,
        }
    }
}

/// Parse a `--flag value` string argument; a following flag is not a
/// value (left for the loop), a missing value leaves the option unset.
fn parse_str(args: &[String], i: &mut usize) -> Option<String> {
    let v = args.get(*i + 1).filter(|v| !v.starts_with("--")).cloned();
    if v.is_some() {
        *i += 1; // consume the value
    }
    v
}

/// Parse a `--flag value` numeric argument; a present, unparsable value is
/// an error (silently ignoring it would run the wrong workload), a missing
/// value leaves the option unset.
fn parse_num<T: std::str::FromStr>(
    args: &[String],
    i: &mut usize,
    flag: &str,
) -> Result<Option<T>, String> {
    if let Some(v) = args.get(*i + 1).filter(|v| !v.starts_with("--")) {
        match v.parse::<T>() {
            Ok(n) => {
                *i += 1; // consume the value
                Ok(Some(n))
            }
            Err(_) => Err(format!("{flag} expects a number, got `{v}`")),
        }
    } else {
        Ok(None)
    }
}

/// Parse `args` (everything after argv[0]). Unknown flags are ignored —
/// the subcommand dispatcher prints usage for unknown commands — but a
/// present, unparsable numeric value is an error: silently falling back
/// to a default would do the opposite of what the user asked for.
fn parse_args(args: &[String]) -> Result<Cli, String> {
    // Normalize `--flag=value` to `--flag value` so both spellings flow
    // through the same arms below.
    let args: Vec<String> = args
        .iter()
        .flat_map(|a| match a.strip_prefix("--").and_then(|rest| rest.split_once('=')) {
            Some((flag, value)) => vec![format!("--{flag}"), value.to_string()],
            None => vec![a.clone()],
        })
        .collect();
    let args = args.as_slice();
    let mut cli = Cli::new("help");
    let mut cmd: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--quick" => cli.quick = true,
            "--out" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.out = Some(v);
                }
            }
            "--jobs" => {
                cli.jobs = parse_num::<usize>(args, &mut i, "--jobs")?.map(|n| n.max(1));
            }
            "--target" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.target = Some(v);
                }
            }
            "--family" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.family = Some(v);
                }
            }
            "--sew" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.sew = Some(v);
                }
            }
            "--n" => cli.n = parse_num::<u32>(args, &mut i, "--n")?,
            "--p" => cli.p = parse_num::<u32>(args, &mut i, "--p")?,
            "--f" => cli.f = parse_num::<u32>(args, &mut i, "--f")?,
            "--seed" => cli.seed = parse_num::<u64>(args, &mut i, "--seed")?,
            "--tiles" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.tiles = Some(v);
                }
            }
            "--batch" => cli.batch = parse_num::<u32>(args, &mut i, "--batch")?,
            "--shard" => cli.shard = true,
            "--json" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.json = Some(v);
                }
            }
            "--timing" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.timing = Some(v);
                }
            }
            "--budget" => cli.budget = parse_num::<u32>(args, &mut i, "--budget")?,
            "--max-insns" => cli.max_insns = parse_num::<u32>(args, &mut i, "--max-insns")?,
            "--replay" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.replay = Some(v);
                }
            }
            "--listen" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.listen = Some(v);
                }
            }
            "--selftest" => cli.selftest = true,
            "--trace" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.trace = Some(v);
                }
            }
            "--requests" => cli.requests = parse_num::<u32>(args, &mut i, "--requests")?,
            "--queue" => cli.queue = parse_num::<usize>(args, &mut i, "--queue")?,
            "--max-batch" => cli.max_batch = parse_num::<usize>(args, &mut i, "--max-batch")?,
            "--linger" => cli.linger = parse_num::<u64>(args, &mut i, "--linger")?,
            "--workers" => cli.workers = parse_num::<usize>(args, &mut i, "--workers")?,
            "--conns" => cli.conns = parse_num::<usize>(args, &mut i, "--conns")?,
            "--load" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.load = Some(v);
                }
            }
            "--throughput" => cli.throughput = true,
            "--graph" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.graph = Some(v);
                }
            }
            "--pipeline" => {
                if let Some(v) = parse_str(args, &mut i) {
                    cli.pipeline = Some(v);
                }
            }
            a if !a.starts_with("--") => {
                // First free-standing word is the subcommand.
                if cmd.is_none() {
                    cmd = Some(a.to_string());
                }
            }
            _ => {} // unknown flag: ignored
        }
        i += 1;
    }
    cli.cmd = cmd.unwrap_or_else(|| "help".to_string());
    Ok(cli)
}

/// Resolve the `sweep` selectors into a concrete scenario point list.
/// `all` (or an absent selector) expands over every target / family /
/// width; explicit dimensions are applied per point with paper-default
/// fallback, and every point is shape-validated so an impossible request
/// becomes a CLI error rather than a panic inside an engine.
fn sweep_points(cli: &Cli) -> Result<Vec<(Target, Kernel, Sew)>, String> {
    fn select<T: Copy>(
        spec: Option<&str>,
        what: &str,
        all: &[T],
        parse: impl Fn(&str) -> Option<T>,
        names: &str,
    ) -> Result<Vec<T>, String> {
        match spec {
            None => Ok(all.to_vec()),
            Some(s) if s.eq_ignore_ascii_case("all") => Ok(all.to_vec()),
            Some(s) => parse(s)
                .map(|t| vec![t])
                .ok_or_else(|| format!("unknown {what} `{s}` (use one of {names} or `all`)")),
        }
    }
    let targets =
        select(cli.target.as_deref(), "--target", &Target::ALL, Target::parse, "cpu|caesar|carus")?;
    let families = select(
        cli.family.as_deref(),
        "--family",
        &Family::ALL,
        Family::parse,
        "xor|add|mul|matmul|gemm|conv2d|relu|leakyrelu|maxpool",
    )?;
    let sews = select(cli.sew.as_deref(), "--sew", &Sew::ALL, Sew::parse, "8|16|32")?;

    let mut points = Vec::new();
    for &target in &targets {
        for &family in &families {
            for &sew in &sews {
                // Resolve each grid point through the one spec path
                // (paper-default shape fallback included).
                let spec = JobSpec::from_selectors(
                    nmc::spec::target_slug(target),
                    nmc::spec::family_slug(family),
                    sew.bits(),
                    cli.n,
                    cli.p,
                    cli.f,
                    cli.seed.unwrap_or(1),
                )
                .map_err(|e| e.to_string())?;
                spec.validate().map_err(|e| format!("{target:?} {family:?} {sew}: {e}"))?;
                points.push((spec.target, spec.kernel, spec.sew));
            }
        }
    }
    Ok(points)
}

/// Scale-friendly default free dimensions per family: sized in *bytes*
/// (element counts shrink with wider elements) so the default batch of a
/// documented invocation fits the SRAM staging pool at every `--sew`,
/// while tile execution still dominates its own staging. Explicit
/// `--n/--p/--f` win.
fn default_scale_dims(family: Family, sew: Sew) -> (Option<u32>, Option<u32>, Option<u32>) {
    let sb = sew.bytes();
    match family {
        // 256 B rows: B + A-columns + output ≈ 6 KiB staged per workload.
        Family::Matmul | Family::Gemm => (None, Some(256 / sb), None),
        Family::Conv2d => (Some(256 / sb), None, Some(3)),
        // 16 input rows + packed output rows ≈ 6 KiB per workload.
        Family::Maxpool => (Some(256 / sb), None, None),
        // 2 KiB per operand.
        _ => (Some(2048 / sb), None, None),
    }
}

/// Parse `--tiles 1,2,4` into a tile-count list.
fn parse_tiles(spec: &str) -> Result<Vec<u32>, String> {
    let mut tiles = Vec::new();
    for part in spec.split(',') {
        let t: u32 = part
            .trim()
            .parse()
            .map_err(|_| format!("--tiles expects comma-separated counts, got `{part}`"))?;
        if t == 0 || t as usize > nmc::bus::MAX_TILES {
            return Err(format!("tile count {t} out of range 1..={}", nmc::bus::MAX_TILES));
        }
        tiles.push(t);
    }
    if tiles.is_empty() {
        return Err("--tiles list is empty".to_string());
    }
    Ok(tiles)
}

/// Resolve the `scale` selectors into a batch spec + tile-count list.
fn scale_spec(cli: &Cli) -> Result<(BatchSpec, Vec<u32>), String> {
    // Family and width resolve first so the scale-specific default
    // dimensions can be computed; the full tuple then goes through the
    // one spec path like every other selector surface.
    let family = match cli.family.as_deref() {
        None => Family::Matmul,
        Some(s) => Family::parse(s).ok_or_else(|| format!("unknown --family `{s}`"))?,
    };
    let sew = match cli.sew.as_deref() {
        None => Sew::E8,
        Some(s) => Sew::parse(s).ok_or_else(|| format!("unknown --sew `{s}` (8|16|32)"))?,
    };
    let (dn, dp, df) = default_scale_dims(family, sew);
    let job = JobSpec::from_selectors(
        cli.target.as_deref().unwrap_or("carus"),
        nmc::spec::family_slug(family),
        sew.bits(),
        cli.n.or(dn),
        cli.p.or(dp),
        cli.f.or(df),
        cli.seed.unwrap_or(1),
    )
    .map_err(|e| format!("{e} (tile targets: caesar|carus)"))?;
    let tiles = parse_tiles(cli.tiles.as_deref().unwrap_or("1,2,4"))?;
    let max_t = *tiles.iter().max().expect("non-empty tile list");
    // Default batch: a few rounds per tile at the largest count (quick
    // halves it), capped so default shapes stay within the staging pool.
    let mult = if cli.quick { 2 } else { 4 };
    let batch = cli.batch.unwrap_or_else(|| (mult * max_t).clamp(max_t, 16));
    let spec = BatchSpec {
        target: job.target,
        kernel: job.kernel,
        sew: job.sew,
        seed: job.seed,
        batch,
        shard: cli.shard,
    };
    Ok((spec, tiles))
}

/// Render the machine-readable bench summary (`BENCH_6.json` schema):
/// deterministic simulated cycles plus informational wall time and
/// simulator throughput (simulated cycles per host second) per point.
fn scale_json(points: &[ScalePoint]) -> String {
    let timing = nmc::clock::mode();
    let mut s = format!(
        "{{\n  \"schema\": \"{}\",\n  \"timing\": \"{timing}\",\n  \"reports\": [\n",
        nmc::spec::schemas::BENCH
    );
    for (i, p) in points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"id\": \"scale_t{}\", \"tiles\": {}, \"cycles\": {}, \"wall_ms\": {:.3}, \
             \"sim_cycles_per_s\": {:.0}, \"speedup\": {:.4}, \"mean_utilization\": {:.4}, \
             \"contention_cycles\": {}, \"energy_uj\": {:.3}}}{}\n",
            p.tiles,
            p.tiles,
            p.cycles,
            p.wall_ms,
            p.sim_cycles_per_s,
            p.speedup,
            p.mean_utilization,
            p.contention_cycles,
            p.energy_uj,
            if i + 1 < points.len() { "," } else { "" }
        ));
    }
    let agg: u64 = points.iter().map(|p| p.cycles).sum();
    s.push_str(&format!("  ],\n  \"aggregate_cycles\": {agg}\n}}\n"));
    s
}

fn write_reports(reports: &[Report], out: Option<&str>) {
    for r in reports {
        println!("== {} — {} ==", r.id, r.title);
        println!("{}", r.text);
        if let Some(dir) = out {
            std::fs::create_dir_all(dir).expect("create results dir");
            let mut path = std::path::PathBuf::from(dir);
            path.push(format!("{}.txt", r.id));
            std::fs::write(&path, &r.text).expect("write report");
            for (name, csv) in &r.csv {
                let mut p = std::path::PathBuf::from(dir);
                p.push(name);
                std::fs::write(&p, csv).expect("write csv");
            }
            println!("(written to {dir}/{}.txt)", r.id);
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = match parse_args(&args) {
        Ok(cli) => cli,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Some(spec) = &cli.timing {
        match nmc::clock::TimingMode::parse(spec) {
            Some(mode) => nmc::clock::set_global(mode),
            None => {
                eprintln!("error: unknown --timing `{spec}` (use `cycle` or `event`)");
                std::process::exit(2);
            }
        }
    }
    let out = cli.out.as_deref();
    let jobs = cli.jobs.unwrap_or_else(executor::default_jobs);
    // One memoizing session per invocation: every subcommand that
    // simulates drains through it (`Arc` so `scale` can fan tile counts
    // over worker threads).
    let session = Arc::new(SweepSession::new());

    match cli.cmd.as_str() {
        "all" => {
            let reports = harness::all_with_jobs(cli.quick, jobs);
            write_reports(&reports, out.or(Some("results")));
        }
        "table4" => write_reports(&[harness::table4()], out),
        "fig7" => write_reports(&[harness::fig7()], out),
        "table5" | "fig11" => {
            let rows = harness::run_table5(&session, cli.quick);
            let reps = vec![harness::table5(&rows), harness::fig11(&rows)];
            write_reports(&reps, out);
        }
        "fig12" => write_reports(&[harness::fig12(&session, cli.quick)], out),
        "fig13" => write_reports(&[harness::fig13(&session)], out),
        "table6" => write_reports(&[harness::table6(&session)], out),
        "table7" => write_reports(&[harness::table7()], out),
        "table8" => write_reports(&[harness::table8()], out),
        "ablations" => write_reports(&harness::ablations::all(&session), out),
        "sweep" => {
            let points = match sweep_points(&cli) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("error: {e}");
                    std::process::exit(2);
                }
            };
            let rep = harness::sweep_report(&session, &points, cli.seed.unwrap_or(1));
            write_reports(&[rep], out);
        }
        "scale" => {
            let (spec, tiles) = match scale_spec(&cli) {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("error: {e}");
                    std::process::exit(2);
                }
            };
            match harness::scale_report(&session, spec, &tiles, jobs) {
                Ok((rep, points)) => {
                    write_reports(&[rep], out);
                    if let Some(path) = &cli.json {
                        std::fs::write(path, scale_json(&points)).expect("write bench json");
                        println!("(bench summary written to {path})");
                    }
                }
                Err(e) => {
                    eprintln!("error: {e}");
                    std::process::exit(2);
                }
            }
        }
        "fuzz" => {
            std::process::exit(run_fuzz(&cli));
        }
        "serve" => {
            std::process::exit(run_serve(&cli));
        }
        "model" => {
            std::process::exit(run_model_cmd(&cli));
        }
        "ad" => {
            let golden = nmc::apps::anomaly::golden_forward(&nmc::apps::anomaly::model(2));
            for target in Target::ALL {
                let res = session.anomaly(target, 2);
                let ok = res.output == golden;
                println!(
                    "{:<22} {:>9} cycles  {:>8.2} uJ  output {}",
                    res.name,
                    res.cycles,
                    res.energy_uj,
                    if ok { "OK (matches golden)" } else { "MISMATCH" }
                );
            }
        }
        "help" => {
            print!("{}", usage());
        }
        other => {
            // Unknown subcommand: usage goes to stderr and the exit code
            // is non-zero so scripts (and CI) can't silently no-op.
            eprint!("{}", usage());
            eprintln!("error: unknown subcommand `{other}`");
            std::process::exit(2);
        }
    }
}

/// The `fuzz` subcommand: run the differential fuzzer (or `--replay` one
/// repro file) and map the outcome to an exit code — 0 clean, 1 divergence,
/// 2 unusable invocation.
fn run_fuzz(cli: &Cli) -> i32 {
    use nmc::fuzz;
    if let Some(path) = &cli.replay {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprint!("{}", usage());
                eprintln!("error: cannot read --replay file `{path}`: {e}");
                return 2;
            }
        };
        let case = match fuzz::from_json(&text) {
            Ok(c) => c,
            Err(e) => {
                eprint!("{}", usage());
                eprintln!("error: `{path}` is not a fuzz repro file: {e}");
                return 2;
            }
        };
        return match fuzz::replay(&case) {
            Ok(()) => {
                println!("replay of {path}: no divergence (case seed {})", case.seed);
                0
            }
            Err(d) => {
                println!("replay of {path}: DIVERGENCE");
                println!("  {d}");
                1
            }
        };
    }
    let seed = cli.seed.unwrap_or(1);
    let budget = cli.budget.unwrap_or(200);
    let max_insns = cli.max_insns.unwrap_or(64);
    println!(
        "fuzz: seed {seed}, budget {budget} cases, {max_insns} instructions per ISA surface"
    );
    let report = fuzz::run(seed, budget, max_insns);
    match report.failure {
        None => {
            println!("{} cases checked across engines × tiles × shard × timing: no divergence", report.cases);
            0
        }
        Some(f) => {
            let json = fuzz::to_json(&f.case, &f.divergence.to_string());
            let name = format!("fuzz-repro-{}.json", f.case.seed);
            let path = match cli.out.as_deref() {
                Some(dir) => {
                    std::fs::create_dir_all(dir).expect("create results dir");
                    format!("{dir}/{name}")
                }
                None => name,
            };
            std::fs::write(&path, &json).expect("write fuzz repro");
            println!("DIVERGENCE after {} cases:", report.cases);
            println!("  {}", f.divergence);
            println!(
                "  shrunk to {} kept instructions, {:?} {:?} {} on {} tiles",
                f.case.kept_insns(),
                f.case.spec.target,
                f.case.spec.kernel,
                f.case.spec.sew,
                f.case.tiles,
            );
            println!("  repro written to {path}");
            println!("  replay locally with: heeperator fuzz --replay {path}");
            1
        }
    }
}

/// The `model` subcommand: compile a multi-layer graph spec onto NM-Carus
/// tiles and run it in both residency policies — inter-layer tensors
/// resident in tile SRAM, then every boundary forced through the host
/// staging pool — so the report can quantify the DMA savings on otherwise
/// identical runs. Both runs assert byte-identity against the CPU-golden
/// chain before reporting. Exit code 0 = ran, 1 = execution failed,
/// 2 = unusable invocation.
fn run_model_cmd(cli: &Cli) -> i32 {
    use nmc::graph::{self, Graph, Pipeline};
    use nmc::sched::pipeline::{run_model, Residency};
    let sew = match cli.sew.as_deref() {
        None => Sew::E8,
        Some(s) => match Sew::parse(s) {
            Some(sew) => sew,
            None => {
                eprint!("{}", usage());
                eprintln!("error: unknown --sew `{s}` (8|16|32)");
                return 2;
            }
        },
    };
    let pipeline = {
        let s = cli.pipeline.as_deref().unwrap_or("layer");
        match Pipeline::parse(s) {
            Some(p) => p,
            None => {
                eprint!("{}", usage());
                eprintln!("error: unknown --pipeline `{s}` (layer|batch)");
                return 2;
            }
        }
    };
    let tiles = match cli.tiles.as_deref() {
        None => 2u32,
        Some(s) => match s.parse::<u32>() {
            Ok(t) if t >= 1 && t as usize <= nmc::bus::MAX_TILES => t,
            _ => {
                eprint!("{}", usage());
                eprintln!(
                    "error: model expects --tiles N in 1..={}, got `{s}`",
                    nmc::bus::MAX_TILES
                );
                return 2;
            }
        },
    };
    let spec = cli.graph.as_deref().unwrap_or(graph::CANONICAL);
    let g = match Graph::parse(spec, sew, cli.seed.unwrap_or(1)) {
        Ok(g) => g,
        Err(e) => {
            eprintln!("error: bad --graph `{spec}`: {e}");
            return 2;
        }
    };
    let sch = match graph::compile(&g, tiles, pipeline) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: `{spec}` does not lower onto {tiles} tile(s): {e}");
            return 2;
        }
    };
    let run = |residency| match run_model(&sch, residency) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("error: model run failed: {e}");
            None
        }
    };
    let Some(resident) = run(Residency::Auto) else { return 1 };
    let Some(staged) = run(Residency::ForceStaged) else { return 1 };
    let rep = harness::model_report(&sch, &resident, &staged);
    write_reports(&[rep], cli.out.as_deref());
    if let Some(path) = &cli.json {
        std::fs::write(path, model_json(&sch, &resident, &staged)).expect("write model json");
        println!("(model summary written to {path})");
    }
    0
}

/// Render the machine-readable model summary (`heeperator-model-v1`):
/// both residency runs' deterministic cycle/DMA/energy totals, the DMA
/// savings the resident policy banked, and the per-layer breakdown of the
/// resident run — what the CI model-smoke job folds into `BENCH_10.json`.
fn model_json(
    sch: &nmc::graph::Schedule,
    resident: &nmc::sched::pipeline::ModelRunResult,
    staged: &nmc::sched::pipeline::ModelRunResult,
) -> String {
    use std::fmt::Write as _;
    let mut s = String::from("{\n");
    writeln!(s, "  \"schema\": \"{}\",", nmc::spec::schemas::MODEL).unwrap();
    writeln!(s, "  \"timing\": \"{}\",", nmc::clock::mode()).unwrap();
    writeln!(s, "  \"graph\": \"{}\",", nmc::spec::json_escape(&sch.graph.spec_string())).unwrap();
    writeln!(s, "  \"sew\": {},", sch.graph.sew.bits()).unwrap();
    writeln!(s, "  \"seed\": {},", sch.graph.seed).unwrap();
    writeln!(s, "  \"tiles\": {},", sch.tiles).unwrap();
    writeln!(s, "  \"pipeline\": \"{}\",", sch.pipeline.name()).unwrap();
    writeln!(s, "  \"items\": {},", resident.items).unwrap();
    for (key, r) in [("resident", resident), ("staged", staged)] {
        writeln!(
            s,
            "  \"{key}\": {{\"cycles\": {}, \"dma_active_cycles\": {}, \"dma_transfers\": {}, \
             \"bus_txns\": {}, \"contention_cycles\": {}, \"energy_uj\": {:.3}, \
             \"resident_boundaries\": {}, \"staged_boundaries\": {}}},",
            r.cycles,
            r.dma_active_cycles,
            r.dma_transfers,
            r.bus_txns,
            r.contention_cycles,
            r.energy.total() / 1e6,
            r.resident_boundaries,
            r.staged_boundaries
        )
        .unwrap();
    }
    writeln!(
        s,
        "  \"dma_savings_cycles\": {},",
        staged.dma_active_cycles.saturating_sub(resident.dma_active_cycles)
    )
    .unwrap();
    writeln!(s, "  \"layers\": [").unwrap();
    for (i, l) in resident.layers.iter().enumerate() {
        writeln!(
            s,
            "    {{\"layer\": {i}, \"kernel\": \"{}\", \"boundary\": \"{}\", \"cycles\": {}, \
             \"dma_active_cycles\": {}, \"dma_transfers\": {}}}{}",
            nmc::spec::family_slug(l.kernel.family()),
            l.boundary.name(),
            l.cycles,
            l.dma_active_cycles,
            l.dma_transfers,
            if i + 1 < resident.layers.len() { "," } else { "" }
        )
        .unwrap();
    }
    s.push_str("  ]\n}\n");
    s
}

/// The `serve` subcommand: the deterministic seeded selftest
/// (`--selftest`, a virtual-clock replay of a generated load trace, or —
/// with `--load closed` — a closed-loop client fleet reacting to its own
/// rejections; both CI-gated), the self-contained live throughput smoke
/// (`--throughput`), or the live service over stdin/TCP with `--workers`
/// parallel SoC replicas and up to `--conns` simultaneous connections.
/// Exit code 0 = served, 2 = unusable invocation.
fn run_serve(cli: &Cli) -> i32 {
    use nmc::serve::{self, load};
    let tiles = match cli.tiles.as_deref() {
        None => 4usize,
        Some(s) => match s.parse::<usize>() {
            Ok(t) if t >= 1 && t <= nmc::bus::MAX_TILES => t,
            _ => {
                eprint!("{}", usage());
                eprintln!(
                    "error: serve expects --tiles N in 1..={}, got `{s}`",
                    nmc::bus::MAX_TILES
                );
                return 2;
            }
        },
    };
    let cfg = serve::ServeConfig {
        tiles,
        // The throughput smoke measures execution scaling, not admission
        // policy: a small default queue would make req/s depend on
        // timing-sensitive rejections, so it defaults deep.
        queue_cap: cli.queue.unwrap_or(if cli.throughput { 4096 } else { 64 }),
        max_batch: cli.max_batch.unwrap_or(8),
        linger_cycles: cli.linger.unwrap_or(100_000),
        workers: cli.workers.unwrap_or(1),
        conns: cli.conns.unwrap_or(4),
    };
    if cfg.queue_cap == 0 || cfg.max_batch == 0 || cfg.workers == 0 || cfg.conns == 0 {
        eprintln!("error: --queue, --max-batch, --workers and --conns must be at least 1");
        return 2;
    }
    let load_mode = cli.load.as_deref().unwrap_or("open");
    if !matches!(load_mode, "open" | "closed") {
        eprint!("{}", usage());
        eprintln!("error: unknown --load `{load_mode}` (open|closed)");
        return 2;
    }
    let seed = cli.seed.unwrap_or(1);

    if cli.throughput {
        // Self-contained live smoke: ephemeral TCP listener + worker
        // pool, driven by `conns` real client threads.
        let per_client = cli.requests.unwrap_or(48);
        return match serve::throughput(&cfg, per_client, seed) {
            Ok(run) => {
                eprint!("{}", harness::serve_report(&run.stats, &cfg, "throughput", seed).text);
                if let Some(path) = &cli.json {
                    std::fs::write(path, serve::throughput_json(&run, &cfg, seed))
                        .expect("write serve throughput json");
                    println!("(serve throughput summary written to {path})");
                }
                0
            }
            Err(e) => {
                eprintln!("error: throughput run failed: {e}");
                1
            }
        };
    }

    if load_mode == "closed" && !cli.selftest {
        eprint!("{}", usage());
        eprintln!("error: --load closed is a virtual-clock mode; it requires --selftest");
        return 2;
    }

    if cli.selftest {
        let requests = cli.requests.unwrap_or(if cli.quick { 64 } else { 256 });
        let (stats, slug) = if load_mode == "closed" {
            let (stats, _) = serve::run_closed(&cfg, seed, requests);
            (stats, "closed")
        } else {
            let trace = cli.trace.as_deref().unwrap_or("mixed");
            let Some(kind) = load::TraceKind::parse(trace) else {
                eprint!("{}", usage());
                eprintln!("error: unknown --trace `{trace}` (poisson|bursty|mixed)");
                return 2;
            };
            let (stats, _) = serve::selftest(&cfg, kind, seed, requests);
            (stats, kind.slug())
        };
        let rep = harness::serve_report(&stats, &cfg, slug, seed);
        write_reports(&[rep], cli.out.as_deref());
        if let Some(path) = &cli.json {
            std::fs::write(path, serve::summary_json(&stats, &cfg, slug, seed))
                .expect("write serve json");
            println!("(serve summary written to {path})");
        }
        return 0;
    }

    // Live service: responses stream to stdout, the session report to
    // stderr so piped consumers see only JSONL.
    match cli.listen.as_deref().unwrap_or("stdin") {
        "stdin" => {
            let stdin = std::io::stdin();
            let stats = serve::serve_stream(&cfg, stdin.lock(), std::io::stdout());
            eprint!("{}", harness::serve_report(&stats, &cfg, "stdin", seed).text);
            0
        }
        port => {
            let Ok(port) = port.parse::<u16>() else {
                eprint!("{}", usage());
                eprintln!("error: --listen expects `stdin` or a TCP port, got `{port}`");
                return 2;
            };
            let listener = match std::net::TcpListener::bind(("127.0.0.1", port)) {
                Ok(l) => l,
                Err(e) => {
                    eprintln!("error: cannot bind 127.0.0.1:{port}: {e}");
                    return 2;
                }
            };
            let addr = listener.local_addr().expect("bound socket has an address");
            eprintln!(
                "serving on {addr} (JSONL requests, up to {} connections, {} workers)",
                cfg.conns, cfg.workers
            );
            match serve::serve_tcp(&cfg, &listener, None) {
                Ok(stats) => {
                    eprint!("{}", harness::serve_report(&stats, &cfg, "tcp", seed).text);
                    0
                }
                Err(e) => {
                    eprintln!("error: accept failed: {e}");
                    1
                }
            }
        }
    }
}

/// The usage text (stdout for `help`, stderr for unknown subcommands).
fn usage() -> String {
    let mut o = String::new();
    let w = &mut o;
    use std::fmt::Write as _;
    writeln!(w, "usage: heeperator <all|table4|fig7|table5|fig11|fig12|fig13|table6|table7|table8|ablations|ad|sweep|scale|fuzz|serve|model> [--quick] [--out DIR]").unwrap();
    writeln!(w, "       `all` additionally accepts --jobs N (worker pool bound; 1 = sequential)").unwrap();
    writeln!(w, "       `sweep` selects scenarios: --target cpu|caesar|carus|all --family xor|add|mul|matmul|gemm|conv2d|relu|leakyrelu|maxpool|all").unwrap();
    writeln!(w, "               --sew 8|16|32|all, free dims --n N --p P --f F (default: paper Table V shapes), --seed S").unwrap();
    writeln!(w, "       `scale` sweeps a batched workload across NMC tile counts: --tiles 1,2,4 --batch B [--shard]").unwrap();
    writeln!(w, "               --target caesar|carus (default carus), --family/--sew/--n/--p/--f as in sweep,").unwrap();
    writeln!(w, "               --json FILE writes the machine-readable cycles+wall-time summary (CI perf tracking)").unwrap();
    writeln!(w, "       `fuzz` runs the differential fuzzer: --seed S --budget N (cases, default 200) --max-insns K (default 64);").unwrap();
    writeln!(w, "               --replay FILE re-checks a fuzz-repro-<seed>.json; a divergence writes one (into --out DIR if given)").unwrap();
    writeln!(w, "       `serve` runs the batch-inference service: --listen stdin|PORT (default stdin), --tiles N (default 4),").unwrap();
    writeln!(w, "               --queue N --max-batch N --linger CYC set the admission + batching policy;").unwrap();
    writeln!(w, "               --workers N runs N parallel SoC worker replicas, --conns N caps simultaneous TCP connections (both default small);").unwrap();
    writeln!(w, "               --selftest replays a seeded load trace on a virtual clock instead: --trace poisson|bursty|mixed,").unwrap();
    writeln!(w, "               --requests N --seed S, --json FILE writes the summary the CI serve-smoke job gates on;").unwrap();
    writeln!(w, "               --selftest --load closed runs a closed-loop client fleet (backoff+retry on rejection) on the virtual clock;").unwrap();
    writeln!(w, "               --throughput runs a self-contained live TCP smoke (--conns clients x --requests each) and").unwrap();
    writeln!(w, "               reports wall-clock req/s (--json FILE writes the heeperator-serve-live-v1 summary)").unwrap();
    writeln!(w, "       `model` compiles a multi-layer graph onto NM-Carus tiles: --graph SPEC (kernel chain, e.g.").unwrap();
    writeln!(w, "               `matmul:p=32,add,relu,maxpool`, the default), --tiles N (default 2), --pipeline layer|batch,").unwrap();
    writeln!(w, "               --sew 8|16|32 --seed S; runs resident and staged and reports the DMA savings,").unwrap();
    writeln!(w, "               --json FILE writes the heeperator-model-v1 summary the CI model-smoke job gates on").unwrap();
    writeln!(w, "       every subcommand accepts --timing cycle|event (skip-ahead event timing is the default;").unwrap();
    writeln!(w, "               `cycle` forces the per-cycle reference loop; SOC_TIMING env var works too)").unwrap();
    writeln!(w, "       every --flag accepts both `--flag value` and `--flag=value`").unwrap();
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    /// Parse a known-good command line.
    fn p(list: &[&str]) -> Cli {
        parse_args(&argv(list)).expect("valid command line")
    }

    #[test]
    fn subcommand_selection() {
        assert_eq!(p(&["all"]).cmd, "all");
        assert_eq!(p(&["table5", "--quick"]).cmd, "table5");
        // No positional argument → help.
        assert_eq!(p(&[]).cmd, "help");
        assert_eq!(p(&["--quick"]).cmd, "help");
        // Flags before the subcommand still find it.
        assert_eq!(p(&["--quick", "fig12"]).cmd, "fig12");
    }

    #[test]
    fn quick_flag() {
        assert!(p(&["all", "--quick"]).quick);
        assert!(!p(&["all"]).quick);
    }

    #[test]
    fn out_dir_parsing() {
        assert_eq!(p(&["all", "--out", "results/x"]).out.as_deref(), Some("results/x"));
        // Dangling --out without a value is tolerated as no-out.
        assert_eq!(p(&["all", "--out"]).out, None);
        assert_eq!(p(&["all"]).out, None);
        // A following flag is not swallowed as the value.
        let cli = p(&["all", "--out", "--quick"]);
        assert_eq!(cli.out, None);
        assert!(cli.quick);
    }

    #[test]
    fn jobs_parsing_and_clamping() {
        assert_eq!(p(&["all", "--jobs", "4"]).jobs, Some(4));
        // 0 clamps to the sequential minimum of 1.
        assert_eq!(p(&["all", "--jobs", "0"]).jobs, Some(1));
        // Missing value means "default worker count".
        assert_eq!(p(&["all", "--jobs"]).jobs, None);
        assert_eq!(p(&["all"]).jobs, None);
        // A following flag is not swallowed as the value.
        let cli = p(&["all", "--jobs", "--quick"]);
        assert_eq!(cli.jobs, None);
        assert!(cli.quick);
    }

    #[test]
    fn garbage_jobs_value_is_an_error() {
        // Falling back to max parallelism would invert the user's intent.
        let err = parse_args(&argv(&["all", "--jobs", "lots"])).unwrap_err();
        assert!(err.contains("--jobs"), "{err}");
        assert!(err.contains("lots"), "{err}");
    }

    #[test]
    fn combined_flags_any_order() {
        let cli = p(&["--jobs", "2", "all", "--quick", "--out", "r"]);
        assert_eq!(cli.cmd, "all");
        assert!(cli.quick);
        assert_eq!(cli.out.as_deref(), Some("r"));
        assert_eq!(cli.jobs, Some(2));
    }

    #[test]
    fn sweep_flags_parse() {
        let cli = p(&[
            "sweep", "--target", "carus", "--family", "matmul", "--sew", "8", "--p", "96",
            "--seed", "7",
        ]);
        assert_eq!(cli.cmd, "sweep");
        assert_eq!(cli.target.as_deref(), Some("carus"));
        assert_eq!(cli.family.as_deref(), Some("matmul"));
        assert_eq!(cli.sew.as_deref(), Some("8"));
        assert_eq!(cli.p, Some(96));
        assert_eq!(cli.n, None);
        assert_eq!(cli.f, None);
        assert_eq!(cli.seed, Some(7));
    }

    #[test]
    fn garbage_dim_value_is_an_error() {
        let err = parse_args(&argv(&["sweep", "--n", "many"])).unwrap_err();
        assert!(err.contains("--n"), "{err}");
        assert!(err.contains("many"), "{err}");
    }

    #[test]
    fn sweep_points_expand_and_validate() {
        // Single explicit point.
        let cli = p(&["sweep", "--target", "carus", "--family", "matmul", "--sew", "8", "--p", "96"]);
        let pts = sweep_points(&cli).unwrap();
        assert_eq!(pts, vec![(Target::Carus, Kernel::Matmul { p: 96 }, Sew::E8)]);
        // `all` selectors expand the full cross product.
        let cli = p(&["sweep"]);
        let pts = sweep_points(&cli).unwrap();
        assert_eq!(pts.len(), 3 * 9 * 3);
        // Unknown names are reported, not ignored.
        let cli = p(&["sweep", "--family", "fft"]);
        let err = sweep_points(&cli).unwrap_err();
        assert!(err.contains("fft"), "{err}");
        // Paper-default dimensions apply when no dim flag is given.
        let cli = p(&["sweep", "--target", "cpu", "--family", "add", "--sew", "8"]);
        let pts = sweep_points(&cli).unwrap();
        assert_eq!(pts, vec![(Target::Cpu, Kernel::Add { n: 5120 }, Sew::E8)]);
        // The parse functions' aliases work here too (one source of truth).
        let cli = p(&["sweep", "--target", "nm-carus", "--family", "conv", "--sew", "e8"]);
        let pts = sweep_points(&cli).unwrap();
        assert_eq!(pts, vec![(Target::Carus, Kernel::Conv2d { n: 1024, f: 3 }, Sew::E8)]);
    }

    #[test]
    fn sweep_points_reject_impossible_shapes() {
        // A filter larger than the 8-row image would underflow `8-f+1`
        // inside the engines; the CLI reports it instead.
        let cli = p(&["sweep", "--family", "conv2d", "--f", "12"]);
        let err = sweep_points(&cli).unwrap_err();
        assert!(err.contains("f ≤ 8") || err.contains("f = 12"), "{err}");
        // An NM-Carus B row must fit one 1 KiB logical register.
        let cli = p(&["sweep", "--target", "carus", "--family", "matmul", "--sew", "32", "--p", "1024"]);
        let err = sweep_points(&cli).unwrap_err();
        assert!(err.contains("NM-Carus"), "{err}");
    }

    #[test]
    fn scale_flags_parse() {
        let cli = p(&["scale", "--tiles", "1,2,4", "--batch", "8", "--shard", "--json", "B.json"]);
        assert_eq!(cli.cmd, "scale");
        assert_eq!(cli.tiles.as_deref(), Some("1,2,4"));
        assert_eq!(cli.batch, Some(8));
        assert!(cli.shard);
        assert_eq!(cli.json.as_deref(), Some("B.json"));
        // Defaults stay unset without the flags.
        let cli = p(&["scale"]);
        assert_eq!(cli.tiles, None);
        assert_eq!(cli.batch, None);
        assert!(!cli.shard);
        assert_eq!(cli.json, None);
    }

    #[test]
    fn scale_spec_defaults_and_overrides() {
        let (spec, tiles) = scale_spec(&p(&["scale"])).unwrap();
        assert_eq!(spec.target, Target::Carus);
        assert_eq!(spec.kernel, Kernel::Matmul { p: 256 });
        assert_eq!(spec.sew, Sew::E8);
        assert_eq!(tiles, vec![1, 2, 4]);
        assert_eq!(spec.batch, 16, "4 rounds at the largest tile count");
        assert!(!spec.shard);
        // --quick halves the default batch.
        let (spec, _) = scale_spec(&p(&["scale", "--quick"])).unwrap();
        assert_eq!(spec.batch, 8);
        // Explicit dimensions and batch win over the scale defaults.
        let (spec, _) = scale_spec(&p(&["scale", "--p", "64", "--batch", "3"])).unwrap();
        assert_eq!(spec.kernel, Kernel::Matmul { p: 64 });
        assert_eq!(spec.batch, 3);
        let cli = p(&["scale", "--family", "relu", "--tiles", "2,8"]);
        let (spec, tiles) = scale_spec(&cli).unwrap();
        assert_eq!(spec.kernel, Kernel::Relu { n: 2048 });
        assert_eq!(tiles, vec![2, 8]);
    }

    #[test]
    fn scale_default_shapes_fit_the_staging_pool() {
        // The documented default invocations must plan cleanly at every
        // element width — wider elements shrink the default element
        // counts so the byte footprint stays pool-sized.
        for args in [
            vec!["scale", "--target", "caesar", "--family", "add", "--sew", "32"],
            vec!["scale", "--family", "maxpool"],
            vec!["scale", "--family", "add", "--sew", "16"],
            vec!["scale", "--sew", "16"],
        ] {
            let (spec, tiles) = scale_spec(&p(&args)).unwrap();
            let t = *tiles.iter().max().unwrap() as usize;
            let r = nmc::sched::plan(&spec, t);
            assert!(r.is_ok(), "{args:?}: {}", r.err().unwrap());
        }
    }

    #[test]
    fn scale_spec_rejects_bad_selectors() {
        assert!(scale_spec(&p(&["scale", "--tiles", "0"])).is_err());
        assert!(scale_spec(&p(&["scale", "--tiles", "1,x"])).is_err());
        assert!(scale_spec(&p(&["scale", "--tiles", "99"])).is_err());
        assert!(scale_spec(&p(&["scale", "--target", "tpu"])).is_err());
        assert!(scale_spec(&p(&["scale", "--family", "fft"])).is_err());
    }

    #[test]
    fn timing_flag_parses_in_both_spellings() {
        assert_eq!(p(&["scale", "--timing", "cycle"]).timing.as_deref(), Some("cycle"));
        assert_eq!(p(&["all", "--timing=event"]).timing.as_deref(), Some("event"));
        // Default: unset (the library then consults SOC_TIMING / default).
        assert_eq!(p(&["scale"]).timing, None);
        // A following flag is not swallowed as the value.
        let cli = p(&["scale", "--timing", "--quick"]);
        assert_eq!(cli.timing, None);
        assert!(cli.quick);
        // The mode names round-trip through the library parser.
        for name in ["cycle", "event"] {
            assert!(nmc::clock::TimingMode::parse(name).is_some(), "{name}");
        }
        assert!(nmc::clock::TimingMode::parse("warp").is_none());
    }

    #[test]
    fn fuzz_flags_parse_in_both_spellings() {
        let cli = p(&["fuzz", "--seed", "7", "--budget", "500", "--max-insns", "32"]);
        assert_eq!(cli.cmd, "fuzz");
        assert_eq!(cli.seed, Some(7));
        assert_eq!(cli.budget, Some(500));
        assert_eq!(cli.max_insns, Some(32));
        // The `=` spelling normalizes to the same parse.
        let eq = p(&["fuzz", "--seed=7", "--budget=500", "--max-insns=32", "--replay=r.json"]);
        assert_eq!(eq.seed, Some(7));
        assert_eq!(eq.budget, Some(500));
        assert_eq!(eq.max_insns, Some(32));
        assert_eq!(eq.replay.as_deref(), Some("r.json"));
        // Defaults stay unset (the subcommand fills them in).
        let cli = p(&["fuzz"]);
        assert_eq!(cli.budget, None);
        assert_eq!(cli.max_insns, None);
        assert_eq!(cli.replay, None);
    }

    #[test]
    fn garbage_budget_value_is_an_error_in_both_spellings() {
        let err = parse_args(&argv(&["fuzz", "--budget", "tons"])).unwrap_err();
        assert!(err.contains("--budget"), "{err}");
        assert!(err.contains("tons"), "{err}");
        let err = parse_args(&argv(&["fuzz", "--budget=tons"])).unwrap_err();
        assert!(err.contains("--budget"), "{err}");
    }

    #[test]
    fn model_flags_parse_in_both_spellings() {
        let cli = p(&[
            "model", "--graph", "matmul:p=32,relu", "--tiles", "2", "--pipeline", "batch",
            "--sew", "8", "--seed", "3",
        ]);
        assert_eq!(cli.cmd, "model");
        assert_eq!(cli.graph.as_deref(), Some("matmul:p=32,relu"));
        assert_eq!(cli.tiles.as_deref(), Some("2"));
        assert_eq!(cli.pipeline.as_deref(), Some("batch"));
        assert_eq!(cli.sew.as_deref(), Some("8"));
        assert_eq!(cli.seed, Some(3));
        // The `=` spelling normalizes to the same parse.
        let eq = p(&["model", "--graph=matmul:p=32,relu", "--pipeline=layer", "--json=M.json"]);
        assert_eq!(eq.graph.as_deref(), Some("matmul:p=32,relu"));
        assert_eq!(eq.pipeline.as_deref(), Some("layer"));
        assert_eq!(eq.json.as_deref(), Some("M.json"));
        // Defaults stay unset (run_model_cmd fills them in).
        let cli = p(&["model"]);
        assert_eq!(cli.graph, None);
        assert_eq!(cli.pipeline, None);
        assert_eq!(cli.tiles, None);
    }

    #[test]
    fn usage_covers_every_subcommand() {
        let u = usage();
        for cmd in [
            "all", "table4", "fig11", "ablations", "ad", "sweep", "scale", "fuzz", "serve",
            "model",
        ] {
            assert!(u.contains(cmd), "usage must mention `{cmd}`");
        }
        assert!(u.contains("--graph"));
        assert!(u.contains("--pipeline"));
        assert!(u.contains("--json"));
        assert!(u.contains("--tiles"));
        assert!(u.contains("--timing"));
        assert!(u.contains("--replay"));
        assert!(u.contains("--budget"));
        assert!(u.contains("--listen"));
        assert!(u.contains("--selftest"));
        assert!(u.contains("--trace"));
        assert!(u.contains("--linger"));
        assert!(u.contains("--workers"));
        assert!(u.contains("--conns"));
        assert!(u.contains("--load closed"));
        assert!(u.contains("--throughput"));
    }

    #[test]
    fn serve_flags_parse_in_both_spellings() {
        let cli = p(&[
            "serve", "--listen", "7777", "--tiles", "4", "--queue", "32", "--max-batch", "4",
            "--linger", "50000",
        ]);
        assert_eq!(cli.cmd, "serve");
        assert_eq!(cli.listen.as_deref(), Some("7777"));
        assert_eq!(cli.tiles.as_deref(), Some("4"));
        assert_eq!(cli.queue, Some(32));
        assert_eq!(cli.max_batch, Some(4));
        assert_eq!(cli.linger, Some(50_000));
        assert!(!cli.selftest);
        // The `=` spelling normalizes to the same parse.
        let eq = p(&["serve", "--selftest", "--trace=bursty", "--requests=128", "--seed=9"]);
        assert!(eq.selftest);
        assert_eq!(eq.trace.as_deref(), Some("bursty"));
        assert_eq!(eq.requests, Some(128));
        assert_eq!(eq.seed, Some(9));
        // Defaults stay unset (run_serve fills them in).
        let cli = p(&["serve"]);
        assert_eq!(cli.listen, None);
        assert_eq!(cli.trace, None);
        assert_eq!(cli.requests, None);
        assert_eq!(cli.queue, None);
        assert_eq!(cli.max_batch, None);
        assert_eq!(cli.linger, None);
        assert_eq!(cli.workers, None);
        assert_eq!(cli.conns, None);
        assert_eq!(cli.load, None);
        assert!(!cli.throughput);
    }

    #[test]
    fn serve_concurrency_flags_parse_in_both_spellings() {
        let cli = p(&["serve", "--workers", "4", "--conns", "8", "--load", "closed"]);
        assert_eq!(cli.workers, Some(4));
        assert_eq!(cli.conns, Some(8));
        assert_eq!(cli.load.as_deref(), Some("closed"));
        let eq = p(&["serve", "--workers=4", "--conns=8", "--load=closed", "--throughput"]);
        assert_eq!(eq.workers, Some(4));
        assert_eq!(eq.conns, Some(8));
        assert_eq!(eq.load.as_deref(), Some("closed"));
        assert!(eq.throughput);
    }

    #[test]
    fn garbage_serve_values_are_errors() {
        let err = parse_args(&argv(&["serve", "--queue", "deep"])).unwrap_err();
        assert!(err.contains("--queue"), "{err}");
        assert!(err.contains("deep"), "{err}");
        let err = parse_args(&argv(&["serve", "--requests=lots"])).unwrap_err();
        assert!(err.contains("--requests"), "{err}");
        let err = parse_args(&argv(&["serve", "--linger", "forever"])).unwrap_err();
        assert!(err.contains("--linger"), "{err}");
        let err = parse_args(&argv(&["serve", "--workers", "many"])).unwrap_err();
        assert!(err.contains("--workers"), "{err}");
        let err = parse_args(&argv(&["serve", "--conns=lots"])).unwrap_err();
        assert!(err.contains("--conns"), "{err}");
    }

    #[test]
    fn scale_json_is_well_formed() {
        let points = vec![
            ScalePoint {
                tiles: 1,
                cycles: 100,
                wall_ms: 1.0,
                sim_cycles_per_s: 100_000.0,
                speedup: 1.0,
                mean_utilization: 0.5,
                contention_cycles: 3,
                energy_uj: 2.0,
            },
            ScalePoint {
                tiles: 4,
                cycles: 40,
                wall_ms: 0.5,
                sim_cycles_per_s: 80_000.0,
                speedup: 2.5,
                mean_utilization: 0.9,
                contention_cycles: 5,
                energy_uj: 2.5,
            },
        ];
        let s = scale_json(&points);
        assert!(s.contains("\"schema\": \"heeperator-bench-v1\""));
        assert!(s.contains("\"timing\": \""));
        assert!(s.contains("\"aggregate_cycles\": 140"));
        assert!(s.contains("\"id\": \"scale_t1\""));
        assert!(s.contains("\"id\": \"scale_t4\""));
        assert!(s.contains("\"sim_cycles_per_s\": 100000"));
        assert_eq!(s.matches("\"id\"").count(), 2);
    }

    #[test]
    fn table4_smoke_nonempty_text_and_csv() {
        let rep = harness::table4();
        assert_eq!(rep.id, "table4");
        assert!(rep.text.contains("NM-Caesar"));
        assert!(rep.text.contains("NM-Carus"));
        assert!(!rep.csv.is_empty());
        let (name, csv) = &rep.csv[0];
        assert_eq!(name, "table4.csv");
        assert!(csv.lines().count() >= 4, "header + three rows");
        assert!(csv.starts_with("macro,area_um2"));
    }
}
