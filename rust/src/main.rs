//! `heeperator` — CLI for the NM-Caesar / NM-Carus reproduction.
//!
//! Regenerates every table and figure of the paper's evaluation:
//!
//! ```text
//! heeperator all [--quick] [--out DIR] [--jobs N]   # everything (Tables IV–VIII, Figs 7/11/12/13)
//! heeperator table4|fig7|table5|fig11|fig12|fig13|table6|table7|table8 [--quick] [--out DIR]
//! heeperator ablations [--out DIR]                  # the four ablation studies
//! heeperator ad                                     # Anomaly-Detection end-to-end summary
//! ```
//!
//! `all` fans the independent reports out over a `std::thread` worker
//! pool (`harness::executor`); `--jobs N` bounds the pool, `--jobs 1` is
//! the sequential baseline and produces byte-identical report text.
//!
//! (Hand-rolled argument parsing: clap is not in the offline vendor set.)

use nmc::harness::{self, executor, Report};
use std::io::Write;

/// Parsed command line. Kept dumb (no behavior) so tests can assert on
/// exactly what the hand-rolled parser extracted.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Cli {
    cmd: String,
    quick: bool,
    out: Option<String>,
    jobs: Option<usize>,
}

/// Parse `args` (everything after argv[0]). Unknown flags are ignored —
/// the subcommand dispatcher prints usage for unknown commands — but a
/// present, unparsable `--jobs` value is an error: silently falling
/// back to full parallelism would do the opposite of what the user
/// asked for.
fn parse_args(args: &[String]) -> Result<Cli, String> {
    let mut cmd: Option<String> = None;
    let mut quick = false;
    let mut out: Option<String> = None;
    let mut jobs: Option<usize> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--quick" => quick = true,
            "--out" => {
                // A following flag is not a value — leave it for the loop.
                if let Some(v) = args.get(i + 1).filter(|v| !v.starts_with("--")) {
                    out = Some(v.clone());
                    i += 1; // consume the value
                }
            }
            "--jobs" => {
                if let Some(v) = args.get(i + 1).filter(|v| !v.starts_with("--")) {
                    match v.parse::<usize>() {
                        Ok(n) => jobs = Some(n.max(1)),
                        Err(_) => return Err(format!("--jobs expects a number, got `{v}`")),
                    }
                    i += 1; // consume the value
                }
            }
            a if !a.starts_with("--") => {
                // First free-standing word is the subcommand.
                if cmd.is_none() {
                    cmd = Some(a.to_string());
                }
            }
            _ => {} // unknown flag: ignored
        }
        i += 1;
    }
    Ok(Cli { cmd: cmd.unwrap_or_else(|| "help".to_string()), quick, out, jobs })
}

fn write_reports(reports: &[Report], out: Option<&str>) {
    for r in reports {
        println!("== {} — {} ==", r.id, r.title);
        println!("{}", r.text);
        if let Some(dir) = out {
            std::fs::create_dir_all(dir).expect("create results dir");
            let mut path = std::path::PathBuf::from(dir);
            path.push(format!("{}.txt", r.id));
            std::fs::write(&path, &r.text).expect("write report");
            for (name, csv) in &r.csv {
                let mut p = std::path::PathBuf::from(dir);
                p.push(name);
                std::fs::write(&p, csv).expect("write csv");
            }
            println!("(written to {dir}/{}.txt)", r.id);
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = match parse_args(&args) {
        Ok(cli) => cli,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let out = cli.out.as_deref();
    let jobs = cli.jobs.unwrap_or_else(executor::default_jobs);

    match cli.cmd.as_str() {
        "all" => {
            let reports = harness::all_with_jobs(cli.quick, jobs);
            write_reports(&reports, out.or(Some("results")));
        }
        "table4" => write_reports(&[harness::table4()], out),
        "fig7" => write_reports(&[harness::fig7()], out),
        "table5" | "fig11" => {
            let rows = harness::run_table5(cli.quick);
            let reps = vec![harness::table5(&rows), harness::fig11(&rows)];
            write_reports(&reps, out);
        }
        "fig12" => write_reports(&[harness::fig12(cli.quick)], out),
        "fig13" => write_reports(&[harness::fig13()], out),
        "table6" => write_reports(&[harness::table6()], out),
        "table7" => write_reports(&[harness::table7()], out),
        "table8" => write_reports(&[harness::table8()], out),
        "ablations" => write_reports(&harness::ablations::all(), out),
        "ad" => {
            let m = nmc::apps::anomaly::model(2);
            let golden = nmc::apps::anomaly::golden_forward(&m);
            for res in [
                nmc::apps::anomaly::run_cpu(&m),
                nmc::apps::anomaly::run_caesar(&m),
                nmc::apps::anomaly::run_carus(&m),
            ] {
                let ok = res.output == golden;
                println!(
                    "{:<22} {:>9} cycles  {:>8.2} uJ  output {}",
                    res.name,
                    res.cycles,
                    res.energy_uj,
                    if ok { "OK (matches golden)" } else { "MISMATCH" }
                );
            }
        }
        _ => {
            let mut o = std::io::stdout();
            writeln!(o, "usage: heeperator <all|table4|fig7|table5|fig11|fig12|fig13|table6|table7|table8|ablations|ad> [--quick] [--out DIR]").unwrap();
            writeln!(o, "       `all` additionally accepts --jobs N (worker pool bound; 1 = sequential)").unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    /// Parse a known-good command line.
    fn p(list: &[&str]) -> Cli {
        parse_args(&argv(list)).expect("valid command line")
    }

    #[test]
    fn subcommand_selection() {
        assert_eq!(p(&["all"]).cmd, "all");
        assert_eq!(p(&["table5", "--quick"]).cmd, "table5");
        // No positional argument → help.
        assert_eq!(p(&[]).cmd, "help");
        assert_eq!(p(&["--quick"]).cmd, "help");
        // Flags before the subcommand still find it.
        assert_eq!(p(&["--quick", "fig12"]).cmd, "fig12");
    }

    #[test]
    fn quick_flag() {
        assert!(p(&["all", "--quick"]).quick);
        assert!(!p(&["all"]).quick);
    }

    #[test]
    fn out_dir_parsing() {
        assert_eq!(p(&["all", "--out", "results/x"]).out.as_deref(), Some("results/x"));
        // Dangling --out without a value is tolerated as no-out.
        assert_eq!(p(&["all", "--out"]).out, None);
        assert_eq!(p(&["all"]).out, None);
        // A following flag is not swallowed as the value.
        let cli = p(&["all", "--out", "--quick"]);
        assert_eq!(cli.out, None);
        assert!(cli.quick);
    }

    #[test]
    fn jobs_parsing_and_clamping() {
        assert_eq!(p(&["all", "--jobs", "4"]).jobs, Some(4));
        // 0 clamps to the sequential minimum of 1.
        assert_eq!(p(&["all", "--jobs", "0"]).jobs, Some(1));
        // Missing value means "default worker count".
        assert_eq!(p(&["all", "--jobs"]).jobs, None);
        assert_eq!(p(&["all"]).jobs, None);
        // A following flag is not swallowed as the value.
        let cli = p(&["all", "--jobs", "--quick"]);
        assert_eq!(cli.jobs, None);
        assert!(cli.quick);
    }

    #[test]
    fn garbage_jobs_value_is_an_error() {
        // Falling back to max parallelism would invert the user's intent.
        let err = parse_args(&argv(&["all", "--jobs", "lots"])).unwrap_err();
        assert!(err.contains("--jobs"), "{err}");
        assert!(err.contains("lots"), "{err}");
    }

    #[test]
    fn combined_flags_any_order() {
        let cli = p(&["--jobs", "2", "all", "--quick", "--out", "r"]);
        assert_eq!(
            cli,
            Cli { cmd: "all".into(), quick: true, out: Some("r".into()), jobs: Some(2) }
        );
    }

    #[test]
    fn table4_smoke_nonempty_text_and_csv() {
        let rep = harness::table4();
        assert_eq!(rep.id, "table4");
        assert!(rep.text.contains("NM-Caesar"));
        assert!(rep.text.contains("NM-Carus"));
        assert!(!rep.csv.is_empty());
        let (name, csv) = &rep.csv[0];
        assert_eq!(name, "table4.csv");
        assert!(csv.lines().count() >= 4, "header + three rows");
        assert!(csv.starts_with("macro,area_um2"));
    }
}
