//! `heeperator` — CLI for the NM-Caesar / NM-Carus reproduction.
//!
//! Regenerates every table and figure of the paper's evaluation:
//!
//! ```text
//! heeperator all [--quick] [--out DIR]   # everything (Tables IV–VIII, Figs 7/11/12/13)
//! heeperator table4|fig7|table5|fig11|fig12|fig13|table6|table7|table8 [--quick] [--out DIR]
//! heeperator ad                           # Anomaly-Detection end-to-end summary
//! ```
//!
//! (Hand-rolled argument parsing: clap is not in the offline vendor set.)

use nmc::harness::{self, Report};
use std::io::Write;

fn write_reports(reports: &[Report], out: Option<&str>) {
    for r in reports {
        println!("== {} — {} ==", r.id, r.title);
        println!("{}", r.text);
        if let Some(dir) = out {
            std::fs::create_dir_all(dir).expect("create results dir");
            let mut path = std::path::PathBuf::from(dir);
            path.push(format!("{}.txt", r.id));
            std::fs::write(&path, &r.text).expect("write report");
            for (name, csv) in &r.csv {
                let mut p = std::path::PathBuf::from(dir);
                p.push(name);
                std::fs::write(&p, csv).expect("write csv");
            }
            println!("(written to {dir}/{}.txt)", r.id);
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let quick = args.iter().any(|a| a == "--quick");
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str);

    match cmd {
        "all" => {
            let reports = harness::all(quick);
            write_reports(&reports, out.or(Some("results")));
        }
        "table4" => write_reports(&[harness::table4()], out),
        "fig7" => write_reports(&[harness::fig7()], out),
        "table5" | "fig11" => {
            let rows = harness::run_table5(quick);
            let reps = vec![harness::table5(&rows), harness::fig11(&rows)];
            write_reports(&reps, out);
        }
        "fig12" => write_reports(&[harness::fig12(quick)], out),
        "fig13" => write_reports(&[harness::fig13()], out),
        "table6" => write_reports(&[harness::table6()], out),
        "table7" => write_reports(&[harness::table7()], out),
        "table8" => write_reports(&[harness::table8()], out),
        "ablations" => write_reports(&harness::ablations::all(), out),
        "ad" => {
            let m = nmc::apps::anomaly::model(2);
            let golden = nmc::apps::anomaly::golden_forward(&m);
            for res in [
                nmc::apps::anomaly::run_cpu(&m),
                nmc::apps::anomaly::run_caesar(&m),
                nmc::apps::anomaly::run_carus(&m),
            ] {
                let ok = res.output == golden;
                println!(
                    "{:<22} {:>9} cycles  {:>8.2} uJ  output {}",
                    res.name,
                    res.cycles,
                    res.energy_uj,
                    if ok { "OK (matches golden)" } else { "MISMATCH" }
                );
            }
        }
        _ => {
            let mut o = std::io::stdout();
            writeln!(o, "usage: heeperator <all|table4|fig7|table5|fig11|fig12|fig13|table6|table7|table8|ablations|ad> [--quick] [--out DIR]").unwrap();
        }
    }
}
