//! Packed-SIMD (SWAR) word semantics shared by every datapath in the system.
//!
//! NM-Caesar's ALU (§III-A2), NM-Carus's lane ALUs (§III-B2) and the Xcv
//! DSP extension all operate on 32-bit words holding 4×8-bit, 2×16-bit or
//! 1×32-bit integer elements. Centralizing the element algebra here means
//! the simulator, the golden Rust references and the instruction semantics
//! can never drift apart — and the property tests in
//! `rust/tests/prop_invariants.rs` verify each packed op against a
//! per-element scalar loop.

use crate::isa::Sew;

/// Element-wise view of a 32-bit word.
pub mod elem {
    use super::Sew;

    /// Extract element `i` of `w` as a sign-extended i32.
    #[inline]
    pub fn get_signed(w: u32, i: u32, sew: Sew) -> i32 {
        match sew {
            Sew::E8 => (w >> (8 * i)) as u8 as i8 as i32,
            Sew::E16 => (w >> (16 * i)) as u16 as i16 as i32,
            Sew::E32 => w as i32,
        }
    }

    /// Extract element `i` of `w` zero-extended.
    #[inline]
    pub fn get_unsigned(w: u32, i: u32, sew: Sew) -> u32 {
        match sew {
            Sew::E8 => (w >> (8 * i)) as u8 as u32,
            Sew::E16 => (w >> (16 * i)) as u16 as u32,
            Sew::E32 => w,
        }
    }

    /// Replace element `i` of `w` with the low bits of `v`.
    #[inline]
    pub fn set(w: u32, i: u32, sew: Sew, v: u32) -> u32 {
        match sew {
            Sew::E8 => {
                let sh = 8 * i;
                (w & !(0xffu32 << sh)) | ((v & 0xff) << sh)
            }
            Sew::E16 => {
                let sh = 16 * i;
                (w & !(0xffffu32 << sh)) | ((v & 0xffff) << sh)
            }
            Sew::E32 => v,
        }
    }

    /// Build a word by broadcasting (splatting) `v` into every element.
    #[inline]
    pub fn splat(v: u32, sew: Sew) -> u32 {
        match sew {
            Sew::E8 => {
                let b = v & 0xff;
                b | (b << 8) | (b << 16) | (b << 24)
            }
            Sew::E16 => {
                let h = v & 0xffff;
                h | (h << 16)
            }
            Sew::E32 => v,
        }
    }
}

/// Packed word operations. Each function computes, element by element, the
/// obvious scalar operation with wrap-around integer semantics (matching
/// the 2's-complement hardware datapath).
pub mod swar {
    use super::{elem, Sew};

    /// Apply a scalar binary op element-wise. The building block for all
    /// packed ops; the per-op wrappers below exist so hot paths stay
    /// monomorphized and readable.
    #[inline]
    pub fn map2(a: u32, b: u32, sew: Sew, f: impl Fn(i32, i32) -> i32) -> u32 {
        match sew {
            Sew::E32 => f(a as i32, b as i32) as u32,
            _ => {
                let mut out = 0u32;
                for i in 0..sew.lanes() {
                    let r = f(elem::get_signed(a, i, sew), elem::get_signed(b, i, sew));
                    out = elem::set(out, i, sew, r as u32);
                }
                out
            }
        }
    }

    /// Packed wrapping addition.
    #[inline]
    pub fn add(a: u32, b: u32, sew: Sew) -> u32 {
        match sew {
            Sew::E32 => a.wrapping_add(b),
            // Classic SWAR: clear each element's MSB, add, restore carries.
            Sew::E16 | Sew::E8 => {
                let (mask_lo, mask_hi) = if sew == Sew::E8 {
                    (0x7f7f_7f7fu32, 0x8080_8080u32)
                } else {
                    (0x7fff_7fffu32, 0x8000_8000u32)
                };
                let s = (a & mask_lo).wrapping_add(b & mask_lo);
                s ^ ((a ^ b) & mask_hi)
            }
        }
    }

    /// Packed wrapping subtraction.
    #[inline]
    pub fn sub(a: u32, b: u32, sew: Sew) -> u32 {
        map2(a, b, sew, |x, y| x.wrapping_sub(y))
    }

    /// Packed truncating multiplication (low `sew` bits of the product).
    #[inline]
    pub fn mul(a: u32, b: u32, sew: Sew) -> u32 {
        map2(a, b, sew, |x, y| x.wrapping_mul(y))
    }

    /// Packed signed minimum.
    #[inline]
    pub fn min_signed(a: u32, b: u32, sew: Sew) -> u32 {
        map2(a, b, sew, |x, y| x.min(y))
    }

    /// Packed signed maximum.
    #[inline]
    pub fn max_signed(a: u32, b: u32, sew: Sew) -> u32 {
        map2(a, b, sew, |x, y| x.max(y))
    }

    /// Packed unsigned minimum.
    #[inline]
    pub fn min_unsigned(a: u32, b: u32, sew: Sew) -> u32 {
        let mut out = 0u32;
        for i in 0..sew.lanes() {
            let r = elem::get_unsigned(a, i, sew).min(elem::get_unsigned(b, i, sew));
            out = elem::set(out, i, sew, r);
        }
        out
    }

    /// Packed unsigned maximum.
    #[inline]
    pub fn max_unsigned(a: u32, b: u32, sew: Sew) -> u32 {
        let mut out = 0u32;
        for i in 0..sew.lanes() {
            let r = elem::get_unsigned(a, i, sew).max(elem::get_unsigned(b, i, sew));
            out = elem::set(out, i, sew, r);
        }
        out
    }

    /// Packed logical shift left. The shift amount for each element is the
    /// corresponding element of `b`, masked to the element width.
    #[inline]
    pub fn sll(a: u32, b: u32, sew: Sew) -> u32 {
        let m = sew.bits() - 1;
        map2(a, b, sew, |x, y| ((x as u32) << (y as u32 & m)) as i32)
    }

    /// Packed logical shift right (zero fill within each element).
    #[inline]
    pub fn srl(a: u32, b: u32, sew: Sew) -> u32 {
        let m = sew.bits() - 1;
        let mut out = 0u32;
        for i in 0..sew.lanes() {
            let sh = elem::get_unsigned(b, i, sew) & m;
            out = elem::set(out, i, sew, elem::get_unsigned(a, i, sew) >> sh);
        }
        out
    }

    /// Packed arithmetic shift right (sign fill within each element).
    #[inline]
    pub fn sra(a: u32, b: u32, sew: Sew) -> u32 {
        let m = sew.bits() - 1;
        map2(a, b, sew, |x, y| x >> (y as u32 & m))
    }

    /// Sum of signed element-wise products of one word pair (the Xcv
    /// `cv.sdotsp` / NM-Caesar `DOT` primitive). Returns the full i32 sum.
    #[inline]
    pub fn dotp_signed(a: u32, b: u32, sew: Sew) -> i32 {
        let mut acc = 0i32;
        for i in 0..sew.lanes() {
            acc = acc.wrapping_add(
                elem::get_signed(a, i, sew).wrapping_mul(elem::get_signed(b, i, sew)),
            );
        }
        acc
    }

    /// Packed element-wise MAC: `acc[i] + a[i]*b[i]` per element (the
    /// NM-Caesar `MAC` and NM-Carus `vmacc` primitive).
    #[inline]
    pub fn mac(acc: u32, a: u32, b: u32, sew: Sew) -> u32 {
        match sew {
            Sew::E32 => (acc as i32).wrapping_add((a as i32).wrapping_mul(b as i32)) as u32,
            _ => {
                let mut out = 0u32;
                for i in 0..sew.lanes() {
                    let r = elem::get_signed(acc, i, sew).wrapping_add(
                        elem::get_signed(a, i, sew).wrapping_mul(elem::get_signed(b, i, sew)),
                    );
                    out = elem::set(out, i, sew, r as u32);
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_swar_matches_scalar() {
        // SWAR fast path vs map2 reference over interesting patterns.
        let pats = [0u32, 0xffff_ffff, 0x7f80_017f, 0x8000_0001, 0x1234_5678, 0xdead_beef];
        for &a in &pats {
            for &b in &pats {
                for sew in Sew::ALL {
                    let fast = swar::add(a, b, sew);
                    let slow = swar::map2(a, b, sew, |x, y| x.wrapping_add(y));
                    assert_eq!(fast, slow, "add {a:#x}+{b:#x} {sew}");
                }
            }
        }
    }

    #[test]
    fn elem_set_get_roundtrip() {
        for sew in Sew::ALL {
            for i in 0..sew.lanes() {
                let w = elem::set(0xaaaa_aaaa, i, sew, 0x5b);
                assert_eq!(elem::get_unsigned(w, i, sew), 0x5b);
            }
        }
    }

    #[test]
    fn splat_fills_all_lanes() {
        assert_eq!(elem::splat(0xab, Sew::E8), 0xabab_abab);
        assert_eq!(elem::splat(0x1234, Sew::E16), 0x1234_1234);
        assert_eq!(elem::splat(0xdeadbeef, Sew::E32), 0xdead_beef);
    }

    #[test]
    fn mul_truncates_per_element() {
        // 8-bit: 16*16 = 256 → truncates to 0.
        let a = elem::splat(16, Sew::E8);
        assert_eq!(swar::mul(a, a, Sew::E8), 0);
        // 16-bit keeps it: 256 fits.
        let a = elem::splat(16, Sew::E16);
        assert_eq!(swar::mul(a, a, Sew::E16), elem::splat(256, Sew::E16));
    }

    #[test]
    fn shifts_mask_amounts() {
        // Shift amount masked to element width: 8-bit shift by 9 == shift by 1.
        let a = elem::splat(0x40, Sew::E8);
        let nine = elem::splat(9, Sew::E8);
        let one = elem::splat(1, Sew::E8);
        assert_eq!(swar::sll(a, nine, Sew::E8), swar::sll(a, one, Sew::E8));
        // sra keeps sign within element.
        let neg = elem::splat(0x80, Sew::E8); // -128 per lane
        assert_eq!(swar::sra(neg, one, Sew::E8), elem::splat(0xc0, Sew::E8)); // -64
        // srl zero-fills.
        assert_eq!(swar::srl(neg, one, Sew::E8), elem::splat(0x40, Sew::E8));
    }

    #[test]
    fn mac_per_element() {
        let acc = elem::splat(10, Sew::E16);
        let a = elem::splat(3, Sew::E16);
        let b = elem::splat(4, Sew::E16);
        assert_eq!(swar::mac(acc, a, b, Sew::E16), elem::splat(22, Sew::E16));
        // Negative products.
        let a = elem::splat((-3i32) as u32, Sew::E8);
        let b = elem::splat(4, Sew::E8);
        assert_eq!(swar::mac(0, a, b, Sew::E8), elem::splat((-12i32) as u32, Sew::E8));
    }

    #[test]
    fn dotp_all_widths() {
        let a = 0x0102_0304u32; // bytes 4,3,2,1
        let b = 0x0101_0101u32;
        assert_eq!(swar::dotp_signed(a, b, Sew::E8), 10);
        assert_eq!(swar::dotp_signed(a, b, Sew::E16), (0x0304 * 0x0101 + 0x0102 * 0x0101));
        assert_eq!(swar::dotp_signed(2, 3, Sew::E32), 6);
    }
}
