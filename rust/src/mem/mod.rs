//! Memory models: single-port SRAM banks, register-file macros, and the
//! flash/ROM used to stream Anomaly-Detection weights.
//!
//! Every model is functional (byte-accurate little-endian storage) plus
//! *event-counting*: each read/write access increments per-bank counters
//! that the [`crate::energy`] model later converts to pJ using the 65 nm
//! calibration table. Single-port timing (one access per cycle) is enforced
//! by the owners of the banks (SoC bus, Caesar scheduler, Carus VRF lanes),
//! not here — this module only provides the storage and the accounting.

/// Access counters for one memory macro.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemStats {
    pub reads: u64,
    pub writes: u64,
}

impl MemStats {
    /// Total accesses.
    pub fn total(&self) -> u64 {
        self.reads + self.writes
    }
    /// Accumulate another counter set (used by the SoC energy roll-up).
    pub fn add(&mut self, other: &MemStats) {
        self.reads += other.reads;
        self.writes += other.writes;
    }
}

/// Kind of memory macro, used by the energy/area models to pick constants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MacroKind {
    /// Foundry single-port 6T SRAM, 32 KiB (the reference bank).
    Sram32k,
    /// 16 KiB single-port SRAM (NM-Caesar internal banks).
    Sram16k,
    /// 8 KiB single-port SRAM (NM-Carus VRF banks).
    Sram8k,
    /// 512 B register-file macro (NM-Carus eMEM).
    RegFile512,
    /// Embedded flash/ROM (weight storage for the AD app).
    Rom,
}

impl MacroKind {
    /// Capacity in bytes (Rom is unboundedly sized by its contents).
    pub fn capacity(self) -> u32 {
        match self {
            MacroKind::Sram32k => 32 * 1024,
            MacroKind::Sram16k => 16 * 1024,
            MacroKind::Sram8k => 8 * 1024,
            MacroKind::RegFile512 => 512,
            MacroKind::Rom => u32::MAX,
        }
    }
}

/// A single-port memory bank (SRAM / register file / ROM).
#[derive(Debug, Clone)]
pub struct Bank {
    pub kind: MacroKind,
    data: Vec<u8>,
    pub stats: MemStats,
}

impl Bank {
    /// Create a zero-initialized bank of the macro's natural capacity.
    pub fn new(kind: MacroKind) -> Self {
        let cap = if kind == MacroKind::Rom { 0 } else { kind.capacity() as usize };
        Bank { kind, data: vec![0; cap], stats: MemStats::default() }
    }

    /// Create a ROM from contents.
    pub fn rom(contents: Vec<u8>) -> Self {
        Bank { kind: MacroKind::Rom, data: contents, stats: MemStats::default() }
    }

    /// Size in bytes.
    pub fn len(&self) -> u32 {
        self.data.len() as u32
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Read `size` ∈ {1,2,4} bytes at `off`, zero-extended. Counts one access.
    #[inline]
    pub fn read(&mut self, off: u32, size: u32) -> u32 {
        self.stats.reads += 1;
        self.peek(off, size)
    }

    /// Read without counting an access (debug/verification path).
    #[inline]
    pub fn peek(&self, off: u32, size: u32) -> u32 {
        let o = off as usize;
        match size {
            1 => self.data[o] as u32,
            2 => u16::from_le_bytes([self.data[o], self.data[o + 1]]) as u32,
            4 => u32::from_le_bytes([self.data[o], self.data[o + 1], self.data[o + 2], self.data[o + 3]]),
            _ => panic!("unsupported access size {size}"),
        }
    }

    /// Write `size` ∈ {1,2,4} bytes at `off`. Counts one access.
    #[inline]
    pub fn write(&mut self, off: u32, size: u32, val: u32) {
        self.stats.writes += 1;
        self.poke(off, size, val);
    }

    /// Write without counting an access (initialization path).
    #[inline]
    pub fn poke(&mut self, off: u32, size: u32, val: u32) {
        let o = off as usize;
        match size {
            1 => self.data[o] = val as u8,
            2 => self.data[o..o + 2].copy_from_slice(&(val as u16).to_le_bytes()),
            4 => self.data[o..o + 4].copy_from_slice(&val.to_le_bytes()),
            _ => panic!("unsupported access size {size}"),
        }
    }

    /// Bulk-load bytes at `off` without counting accesses (program load,
    /// dataset initialization — the paper embeds inputs in the firmware).
    pub fn load(&mut self, off: u32, bytes: &[u8]) {
        let o = off as usize;
        self.data[o..o + bytes.len()].copy_from_slice(bytes);
    }

    /// Snapshot a byte range without counting accesses.
    pub fn dump(&self, off: u32, len: u32) -> Vec<u8> {
        self.data[off as usize..(off + len) as usize].to_vec()
    }

    /// Reset counters (between benchmark phases).
    pub fn reset_stats(&mut self) {
        self.stats = MemStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rw_all_sizes_little_endian() {
        let mut b = Bank::new(MacroKind::Sram32k);
        b.write(0x100, 4, 0xdead_beef);
        assert_eq!(b.read(0x100, 1), 0xef);
        assert_eq!(b.read(0x101, 1), 0xbe);
        assert_eq!(b.read(0x100, 2), 0xbeef);
        assert_eq!(b.read(0x102, 2), 0xdead);
        assert_eq!(b.read(0x100, 4), 0xdead_beef);
        assert_eq!(b.stats, MemStats { reads: 5, writes: 1 });
    }

    #[test]
    fn peek_poke_do_not_count() {
        let mut b = Bank::new(MacroKind::Sram8k);
        b.poke(0, 4, 42);
        assert_eq!(b.peek(0, 4), 42);
        assert_eq!(b.stats.total(), 0);
    }

    #[test]
    fn load_and_dump() {
        let mut b = Bank::new(MacroKind::RegFile512);
        b.load(16, &[1, 2, 3, 4]);
        assert_eq!(b.dump(16, 4), vec![1, 2, 3, 4]);
        assert_eq!(b.peek(16, 4), 0x0403_0201);
    }

    #[test]
    fn subword_write_preserves_neighbors() {
        let mut b = Bank::new(MacroKind::Sram16k);
        b.poke(8, 4, 0xffff_ffff);
        b.write(9, 1, 0x00);
        assert_eq!(b.peek(8, 4), 0xffff_00ff);
        b.write(10, 2, 0x1234);
        assert_eq!(b.peek(8, 4), 0x1234_00ff);
    }

    #[test]
    fn rom_from_contents() {
        let b = Bank::rom(vec![9, 8, 7, 6]);
        assert_eq!(b.len(), 4);
        assert_eq!(b.peek(0, 4), 0x0607_0809);
    }
}
