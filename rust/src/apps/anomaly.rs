//! Anomaly-Detection TinyML application (Table VI, §V-B2).
//!
//! The MLPerf-Tiny AD model [43]: a fully-connected autoencoder of ten
//! matrix-vector layers (640-128-128-128-128-8-128-128-128-128-640) with
//! ReLU activations, int8-quantized. The paper deploys it on a minimal
//! system with a single 32 KiB L1 bank (replaced by the NMC device in the
//! NMC rows) and weights streamed from embedded flash; we reproduce that
//! topology with synthetic int8 weights/inputs (the learned values do not
//! affect cycles or energy) and **mod-256 accumulate semantics** shared by
//! every target and by the JAX golden model (`python/compile/model.py`):
//! `out = relu(wrap8(Σ w·x))` — bit-exact across CPU/Caesar/Carus/XLA.
//!
//! Per-target mapping:
//! - **CPU (CV32E40P + Xcv)**: `cv.sdotsp.b` packed MACs, weights read
//!   directly from flash, ≈2 cycles/MAC — lands on the paper's 561 k cycles.
//! - **NM-Caesar + CV32E20**: per layer, per k-tile: weight tile DMA'd into
//!   the macro (memory mode), `x` splat words prepared by the host, then
//!   the host issues `MAC_*` micro-op streams online (the
//!   `*(BASE+DEST)=op` pattern — an E20 without hardware multiply can
//!   still issue one op every ~3 cycles because consecutive op words
//!   differ by the constant `0x2001`). Multi-tile layers accumulate
//!   partial sums with an extra `ADD` per output chunk.
//! - **NM-Carus + CV32E20**: one generic 20-instruction matvec kernel
//!   (vmacc.vx over column vectors + emvx operand fetch, indirect register
//!   addressing) reused for every layer and tile; weights DMA'd
//!   column-major from flash, activations bounced through SRAM.
//! - **Multi-core rows**: ideal linear scaling, exactly as the paper
//!   assumes: cycles/N; energy re-evaluated with the time-proportional
//!   (always-on) component divided by N. Instruction-memory energy is
//!   excluded from every Table VI figure (paper footnote).

use crate::asm::Asm;
use crate::bus::{periph, BANK_SIZE, CAESAR_BASE, CARUS_BASE, PERIPH_BASE, ROM_BASE};
use crate::caesar::isa::{encode as cenc, MicroOp, Op};
use crate::carus::{ARG_OFFSET, CTL_OFFSET, CTL_START};
use crate::cpu::CpuConfig;
use crate::energy::{self, Activity, Breakdown};
use crate::isa::reg::*;
use crate::isa::xvnmc::{pack_indexes, VOp, VSrc};
use crate::isa::Sew;
use crate::kernels::golden::Rng;
use crate::soc::{Halt, Soc};

/// Layer shapes: (in, out, relu).
pub fn network() -> Vec<(u32, u32, bool)> {
    vec![
        (640, 128, true),
        (128, 128, true),
        (128, 128, true),
        (128, 128, true),
        (128, 8, true),
        (8, 128, true),
        (128, 128, true),
        (128, 128, true),
        (128, 128, true),
        (128, 640, false),
    ]
}

/// Total MAC count (≈264 k).
pub fn total_macs() -> u64 {
    network().iter().map(|&(i, o, _)| i as u64 * o as u64).sum()
}

/// Synthetic int8 model: weights per layer (row-major `w[out][in]`) + input.
pub struct Model {
    pub weights: Vec<Vec<i8>>,
    pub input: Vec<i8>,
}

pub fn model(seed: u64) -> Model {
    let mut rng = Rng(seed ^ 0x5eed_ad00);
    let weights = network()
        .iter()
        .map(|&(i, o, _)| (0..i * o).map(|_| rng.next_u32() as i8).collect())
        .collect();
    let input = (0..640).map(|_| rng.next_u32() as i8).collect();
    Model { weights, input }
}

/// Golden forward pass (shared semantics; see module docs).
pub fn golden_forward(m: &Model) -> Vec<i8> {
    let mut x: Vec<i8> = m.input.clone();
    for (l, &(ins, outs, relu)) in network().iter().enumerate() {
        let w = &m.weights[l];
        let mut y = vec![0i8; outs as usize];
        for j in 0..outs as usize {
            let mut acc: i32 = 0;
            for k in 0..ins as usize {
                acc = acc.wrapping_add(w[j * ins as usize + k] as i32 * x[k] as i32);
            }
            let v = acc as i8; // wrap8
            y[j] = if relu && v < 0 { 0 } else { v };
        }
        x = y;
    }
    x
}

/// Result of one Table VI configuration.
#[derive(Debug, Clone)]
pub struct AdResult {
    pub name: &'static str,
    pub cycles: u64,
    /// Energy with instruction-memory contribution excluded (Table VI), µJ.
    pub energy_uj: f64,
    /// Full breakdown (instruction fetches included), for reference.
    pub energy_full: Breakdown,
    /// Activity record (multicore scaling, Fig.-13-style analysis).
    pub activity: Activity,
    pub output: Vec<i8>,
}

/// Energy with the instruction-memory share removed (Table VI footnote).
fn energy_excl_imem(act: &Activity) -> f64 {
    let mut a = act.clone();
    a.cpu_fetches = 0;
    energy::energy(&a).total() / 1.0e6 // pJ → µJ
}

fn finish(name: &'static str, soc: &Soc, output: Vec<i8>) -> AdResult {
    let act = soc.activity();
    AdResult {
        name,
        cycles: soc.cycle,
        energy_uj: energy_excl_imem(&act),
        energy_full: soc.energy(),
        activity: act,
        output,
    }
}

/// Dispatch one Table VI system configuration by execution target — the
/// seam [`crate::sweep::SweepSession::anomaly`] memoizes behind, so every
/// consumer (Table VI, `heeperator ad`, the example, the benches) shares
/// one simulation per invocation.
pub fn run_target(m: &Model, target: crate::kernels::Target) -> AdResult {
    use crate::kernels::Target;
    match target {
        Target::Cpu => run_cpu(m),
        Target::Caesar => run_caesar(m),
        Target::Carus => run_carus(m),
    }
}

/// Ideal-linear-scaling multi-core projection from the single-core run
/// (the paper's own Table VI methodology).
pub fn scale_multicore(single: &AdResult, cores: u64) -> AdResult {
    let mut act = single.activity.clone();
    act.cpu_fetches = 0; // Table VI excludes instruction memory
    let e = energy::energy(&act);
    // Work energy (CPU switching, data memory, interconnect) is invariant;
    // time-proportional energy (always-on "other") shrinks by N.
    let scaled = e.cpu + e.memory + e.nmc_logic + e.interconnect + e.other / cores as f64;
    AdResult {
        name: match cores {
            2 => "CV32E40P (2 cores)",
            4 => "CV32E40P (4 cores)",
            _ => "CV32E40P (N cores)",
        },
        cycles: single.cycles / cores,
        energy_uj: scaled / 1.0e6,
        energy_full: single.energy_full,
        activity: single.activity.clone(),
        output: single.output.clone(),
    }
}

// --------------------------------------------------------------------------
// CPU baseline (CV32E40P + Xcv), weights streamed from flash.
// --------------------------------------------------------------------------

/// Activation ping-pong buffers in SRAM bank 1.
const X_BUF: u32 = BANK_SIZE;
const Y_BUF: u32 = BANK_SIZE + 0x1000;

pub fn run_cpu(m: &Model) -> AdResult {
    let mut soc = Soc::new(CpuConfig::CV32E40P_XCV, 4);
    // Weights in flash, row-major, layer after layer (word aligned).
    let mut rom = Vec::new();
    let mut w_offsets = Vec::new();
    for w in &m.weights {
        w_offsets.push(rom.len() as u32);
        rom.extend(w.iter().map(|&v| v as u8));
        while rom.len() % 4 != 0 {
            rom.push(0);
        }
    }
    soc.set_rom(rom);
    soc.load_data(X_BUF, &m.input.iter().map(|&v| v as u8).collect::<Vec<_>>());

    let mut a = Asm::new(0);
    let mut xb = X_BUF;
    let mut yb = Y_BUF;
    for (l, &(ins, outs, relu)) in network().iter().enumerate() {
        let lab = |s: &str| format!("l{l}_{s}");
        a.li(S0, (ROM_BASE + w_offsets[l]) as i32) // w row pointer
            .li(S1, xb as i32) // x base
            .li(S2, yb as i32) // y pointer
            .li(S3, outs as i32) // j counter
            .label(&lab("jloop"))
            .mv(T0, S0) // w walker
            .mv(T1, S1) // x walker
            .li(T2, 0) // acc
            .li(T3, (ins / 4) as i32) // k-word counter
            .label(&lab("kloop"))
            .lw(T4, 0, T0)
            .lw(T5, 0, T1)
            .cv_sdotsp_b(T2, T4, T5)
            .addi(T0, T0, 4)
            .addi(T1, T1, 4)
            .addi(T3, T3, -1)
            .bne(T3, ZERO, &lab("kloop"))
            // wrap to int8 then ReLU.
            .slli(T2, T2, 24)
            .srai(T2, T2, 24);
        if relu {
            a.cv_max(T2, T2, ZERO);
        }
        a.sb(T2, 0, S2)
            .addi(S2, S2, 1)
            .addi(S0, S0, ins as i32) // next weight row
            .addi(S3, S3, -1)
            .bne(S3, ZERO, &lab("jloop"));
        std::mem::swap(&mut xb, &mut yb);
    }
    a.ebreak();
    let prog = a.assemble().expect("AD cpu firmware");
    soc.load_firmware(&prog, 0);
    soc.reset_stats();
    let budget = crate::kernels::run_timeout_or(50_000_000);
    let (halt, cycles) = soc.run(budget);
    assert_eq!(
        halt,
        Halt::Done,
        "AD firmware did not complete: {halt:?} after {cycles} cycles (budget {budget}; raise \
         SOC_RUN_TIMEOUT to extend)"
    );
    let out = soc.dump(xb, 640).iter().map(|&b| b as i8).collect();
    finish("CV32E40P (1 core)", &soc, out)
}

// --------------------------------------------------------------------------
// NM-Caesar + CV32E20
// --------------------------------------------------------------------------

/// Caesar-internal layout (word offsets): x/out packed + splats in bank 0,
/// weight tile + constants in bank 1.
mod cl {
    pub const X: u32 = 0; // ≤160 words (640 B)
    pub const OUT: u32 = 256; // ≤160 words
    pub const SPLAT: u32 = 512; // ≤ ktile words
    pub const W: u32 = 4096; // weight tile, ≤ 3072 words (12 KiB)
    pub const ZERO: u32 = 7900; // zero splat (bank 1)
    pub const TMP: u32 = 7901; // partial-sum scratch (bank 1)
    pub const W_WORDS: u32 = 3072;
}

pub fn run_caesar(m: &Model) -> AdResult {
    let mut soc = Soc::new(CpuConfig::CV32E20, 4);
    // Flash layout: per layer, per k-tile, column-chunk-major words:
    // word(c, k) = w[4c..4c+4][k]; chunk-major, k inner.
    let mut rom = Vec::new();
    let mut tiles_per_layer: Vec<Vec<(u32, u32, u32)>> = Vec::new(); // (rom_off, k0, ktile)
    for &(ins, outs, _) in network().iter() {
        let l = tiles_per_layer.len();
        let w = &m.weights[l];
        let chunks = outs.div_ceil(4);
        let max_ktile = (cl::W_WORDS / chunks).min(ins).max(3);
        let mut tiles = Vec::new();
        let mut k0 = 0;
        while k0 < ins {
            let ktile = max_ktile.min(ins - k0);
            assert!(ktile >= 3, "MAC stream needs INIT + ≥1 MAC + STORE");
            tiles.push((rom.len() as u32, k0, ktile));
            for c in 0..chunks {
                for k in k0..k0 + ktile {
                    for e in 0..4 {
                        let j = 4 * c + e;
                        rom.push(if j < outs { w[(j * ins + k) as usize] as u8 } else { 0 });
                    }
                }
            }
            k0 += ktile;
        }
        tiles_per_layer.push(tiles);
    }
    soc.set_rom(rom);
    soc.caesar_mut().sew = Sew::E8;
    soc.caesar_mut().load(cl::X * 4, &m.input.iter().map(|&v| v as u8).collect::<Vec<_>>());
    soc.caesar_mut().splat_word(cl::ZERO, 0);

    let mut a = Asm::new(0);
    let imc_reg = (PERIPH_BASE + periph::CAESAR_IMC) as i32;
    let mut x_w = cl::X;
    let mut out_w = cl::OUT;
    for (l, &(ins, outs, relu)) in network().iter().enumerate() {
        let chunks = outs.div_ceil(4);
        let _ = ins;
        for (t, &(rom_off, k0, ktile)) in tiles_per_layer[l].iter().enumerate() {
            let lab = |s: &str| format!("l{l}t{t}_{s}");
            let first_tile = t == 0;
            // Phase A (memory mode): DMA weight tile flash → Caesar.
            a.li(T0, imc_reg).sw(ZERO, 0, T0);
            dma_copy(&mut a, ROM_BASE + rom_off, CAESAR_BASE + cl::W * 4, chunks * ktile * 4);
            // Phase B: build splat words for x[k0..k0+ktile].
            a.li(T0, (CAESAR_BASE + x_w * 4 + k0) as i32) // x bytes
                .li(T1, (CAESAR_BASE + cl::SPLAT * 4) as i32)
                .li(T2, ktile as i32)
                .label(&lab("splat"))
                .lbu(A0, 0, T0)
                .slli(A1, A0, 8)
                .or(A0, A0, A1)
                .slli(A1, A0, 16)
                .or(A0, A0, A1)
                .sw(A0, 0, T1)
                .addi(T0, T0, 1)
                .addi(T1, T1, 4)
                .addi(T2, T2, -1)
                .bne(T2, ZERO, &lab("splat"));
            // Phase C (computing mode): issue one MAC stream per out chunk.
            a.li(T0, imc_reg).li(T1, 1).sw(T1, 0, T0);
            let init_op =
                cenc(&MicroOp { op: Op::MacInit, src1: cl::W as u16, src2: cl::SPLAT as u16 });
            let mac_op = cenc(&MicroOp { op: Op::Mac, src1: cl::W as u16, src2: cl::SPLAT as u16 });
            let store_op = cenc(&MicroOp {
                op: Op::MacStore,
                src1: (cl::W + ktile - 1) as u16,
                src2: (cl::SPLAT + ktile - 1) as u16,
            });
            let add_op =
                cenc(&MicroOp { op: Op::Add, src1: out_w as u16, src2: cl::TMP as u16 });
            // Registers: S0 chunk ctr, S1 out-dest ptr, A0 TMP addr,
            // A1 = 0x2001 (both sources advance one word per k), A2 dummy
            // dest, A3/A4/A5 rolling INIT/MAC/STORE op words, T2 rolling
            // ADD op, T0/T1 inner loop.
            a.li(S0, chunks as i32)
                .li(S1, (CAESAR_BASE + out_w * 4) as i32)
                .li(A0, (CAESAR_BASE + cl::TMP * 4) as i32)
                .li(A1, 0x2001)
                .li(A2, (CAESAR_BASE + 0x1000) as i32) // dummy dest (no writeback ops)
                .li(A3, init_op as i32)
                .li(A4, mac_op as i32)
                .li(A5, store_op as i32)
                .li(T2, add_op as i32)
                .label(&lab("chunk"))
                .sw(A3, 0, A2) // MAC_INIT (k = k0)
                .add(T0, A4, A1) // first MAC (k = k0+1)
                .li(T1, (ktile - 2) as i32)
                .label(&lab("mac"))
                .sw(T0, 0, A2)
                .add(T0, T0, A1)
                .addi(T1, T1, -1)
                .bne(T1, ZERO, &lab("mac"));
            if first_tile {
                a.sw(A5, 0, S1); // MAC_STORE → out chunk
            } else {
                a.sw(A5, 0, A0) // MAC_STORE → TMP
                    .sw(T2, 0, S1) // ADD out, out, TMP
                    .addi(T2, T2, 1); // next out word as src1
            }
            a.addi(A3, A3, ktile as i32) // W base advances by ktile words
                .addi(A4, A4, ktile as i32)
                .addi(A5, A5, ktile as i32)
                .addi(S1, S1, 4)
                .addi(S0, S0, -1)
                .bne(S0, ZERO, &lab("chunk"));
        }
        // ReLU pass (still in computing mode): in-place MAX vs zero splat.
        if relu {
            let max_op =
                cenc(&MicroOp { op: Op::Max, src1: out_w as u16, src2: cl::ZERO as u16 });
            let words = outs.div_ceil(4);
            a.li(T0, max_op as i32)
                .li(T1, (CAESAR_BASE + out_w * 4) as i32)
                .li(T2, words as i32)
                .label(&format!("l{l}_relu"))
                .sw(T0, 0, T1)
                .addi(T0, T0, 1)
                .addi(T1, T1, 4)
                .addi(T2, T2, -1)
                .bne(T2, ZERO, &format!("l{l}_relu"));
        }
        a.li(T0, imc_reg).sw(ZERO, 0, T0);
        std::mem::swap(&mut x_w, &mut out_w);
    }
    a.ebreak();
    let prog = a.assemble().expect("AD caesar firmware");
    soc.load_firmware(&prog, 0);
    soc.reset_stats();
    let budget = crate::kernels::run_timeout_or(50_000_000);
    let (halt, cycles) = soc.run(budget);
    assert_eq!(
        halt,
        Halt::Done,
        "AD firmware did not complete: {halt:?} after {cycles} cycles (budget {budget}; raise \
         SOC_RUN_TIMEOUT to extend)"
    );
    let out = soc.dump(CAESAR_BASE + x_w * 4, 640).iter().map(|&b| b as i8).collect();
    finish("NM-Caesar + CV32E20", &soc, out)
}

/// Emit a DMA copy sequence (copy mode) + wfi + ack.
fn dma_copy(a: &mut Asm, src: u32, dst: u32, len: u32) {
    debug_assert!(src % 4 == 0 && dst % 4 == 0, "DMA endpoints must be word aligned");
    a.li(T0, (PERIPH_BASE + periph::DMA_SRC) as i32)
        .li(T1, src as i32)
        .sw(T1, 0, T0)
        .li(T0, (PERIPH_BASE + periph::DMA_DST) as i32)
        .li(T1, dst as i32)
        .sw(T1, 0, T0)
        .li(T0, (PERIPH_BASE + periph::DMA_LEN) as i32)
        .li(T1, len.div_ceil(4) as i32 * 4)
        .sw(T1, 0, T0)
        .li(T0, (PERIPH_BASE + periph::DMA_CTL) as i32)
        .li(T1, 1)
        .sw(T1, 0, T0)
        .wfi()
        .li(T0, (PERIPH_BASE + periph::DMA_STATUS) as i32)
        .lw(T1, 0, T0);
}

// --------------------------------------------------------------------------
// NM-Carus + CV32E20
// --------------------------------------------------------------------------

pub fn run_carus(m: &Model) -> AdResult {
    let mut soc = Soc::new(CpuConfig::CV32E20, 4);
    // Flash: per layer, column-major (col k = w[:,k], `out` bytes each).
    let mut rom = Vec::new();
    let mut col_offsets = Vec::new();
    for (l, &(ins, outs, _)) in network().iter().enumerate() {
        col_offsets.push(rom.len() as u32);
        let w = &m.weights[l];
        for k in 0..ins {
            for j in 0..outs {
                rom.push(w[(j * ins + k) as usize] as u8);
            }
        }
    }
    soc.set_rom(rom);
    let kernel = matvec_kernel();
    let kbytes: Vec<u8> = kernel.words.iter().flat_map(|w| w.to_le_bytes()).collect();
    const KSTAGE: u32 = 2 * BANK_SIZE; // kernel staging in SRAM bank 2
    soc.load_data(KSTAGE, &kbytes);
    soc.load_data(X_BUF, &m.input.iter().map(|&v| v as u8).collect::<Vec<_>>());

    let mut a = Asm::new(0);
    let mode_reg = (PERIPH_BASE + periph::CARUS_MODE) as i32;
    // Upload the kernel once.
    a.li(T0, mode_reg).li(T1, 1).sw(T1, 0, T0);
    dma_copy(&mut a, KSTAGE, CARUS_BASE, kbytes.len() as u32);
    a.li(T0, mode_reg).sw(ZERO, 0, T0);

    for (l, &(ins, outs, relu)) in network().iter().enumerate() {
        let vl = outs;
        // ktile ≤ vl (x tile lives in logical reg 1), VRF capacity bound,
        // and word-aligned so DMA endpoints stay aligned.
        let cap = (crate::carus::vrf::CAPACITY / vl).saturating_sub(4);
        let max_ktile = (vl.min(cap).min(ins) / 4).max(1) * 4;
        let mut k0 = 0;
        let mut t = 0;
        while k0 < ins {
            let ktile = max_ktile.min(ins - k0);
            // x tile → VRF reg 1 (byte offset vl).
            dma_copy(&mut a, X_BUF + k0, CARUS_BASE + vl, ktile);
            // w tile (cols k0..) → VRF regs 4.. (byte offset 4·vl).
            dma_copy(&mut a, ROM_BASE + col_offsets[l] + k0 * outs, CARUS_BASE + 4 * vl, ktile * outs);
            let last = k0 + ktile >= ins;
            a.li(T0, mode_reg).li(T1, 1).sw(T1, 0, T0); // config mode
            for (i, val) in [vl, ktile, (t == 0) as u32, (relu && last) as u32].iter().enumerate() {
                a.li(T0, (CARUS_BASE + ARG_OFFSET + 4 * i as u32) as i32)
                    .li(T1, *val as i32)
                    .sw(T1, 0, T0);
            }
            a.li(A0, (CARUS_BASE + CTL_OFFSET) as i32)
                .li(T1, CTL_START as i32)
                .sw(T1, 0, A0)
                .wfi()
                .lw(A1, 0, A0)
                .sw(ZERO, 0, A0)
                .li(T0, mode_reg)
                .sw(ZERO, 0, T0); // memory mode
            k0 += ktile;
            t += 1;
        }
        // Result (acc = VRF bytes 0..outs) → SRAM x buffer for next layer.
        dma_copy(&mut a, CARUS_BASE, X_BUF, outs);
    }
    a.ebreak();
    let prog = a.assemble().expect("AD carus firmware");
    soc.load_firmware(&prog, 0);
    soc.reset_stats();
    let budget = crate::kernels::run_timeout_or(50_000_000);
    let (halt, cycles) = soc.run(budget);
    assert_eq!(
        halt,
        Halt::Done,
        "AD firmware did not complete: {halt:?} after {cycles} cycles (budget {budget}; raise \
         SOC_RUN_TIMEOUT to extend)"
    );
    let out = soc.dump(X_BUF, 640).iter().map(|&b| b as i8).collect();
    finish("NM-Carus + CV32E20", &soc, out)
}

/// The reusable Carus matvec kernel: `acc(v0) += Σ_k x[k]·w_col(v4+k)`,
/// optional clear and fused ReLU. 20 instructions — the paper's code-size
/// story in action.
fn matvec_kernel() -> crate::asm::Program {
    let mut a = Asm::new(0);
    a.li(T0, ARG_OFFSET as i32)
        .lw(A0, 0, T0) // vl
        .lw(S0, 4, T0) // ktile
        .lw(A3, 8, T0) // clear?
        .lw(A4, 12, T0) // relu?
        .vsetvli(T0, A0, Sew::E8)
        .beq(A3, ZERO, "noclear")
        .vmv_vx(0, ZERO) // acc = 0
        .label("noclear")
        .li(A5, 0) // k
        .li(S1, pack_indexes(0, 4, 0) as i32) // {vd=0, vs2=4+k}
        .label("kloop")
        .emvx(A2, 1, A5) // x[k]
        .v_opr(VOp::Macc, S1, VSrc::X(A2))
        .addi(A5, A5, 1)
        .addi(S1, S1, 0x100)
        .bne(A5, S0, "kloop")
        .beq(A4, ZERO, "done")
        .vmax_vx(0, 0, ZERO) // fused ReLU
        .label("done")
        .ebreak();
    a.assemble().expect("matvec kernel")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_forward_deterministic() {
        let m = model(1);
        let y1 = golden_forward(&m);
        let y2 = golden_forward(&m);
        assert_eq!(y1, y2);
        assert_eq!(y1.len(), 640);
        assert_eq!(total_macs(), 264_192);
    }

    #[test]
    fn cpu_matches_golden_and_paper_cycles() {
        let m = model(2);
        let res = run_cpu(&m);
        assert_eq!(res.output, golden_forward(&m), "CPU output mismatch");
        // Paper: 561e3 cycles on the CV32E40P with RV32IMCXcv.
        assert!(
            (430_000..720_000).contains(&res.cycles),
            "cycles = {} (paper 561e3)",
            res.cycles
        );
    }

    #[test]
    fn carus_matches_golden() {
        let m = model(2);
        let res = run_carus(&m);
        assert_eq!(res.output, golden_forward(&m), "Carus output mismatch");
        // Paper: 3.55× faster than single core ⇒ ≈158e3 cycles.
        assert!(res.cycles < 320_000, "cycles = {}", res.cycles);
    }

    #[test]
    fn caesar_matches_golden() {
        let m = model(2);
        let res = run_caesar(&m);
        assert_eq!(res.output, golden_forward(&m), "Caesar output mismatch");
        // Paper: 1.29× faster than single core ⇒ ≈435e3 cycles.
        assert!(res.cycles < 750_000, "cycles = {}", res.cycles);
    }

    #[test]
    fn multicore_scaling_monotonic() {
        let m = model(3);
        let single = run_cpu(&m);
        let dual = scale_multicore(&single, 2);
        let quad = scale_multicore(&single, 4);
        assert_eq!(dual.cycles, single.cycles / 2);
        assert_eq!(quad.cycles, single.cycles / 4);
        assert!(dual.energy_uj < single.energy_uj);
        assert!(quad.energy_uj < dual.energy_uj);
        // Energy gain is sub-linear (the paper's 1.37× / 1.67×).
        assert!(single.energy_uj / quad.energy_uj < 4.0);
    }
}
