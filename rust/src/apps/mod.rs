//! End-to-end applications (§V-B2).
//!
//! [`anomaly`] deploys the MLPerf-Tiny *Anomaly Detection* autoencoder on
//! the HEEPerator testbench in the five Table VI configurations: 1/2/4-core
//! CV32E40P (RV32IMCXcv) clusters, and CV32E20 + NM-Caesar / NM-Carus.

pub mod anomaly;
