//! Differential fuzzing of the NMC ISAs and the batch scheduler.
//!
//! A [`FuzzCase`] is entirely determined by `(seed, max_insns)`: seeded
//! random programs over the three ISA surfaces (xvnmc, Xcv, NM-Caesar
//! micro-ops) plus one random batch scenario ([`gen::rand_batch_scenario`]).
//! The oracle ([`check`]) runs every case across four axes and demands
//! byte-identical outputs plus the energy/activity invariants of §7:
//!
//! 1. **Isa** — `decode(encode(i)) == i` on every kept instruction.
//! 2. **Engines** — the CPU engine and the scenario's NMC engine both
//!    reproduce the golden reference bit-exactly.
//! 3. **Tiles** — a multi-tile schedule (batched or sharded) produces the
//!    same bytes as the single-tile schedule, and the batch counters obey
//!    the activity invariants.
//! 4. **Timing** — `--timing cycle` and `--timing event` agree exactly:
//!    cycles, outputs, every counter, and bitwise-identical energies.
//!
//! A failing case is greedily [`shrink`]-minimized (drop instructions,
//! shrink shapes, reduce tiles) and serialized to a replayable
//! `fuzz-repro-<seed>.json` ([`to_json`] / [`from_json`]); `heeperator
//! fuzz --replay FILE` re-runs exactly that case. The oracle is
//! self-verified by `rust/tests/fuzz_oracle.rs`, which arms a test-only
//! decode fault ([`arm_decode_fault`]) and asserts the fuzzer finds and
//! shrinks it.

pub mod gen;

use crate::caesar::isa as cisa;
use crate::clock::{self, TimingMode};
use crate::energy::{Activity, Breakdown};
use crate::isa::{xcv, xvnmc};
use crate::kernels::{self, engine, golden, Kernel, RunResult, Target};
use crate::sched::{self, BatchRunResult, BatchSpec};
use crate::spec::{
    json_bool, json_escape, json_list, json_u32_list, json_u64, schemas, JobSpec, JsonSpecOptions,
};
use gen::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};

/// Salt separating the scenario stream from the per-case seed.
const SCENARIO_SALT: u64 = 0x5eed_5ca1_ab1e_0001;
/// Salt separating the instruction stream from the scenario stream.
const ISA_STREAM_SALT: u64 = 0xf0cc_ac1a_b01d_0002;

// ---------------------------------------------------------------------------
// Cases
// ---------------------------------------------------------------------------

/// One fully-determined fuzz case. The instruction programs are *not*
/// stored — they re-materialize from `seed ^ ISA_STREAM_SALT` on demand —
/// only the keep-lists the shrinker filters them through.
#[derive(Debug, Clone, PartialEq)]
pub struct FuzzCase {
    /// Per-case seed (already mixed by the driver).
    pub seed: u64,
    /// Instructions generated per ISA surface before filtering.
    pub max_insns: u32,
    /// Indices of the xvnmc instructions still in the case.
    pub xvnmc_keep: Vec<u32>,
    /// Indices of the Xcv instructions still in the case.
    pub xcv_keep: Vec<u32>,
    /// Indices of the NM-Caesar micro-ops still in the case.
    pub caesar_keep: Vec<u32>,
    /// The batch scenario (target, kernel, sew, seed, batch, shard).
    pub spec: BatchSpec,
    /// Tile count for the multi-tile axis.
    pub tiles: u32,
}

impl FuzzCase {
    /// Build the case for `seed`: full keep-lists plus a random scenario,
    /// resampled (planning is cheap — no simulation) until the scheduler
    /// accepts it, with a known-good fallback so every seed yields a case.
    pub fn from_seed(seed: u64, max_insns: u32) -> FuzzCase {
        let keep: Vec<u32> = (0..max_insns).collect();
        let mut rng = Rng(seed ^ SCENARIO_SALT);
        let (spec, tiles) = (0..100)
            .map(|_| gen::rand_batch_scenario(&mut rng))
            .find(|(s, t)| sched::plan(s, *t as usize).is_ok())
            .unwrap_or_else(|| {
                let spec = BatchSpec {
                    target: Target::Carus,
                    kernel: Kernel::Add { n: 64 },
                    sew: crate::isa::Sew::E32,
                    seed,
                    batch: 1,
                    shard: false,
                };
                (spec, 1)
            });
        FuzzCase { seed, max_insns, xvnmc_keep: keep.clone(), xcv_keep: keep.clone(), caesar_keep: keep, spec, tiles }
    }

    /// Re-materialize the kept instructions of every surface, tagged with
    /// their stream indices (deterministic in `seed` and `max_insns`).
    fn programs(&self) -> Programs {
        let mut rng = Rng(self.seed ^ ISA_STREAM_SALT);
        let xv: Vec<xvnmc::VInstr> = (0..self.max_insns).map(|_| gen::rand_xvnmc_instr(&mut rng)).collect();
        let xc: Vec<xcv::XcvInstr> = (0..self.max_insns).map(|_| gen::rand_xcv_instr(&mut rng)).collect();
        let ca: Vec<cisa::MicroOp> = (0..self.max_insns).map(|_| gen::rand_caesar_microop(&mut rng)).collect();
        let pick = |keep: &[u32]| {
            keep.iter().copied().filter(|&i| i < self.max_insns).collect::<Vec<u32>>()
        };
        Programs {
            xvnmc: pick(&self.xvnmc_keep).into_iter().map(|i| (i, xv[i as usize])).collect(),
            xcv: pick(&self.xcv_keep).into_iter().map(|i| (i, xc[i as usize])).collect(),
            caesar: pick(&self.caesar_keep).into_iter().map(|i| (i, ca[i as usize])).collect(),
        }
    }

    /// Total instructions the case still carries (shrink metric).
    pub fn kept_insns(&self) -> usize {
        self.xvnmc_keep.len() + self.xcv_keep.len() + self.caesar_keep.len()
    }
}

struct Programs {
    xvnmc: Vec<(u32, xvnmc::VInstr)>,
    xcv: Vec<(u32, xcv::XcvInstr)>,
    caesar: Vec<(u32, cisa::MicroOp)>,
}

// ---------------------------------------------------------------------------
// Divergences
// ---------------------------------------------------------------------------

/// The oracle's four differential axes. A [`Divergence`] names the stage
/// it surfaced in; the shrinker re-checks only that stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Isa,
    Engines,
    Tiles,
    Timing,
}

impl Stage {
    pub const ALL: [Stage; 4] = [Stage::Isa, Stage::Engines, Stage::Tiles, Stage::Timing];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Isa => "isa",
            Stage::Engines => "engines",
            Stage::Tiles => "tiles",
            Stage::Timing => "timing",
        }
    }
}

/// One observed disagreement between two executions that must agree (or a
/// violated invariant within one execution).
#[derive(Debug, Clone, PartialEq)]
pub enum Divergence {
    /// `decode(encode(i)) != i` on one ISA surface.
    IsaRoundtrip { surface: &'static str, index: u32, detail: String },
    /// Two engines / schedules / timing modes produced different bytes.
    OutputMismatch { stage: Stage, detail: String },
    /// Negative, non-finite, or non-additive energy.
    EnergyInvariant { stage: Stage, detail: String },
    /// Activity counters that do not sum to the cycle count.
    ActivityInvariant { stage: Stage, detail: String },
    /// A simulation panicked (golden mismatch, internal assert).
    Panic { stage: Stage, detail: String },
    /// The scheduler rejected a case it had previously accepted.
    Plan { detail: String },
}

impl Divergence {
    pub fn stage(&self) -> Stage {
        match self {
            Divergence::IsaRoundtrip { .. } => Stage::Isa,
            Divergence::OutputMismatch { stage, .. }
            | Divergence::EnergyInvariant { stage, .. }
            | Divergence::ActivityInvariant { stage, .. }
            | Divergence::Panic { stage, .. } => *stage,
            Divergence::Plan { .. } => Stage::Tiles,
        }
    }
}

impl std::fmt::Display for Divergence {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Divergence::IsaRoundtrip { surface, index, detail } => {
                write!(f, "[isa] {surface} instruction #{index} does not roundtrip: {detail}")
            }
            Divergence::OutputMismatch { stage, detail } => {
                write!(f, "[{}] output mismatch: {detail}", stage.name())
            }
            Divergence::EnergyInvariant { stage, detail } => {
                write!(f, "[{}] energy invariant violated: {detail}", stage.name())
            }
            Divergence::ActivityInvariant { stage, detail } => {
                write!(f, "[{}] activity invariant violated: {detail}", stage.name())
            }
            Divergence::Panic { stage, detail } => {
                write!(f, "[{}] simulation panicked: {detail}", stage.name())
            }
            Divergence::Plan { detail } => write!(f, "[tiles] plan rejected: {detail}"),
        }
    }
}

/// A failing case plus what diverged.
#[derive(Debug, Clone)]
pub struct Failure {
    pub case: FuzzCase,
    pub divergence: Divergence,
}

// ---------------------------------------------------------------------------
// Test-only fault injection
// ---------------------------------------------------------------------------

static DECODE_FAULT: AtomicBool = AtomicBool::new(false);

/// Arm (or disarm) the test-only xvnmc decode fault: while armed, the
/// oracle's decoder wrapper mis-decodes `VOp::Max` as `VOp::Min` —
/// exactly the class of bug the roundtrip axis exists to catch. Used by
/// `rust/tests/fuzz_oracle.rs` to prove the fuzzer detects and shrinks a
/// seeded decode fault; never armed in production paths.
#[doc(hidden)]
pub fn arm_decode_fault(on: bool) {
    DECODE_FAULT.store(on, Ordering::SeqCst);
}

/// The xvnmc decode the oracle actually calls: real decode, then the
/// armed fault (if any) applied on top.
fn oracle_xvnmc_decode(w: u32) -> Option<xvnmc::VInstr> {
    let mut d = xvnmc::decode(w)?;
    if DECODE_FAULT.load(Ordering::SeqCst) {
        if let xvnmc::VInstr::Op { op, .. } = &mut d {
            if *op == xvnmc::VOp::Max {
                *op = xvnmc::VOp::Min;
            }
        }
    }
    Some(d)
}

// ---------------------------------------------------------------------------
// The oracle
// ---------------------------------------------------------------------------

/// Run every stage of the oracle on one case.
pub fn check(case: &FuzzCase) -> Result<(), Divergence> {
    for stage in Stage::ALL {
        check_stage(case, stage)?;
    }
    Ok(())
}

/// Run one stage of the oracle (the shrinker's predicate).
pub fn check_stage(case: &FuzzCase, stage: Stage) -> Result<(), Divergence> {
    match stage {
        Stage::Isa => check_isa(case),
        Stage::Engines => check_engines(case),
        Stage::Tiles => check_tiles(case),
        Stage::Timing => check_timing(case),
    }
}

/// Stage 1: `decode ∘ encode = id` on every kept instruction of every
/// surface (xvnmc through the faultable wrapper).
fn check_isa(case: &FuzzCase) -> Result<(), Divergence> {
    let p = case.programs();
    for &(i, v) in &p.xvnmc {
        let back = oracle_xvnmc_decode(xvnmc::encode(&v));
        if back != Some(v) {
            return Err(Divergence::IsaRoundtrip {
                surface: "xvnmc",
                index: i,
                detail: format!("{v:?} -> {back:?}"),
            });
        }
    }
    for &(i, x) in &p.xcv {
        let back = xcv::decode(xcv::encode(&x));
        if back != Some(x) {
            return Err(Divergence::IsaRoundtrip {
                surface: "xcv",
                index: i,
                detail: format!("{x:?} -> {back:?}"),
            });
        }
    }
    for &(i, m) in &p.caesar {
        let back = cisa::decode(cisa::encode(&m));
        if back != Some(m) {
            return Err(Divergence::IsaRoundtrip {
                surface: "caesar",
                index: i,
                detail: format!("{m:?} -> {back:?}"),
            });
        }
    }
    Ok(())
}

/// Stage 2: the CPU engine and the scenario's NMC engine both reproduce
/// the golden reference, and each run obeys the energy/activity
/// invariants.
fn check_engines(case: &FuzzCase) -> Result<(), Divergence> {
    let spec = &case.spec;
    let data = golden::generate(spec.kernel, spec.sew, spec.seed);
    for target in [Target::Cpu, spec.target] {
        let res = catch_unwind(AssertUnwindSafe(|| {
            let prog = kernels::prepared(target, spec.kernel, spec.sew);
            engine(target).execute(&prog, &data)
        }))
        .map_err(|p| Divergence::Panic {
            stage: Stage::Engines,
            detail: format!("{target:?} {:?} {}: {}", spec.kernel, spec.sew, panic_msg(&p)),
        })?;
        if res.output != data.expect {
            return Err(Divergence::OutputMismatch {
                stage: Stage::Engines,
                detail: format!(
                    "{target:?} {:?} {} differs from golden ({} vs {} bytes, first diff at {:?})",
                    spec.kernel,
                    spec.sew,
                    res.output.len(),
                    data.expect.len(),
                    first_diff(&res.output, &data.expect),
                ),
            });
        }
        run_invariants(&res, Stage::Engines)?;
    }
    Ok(())
}

/// Stage 3: the multi-tile schedule agrees byte-for-byte with the
/// single-tile schedule (and, for sharded cases, with the unsharded whole
/// kernel), and the batch counters obey the invariants.
fn check_tiles(case: &FuzzCase) -> Result<(), Divergence> {
    let multi = run_batch_checked(&case.spec, case.tiles, Stage::Tiles)?;
    batch_invariants(&multi, Stage::Tiles)?;
    let single = run_batch_checked(&case.spec, 1, Stage::Tiles)?;
    batch_invariants(&single, Stage::Tiles)?;
    if multi.outputs != single.outputs {
        return Err(Divergence::OutputMismatch {
            stage: Stage::Tiles,
            detail: format!(
                "{} tiles vs 1 tile disagree for {:?} ({} vs {} outputs)",
                case.tiles,
                case.spec,
                multi.outputs.len(),
                single.outputs.len(),
            ),
        });
    }
    if case.spec.shard {
        // The reassembled shard output must equal the whole, unsharded
        // kernel computed on one tile.
        let whole_spec = BatchSpec { shard: false, batch: 1, ..case.spec };
        let whole = run_batch_checked(&whole_spec, 1, Stage::Tiles)?;
        if multi.outputs.first() != whole.outputs.first() {
            return Err(Divergence::OutputMismatch {
                stage: Stage::Tiles,
                detail: format!(
                    "sharded {:?} across {} tiles differs from the unsharded whole",
                    case.spec.kernel, case.tiles,
                ),
            });
        }
    }
    Ok(())
}

/// Stage 4: `--timing cycle` and `--timing event` are byte- and
/// counter-identical — including bitwise-equal f64 energies.
fn check_timing(case: &FuzzCase) -> Result<(), Divergence> {
    let run = |mode: TimingMode| {
        clock::with_mode(mode, || run_batch_checked(&case.spec, case.tiles, Stage::Timing))
    };
    let cyc = run(TimingMode::Cycle)?;
    let evt = run(TimingMode::Event)?;
    let mism = |what: &str, a: String, b: String| Divergence::OutputMismatch {
        stage: Stage::Timing,
        detail: format!("cycle vs event disagree on {what}: {a} vs {b} for {:?}", case.spec),
    };
    if cyc.cycles != evt.cycles {
        return Err(mism("cycles", cyc.cycles.to_string(), evt.cycles.to_string()));
    }
    if cyc.outputs != evt.outputs {
        return Err(mism("output bytes", format!("{} outputs", cyc.outputs.len()), format!("{} outputs", evt.outputs.len())));
    }
    let counters = |r: &BatchRunResult| {
        let mut c = vec![r.dma_active_cycles, r.dma_transfers, r.bus_txns, r.contention_cycles];
        c.extend(r.per_tile.iter().map(|t| t.busy_cycles));
        c
    };
    if counters(&cyc) != counters(&evt) {
        return Err(mism("activity counters", format!("{:?}", counters(&cyc)), format!("{:?}", counters(&evt))));
    }
    let bits = |b: &Breakdown| {
        [b.cpu, b.memory, b.nmc_logic, b.interconnect, b.other].map(f64::to_bits)
    };
    if bits(&cyc.energy) != bits(&evt.energy) {
        return Err(mism("energy breakdown", format!("{:?}", cyc.energy), format!("{:?}", evt.energy)));
    }
    Ok(())
}

/// `sched::run_batch` with panics and plan errors folded into divergences.
fn run_batch_checked(spec: &BatchSpec, tiles: u32, stage: Stage) -> Result<BatchRunResult, Divergence> {
    catch_unwind(AssertUnwindSafe(|| sched::run_batch(spec, tiles as usize)))
        .map_err(|p| Divergence::Panic {
            stage,
            detail: format!("{spec:?} on {tiles} tiles: {}", panic_msg(&p)),
        })?
        .map_err(|e| Divergence::Plan { detail: format!("{spec:?} on {tiles} tiles: {e}") })
}

/// Energy + activity invariants of one single-kernel run (§7 anchors).
fn run_invariants(res: &RunResult, stage: Stage) -> Result<(), Divergence> {
    energy_invariants(&res.energy, stage, res.target)?;
    activity_invariants(&res.activity, stage, res.target)
}

/// Invariants of one batch co-simulation.
fn batch_invariants(r: &BatchRunResult, stage: Stage) -> Result<(), Divergence> {
    energy_invariants(&r.energy, stage, r.spec.target)?;
    if r.cycles == 0 {
        return Err(Divergence::ActivityInvariant {
            stage,
            detail: format!("{:?}: zero-cycle schedule", r.spec),
        });
    }
    if r.dma_active_cycles > r.cycles {
        return Err(Divergence::ActivityInvariant {
            stage,
            detail: format!("dma_active {} > makespan {}", r.dma_active_cycles, r.cycles),
        });
    }
    for (i, t) in r.per_tile.iter().enumerate() {
        if t.busy_cycles > r.cycles {
            return Err(Divergence::ActivityInvariant {
                stage,
                detail: format!("tile {i} busy {} > makespan {}", t.busy_cycles, r.cycles),
            });
        }
    }
    if r.outputs.is_empty() {
        return Err(Divergence::OutputMismatch {
            stage,
            detail: format!("{:?}: schedule produced no outputs", r.spec),
        });
    }
    Ok(())
}

fn energy_invariants(b: &Breakdown, stage: Stage, target: Target) -> Result<(), Divergence> {
    let parts = [("cpu", b.cpu), ("memory", b.memory), ("nmc_logic", b.nmc_logic), ("interconnect", b.interconnect), ("other", b.other)];
    for (name, v) in parts {
        if !v.is_finite() || v < 0.0 {
            return Err(Divergence::EnergyInvariant {
                stage,
                detail: format!("{target:?}: {name} = {v} (must be finite and ≥ 0)"),
            });
        }
    }
    let sum: f64 = parts.iter().map(|(_, v)| v).sum();
    if b.total().to_bits() != sum.to_bits() {
        return Err(Divergence::EnergyInvariant {
            stage,
            detail: format!("{target:?}: total {} ≠ Σ components {}", b.total(), sum),
        });
    }
    Ok(())
}

fn activity_invariants(a: &Activity, stage: Stage, target: Target) -> Result<(), Divergence> {
    if a.cycles == 0 {
        return Err(Divergence::ActivityInvariant {
            stage,
            detail: format!("{target:?}: zero-cycle run"),
        });
    }
    if a.cpu_active + a.cpu_sleep != a.cycles {
        return Err(Divergence::ActivityInvariant {
            stage,
            detail: format!(
                "{target:?}: cpu_active {} + cpu_sleep {} ≠ cycles {}",
                a.cpu_active, a.cpu_sleep, a.cycles
            ),
        });
    }
    if a.dma_active > a.cycles {
        return Err(Divergence::ActivityInvariant {
            stage,
            detail: format!("{target:?}: dma_active {} > cycles {}", a.dma_active, a.cycles),
        });
    }
    Ok(())
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn first_diff(a: &[u8], b: &[u8]) -> Option<usize> {
    let at = a.iter().zip(b).position(|(x, y)| x != y);
    at.or_else(|| (a.len() != b.len()).then(|| a.len().min(b.len())))
}

// ---------------------------------------------------------------------------
// Shrinking
// ---------------------------------------------------------------------------

/// Greedily minimize a failing case to a fixpoint. The predicate is "the
/// *original* failing stage still fails" — cheaper and more stable than
/// re-running the whole oracle, and it keeps the shrunk case on the same
/// bug. Moves: empty/delta-debug the instruction keep-lists, force
/// `batch = 1` / `shard = false` / fewer tiles, and halve shape dims
/// (guarded by `Kernel::validate` + `sched::plan` so every candidate is a
/// case the generator could have produced).
pub fn shrink(failure: Failure) -> Failure {
    let _quiet = QuietPanics::install();
    shrink_impl(failure)
}

fn shrink_impl(failure: Failure) -> Failure {
    let stage = failure.divergence.stage();
    let fails = |c: &FuzzCase| check_stage(c, stage).is_err();
    let mut cur = failure.case;
    debug_assert!(fails(&cur), "shrink must start from a failing case");

    loop {
        let before = (cur.kept_insns(), cur.spec, cur.tiles);

        // The scenario axes are independent of the instruction lists, so
        // try the cheapest big cuts first.
        for surface in 0..3 {
            let mut cand = cur.clone();
            *keep_list_mut(&mut cand, surface) = Vec::new();
            if fails(&cand) {
                cur = cand;
            }
        }
        for surface in 0..3 {
            cur = minimize_list(cur, surface, &fails);
        }

        // Scenario shrinks: smaller batch, no sharding, fewer tiles.
        for cand_spec in [
            BatchSpec { batch: 1, ..cur.spec },
            BatchSpec { shard: false, batch: 1, ..cur.spec },
        ] {
            let cand = FuzzCase { spec: cand_spec, ..cur.clone() };
            if plannable(&cand) && fails(&cand) {
                cur = cand;
            }
        }
        for t in [1, cur.tiles / 2] {
            if t >= 1 && t < cur.tiles {
                let cand = FuzzCase { tiles: t, ..cur.clone() };
                if plannable(&cand) && fails(&cand) {
                    cur = cand;
                }
            }
        }

        // Shape shrinks: halve the free dimension while both targets
        // still accept the kernel.
        for k in shrunk_kernels(cur.spec.kernel) {
            if k.validate(cur.spec.target, cur.spec.sew).is_err()
                || k.validate(Target::Cpu, cur.spec.sew).is_err()
            {
                continue;
            }
            let cand = FuzzCase { spec: BatchSpec { kernel: k, ..cur.spec }, ..cur.clone() };
            if plannable(&cand) && fails(&cand) {
                cur = cand;
            }
        }

        if (cur.kept_insns(), cur.spec, cur.tiles) == before {
            break;
        }
    }

    let divergence = check_stage(&cur, stage).expect_err("fixpoint case must still fail");
    Failure { case: cur, divergence }
}

fn plannable(c: &FuzzCase) -> bool {
    catch_unwind(AssertUnwindSafe(|| sched::plan(&c.spec, c.tiles as usize).is_ok())).unwrap_or(false)
}

fn keep_list_mut(c: &mut FuzzCase, surface: usize) -> &mut Vec<u32> {
    match surface {
        0 => &mut c.xvnmc_keep,
        1 => &mut c.xcv_keep,
        _ => &mut c.caesar_keep,
    }
}

/// ddmin-style list minimization: repeatedly try removing contiguous
/// chunks (halving the chunk size down to 1) while the case still fails.
fn minimize_list(mut cur: FuzzCase, surface: usize, fails: &dyn Fn(&FuzzCase) -> bool) -> FuzzCase {
    let mut chunk = keep_list_mut(&mut cur, surface).len() / 2;
    while chunk >= 1 {
        let mut start = 0;
        while start < keep_list_mut(&mut cur, surface).len() {
            let mut cand = cur.clone();
            {
                let list = keep_list_mut(&mut cand, surface);
                let end = (start + chunk).min(list.len());
                list.drain(start..end);
            }
            if fails(&cand) {
                cur = cand; // keep the cut, retry the same start
            } else {
                start += chunk;
            }
        }
        chunk /= 2;
    }
    cur
}

/// Candidate kernels with the free dimension halved (filter size stays —
/// halving it changes the kernel family's contract, not just its size).
/// Callers re-validate against both targets before trying a candidate.
fn shrunk_kernels(k: Kernel) -> Vec<Kernel> {
    match k {
        Kernel::Xor { n } => vec![Kernel::Xor { n: n / 2 }],
        Kernel::Add { n } => vec![Kernel::Add { n: n / 2 }],
        Kernel::Mul { n } => vec![Kernel::Mul { n: n / 2 }],
        Kernel::Matmul { p } => vec![Kernel::Matmul { p: p / 2 }],
        Kernel::Gemm { p } => vec![Kernel::Gemm { p: p / 2 }],
        Kernel::Conv2d { n, f } => vec![Kernel::Conv2d { n: n / 2, f }],
        Kernel::Relu { n } => vec![Kernel::Relu { n: n / 2 }],
        Kernel::LeakyRelu { n } => vec![Kernel::LeakyRelu { n: n / 2 }],
        Kernel::Maxpool { n } => vec![Kernel::Maxpool { n: n / 2 }],
    }
}

// ---------------------------------------------------------------------------
// Repro files
// ---------------------------------------------------------------------------

// The job-spec vocabulary (wire slugs, exact-shape kernel reconstruction,
// flat-JSON field helpers) lives in [`crate::spec`] since the repro format
// became one of its surfaces; re-exported because the helpers debuted here
// and callers still reach for `fuzz::kernel_from` & co.
pub use crate::spec::schemas::FUZZ_REPRO as REPRO_SCHEMA;
pub use crate::spec::{kernel_from, shape_of};

/// Serialize a failing case to the replayable repro format. `divergence`
/// is informational — replay recomputes it from the case. The
/// `(target, family, sew, n, p, f, spec_seed)` block is rendered by
/// [`JobSpec::render_json`] — the one spec serializer.
pub fn to_json(case: &FuzzCase, divergence: &str) -> String {
    let spec = JobSpec {
        target: case.spec.target,
        kernel: case.spec.kernel,
        sew: case.spec.sew,
        seed: case.spec.seed,
    };
    format!(
        "{{\n  \"schema\": \"{REPRO_SCHEMA}\",\n  \"seed\": {},\n  \"max_insns\": {},\n  \"xvnmc_keep\": {},\n  \"xcv_keep\": {},\n  \"caesar_keep\": {},\n  {},\n  \"batch\": {},\n  \"shard\": {},\n  \"tiles\": {},\n  \"divergence\": \"{}\"\n}}\n",
        case.seed,
        case.max_insns,
        json_list(&case.xvnmc_keep),
        json_list(&case.xcv_keep),
        json_list(&case.caesar_keep),
        spec.render_json("\n  ", "spec_seed"),
        case.spec.batch,
        case.spec.shard,
        case.tiles,
        json_escape(divergence),
    )
}

/// Parse a repro file back into the exact case it serialized. A wrong or
/// missing `schema` tag is a typed rejection up front
/// ([`crate::spec::SpecError::Schema`]) — never best-effort parsing of a
/// different format version.
pub fn from_json(s: &str) -> Result<FuzzCase, String> {
    schemas::check(s, schemas::FUZZ_REPRO, true).map_err(|e| e.to_string())?;
    let opt = JsonSpecOptions { seed_key: "spec_seed", default_seed: None, require_dims: true };
    let spec = JobSpec::parse_json(s, &opt).map_err(|e| e.to_string())?;
    Ok(FuzzCase {
        seed: json_u64(s, "seed")?,
        max_insns: json_u64(s, "max_insns")? as u32,
        xvnmc_keep: json_u32_list(s, "xvnmc_keep")?,
        xcv_keep: json_u32_list(s, "xcv_keep")?,
        caesar_keep: json_u32_list(s, "caesar_keep")?,
        spec: BatchSpec {
            target: spec.target,
            kernel: spec.kernel,
            sew: spec.sew,
            seed: spec.seed,
            batch: json_u64(s, "batch")? as u32,
            shard: json_bool(s, "shard")?,
        },
        tiles: json_u64(s, "tiles")? as u32,
    })
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

/// Outcome of one fuzzing run.
#[derive(Debug)]
pub struct FuzzReport {
    /// Cases executed (including the failing one, if any).
    pub cases: u32,
    /// The first failure, already shrunk. `None` = divergence-free run.
    pub failure: Option<Failure>,
}

/// Run `budget` cases derived from `seed`; on the first divergence,
/// shrink it and stop. Panics raised inside simulations are caught (they
/// *are* divergences) and their default stderr backtraces suppressed for
/// the duration of the run.
pub fn run(seed: u64, budget: u32, max_insns: u32) -> FuzzReport {
    let _quiet = QuietPanics::install();
    for i in 0..budget {
        let case_seed = Rng(seed.wrapping_add(i as u64)).next_u64();
        let case = FuzzCase::from_seed(case_seed, max_insns);
        if let Err(divergence) = check(&case) {
            return FuzzReport { cases: i + 1, failure: Some(shrink_impl(Failure { case, divergence })) };
        }
    }
    FuzzReport { cases: budget, failure: None }
}

/// Re-check one previously-serialized case (the `--replay` path).
pub fn replay(case: &FuzzCase) -> Result<(), Divergence> {
    let _quiet = QuietPanics::install();
    check(case)
}

/// Scoped suppression of the default panic hook: expected divergence
/// panics (golden-mismatch asserts under `catch_unwind`) should not spray
/// backtraces over fuzz progress output. Restores the previous hook on
/// drop.
pub(crate) struct QuietPanics {
    prev: Option<Box<dyn Fn(&std::panic::PanicHookInfo<'_>) + Sync + Send>>,
}

impl QuietPanics {
    pub(crate) fn install() -> QuietPanics {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        QuietPanics { prev: Some(prev) }
    }
}

impl Drop for QuietPanics {
    fn drop(&mut self) {
        if let Some(prev) = self.prev.take() {
            std::panic::set_hook(prev);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_are_deterministic_in_the_seed() {
        let a = FuzzCase::from_seed(0xdead_beef, 16);
        let b = FuzzCase::from_seed(0xdead_beef, 16);
        assert_eq!(a, b);
        assert_eq!(a.kept_insns(), 3 * 16);
        // The scenario is always plannable.
        assert!(sched::plan(&a.spec, a.tiles as usize).is_ok());
    }

    #[test]
    fn small_fixed_seed_run_is_divergence_free() {
        let report = run(11, 2, 24);
        assert_eq!(report.cases, 2);
        assert!(
            report.failure.is_none(),
            "unexpected divergence: {}",
            report.failure.as_ref().unwrap().divergence
        );
    }

    #[test]
    fn repro_json_roundtrips() {
        let case = FuzzCase {
            seed: u64::MAX,
            max_insns: 64,
            xvnmc_keep: vec![0, 7, 63],
            xcv_keep: vec![],
            caesar_keep: vec![5],
            spec: BatchSpec {
                target: Target::Caesar,
                kernel: Kernel::Conv2d { n: 16, f: 3 },
                sew: crate::isa::Sew::E16,
                seed: 42,
                batch: 2,
                shard: false,
            },
            tiles: 9,
        };
        let j = to_json(&case, "quote \" backslash \\ newline \n done");
        let back = from_json(&j).expect("repro roundtrip");
        assert_eq!(back, case);
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(from_json("").is_err());
        assert!(from_json("{\"schema\": \"something-else\"}").is_err());
        assert!(from_json("{\"schema\": \"heeperator-fuzz-repro-v1\", \"seed\": true}").is_err());
    }

    #[test]
    fn kernel_from_inverts_shape_of() {
        let kernels = [
            Kernel::Xor { n: 8 },
            Kernel::Add { n: 12 },
            Kernel::Mul { n: 4 },
            Kernel::Matmul { p: 16 },
            Kernel::Gemm { p: 8 },
            Kernel::Conv2d { n: 16, f: 3 },
            Kernel::Relu { n: 32 },
            Kernel::LeakyRelu { n: 32 },
            Kernel::Maxpool { n: 8 },
        ];
        for k in kernels {
            let (n, p, f) = shape_of(k);
            assert_eq!(kernel_from(k.family(), n, p, f), k);
        }
    }

    #[test]
    fn minimize_list_reaches_a_single_element() {
        // Synthetic predicate: the case "fails" iff index 13 survives in
        // the xvnmc list. ddmin must strip everything else.
        let mut case = FuzzCase::from_seed(1, 32);
        case.xvnmc_keep = (0..32).collect();
        let fails = |c: &FuzzCase| c.xvnmc_keep.contains(&13);
        let out = minimize_list(case, 0, &fails);
        assert_eq!(out.xvnmc_keep, vec![13]);
    }
}
