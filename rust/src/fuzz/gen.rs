//! Seeded random generation: the splitmix64 generator plus the
//! random-instruction and random-scenario builders shared by the
//! differential fuzzer ([`crate::fuzz`]) and the property-based tests
//! (`rust/tests/prop_invariants.rs`).
//!
//! Everything here is deterministic in the seed: the same `Rng` state
//! produces the same instruction/scenario stream on every platform, which
//! is what makes `fuzz-repro-<seed>.json` files replayable.

use crate::caesar::isa as cisa;
use crate::isa::rv32::{AluOp, BranchOp, Instr, LoadOp, MulOp, StoreOp};
use crate::isa::xcv::{self, XcvInstr, XcvOp};
use crate::isa::xvnmc::{VInstr, VOp, VSrc};
use crate::isa::{Reg, Sew};
use crate::kernels::{Family, Kernel, Target};
use crate::sched::BatchSpec;

/// Splitmix64: tiny, deterministic, good-enough generator for inputs.
#[derive(Debug, Clone)]
pub struct Rng(pub u64);

impl Rng {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
    pub fn next_u32(&mut self) -> u32 {
        self.next_u64() as u32
    }
    /// Uniform value in `0..n` (n > 0).
    pub fn below(&mut self, n: u32) -> u32 {
        self.next_u32() % n
    }
    /// Random element value (full range of the SEW), sign-extended to i64.
    pub fn elem(&mut self, sew: Sew) -> i64 {
        match sew {
            Sew::E8 => self.next_u32() as u8 as i8 as i64,
            Sew::E16 => self.next_u32() as u16 as i16 as i64,
            Sew::E32 => self.next_u32() as i32 as i64,
        }
    }
}

/// Random GPR index.
pub fn rand_reg(rng: &mut Rng) -> Reg {
    (rng.next_u32() % 32) as Reg
}

/// Random valid RV32IM instruction (every format the decoder accepts).
pub fn rand_rv32_instr(rng: &mut Rng) -> Instr {
    let rd = rand_reg(rng);
    let rs1 = rand_reg(rng);
    let rs2 = rand_reg(rng);
    let imm12 = (rng.next_u32() as i32 % 2048).clamp(-2048, 2047);
    match rng.next_u32() % 10 {
        0 => Instr::Lui { rd, imm: ((rng.next_u32() & 0xfffff) << 12) as i32 },
        1 => Instr::Auipc { rd, imm: ((rng.next_u32() & 0xfffff) << 12) as i32 },
        2 => {
            let ops = [
                AluOp::Add,
                AluOp::Sub,
                AluOp::Sll,
                AluOp::Slt,
                AluOp::Sltu,
                AluOp::Xor,
                AluOp::Srl,
                AluOp::Sra,
                AluOp::Or,
                AluOp::And,
            ];
            Instr::Alu { op: ops[(rng.next_u32() % 10) as usize], rd, rs1, rs2 }
        }
        3 => {
            let ops = [AluOp::Add, AluOp::Slt, AluOp::Sltu, AluOp::Xor, AluOp::Or, AluOp::And];
            Instr::AluImm { op: ops[(rng.next_u32() % 6) as usize], rd, rs1, imm: imm12 }
        }
        4 => {
            let ops = [AluOp::Sll, AluOp::Srl, AluOp::Sra];
            Instr::AluImm {
                op: ops[(rng.next_u32() % 3) as usize],
                rd,
                rs1,
                imm: (rng.next_u32() % 32) as i32,
            }
        }
        5 => {
            let ops = [
                MulOp::Mul,
                MulOp::Mulh,
                MulOp::Mulhsu,
                MulOp::Mulhu,
                MulOp::Div,
                MulOp::Divu,
                MulOp::Rem,
                MulOp::Remu,
            ];
            Instr::MulDiv { op: ops[(rng.next_u32() % 8) as usize], rd, rs1, rs2 }
        }
        6 => {
            let ops = [LoadOp::Lb, LoadOp::Lh, LoadOp::Lw, LoadOp::Lbu, LoadOp::Lhu];
            Instr::Load { op: ops[(rng.next_u32() % 5) as usize], rd, rs1, off: imm12 }
        }
        7 => {
            let ops = [StoreOp::Sb, StoreOp::Sh, StoreOp::Sw];
            Instr::Store { op: ops[(rng.next_u32() % 3) as usize], rs2, rs1, off: imm12 }
        }
        8 => {
            let ops = [
                BranchOp::Beq,
                BranchOp::Bne,
                BranchOp::Blt,
                BranchOp::Bge,
                BranchOp::Bltu,
                BranchOp::Bgeu,
            ];
            Instr::Branch { op: ops[(rng.next_u32() % 6) as usize], rs1, rs2, off: (imm12 / 2) * 2 }
        }
        _ => Instr::Jal { rd, off: (imm12 / 2) * 2 },
    }
}

/// Every xvnmc arithmetic/logic/permutation op (Table II order).
pub const XVNMC_OPS: [VOp; 19] = [
    VOp::Add,
    VOp::Sub,
    VOp::Mul,
    VOp::Macc,
    VOp::And,
    VOp::Or,
    VOp::Xor,
    VOp::Min,
    VOp::Minu,
    VOp::Max,
    VOp::Maxu,
    VOp::Sll,
    VOp::Srl,
    VOp::Sra,
    VOp::Mv,
    VOp::SlideUp,
    VOp::SlideDown,
    VOp::Slide1Up,
    VOp::Slide1Down,
];

/// Random valid xvnmc instruction: mostly arithmetic `VInstr::Op` (direct
/// and indirect addressing, every source kind Table II allows), with a
/// tail of element moves and vsetvl-family config instructions. All
/// immediate fields are pre-masked to their encodable widths so
/// `encode ∘ decode = id` is a true invariant of the generator's output.
pub fn rand_xvnmc_instr(rng: &mut Rng) -> VInstr {
    if rng.below(5) == 0 {
        // Moves + config (the non-Op 20%).
        return match rng.below(5) {
            0 => VInstr::Emvv { vd: rng.below(32) as u8, idx: rand_reg(rng), rs1: rand_reg(rng) },
            1 => VInstr::Emvx { rd: rand_reg(rng), vs2: rng.below(32) as u8, idx: rand_reg(rng) },
            2 => VInstr::VsetVli {
                rd: rand_reg(rng),
                rs1: rand_reg(rng),
                vtype: (rng.next_u32() & 0x7ff) as u16,
            },
            3 => VInstr::VsetIVli {
                rd: rand_reg(rng),
                avl: rng.below(32) as u8,
                vtype: (rng.next_u32() & 0x3ff) as u16,
            },
            _ => VInstr::VsetVl { rd: rand_reg(rng), rs1: rand_reg(rng), rs2: rand_reg(rng) },
        };
    }
    loop {
        let op = XVNMC_OPS[rng.below(XVNMC_OPS.len() as u32) as usize];
        let src = match rng.below(3) {
            0 => VSrc::V(rng.below(32) as u8),
            1 => VSrc::X(rand_reg(rng)),
            _ => VSrc::I((rng.next_u32() as i32 % 16) as i8),
        };
        if !op.allows(src.kind()) {
            continue;
        }
        let indirect = rng.below(2) == 1;
        return VInstr::Op {
            op,
            vd: if indirect { 0 } else { rng.below(32) as u8 },
            vs2: if indirect { 0 } else { rng.below(32) as u8 },
            src,
            indirect,
            idx_gpr: if indirect { rand_reg(rng) } else { 0 },
        };
    }
}

/// Random valid Xcv instruction (resampled until `xcv::valid`).
pub fn rand_xcv_instr(rng: &mut Rng) -> XcvInstr {
    let ops = [XcvOp::SdotSp, XcvOp::Add, XcvOp::Sub, XcvOp::Min, XcvOp::Max, XcvOp::Sra];
    loop {
        let op = ops[rng.below(6) as usize];
        let sew = Sew::ALL[rng.below(3) as usize];
        if !xcv::valid(op, sew) {
            continue;
        }
        return XcvInstr { op, sew, rd: rand_reg(rng), rs1: rand_reg(rng), rs2: rand_reg(rng) };
    }
}

/// Random NM-Caesar micro-op (any op, any in-range bank addresses).
pub fn rand_caesar_microop(rng: &mut Rng) -> cisa::MicroOp {
    cisa::MicroOp {
        op: cisa::Op::ALL[rng.below(cisa::Op::ALL.len() as u32) as usize],
        src1: rng.below(8192) as u16,
        src2: rng.below(8192) as u16,
    }
}

/// Random small kernel shape for `family`, valid on **both** `target` and
/// the CPU (the differential oracle runs every case on both). Shapes stay
/// deliberately small — the fuzzer's value is in crossing many scenarios,
/// not in giant workloads. `None` if no valid shape was found (does not
/// happen for the built-in families, but keeps the contract honest).
pub fn rand_kernel(rng: &mut Rng, family: Family, target: Target, sew: Sew) -> Option<Kernel> {
    // Elements per 32-bit word: the alignment unit of every staging path.
    let unit = 4 / sew.bytes();
    for _ in 0..64 {
        let k = match family {
            Family::Xor => Kernel::Xor { n: unit * (1 + rng.below(64)) },
            Family::Add => Kernel::Add { n: unit * (1 + rng.below(64)) },
            Family::Mul => Kernel::Mul { n: unit * (1 + rng.below(64)) },
            Family::Relu => Kernel::Relu { n: unit * (1 + rng.below(64)) },
            Family::LeakyRelu => Kernel::LeakyRelu { n: unit * (1 + rng.below(64)) },
            Family::Matmul => Kernel::Matmul { p: unit * (1 + rng.below(32)) },
            Family::Gemm => Kernel::Gemm { p: unit * (1 + rng.below(32)) },
            Family::Conv2d => {
                let n = unit * (2 + rng.below(16));
                Kernel::Conv2d { n, f: 1 + rng.below(4.min(n)) }
            }
            Family::Maxpool => Kernel::Maxpool { n: unit.max(2) * (1 + rng.below(16)) },
        };
        if k.validate(target, sew).is_ok() && k.validate(Target::Cpu, sew).is_ok() {
            return Some(k);
        }
    }
    None
}

/// True if the scheduler's column-sharding decomposition supports this
/// family (2-D window kernels span the split and cannot shard).
pub fn shardable(family: Family) -> bool {
    !matches!(family, Family::Conv2d | Family::Maxpool)
}

/// Random batch scenario: an NMC target, a kernel family × SEW × small
/// shape, a batch of 1–3 workloads (or a sharded single workload on the
/// shardable families), and 1–16 tiles. Returns `(spec, tiles)`. The
/// scenario is *plausible*, not guaranteed plannable — callers retry
/// through [`crate::sched::plan`].
pub fn rand_batch_scenario(rng: &mut Rng) -> (BatchSpec, u32) {
    let target = if rng.below(2) == 0 { Target::Caesar } else { Target::Carus };
    let family = Family::ALL[rng.below(Family::ALL.len() as u32) as usize];
    let sew = Sew::ALL[rng.below(3) as usize];
    let kernel = rand_kernel(rng, family, target, sew)
        .unwrap_or(Kernel::Add { n: 64 / sew.bytes() });
    let shard = shardable(family) && rng.below(3) == 0;
    let spec = BatchSpec {
        target,
        kernel,
        sew,
        seed: rng.next_u64(),
        batch: if shard { 1 } else { 1 + rng.below(3) },
        shard,
    };
    (spec, 1 + rng.below(16))
}

/// Random **raw** coalesced-group scenario: unlike [`rand_batch_scenario`]
/// nothing is pre-validated — dims are drawn from a skewed range that
/// includes 0, sub-word odd sizes, and far-over-envelope values, the
/// target may be the host CPU, families may mix within one group, and
/// the tile count may be out of range. The planner's contract under
/// test: *any* such input answers `Ok` or a typed `SchedError`, never a
/// panic — the serve front-end feeds it request-supplied shapes.
pub fn rand_raw_jobs(rng: &mut Rng) -> (Target, Sew, Vec<(Kernel, u64)>, usize) {
    let target = Target::ALL[rng.below(3) as usize];
    let sew = Sew::ALL[rng.below(3) as usize];
    fn raw_dim(rng: &mut Rng) -> u32 {
        match rng.below(4) {
            0 => 0,
            1 => rng.below(8),
            2 => rng.below(512),
            _ => 1 + rng.below(100_000),
        }
    }
    let jobs = (0..rng.below(6))
        .map(|_| {
            let family = Family::ALL[rng.below(Family::ALL.len() as u32) as usize];
            let k = crate::fuzz::kernel_from(family, raw_dim(rng), raw_dim(rng), raw_dim(rng));
            (k, rng.next_u64())
        })
        .collect();
    (target, sew, jobs, rng.below(20) as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::xvnmc;

    #[test]
    fn splitmix_is_deterministic_and_full_period_ish() {
        let mut a = Rng(42);
        let mut b = Rng(42);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
        // No immediate cycle.
        assert_eq!(xs.iter().collect::<std::collections::HashSet<_>>().len(), 16);
    }

    #[test]
    fn generated_instructions_are_always_encodable() {
        let mut rng = Rng(0xfeed);
        for _ in 0..500 {
            let v = rand_xvnmc_instr(&mut rng);
            assert_eq!(xvnmc::decode(xvnmc::encode(&v)), Some(v));
            let x = rand_xcv_instr(&mut rng);
            assert_eq!(xcv::decode(xcv::encode(&x)), Some(x));
            let m = rand_caesar_microop(&mut rng);
            assert_eq!(cisa::decode(cisa::encode(&m)), Some(m));
        }
    }

    #[test]
    fn random_kernels_validate_on_target_and_cpu() {
        let mut rng = Rng(0xbeef);
        for family in Family::ALL {
            for target in [Target::Caesar, Target::Carus] {
                for sew in Sew::ALL {
                    let k = rand_kernel(&mut rng, family, target, sew)
                        .unwrap_or_else(|| panic!("no shape for {family:?} {target:?} {sew}"));
                    assert_eq!(k.validate(target, sew), Ok(()));
                    assert_eq!(k.validate(Target::Cpu, sew), Ok(()));
                    assert_eq!(k.family(), family);
                }
            }
        }
    }

    #[test]
    fn planner_never_panics_on_raw_scenarios() {
        // Satellite of the serve work: the staging paths that used to
        // `expect`/`assert!` must degrade to typed errors on arbitrary
        // request-supplied shapes. Raw scenarios deliberately include
        // zero dims, sub-word sizes, host targets, mixed families, and
        // out-of-range tile counts.
        let mut rng = Rng(0x5eed);
        for _ in 0..400 {
            let (target, sew, jobs, tiles) = rand_raw_jobs(&mut rng);
            let _ = crate::sched::plan_jobs(target, sew, &jobs, tiles);
            if let Some(&(kernel, seed)) = jobs.first() {
                let spec = BatchSpec {
                    target,
                    kernel,
                    sew,
                    seed,
                    batch: jobs.len() as u32,
                    shard: rng.below(2) == 1,
                };
                let _ = crate::sched::plan(&spec, tiles);
            }
        }
    }

    #[test]
    fn scenarios_cover_both_targets_and_shard_modes() {
        let mut rng = Rng(7);
        let (mut caesar, mut carus, mut sharded) = (0, 0, 0);
        for _ in 0..200 {
            let (spec, tiles) = rand_batch_scenario(&mut rng);
            assert!(tiles >= 1 && tiles <= 16);
            assert!(spec.batch >= 1);
            match spec.target {
                Target::Caesar => caesar += 1,
                Target::Carus => carus += 1,
                Target::Cpu => panic!("the CPU is the host, never a scenario target"),
            }
            if spec.shard {
                sharded += 1;
                assert!(shardable(spec.kernel.family()));
                assert_eq!(spec.batch, 1);
            }
        }
        assert!(caesar > 0 && carus > 0 && sharded > 0);
    }
}
