//! Experiment harness: regenerates every table and figure of §IV–V.
//!
//! Each `table*`/`fig*` function produces a [`Report`]: the paper-style
//! text table (printed by the CLI) plus a CSV for plotting. `all()` runs
//! the complete set and writes everything under a results directory —
//! `make tables` / `heeperator all`.
//!
//! Paper-vs-measured tracking: each report embeds the paper's reference
//! values next to the simulated ones, which is what EXPERIMENTS.md records.

pub mod ablations;
pub mod executor;

use crate::apps::anomaly;
use crate::area;
use crate::compare;
use crate::energy::Breakdown;
use crate::isa::Sew;
use crate::kernels::{Family, Kernel, RunResult, Target};
use crate::sweep::SweepSession;
use std::fmt::Write as _;
use std::sync::Arc;

/// One regenerated experiment.
pub struct Report {
    pub id: &'static str,
    pub title: &'static str,
    pub text: String,
    /// (file name, contents) pairs for CSV outputs.
    pub csv: Vec<(String, String)>,
}

impl Report {
    fn new(id: &'static str, title: &'static str) -> Self {
        Report { id, title, text: String::new(), csv: Vec::new() }
    }
}

fn fmt_si(v: f64) -> String {
    if !v.is_finite() {
        return "N/A".into();
    }
    // Scale by magnitude so negative values pick the same unit as their
    // absolute value instead of falling through every threshold and
    // rendering unscaled ("-2.0M", never "-2000000.0").
    let sign = if v < 0.0 { "-" } else { "" };
    let m = v.abs();
    // Thresholds sit at the {:.1} rounding boundary of the next unit so
    // no value ever renders out of notation (999 950 is "1.0M", never
    // "1000.0k").
    if m >= 999.95e6 {
        format!("{sign}{:.1}G", m / 1.0e9)
    } else if m >= 999.95e3 {
        format!("{sign}{:.1}M", m / 1.0e6)
    } else if m >= 999.5 {
        format!("{sign}{:.1}k", m / 1.0e3)
    } else if m >= 99.95 {
        format!("{sign}{m:.0}")
    } else {
        format!("{sign}{m:.1}")
    }
}

// ---------------------------------------------------------------------------
// Table IV + Fig. 7 — physical characteristics (analytical model)
// ---------------------------------------------------------------------------

pub fn table4() -> Report {
    let mut r = Report::new("table4", "Post-layout area and timing (65 nm)");
    let rows = [
        ("SRAM 32 KiB", area::sram32k(), area::timing_sram32k(), (200.0e3, 0.0)),
        ("NM-Caesar", area::caesar(), area::timing_caesar(), (256.0e3, 28.0)),
        ("NM-Carus", area::carus(4), area::timing_carus(), (419.0e3, 110.0)),
    ];
    let t = &mut r.text;
    writeln!(t, "{:<12} {:>12} {:>10} {:>10} {:>9} {:>10} {:>10}", "Macro", "area[um2]", "paper", "overhead", "fmax", "in[ns]", "out[ns]").unwrap();
    let mut csv = String::from("macro,area_um2,paper_area_um2,fmax_mhz,in_ns,out_ns\n");
    for (name, m, tim, (paper_area, paper_ovh)) in rows {
        let a = m.total();
        writeln!(
            t,
            "{:<12} {:>12} {:>10} {:>9.0}% {:>6.0}MHz {:>10.2} {:>10.2}",
            name,
            fmt_si(a),
            fmt_si(paper_area),
            m.overhead_vs_sram32k() * 100.0,
            tim.fmax_mhz,
            tim.input_delay_ns,
            tim.output_delay_ns
        )
        .unwrap();
        let _ = paper_ovh;
        writeln!(csv, "{name},{a:.0},{paper_area:.0},{},{},{}", tim.fmax_mhz, tim.input_delay_ns, tim.output_delay_ns).unwrap();
    }
    r.csv.push(("table4.csv".into(), csv));
    r
}

pub fn fig7() -> Report {
    let mut r = Report::new("fig7", "Post-synthesis area breakdown");
    let mut csv = String::from("macro,component,area_um2\n");
    for m in [area::caesar(), area::carus(4)] {
        writeln!(r.text, "{} (total {}):", m.name, fmt_si(m.total())).unwrap();
        for (part, a) in &m.parts {
            writeln!(r.text, "  {:<24} {:>10}  ({:>4.1} %)", part, fmt_si(*a), a / m.total() * 100.0).unwrap();
            writeln!(csv, "{},{},{:.0}", m.name, part, a).unwrap();
        }
        writeln!(r.text, "  memory fraction: {:.0} %", m.memory_fraction() * 100.0).unwrap();
    }
    r.csv.push(("fig7.csv".into(), csv));
    r
}

// ---------------------------------------------------------------------------
// Table V + Fig. 11 — recurrent kernels
// ---------------------------------------------------------------------------

/// Paper Table V reference values: (family, sew) →
/// (cpu cycles/out, cpu pJ/out, caesar speedup, caesar energy gain,
///  carus speedup, carus energy gain).
pub fn paper_table5(family: Family, sew: Sew) -> (f64, f64, f64, f64, f64, f64) {
    use Family::*;
    use Sew::*;
    match (family, sew) {
        (Xor, E8) => (2.5, 61.0, 5.0, 4.0, 12.7, 6.6),
        (Xor, E16) => (5.0, 124.0, 5.0, 4.1, 12.7, 6.7),
        (Xor, E32) => (10.0, 281.0, 5.0, 4.7, 12.7, 7.5),
        (Add, E8) => (4.0, 99.0, 8.0, 6.4, 20.3, 10.6),
        (Add, E16) => (11.0, 269.0, 11.0, 8.9, 27.9, 14.5),
        (Add, E32) => (10.0, 278.0, 5.0, 4.7, 12.7, 7.5),
        (Mul, E8) => (11.0, 267.0, 22.0, 17.4, 42.0, 23.7),
        (Mul, E16) => (11.0, 285.0, 11.0, 9.5, 27.9, 14.9),
        (Mul, E32) => (10.0, 279.0, 5.0, 4.7, 12.6, 7.1),
        (Matmul, E8) => (112.0, 2880.0, 28.0, 25.0, 53.9, 35.6),
        (Matmul, E16) => (112.0, 3000.0, 14.0, 13.4, 37.1, 21.8),
        (Matmul, E32) => (89.1, 2540.0, 5.6, 5.8, 11.0, 7.1),
        (Gemm, E8) => (73.1, 1910.0, 9.1, 8.1, 31.6, 20.7),
        (Gemm, E16) => (81.2, 2260.0, 6.7, 6.5, 24.1, 14.4),
        (Gemm, E32) => (66.3, 1950.0, 3.3, 3.4, 7.3, 4.8),
        (Conv2d, E8) => (135.0, 3300.0, 16.9, 14.2, 47.5, 29.4),
        (Conv2d, E16) => (133.0, 3400.0, 8.3, 7.6, 29.3, 17.6),
        (Conv2d, E32) => (115.1, 3100.0, 6.4, 6.1, 10.0, 6.3),
        (Relu, E8) => (13.0, 344.0, 26.0, 22.4, 99.6, 59.3),
        (Relu, E16) => (12.0, 338.0, 12.0, 11.6, 46.0, 28.9),
        (Relu, E32) => (10.0, 300.0, 5.0, 5.1, 19.1, 2.8),
        (LeakyRelu, E8) => (12.0, 300.0, 12.0, 10.3, 26.9, 17.3),
        (LeakyRelu, E16) => (11.5, 295.0, 5.7, 5.0, 12.9, 8.6),
        (LeakyRelu, E32) => (9.5, 258.0, 2.4, 2.2, 5.3, 3.7),
        (Maxpool, E8) => (64.6, 1440.0, 3.9, 3.8, 6.3, 6.7),
        (Maxpool, E16) => (65.6, 1500.0, 3.5, 3.5, 5.7, 5.8),
        (Maxpool, E32) => (50.3, 1200.0, 6.1, 5.8, 3.7, 3.5),
    }
}

/// One Table V cell group: measured results for the three targets
/// (shared out of the session cache — Table V and Fig. 11 read the same
/// grid without re-simulating it).
pub struct T5Row {
    pub family: Family,
    pub sew: Sew,
    pub cpu: Arc<RunResult>,
    pub caesar: Arc<RunResult>,
    pub carus: Arc<RunResult>,
}

impl T5Row {
    pub fn caesar_speedup(&self) -> f64 {
        self.cpu.cycles_per_output() / self.caesar.cycles_per_output()
    }
    pub fn carus_speedup(&self) -> f64 {
        self.cpu.cycles_per_output() / self.carus.cycles_per_output()
    }
    pub fn caesar_egain(&self) -> f64 {
        self.cpu.energy_per_output_pj() / self.caesar.energy_per_output_pj()
    }
    pub fn carus_egain(&self) -> f64 {
        self.cpu.energy_per_output_pj() / self.carus.energy_per_output_pj()
    }
}

/// Run the full Table V grid through `session`. `quick` shrinks workloads
/// (CI-friendly). Every report that needs the grid calls this with the
/// shared session; the 81 points are simulated at most once per
/// invocation.
pub fn run_table5(session: &SweepSession, quick: bool) -> Vec<T5Row> {
    let mut rows = Vec::new();
    for family in Family::ALL {
        for sew in Sew::ALL {
            let shrink = |k: Kernel| -> Kernel {
                if !quick {
                    return k;
                }
                match k {
                    Kernel::Xor { n } => Kernel::Xor { n: n / 4 },
                    Kernel::Add { n } => Kernel::Add { n: n / 4 },
                    Kernel::Mul { n } => Kernel::Mul { n: n / 4 },
                    Kernel::Matmul { p } => Kernel::Matmul { p: p / 4 },
                    Kernel::Gemm { p } => Kernel::Gemm { p: p / 4 },
                    Kernel::Conv2d { n, f } => Kernel::Conv2d { n: n / 4, f },
                    Kernel::Relu { n } => Kernel::Relu { n: n / 4 },
                    Kernel::LeakyRelu { n } => Kernel::LeakyRelu { n: n / 4 },
                    Kernel::Maxpool { n } => Kernel::Maxpool { n: n / 4 },
                }
            };
            let point = |target: Target| {
                session.run(target, shrink(Kernel::paper_default(family, target, sew)), sew, 5)
            };
            let (cpu, caesar, carus) =
                (point(Target::Cpu), point(Target::Caesar), point(Target::Carus));
            rows.push(T5Row { family, sew, cpu, caesar, carus });
        }
    }
    rows
}

pub fn table5(rows: &[T5Row]) -> Report {
    let mut r = Report::new(
        "table5",
        "System-level throughput and energy improvement vs CPU-only (Table V)",
    );
    let t = &mut r.text;
    writeln!(
        t,
        "{:<26} {:>6} | {:>9} {:>9} | {:>8} {:>8} | {:>8} {:>8} |  paper: czr/carus speedup",
        "kernel", "width", "cpu c/out", "cpu pJ/out", "czr spd", "czr eng", "carus spd", "carus eng"
    )
    .unwrap();
    let mut csv = String::from(
        "family,sew,cpu_cpo,cpu_pjo,caesar_speedup,caesar_egain,carus_speedup,carus_egain,paper_caesar_speedup,paper_carus_speedup\n",
    );
    for row in rows {
        let p = paper_table5(row.family, row.sew);
        writeln!(
            t,
            "{:<26} {:>6} | {:>9.1} {:>9.0} | {:>7.1}x {:>7.1}x | {:>7.1}x {:>7.1}x |  {:>5.1}x / {:>5.1}x",
            row.family.name(),
            format!("{}", row.sew),
            row.cpu.cycles_per_output(),
            row.cpu.energy_per_output_pj(),
            row.caesar_speedup(),
            row.caesar_egain(),
            row.carus_speedup(),
            row.carus_egain(),
            p.2,
            p.4,
        )
        .unwrap();
        writeln!(
            csv,
            "{:?},{},{:.2},{:.1},{:.2},{:.2},{:.2},{:.2},{},{}",
            row.family,
            row.sew.bits(),
            row.cpu.cycles_per_output(),
            row.cpu.energy_per_output_pj(),
            row.caesar_speedup(),
            row.caesar_egain(),
            row.carus_speedup(),
            row.carus_egain(),
            p.2,
            p.4
        )
        .unwrap();
    }
    r.csv.push(("table5.csv".into(), csv));
    r
}

pub fn fig11(rows: &[T5Row]) -> Report {
    let mut r = Report::new("fig11", "Energy-efficiency gain vs CPU-only (Fig. 11)");
    let mut csv = String::from("family,sew,caesar_gain,carus_gain\n");
    for row in rows {
        writeln!(
            r.text,
            "{:<26} {:>6}:  NM-Caesar {:>6.1}x   NM-Carus {:>6.1}x",
            row.family.name(),
            format!("{}", row.sew),
            row.caesar_egain(),
            row.carus_egain()
        )
        .unwrap();
        writeln!(csv, "{:?},{},{:.2},{:.2}", row.family, row.sew.bits(), row.caesar_egain(), row.carus_egain()).unwrap();
    }
    r.csv.push(("fig11.csv".into(), csv));
    r
}

// ---------------------------------------------------------------------------
// Fig. 12 — matmul scaling
// ---------------------------------------------------------------------------

pub fn fig12(session: &SweepSession, quick: bool) -> Report {
    let mut r = Report::new("fig12", "Matmul throughput/energy scaling (Fig. 12)");
    let mut csv = String::from("target,sew,p,outputs_per_cycle,pj_per_output\n");
    let ps: &[u32] = if quick { &[8, 32, 128] } else { &[8, 16, 32, 64, 128, 256, 512, 1024] };
    writeln!(r.text, "{:<10} {:>6} {:>6} {:>12} {:>12}", "target", "width", "P", "out/cycle", "pJ/out").unwrap();
    for sew in Sew::ALL {
        let pmax = 1024 / sew.bytes();
        for &p in ps.iter().filter(|&&p| p <= pmax) {
            for target in [Target::Cpu, Target::Caesar, Target::Carus] {
                // The paper plots the CPU line only for 32-bit (flat).
                if target == Target::Cpu && sew != Sew::E32 {
                    continue;
                }
                let res = session.run(target, Kernel::Matmul { p }, sew, 6);
                let opc = res.outputs as f64 / res.cycles as f64;
                writeln!(
                    r.text,
                    "{:<10} {:>6} {:>6} {:>12.3} {:>12.1}",
                    format!("{target:?}"),
                    format!("{sew}"),
                    p,
                    opc,
                    res.energy_per_output_pj()
                )
                .unwrap();
                writeln!(csv, "{:?},{},{},{:.4},{:.1}", target, sew.bits(), p, opc, res.energy_per_output_pj()).unwrap();
            }
        }
    }
    writeln!(r.text, "paper saturation (8-bit): NM-Carus 0.48 out/cycle @ 66 pJ/out; NM-Caesar 0.25 out/cycle @ 175 pJ/out").unwrap();
    r.csv.push(("fig12.csv".into(), csv));
    r
}

// ---------------------------------------------------------------------------
// Fig. 13 — power breakdown (2D convolution)
// ---------------------------------------------------------------------------

pub fn fig13(session: &SweepSession) -> Report {
    let mut r = Report::new("fig13", "Average power breakdown, 2D conv (Fig. 13)");
    let mut csv = String::from("target,sew,cpu_mw,memory_mw,nmc_mw,interconnect_mw,other_mw,total_mw\n");
    writeln!(
        r.text,
        "{:<10} {:>6} | {:>8} {:>8} {:>8} {:>8} {:>8} | {:>8}",
        "target", "width", "CPU", "memory", "NMC", "bus+DMA", "other", "total[mW]"
    )
    .unwrap();
    for sew in [Sew::E8, Sew::E32] {
        for target in [Target::Cpu, Target::Caesar, Target::Carus] {
            let kernel = Kernel::paper_default(Family::Conv2d, target, sew);
            let res = session.run(target, kernel, sew, 13);
            let b: Breakdown = res.energy;
            let cyc = res.cycles;
            let mw = |x: f64| x / (cyc as f64 * crate::energy::params::CYCLE_NS);
            writeln!(
                r.text,
                "{:<10} {:>6} | {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2} | {:>8.2}",
                format!("{target:?}"),
                format!("{sew}"),
                mw(b.cpu),
                mw(b.memory),
                mw(b.nmc_logic),
                mw(b.interconnect),
                mw(b.other),
                b.avg_power_mw(cyc)
            )
            .unwrap();
            writeln!(
                csv,
                "{:?},{},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3}",
                target,
                sew.bits(),
                mw(b.cpu),
                mw(b.memory),
                mw(b.nmc_logic),
                mw(b.interconnect),
                mw(b.other),
                b.avg_power_mw(cyc)
            )
            .unwrap();
        }
    }
    writeln!(r.text, "paper: memory ≈ CPU in the CPU case; ≈70 % memory for NM-Caesar (half = µop stream); VRF ≈ 60 % for NM-Carus").unwrap();
    r.csv.push(("fig13.csv".into(), csv));
    r
}

// ---------------------------------------------------------------------------
// Table VI — Anomaly-Detection application
// ---------------------------------------------------------------------------

pub fn table6(session: &SweepSession) -> Report {
    let mut r = Report::new("table6", "Anomaly Detection end-to-end (Table VI)");
    let single = session.anomaly(Target::Cpu, 2);
    let dual = anomaly::scale_multicore(&single, 2);
    let quad = anomaly::scale_multicore(&single, 4);
    let caesar = session.anomaly(Target::Caesar, 2);
    let carus = session.anomaly(Target::Carus, 2);

    let areas = [
        area::system_cpu_cluster(1),
        area::system_cpu_cluster(2),
        area::system_cpu_cluster(4),
        area::system_nmc(&area::caesar()),
        area::system_nmc(&area::carus(4)),
    ];
    // Paper reference: cycles ratio, energy ratio, area ratio vs 1-core.
    let paper = [
        (1.0, 1.0, 1.0),
        (2.0, 1.37, 1.43),
        (4.0, 1.67, 2.29),
        (1.29, 1.20, 0.90),
        (3.55, 2.36, 1.36),
    ];
    let rows = [single.as_ref(), &dual, &quad, caesar.as_ref(), carus.as_ref()];
    let t = &mut r.text;
    writeln!(
        t,
        "{:<22} {:>10} {:>9} {:>10} {:>9} {:>10} {:>8} | paper (spd, egain, area)",
        "config", "cycles", "speedup", "energy[uJ]", "egain", "area[um2]", "arearat"
    )
    .unwrap();
    let mut csv =
        String::from("config,cycles,speedup,energy_uj,energy_gain,area_um2,area_ratio,paper_speedup,paper_egain,paper_area\n");
    for (i, res) in rows.iter().enumerate() {
        let spd = single.cycles as f64 / res.cycles as f64;
        let eg = single.energy_uj / res.energy_uj;
        let ar = areas[i] / areas[0];
        writeln!(
            t,
            "{:<22} {:>10} {:>8.2}x {:>10.2} {:>8.2}x {:>10} {:>7.2}x | {:>5.2}x {:>5.2}x {:>5.2}x",
            res.name,
            res.cycles,
            spd,
            res.energy_uj,
            eg,
            fmt_si(areas[i]),
            ar,
            paper[i].0,
            paper[i].1,
            paper[i].2
        )
        .unwrap();
        writeln!(
            csv,
            "{},{},{:.3},{:.3},{:.3},{:.0},{:.3},{},{},{}",
            res.name, res.cycles, spd, res.energy_uj, eg, areas[i], ar, paper[i].0, paper[i].1, paper[i].2
        )
        .unwrap();
    }
    r.csv.push(("table6.csv".into(), csv));
    r
}

// ---------------------------------------------------------------------------
// Tables VII and VIII — state of the art
// ---------------------------------------------------------------------------

pub fn table7() -> Report {
    let mut r = Report::new("table7", "Comparison with state-of-the-art CIM (Table VII)");
    let mut rows = compare::comparators();
    rows.push(compare::caesar_row());
    rows.push(compare::carus_row(4));
    let t = &mut r.text;
    writeln!(
        t,
        "{:<24} {:<8} {:>10} {:>8} {:>10} {:>10} {:>12}",
        "design", "type", "area[um2]", "f[MHz]", "GOPS", "GOPS/W", "GOPS/mm2"
    )
    .unwrap();
    let mut csv = String::from("design,type,area_um2,freq_mhz,peak_gops,gops_per_w,gops_per_mm2\n");
    for row in &rows {
        writeln!(
            t,
            "{:<24} {:<8} {:>10} {:>8.0} {:>10.2} {:>10.1} {:>12.1}",
            row.name,
            row.cim_type,
            fmt_si(row.area_um2),
            row.freq_mhz,
            row.peak_gops,
            row.gops_per_w,
            row.gops_per_mm2
        )
        .unwrap();
        writeln!(
            csv,
            "{},{},{:.0},{},{},{:.1},{:.1}",
            row.name, row.cim_type, row.area_um2, row.freq_mhz, row.peak_gops, row.gops_per_w, row.gops_per_mm2
        )
        .unwrap();
    }
    writeln!(t, "paper: NM-Caesar 1.32 GOPS / 200.3 GOPS/W; NM-Carus 2.64 GOPS / 306.7 GOPS/W").unwrap();
    writeln!(t, "note: our GOPS/W uses the system-calibrated energy model; see EXPERIMENTS.md for the deviation discussion").unwrap();
    r.csv.push(("table7.csv".into(), csv));
    r
}

pub fn table8() -> Report {
    let mut r = Report::new("table8", "Peak matmul comparison (Table VIII)");
    let mut rows = compare::table8_comparators();
    rows.push(compare::table8_caesar());
    rows.push(compare::table8_carus(4));
    let t = &mut r.text;
    writeln!(
        t,
        "{:<24} | {:>9} {:>9} {:>9} | {:>8} {:>8} {:>8}",
        "design (A[10,10]xB[10,p])", "cyc e8", "cyc e16", "cyc e32", "pJ/MAC8", "pJ/MAC16", "pJ/MAC32"
    )
    .unwrap();
    let mut csv = String::from("design,cycles_e8,cycles_e16,cycles_e32,pj_mac_e8,pj_mac_e16,pj_mac_e32\n");
    for row in &rows {
        writeln!(
            t,
            "{:<24} | {:>9} {:>9} {:>9} | {:>8.1} {:>8.1} {:>8.1}",
            row.name,
            fmt_si(row.cycles[0]),
            fmt_si(row.cycles[1]),
            fmt_si(row.cycles[2]),
            row.pj_per_mac[0],
            row.pj_per_mac[1],
            row.pj_per_mac[2]
        )
        .unwrap();
        writeln!(
            csv,
            "{},{:.0},{:.0},{:.0},{:.2},{:.2},{:.2}",
            row.name, row.cycles[0], row.cycles[1], row.cycles[2], row.pj_per_mac[0], row.pj_per_mac[1], row.pj_per_mac[2]
        )
        .unwrap();
    }
    writeln!(t, "paper NM-Caesar: 51.2k cycles (all widths); NM-Carus: 26.6k/19.5k/26.0k cycles, 6.8/12.0/31.2 pJ/MAC").unwrap();
    r.csv.push(("table8.csv".into(), csv));
    r
}

/// The full report set as independent thunks, in paper order, all
/// draining their simulations through one shared [`SweepSession`]. Table V
/// and Fig. 11 are separate jobs that read the same 81-point grid — the
/// session guarantees the grid is simulated at most once regardless of
/// which job reaches a point first (a concurrent reader blocks on that
/// point only, not the whole grid).
fn report_jobs(session: &Arc<SweepSession>, quick: bool) -> Vec<executor::Job<Vec<Report>>> {
    let s5 = Arc::clone(session);
    let s11 = Arc::clone(session);
    let s12 = Arc::clone(session);
    let s13 = Arc::clone(session);
    let s6 = Arc::clone(session);
    let sab = Arc::clone(session);
    vec![
        Box::new(|| vec![table4()]),
        Box::new(|| vec![fig7()]),
        Box::new(move || vec![table5(&run_table5(&s5, quick))]),
        Box::new(move || vec![fig11(&run_table5(&s11, quick))]),
        Box::new(move || vec![fig12(&s12, quick)]),
        Box::new(move || vec![fig13(&s13)]),
        Box::new(move || vec![table6(&s6)]),
        Box::new(|| vec![table7()]),
        Box::new(|| vec![table8()]),
        Box::new(|| vec![ablations::lane_scaling()]),
        Box::new(|| vec![ablations::issue_strategy()]),
        Box::new(|| vec![ablations::bank_placement()]),
        Box::new(move || vec![ablations::scoreboard_policy(&sab)]),
    ]
}

/// Run everything on `jobs` worker threads; returns the reports in paper
/// order. Output is byte-identical for every `jobs` value — the executor
/// collects results in submission order, each report renders pure
/// functions of its simulation results, and the shared session hands
/// every consumer of a grid point the same memoized result.
pub fn all_with_jobs(quick: bool, jobs: usize) -> Vec<Report> {
    let session = Arc::new(SweepSession::new());
    executor::run_ordered(report_jobs(&session, quick), jobs)
        .into_iter()
        .flatten()
        .collect()
}

/// Run everything with one worker per available core.
pub fn all(quick: bool) -> Vec<Report> {
    all_with_jobs(quick, executor::default_jobs())
}

// ---------------------------------------------------------------------------
// `heeperator sweep` — arbitrary scenario points as a first-class report
// ---------------------------------------------------------------------------

/// Run an arbitrary list of `(target, kernel, sew)` scenario points
/// through `session` and render them as one report — the engine behind
/// `heeperator sweep`, where non-paper shapes become first-class
/// workloads.
pub fn sweep_report(
    session: &SweepSession,
    points: &[(Target, Kernel, Sew)],
    seed: u64,
) -> Report {
    let mut r = Report::new("sweep", "Custom scenario sweep");
    writeln!(
        r.text,
        "{:<12} {:<26} {:>6} {:>12} {:>10} {:>10} {:>10}",
        "target", "kernel", "width", "cycles", "c/out", "pJ/out", "mW"
    )
    .unwrap();
    let mut csv = String::from(
        "target,family,sew,seed,n,p,f,cycles,outputs,cycles_per_output,pj_per_output,avg_power_mw\n",
    );
    for &(target, kernel, sew) in points {
        let res = session.run(target, kernel, sew, seed);
        // Free dimensions as separate CSV columns (the kernel debug form
        // contains commas); absent dimensions stay empty.
        let (n, p, f) = match kernel {
            Kernel::Xor { n }
            | Kernel::Add { n }
            | Kernel::Mul { n }
            | Kernel::Relu { n }
            | Kernel::LeakyRelu { n }
            | Kernel::Maxpool { n } => (Some(n), None, None),
            Kernel::Matmul { p } | Kernel::Gemm { p } => (None, Some(p), None),
            Kernel::Conv2d { n, f } => (Some(n), None, Some(f)),
        };
        let dim = |d: Option<u32>| d.map(|v| v.to_string()).unwrap_or_default();
        writeln!(
            r.text,
            "{:<12} {:<26} {:>6} {:>12} {:>10.2} {:>10.1} {:>10.2}",
            format!("{target:?}"),
            format!("{kernel:?}"),
            format!("{sew}"),
            res.cycles,
            res.cycles_per_output(),
            res.energy_per_output_pj(),
            res.avg_power_mw()
        )
        .unwrap();
        writeln!(
            csv,
            "{:?},{:?},{},{},{},{},{},{},{},{:.4},{:.2},{:.3}",
            target,
            kernel.family(),
            sew.bits(),
            seed,
            dim(n),
            dim(p),
            dim(f),
            res.cycles,
            res.outputs,
            res.cycles_per_output(),
            res.energy_per_output_pj(),
            res.avg_power_mw()
        )
        .unwrap();
    }
    writeln!(
        r.text,
        "({} points, {} simulations — repeated points served from the session cache)",
        points.len(),
        session.simulations()
    )
    .unwrap();
    r.csv.push(("sweep.csv".into(), csv));
    r
}

// ---------------------------------------------------------------------------
// `heeperator scale` — multi-tile scaling curves
// ---------------------------------------------------------------------------

/// One machine-readable point of a scaling curve (the `BENCH_6.json`
/// schema of the CI perf-smoke job: simulated cycles + wall time).
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub tiles: u32,
    pub cycles: u64,
    pub wall_ms: f64,
    /// Simulator wall-clock throughput: simulated cycles per host second
    /// (`cycles / wall_ms`). Machine-dependent — informational, like
    /// `wall_ms` — but it is the number the event-driven timing core is
    /// judged on, so the JSON summary carries it per point.
    pub sim_cycles_per_s: f64,
    pub speedup: f64,
    pub mean_utilization: f64,
    pub contention_cycles: u64,
    pub energy_uj: f64,
}

/// Sweep a [`crate::sched::BatchSpec`] over `tile_counts` (fanned out over
/// `jobs` workers, deduplicated through `session`) and render the
/// scaling-curve report: aggregate speedup and energy vs tile count,
/// per-tile utilization, amortized DMA staging, and bus contention.
///
/// Every tile count is asserted byte-identical to the first (single-tile
/// reference) run before the report renders — the scheduler cannot trade
/// correctness for speedup.
pub fn scale_report(
    session: &Arc<SweepSession>,
    spec: crate::sched::BatchSpec,
    tile_counts: &[u32],
    jobs: usize,
) -> Result<(Report, Vec<ScalePoint>), String> {
    type ScaleJobOut = (u32, Result<(Arc<crate::sched::BatchRunResult>, f64), String>);
    if tile_counts.is_empty() {
        return Err("no tile counts given (use --tiles 1,2,4)".to_string());
    }
    let mut jlist: Vec<executor::Job<ScaleJobOut>> = Vec::new();
    for &t in tile_counts {
        let session = Arc::clone(session);
        jlist.push(Box::new(move || {
            let t0 = std::time::Instant::now();
            let r = session
                .scale(&spec, t)
                .map(|res| (res, t0.elapsed().as_secs_f64() * 1e3));
            (t, r)
        }));
    }
    let mut runs = Vec::with_capacity(tile_counts.len());
    for (t, r) in executor::run_ordered(jlist, jobs) {
        let (res, wall) = r.map_err(|e| format!("scale x{t}: {e}"))?;
        runs.push((t, res, wall));
    }
    // Byte-identity across the whole curve (outputs of cached points were
    // already asserted against the golden reference at run time).
    let (first, rest) = runs.split_first().expect("at least one tile count");
    for (t, res, _) in rest {
        assert_eq!(
            res.outputs, first.1.outputs,
            "{t}-tile schedule output diverged from the {}-tile reference",
            first.0
        );
    }
    // Speedups are reported against the 1-tile run when present, else the
    // first listed count.
    let base = runs
        .iter()
        .find(|(t, ..)| *t == 1)
        .map(|(_, r, _)| Arc::clone(r))
        .unwrap_or_else(|| Arc::clone(&runs[0].1));

    let mut r = Report::new("scale", "Multi-tile batch scaling");
    let mode = if spec.shard { "shard" } else { "batch" };
    writeln!(
        r.text,
        "{:?} {:?} {} — {} mode, {} workload(s), seed {}",
        spec.target,
        spec.kernel,
        spec.sew,
        mode,
        first.1.outputs.len(),
        spec.seed
    )
    .unwrap();
    writeln!(
        r.text,
        "{:<6} {:>12} {:>8} {:>7} {:>22} {:>10} {:>8} {:>11} {:>10}",
        "tiles", "cycles", "speedup", "util", "per-tile util", "dma-act", "dma-tx", "contention", "uJ"
    )
    .unwrap();
    // No wall-clock column: report text and CSV stay byte-identical for
    // every `--jobs` value (wall times live in the JSON summary only).
    let mut csv = String::from(
        "tiles,cycles,speedup,mean_utilization,dma_active_cycles,dma_transfers,bus_txns,contention_cycles,energy_pj\n",
    );
    let mut points = Vec::with_capacity(runs.len());
    for (t, res, wall) in &runs {
        let speedup = res.speedup_vs(&base);
        let utils: Vec<String> = (0..res.per_tile.len())
            .map(|i| format!("{:.0}%", 100.0 * res.utilization(i)))
            .collect();
        let energy_uj = res.energy.total() / 1e6;
        writeln!(
            r.text,
            "{:<6} {:>12} {:>7.2}x {:>6.0}% {:>22} {:>10} {:>8} {:>11} {:>10.2}",
            t,
            res.cycles,
            speedup,
            100.0 * res.mean_utilization(),
            utils.join(" "),
            res.dma_active_cycles,
            res.dma_transfers,
            res.contention_cycles,
            energy_uj
        )
        .unwrap();
        writeln!(
            csv,
            "{},{},{:.4},{:.4},{},{},{},{},{:.1}",
            t,
            res.cycles,
            speedup,
            res.mean_utilization(),
            res.dma_active_cycles,
            res.dma_transfers,
            res.bus_txns,
            res.contention_cycles,
            res.energy.total()
        )
        .unwrap();
        points.push(ScalePoint {
            tiles: *t,
            cycles: res.cycles,
            wall_ms: *wall,
            // Guard the cached-run corner (a memoized point can report a
            // near-zero wall time) so the JSON never carries `inf`.
            sim_cycles_per_s: res.cycles as f64 / (*wall / 1e3).max(1e-9),
            speedup,
            mean_utilization: res.mean_utilization(),
            contention_cycles: res.contention_cycles,
            energy_uj,
        });
    }
    writeln!(
        r.text,
        "(outputs byte-identical across all {} tile configurations)",
        runs.len()
    )
    .unwrap();
    r.csv.push(("scale.csv".into(), csv));
    Ok((r, points))
}

// ---------------------------------------------------------------------------
// heeperator model — multi-layer graph pipeline report
// ---------------------------------------------------------------------------

/// Render a model run pair — the resident-tensor execution next to the
/// same schedule forced through host staging — with the per-layer cycle
/// breakdown and the DMA cycles residency saved. Both runs were already
/// asserted byte-identical to the CPU-golden chain by the executor.
pub fn model_report(
    sch: &crate::graph::Schedule,
    resident: &crate::sched::pipeline::ModelRunResult,
    staged: &crate::sched::pipeline::ModelRunResult,
) -> Report {
    let mut r = Report::new("model", "Multi-layer graph pipeline on NM-Carus tiles");
    let t = &mut r.text;
    writeln!(
        t,
        "graph {} — {} {} tile(s), {} pipeline, {} item(s), seed {}",
        sch.graph.spec_string(),
        sch.graph.sew,
        sch.tiles,
        sch.pipeline.name(),
        resident.items,
        sch.graph.seed
    )
    .unwrap();
    writeln!(
        t,
        "{:<6} {:<10} {:<9} {:>12} {:>10} {:>7}",
        "layer", "kernel", "boundary", "cycles", "dma-act", "dma-tx"
    )
    .unwrap();
    for (i, l) in resident.layers.iter().enumerate() {
        writeln!(
            t,
            "{:<6} {:<10} {:<9} {:>12} {:>10} {:>7}",
            i,
            crate::spec::family_slug(l.kernel.family()),
            l.boundary.name(),
            l.cycles,
            l.dma_active_cycles,
            l.dma_transfers
        )
        .unwrap();
    }
    writeln!(t, "{:<15} {:>12} {:>12} {:>12}", "", "resident", "staged", "saved").unwrap();
    writeln!(
        t,
        "{:<15} {:>12} {:>12} {:>12}",
        "cycles",
        resident.cycles,
        staged.cycles,
        staged.cycles.saturating_sub(resident.cycles)
    )
    .unwrap();
    writeln!(
        t,
        "{:<15} {:>12} {:>12} {:>12}",
        "dma active",
        resident.dma_active_cycles,
        staged.dma_active_cycles,
        staged.dma_active_cycles.saturating_sub(resident.dma_active_cycles)
    )
    .unwrap();
    writeln!(
        t,
        "{:<15} {:>12} {:>12} {:>12}",
        "dma transfers",
        resident.dma_transfers,
        staged.dma_transfers,
        staged.dma_transfers.saturating_sub(resident.dma_transfers)
    )
    .unwrap();
    writeln!(
        t,
        "{:<15} {:>12.2} {:>12.2}",
        "energy uJ",
        resident.energy.total() / 1e6,
        staged.energy.total() / 1e6
    )
    .unwrap();
    writeln!(
        t,
        "boundaries: {} resident + {} staged (forced-staged run: {} staged); outputs \
         byte-identical to the CPU-golden chain in both runs",
        resident.resident_boundaries,
        resident.staged_boundaries,
        staged.staged_boundaries
    )
    .unwrap();

    let mut csv =
        String::from("layer,kernel,boundary,cycles,dma_active_cycles,dma_transfers\n");
    for (i, l) in resident.layers.iter().enumerate() {
        writeln!(
            csv,
            "{i},{},{},{},{},{}",
            crate::spec::family_slug(l.kernel.family()),
            l.boundary.name(),
            l.cycles,
            l.dma_active_cycles,
            l.dma_transfers
        )
        .unwrap();
    }
    r.csv.push(("model.csv".into(), csv));
    r
}

// ---------------------------------------------------------------------------
// heeperator serve — service latency / utilization report
// ---------------------------------------------------------------------------

/// Render a serve run's statistics: latency percentiles, queue behavior,
/// batch-size histogram, and per-tile utilization — the human-readable
/// companion of [`crate::serve::summary_json`] (which carries the same
/// numbers machine-readably for CI).
pub fn serve_report(
    stats: &crate::serve::ServeStats,
    cfg: &crate::serve::ServeConfig,
    trace: &str,
    seed: u64,
) -> Report {
    let mut r = Report::new("serve", "Batch-inference service (seeded load selftest)");
    let t = &mut r.text;
    writeln!(
        t,
        "trace {trace}, seed {seed} — {} tile(s), queue cap {}, max batch {}, linger {} cycles",
        cfg.tiles, cfg.queue_cap, cfg.max_batch, cfg.linger_cycles
    )
    .unwrap();
    writeln!(
        t,
        "requests {:>6}   completed {:>6}   rejected {:>5}   errored {:>5}   batches {:>5}",
        stats.requests, stats.completed, stats.rejected, stats.errored, stats.batches
    )
    .unwrap();
    writeln!(
        t,
        "latency[cyc]   p50 {:>8}   p95 {:>8}   p99 {:>8}   max {:>8}",
        fmt_si(stats.latency_percentile(0.50) as f64),
        fmt_si(stats.latency_percentile(0.95) as f64),
        fmt_si(stats.latency_percentile(0.99) as f64),
        fmt_si(stats.latency_max() as f64)
    )
    .unwrap();
    writeln!(
        t,
        "queue depth    max {:>8}   mean {:>7.2}   mean batch {:>5.2}   sim cycles {:>9}",
        stats.queue_depth_max(),
        stats.queue_depth_mean(),
        stats.mean_batch_size(),
        fmt_si(stats.sim_cycles as f64)
    )
    .unwrap();
    let utils: Vec<String> =
        (0..cfg.tiles).map(|i| format!("{:.0}%", 100.0 * stats.utilization(i))).collect();
    writeln!(t, "per-tile util  {}", utils.join(" ")).unwrap();
    let hist: Vec<String> = stats
        .batch_size_histogram(cfg.max_batch)
        .iter()
        .enumerate()
        .map(|(i, c)| format!("{}:{c}", i + 1))
        .collect();
    writeln!(t, "batch sizes    {}", hist.join(" ")).unwrap();
    // Wall-clock throughput exists only on the live path; the
    // virtual-clock paths measure simulated cycles instead.
    if stats.wall_ms > 0.0 {
        writeln!(
            t,
            "throughput     {:>8.1} req/s over {:>8.1} ms wall  ({} worker(s), conn cap {})",
            stats.req_per_s(),
            stats.wall_ms,
            cfg.workers,
            cfg.conns
        )
        .unwrap();
    }

    let mut csv = String::from("metric,value\n");
    for (k, v) in [
        ("requests", stats.requests as f64),
        ("completed", stats.completed as f64),
        ("rejected", stats.rejected as f64),
        ("errored", stats.errored as f64),
        ("batches", stats.batches as f64),
        ("sim_cycles", stats.sim_cycles as f64),
        ("p50_latency_cycles", stats.latency_percentile(0.50) as f64),
        ("p95_latency_cycles", stats.latency_percentile(0.95) as f64),
        ("p99_latency_cycles", stats.latency_percentile(0.99) as f64),
        ("max_latency_cycles", stats.latency_max() as f64),
        ("mean_batch_size", stats.mean_batch_size()),
        ("queue_depth_max", stats.queue_depth_max() as f64),
        ("queue_depth_mean", stats.queue_depth_mean()),
        ("wall_ms", stats.wall_ms),
        ("req_per_s", stats.req_per_s()),
    ] {
        writeln!(csv, "{k},{v}").unwrap();
    }
    for i in 0..cfg.tiles {
        writeln!(csv, "tile{i}_utilization,{:.6}", stats.utilization(i)).unwrap();
    }
    r.csv.push(("serve.csv".into(), csv));
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_table5_has_expected_shape() {
        // One family is enough for the unit test; the integration tests and
        // the CLI cover the full grid.
        let session = SweepSession::new();
        let cpu = session.run(Target::Cpu, Kernel::Relu { n: 512 }, Sew::E8, 5);
        let caesar = session.run(Target::Caesar, Kernel::Relu { n: 512 }, Sew::E8, 5);
        let carus = session.run(Target::Carus, Kernel::Relu { n: 512 }, Sew::E8, 5);
        let row = T5Row { family: Family::Relu, sew: Sew::E8, cpu, caesar, carus };
        assert!(row.caesar_speedup() > 5.0);
        assert!(row.carus_speedup() > row.caesar_speedup());
        let rep = table5(&[row]);
        assert!(rep.text.contains("ReLU"));
        assert!(!rep.csv.is_empty());
    }

    #[test]
    fn sweep_report_renders_and_caches() {
        let session = SweepSession::new();
        let points = [
            (Target::Cpu, Kernel::Relu { n: 128 }, Sew::E8),
            (Target::Caesar, Kernel::Relu { n: 128 }, Sew::E8),
            // Repeated point: must be served from the cache, not re-run.
            (Target::Cpu, Kernel::Relu { n: 128 }, Sew::E8),
        ];
        let rep = sweep_report(&session, &points, 42);
        assert_eq!(session.simulations(), 2, "repeated point must not re-simulate");
        assert_eq!(rep.text.matches("Relu").count(), 3, "every point renders a row");
        let (name, csv) = &rep.csv[0];
        assert_eq!(name, "sweep.csv");
        assert_eq!(csv.lines().count(), 4, "header + three rows");
        assert!(csv.starts_with("target,family,sew,seed,n,p,f,"));
    }

    #[test]
    fn scale_report_renders_curve_and_json_points() {
        let session = Arc::new(SweepSession::new());
        let spec = crate::sched::BatchSpec {
            target: Target::Carus,
            kernel: Kernel::Add { n: 256 },
            sew: Sew::E32,
            seed: 3,
            batch: 4,
            shard: false,
        };
        let (rep, points) = scale_report(&session, spec, &[1, 2], 2).unwrap();
        assert_eq!(points.len(), 2);
        assert!((points[0].speedup - 1.0).abs() < 1e-9, "1-tile run is the baseline");
        assert!(points[1].cycles > 0 && points[1].speedup > 0.8);
        for p in &points {
            assert!(p.sim_cycles_per_s.is_finite() && p.sim_cycles_per_s > 0.0);
        }
        assert!(rep.text.contains("tiles"));
        assert!(rep.text.contains("byte-identical"));
        assert_eq!(rep.csv[0].0, "scale.csv");
        assert_eq!(session.simulations(), 2);
        // Unknown tile targets surface as errors, not panics.
        let bad = crate::sched::BatchSpec { target: Target::Cpu, ..spec };
        assert!(scale_report(&session, bad, &[1], 1).is_err());
    }

    #[test]
    fn static_reports_render() {
        for rep in [table4(), fig7(), table7(), table8()] {
            assert!(!rep.text.is_empty(), "{}", rep.id);
        }
    }

    #[test]
    fn fmt_si_boundaries() {
        // Sub-hundred keeps one decimal; 100..1k is integral.
        assert_eq!(fmt_si(0.0), "0.0");
        assert_eq!(fmt_si(99.94), "99.9");
        assert_eq!(fmt_si(100.0), "100");
        assert_eq!(fmt_si(999.0), "999");
        // Kilo range, including the rounding boundary into it.
        assert_eq!(fmt_si(999.6), "1.0k");
        assert_eq!(fmt_si(1.0e3), "1.0k");
        assert_eq!(fmt_si(256.0e3), "256.0k");
        assert_eq!(fmt_si(999_940.0), "999.9k");
        // Mega range — previously rendered as the bogus "1500.0e3" style.
        // 999 950 rounds *up* a unit: "1.0M", never "1000.0k".
        assert_eq!(fmt_si(999_950.0), "1.0M");
        assert_eq!(fmt_si(1.0e6), "1.0M");
        assert_eq!(fmt_si(1.5e6), "1.5M");
        assert_eq!(fmt_si(4.0e6), "4.0M");
        assert_eq!(fmt_si(120.0e6), "120.0M");
        // Giga range exists rather than saturating at "1500.0M".
        assert_eq!(fmt_si(1.5e9), "1.5G");
        // Non-finite values degrade to N/A (Table VII has an N/A cell).
        assert_eq!(fmt_si(f64::NAN), "N/A");
        assert_eq!(fmt_si(f64::INFINITY), "N/A");
        assert_eq!(fmt_si(f64::NEG_INFINITY), "N/A");
    }

    #[test]
    fn fmt_si_negative_boundaries() {
        // Negatives scale by magnitude — previously they fell through
        // every threshold and rendered unscaled ("-2000000.0").
        assert_eq!(fmt_si(-2.0e6), "-2.0M");
        assert_eq!(fmt_si(-1.5e9), "-1.5G");
        assert_eq!(fmt_si(-256.0e3), "-256.0k");
        // The same rounding boundaries as the positive range.
        assert_eq!(fmt_si(-999_940.0), "-999.9k");
        assert_eq!(fmt_si(-999_950.0), "-1.0M");
        assert_eq!(fmt_si(-999.6), "-1.0k");
        assert_eq!(fmt_si(-999.0), "-999");
        assert_eq!(fmt_si(-100.0), "-100");
        assert_eq!(fmt_si(-99.94), "-99.9");
        assert_eq!(fmt_si(-0.5), "-0.5");
        // Signed zero renders unsigned.
        assert_eq!(fmt_si(-0.0), "0.0");
    }

    #[test]
    fn table5_and_fig11_share_one_simulated_grid() {
        // The acceptance contract behind `heeperator all`: the second
        // report consuming the Table V grid adds zero simulations.
        let session = SweepSession::new();
        let rows = run_table5(&session, true);
        assert_eq!(rows.len(), 27);
        let sims = session.simulations();
        assert_eq!(sims, 81, "9 families x 3 widths x 3 targets");
        let again = run_table5(&session, true);
        assert_eq!(session.simulations(), sims, "second grid pass must be fully cached");
        // And the two passes render byte-identically.
        assert_eq!(table5(&rows).text, table5(&again).text);
        assert_eq!(fig11(&rows).text, fig11(&again).text);
    }

    #[test]
    fn parallel_reports_byte_identical_to_sequential() {
        // The executor contract on real report thunks: same bytes, any
        // worker count. Static reports keep this cheap; the full-grid
        // identity is exercised by `heeperator all --quick` end to end.
        let mk = || -> Vec<executor::Job<Vec<Report>>> {
            vec![
                Box::new(|| vec![table4()]),
                Box::new(|| vec![fig7()]),
                Box::new(|| vec![table7()]),
                Box::new(|| vec![table8()]),
            ]
        };
        let seq: Vec<Report> = executor::run_ordered(mk(), 1).into_iter().flatten().collect();
        let par: Vec<Report> = executor::run_ordered(mk(), 4).into_iter().flatten().collect();
        assert_eq!(seq.len(), par.len());
        for (s, p) in seq.iter().zip(&par) {
            assert_eq!(s.id, p.id);
            assert_eq!(s.text, p.text, "{} text diverged", s.id);
            assert_eq!(s.csv, p.csv, "{} csv diverged", s.id);
        }
    }
}
