//! Deterministic `std::thread` worker pool for the report harness.
//!
//! `heeperator all` regenerates nine independent reports (Tables IV–VIII,
//! Figs 7/11/12/13) plus four ablations; each one builds its own `Soc`
//! instances from scratch, so they share no mutable state and can run
//! concurrently. This module fans a list of report *thunks* out over a
//! bounded worker pool and collects the results **in submission order**,
//! which is what keeps the parallel output byte-identical to the
//! sequential one (the acceptance contract of `--jobs`).
//!
//! Hand-rolled on `std::sync::mpsc` + a shared `VecDeque` work queue:
//! rayon is not in the offline vendor set, and the workload shape (a
//! dozen coarse, seconds-long jobs) needs nothing fancier than
//! work-stealing-free FIFO dispatch.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// A unit of work: produces one ordered result.
pub type Job<T> = Box<dyn FnOnce() -> T + Send + 'static>;

/// Default worker count: one per available hardware thread.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `jobs` on up to `workers` threads; results are returned in
/// submission order regardless of completion order.
///
/// `workers <= 1` degenerates to a plain in-order loop on the calling
/// thread (the `--jobs 1` sequential baseline). A panicking job poisons
/// nothing: the panic is propagated to the caller after the surviving
/// workers drain, via the worker's `JoinHandle`.
pub fn run_ordered<T: Send + 'static>(jobs: Vec<Job<T>>, workers: usize) -> Vec<T> {
    let n = jobs.len();
    if workers <= 1 || n <= 1 {
        return jobs.into_iter().map(|job| job()).collect();
    }
    let queue: Arc<Mutex<VecDeque<(usize, Job<T>)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().collect()));
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let workers = workers.min(n);
    let mut handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || loop {
            // Pop under the lock, run outside it.
            let next = queue.lock().expect("work queue poisoned").pop_front();
            let Some((idx, job)) = next else { break };
            // A send can only fail if the collector hung up early, which
            // it never does while workers hold results to deliver.
            let _ = tx.send((idx, job()));
        }));
    }
    drop(tx); // collector stops when every worker is done

    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (idx, result) in rx {
        slots[idx] = Some(result);
    }
    for h in handles {
        if let Err(payload) = h.join() {
            std::panic::resume_unwind(payload);
        }
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.unwrap_or_else(|| panic!("job {i} produced no result")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_submission_order() {
        // Jobs finish out of order (later jobs sleep less) but the output
        // must stay ordered by submission index.
        let jobs: Vec<Job<usize>> = (0..16)
            .map(|i| {
                Box::new(move || {
                    std::thread::sleep(std::time::Duration::from_millis((16 - i) as u64));
                    i
                }) as Job<usize>
            })
            .collect();
        let out = run_ordered(jobs, 8);
        assert_eq!(out, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_and_parallel_agree() {
        let mk = || -> Vec<Job<String>> {
            (0..12).map(|i| Box::new(move || format!("report-{i}")) as Job<String>).collect()
        };
        let seq = run_ordered(mk(), 1);
        let par = run_ordered(mk(), 4);
        assert_eq!(seq, par);
    }

    #[test]
    fn worker_count_edge_cases() {
        let mk = |n: usize| -> Vec<Job<usize>> {
            (0..n).map(|i| Box::new(move || i * i) as Job<usize>).collect()
        };
        assert_eq!(run_ordered(mk(0), 4), Vec::<usize>::new());
        assert_eq!(run_ordered(mk(1), 4), vec![0]);
        // More workers than jobs.
        assert_eq!(run_ordered(mk(3), 64), vec![0, 1, 4]);
        // Zero workers degrades to sequential, not deadlock.
        assert_eq!(run_ordered(mk(3), 0), vec![0, 1, 4]);
    }

    #[test]
    fn default_jobs_is_positive() {
        assert!(default_jobs() >= 1);
    }

    #[test]
    fn panics_propagate() {
        let jobs: Vec<Job<u32>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom")),
            Box::new(|| 3),
        ];
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_ordered(jobs, 2)));
        assert!(res.is_err(), "worker panic must reach the caller");
    }
}
