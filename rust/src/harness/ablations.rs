//! Ablation studies for the design choices the paper calls out.
//!
//! Four questions the paper answers qualitatively, quantified here on the
//! simulated system (regenerate with `heeperator ablations`):
//!
//! 1. **Lane scaling** (§III-B2, §V-C): "NM-Carus VPU can be scaled
//!    arbitrarily … throughput scales almost linearly with the number of
//!    ALUs, while the area overhead … is contained." We sweep 1–16 lanes
//!    on the saturated 8-bit matmul and report throughput, area, and the
//!    derived GOPS/mm².
//! 2. **Issue strategy** (§I, §V-B1): NM-Caesar micro-ops can be streamed
//!    by the DMA (predefined sequences → code size) or encoded online by
//!    the host CPU (runtime cost). We run the same kernel both ways.
//! 3. **Bank-aware data placement** (§III-A2): the 3-cycle same-bank
//!    penalty, end to end — the data-placement *freedom* NM-Caesar offers
//!    vs. the constraint-induced slowdowns of IMC comparators.
//! 4. **Scoreboard precision** (§III-B1): the precise emvx hazard check
//!    vs. a conservative drain-always policy — why the eCPU can prefetch
//!    operands during vmacc execution (the matmul row loop depends on it).

use super::Report;
use crate::area;
use crate::bus::{periph, BANK_SIZE, CAESAR_BASE, PERIPH_BASE};
use crate::caesar::compiler::CaesarProgram;
use crate::carus::vpu::{Vpu, EMV_COST};
use crate::cpu::CpuConfig;
use crate::isa::reg::*;
use crate::isa::xvnmc::VOp;
use crate::isa::Sew;
use crate::kernels::{Kernel, Target};
use crate::soc::{Halt, Soc};
use crate::sweep::SweepSession;
use std::fmt::Write as _;

/// Ablation 1: NM-Carus lane scaling on the saturated 8-bit matmul.
pub fn lane_scaling() -> Report {
    let mut r = Report::new("ablation_lanes", "NM-Carus lane scaling (matmul 8-bit, P=1024)");
    writeln!(
        r.text,
        "{:>6} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "lanes", "cycles", "out/cycle", "area[um2]", "GOPS@330", "GOPS/mm2"
    )
    .unwrap();
    let mut csv = String::from("lanes,cycles,outputs_per_cycle,area_um2,gops,gops_per_mm2\n");
    let mut prev_opc = 0.0;
    for lanes in [1u32, 2, 4, 8, 16] {
        // Run the real kernel on a SoC with this lane count.
        let data = crate::kernels::golden::generate(Kernel::Matmul { p: 1024 }, Sew::E8, 77);
        let res = run_carus_with_lanes(lanes, Kernel::Matmul { p: 1024 }, Sew::E8, &data);
        let opc = res.0 as f64; // outputs
        let cycles = res.1;
        let out_per_cycle = opc / cycles as f64;
        let a = area::carus(lanes).total();
        let gops = out_per_cycle * 8.0 * 2.0 * 330.0e6 / 1e9; // 8 MAC/out, 2 op/MAC
        writeln!(
            r.text,
            "{:>6} {:>12} {:>12.3} {:>12.0} {:>12.2} {:>12.2}",
            lanes,
            cycles,
            out_per_cycle,
            a,
            gops,
            gops / (a / 1e6)
        )
        .unwrap();
        writeln!(csv, "{lanes},{cycles},{out_per_cycle:.4},{a:.0},{gops:.2},{:.2}", gops / (a / 1e6)).unwrap();
        // Near-linear scaling until the issue overhead bites.
        if prev_opc > 0.0 && lanes <= 8 {
            let ratio = out_per_cycle / prev_opc;
            assert!(ratio > 1.6, "lane scaling broke: {ratio:.2} at {lanes} lanes");
        }
        prev_opc = out_per_cycle;
    }
    writeln!(r.text, "paper: \"throughput scales almost linearly with the number of ALUs\" (§V-C)").unwrap();
    r.csv.push(("ablation_lanes.csv".into(), csv));
    r
}

/// Run a Carus kernel on a SoC with a custom lane count (the kernels::carus
/// driver is fixed at 4 lanes; this duplicates the essential path).
fn run_carus_with_lanes(
    lanes: u32,
    kernel: Kernel,
    sew: Sew,
    data: &crate::kernels::golden::WorkloadData,
) -> (u64, u64) {
    // Reuse the standard builder against a custom SoC.
    let mut soc = Soc::new(CpuConfig::CV32E40P, lanes);
    let outputs = kernel.outputs();
    // Drive NM-Carus directly (macro-level ablation: no host driver).
    let Kernel::Matmul { p } = kernel else { unimplemented!("ablation covers matmul") };
    let row_bytes = p * sew.bytes();
    let av = crate::kernels::golden::unpack(&data.a, sew);
    for r in 0..8u32 {
        soc.carus_mut().vrf.load(r * row_bytes, &data.b[(r * row_bytes) as usize..((r + 1) * row_bytes) as usize]);
    }
    for k in 0..8u32 {
        for i in 0..8u32 {
            soc.carus_mut().vrf.set_elem((16 + k) as u8, i, p, sew, av[(i * 8 + k) as usize] as u32);
        }
    }
    let mut a = crate::asm::Asm::new(0);
    a.li(A0, p as i32).vsetvli(T0, A0, sew).li(S0, 0);
    a.label("iloop").addi(S1, S0, 8).v_opr(VOp::Mv, S1, crate::isa::xvnmc::VSrc::I(0));
    for k in 0..8u8 {
        a.emvx(A2, 16 + k, S0);
        if k > 0 {
            a.addi(S1, S1, 0x100);
        }
        a.v_opr(VOp::Macc, S1, crate::isa::xvnmc::VSrc::X(A2));
    }
    a.addi(S0, S0, 1).li(T2, 8).bne(S0, T2, "iloop").ebreak();
    // One accessor lookup, then drive the device directly — the loop
    // below is the ablation's hot path.
    let carus = soc.carus_mut();
    carus.load_kernel(&a.assemble().unwrap().words);
    carus.config_mode = true;
    carus.bus_write(crate::carus::CTL_OFFSET, 4, crate::carus::CTL_START);
    carus.config_mode = false;
    let mut cycles = 0u64;
    while carus.busy() {
        carus.step();
        cycles += 1;
        assert!(cycles < 50_000_000);
    }
    (outputs, cycles)
}

/// Ablation 2: NM-Caesar issue strategy — DMA stream vs host-CPU online
/// encoding (the §I trade-off: code size vs CPU time).
pub fn issue_strategy() -> Report {
    let mut r = Report::new("ablation_issue", "NM-Caesar issue strategy (1024-word XOR)");
    let words = 1024u32;
    // Common data.
    let build_soc = || {
        let mut soc = Soc::heeperator();
        for i in 0..words {
            soc.caesar_mut().poke_word(i, i);
            soc.caesar_mut().poke_word(4096 + i, 0x5555_5555);
        }
        soc
    };

    // (a) DMA-streamed predefined sequence.
    let mut p = CaesarProgram::new();
    p.csrw(Sew::E32);
    for i in 0..words {
        p.xor(2048 + i, i, 4096 + i);
    }
    let stream = p.to_stream(CAESAR_BASE);
    let mut soc = build_soc();
    soc.load_data(BANK_SIZE, &stream);
    let mut a = crate::asm::Asm::new(0);
    a.li(T0, (PERIPH_BASE + periph::CAESAR_IMC) as i32)
        .li(T1, 1)
        .sw(T1, 0, T0)
        .li(T0, (PERIPH_BASE + periph::DMA_SRC) as i32)
        .li(T1, BANK_SIZE as i32)
        .sw(T1, 0, T0)
        .li(T0, (PERIPH_BASE + periph::DMA_LEN) as i32)
        .li(T1, p.stream_len() as i32)
        .sw(T1, 0, T0)
        .li(T0, (PERIPH_BASE + periph::DMA_CTL) as i32)
        .li(T1, 0b11)
        .sw(T1, 0, T0)
        .wfi()
        .ebreak();
    soc.load_firmware(&a.assemble().unwrap(), 0);
    soc.reset_stats();
    let (h, dma_cycles) = soc.run(1_000_000);
    assert_eq!(h, Halt::Done);
    let dma_energy = soc.energy().total();

    // (b) host-CPU online encoding (op word advances by a constant).
    let mut soc = build_soc();
    let xor0 = crate::caesar::isa::encode(&crate::caesar::isa::MicroOp {
        op: crate::caesar::isa::Op::Xor,
        src1: 0,
        src2: 4096,
    });
    let mut a = crate::asm::Asm::new(0);
    a.li(T0, (PERIPH_BASE + periph::CAESAR_IMC) as i32)
        .li(T1, 1)
        .sw(T1, 0, T0)
        // CSRW first.
        .li(A0, CAESAR_BASE as i32)
        .li(T1, crate::caesar::isa::encode_csrw(Sew::E32) as i32)
        .sw(T1, 0, A0)
        .li(A1, xor0 as i32) // rolling op word
        .li(A2, (CAESAR_BASE + 2048 * 4) as i32) // rolling dest
        .li(A3, 0x2001) // src1+1, src2+1
        .li(A4, words as i32)
        .label("loop")
        .sw(A1, 0, A2)
        .add(A1, A1, A3)
        .addi(A2, A2, 4)
        .addi(A4, A4, -1)
        .bne(A4, ZERO, "loop")
        .ebreak();
    soc.load_firmware(&a.assemble().unwrap(), 0);
    soc.reset_stats();
    let (h, cpu_cycles) = soc.run(1_000_000);
    assert_eq!(h, Halt::Done);
    let cpu_energy = soc.energy().total();

    writeln!(
        r.text,
        "{:<28} {:>10} {:>12} {:>14}",
        "strategy", "cycles", "energy[pJ]", "host mem[B]"
    )
    .unwrap();
    writeln!(
        r.text,
        "{:<28} {:>10} {:>12.0} {:>14}",
        "DMA stream (predefined)", dma_cycles, dma_energy, p.stream_len()
    )
    .unwrap();
    writeln!(
        r.text,
        "{:<28} {:>10} {:>12.0} {:>14}",
        "CPU online encoding", cpu_cycles, cpu_energy, 15 * 4
    )
    .unwrap();
    writeln!(
        r.text,
        "trade-off (§I): streaming is ~{:.1}x faster but costs {} B of predefined sequence;\nonline encoding is CPU-bound (~{:.1} cycles/op) with constant code size.",
        cpu_cycles as f64 / dma_cycles as f64,
        p.stream_len(),
        cpu_cycles as f64 / words as f64
    )
    .unwrap();
    let mut csv = String::from("strategy,cycles,energy_pj,host_bytes\n");
    writeln!(csv, "dma_stream,{dma_cycles},{dma_energy:.0},{}", p.stream_len()).unwrap();
    writeln!(csv, "cpu_online,{cpu_cycles},{cpu_energy:.0},60").unwrap();
    r.csv.push(("ablation_issue.csv".into(), csv));
    r
}

/// Ablation 3: data placement — cross-bank vs same-bank operand layout.
pub fn bank_placement() -> Report {
    let mut r = Report::new("ablation_banks", "NM-Caesar operand placement (1024 ADDs)");
    let run_with = |same_bank: bool| -> u64 {
        let mut c = crate::caesar::Caesar::new();
        for i in 0..1024u32 {
            c.poke_word(i, i);
            c.poke_word(if same_bank { 1024 + i } else { 4096 + i }, 7);
        }
        let src2 = if same_bank { 1024 } else { 4096 };
        for i in 0..1024u32 {
            while !c.ready() {
                c.step();
            }
            let m = crate::caesar::isa::MicroOp {
                op: crate::caesar::isa::Op::Add,
                src1: i as u16,
                src2: (src2 + i) as u16,
            };
            c.issue(2048 + i, crate::caesar::isa::encode(&m));
            c.step();
        }
        while !c.ready() {
            c.step();
        }
        c.stats.busy_cycles
    };
    let cross = run_with(false);
    let same = run_with(true);
    writeln!(r.text, "cross-bank operands: {cross} cycles (2 cycles/op)").unwrap();
    writeln!(r.text, "same-bank operands:  {same} cycles (3 cycles/op, sequential fetch)").unwrap();
    writeln!(
        r.text,
        "penalty: {:.2}x — but unlike IMC comparators this is a *performance* knob,\nnot a correctness constraint (any placement computes correctly).",
        same as f64 / cross as f64
    )
    .unwrap();
    let mut csv = String::from("layout,busy_cycles\n");
    writeln!(csv, "cross_bank,{cross}\nsame_bank,{same}").unwrap();
    r.csv.push(("ablation_banks.csv".into(), csv));
    r
}

/// Ablation 4: precise vs conservative emvx scoreboard. The measured
/// reference point drains through `session` — `heeperator all` shares it
/// with any other report that asks for the same workload.
pub fn scoreboard_policy(session: &SweepSession) -> Report {
    let mut r = Report::new(
        "ablation_scoreboard",
        "emvx hazard policy (matmul row loop, vl=1024, e8)",
    );
    // Model both policies analytically on the VPU cost model, then verify
    // the precise one against the measured end-to-end kernel.
    let mut vpu = Vpu::new(4);
    vpu.set_vtype(1024, Sew::E8);
    let vmacc = vpu.op_cost(VOp::Macc, crate::isa::xvnmc::VSrcKind::Vx) as u64;
    // Precise: emvx overlaps with the in-flight vmacc (reads another reg).
    let precise_per_k = vmacc - 2; // queued issue overlap
    // Conservative: emvx waits for the full drain every iteration.
    let conservative_per_k = vmacc + EMV_COST as u64;
    let k_steps = 8 * 8; // 8 rows × 8 k
    writeln!(r.text, "per-k cost: precise {precise_per_k} cycles, conservative {conservative_per_k} cycles").unwrap();
    writeln!(
        r.text,
        "matmul [8,8]x[8,1024]: precise ≈ {} cycles, conservative ≈ {} cycles ({:+.1} %)",
        precise_per_k * k_steps,
        conservative_per_k * k_steps,
        (conservative_per_k as f64 / precise_per_k as f64 - 1.0) * 100.0
    )
    .unwrap();
    // Measured end-to-end (includes driver) must sit near the precise model.
    let res = session.run(Target::Carus, Kernel::Matmul { p: 1024 }, Sew::E8, 55);
    writeln!(r.text, "measured end-to-end: {} cycles (precise-policy simulator)", res.cycles).unwrap();
    writeln!(
        r.text,
        "the conservative policy would forfeit the paper's 0.48 out/cycle saturation\n(emvx is \"the only mechanism … causing data hazards\", §III-B1 — precision pays)."
    )
    .unwrap();
    // Sanity: measured within 15 % of the precise analytical model.
    let model = precise_per_k * k_steps;
    assert!(
        (res.cycles as f64 - model as f64).abs() / (model as f64) < 0.15,
        "measured {} vs model {model}",
        res.cycles
    );
    r
}

/// All ablations in order, sharing `session` where a study consumes
/// grid workloads.
pub fn all(session: &SweepSession) -> Vec<Report> {
    vec![lane_scaling(), issue_strategy(), bank_placement(), scoreboard_policy(session)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_scaling_runs_and_scales() {
        let rep = lane_scaling();
        assert!(rep.text.contains("16"));
    }

    #[test]
    fn issue_strategy_tradeoff_holds() {
        let rep = issue_strategy();
        // DMA streaming must win on cycles; online encoding on memory.
        assert!(rep.text.contains("faster"));
    }

    #[test]
    fn bank_placement_penalty() {
        let rep = bank_placement();
        assert!(rep.text.contains("1.50x") || rep.text.contains("1.5"));
    }

    #[test]
    fn scoreboard_policy_analysis() {
        let rep = scoreboard_policy(&SweepSession::new());
        assert!(rep.text.contains("precise"));
    }
}
