//! # heeperator — NM-Caesar / NM-Carus near-memory computing, reproduced
//!
//! Full-system reproduction of *"Scalable and RISC-V Programmable
//! Near-Memory Computing Architectures for Edge Nodes"* (IEEE TETC 2024):
//! a cycle-approximate, energy-annotated simulator of the HEEPerator MCU
//! (X-HEEP host + NM-Caesar + NM-Carus), the paper's custom ISAs and
//! toolchains, analytical area/energy models calibrated to the paper's
//! 65 nm post-layout data, and a PJRT-based golden-model runtime that
//! cross-checks every simulated kernel against AOT-compiled JAX/Pallas
//! artifacts.
//!
//! Architecture map (the repo-root `DESIGN.md` carries the full module
//! inventory and the calibration / invariant anchors §5 and §7):
//! - [`isa`], [`asm`]: RV32IM/E + Xcv + xvnmc definitions and assembler.
//! - [`simd`]: shared packed-SIMD element algebra.
//! - [`mem`], [`bus`], [`dma`]: memory subsystem substrates.
//! - [`cpu`]: RV32 ISS with CV32E40P-class timing.
//! - [`caesar`], [`carus`]: the paper's two NMC macros.
//! - [`soc`]: the HEEPerator system (cycle-accurate co-simulation).
//! - [`clock`]: timing discipline — the event-driven skip-ahead layer
//!   (`--timing=event`, the default) and the per-cycle differential
//!   reference (`--timing=cycle`), equivalence locked by
//!   `rust/tests/timing_equivalence.rs`.
//! - [`kernels`], [`apps`]: benchmark kernels (3 targets × 9 kernels ×
//!   3 bitwidths) and the Anomaly-Detection application.
//! - [`energy`], [`area`]: calibrated 65 nm power/area models.
//! - [`compare`]: BLADE / C-SRAM / Vecim analytical comparison models.
//! - [`runtime`]: PJRT golden-model seam (loads `artifacts/*.hlo.txt`;
//!   offline builds skip gracefully).
//! - [`kernels::Engine`]: the execution-backend seam — firmware assembly
//!   (`prepare`) separated from simulation (`execute`), with assembled
//!   programs cached per `(target, kernel, sew)`.
//! - [`sched`]: the multi-tile batch scheduler — [`soc::Soc`] scaled out
//!   to N NMC tiles, workloads sharded/batched across them with DMA
//!   staging overlapped against tile execution (`heeperator scale`).
//! - [`sweep`]: memoizing [`sweep::SweepSession`] — one simulation per
//!   `(target, kernel, sew, seed)` point (and one co-simulation per
//!   `(scale spec, tiles)` point) per invocation, shared by every
//!   report, the CLI `sweep`/`scale` subcommands, benches, and examples.
//! - [`harness`]: regenerates every table and figure of §V, fanning the
//!   independent reports over the [`harness::executor`] thread pool and
//!   deduplicating their simulations through one shared session.
//! - [`fuzz`]: the differential fuzzer — seeded random programs over the
//!   xvnmc/xcv/micro-op ISA surfaces and random batch scenarios, checked
//!   across every execution axis (engine × tiles × shard × timing) with a
//!   greedy shrinker and replayable repro files (`heeperator fuzz`).
//! - [`serve`]: the batch-inference service — JSONL requests over
//!   stdin/TCP through admission control and a coalescing batcher onto
//!   [`sched::plan_jobs`], with a deterministic seeded load generator
//!   and latency/utilization reporting (`heeperator serve`).
//! - [`spec`]: the unified job-spec vocabulary — one parse / validate /
//!   serialize path for the `(target, family, sew, n, p, f, seed)` tuple
//!   plus the versioned wire-schema tags ([`spec::schemas`]) shared by
//!   serve, the CLI selectors, and the fuzz repro format.
//! - [`graph`]: the linear graph IR for multi-layer INT8 inference —
//!   kernel chains with a quantize/dequantize boundary, compiled to a
//!   per-layer tile schedule and executed by [`sched::pipeline`] with
//!   inter-layer tensors resident in tile SRAM (`heeperator model`).

pub mod apps;
pub mod area;
pub mod asm;
pub mod benchlib;
pub mod bus;
pub mod clock;
pub mod compare;
pub mod cpu;
pub mod dma;
pub mod energy;
pub mod fuzz;
pub mod graph;
pub mod harness;
pub mod isa;
pub mod kernels;
pub mod mem;
pub mod runtime;
pub mod caesar;
pub mod carus;
pub mod sched;
pub mod serve;
pub mod simd;
pub mod soc;
pub mod spec;
pub mod sweep;
