//! 65 nm energy calibration constants (pJ per event, typical corner).
//!
//! Derivation notes — every constant traces to a published anchor:
//!
//! * **SRAM access energies.** 65 nm low-power single-port compiler macros
//!   run ≈0.25–0.35 pJ/bit/read at this capacity; the paper's own Fig. 13
//!   requires the 32 KiB bank to burn about as much as the CV32E40P core on
//!   the fetch-dominated CPU case (≈9 fetches + 3 data accesses per 10
//!   cycles ≈ CPU core energy) ⇒ ~9 pJ/read. Smaller macros scale
//!   sub-linearly (shorter bit-lines): 16 KiB ≈ 0.72×, 8 KiB ≈ 0.52×,
//!   matching commercial compiler datasheets. Writes ≈ 1.15× reads.
//! * **CPU core energies.** CV32E40P ≈ 35 µW/MHz at 65 nm LP (literature on
//!   PULPino-class cores) ⇒ ≈9 pJ/cycle active. CV32E20 ("micro-riscy") is
//!   reported ~2.5–3× leaner ⇒ 3.5 pJ/cycle. The CV32E40X in RV32EC config
//!   plus the XIF sits between ⇒ 4 pJ/cycle. Clock-gated cores keep ~10 %.
//! * **ALU element-op energies.** The ~100:1 SRAM:ALU rule [Hennessy &
//!   Patterson] puts an 8-bit add at ~0.03 pJ and a 32-bit MAC around
//!   1–3 pJ at 65 nm; we charge per *element* op through the shared
//!   SIMD datapath (incl. local register/pipeline overhead), with
//!   multiplies ≈ 2.5× adds.
//! * **Interconnect.** OBI crossbar transaction ≈1.5 pJ (drivers + arbitration),
//!   DMA engine ≈2 pJ/active cycle. Residual always-on power (peripheral
//!   subsystem, clock tree, leakage) ≈ 1 mW at 250 MHz ⇒ 4 pJ/cycle.
//!
//! The end-to-end validation of these numbers is `rust/tests/calibration.rs`
//! which reproduces the Table V energy ratios within tolerance, and the
//! Fig. 13 breakdown shares.

/// System clock: 250 MHz post-layout operating point (§V-A1).
pub const F_CLK_HZ: f64 = 250.0e6;
/// Cycle time in ns.
pub const CYCLE_NS: f64 = 4.0;

// --- Memory macros (pJ per access) -----------------------------------------
pub const E_SRAM32K_READ: f64 = 9.0;
pub const E_SRAM32K_WRITE: f64 = 10.4;
pub const E_SRAM16K_READ: f64 = 6.5;
pub const E_SRAM16K_WRITE: f64 = 7.5;
pub const E_SRAM8K_READ: f64 = 4.7;
pub const E_SRAM8K_WRITE: f64 = 5.4;
/// 512 B latch-based register file (NM-Carus eMEM).
pub const E_EMEM_ACCESS: f64 = 1.2;
/// Embedded flash read (AD weight streaming).
pub const E_ROM_READ: f64 = 15.0;

// --- CPU cores (pJ per cycle) ----------------------------------------------
pub const E_CPU_E40P_CYCLE: f64 = 9.0;
pub const E_CPU_E20_CYCLE: f64 = 3.5;
pub const E_ECPU_CYCLE: f64 = 4.0;
pub const E_CPU_SLEEP_CYCLE: f64 = 0.9;
pub const E_ECPU_SLEEP_CYCLE: f64 = 0.4;

// --- SIMD/vector ALU datapaths (pJ per element operation) -------------------
/// Logic / min / max / shift element ops.
pub const E_ALU_LIGHT_ELEM: f64 = 0.9;
/// Add/sub element ops (partitioned multi-precision adder).
pub const E_ALU_ADD_ELEM: f64 = 1.2;
/// Multiply / MAC / dot element ops (16-bit multiplier passes).
pub const E_ALU_MUL_ELEM: f64 = 3.0;

// --- NMC control logic (pJ per cycle) ----------------------------------------
/// NM-Caesar controller + pipeline registers while busy.
pub const E_CAESAR_CTL_CYCLE: f64 = 1.6;
/// NM-Carus VPU control (decode/commit/loop unit) while busy.
pub const E_VPU_CTL_CYCLE: f64 = 2.2;
/// NM-Carus VPU when clock-gated (no vector instruction in flight).
pub const E_VPU_GATED_CYCLE: f64 = 0.15;

// --- Interconnect ------------------------------------------------------------
/// One granted crossbar transaction.
pub const E_BUS_TXN: f64 = 1.5;
/// DMA engine per active cycle.
pub const E_DMA_CYCLE: f64 = 2.0;

// --- Always-on residue (pJ per cycle) ----------------------------------------
/// Peripheral subsystem + clock tree + leakage of the whole MCU (the
/// paper's two-tile HEEPerator).
pub const E_STATIC_CYCLE: f64 = 4.0;
/// Clock-tree + leakage share of one additional NMC tile beyond the
/// baseline two (scale-out configurations). A 32 KiB-class macro plus its
/// window of the crossbar is a fraction of the whole-MCU residue.
pub const E_TILE_STATIC_CYCLE: f64 = 0.8;
