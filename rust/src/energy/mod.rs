//! Event-based energy model, calibrated to the paper's 65 nm post-layout
//! power analysis (PrimePower, typical corner, 250 MHz).
//!
//! The simulator counts *events* (memory accesses per macro kind, ALU
//! element-ops, CPU cycles by state, bus transactions, DMA activity) and
//! this module converts them to energy with the per-event constants in
//! [`params`]. Static/clock-tree power is charged per cycle per component
//! state (active / clock-gated), matching how the paper's VCD-based
//! analysis attributes idle power.
//!
//! # Calibration (see DESIGN.md §5)
//!
//! The constants are solved from the paper's own anchor points rather than
//! invented: the CPU 32-bit element-wise-add baseline (10 cycles and 278 pJ
//! per output), the Fig. 13 power-breakdown shares (CPU ≈ memory for the
//! CPU case; micro-op streaming ≈ half of NM-Caesar's memory power; VRF ≈
//! 60 % of NM-Carus system power), and the Table V headline energy ratios
//! (25.0× NM-Caesar, 35.6× NM-Carus on 8-bit matmul). The calibration test
//! suite (`rust/tests/calibration.rs`) locks the reproduced ratios.

pub mod params;

use crate::mem::MacroKind;
use params::*;

/// Activity counters for one benchmark run, filled by the SoC.
#[derive(Debug, Clone, Default)]
pub struct Activity {
    /// Total cycles simulated.
    pub cycles: u64,
    /// Host CPU cycles actively executing (incl. stalls) / sleeping (WFI).
    pub cpu_active: u64,
    pub cpu_sleep: u64,
    /// Instruction fetches by the host CPU (each is a code-bank read).
    pub cpu_fetches: u64,
    /// Data accesses (reads, writes) per macro kind, aggregated over banks.
    pub mem_reads: Vec<(MacroKind, u64)>,
    pub mem_writes: Vec<(MacroKind, u64)>,
    /// Bus transactions granted.
    pub bus_txns: u64,
    /// DMA active cycles.
    pub dma_active: u64,
    /// NM-Caesar: controller busy cycles and ALU element-operations by class.
    pub caesar_busy: u64,
    pub caesar_alu_light: u64, // logic/min/max/shift element-ops
    pub caesar_alu_add: u64,   // add/sub element-ops
    pub caesar_alu_mul: u64,   // mul/mac/dot element-ops
    /// NM-Carus: eCPU active cycles, VPU busy cycles, lane element-ops.
    pub carus_ecpu_active: u64,
    pub carus_ecpu_sleep: u64,
    pub carus_emem_accesses: u64,
    pub carus_vpu_busy: u64,
    pub carus_vpu_idle: u64,
    pub carus_alu_light: u64,
    pub carus_alu_add: u64,
    pub carus_alu_mul: u64,
    /// Populated NMC tile windows. The paper's HEEPerator has two (one
    /// NM-Caesar + one NM-Carus), which the baseline static residue
    /// already covers; each tile beyond two adds its own clock-tree +
    /// leakage share per cycle ([`params::E_TILE_STATIC_CYCLE`]).
    pub nmc_tiles: u32,
    /// Which CPU is the host (scales core energy/cycle).
    pub host_kind: HostKind,
}

/// Host CPU kind for core-energy scaling.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HostKind {
    #[default]
    Cv32e40p,
    Cv32e20,
}

/// Energy breakdown in pJ, aligned with the Fig. 13 categories.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Breakdown {
    /// Host CPU core (incl. its sleep power).
    pub cpu: f64,
    /// All memory macros: system SRAM, NMC-internal banks, eMEM, ROM.
    pub memory: f64,
    /// NMC compute + control logic (Caesar ALU/ctl, Carus eCPU/VPU).
    pub nmc_logic: f64,
    /// Bus + DMA.
    pub interconnect: f64,
    /// Always-on residue: peripherals, clock tree, leakage.
    pub other: f64,
}

impl Breakdown {
    /// Total energy in pJ.
    pub fn total(&self) -> f64 {
        self.cpu + self.memory + self.nmc_logic + self.interconnect + self.other
    }
    /// Average power in mW given a cycle count at `F_CLK_HZ`.
    pub fn avg_power_mw(&self, cycles: u64) -> f64 {
        if cycles == 0 {
            return 0.0;
        }
        // pJ / (cycles * 4 ns) = pJ/ns * 1e-3 ... 1 pJ/ns = 1 mW.
        self.total() / (cycles as f64 * CYCLE_NS) * 1.0e0
    }
    /// Percentage shares (cpu, memory, nmc, interconnect, other).
    pub fn shares(&self) -> [f64; 5] {
        let t = self.total().max(1e-12);
        [
            self.cpu / t * 100.0,
            self.memory / t * 100.0,
            self.nmc_logic / t * 100.0,
            self.interconnect / t * 100.0,
            self.other / t * 100.0,
        ]
    }
}

/// Energy of one access to a macro kind.
pub fn mem_access_pj(kind: MacroKind, write: bool) -> f64 {
    match (kind, write) {
        (MacroKind::Sram32k, false) => E_SRAM32K_READ,
        (MacroKind::Sram32k, true) => E_SRAM32K_WRITE,
        (MacroKind::Sram16k, false) => E_SRAM16K_READ,
        (MacroKind::Sram16k, true) => E_SRAM16K_WRITE,
        (MacroKind::Sram8k, false) => E_SRAM8K_READ,
        (MacroKind::Sram8k, true) => E_SRAM8K_WRITE,
        (MacroKind::RegFile512, _) => E_EMEM_ACCESS,
        (MacroKind::Rom, _) => E_ROM_READ,
    }
}

/// Convert an [`Activity`] record into a [`Breakdown`].
pub fn energy(act: &Activity) -> Breakdown {
    let mut b = Breakdown::default();

    // Host CPU core.
    let (e_active, e_sleep) = match act.host_kind {
        HostKind::Cv32e40p => (E_CPU_E40P_CYCLE, E_CPU_SLEEP_CYCLE),
        HostKind::Cv32e20 => (E_CPU_E20_CYCLE, E_CPU_SLEEP_CYCLE),
    };
    b.cpu = act.cpu_active as f64 * e_active + act.cpu_sleep as f64 * e_sleep;

    // Memories: instruction fetches hit the 32 KiB code bank.
    b.memory = act.cpu_fetches as f64 * E_SRAM32K_READ;
    for &(k, n) in &act.mem_reads {
        b.memory += n as f64 * mem_access_pj(k, false);
    }
    for &(k, n) in &act.mem_writes {
        b.memory += n as f64 * mem_access_pj(k, true);
    }
    b.memory += act.carus_emem_accesses as f64 * E_EMEM_ACCESS;

    // NMC logic: Caesar controller + ALU.
    b.nmc_logic += act.caesar_busy as f64 * E_CAESAR_CTL_CYCLE
        + act.caesar_alu_light as f64 * E_ALU_LIGHT_ELEM
        + act.caesar_alu_add as f64 * E_ALU_ADD_ELEM
        + act.caesar_alu_mul as f64 * E_ALU_MUL_ELEM;
    // NMC logic: Carus eCPU + VPU.
    b.nmc_logic += act.carus_ecpu_active as f64 * E_ECPU_CYCLE
        + act.carus_ecpu_sleep as f64 * E_ECPU_SLEEP_CYCLE
        + act.carus_vpu_busy as f64 * E_VPU_CTL_CYCLE
        + act.carus_vpu_idle as f64 * E_VPU_GATED_CYCLE
        + act.carus_alu_light as f64 * E_ALU_LIGHT_ELEM
        + act.carus_alu_add as f64 * E_ALU_ADD_ELEM
        + act.carus_alu_mul as f64 * E_ALU_MUL_ELEM;

    // Interconnect.
    b.interconnect =
        act.bus_txns as f64 * E_BUS_TXN + act.dma_active as f64 * E_DMA_CYCLE;

    // Always-on residue. The baseline covers the paper's two-tile MCU;
    // scale-out tiles each add their own always-on share (dynamic idle
    // power is already event-counted per tile above).
    let extra_tiles = act.nmc_tiles.saturating_sub(2) as f64;
    b.other = act.cycles as f64 * (E_STATIC_CYCLE + extra_tiles * E_TILE_STATIC_CYCLE);
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_add32_anchor_point() {
        // The calibration anchor: 32-bit element-wise add on the CPU is
        // 10 cycles and ~278 pJ per output (Table V baseline). Events per
        // output: 9 instruction fetches, 2 data reads, 1 data write, 10
        // active CPU cycles, 3 bus txns.
        let n = 1000u64;
        let act = Activity {
            cycles: 10 * n,
            cpu_active: 10 * n,
            cpu_fetches: 9 * n,
            mem_reads: vec![(MacroKind::Sram32k, 2 * n)],
            mem_writes: vec![(MacroKind::Sram32k, n)],
            bus_txns: 3 * n,
            ..Default::default()
        };
        let b = energy(&act);
        let per_output = b.total() / n as f64;
        assert!(
            (per_output - 278.0).abs() / 278.0 < 0.15,
            "expected ≈278 pJ/output, got {per_output:.1}"
        );
        // Fig. 13: memory ≈ CPU for the CPU-only case.
        let ratio = b.memory / b.cpu;
        assert!((0.7..1.4).contains(&ratio), "memory/cpu = {ratio:.2}");
    }

    #[test]
    fn power_conversion() {
        let b = Breakdown { cpu: 4000.0, ..Default::default() }; // 4000 pJ
        // over 1000 cycles @ 4 ns → 4000 pJ / 4000 ns = 1 mW
        assert!((b.avg_power_mw(1000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn shares_sum_to_100() {
        let act = Activity {
            cycles: 100,
            cpu_active: 50,
            cpu_sleep: 50,
            cpu_fetches: 40,
            bus_txns: 10,
            dma_active: 5,
            ..Default::default()
        };
        let s = energy(&act).shares();
        assert!((s.iter().sum::<f64>() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn extra_tiles_add_static_power() {
        let base = Activity { cycles: 1000, nmc_tiles: 2, ..Default::default() };
        let four = Activity { cycles: 1000, nmc_tiles: 4, ..Default::default() };
        let d = energy(&four).other - energy(&base).other;
        assert!((d - 2.0 * 1000.0 * E_TILE_STATIC_CYCLE).abs() < 1e-9);
        // Pre-scale-out records (tiles unset) cost the same as the
        // paper's two-tile baseline — the calibration anchors hold.
        let zero = Activity { cycles: 1000, ..Default::default() };
        assert_eq!(energy(&zero).other, energy(&base).other);
    }

    #[test]
    fn bigger_macros_cost_more() {
        assert!(mem_access_pj(MacroKind::Sram32k, false) > mem_access_pj(MacroKind::Sram16k, false));
        assert!(mem_access_pj(MacroKind::Sram16k, false) > mem_access_pj(MacroKind::Sram8k, false));
        assert!(mem_access_pj(MacroKind::Sram8k, false) > mem_access_pj(MacroKind::RegFile512, false));
        // Writes cost more than reads for SRAM.
        assert!(mem_access_pj(MacroKind::Sram32k, true) > mem_access_pj(MacroKind::Sram32k, false));
    }
}
