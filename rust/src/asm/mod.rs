//! Two-pass assembler DSL for RV32IM + Xcv + xvnmc programs.
//!
//! All firmware in the simulation — host CPU kernels (Table V baselines),
//! the NM-Carus eCPU kernels (xvnmc programs loaded into the eMEM), and the
//! Anomaly-Detection application — is written against this builder, which
//! plays the role of GCC 11 `-O3` + the paper's extended GNU assembler.
//!
//! The builder is label-based and two-pass: branch/jump targets may be
//! referenced before they are defined; [`Asm::assemble`] resolves them and
//! emits the final machine-code words.
//!
//! ```no_run
//! # // no_run: rustdoc test binaries bypass the cargo rpath config that
//! # // locates the xla_extension-bundled libstdc++ in this environment.
//! use nmc::asm::Asm;
//! use nmc::isa::reg::*;
//! let mut a = Asm::new(0x1000);
//! a.li(A0, 10).label("loop").addi(A0, A0, -1).bne(A0, ZERO, "loop").ret();
//! let prog = a.assemble().unwrap();
//! assert_eq!(prog.base, 0x1000);
//! assert!(prog.words.len() >= 4);
//! ```

use crate::isa::rv32::{encode, AluOp, BranchOp, CsrOp, Instr, LoadOp, MulOp, StoreOp};
use crate::isa::xcv::{XcvInstr, XcvOp};
use crate::isa::xvnmc::{VInstr, VOp, VSrc};
use crate::isa::{reg, Reg, Sew};
use std::collections::HashMap;

/// An assembled program: machine words plus its load address.
#[derive(Debug, Clone)]
pub struct Program {
    /// Load/base address of the first word.
    pub base: u32,
    /// 32-bit little-endian machine words.
    pub words: Vec<u32>,
    /// Label → byte address, for entry points and debugging.
    pub symbols: HashMap<String, u32>,
}

impl Program {
    /// Size in bytes.
    pub fn size(&self) -> u32 {
        (self.words.len() * 4) as u32
    }
    /// Address of a label.
    pub fn addr_of(&self, label: &str) -> Option<u32> {
        self.symbols.get(label).copied()
    }
    /// Raw bytes, little-endian (for loading into simulated memories).
    pub fn bytes(&self) -> Vec<u8> {
        self.words.iter().flat_map(|w| w.to_le_bytes()).collect()
    }
}

/// Assembly errors surfaced by [`Asm::assemble`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AsmError {
    UndefinedLabel(String),
    DuplicateLabel(String),
    BranchOutOfRange { label: String, offset: i64 },
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AsmError::UndefinedLabel(l) => write!(f, "undefined label `{l}`"),
            AsmError::DuplicateLabel(l) => write!(f, "duplicate label `{l}`"),
            AsmError::BranchOutOfRange { label, offset } => {
                write!(f, "branch to `{label}` out of range ({offset} bytes)")
            }
        }
    }
}
impl std::error::Error for AsmError {}

enum Item {
    Fixed(Instr),
    Branch { op: BranchOp, rs1: Reg, rs2: Reg, target: String },
    Jal { rd: Reg, target: String },
    /// `la rd, label` — expands to auipc+addi (2 words, reserved up front).
    La { rd: Reg, target: String },
    Word(u32),
}

impl Item {
    fn words(&self) -> usize {
        match self {
            Item::La { .. } => 2,
            _ => 1,
        }
    }
}

/// The assembler builder. Every mnemonic method appends one instruction
/// and returns `&mut Self` for chaining.
pub struct Asm {
    base: u32,
    items: Vec<Item>,
    labels: HashMap<String, usize>, // label -> item index
}

impl Asm {
    /// Create an assembler for code loaded at `base`.
    pub fn new(base: u32) -> Self {
        Asm { base, items: Vec::new(), labels: HashMap::new() }
    }

    /// Define a label at the current position.
    pub fn label(&mut self, name: &str) -> &mut Self {
        if self.labels.insert(name.to_string(), self.items.len()).is_some() {
            // Surface at assemble() time to keep the builder API infallible.
            self.items.push(Item::Word(u32::MAX)); // poison
            self.labels.insert(format!("__dup__{name}"), usize::MAX);
        }
        self
    }

    /// Append a raw pre-encoded word (escape hatch / data in code).
    pub fn word(&mut self, w: u32) -> &mut Self {
        self.items.push(Item::Word(w));
        self
    }

    /// Append an already-built [`Instr`].
    pub fn instr(&mut self, i: Instr) -> &mut Self {
        self.items.push(Item::Fixed(i));
        self
    }

    // ---- RV32I ----------------------------------------------------------

    pub fn lui(&mut self, rd: Reg, imm20: i32) -> &mut Self {
        self.instr(Instr::Lui { rd, imm: imm20 << 12 })
    }
    pub fn auipc(&mut self, rd: Reg, imm20: i32) -> &mut Self {
        self.instr(Instr::Auipc { rd, imm: imm20 << 12 })
    }
    pub fn jal(&mut self, rd: Reg, target: &str) -> &mut Self {
        self.items.push(Item::Jal { rd, target: target.to_string() });
        self
    }
    pub fn j(&mut self, target: &str) -> &mut Self {
        self.jal(reg::ZERO, target)
    }
    pub fn jalr(&mut self, rd: Reg, rs1: Reg, off: i32) -> &mut Self {
        self.instr(Instr::Jalr { rd, rs1, off })
    }
    pub fn ret(&mut self) -> &mut Self {
        self.jalr(reg::ZERO, reg::RA, 0)
    }

    fn branch(&mut self, op: BranchOp, rs1: Reg, rs2: Reg, target: &str) -> &mut Self {
        self.items.push(Item::Branch { op, rs1, rs2, target: target.to_string() });
        self
    }
    pub fn beq(&mut self, rs1: Reg, rs2: Reg, t: &str) -> &mut Self {
        self.branch(BranchOp::Beq, rs1, rs2, t)
    }
    pub fn bne(&mut self, rs1: Reg, rs2: Reg, t: &str) -> &mut Self {
        self.branch(BranchOp::Bne, rs1, rs2, t)
    }
    pub fn blt(&mut self, rs1: Reg, rs2: Reg, t: &str) -> &mut Self {
        self.branch(BranchOp::Blt, rs1, rs2, t)
    }
    pub fn bge(&mut self, rs1: Reg, rs2: Reg, t: &str) -> &mut Self {
        self.branch(BranchOp::Bge, rs1, rs2, t)
    }
    pub fn bltu(&mut self, rs1: Reg, rs2: Reg, t: &str) -> &mut Self {
        self.branch(BranchOp::Bltu, rs1, rs2, t)
    }
    pub fn bgeu(&mut self, rs1: Reg, rs2: Reg, t: &str) -> &mut Self {
        self.branch(BranchOp::Bgeu, rs1, rs2, t)
    }

    pub fn lb(&mut self, rd: Reg, off: i32, rs1: Reg) -> &mut Self {
        self.instr(Instr::Load { op: LoadOp::Lb, rd, rs1, off })
    }
    pub fn lbu(&mut self, rd: Reg, off: i32, rs1: Reg) -> &mut Self {
        self.instr(Instr::Load { op: LoadOp::Lbu, rd, rs1, off })
    }
    pub fn lh(&mut self, rd: Reg, off: i32, rs1: Reg) -> &mut Self {
        self.instr(Instr::Load { op: LoadOp::Lh, rd, rs1, off })
    }
    pub fn lhu(&mut self, rd: Reg, off: i32, rs1: Reg) -> &mut Self {
        self.instr(Instr::Load { op: LoadOp::Lhu, rd, rs1, off })
    }
    pub fn lw(&mut self, rd: Reg, off: i32, rs1: Reg) -> &mut Self {
        self.instr(Instr::Load { op: LoadOp::Lw, rd, rs1, off })
    }
    pub fn sb(&mut self, rs2: Reg, off: i32, rs1: Reg) -> &mut Self {
        self.instr(Instr::Store { op: StoreOp::Sb, rs2, rs1, off })
    }
    pub fn sh(&mut self, rs2: Reg, off: i32, rs1: Reg) -> &mut Self {
        self.instr(Instr::Store { op: StoreOp::Sh, rs2, rs1, off })
    }
    pub fn sw(&mut self, rs2: Reg, off: i32, rs1: Reg) -> &mut Self {
        self.instr(Instr::Store { op: StoreOp::Sw, rs2, rs1, off })
    }

    /// SEW-dispatched signed element load (`lb`/`lh`/`lw`) — the shared
    /// helper behind every kernel builder that walks element arrays
    /// (signed loads, like GCC emits for signed element types).
    pub fn lx(&mut self, sew: Sew, rd: Reg, off: i32, rs1: Reg) -> &mut Self {
        match sew {
            Sew::E8 => self.lb(rd, off, rs1),
            Sew::E16 => self.lh(rd, off, rs1),
            Sew::E32 => self.lw(rd, off, rs1),
        }
    }
    /// SEW-dispatched element store (`sb`/`sh`/`sw`), dual of [`Asm::lx`].
    pub fn sx(&mut self, sew: Sew, rs2: Reg, off: i32, rs1: Reg) -> &mut Self {
        match sew {
            Sew::E8 => self.sb(rs2, off, rs1),
            Sew::E16 => self.sh(rs2, off, rs1),
            Sew::E32 => self.sw(rs2, off, rs1),
        }
    }

    #[track_caller]
    fn chk12(imm: i32) -> i32 {
        assert!((-2048..=2047).contains(&imm), "12-bit immediate out of range: {imm}");
        imm
    }
    #[track_caller]
    pub fn addi(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        self.instr(Instr::AluImm { op: AluOp::Add, rd, rs1, imm: Self::chk12(imm) })
    }
    #[track_caller]
    pub fn andi(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        self.instr(Instr::AluImm { op: AluOp::And, rd, rs1, imm: Self::chk12(imm) })
    }
    #[track_caller]
    pub fn ori(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        self.instr(Instr::AluImm { op: AluOp::Or, rd, rs1, imm: Self::chk12(imm) })
    }
    #[track_caller]
    pub fn xori(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        self.instr(Instr::AluImm { op: AluOp::Xor, rd, rs1, imm: Self::chk12(imm) })
    }
    #[track_caller]
    pub fn slti(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        self.instr(Instr::AluImm { op: AluOp::Slt, rd, rs1, imm: Self::chk12(imm) })
    }
    #[track_caller]
    pub fn sltiu(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        self.instr(Instr::AluImm { op: AluOp::Sltu, rd, rs1, imm: Self::chk12(imm) })
    }
    pub fn slli(&mut self, rd: Reg, rs1: Reg, sh: i32) -> &mut Self {
        self.instr(Instr::AluImm { op: AluOp::Sll, rd, rs1, imm: sh })
    }
    pub fn srli(&mut self, rd: Reg, rs1: Reg, sh: i32) -> &mut Self {
        self.instr(Instr::AluImm { op: AluOp::Srl, rd, rs1, imm: sh })
    }
    pub fn srai(&mut self, rd: Reg, rs1: Reg, sh: i32) -> &mut Self {
        self.instr(Instr::AluImm { op: AluOp::Sra, rd, rs1, imm: sh })
    }

    pub fn add(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::Alu { op: AluOp::Add, rd, rs1, rs2 })
    }
    pub fn sub(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::Alu { op: AluOp::Sub, rd, rs1, rs2 })
    }
    pub fn and(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::Alu { op: AluOp::And, rd, rs1, rs2 })
    }
    pub fn or(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::Alu { op: AluOp::Or, rd, rs1, rs2 })
    }
    pub fn xor(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::Alu { op: AluOp::Xor, rd, rs1, rs2 })
    }
    pub fn sll(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::Alu { op: AluOp::Sll, rd, rs1, rs2 })
    }
    pub fn srl(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::Alu { op: AluOp::Srl, rd, rs1, rs2 })
    }
    pub fn sra(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::Alu { op: AluOp::Sra, rd, rs1, rs2 })
    }
    pub fn slt(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::Alu { op: AluOp::Slt, rd, rs1, rs2 })
    }
    pub fn sltu(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::Alu { op: AluOp::Sltu, rd, rs1, rs2 })
    }

    // ---- RV32M ----------------------------------------------------------

    pub fn mul(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::MulDiv { op: MulOp::Mul, rd, rs1, rs2 })
    }
    pub fn mulh(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::MulDiv { op: MulOp::Mulh, rd, rs1, rs2 })
    }
    pub fn div(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::MulDiv { op: MulOp::Div, rd, rs1, rs2 })
    }
    pub fn rem(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::MulDiv { op: MulOp::Rem, rd, rs1, rs2 })
    }

    // ---- System ---------------------------------------------------------

    pub fn csrrw(&mut self, rd: Reg, csr: u16, rs1: Reg) -> &mut Self {
        self.instr(Instr::Csr { op: CsrOp::Csrrw, rd, rs1, csr })
    }
    pub fn csrrs(&mut self, rd: Reg, csr: u16, rs1: Reg) -> &mut Self {
        self.instr(Instr::Csr { op: CsrOp::Csrrs, rd, rs1, csr })
    }
    pub fn ecall(&mut self) -> &mut Self {
        self.instr(Instr::Ecall)
    }
    pub fn ebreak(&mut self) -> &mut Self {
        self.instr(Instr::Ebreak)
    }
    pub fn wfi(&mut self) -> &mut Self {
        self.instr(Instr::Wfi)
    }
    pub fn nop(&mut self) -> &mut Self {
        self.addi(reg::ZERO, reg::ZERO, 0)
    }

    // ---- Pseudo-instructions --------------------------------------------

    /// `li rd, imm` — 1 or 2 instructions depending on the immediate.
    pub fn li(&mut self, rd: Reg, imm: i32) -> &mut Self {
        if (-2048..=2047).contains(&imm) {
            return self.addi(rd, reg::ZERO, imm);
        }
        let hi = (imm.wrapping_add(0x800)) >> 12;
        let lo = imm.wrapping_sub(hi << 12);
        self.lui(rd, hi);
        if lo != 0 {
            self.addi(rd, rd, lo);
        }
        self
    }
    /// `mv rd, rs`.
    pub fn mv(&mut self, rd: Reg, rs: Reg) -> &mut Self {
        self.addi(rd, rs, 0)
    }
    /// `la rd, label` — position-independent auipc+addi pair.
    pub fn la(&mut self, rd: Reg, target: &str) -> &mut Self {
        self.items.push(Item::La { rd, target: target.to_string() });
        self
    }

    // ---- Xcv (CV32E40P DSP subset) ---------------------------------------

    fn xcv(&mut self, op: XcvOp, sew: Sew, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.instr(Instr::Xcv(XcvInstr { op, sew, rd, rs1, rs2 }))
    }
    /// `cv.sdotsp.b rd, rs1, rs2` — rd += Σ 4 int8 products.
    pub fn cv_sdotsp_b(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.xcv(XcvOp::SdotSp, Sew::E8, rd, rs1, rs2)
    }
    /// `cv.sdotsp.h rd, rs1, rs2` — rd += Σ 2 int16 products.
    pub fn cv_sdotsp_h(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.xcv(XcvOp::SdotSp, Sew::E16, rd, rs1, rs2)
    }
    /// `cv.max.b` — packed int8 max (ReLU against zero).
    pub fn cv_max_b(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.xcv(XcvOp::Max, Sew::E8, rd, rs1, rs2)
    }
    pub fn cv_max_h(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.xcv(XcvOp::Max, Sew::E16, rd, rs1, rs2)
    }
    pub fn cv_max(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.xcv(XcvOp::Max, Sew::E32, rd, rs1, rs2)
    }
    pub fn cv_min(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.xcv(XcvOp::Min, Sew::E32, rd, rs1, rs2)
    }
    pub fn cv_add_b(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.xcv(XcvOp::Add, Sew::E8, rd, rs1, rs2)
    }
    pub fn cv_sra_b(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.xcv(XcvOp::Sra, Sew::E8, rd, rs1, rs2)
    }

    // ---- xvnmc (NM-Carus vector extension) --------------------------------

    /// Generic direct-addressed vector op.
    pub fn v_op(&mut self, op: VOp, vd: u8, vs2: u8, src: VSrc) -> &mut Self {
        self.instr(Instr::Xvnmc(VInstr::Op { op, vd, vs2, src, indirect: false, idx_gpr: 0 }))
    }
    /// Generic indirect-addressed (`[r]`) vector op: register indexes come
    /// from `idx_gpr` at runtime (see [`crate::isa::xvnmc::pack_indexes`]).
    pub fn v_opr(&mut self, op: VOp, idx_gpr: Reg, src: VSrc) -> &mut Self {
        self.instr(Instr::Xvnmc(VInstr::Op { op, vd: 0, vs2: 0, src, indirect: true, idx_gpr }))
    }
    pub fn vadd_vv(&mut self, vd: u8, vs2: u8, vs1: u8) -> &mut Self {
        self.v_op(VOp::Add, vd, vs2, VSrc::V(vs1))
    }
    pub fn vadd_vx(&mut self, vd: u8, vs2: u8, rs1: Reg) -> &mut Self {
        self.v_op(VOp::Add, vd, vs2, VSrc::X(rs1))
    }
    pub fn vmacc_vx(&mut self, vd: u8, vs2: u8, rs1: Reg) -> &mut Self {
        self.v_op(VOp::Macc, vd, vs2, VSrc::X(rs1))
    }
    pub fn vmaccr_vx(&mut self, idx_gpr: Reg, rs1: Reg) -> &mut Self {
        self.v_opr(VOp::Macc, idx_gpr, VSrc::X(rs1))
    }
    pub fn vmul_vv(&mut self, vd: u8, vs2: u8, vs1: u8) -> &mut Self {
        self.v_op(VOp::Mul, vd, vs2, VSrc::V(vs1))
    }
    pub fn vxor_vv(&mut self, vd: u8, vs2: u8, vs1: u8) -> &mut Self {
        self.v_op(VOp::Xor, vd, vs2, VSrc::V(vs1))
    }
    pub fn vmax_vx(&mut self, vd: u8, vs2: u8, rs1: Reg) -> &mut Self {
        self.v_op(VOp::Max, vd, vs2, VSrc::X(rs1))
    }
    pub fn vmin_vv(&mut self, vd: u8, vs2: u8, vs1: u8) -> &mut Self {
        self.v_op(VOp::Min, vd, vs2, VSrc::V(vs1))
    }
    pub fn vmax_vv(&mut self, vd: u8, vs2: u8, vs1: u8) -> &mut Self {
        self.v_op(VOp::Max, vd, vs2, VSrc::V(vs1))
    }
    pub fn vsra_vx(&mut self, vd: u8, vs2: u8, rs1: Reg) -> &mut Self {
        self.v_op(VOp::Sra, vd, vs2, VSrc::X(rs1))
    }
    pub fn vmv_vv(&mut self, vd: u8, vs1: u8) -> &mut Self {
        self.v_op(VOp::Mv, vd, 0, VSrc::V(vs1))
    }
    pub fn vmv_vx(&mut self, vd: u8, rs1: Reg) -> &mut Self {
        self.v_op(VOp::Mv, vd, 0, VSrc::X(rs1))
    }
    pub fn vslidedown_vx(&mut self, vd: u8, vs2: u8, rs1: Reg) -> &mut Self {
        self.v_op(VOp::SlideDown, vd, vs2, VSrc::X(rs1))
    }
    /// `xvnmc.emvv vd[x[idx]], x[rs1]`.
    pub fn emvv(&mut self, vd: u8, idx: Reg, rs1: Reg) -> &mut Self {
        self.instr(Instr::Xvnmc(VInstr::Emvv { vd, idx, rs1 }))
    }
    /// `xvnmc.emvx rd, vs2[x[idx]]`.
    pub fn emvx(&mut self, rd: Reg, vs2: u8, idx: Reg) -> &mut Self {
        self.instr(Instr::Xvnmc(VInstr::Emvx { rd, vs2, idx }))
    }
    /// `xvnmc.vsetvli rd, rs1, e{8,16,32}`.
    pub fn vsetvli(&mut self, rd: Reg, rs1: Reg, sew: Sew) -> &mut Self {
        self.instr(Instr::Xvnmc(VInstr::VsetVli { rd, rs1, vtype: (sew.code() << 3) as u16 }))
    }

    // ---- Assembly --------------------------------------------------------

    /// Resolve labels and emit machine code.
    pub fn assemble(&self) -> Result<Program, AsmError> {
        // Pass 1: item index -> byte offset.
        let mut offsets = Vec::with_capacity(self.items.len());
        let mut pos = 0u32;
        for it in &self.items {
            offsets.push(pos);
            pos += (it.words() * 4) as u32;
        }
        for (l, _) in self.labels.iter() {
            if let Some(stripped) = l.strip_prefix("__dup__") {
                return Err(AsmError::DuplicateLabel(stripped.to_string()));
            }
        }
        let addr_of = |label: &str| -> Result<u32, AsmError> {
            let idx = *self
                .labels
                .get(label)
                .ok_or_else(|| AsmError::UndefinedLabel(label.to_string()))?;
            Ok(self.base + offsets.get(idx).copied().unwrap_or(pos))
        };
        // Pass 2: encode.
        let mut words = Vec::with_capacity(self.items.len());
        for (i, it) in self.items.iter().enumerate() {
            let pc = self.base + offsets[i];
            match it {
                Item::Fixed(instr) => words.push(encode(instr)),
                Item::Word(w) => words.push(*w),
                Item::Branch { op, rs1, rs2, target } => {
                    let off = addr_of(target)? as i64 - pc as i64;
                    if off < -4096 || off > 4094 {
                        return Err(AsmError::BranchOutOfRange { label: target.clone(), offset: off });
                    }
                    words.push(encode(&Instr::Branch {
                        op: *op,
                        rs1: *rs1,
                        rs2: *rs2,
                        off: off as i32,
                    }));
                }
                Item::Jal { rd, target } => {
                    let off = addr_of(target)? as i64 - pc as i64;
                    if off < -(1 << 20) || off >= (1 << 20) {
                        return Err(AsmError::BranchOutOfRange { label: target.clone(), offset: off });
                    }
                    words.push(encode(&Instr::Jal { rd: *rd, off: off as i32 }));
                }
                Item::La { rd, target } => {
                    let abs = addr_of(target)? as i64;
                    let rel = abs - pc as i64;
                    let hi = ((rel + 0x800) >> 12) as i32;
                    let lo = (rel - ((hi as i64) << 12)) as i32;
                    words.push(encode(&Instr::Auipc { rd: *rd, imm: hi << 12 }));
                    words.push(encode(&Instr::AluImm { op: AluOp::Add, rd: *rd, rs1: *rd, imm: lo }));
                }
            }
        }
        let symbols = self
            .labels
            .iter()
            .filter(|(l, _)| !l.starts_with("__dup__"))
            .map(|(l, &idx)| {
                let off = offsets.get(idx).copied().unwrap_or(pos);
                (l.clone(), self.base + off)
            })
            .collect();
        Ok(Program { base: self.base, words, symbols })
    }

    /// Number of instructions (words) emitted so far (La counts as 2).
    pub fn len_words(&self) -> usize {
        self.items.iter().map(|i| i.words()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::reg::*;
    use crate::isa::rv32::decode;

    #[test]
    fn forward_and_backward_branches() {
        let mut a = Asm::new(0x100);
        a.li(A0, 3)
            .label("loop")
            .addi(A0, A0, -1)
            .bne(A0, ZERO, "loop")
            .beq(ZERO, ZERO, "end")
            .nop()
            .label("end")
            .ret();
        let p = a.assemble().unwrap();
        assert_eq!(p.base, 0x100);
        // bne at word 2 → offset -4
        match decode(p.words[2]).unwrap() {
            Instr::Branch { off, .. } => assert_eq!(off, -4),
            other => panic!("{other:?}"),
        }
        // beq at word 3 → skips nop → offset +8
        match decode(p.words[3]).unwrap() {
            Instr::Branch { off, .. } => assert_eq!(off, 8),
            other => panic!("{other:?}"),
        }
        assert_eq!(p.addr_of("end"), Some(0x100 + 5 * 4));
    }

    #[test]
    fn li_expansion() {
        let mut a = Asm::new(0);
        a.li(T0, 5);
        assert_eq!(a.len_words(), 1);
        a.li(T0, 0x12345678);
        assert_eq!(a.len_words(), 3);
        a.li(T1, -1);
        assert_eq!(a.len_words(), 4);
        let p = a.assemble().unwrap();
        // Verify the constants materialize by symbolic execution of lui/addi.
        let mut regs = [0i64; 32];
        for w in &p.words {
            match decode(*w).unwrap() {
                Instr::Lui { rd, imm } => regs[rd as usize] = imm as i64,
                Instr::AluImm { rd, rs1, imm, .. } => {
                    regs[rd as usize] = (regs[rs1 as usize] as i32).wrapping_add(imm) as i64
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(regs[T1 as usize] as i32, -1);
        assert_eq!(regs[T0 as usize] as i32, 0x12345678);
    }

    #[test]
    fn errors_detected() {
        let mut a = Asm::new(0);
        a.j("nowhere");
        assert_eq!(a.assemble().unwrap_err(), AsmError::UndefinedLabel("nowhere".into()));

        let mut a = Asm::new(0);
        a.label("x").nop().label("x");
        assert!(matches!(a.assemble().unwrap_err(), AsmError::DuplicateLabel(_)));
    }

    #[test]
    fn la_is_pc_relative() {
        let mut a = Asm::new(0x2000);
        a.la(A0, "data").ret().label("data").word(0xdeadbeef);
        let p = a.assemble().unwrap();
        assert_eq!(p.words.len(), 4);
        assert_eq!(p.addr_of("data"), Some(0x2000 + 12));
    }

    #[test]
    fn xvnmc_methods_encode() {
        let mut a = Asm::new(0);
        a.vsetvli(T0, A0, Sew::E8).vmacc_vx(2, 1, A1).emvx(A2, 0, A3);
        let p = a.assemble().unwrap();
        for w in &p.words {
            assert_eq!(w & 0x7f, 0x5b, "{w:#010x} not custom-2");
        }
    }

    #[test]
    fn lx_sx_dispatch_on_sew() {
        // The shared SEW helpers emit exactly the width-specific opcodes.
        let mut a = Asm::new(0);
        for sew in Sew::ALL {
            a.lx(sew, T0, 0, A0).sx(sew, T0, 0, A1);
        }
        let mut b = Asm::new(0);
        b.lb(T0, 0, A0)
            .sb(T0, 0, A1)
            .lh(T0, 0, A0)
            .sh(T0, 0, A1)
            .lw(T0, 0, A0)
            .sw(T0, 0, A1);
        assert_eq!(a.assemble().unwrap().words, b.assemble().unwrap().words);
    }
}
