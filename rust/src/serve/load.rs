//! Deterministic load generator for the serve path: seeded Poisson,
//! bursty, and mixed arrival traces over randomized kernel mixes.
//!
//! Everything is a pure function of `(kind, seed, requests)`, built on
//! the fuzzer's splitmix64 [`Rng`] — the same call produces the same
//! trace on every run, which is what makes `serve --selftest` a CI
//! determinism gate. Request "flavors" (target × family × SEW × shape)
//! are **sticky** across a handful of consecutive requests (and across a
//! whole burst), because a gateway's clients repeat themselves — and
//! because without runs of mutually-coalescible requests the batching
//! policy would degenerate to batch-of-one. NM-Carus flavors re-roll the
//! *shape* per request within the family to exercise heterogeneous
//! coalesced batches; NM-Caesar flavors keep the exact kernel (stream
//! tiles replay one rendered micro-op stream per tile).

use crate::fuzz::gen::{rand_kernel, Rng};
use crate::isa::Sew;
use crate::kernels::{Family, Kernel, Target};
use crate::serve::Request;

/// Mean Poisson inter-arrival gap in simulated cycles.
pub const POISSON_MEAN_CYCLES: u64 = 40_000;
/// First retry delay of a closed-loop client after a `rejected`
/// response; doubles per consecutive rejection.
pub const BACKOFF_BASE_CYCLES: u64 = 50_000;
/// Retry delays stop doubling here (capped exponential backoff).
pub const BACKOFF_CAP_CYCLES: u64 = 1_600_000;
/// Gap between burst starts.
pub const BURST_GAP_CYCLES: u64 = 400_000;
/// Requests per burst.
pub const BURST_SIZE: u32 = 8;
/// Intra-burst request spacing.
pub const BURST_SPACING_CYCLES: u64 = 64;

/// Arrival-process shape of a generated trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Exponential inter-arrival gaps (mean [`POISSON_MEAN_CYCLES`]).
    Poisson,
    /// Bursts of [`BURST_SIZE`] back-to-back requests, widely spaced.
    Bursty,
    /// First half Poisson, second half bursty.
    Mixed,
}

impl TraceKind {
    pub fn parse(s: &str) -> Option<TraceKind> {
        match s {
            "poisson" => Some(TraceKind::Poisson),
            "bursty" => Some(TraceKind::Bursty),
            "mixed" => Some(TraceKind::Mixed),
            _ => None,
        }
    }

    pub fn slug(self) -> &'static str {
        match self {
            TraceKind::Poisson => "poisson",
            TraceKind::Bursty => "bursty",
            TraceKind::Mixed => "mixed",
        }
    }
}

/// A sticky request flavor: one client's repeated workload.
#[derive(Debug, Clone, Copy)]
struct Flavor {
    target: Target,
    family: Family,
    sew: Sew,
    kernel: Kernel,
}

fn rand_flavor(rng: &mut Rng) -> Flavor {
    let target = if rng.below(2) == 0 { Target::Caesar } else { Target::Carus };
    let family = Family::ALL[rng.below(Family::ALL.len() as u32) as usize];
    let sew = Sew::ALL[rng.below(3) as usize];
    let kernel =
        rand_kernel(rng, family, target, sew).unwrap_or(Kernel::Add { n: 64 / sew.bytes() });
    Flavor { target, family, sew, kernel }
}

fn request(rng: &mut Rng, id: u64, fl: &Flavor) -> Request {
    // NM-Carus batches coalesce any shape of one family, so re-roll the
    // shape per request; NM-Caesar keeps the flavor's exact kernel.
    let kernel = if fl.target == Target::Carus {
        rand_kernel(rng, fl.family, fl.target, fl.sew).unwrap_or(fl.kernel)
    } else {
        fl.kernel
    };
    Request { id, target: fl.target, kernel, sew: fl.sew, seed: rng.next_u64(), model: None }
}

/// Exponential inter-arrival gap by inverse CDF. `ln` goes through the
/// platform libm, so cross-*platform* bit-identity is not promised — the
/// CI determinism gate compares two runs of the same binary, which is.
fn exp_interval(rng: &mut Rng, mean: u64) -> u64 {
    let u = ((rng.next_u64() >> 11) as f64 + 1.0) / (1u64 << 53) as f64;
    (-(mean as f64) * u.ln()).round() as u64 + 1
}

/// Generate a timestamped request trace, sorted by arrival cycle, ids
/// `1..=requests` in arrival order. Deterministic in `(kind, seed,
/// requests)`.
pub fn gen_trace(kind: TraceKind, seed: u64, requests: u32) -> Vec<(u64, Request)> {
    // Salted so `serve --seed 7` and `fuzz --seed 7` explore
    // unrelated streams.
    let mut rng = Rng(seed ^ 0x5e72_7e5a_11ab_1e5e);
    match kind {
        TraceKind::Poisson => poisson(&mut rng, 1, requests, 0),
        TraceKind::Bursty => bursty(&mut rng, 1, requests, 0),
        TraceKind::Mixed => {
            let half = requests / 2;
            let mut t = poisson(&mut rng, 1, half, 0);
            let at = t.last().map_or(0, |&(c, _)| c) + BURST_GAP_CYCLES;
            t.extend(bursty(&mut rng, half as u64 + 1, requests - half, at));
            t
        }
    }
}

/// One closed-loop client (`--load closed`): a sticky flavor that
/// drifts like the open-loop generator's, at most one outstanding
/// request, exponential think time between completions, and **capped
/// exponential backoff with seeded jitter** after a `rejected` response
/// — the reactive half of the serve contract that an open-loop trace
/// cannot exercise. Everything is a pure function of `(seed, client)`,
/// so the closed-loop selftest stays a byte-determinism gate.
pub struct ClosedClient {
    rng: Rng,
    flavor: Flavor,
    /// Requests left before the flavor re-rolls.
    flavor_left: u32,
    /// Current backoff step; doubles per consecutive rejection.
    backoff: u64,
}

impl ClosedClient {
    /// Client `client` of a fleet seeded with `seed`. Per-client salt on
    /// top of the fleet seed, so the clients explore distinct request
    /// streams while the fleet as a whole stays reproducible.
    pub fn new(seed: u64, client: u32) -> ClosedClient {
        let mut rng = Rng(seed ^ 0xc105_ed00_c11e_4700 ^ ((client as u64) << 32));
        let flavor = rand_flavor(&mut rng);
        let flavor_left = 4 + rng.below(5);
        ClosedClient { rng, flavor, flavor_left, backoff: BACKOFF_BASE_CYCLES }
    }

    /// The next request this client submits; the service loop assigns
    /// the globally-unique `id` (a retry is a *new* request, so every id
    /// is still answered exactly once).
    pub fn next_request(&mut self, id: u64) -> Request {
        if self.flavor_left == 0 {
            self.flavor = rand_flavor(&mut self.rng);
            self.flavor_left = 4 + self.rng.below(5);
        }
        self.flavor_left -= 1;
        request(&mut self.rng, id, &self.flavor)
    }

    /// Think-time gap before this client's next first-attempt
    /// submission (exponential, mean [`POISSON_MEAN_CYCLES`]).
    pub fn think(&mut self) -> u64 {
        exp_interval(&mut self.rng, POISSON_MEAN_CYCLES)
    }

    /// Rejected: the retry delay — the current backoff step plus seeded
    /// jitter of up to half the step (so a rejected burst does not
    /// retry in lockstep) — and the step doubles toward
    /// [`BACKOFF_CAP_CYCLES`].
    pub fn backoff(&mut self) -> u64 {
        let step = self.backoff;
        let jitter = self.rng.next_u64() % (step / 2 + 1);
        self.backoff = (step * 2).min(BACKOFF_CAP_CYCLES);
        step + jitter
    }

    /// Any terminal response (`ok` or `error`) resets the backoff.
    pub fn reset(&mut self) {
        self.backoff = BACKOFF_BASE_CYCLES;
    }
}

fn poisson(rng: &mut Rng, first_id: u64, n: u32, start: u64) -> Vec<(u64, Request)> {
    let mut out = Vec::with_capacity(n as usize);
    let mut now = start;
    let mut flavor = rand_flavor(rng);
    let mut left = 4 + rng.below(5); // sticky for 4–8 requests
    for i in 0..n {
        now += exp_interval(rng, POISSON_MEAN_CYCLES);
        if left == 0 {
            flavor = rand_flavor(rng);
            left = 4 + rng.below(5);
        }
        left -= 1;
        out.push((now, request(rng, first_id + i as u64, &flavor)));
    }
    out
}

fn bursty(rng: &mut Rng, first_id: u64, n: u32, start: u64) -> Vec<(u64, Request)> {
    let mut out = Vec::with_capacity(n as usize);
    let mut burst_at = start;
    let mut id = first_id;
    let mut done = 0u32;
    while done < n {
        let flavor = rand_flavor(rng); // one flavor per burst
        let size = BURST_SIZE.min(n - done);
        for j in 0..size {
            out.push((burst_at + j as u64 * BURST_SPACING_CYCLES, request(rng, id, &flavor)));
            id += 1;
        }
        done += size;
        burst_at += BURST_GAP_CYCLES;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_sorted_and_fully_idd() {
        for kind in [TraceKind::Poisson, TraceKind::Bursty, TraceKind::Mixed] {
            let a = gen_trace(kind, 7, 64);
            let b = gen_trace(kind, 7, 64);
            assert_eq!(a, b, "{kind:?}: same seed, same trace");
            assert_ne!(a, gen_trace(kind, 8, 64), "{kind:?}: seed matters");
            assert_eq!(a.len(), 64);
            for w in a.windows(2) {
                assert!(w[0].0 <= w[1].0, "{kind:?}: sorted by arrival");
            }
            let ids: Vec<u64> = a.iter().map(|(_, r)| r.id).collect();
            assert_eq!(ids, (1..=64).collect::<Vec<u64>>(), "{kind:?}");
            for (_, r) in &a {
                assert_ne!(r.target, Target::Cpu);
                assert_eq!(r.kernel.validate(r.target, r.sew), Ok(()), "{r:?}");
            }
        }
    }

    #[test]
    fn flavors_are_sticky_enough_to_coalesce_and_diverse_enough_to_mix() {
        let trace = gen_trace(TraceKind::Mixed, 7, 256);
        let mut coalescible_adjacent = 0;
        let mut families = std::collections::HashSet::new();
        let mut targets = std::collections::HashSet::new();
        for w in trace.windows(2) {
            if crate::serve::coalescible(&w[0].1, &w[1].1) {
                coalescible_adjacent += 1;
            }
        }
        for (_, r) in &trace {
            families.insert(r.kernel.family());
            targets.insert(r.target);
        }
        // Sticky: most adjacent pairs can share a batch; diverse: the
        // mix still crosses targets and several families.
        assert!(coalescible_adjacent * 2 > trace.len(), "{coalescible_adjacent}/256");
        assert!(families.len() >= 3, "{families:?}");
        assert_eq!(targets.len(), 2, "{targets:?}");
    }

    #[test]
    fn closed_clients_are_deterministic_and_emit_valid_requests() {
        let mut a = ClosedClient::new(7, 3);
        let mut b = ClosedClient::new(7, 3);
        for id in 1..=32u64 {
            assert_eq!(a.next_request(id), b.next_request(id), "same (seed, client), same stream");
            assert_eq!(a.think(), b.think());
        }
        // Distinct clients of one fleet explore distinct streams.
        let mut c = ClosedClient::new(7, 4);
        let r3 = ClosedClient::new(7, 3).next_request(1);
        assert_ne!(c.next_request(1), r3);
        // Every emitted request is servable.
        let mut cl = ClosedClient::new(11, 0);
        for id in 1..=64u64 {
            let r = cl.next_request(id);
            assert_eq!(r.id, id);
            assert_ne!(r.target, Target::Cpu);
            assert_eq!(r.kernel.validate(r.target, r.sew), Ok(()), "{r:?}");
        }
    }

    #[test]
    fn backoff_doubles_with_jitter_caps_and_resets() {
        let mut c = ClosedClient::new(7, 0);
        let mut step = BACKOFF_BASE_CYCLES;
        for i in 0..8 {
            let delay = c.backoff();
            // Delay = current step + jitter in 0..=step/2.
            assert!(delay >= step && delay <= step + step / 2, "attempt {i}: {delay} vs {step}");
            step = (step * 2).min(BACKOFF_CAP_CYCLES);
        }
        assert_eq!(step, BACKOFF_CAP_CYCLES, "the step must have hit the cap");
        let capped = c.backoff();
        assert!(capped >= BACKOFF_CAP_CYCLES && capped <= BACKOFF_CAP_CYCLES * 3 / 2);
        // A terminal response resets the ladder.
        c.reset();
        let fresh = c.backoff();
        assert!(
            fresh >= BACKOFF_BASE_CYCLES && fresh <= BACKOFF_BASE_CYCLES * 3 / 2,
            "{fresh}"
        );
    }

    #[test]
    fn burst_timing_is_bursty() {
        let trace = gen_trace(TraceKind::Bursty, 7, 32);
        // 4 bursts of 8: intra-burst gaps are tiny, inter-burst huge.
        let gaps: Vec<u64> = trace.windows(2).map(|w| w[1].0 - w[0].0).collect();
        let big = gaps.iter().filter(|&&g| g >= BURST_GAP_CYCLES / 2).count();
        let small = gaps.iter().filter(|&&g| g == BURST_SPACING_CYCLES).count();
        assert_eq!(big, 3, "{gaps:?}");
        assert_eq!(small, 28, "{gaps:?}");
    }
}
