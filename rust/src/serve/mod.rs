//! `heeperator serve`: a long-running batch-inference service over the
//! multi-tile scheduler.
//!
//! The paper positions NM-Caesar/NM-Carus as *edge-node* accelerators,
//! and edge gateways see continuous request streams, not one-shot kernel
//! invocations. This module is the system-software layer that gap
//! implies: requests arrive as JSONL (stdin or TCP), pass **admission
//! control** against a bounded queue, are **coalesced** into
//! same-family batches by a batching policy (max batch size + max
//! linger), compiled through [`sched::plan_jobs`], co-simulated with
//! [`sched::run_planned`] across the configured tile count, and answered
//! with per-request JSONL responses.
//!
//! Two execution paths share the policy code:
//!
//! - [`run_trace`] — the **virtual-time** path: arrivals carry explicit
//!   cycle timestamps (from [`load::gen_trace`] or a test), and the
//!   service advances a simulated clock, so queueing + execution latency
//!   is exact and **deterministic** — the same trace produces
//!   byte-identical responses and summary JSON on every run. CI gates on
//!   this path (`serve --selftest`).
//! - [`serve_stream`] — the **live** path: a listener thread parses and
//!   admits requests while a coalescer thread drains the queue
//!   (`std::thread::scope`; the repo is std-only — no tokio). Wall-clock
//!   arrival order is not deterministic, so live responses report the
//!   simulated batch makespan as their latency and the summary omits
//!   nothing else.
//!
//! A malformed or overload-rejected request must never take the service
//! down: every planner failure is a typed [`sched::SchedError`] since the
//! staging paths were hardened (see [`sched`]), and the executor
//! additionally wraps the co-simulation in `catch_unwind` so even a
//! modeling bug degrades to an error response.

pub mod load;

use std::collections::VecDeque;
use std::io::{BufRead, Write};
use std::net::TcpListener;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

use crate::fuzz::{
    family_slug, json_escape, json_str, json_u64, kernel_from, shape_of, target_slug,
};
use crate::isa::Sew;
use crate::kernels::{Family, Kernel, Target};
use crate::sched::{self, plan_jobs, run_planned, BatchRunResult};

/// Schema tag of the `--json` summary ([`summary_json`]).
pub const SUMMARY_SCHEMA: &str = "heeperator-serve-v1";

/// Service configuration: tile count, admission bound, batching policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeConfig {
    /// Simulated NMC tiles behind the service.
    pub tiles: usize,
    /// Admission control: requests arriving at a full queue are rejected
    /// with a typed overload response, never dropped silently.
    pub queue_cap: usize,
    /// Close a batch as soon as this many requests are queued.
    pub max_batch: usize,
    /// Close a batch once its oldest request has waited this long
    /// (virtual-time path; the live path lingers a few milliseconds).
    pub linger_cycles: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { tiles: 4, queue_cap: 64, max_batch: 8, linger_cycles: 100_000 }
    }
}

/// One admitted workload request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub target: Target,
    pub kernel: Kernel,
    pub sew: Sew,
    /// Golden-input seed (defaults to `id` when the line omits it).
    pub seed: u64,
}

/// One per-request JSONL response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    /// The request's batch ran and its output matched the golden
    /// reference. `latency_cycles` is arrival→completion on the
    /// virtual-time path and the batch makespan on the live path.
    Ok { id: u64, latency_cycles: u64, batch: u32, batch_cycles: u64 },
    /// Admission control: the bounded queue was full on arrival.
    Rejected { id: u64, queue_depth: usize },
    /// The line did not parse, the shape failed validation, or the
    /// planner returned a typed [`sched::SchedError`].
    Error { id: u64, error: String },
}

impl Response {
    pub fn id(&self) -> u64 {
        match self {
            Response::Ok { id, .. }
            | Response::Rejected { id, .. }
            | Response::Error { id, .. } => *id,
        }
    }

    /// One JSONL line (no trailing newline).
    pub fn render(&self) -> String {
        match self {
            Response::Ok { id, latency_cycles, batch, batch_cycles } => format!(
                "{{\"id\":{id},\"status\":\"ok\",\"latency_cycles\":{latency_cycles},\
                 \"batch\":{batch},\"batch_cycles\":{batch_cycles}}}"
            ),
            Response::Rejected { id, queue_depth } => format!(
                "{{\"id\":{id},\"status\":\"rejected\",\"reason\":\"overload\",\
                 \"queue_depth\":{queue_depth}}}"
            ),
            Response::Error { id, error } => {
                format!("{{\"id\":{id},\"status\":\"error\",\"error\":\"{}\"}}", json_escape(error))
            }
        }
    }
}

/// Parse one JSONL request line. Required keys: `id`, `target`,
/// `family`, `sew`; optional: `n`/`p`/`f` (shape dims, default 0) and
/// `seed` (default `id`). Shape validation runs here so an invalid
/// request is answered immediately and can never poison a batch.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let id = json_u64(line, "id")?;
    let t = json_str(line, "target")?;
    let target = Target::parse(t).ok_or_else(|| format!("unknown target {t:?}"))?;
    if target == Target::Cpu {
        return Err("the CPU is the host, never a serve target".to_string());
    }
    let fam = json_str(line, "family")?;
    let family = Family::parse(fam).ok_or_else(|| format!("unknown family {fam:?}"))?;
    let sew = match json_u64(line, "sew")? {
        8 => Sew::E8,
        16 => Sew::E16,
        32 => Sew::E32,
        b => return Err(format!("unknown sew {b} (expected 8, 16, or 32)")),
    };
    let dim = |key| json_u64(line, key).unwrap_or(0) as u32;
    let kernel = kernel_from(family, dim("n"), dim("p"), dim("f"));
    kernel.validate(target, sew).map_err(|e| format!("invalid shape: {e}"))?;
    let seed = json_u64(line, "seed").unwrap_or(id);
    Ok(Request { id, target, kernel, sew, seed })
}

/// Render a request back to its JSONL line (the exact inverse of
/// [`parse_request`]) — the load generator and tests feed the live path
/// through this.
pub fn render_request(r: &Request) -> String {
    let (n, p, f) = shape_of(r.kernel);
    format!(
        "{{\"id\":{},\"target\":\"{}\",\"family\":\"{}\",\"sew\":{},\"n\":{n},\"p\":{p},\
         \"f\":{f},\"seed\":{}}}",
        r.id,
        target_slug(r.target),
        family_slug(r.kernel.family()),
        r.sew.bits(),
        r.seed
    )
}

/// Can `b` join a batch headed by `a`? One target and SEW per batch;
/// autonomous NM-Carus tiles take any shape of one family (the shape
/// travels in the per-workload argument words), stream-executed
/// NM-Caesar tiles replay one rendered micro-op stream per tile, so they
/// require the exact kernel.
pub fn coalescible(a: &Request, b: &Request) -> bool {
    if a.target != b.target || a.sew != b.sew {
        return false;
    }
    match a.target {
        Target::Caesar => a.kernel == b.kernel,
        _ => a.kernel.family() == b.kernel.family(),
    }
}

/// Compile and co-simulate one coalesced batch. Planner failures come
/// back as the typed [`sched::SchedError`] message; a panic inside the
/// co-simulation (a modeling bug — `run_planned` asserts golden
/// byte-identity) is caught so the service answers instead of dying.
fn execute(batch: &[Request], tiles: usize) -> Result<BatchRunResult, String> {
    let jobs: Vec<(Kernel, u64)> = batch.iter().map(|r| (r.kernel, r.seed)).collect();
    let plan = plan_jobs(batch[0].target, batch[0].sew, &jobs, tiles)
        .map_err(|e: sched::SchedError| e.to_string())?;
    std::panic::catch_unwind(AssertUnwindSafe(|| run_planned(&plan)))
        .map_err(|_| "internal: co-simulation panicked (modeling bug)".to_string())
}

/// Accumulated service statistics — everything the summary reports.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub errored: u64,
    pub batches: u64,
    /// Virtual-time path: the simulated clock at drain; live path: the
    /// sum of batch makespans.
    pub sim_cycles: u64,
    /// Per-completed-request latency in simulated cycles.
    pub latencies: Vec<u64>,
    pub batch_sizes: Vec<u32>,
    /// Queue depth sampled at each batch close — "queue depth over time".
    pub depth_samples: Vec<u32>,
    /// Busy cycles per configured tile, summed over batches.
    pub tile_busy: Vec<u64>,
    /// Sum of batch makespans (the window tiles could have been busy).
    pub busy_window: u64,
}

impl ServeStats {
    /// Nearest-rank percentile of the completed-request latencies
    /// (`q` in 0..=1); 0 when nothing completed.
    pub fn latency_percentile(&self, q: f64) -> u64 {
        if self.latencies.is_empty() {
            return 0;
        }
        let mut xs = self.latencies.clone();
        xs.sort_unstable();
        let idx = ((q * xs.len() as f64).ceil() as usize).max(1) - 1;
        xs[idx.min(xs.len() - 1)]
    }

    pub fn latency_max(&self) -> u64 {
        self.latencies.iter().copied().max().unwrap_or(0)
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().map(|&b| b as f64).sum::<f64>() / self.batch_sizes.len() as f64
    }

    pub fn queue_depth_max(&self) -> u32 {
        self.depth_samples.iter().copied().max().unwrap_or(0)
    }

    pub fn queue_depth_mean(&self) -> f64 {
        if self.depth_samples.is_empty() {
            return 0.0;
        }
        self.depth_samples.iter().map(|&d| d as f64).sum::<f64>() / self.depth_samples.len() as f64
    }

    /// Fraction of the service window tile `i` spent computing.
    /// Out-of-range indices answer 0.0, like
    /// [`BatchRunResult::utilization`].
    pub fn utilization(&self, i: usize) -> f64 {
        self.tile_busy.get(i).map_or(0.0, |&b| b as f64 / self.sim_cycles.max(1) as f64)
    }

    /// `hist[k-1]` = number of closed batches of size `k`.
    pub fn batch_size_histogram(&self, max_batch: usize) -> Vec<u32> {
        let mut hist = vec![0u32; max_batch.max(1)];
        for &b in &self.batch_sizes {
            let slot = (b as usize).clamp(1, hist.len());
            hist[slot - 1] += 1;
        }
        hist
    }
}

/// The machine-readable summary CI gates on (`--json`). Deterministic
/// key order and fixed float precision: the same stats render to the
/// same bytes.
pub fn summary_json(stats: &ServeStats, cfg: &ServeConfig, trace: &str, seed: u64) -> String {
    let join_u32 = |xs: &[u32]| {
        xs.iter().map(u32::to_string).collect::<Vec<_>>().join(",")
    };
    let util: Vec<String> =
        (0..cfg.tiles).map(|i| format!("{:.6}", stats.utilization(i))).collect();
    format!(
        "{{\n  \"schema\": \"{SUMMARY_SCHEMA}\",\n  \"trace\": \"{}\",\n  \"seed\": {seed},\n  \
         \"tiles\": {},\n  \"queue_cap\": {},\n  \"max_batch\": {},\n  \"linger_cycles\": {},\n  \
         \"requests\": {},\n  \"completed\": {},\n  \"rejected\": {},\n  \"errored\": {},\n  \
         \"batches\": {},\n  \"sim_cycles\": {},\n  \"p50_latency_cycles\": {},\n  \
         \"p95_latency_cycles\": {},\n  \"p99_latency_cycles\": {},\n  \
         \"max_latency_cycles\": {},\n  \"mean_batch_size\": {:.3},\n  \
         \"queue_depth_max\": {},\n  \"queue_depth_mean\": {:.3},\n  \
         \"per_tile_utilization\": [{}],\n  \"batch_size_histogram\": [{}],\n  \
         \"queue_depth_samples\": [{}]\n}}\n",
        json_escape(trace),
        cfg.tiles,
        cfg.queue_cap,
        cfg.max_batch,
        cfg.linger_cycles,
        stats.requests,
        stats.completed,
        stats.rejected,
        stats.errored,
        stats.batches,
        stats.sim_cycles,
        stats.latency_percentile(0.50),
        stats.latency_percentile(0.95),
        stats.latency_percentile(0.99),
        stats.latency_max(),
        stats.mean_batch_size(),
        stats.queue_depth_max(),
        stats.queue_depth_mean(),
        util.join(","),
        join_u32(&stats.batch_size_histogram(cfg.max_batch)),
        join_u32(&stats.depth_samples),
    )
}

/// Run a timestamped trace through the service on a **virtual clock**:
/// arrivals are admitted when the clock passes their cycle, batches
/// close on the policy (full / lingered / input drained), execution
/// advances the clock by the co-simulated makespan, and each completed
/// request's latency is arrival→batch-completion in simulated cycles.
/// Fully deterministic in the trace — the CI determinism gate and the
/// e2e tests run here.
pub fn run_trace(
    cfg: &ServeConfig,
    trace: &[(u64, Request)],
    mut on_response: impl FnMut(&Response),
) -> ServeStats {
    let mut stats = ServeStats {
        requests: trace.len() as u64,
        tile_busy: vec![0; cfg.tiles],
        ..Default::default()
    };
    let mut queue: VecDeque<(u64, Request)> = VecDeque::new();
    let mut now: u64 = 0;
    let mut next = 0usize;

    loop {
        // Admission: every arrival the clock has passed, in trace order.
        while next < trace.len() && trace[next].0 <= now {
            let (at, req) = trace[next];
            next += 1;
            if queue.len() >= cfg.queue_cap {
                stats.rejected += 1;
                on_response(&Response::Rejected { id: req.id, queue_depth: queue.len() });
            } else {
                queue.push_back((at, req));
            }
        }

        if queue.is_empty() {
            match trace.get(next) {
                Some(&(at, _)) => {
                    now = now.max(at);
                    continue;
                }
                None => break,
            }
        }

        // Batching policy: close when full, when the oldest request has
        // lingered out, or when no further arrival can grow the batch.
        let oldest = queue[0].0;
        let drained = next == trace.len();
        let full = queue.len() >= cfg.max_batch;
        let lingered = now >= oldest.saturating_add(cfg.linger_cycles);
        if !(full || lingered || drained) {
            // Sleep until whichever comes first: the next arrival or the
            // oldest request's linger deadline.
            let deadline = oldest.saturating_add(cfg.linger_cycles);
            now = deadline.min(trace[next].0).max(now + 1);
            continue;
        }

        // Close the longest head-compatible prefix (FIFO: no reordering).
        let head = queue[0].1;
        let mut take = 1;
        while take < queue.len().min(cfg.max_batch) && coalescible(&head, &queue[take].1) {
            take += 1;
        }
        stats.depth_samples.push(queue.len() as u32);
        let batch: Vec<(u64, Request)> = queue.drain(..take).collect();
        let reqs: Vec<Request> = batch.iter().map(|&(_, r)| r).collect();
        match execute(&reqs, cfg.tiles) {
            Ok(res) => {
                let end = now + res.cycles;
                stats.batches += 1;
                stats.batch_sizes.push(reqs.len() as u32);
                stats.busy_window += res.cycles;
                for (i, busy) in stats.tile_busy.iter_mut().enumerate() {
                    *busy += res.per_tile.get(i).map_or(0, |t| t.busy_cycles);
                }
                for &(at, r) in &batch {
                    let lat = end - at;
                    stats.completed += 1;
                    stats.latencies.push(lat);
                    on_response(&Response::Ok {
                        id: r.id,
                        latency_cycles: lat,
                        batch: reqs.len() as u32,
                        batch_cycles: res.cycles,
                    });
                }
                now = end;
            }
            Err(e) => {
                // Planning is host-side and cheap; an errored batch
                // consumes no simulated time, only its queue slots.
                for &(_, r) in &batch {
                    stats.errored += 1;
                    on_response(&Response::Error { id: r.id, error: e.clone() });
                }
            }
        }
    }
    stats.sim_cycles = now;
    stats
}

/// Generate a seeded trace and run it on the virtual clock — the
/// `serve --selftest` body, also used by the e2e tests.
pub fn selftest(
    cfg: &ServeConfig,
    kind: load::TraceKind,
    seed: u64,
    requests: u32,
) -> (ServeStats, Vec<Response>) {
    let trace = load::gen_trace(kind, seed, requests);
    let mut responses = Vec::new();
    let stats = run_trace(cfg, &trace, |r| responses.push(r.clone()));
    (stats, responses)
}

/// The live path: a **listener** thread parses JSONL request lines from
/// `input` and admits them against the bounded queue (immediate
/// `rejected`/`error` responses on overflow or parse failure), while the
/// calling thread **coalesces** and executes batches, writing `ok`
/// responses as batches complete. Returns when the input reaches EOF and
/// the queue drains. Response *content* is deterministic; arrival
/// interleaving (and hence batching) is wall-clock, so live responses
/// report the batch makespan as their latency.
pub fn serve_stream<R: BufRead + Send, W: Write + Send>(
    cfg: &ServeConfig,
    input: R,
    output: W,
) -> ServeStats {
    let out = Mutex::new(output);
    // (queue, input closed)
    let state: Mutex<(VecDeque<Request>, bool)> = Mutex::new((VecDeque::new(), false));
    let cv = Condvar::new();
    let requests = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let parse_errors = AtomicU64::new(0);
    let mut stats = ServeStats { tile_busy: vec![0; cfg.tiles], ..Default::default() };

    std::thread::scope(|s| {
        let (out, state, cv) = (&out, &state, &cv);
        let (requests, rejected, parse_errors) = (&requests, &rejected, &parse_errors);
        s.spawn(move || {
            for line in input.lines() {
                let Ok(line) = line else { break };
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                requests.fetch_add(1, Ordering::Relaxed);
                match parse_request(line) {
                    Err(e) => {
                        parse_errors.fetch_add(1, Ordering::Relaxed);
                        let id = json_u64(line, "id").unwrap_or(0);
                        let resp = Response::Error { id, error: e };
                        let _ = writeln!(out.lock().unwrap(), "{}", resp.render());
                    }
                    Ok(req) => {
                        let mut st = state.lock().unwrap();
                        if st.0.len() >= cfg.queue_cap {
                            let depth = st.0.len();
                            drop(st);
                            rejected.fetch_add(1, Ordering::Relaxed);
                            let resp = Response::Rejected { id: req.id, queue_depth: depth };
                            let _ = writeln!(out.lock().unwrap(), "{}", resp.render());
                        } else {
                            st.0.push_back(req);
                            drop(st);
                            cv.notify_all();
                        }
                    }
                }
            }
            state.lock().unwrap().1 = true;
            cv.notify_all();
        });

        // Coalescer/executor: this thread.
        loop {
            let mut st = state.lock().unwrap();
            while st.0.is_empty() && !st.1 {
                st = cv.wait(st).unwrap();
            }
            if st.0.is_empty() && st.1 {
                break;
            }
            if st.0.len() < cfg.max_batch && !st.1 {
                // Linger briefly for a fuller batch while input is live.
                let (g, _) = cv.wait_timeout(st, std::time::Duration::from_millis(20)).unwrap();
                st = g;
                if st.0.is_empty() {
                    continue;
                }
            }
            let head = st.0[0];
            let mut take = 1;
            while take < st.0.len().min(cfg.max_batch) && coalescible(&head, &st.0[take]) {
                take += 1;
            }
            stats.depth_samples.push(st.0.len() as u32);
            let batch: Vec<Request> = st.0.drain(..take).collect();
            drop(st);
            cv.notify_all();
            match execute(&batch, cfg.tiles) {
                Ok(res) => {
                    stats.batches += 1;
                    stats.batch_sizes.push(batch.len() as u32);
                    stats.busy_window += res.cycles;
                    stats.sim_cycles += res.cycles;
                    for (i, busy) in stats.tile_busy.iter_mut().enumerate() {
                        *busy += res.per_tile.get(i).map_or(0, |t| t.busy_cycles);
                    }
                    let mut w = out.lock().unwrap();
                    for r in &batch {
                        stats.completed += 1;
                        stats.latencies.push(res.cycles);
                        let resp = Response::Ok {
                            id: r.id,
                            latency_cycles: res.cycles,
                            batch: batch.len() as u32,
                            batch_cycles: res.cycles,
                        };
                        let _ = writeln!(w, "{}", resp.render());
                    }
                }
                Err(e) => {
                    let mut w = out.lock().unwrap();
                    for r in &batch {
                        stats.errored += 1;
                        let resp = Response::Error { id: r.id, error: e.clone() };
                        let _ = writeln!(w, "{}", resp.render());
                    }
                }
            }
        }
    });

    stats.requests = requests.load(Ordering::Relaxed);
    stats.rejected = rejected.load(Ordering::Relaxed);
    stats.errored += parse_errors.load(Ordering::Relaxed);
    let _ = out.lock().unwrap().flush();
    stats
}

/// Accept **one** TCP connection and serve it to completion (EOF on the
/// read half ends the session). The CLI loops this for sequential
/// connections; tests bind an ephemeral port and connect once.
pub fn serve_one_tcp(cfg: &ServeConfig, listener: &TcpListener) -> std::io::Result<ServeStats> {
    let (stream, _) = listener.accept()?;
    let input = std::io::BufReader::new(stream.try_clone()?);
    Ok(serve_stream(cfg, input, stream))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, target: Target, kernel: Kernel, sew: Sew) -> Request {
        Request { id, target, kernel, sew, seed: id }
    }

    #[test]
    fn request_lines_roundtrip_exactly() {
        let cases = [
            req(1, Target::Carus, Kernel::Add { n: 64 }, Sew::E32),
            req(2, Target::Caesar, Kernel::Matmul { p: 16 }, Sew::E16),
            req(9000, Target::Carus, Kernel::Conv2d { n: 16, f: 3 }, Sew::E8),
        ];
        for r in cases {
            let line = render_request(&r);
            assert_eq!(parse_request(&line), Ok(r), "{line}");
        }
        // Omitted seed defaults to the id; omitted dims default to 0.
        let r = parse_request(r#"{"id":5,"target":"carus","family":"add","sew":8,"n":64}"#)
            .unwrap();
        assert_eq!(r.seed, 5);
        assert_eq!(r.kernel, Kernel::Add { n: 64 });
    }

    #[test]
    fn malformed_requests_are_typed_errors() {
        let bad = [
            (r#"{"target":"carus","family":"add","sew":8,"n":64}"#, "id"),
            (r#"{"id":1,"target":"cpu","family":"add","sew":8,"n":64}"#, "host"),
            (r#"{"id":1,"target":"carus","family":"frob","sew":8,"n":64}"#, "family"),
            (r#"{"id":1,"target":"carus","family":"add","sew":7,"n":64}"#, "sew"),
            (r#"{"id":1,"target":"carus","family":"add","sew":8,"n":0}"#, "invalid shape"),
            ("not json at all", "id"),
        ];
        for (line, needle) in bad {
            let e = parse_request(line).unwrap_err();
            assert!(e.contains(needle), "{line} -> {e}");
        }
    }

    #[test]
    fn coalescing_rules_follow_the_execution_models() {
        let a = req(1, Target::Carus, Kernel::Add { n: 64 }, Sew::E32);
        // NM-Carus: any shape of one family.
        assert!(coalescible(&a, &req(2, Target::Carus, Kernel::Add { n: 32 }, Sew::E32)));
        assert!(!coalescible(&a, &req(2, Target::Carus, Kernel::Relu { n: 64 }, Sew::E32)));
        // One SEW and one target per batch.
        assert!(!coalescible(&a, &req(2, Target::Carus, Kernel::Add { n: 64 }, Sew::E8)));
        assert!(!coalescible(&a, &req(2, Target::Caesar, Kernel::Add { n: 64 }, Sew::E32)));
        // NM-Caesar: the exact kernel (one rendered stream per tile).
        let c = req(1, Target::Caesar, Kernel::Add { n: 64 }, Sew::E32);
        assert!(coalescible(&c, &req(2, Target::Caesar, Kernel::Add { n: 64 }, Sew::E32)));
        assert!(!coalescible(&c, &req(2, Target::Caesar, Kernel::Add { n: 32 }, Sew::E32)));
    }

    #[test]
    fn percentiles_are_nearest_rank_and_bounded() {
        let mut s = ServeStats::default();
        assert_eq!(s.latency_percentile(0.99), 0);
        s.latencies = vec![50, 10, 40, 20, 30];
        assert_eq!(s.latency_percentile(0.50), 30);
        assert_eq!(s.latency_percentile(0.95), 50);
        assert_eq!(s.latency_percentile(0.99), 50);
        assert_eq!(s.latency_max(), 50);
        assert!(s.latency_percentile(0.50) <= s.latency_percentile(0.95));
        // Out-of-range utilization indices answer 0.0.
        assert_eq!(s.utilization(usize::MAX), 0.0);
    }

    #[test]
    fn run_trace_batches_and_answers_every_request() {
        let cfg = ServeConfig { tiles: 2, ..Default::default() };
        let a = req(1, Target::Carus, Kernel::Add { n: 64 }, Sew::E32);
        let b = req(2, Target::Carus, Kernel::Add { n: 32 }, Sew::E32);
        let mut responses = Vec::new();
        let stats = run_trace(&cfg, &[(0, a), (0, b)], |r| responses.push(r.clone()));
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.rejected + stats.errored, 0);
        assert!(stats.sim_cycles > 0);
        assert_eq!(responses.len(), 2);
        for r in &responses {
            assert!(matches!(r, Response::Ok { batch: 2, .. }), "{r:?}");
            assert!(r.render().contains("\"status\":\"ok\""));
        }
        // Both tiles saw work (two workloads round-robin across two tiles).
        assert!(stats.utilization(0) > 0.0 && stats.utilization(1) > 0.0);
    }

    #[test]
    fn summary_json_is_deterministic_and_carries_the_gated_keys() {
        let cfg = ServeConfig::default();
        let (stats, _) = selftest(&cfg, load::TraceKind::Mixed, 7, 24);
        let a = summary_json(&stats, &cfg, "mixed", 7);
        let (stats2, _) = selftest(&cfg, load::TraceKind::Mixed, 7, 24);
        let b = summary_json(&stats2, &cfg, "mixed", 7);
        assert_eq!(a, b, "same seed, same bytes");
        for key in [
            "\"schema\": \"heeperator-serve-v1\"",
            "\"p50_latency_cycles\"",
            "\"p95_latency_cycles\"",
            "\"p99_latency_cycles\"",
            "\"per_tile_utilization\"",
            "\"batch_size_histogram\"",
            "\"queue_depth_samples\"",
            "\"rejected\"",
        ] {
            assert!(a.contains(key), "missing {key} in {a}");
        }
    }
}
