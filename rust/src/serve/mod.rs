//! `heeperator serve`: a long-running batch-inference service over the
//! multi-tile scheduler.
//!
//! The paper positions NM-Caesar/NM-Carus as *edge-node* accelerators,
//! and edge gateways see continuous request streams, not one-shot kernel
//! invocations. This module is the system-software layer that gap
//! implies: requests arrive as JSONL (stdin or TCP), pass **admission
//! control** against a bounded queue, are **coalesced** into
//! same-family batches by a batching policy (max batch size + max
//! linger), compiled through [`sched::plan_jobs`], co-simulated with
//! [`sched::run_planned`] across the configured tile count, and answered
//! with per-request JSONL responses.
//!
//! The execution paths share the policy code:
//!
//! - [`run_trace`] — the **virtual-time** path: arrivals carry explicit
//!   cycle timestamps (from [`load::gen_trace`] or a test), and the
//!   service advances a simulated clock, so queueing + execution latency
//!   is exact and **deterministic** — the same trace produces
//!   byte-identical responses and summary JSON on every run. CI gates on
//!   this path (`serve --selftest`).
//! - [`run_closed`] — the virtual-time **closed-loop** path (`--load
//!   closed`): instead of replaying a pre-generated trace, a fleet of
//!   [`load::ClosedClient`]s reacts to its own responses — at most one
//!   outstanding request each, exponential think time, and capped
//!   exponential backoff with seeded jitter after a `rejected` answer.
//!   Equally deterministic, equally CI-gated.
//! - [`serve_stream`] / [`serve_tcp`] — the **live** path
//!   (`std::thread::scope`; the repo is std-only — no tokio). A reader
//!   thread per connection parses and admits requests against the one
//!   shared bounded queue (up to `conns` simultaneous TCP connections;
//!   one past the cap gets a typed busy rejection), and a pool of
//!   `workers` worker threads — each owning pre-warmed, recyclable
//!   [`Soc`] replicas — claims coalesced batches and executes them **in
//!   parallel**, so wall-clock throughput scales with host cores.
//!   Responses are routed back to the originating connection and
//!   delivered in that connection's request order; the per-batch
//!   *simulated* timing/energy stays bit-identical to the serial path
//!   ([`sched::run_planned_on`] recycles the replica to the
//!   fresh-construction state before every batch). Wall-clock arrival
//!   order is not deterministic, so live responses report the simulated
//!   batch makespan as their latency.
//!
//! Request lines speak the unified [`crate::spec`] vocabulary (one
//! parser for the `(target, family, sew, n, p, f, seed)` tuple across
//! every surface), and a line may instead carry `{"model": ...}` — a
//! multi-layer graph spec ([`Graph::parse`]) compiled onto the service's
//! tiles and executed by the resident-tensor pipeline executor
//! ([`pipeline::run_model_on`]), answered with a per-layer cycle
//! breakdown ([`Response::ModelOk`]).
//!
//! A malformed or overload-rejected request must never take the service
//! down: every planner failure is a typed [`sched::SchedError`] since the
//! staging paths were hardened (see [`sched`]), and the executor
//! additionally wraps the co-simulation in `catch_unwind` so even a
//! modeling bug degrades to an error response.

pub mod load;

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, Write};
use std::net::TcpListener;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::graph::{self, Graph, Pipeline};
use crate::isa::Sew;
use crate::kernels::{Kernel, Target};
use crate::sched::{self, pipeline, plan_jobs, run_planned, run_planned_on, BatchRunResult};
use crate::soc::{Soc, TileKind};
use crate::spec::{
    family_slug, json_escape, json_str, json_u64, schemas, sew_from_bits, shape_of, target_slug,
    JobSpec, JsonSpecOptions,
};

/// Schema tag of the `--json` summary ([`summary_json`]) — the canonical
/// constant lives in [`crate::spec::schemas`].
pub use crate::spec::schemas::SERVE_SUMMARY as SUMMARY_SCHEMA;

/// Service configuration: tile count, admission bound, batching policy,
/// and the live path's parallelism (worker pool + connection cap).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeConfig {
    /// Simulated NMC tiles behind the service (per worker replica).
    pub tiles: usize,
    /// Admission control: requests arriving at a full queue are rejected
    /// with a typed overload response, never dropped silently.
    pub queue_cap: usize,
    /// Close a batch as soon as this many requests are queued.
    pub max_batch: usize,
    /// Close a batch once its oldest request has waited this long
    /// (virtual-time path; the live path lingers a few milliseconds).
    pub linger_cycles: u64,
    /// Live path: parallel worker threads, each owning independent
    /// pre-warmed [`Soc`] replicas. The virtual-time paths ignore this —
    /// their whole point is a deterministic serial clock.
    pub workers: usize,
    /// Live TCP path: maximum simultaneous connections; one more gets a
    /// typed busy rejection. Doubles as the closed-loop client count.
    pub conns: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            tiles: 4,
            queue_cap: 64,
            max_batch: 8,
            linger_cycles: 100_000,
            workers: 1,
            conns: 4,
        }
    }
}

/// One admitted workload request: a single kernel job, or — when
/// `model` is set — a multi-layer graph pipeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub target: Target,
    pub kernel: Kernel,
    pub sew: Sew,
    /// Golden-input seed (defaults to `id` when the line omits it).
    pub seed: u64,
    /// `{"model": ...}` requests: the parsed graph payload, `Arc`-shared
    /// so requests stay cheap to clone through the queue and batcher.
    /// The kernel selectors above describe the graph's entry layer.
    pub model: Option<Arc<ModelReq>>,
}

/// The graph payload of a `{"model": ...}` request. Compiled onto the
/// service's tile count at execution time ([`graph::compile`]), so one
/// request line works for any `--tiles` the service runs with.
#[derive(Debug, PartialEq, Eq)]
pub struct ModelReq {
    pub graph: Graph,
    pub pipeline: Pipeline,
}

/// One per-request JSONL response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    /// The request's batch ran and its output matched the golden
    /// reference. `latency_cycles` is arrival→completion on the
    /// virtual-time path and the batch makespan on the live path.
    Ok { id: u64, latency_cycles: u64, batch: u32, batch_cycles: u64 },
    /// A `{"model": ...}` request ran byte-identical to its CPU-golden
    /// chain: the per-layer cycle breakdown plus the boundary mix that
    /// actually executed.
    ModelOk {
        id: u64,
        latency_cycles: u64,
        cycles: u64,
        dma_active_cycles: u64,
        resident_boundaries: u32,
        staged_boundaries: u32,
        /// Per layer: (kernel slug, boundary name, cycles).
        layers: Vec<(&'static str, &'static str, u64)>,
    },
    /// Admission control: the bounded queue was full on arrival.
    Rejected { id: u64, queue_depth: usize },
    /// Connection-level admission (TCP): the `--conns` cap was reached,
    /// so this connection gets one typed line and is closed. No request
    /// was read yet, so the line carries id 0.
    Busy { conns: usize },
    /// The line did not parse, the shape failed validation, or the
    /// planner returned a typed [`sched::SchedError`].
    Error { id: u64, error: String },
}

impl Response {
    pub fn id(&self) -> u64 {
        match self {
            Response::Ok { id, .. }
            | Response::ModelOk { id, .. }
            | Response::Rejected { id, .. }
            | Response::Error { id, .. } => *id,
            Response::Busy { .. } => 0,
        }
    }

    /// One JSONL line (no trailing newline).
    pub fn render(&self) -> String {
        match self {
            Response::Ok { id, latency_cycles, batch, batch_cycles } => format!(
                "{{\"id\":{id},\"status\":\"ok\",\"latency_cycles\":{latency_cycles},\
                 \"batch\":{batch},\"batch_cycles\":{batch_cycles}}}"
            ),
            Response::ModelOk {
                id,
                latency_cycles,
                cycles,
                dma_active_cycles,
                resident_boundaries,
                staged_boundaries,
                layers,
            } => {
                let per: Vec<String> = layers
                    .iter()
                    .map(|(k, b, c)| {
                        format!("{{\"kernel\":\"{k}\",\"boundary\":\"{b}\",\"cycles\":{c}}}")
                    })
                    .collect();
                format!(
                    "{{\"id\":{id},\"status\":\"ok\",\"kind\":\"model\",\
                     \"latency_cycles\":{latency_cycles},\"cycles\":{cycles},\
                     \"dma_active_cycles\":{dma_active_cycles},\
                     \"resident_boundaries\":{resident_boundaries},\
                     \"staged_boundaries\":{staged_boundaries},\"layers\":[{}]}}",
                    per.join(",")
                )
            }
            Response::Rejected { id, queue_depth } => format!(
                "{{\"id\":{id},\"status\":\"rejected\",\"reason\":\"overload\",\
                 \"queue_depth\":{queue_depth}}}"
            ),
            Response::Busy { conns } => format!(
                "{{\"id\":0,\"status\":\"rejected\",\"reason\":\"busy\",\"conns\":{conns}}}"
            ),
            Response::Error { id, error } => {
                format!("{{\"id\":{id},\"status\":\"error\",\"error\":\"{}\"}}", json_escape(error))
            }
        }
    }
}

/// Parse one JSONL request line through the unified [`crate::spec`]
/// vocabulary. Required keys: `id`, then either kernel selectors
/// (`target`, `family`, `sew`; optional `n`/`p`/`f` shape dims, default
/// 0) or `model` (a graph spec string, see [`Graph::parse`], with
/// optional `sew` defaulting to 8 and `pipeline` defaulting to `layer`);
/// `seed` defaults to `id` on both forms. A line stamped with a
/// mismatched `schema` tag is rejected outright ([`schemas::check`]).
/// Shape validation runs here so an invalid request is answered
/// immediately and can never poison a batch.
pub fn parse_request(line: &str) -> Result<Request, String> {
    schemas::check(line, schemas::SERVE_REQUEST, false).map_err(|e| e.to_string())?;
    let id = json_u64(line, "id")?;
    // Model requests: a graph spec string instead of kernel selectors.
    if let Ok(spec) = json_str(line, "model") {
        let sew = sew_from_bits(json_u64(line, "sew").unwrap_or(8)).map_err(|e| e.to_string())?;
        let pl = match json_str(line, "pipeline") {
            Ok(p) => Pipeline::parse(p).ok_or_else(|| format!("unknown pipeline {p:?}"))?,
            Err(_) => Pipeline::Layer,
        };
        let seed = json_u64(line, "seed").unwrap_or(id);
        let g = Graph::parse(spec, sew, seed).map_err(|e| format!("bad model: {e}"))?;
        let kernel = g.layers[0];
        return Ok(Request {
            id,
            target: Target::Carus,
            kernel,
            sew,
            seed,
            model: Some(Arc::new(ModelReq { graph: g, pipeline: pl })),
        });
    }
    let opt = JsonSpecOptions { seed_key: "seed", default_seed: Some(id), require_dims: false };
    let spec = JobSpec::parse_json(line, &opt).map_err(|e| e.to_string())?;
    if spec.target == Target::Cpu {
        return Err("the CPU is the host, never a serve target".to_string());
    }
    spec.validate().map_err(|e| e.to_string())?;
    Ok(Request {
        id,
        target: spec.target,
        kernel: spec.kernel,
        sew: spec.sew,
        seed: spec.seed,
        model: None,
    })
}

/// Render a request back to its JSONL line (the exact inverse of
/// [`parse_request`]) — the load generator and tests feed the live path
/// through this.
pub fn render_request(r: &Request) -> String {
    if let Some(m) = &r.model {
        return format!(
            "{{\"id\":{},\"model\":\"{}\",\"sew\":{},\"pipeline\":\"{}\",\"seed\":{}}}",
            r.id,
            json_escape(&m.graph.spec_string()),
            r.sew.bits(),
            m.pipeline.name(),
            r.seed
        );
    }
    let (n, p, f) = shape_of(r.kernel);
    format!(
        "{{\"id\":{},\"target\":\"{}\",\"family\":\"{}\",\"sew\":{},\"n\":{n},\"p\":{p},\
         \"f\":{f},\"seed\":{}}}",
        r.id,
        target_slug(r.target),
        family_slug(r.kernel.family()),
        r.sew.bits(),
        r.seed
    )
}

/// Can `b` join a batch headed by `a`? One target and SEW per batch;
/// autonomous NM-Carus tiles take any shape of one family (the shape
/// travels in the per-workload argument words), stream-executed
/// NM-Caesar tiles replay one rendered micro-op stream per tile, so they
/// require the exact kernel.
pub fn coalescible(a: &Request, b: &Request) -> bool {
    // A model request owns the whole tile array for its pipeline's
    // duration — it always runs as a batch of one.
    if a.model.is_some() || b.model.is_some() {
        return false;
    }
    if a.target != b.target || a.sew != b.sew {
        return false;
    }
    match a.target {
        Target::Caesar => a.kernel == b.kernel,
        _ => a.kernel.family() == b.kernel.family(),
    }
}

/// What one closed batch produced: a coalesced kernel-batch result, or a
/// single model-pipeline run (model requests never coalesce). The
/// accessors express the small shared surface the service loops need, so
/// the batching/stats/response code stays payload-agnostic.
enum Ran {
    Batch(Box<BatchRunResult>),
    Model(Box<pipeline::ModelRunResult>),
}

impl Ran {
    /// Simulated makespan of whatever ran.
    fn cycles(&self) -> u64 {
        match self {
            Ran::Batch(r) => r.cycles,
            Ran::Model(r) => r.cycles,
        }
    }

    /// Busy cycles tile `i` contributed (0 out of range).
    fn tile_busy(&self, i: usize) -> u64 {
        match self {
            Ran::Batch(r) => r.per_tile.get(i).map_or(0, |t| t.busy_cycles),
            Ran::Model(r) => r.tile_busy.get(i).copied().unwrap_or(0),
        }
    }

    /// The per-request response for one member of the closed batch.
    fn response(&self, id: u64, latency_cycles: u64, batch: u32) -> Response {
        match self {
            Ran::Batch(r) => Response::Ok { id, latency_cycles, batch, batch_cycles: r.cycles },
            Ran::Model(r) => Response::ModelOk {
                id,
                latency_cycles,
                cycles: r.cycles,
                dma_active_cycles: r.dma_active_cycles,
                resident_boundaries: r.resident_boundaries,
                staged_boundaries: r.staged_boundaries,
                layers: r
                    .layers
                    .iter()
                    .map(|l| (family_slug(l.kernel.family()), l.boundary.name(), l.cycles))
                    .collect(),
            },
        }
    }
}

/// Compile and co-simulate one coalesced batch. Planner and graph-compile
/// failures come back as the typed error message; a panic inside the
/// co-simulation (a modeling bug — both executors assert golden
/// byte-identity) is caught so the service answers instead of dying.
fn execute(batch: &[Request], tiles: usize) -> Result<Ran, String> {
    if let Some(m) = &batch[0].model {
        let sch = graph::compile(&m.graph, tiles as u32, m.pipeline).map_err(|e| e.to_string())?;
        return std::panic::catch_unwind(AssertUnwindSafe(|| {
            pipeline::run_model(&sch, pipeline::Residency::Auto)
        }))
        .map_err(|_| "internal: co-simulation panicked (modeling bug)".to_string())?
        .map(|r| Ran::Model(Box::new(r)))
        .map_err(|e| e.to_string());
    }
    let jobs: Vec<(Kernel, u64)> = batch.iter().map(|r| (r.kernel, r.seed)).collect();
    let plan = plan_jobs(batch[0].target, batch[0].sew, &jobs, tiles)
        .map_err(|e: sched::SchedError| e.to_string())?;
    std::panic::catch_unwind(AssertUnwindSafe(|| run_planned(&plan)))
        .map_err(|_| "internal: co-simulation panicked (modeling bug)".to_string())
        .map(|r| Ran::Batch(Box::new(r)))
}

/// Accumulated service statistics — everything the summary reports.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub errored: u64,
    pub batches: u64,
    /// Virtual-time path: the simulated clock at drain; live path: the
    /// sum of batch makespans.
    pub sim_cycles: u64,
    /// Per-completed-request latency in simulated cycles.
    pub latencies: Vec<u64>,
    pub batch_sizes: Vec<u32>,
    /// Queue depth sampled at each batch close — "queue depth over time".
    pub depth_samples: Vec<u32>,
    /// Busy cycles per configured tile, summed over batches.
    pub tile_busy: Vec<u64>,
    /// Sum of batch makespans (the window tiles could have been busy).
    pub busy_window: u64,
    /// Wall-clock span of the live service window in milliseconds; 0 on
    /// the virtual-time paths, which measure simulated cycles instead.
    pub wall_ms: f64,
}

impl ServeStats {
    /// Nearest-rank percentile of the completed-request latencies
    /// (`q` in 0..=1); 0 when nothing completed.
    pub fn latency_percentile(&self, q: f64) -> u64 {
        if self.latencies.is_empty() {
            return 0;
        }
        let mut xs = self.latencies.clone();
        xs.sort_unstable();
        let idx = ((q * xs.len() as f64).ceil() as usize).max(1) - 1;
        xs[idx.min(xs.len() - 1)]
    }

    pub fn latency_max(&self) -> u64 {
        self.latencies.iter().copied().max().unwrap_or(0)
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().map(|&b| b as f64).sum::<f64>() / self.batch_sizes.len() as f64
    }

    pub fn queue_depth_max(&self) -> u32 {
        self.depth_samples.iter().copied().max().unwrap_or(0)
    }

    pub fn queue_depth_mean(&self) -> f64 {
        if self.depth_samples.is_empty() {
            return 0.0;
        }
        self.depth_samples.iter().map(|&d| d as f64).sum::<f64>() / self.depth_samples.len() as f64
    }

    /// Fraction of the service window tile `i` spent computing.
    /// Out-of-range indices answer 0.0, like
    /// [`BatchRunResult::utilization`].
    pub fn utilization(&self, i: usize) -> f64 {
        self.tile_busy.get(i).map_or(0.0, |&b| b as f64 / self.sim_cycles.max(1) as f64)
    }

    /// Completed requests per wall-clock second — the live path's
    /// throughput. 0 when no wall-clock window was measured (the
    /// virtual-time paths).
    pub fn req_per_s(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / (self.wall_ms / 1e3)
    }

    /// `hist[k-1]` = number of closed batches of size `k`.
    pub fn batch_size_histogram(&self, max_batch: usize) -> Vec<u32> {
        let mut hist = vec![0u32; max_batch.max(1)];
        for &b in &self.batch_sizes {
            let slot = (b as usize).clamp(1, hist.len());
            hist[slot - 1] += 1;
        }
        hist
    }
}

/// The machine-readable summary CI gates on (`--json`). Deterministic
/// key order and fixed float precision: the same stats render to the
/// same bytes.
pub fn summary_json(stats: &ServeStats, cfg: &ServeConfig, trace: &str, seed: u64) -> String {
    let join_u32 = |xs: &[u32]| {
        xs.iter().map(u32::to_string).collect::<Vec<_>>().join(",")
    };
    let util: Vec<String> =
        (0..cfg.tiles).map(|i| format!("{:.6}", stats.utilization(i))).collect();
    format!(
        "{{\n  \"schema\": \"{SUMMARY_SCHEMA}\",\n  \"trace\": \"{}\",\n  \"seed\": {seed},\n  \
         \"tiles\": {},\n  \"queue_cap\": {},\n  \"max_batch\": {},\n  \"linger_cycles\": {},\n  \
         \"requests\": {},\n  \"completed\": {},\n  \"rejected\": {},\n  \"errored\": {},\n  \
         \"batches\": {},\n  \"sim_cycles\": {},\n  \"p50_latency_cycles\": {},\n  \
         \"p95_latency_cycles\": {},\n  \"p99_latency_cycles\": {},\n  \
         \"max_latency_cycles\": {},\n  \"mean_batch_size\": {:.3},\n  \
         \"queue_depth_max\": {},\n  \"queue_depth_mean\": {:.3},\n  \
         \"per_tile_utilization\": [{}],\n  \"batch_size_histogram\": [{}],\n  \
         \"queue_depth_samples\": [{}]\n}}\n",
        json_escape(trace),
        cfg.tiles,
        cfg.queue_cap,
        cfg.max_batch,
        cfg.linger_cycles,
        stats.requests,
        stats.completed,
        stats.rejected,
        stats.errored,
        stats.batches,
        stats.sim_cycles,
        stats.latency_percentile(0.50),
        stats.latency_percentile(0.95),
        stats.latency_percentile(0.99),
        stats.latency_max(),
        stats.mean_batch_size(),
        stats.queue_depth_max(),
        stats.queue_depth_mean(),
        util.join(","),
        join_u32(&stats.batch_size_histogram(cfg.max_batch)),
        join_u32(&stats.depth_samples),
    )
}

/// Run a timestamped trace through the service on a **virtual clock**:
/// arrivals are admitted when the clock passes their cycle, batches
/// close on the policy (full / lingered / input drained), execution
/// advances the clock by the co-simulated makespan, and each completed
/// request's latency is arrival→batch-completion in simulated cycles.
/// Fully deterministic in the trace — the CI determinism gate and the
/// e2e tests run here.
pub fn run_trace(
    cfg: &ServeConfig,
    trace: &[(u64, Request)],
    mut on_response: impl FnMut(&Response),
) -> ServeStats {
    let mut stats = ServeStats {
        requests: trace.len() as u64,
        tile_busy: vec![0; cfg.tiles],
        ..Default::default()
    };
    let mut queue: VecDeque<(u64, Request)> = VecDeque::new();
    let mut now: u64 = 0;
    let mut next = 0usize;

    loop {
        // Admission: every arrival the clock has passed, in trace order.
        while next < trace.len() && trace[next].0 <= now {
            let (at, req) = trace[next].clone();
            next += 1;
            if queue.len() >= cfg.queue_cap {
                stats.rejected += 1;
                on_response(&Response::Rejected { id: req.id, queue_depth: queue.len() });
            } else {
                queue.push_back((at, req));
            }
        }

        if queue.is_empty() {
            match trace.get(next) {
                Some(&(at, _)) => {
                    now = now.max(at);
                    continue;
                }
                None => break,
            }
        }

        // Batching policy: close when full, when the oldest request has
        // lingered out, or when no further arrival can grow the batch.
        let oldest = queue[0].0;
        let drained = next == trace.len();
        let full = queue.len() >= cfg.max_batch;
        let lingered = now >= oldest.saturating_add(cfg.linger_cycles);
        if !(full || lingered || drained) {
            // Sleep until whichever comes first: the next arrival or the
            // oldest request's linger deadline.
            let deadline = oldest.saturating_add(cfg.linger_cycles);
            now = deadline.min(trace[next].0).max(now + 1);
            continue;
        }

        // Close the longest head-compatible prefix (FIFO: no reordering).
        let head = queue[0].1.clone();
        let mut take = 1;
        while take < queue.len().min(cfg.max_batch) && coalescible(&head, &queue[take].1) {
            take += 1;
        }
        stats.depth_samples.push(queue.len() as u32);
        let batch: Vec<(u64, Request)> = queue.drain(..take).collect();
        let reqs: Vec<Request> = batch.iter().map(|(_, r)| r.clone()).collect();
        match execute(&reqs, cfg.tiles) {
            Ok(res) => {
                let end = now + res.cycles();
                stats.batches += 1;
                stats.batch_sizes.push(reqs.len() as u32);
                stats.busy_window += res.cycles();
                for (i, busy) in stats.tile_busy.iter_mut().enumerate() {
                    *busy += res.tile_busy(i);
                }
                for (at, r) in &batch {
                    let lat = end - at;
                    stats.completed += 1;
                    stats.latencies.push(lat);
                    on_response(&res.response(r.id, lat, reqs.len() as u32));
                }
                now = end;
            }
            Err(e) => {
                // Planning is host-side and cheap; an errored batch
                // consumes no simulated time, only its queue slots.
                for (_, r) in &batch {
                    stats.errored += 1;
                    on_response(&Response::Error { id: r.id, error: e.clone() });
                }
            }
        }
    }
    stats.sim_cycles = now;
    stats
}

/// Generate a seeded trace and run it on the virtual clock — the
/// `serve --selftest` body, also used by the e2e tests.
pub fn selftest(
    cfg: &ServeConfig,
    kind: load::TraceKind,
    seed: u64,
    requests: u32,
) -> (ServeStats, Vec<Response>) {
    let trace = load::gen_trace(kind, seed, requests);
    let mut responses = Vec::new();
    let stats = run_trace(cfg, &trace, |r| responses.push(r.clone()));
    (stats, responses)
}

/// Closed-loop service replay on the **virtual clock** (`--load
/// closed`): `cfg.conns` [`load::ClosedClient`]s submit with at most one
/// outstanding request each, think between completions, and — the part
/// an open-loop trace cannot exercise — react to a `rejected` response
/// with capped exponential backoff plus seeded jitter, then retry as a
/// **new** request id (so every id is still answered exactly once). The
/// fleet issues `budget` attempts in total (first tries + retries);
/// deterministic in `(cfg, seed, budget)`, so the closed-loop selftest
/// is byte-gated in CI exactly like the open-loop one.
pub fn run_closed(cfg: &ServeConfig, seed: u64, budget: u32) -> (ServeStats, Vec<Response>) {
    let mut stats = ServeStats { tile_busy: vec![0; cfg.tiles], ..Default::default() };
    let mut responses: Vec<Response> = Vec::new();
    let n_clients = cfg.conns.max(1);
    let mut clients: Vec<load::ClosedClient> =
        (0..n_clients).map(|i| load::ClosedClient::new(seed, i as u32)).collect();
    // Per-client next submission cycle; `None` while a request is
    // outstanding (queued or executing) or the budget is spent.
    let mut next_at: Vec<Option<u64>> = clients.iter_mut().map(|c| Some(c.think())).collect();
    // (arrival cycle, client, request)
    let mut queue: VecDeque<(u64, usize, Request)> = VecDeque::new();
    let mut issued = 0u32;
    let mut next_id = 1u64;
    let mut now = 0u64;

    loop {
        // Submissions the clock has passed, in (cycle, client) order —
        // the deterministic tie-break.
        while issued < budget {
            let due = (0..n_clients)
                .filter_map(|i| next_at[i].map(|t| (t, i)))
                .filter(|&(t, _)| t <= now)
                .min();
            let Some((_, i)) = due else { break };
            next_at[i] = None;
            let id = next_id;
            next_id += 1;
            issued += 1;
            let req = clients[i].next_request(id);
            stats.requests += 1;
            if queue.len() >= cfg.queue_cap {
                stats.rejected += 1;
                responses.push(Response::Rejected { id, queue_depth: queue.len() });
                // The reactive half of the contract: back off, retry
                // later as a fresh attempt — unless the budget is spent.
                let delay = clients[i].backoff();
                if issued < budget {
                    next_at[i] = Some(now + delay);
                }
            } else {
                queue.push_back((now, i, req));
            }
        }
        if issued >= budget {
            // No client may submit again; silence any scheduled retries.
            next_at.iter_mut().for_each(|t| *t = None);
        }

        let next_sub = next_at.iter().flatten().copied().min();
        if queue.is_empty() {
            match next_sub {
                Some(t) => {
                    now = now.max(t);
                    continue;
                }
                None => break,
            }
        }

        // Batching policy, as in `run_trace`: close when full, when the
        // oldest request has lingered out, or when no further submission
        // can ever arrive.
        let oldest = queue[0].0;
        let full = queue.len() >= cfg.max_batch;
        let lingered = now >= oldest.saturating_add(cfg.linger_cycles);
        if !(full || lingered || next_sub.is_none()) {
            let deadline = oldest.saturating_add(cfg.linger_cycles);
            now = deadline.min(next_sub.unwrap()).max(now + 1);
            continue;
        }

        // Close the longest head-compatible prefix (FIFO: no reordering).
        let head = queue[0].2.clone();
        let mut take = 1;
        while take < queue.len().min(cfg.max_batch) && coalescible(&head, &queue[take].2) {
            take += 1;
        }
        stats.depth_samples.push(queue.len() as u32);
        let batch: Vec<(u64, usize, Request)> = queue.drain(..take).collect();
        let reqs: Vec<Request> = batch.iter().map(|(_, _, r)| r.clone()).collect();
        match execute(&reqs, cfg.tiles) {
            Ok(res) => {
                let end = now + res.cycles();
                stats.batches += 1;
                stats.batch_sizes.push(reqs.len() as u32);
                stats.busy_window += res.cycles();
                for (i, busy) in stats.tile_busy.iter_mut().enumerate() {
                    *busy += res.tile_busy(i);
                }
                for &(at, i, ref r) in &batch {
                    let lat = end - at;
                    stats.completed += 1;
                    stats.latencies.push(lat);
                    responses.push(res.response(r.id, lat, reqs.len() as u32));
                    // The response releases the client: reset its
                    // backoff, think, submit again (budget permitting).
                    clients[i].reset();
                    if issued < budget {
                        next_at[i] = Some(end + clients[i].think());
                    }
                }
                now = end;
            }
            Err(e) => {
                // Planning is host-side and cheap; an errored batch
                // consumes no simulated time, only its queue slots.
                for &(_, i, ref r) in &batch {
                    stats.errored += 1;
                    responses.push(Response::Error { id: r.id, error: e.clone() });
                    clients[i].reset();
                    if issued < budget {
                        next_at[i] = Some(now + clients[i].think());
                    }
                }
            }
        }
    }
    stats.sim_cycles = now;
    (stats, responses)
}

// ---------------------------------------------------------------------
// Live path: concurrent front-end + parallel worker pool
// ---------------------------------------------------------------------

/// One worker thread's pre-warmed [`Soc`] replicas — one per tile kind,
/// built lazily on first use and **recycled** (rebuilt in place from the
/// recorded construction parameters, see [`Soc::recycle`]) rather than
/// reconstructed between batches. Each worker owns its replicas
/// exclusively, so batch execution needs no lock at all.
struct WorkerSocs {
    tiles: usize,
    caesar: Option<Soc>,
    carus: Option<Soc>,
}

impl WorkerSocs {
    fn new(tiles: usize) -> Self {
        WorkerSocs { tiles, caesar: None, carus: None }
    }

    fn soc_for(&mut self, kind: TileKind) -> &mut Soc {
        let (slot, tiles) = match kind {
            TileKind::Caesar => (&mut self.caesar, self.tiles),
            TileKind::Carus => (&mut self.carus, self.tiles),
        };
        slot.get_or_insert_with(|| Soc::scale_out(kind, tiles, 4))
    }
}

/// [`execute`] against a worker's own replica instead of a fresh [`Soc`]:
/// [`sched::run_planned_on`] recycles the replica first, so the simulated
/// timing/energy is bit-identical to fresh construction (locked in by a
/// [`sched`] unit test) — only the wall-clock cost of rebuilding the
/// memory arrays per batch is saved, and workers run in parallel.
fn execute_on(socs: &mut WorkerSocs, batch: &[Request]) -> Result<Ran, String> {
    if let Some(m) = &batch[0].model {
        let sch =
            graph::compile(&m.graph, socs.tiles as u32, m.pipeline).map_err(|e| e.to_string())?;
        let soc = socs.soc_for(TileKind::Carus);
        return std::panic::catch_unwind(AssertUnwindSafe(|| {
            pipeline::run_model_on(soc, &sch, pipeline::Residency::Auto)
        }))
        .map_err(|_| "internal: co-simulation panicked (modeling bug)".to_string())?
        .map(|r| Ran::Model(Box::new(r)))
        .map_err(|e| e.to_string());
    }
    let jobs: Vec<(Kernel, u64)> = batch.iter().map(|r| (r.kernel, r.seed)).collect();
    let plan = plan_jobs(batch[0].target, batch[0].sew, &jobs, socs.tiles)
        .map_err(|e: sched::SchedError| e.to_string())?;
    let soc = socs.soc_for(plan.kind());
    std::panic::catch_unwind(AssertUnwindSafe(|| run_planned_on(soc, &plan)))
        .map_err(|_| "internal: co-simulation panicked (modeling bug)".to_string())
        .map(|r| Ran::Batch(Box::new(r)))
}

struct ConnOutInner<'env> {
    out: Box<dyn Write + Send + 'env>,
    /// Next per-connection arrival sequence to write.
    next: u64,
    /// Responses that completed ahead of an earlier in-flight sequence.
    held: BTreeMap<u64, String>,
}

/// Routes responses back to their originating connection, restoring that
/// connection's **request order**: batches complete out of order across
/// the worker pool, so every response is tagged with its per-connection
/// arrival sequence and held back until all earlier sequences have been
/// written. Rejections and parse errors claim a sequence too, so the
/// stream never stalls waiting on a request that was answered inline.
struct ConnOut<'env> {
    inner: Mutex<ConnOutInner<'env>>,
}

impl<'env> ConnOut<'env> {
    fn new(out: Box<dyn Write + Send + 'env>) -> Self {
        ConnOut { inner: Mutex::new(ConnOutInner { out, next: 0, held: BTreeMap::new() }) }
    }

    /// Hand in the response line for arrival sequence `seq`; writes it
    /// plus any directly following held lines, in sequence order.
    fn deliver(&self, seq: u64, line: String) {
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        inner.held.insert(seq, line);
        let mut wrote = false;
        while let Some(line) = inner.held.remove(&inner.next) {
            let _ = writeln!(inner.out, "{line}");
            inner.next += 1;
            wrote = true;
        }
        // Flush only at a quiescent point: everything deliverable is out.
        if wrote && inner.held.is_empty() {
            let _ = inner.out.flush();
        }
    }

    fn flush(&self) {
        let _ = self.inner.lock().unwrap().out.flush();
    }
}

/// One admitted request together with its return route.
struct Admitted<'env> {
    req: Request,
    dest: Arc<ConnOut<'env>>,
    /// Per-connection arrival sequence (drives in-order delivery).
    seq: u64,
}

struct LiveState<'env> {
    queue: VecDeque<Admitted<'env>>,
    /// Open feeders (connections, plus the acceptor while it may still
    /// admit more). Workers exit once this hits zero with a drained queue.
    producers: usize,
}

/// The shared heart of the live path: one bounded admission queue fed by
/// any number of connection reader threads, drained by the worker pool.
/// Workers claim batches themselves, so a closed group goes to the first
/// idle worker instead of serializing behind the previous batch. Lock
/// order is `state`, then `stats`, then a `ConnOut` — each a leaf by the
/// time the next is taken, so no cycles.
struct LiveCore<'env> {
    cfg: ServeConfig,
    state: Mutex<LiveState<'env>>,
    work: Condvar,
    stats: Mutex<ServeStats>,
}

impl<'env> LiveCore<'env> {
    fn new(cfg: ServeConfig) -> Self {
        LiveCore {
            cfg,
            state: Mutex::new(LiveState { queue: VecDeque::new(), producers: 0 }),
            work: Condvar::new(),
            stats: Mutex::new(ServeStats { tile_busy: vec![0; cfg.tiles], ..Default::default() }),
        }
    }

    fn add_producer(&self) {
        self.state.lock().unwrap().producers += 1;
    }

    fn remove_producer(&self) {
        self.state.lock().unwrap().producers -= 1;
        self.work.notify_all();
    }

    fn take_stats(&self) -> ServeStats {
        std::mem::take(&mut *self.stats.lock().unwrap())
    }

    /// Read JSONL request lines from `input` until EOF, admitting them
    /// against the bounded queue. Parse errors and overload rejections
    /// are answered immediately (still through `dest`, so ordering
    /// holds); admitted requests are answered by whichever worker runs
    /// their batch. Callers bracket this with `add_producer` /
    /// `remove_producer`.
    fn feed<R: BufRead>(&self, input: R, dest: &Arc<ConnOut<'env>>) {
        let mut seq = 0u64;
        for line in input.lines() {
            let Ok(line) = line else { break };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let my_seq = seq;
            seq += 1;
            self.stats.lock().unwrap().requests += 1;
            match parse_request(line) {
                Err(e) => {
                    self.stats.lock().unwrap().errored += 1;
                    let id = json_u64(line, "id").unwrap_or(0);
                    dest.deliver(my_seq, Response::Error { id, error: e }.render());
                }
                Ok(req) => {
                    let mut st = self.state.lock().unwrap();
                    if st.queue.len() >= self.cfg.queue_cap {
                        let depth = st.queue.len();
                        drop(st);
                        self.stats.lock().unwrap().rejected += 1;
                        dest.deliver(
                            my_seq,
                            Response::Rejected { id: req.id, queue_depth: depth }.render(),
                        );
                    } else {
                        st.queue.push_back(Admitted { req, dest: Arc::clone(dest), seq: my_seq });
                        drop(st);
                        self.work.notify_all();
                    }
                }
            }
        }
        dest.flush();
    }

    /// One worker: claim the longest head-compatible prefix, execute it
    /// on this worker's own recycled replicas, route the responses back.
    /// Returns once the queue is drained and no producer remains.
    fn worker(&self) {
        let mut socs = WorkerSocs::new(self.cfg.tiles);
        loop {
            let mut st = self.state.lock().unwrap();
            while st.queue.is_empty() && st.producers > 0 {
                st = self.work.wait(st).unwrap();
            }
            if st.queue.is_empty() {
                return;
            }
            if st.queue.len() < self.cfg.max_batch && st.producers > 0 {
                // Linger briefly for a fuller batch while input is live.
                let (g, _) =
                    self.work.wait_timeout(st, std::time::Duration::from_millis(20)).unwrap();
                st = g;
                if st.queue.is_empty() {
                    continue;
                }
            }
            let head = st.queue[0].req.clone();
            let mut take = 1;
            while take < st.queue.len().min(self.cfg.max_batch)
                && coalescible(&head, &st.queue[take].req)
            {
                take += 1;
            }
            let depth = st.queue.len() as u32;
            let batch: Vec<Admitted<'env>> = st.queue.drain(..take).collect();
            drop(st);
            // Freed queue slots: wake feeders racing the admission bound
            // and any idle worker that can claim the new head.
            self.work.notify_all();

            let reqs: Vec<Request> = batch.iter().map(|a| a.req.clone()).collect();
            let result = execute_on(&mut socs, &reqs);
            let mut stats = self.stats.lock().unwrap();
            stats.depth_samples.push(depth);
            match &result {
                Ok(res) => {
                    stats.batches += 1;
                    stats.batch_sizes.push(reqs.len() as u32);
                    stats.busy_window += res.cycles();
                    stats.sim_cycles += res.cycles();
                    for (i, busy) in stats.tile_busy.iter_mut().enumerate() {
                        *busy += res.tile_busy(i);
                    }
                    stats.completed += reqs.len() as u64;
                    stats.latencies.extend(std::iter::repeat_n(res.cycles(), reqs.len()));
                }
                Err(_) => stats.errored += reqs.len() as u64,
            }
            drop(stats);
            for a in &batch {
                let resp = match &result {
                    Ok(res) => res.response(a.req.id, res.cycles(), reqs.len() as u32),
                    Err(e) => Response::Error { id: a.req.id, error: e.clone() },
                };
                a.dest.deliver(a.seq, resp.render());
            }
        }
    }
}

/// The live path over one input/output pair (stdin mode, pipe tests): a
/// reader thread feeds the admission queue while `cfg.workers` workers
/// execute coalesced batches in parallel. Returns when the input reaches
/// EOF and the queue drains. Response *content* is deterministic and
/// responses come back in request order; which batch a request lands in
/// is wall-clock, so live responses report the batch makespan as their
/// latency.
pub fn serve_stream<R: BufRead + Send, W: Write + Send>(
    cfg: &ServeConfig,
    input: R,
    output: W,
) -> ServeStats {
    let core = LiveCore::new(*cfg);
    let started = std::time::Instant::now();
    std::thread::scope(|s| {
        let core = &core;
        let dest = Arc::new(ConnOut::new(Box::new(output)));
        core.add_producer();
        s.spawn(move || {
            core.feed(input, &dest);
            core.remove_producer();
        });
        for _ in 0..cfg.workers.max(1) {
            s.spawn(move || core.worker());
        }
    });
    let mut stats = core.take_stats();
    stats.wall_ms = started.elapsed().as_secs_f64() * 1e3;
    stats
}

/// Accept **one** TCP connection and serve it to completion (EOF on the
/// read half ends the session) — the single-connection building block;
/// the CLI and the throughput smoke use [`serve_tcp`] for concurrent
/// connections.
pub fn serve_one_tcp(cfg: &ServeConfig, listener: &TcpListener) -> std::io::Result<ServeStats> {
    let (stream, _) = listener.accept()?;
    let input = std::io::BufReader::new(stream.try_clone()?);
    Ok(serve_stream(cfg, input, stream))
}

/// The concurrent TCP front-end: up to `cfg.conns` simultaneous
/// connections, each with its own reader thread feeding the one shared
/// admission queue, while the worker pool executes batches in parallel.
/// A connection past the cap gets a single typed busy line and is
/// closed. Responses return on the originating connection in that
/// connection's request order.
///
/// `accept_limit` = `Some(n)` stops accepting after `n` connections
/// (busy-rejected ones included) and returns once they drain — tests and
/// the throughput smoke; `None` serves until the listener errors.
pub fn serve_tcp(
    cfg: &ServeConfig,
    listener: &TcpListener,
    accept_limit: Option<usize>,
) -> std::io::Result<ServeStats> {
    let core = LiveCore::new(*cfg);
    let active = AtomicUsize::new(0);
    let started = std::time::Instant::now();
    std::thread::scope(|s| {
        let (core, active) = (&core, &active);
        for _ in 0..cfg.workers.max(1) {
            s.spawn(move || core.worker());
        }
        // The acceptor holds a producer token so workers never observe
        // "no producers" while another connection could still arrive.
        core.add_producer();
        let mut accepted = 0usize;
        while accept_limit.is_none_or(|n| accepted < n) {
            let Ok((mut stream, _)) = listener.accept() else { break };
            accepted += 1;
            if active.load(Ordering::Acquire) >= cfg.conns.max(1) {
                // Connection-level admission: one typed line, then close.
                let _ = writeln!(stream, "{}", Response::Busy { conns: cfg.conns }.render());
                continue;
            }
            let reader = match stream.try_clone() {
                Ok(r) => std::io::BufReader::new(r),
                Err(_) => continue,
            };
            active.fetch_add(1, Ordering::AcqRel);
            core.add_producer();
            let dest = Arc::new(ConnOut::new(Box::new(stream)));
            s.spawn(move || {
                core.feed(reader, &dest);
                core.remove_producer();
                active.fetch_sub(1, Ordering::AcqRel);
            });
        }
        core.remove_producer();
    });
    let mut stats = core.take_stats();
    stats.wall_ms = started.elapsed().as_secs_f64() * 1e3;
    Ok(stats)
}

// ---------------------------------------------------------------------
// Live throughput smoke (`--throughput`)
// ---------------------------------------------------------------------

/// Schema tag of the `--throughput` report ([`throughput_json`]) — the
/// canonical constant lives in [`crate::spec::schemas`].
pub use crate::spec::schemas::SERVE_LIVE as LIVE_SCHEMA;

/// Result of one self-contained live throughput run ([`throughput`]).
#[derive(Debug, Clone)]
pub struct ThroughputRun {
    pub stats: ServeStats,
    pub clients: usize,
    pub per_client: u32,
}

/// Self-contained live throughput smoke: bind an ephemeral loopback
/// listener, serve it with the configured worker pool, and drive it from
/// `cfg.conns` real TCP client threads, each pipelining `per_client`
/// seeded requests and reading to EOF. Wall-clock req/s lands in
/// `stats.req_per_s()`. Absolute req/s is machine-dependent — CI gates
/// only the within-run worker-scaling ratio (`--min-worker-speedup`).
pub fn throughput(cfg: &ServeConfig, per_client: u32, seed: u64) -> std::io::Result<ThroughputRun> {
    let clients = cfg.conns.max(1);
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let server_cfg = *cfg;
    let server = std::thread::spawn(move || serve_tcp(&server_cfg, &listener, Some(clients)));
    let mut drivers = Vec::new();
    for c in 0..clients {
        drivers.push(std::thread::spawn(move || -> std::io::Result<usize> {
            let trace =
                load::gen_trace(load::TraceKind::Mixed, seed ^ (c as u64 + 1), per_client);
            let mut stream = std::net::TcpStream::connect(addr)?;
            let mut reader = std::io::BufReader::new(stream.try_clone()?);
            for (_, req) in &trace {
                writeln!(stream, "{}", render_request(req))?;
            }
            stream.flush()?;
            stream.shutdown(std::net::Shutdown::Write)?;
            let mut line = String::new();
            let mut answered = 0usize;
            loop {
                line.clear();
                if reader.read_line(&mut line)? == 0 {
                    break;
                }
                answered += 1;
            }
            Ok(answered)
        }));
    }
    for d in drivers {
        d.join().expect("throughput client panicked")?;
    }
    let stats = server.join().expect("throughput server panicked")?;
    Ok(ThroughputRun { stats, clients, per_client })
}

/// The machine-readable `--throughput` report. Deterministic key order;
/// the wall-clock fields vary run to run by construction, so CI gates
/// only the counts and the within-run worker-scaling ratio.
pub fn throughput_json(run: &ThroughputRun, cfg: &ServeConfig, seed: u64) -> String {
    let s = &run.stats;
    format!(
        "{{\"schema\":\"{LIVE_SCHEMA}\",\"seed\":{seed},\"workers\":{},\"conns\":{},\
         \"tiles\":{},\"clients\":{},\"per_client\":{},\"requests\":{},\"completed\":{},\
         \"rejected\":{},\"errored\":{},\"batches\":{},\"wall_ms\":{:.3},\"req_per_s\":{:.3}}}",
        cfg.workers,
        cfg.conns,
        cfg.tiles,
        run.clients,
        run.per_client,
        s.requests,
        s.completed,
        s.rejected,
        s.errored,
        s.batches,
        s.wall_ms,
        s.req_per_s(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, target: Target, kernel: Kernel, sew: Sew) -> Request {
        Request { id, target, kernel, sew, seed: id, model: None }
    }

    #[test]
    fn request_lines_roundtrip_exactly() {
        let cases = [
            req(1, Target::Carus, Kernel::Add { n: 64 }, Sew::E32),
            req(2, Target::Caesar, Kernel::Matmul { p: 16 }, Sew::E16),
            req(9000, Target::Carus, Kernel::Conv2d { n: 16, f: 3 }, Sew::E8),
        ];
        for r in cases {
            let line = render_request(&r);
            assert_eq!(parse_request(&line), Ok(r), "{line}");
        }
        // Omitted seed defaults to the id; omitted dims default to 0.
        let r = parse_request(r#"{"id":5,"target":"carus","family":"add","sew":8,"n":64}"#)
            .unwrap();
        assert_eq!(r.seed, 5);
        assert_eq!(r.kernel, Kernel::Add { n: 64 });
    }

    #[test]
    fn malformed_requests_are_typed_errors() {
        let bad = [
            (r#"{"target":"carus","family":"add","sew":8,"n":64}"#, "id"),
            (r#"{"id":1,"target":"cpu","family":"add","sew":8,"n":64}"#, "host"),
            (r#"{"id":1,"target":"carus","family":"frob","sew":8,"n":64}"#, "family"),
            (r#"{"id":1,"target":"carus","family":"add","sew":7,"n":64}"#, "sew"),
            (r#"{"id":1,"target":"carus","family":"add","sew":8,"n":0}"#, "invalid shape"),
            ("not json at all", "id"),
            (r#"{"id":1,"model":"matmul:p=32,gemm:p=8"}"#, "bad model"),
            (r#"{"id":1,"model":"matmul:p=32,relu","sew":7}"#, "sew"),
            (r#"{"id":1,"model":"matmul:p=32,relu","pipeline":"spiral"}"#, "pipeline"),
            (r#"{"schema":"heeperator-bench-v1","id":1,"model":"matmul:p=32,relu"}"#, "schema"),
        ];
        for (line, needle) in bad {
            let e = parse_request(line).unwrap_err();
            assert!(e.contains(needle), "{line} -> {e}");
        }
        // The request schema tag itself is accepted (it is optional).
        let tagged = format!(
            "{{\"schema\":\"{}\",\"id\":1,\"target\":\"carus\",\"family\":\"add\",\
             \"sew\":8,\"n\":64}}",
            schemas::SERVE_REQUEST
        );
        assert!(parse_request(&tagged).is_ok());
    }

    #[test]
    fn model_requests_roundtrip_and_answer_per_layer_breakdowns() {
        let line =
            r#"{"id":3,"model":"matmul:p=32,add,relu,maxpool","sew":8,"pipeline":"batch","seed":9}"#;
        let r = parse_request(line).unwrap();
        let m = r.model.as_ref().expect("parsed as a model request");
        assert_eq!(m.graph.layers.len(), 4);
        assert_eq!(m.pipeline, Pipeline::Batch);
        assert_eq!(r.seed, 9);
        // Round-trips through the renderer, and never shares a batch.
        assert_eq!(parse_request(&render_request(&r)).unwrap(), r);
        let k = req(4, Target::Carus, Kernel::Matmul { p: 32 }, Sew::E8);
        assert!(!coalescible(&r, &k) && !coalescible(&k, &r) && !coalescible(&r, &r));
        // End to end on the virtual clock: one per-layer breakdown answer.
        let cfg = ServeConfig { tiles: 2, ..Default::default() };
        let mut responses = Vec::new();
        let stats = run_trace(&cfg, &[(0, r)], |x| responses.push(x.clone()));
        assert_eq!((stats.completed, stats.errored), (1, 0));
        assert!(matches!(
            &responses[0],
            Response::ModelOk { id: 3, resident_boundaries: 3, layers, .. } if layers.len() == 4
        ));
        let rendered = responses[0].render();
        for key in ["\"kind\":\"model\"", "\"layers\":[", "\"boundary\":\"resident\""] {
            assert!(rendered.contains(key), "{rendered}");
        }
    }

    #[test]
    fn coalescing_rules_follow_the_execution_models() {
        let a = req(1, Target::Carus, Kernel::Add { n: 64 }, Sew::E32);
        // NM-Carus: any shape of one family.
        assert!(coalescible(&a, &req(2, Target::Carus, Kernel::Add { n: 32 }, Sew::E32)));
        assert!(!coalescible(&a, &req(2, Target::Carus, Kernel::Relu { n: 64 }, Sew::E32)));
        // One SEW and one target per batch.
        assert!(!coalescible(&a, &req(2, Target::Carus, Kernel::Add { n: 64 }, Sew::E8)));
        assert!(!coalescible(&a, &req(2, Target::Caesar, Kernel::Add { n: 64 }, Sew::E32)));
        // NM-Caesar: the exact kernel (one rendered stream per tile).
        let c = req(1, Target::Caesar, Kernel::Add { n: 64 }, Sew::E32);
        assert!(coalescible(&c, &req(2, Target::Caesar, Kernel::Add { n: 64 }, Sew::E32)));
        assert!(!coalescible(&c, &req(2, Target::Caesar, Kernel::Add { n: 32 }, Sew::E32)));
    }

    #[test]
    fn percentiles_are_nearest_rank_and_bounded() {
        let mut s = ServeStats::default();
        assert_eq!(s.latency_percentile(0.99), 0);
        s.latencies = vec![50, 10, 40, 20, 30];
        assert_eq!(s.latency_percentile(0.50), 30);
        assert_eq!(s.latency_percentile(0.95), 50);
        assert_eq!(s.latency_percentile(0.99), 50);
        assert_eq!(s.latency_max(), 50);
        assert!(s.latency_percentile(0.50) <= s.latency_percentile(0.95));
        // Out-of-range utilization indices answer 0.0.
        assert_eq!(s.utilization(usize::MAX), 0.0);
    }

    #[test]
    fn run_trace_batches_and_answers_every_request() {
        let cfg = ServeConfig { tiles: 2, ..Default::default() };
        let a = req(1, Target::Carus, Kernel::Add { n: 64 }, Sew::E32);
        let b = req(2, Target::Carus, Kernel::Add { n: 32 }, Sew::E32);
        let mut responses = Vec::new();
        let stats = run_trace(&cfg, &[(0, a), (0, b)], |r| responses.push(r.clone()));
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.rejected + stats.errored, 0);
        assert!(stats.sim_cycles > 0);
        assert_eq!(responses.len(), 2);
        for r in &responses {
            assert!(matches!(r, Response::Ok { batch: 2, .. }), "{r:?}");
            assert!(r.render().contains("\"status\":\"ok\""));
        }
        // Both tiles saw work (two workloads round-robin across two tiles).
        assert!(stats.utilization(0) > 0.0 && stats.utilization(1) > 0.0);
    }

    #[test]
    fn summary_json_is_deterministic_and_carries_the_gated_keys() {
        let cfg = ServeConfig::default();
        let (stats, _) = selftest(&cfg, load::TraceKind::Mixed, 7, 24);
        let a = summary_json(&stats, &cfg, "mixed", 7);
        let (stats2, _) = selftest(&cfg, load::TraceKind::Mixed, 7, 24);
        let b = summary_json(&stats2, &cfg, "mixed", 7);
        assert_eq!(a, b, "same seed, same bytes");
        for key in [
            "\"schema\": \"heeperator-serve-v1\"",
            "\"p50_latency_cycles\"",
            "\"p95_latency_cycles\"",
            "\"p99_latency_cycles\"",
            "\"per_tile_utilization\"",
            "\"batch_size_histogram\"",
            "\"queue_depth_samples\"",
            "\"rejected\"",
        ] {
            assert!(a.contains(key), "missing {key} in {a}");
        }
    }
}
