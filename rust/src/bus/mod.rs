//! System-bus model: the HEEPerator memory map, address decoding, and
//! transaction bookkeeping.
//!
//! The X-HEEP interconnect is modeled as a single-grant-per-cycle bus with
//! two masters (host CPU data port, DMA) and fixed DMA-first priority —
//! enough fidelity to reproduce the contention effects the paper measures
//! (DMA streaming micro-ops to NM-Caesar while the CPU polls). Instruction
//! fetches use the CPU's dedicated fetch port and do not arbitrate here
//! (they still count fetch energy; see `crate::cpu`).
//!
//! Memory map (32 KiB granularity for the RAM slots, mirroring the paper's
//! Fig. 1 where banks of the X-HEEP SRAM space are replaced by NMC
//! macros — the "drop-in memory tile" property the paper's scalability
//! claim rests on). Bank slots 6 and up are **NMC tile windows**: the
//! default HEEPerator instantiates one NM-Caesar (slot 6) and one
//! NM-Carus (slot 7), and a scale-out configuration may populate up to
//! [`MAX_TILES`] windows with any mix of the two macros:
//!
//! | Range                      | Slave                              |
//! |----------------------------|------------------------------------|
//! | `0x0000_0000..0x0003_0000` | SRAM banks 0..5 (6 × 32 KiB)       |
//! | `0x0003_0000..0x0003_8000` | NMC tile 0 (default: **NM-Caesar**)|
//! | `0x0003_8000..0x0004_0000` | NMC tile 1 (default: **NM-Carus**) |
//! | `0x0004_0000..0x000b_0000` | NMC tiles 2..15 (scale-out)        |
//! | `0x2000_0000..0x2000_1000` | Peripheral registers               |
//! | `0x4000_0000..`            | Flash/ROM (AD weights)             |

/// Base of the SRAM bank region.
pub const SRAM_BASE: u32 = 0x0000_0000;
/// Size of one RAM slot (32 KiB).
pub const BANK_SIZE: u32 = 0x8000;
/// Number of conventional SRAM banks (slots 0..5).
pub const NUM_SRAM_BANKS: usize = 6;
/// Base of the NMC tile windows (bank slot 6 onward).
pub const NMC_TILE_BASE: u32 = SRAM_BASE + NUM_SRAM_BANKS as u32 * BANK_SIZE;
/// Maximum number of decodable NMC tile windows.
pub const MAX_TILES: usize = 16;
/// Bus window of tile `i` (one bank slot per tile).
pub fn tile_base(i: usize) -> u32 {
    assert!(i < MAX_TILES, "tile {i} beyond the decoded window range");
    NMC_TILE_BASE + i as u32 * BANK_SIZE
}
/// NM-Caesar base address in the default HEEPerator config (tile 0).
pub const CAESAR_BASE: u32 = NMC_TILE_BASE;
/// NM-Carus base address in the default HEEPerator config (tile 1).
pub const CARUS_BASE: u32 = NMC_TILE_BASE + BANK_SIZE;
/// Peripheral register file base.
pub const PERIPH_BASE: u32 = 0x2000_0000;
/// Peripheral region size.
pub const PERIPH_SIZE: u32 = 0x1000;
/// Flash/ROM base.
pub const ROM_BASE: u32 = 0x4000_0000;
/// Flash/ROM maximum size.
pub const ROM_SIZE: u32 = 0x0100_0000;

/// Peripheral register offsets (from [`PERIPH_BASE`]).
pub mod periph {
    /// NM-Caesar `imc` mode pin register (bit 0: 1 = computing mode).
    pub const CAESAR_IMC: u32 = 0x00;
    /// NM-Carus mode register (bit 0: 1 = configuration mode).
    pub const CARUS_MODE: u32 = 0x04;
    /// DMA source address.
    pub const DMA_SRC: u32 = 0x10;
    /// DMA destination address.
    pub const DMA_DST: u32 = 0x14;
    /// DMA transfer length in bytes.
    pub const DMA_LEN: u32 = 0x18;
    /// DMA control: write starts; mode bits in [`crate::dma`].
    pub const DMA_CTL: u32 = 0x1c;
    /// DMA status: bit 0 = busy.
    pub const DMA_STATUS: u32 = 0x20;
    /// Cycle counter (read-only, for firmware-side timing).
    pub const MCYCLE: u32 = 0x30;
    /// Tile interrupt-enable mask: bit `i` lets tile `i`'s completion
    /// IRQ wake a `wfi`-sleeping host (the DMA IRQ always wakes). Resets
    /// to all-ones so single-tile firmware never has to program it; the
    /// batch scheduler narrows it per wait so a *done-but-not-yet-
    /// drained* tile cannot turn later `wfi` sleeps into spins.
    pub const IRQ_MASK: u32 = 0x34;
    /// Per-tile mode registers (bit 0): `TILE_MODE_BASE + 4*i` drives tile
    /// `i`'s mode pin — `imc` for an NM-Caesar tile, configuration mode
    /// for an NM-Carus tile. [`CAESAR_IMC`] / [`CARUS_MODE`] remain as
    /// aliases for the *first* tile of each kind (the single-tile
    /// firmware contract).
    pub const TILE_MODE_BASE: u32 = 0x100;
    /// Per-tile status registers (read-only, bit 0 = busy):
    /// `TILE_STATUS_BASE + 4*i`. This is the scale-out polling interface:
    /// the host watches tile completion without mode-switching the tile's
    /// bus window.
    pub const TILE_STATUS_BASE: u32 = 0x200;

    /// Mode register offset of tile `i`.
    pub fn tile_mode(i: usize) -> u32 {
        TILE_MODE_BASE + 4 * i as u32
    }
    /// Status register offset of tile `i`.
    pub fn tile_status(i: usize) -> u32 {
        TILE_STATUS_BASE + 4 * i as u32
    }
}

/// Decoded bus target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slave {
    /// Conventional SRAM bank `0..NUM_SRAM_BANKS`.
    Sram(usize),
    /// NMC tile window `0..MAX_TILES` (NM-Caesar or NM-Carus; whether the
    /// window is populated is the SoC's business, not the decoder's).
    Tile(usize),
    /// Peripheral registers.
    Periph,
    /// Flash/ROM.
    Rom,
}

/// Decode an address into (slave, offset-within-slave).
///
/// Returns `None` for unmapped addresses (a bus error in hardware; the
/// simulator treats it as a fatal modeling bug).
pub fn decode(addr: u32) -> Option<(Slave, u32)> {
    if addr < NMC_TILE_BASE {
        let bank = (addr / BANK_SIZE) as usize;
        return Some((Slave::Sram(bank), addr % BANK_SIZE));
    }
    if addr < NMC_TILE_BASE + MAX_TILES as u32 * BANK_SIZE {
        let off = addr - NMC_TILE_BASE;
        return Some((Slave::Tile((off / BANK_SIZE) as usize), off % BANK_SIZE));
    }
    if (PERIPH_BASE..PERIPH_BASE + PERIPH_SIZE).contains(&addr) {
        return Some((Slave::Periph, addr - PERIPH_BASE));
    }
    if (ROM_BASE..ROM_BASE.wrapping_add(ROM_SIZE)).contains(&addr) {
        return Some((Slave::Rom, addr - ROM_BASE));
    }
    None
}

/// Bus masters, in priority order (DMA wins ties so that NM-Caesar
/// micro-op streaming is deterministic; the CPU is typically polling or
/// sleeping while the DMA runs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Master {
    Dma,
    Cpu,
}

/// A bus transaction request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BusReq {
    pub addr: u32,
    /// `Some(value)` for writes, `None` for reads.
    pub write: Option<u32>,
    /// Access size in bytes (1, 2, 4).
    pub size: u32,
}

/// Per-run bus statistics (contention analysis / ablations).
#[derive(Debug, Clone, Copy, Default)]
pub struct BusStats {
    /// Transactions granted, per master.
    pub cpu_txns: u64,
    pub dma_txns: u64,
    /// Cycles a master wanted the bus but was not granted.
    pub cpu_wait_cycles: u64,
    pub dma_wait_cycles: u64,
    /// Cycles a granted transaction stalled on a busy slave (e.g. the
    /// NM-Caesar pipeline exerting backpressure).
    pub slave_stall_cycles: u64,
}

impl BusStats {
    pub fn total_txns(&self) -> u64 {
        self.cpu_txns + self.dma_txns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_decodes_every_region() {
        assert_eq!(decode(0x0000_0000), Some((Slave::Sram(0), 0)));
        assert_eq!(decode(0x0000_7fff), Some((Slave::Sram(0), 0x7fff)));
        assert_eq!(decode(0x0000_8000), Some((Slave::Sram(1), 0)));
        assert_eq!(decode(0x0002_ffff), Some((Slave::Sram(5), 0x7fff)));
        assert_eq!(decode(CAESAR_BASE), Some((Slave::Tile(0), 0)));
        assert_eq!(decode(CAESAR_BASE + 0x7fff), Some((Slave::Tile(0), 0x7fff)));
        assert_eq!(decode(CARUS_BASE), Some((Slave::Tile(1), 0)));
        assert_eq!(decode(PERIPH_BASE + periph::DMA_CTL), Some((Slave::Periph, periph::DMA_CTL)));
        assert_eq!(decode(ROM_BASE + 16), Some((Slave::Rom, 16)));
        assert_eq!(decode(0x1000_0000), None);
    }

    #[test]
    fn nmc_macros_sit_in_bank_slots() {
        // The drop-in property: the default Caesar and Carus occupy slots
        // 6 and 7 of what would otherwise be an 8-bank SRAM space.
        assert_eq!(CAESAR_BASE, 6 * BANK_SIZE);
        assert_eq!(CARUS_BASE, 7 * BANK_SIZE);
        assert_eq!(tile_base(0), CAESAR_BASE);
        assert_eq!(tile_base(1), CARUS_BASE);
    }

    #[test]
    fn tile_windows_decode_up_to_max() {
        // Scale-out windows: one 32 KiB slot per tile, contiguous above
        // the conventional banks, below the peripheral space.
        for i in 0..MAX_TILES {
            assert_eq!(decode(tile_base(i)), Some((Slave::Tile(i), 0)));
            assert_eq!(decode(tile_base(i) + 0x1234), Some((Slave::Tile(i), 0x1234)));
        }
        assert!(tile_base(MAX_TILES - 1) + BANK_SIZE <= PERIPH_BASE);
        // Beyond the last window: unmapped.
        assert_eq!(decode(NMC_TILE_BASE + MAX_TILES as u32 * BANK_SIZE), None);
    }

    #[test]
    fn per_tile_periph_offsets() {
        assert_eq!(periph::tile_mode(0), periph::TILE_MODE_BASE);
        assert_eq!(periph::tile_mode(3), periph::TILE_MODE_BASE + 12);
        assert_eq!(periph::tile_status(7), periph::TILE_STATUS_BASE + 28);
        // The register blocks must not collide with each other or the
        // legacy registers.
        assert!(periph::tile_mode(MAX_TILES - 1) < periph::TILE_STATUS_BASE);
        assert!(periph::tile_status(MAX_TILES - 1) < PERIPH_SIZE);
        // IRQ mask sits in the legacy block, clear of both tile ranges.
        assert!(periph::IRQ_MASK > periph::MCYCLE);
        assert!(periph::IRQ_MASK < periph::TILE_MODE_BASE);
        // One mask bit per possible tile.
        assert!(MAX_TILES <= 32);
    }
}
