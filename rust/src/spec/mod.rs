//! The unified job-spec vocabulary: one parse / validate / serialize path
//! for the `(target, family, sew, n, p, f, seed)` tuple that every
//! user-facing surface speaks.
//!
//! Before this module the repo carried three hand-rolled copies of that
//! tuple's wire format — the serve JSONL request parser, the
//! `sweep`/`scale`/`fuzz` CLI selector resolution, and the fuzz repro
//! JSON — each with its own defaulting and error behavior. They now all
//! route through [`JobSpec`]:
//!
//! - **serve** ([`crate::serve`]): [`JobSpec::parse_json`] with
//!   per-request seed defaulting ([`JsonSpecOptions::default_seed`]).
//! - **CLI selectors** ([`JobSpec::from_selectors`]): paper-default shape
//!   fallback via [`Kernel::with_shape`], exactly like `heeperator sweep`.
//! - **fuzz repro files** ([`JobSpec::parse_json`] with
//!   [`JsonSpecOptions::require_dims`]): exact shapes, no defaults.
//!
//! The [`schemas`] submodule is the single home of every versioned wire
//! schema tag; [`schemas::check`] turns a mismatched `schema` field into
//! the typed [`SpecError::Schema`] instead of best-effort parsing.

use crate::isa::Sew;
use crate::kernels::{Family, Kernel, Target};

/// Versioned wire-schema tags. Every JSON artifact the binary reads or
/// writes carries exactly one of these in its `schema` field.
pub mod schemas {
    use super::SpecError;

    /// `heeperator serve --selftest --json` summary.
    pub const SERVE_SUMMARY: &str = "heeperator-serve-v1";
    /// `heeperator serve` JSONL request line. Optional on the wire —
    /// requests predate the tag — but a *wrong* tag is rejected.
    pub const SERVE_REQUEST: &str = "heeperator-serve-req-v1";
    /// `heeperator serve --throughput --json` live-throughput summary.
    pub const SERVE_LIVE: &str = "heeperator-serve-live-v1";
    /// `heeperator fuzz` replayable repro file.
    pub const FUZZ_REPRO: &str = "heeperator-fuzz-repro-v1";
    /// `heeperator scale --json` / CI bench summary.
    pub const BENCH: &str = "heeperator-bench-v1";
    /// `heeperator model --json` graph-pipeline summary.
    pub const MODEL: &str = "heeperator-model-v1";

    /// Check a document's `schema` field against the expected tag.
    ///
    /// `required` surfaces (repro files, summaries) fail on a missing
    /// field; optional surfaces (serve request lines, which predate the
    /// tag) accept its absence but still reject a *wrong* value — a
    /// request stamped for a different protocol version must never be
    /// half-parsed.
    pub fn check(doc: &str, expected: &'static str, required: bool) -> Result<(), SpecError> {
        match super::json_str(doc, "schema") {
            Ok(got) if got == expected => Ok(()),
            Ok(got) => Err(SpecError::Schema { got: got.to_string(), expected }),
            Err(e) if required => Err(SpecError::Bad { field: "schema", reason: e }),
            Err(_) => Ok(()),
        }
    }
}

/// Typed spec-layer error. Shared by every parsing surface so a given
/// malformation produces the same diagnosis everywhere.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpecError {
    /// A required field is absent.
    Missing(&'static str),
    /// A field is present but unusable (wrong type, unknown spelling…).
    Bad { field: &'static str, reason: String },
    /// The document's `schema` tag names a different format/version.
    Schema { got: String, expected: &'static str },
    /// The shape parsed but fails the target's staging envelope.
    InvalidShape { kernel: Kernel, reason: String },
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, fm: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::Missing(field) => write!(fm, "missing field {field:?}"),
            SpecError::Bad { field, reason } => write!(fm, "bad {field:?}: {reason}"),
            SpecError::Schema { got, expected } => {
                write!(fm, "unknown schema {got:?} (expected {expected:?})")
            }
            SpecError::InvalidShape { kernel, reason } => {
                write!(fm, "invalid shape {kernel:?}: {reason}")
            }
        }
    }
}

impl std::error::Error for SpecError {}

/// One fully-resolved job description: which engine runs which kernel
/// shape at which element width, on which deterministic input.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct JobSpec {
    pub target: Target,
    pub kernel: Kernel,
    pub sew: Sew,
    pub seed: u64,
}

/// Knobs for [`JobSpec::parse_json`] — the per-surface defaulting policy,
/// named so each call site documents which wire format it speaks.
#[derive(Debug, Clone, Copy)]
pub struct JsonSpecOptions {
    /// Key carrying the workload seed (`"seed"` for requests,
    /// `"spec_seed"` in repro files where `"seed"` is the fuzzer's own).
    pub seed_key: &'static str,
    /// Seed to use when the key is absent (`None` = field required).
    pub default_seed: Option<u64>,
    /// Require explicit `n`/`p`/`f` keys (repro files reproduce *exact*
    /// shapes); otherwise absent dims default to 0 and surface through
    /// [`JobSpec::validate`].
    pub require_dims: bool,
}

impl JobSpec {
    /// Resolve CLI selector strings (`--target`/`--family`/`--sew` plus
    /// optional dimensions) into a spec, falling back to the paper's
    /// Table V shape for any dimension not given — the `heeperator
    /// sweep`/`scale`/`fuzz` entry point.
    pub fn from_selectors(
        target: &str,
        family: &str,
        sew_bits: u32,
        n: Option<u32>,
        p: Option<u32>,
        f: Option<u32>,
        seed: u64,
    ) -> Result<JobSpec, SpecError> {
        let target = Target::parse(target).ok_or_else(|| SpecError::Bad {
            field: "target",
            reason: format!("unknown target `{target}` (cpu, caesar, carus)"),
        })?;
        let family = Family::parse(family).ok_or_else(|| SpecError::Bad {
            field: "family",
            reason: format!("unknown family `{family}` (xor, add, …, maxpool)"),
        })?;
        let sew = sew_from_bits(sew_bits as u64)?;
        let kernel = Kernel::with_shape(family, target, sew, n, p, f);
        Ok(JobSpec { target, kernel, sew, seed })
    }

    /// Extract a spec from a flat JSON document (a serve request line or
    /// a repro file). Pure extraction: shape legality is a separate
    /// [`JobSpec::validate`] call so surfaces that must round-trip
    /// illegal shapes (shrunken fuzz cases) can opt out.
    pub fn parse_json(doc: &str, opt: &JsonSpecOptions) -> Result<JobSpec, SpecError> {
        let target = json_str(doc, "target")
            .map_err(|reason| SpecError::Bad { field: "target", reason })
            .and_then(|s| {
                Target::parse(s).ok_or_else(|| SpecError::Bad {
                    field: "target",
                    reason: format!("unknown target `{s}`"),
                })
            })?;
        let family = json_str(doc, "family")
            .map_err(|reason| SpecError::Bad { field: "family", reason })
            .and_then(|s| {
                Family::parse(s).ok_or_else(|| SpecError::Bad {
                    field: "family",
                    reason: format!("unknown family `{s}`"),
                })
            })?;
        let sew = json_u64(doc, "sew")
            .map_err(|reason| SpecError::Bad { field: "sew", reason })
            .and_then(sew_from_bits)?;
        let dim = |key: &'static str| -> Result<u32, SpecError> {
            match json_u64(doc, key) {
                Ok(v) => Ok(v as u32),
                Err(_) if !opt.require_dims => Ok(0),
                Err(reason) => Err(SpecError::Bad { field: key, reason }),
            }
        };
        let kernel = kernel_from(family, dim("n")?, dim("p")?, dim("f")?);
        let seed = match (json_u64(doc, opt.seed_key), opt.default_seed) {
            (Ok(s), _) => s,
            (Err(_), Some(d)) => d,
            (Err(reason), None) => return Err(SpecError::Bad { field: "seed", reason }),
        };
        Ok(JobSpec { target, kernel, sew, seed })
    }

    /// Render the spec's JSON fields (without braces) in canonical order,
    /// `sep` between fields — the one serializer every surface embeds.
    pub fn render_json(&self, sep: &str, seed_key: &str) -> String {
        let (n, p, f) = shape_of(self.kernel);
        format!(
            "\"target\": \"{}\",{sep}\"family\": \"{}\",{sep}\"sew\": {},{sep}\"n\": {n},{sep}\
             \"p\": {p},{sep}\"f\": {f},{sep}\"{seed_key}\": {}",
            target_slug(self.target),
            family_slug(self.kernel.family()),
            self.sew.bits(),
            self.seed
        )
    }

    /// Check the shape against the target's staging envelope.
    pub fn validate(&self) -> Result<(), SpecError> {
        self.kernel
            .validate(self.target, self.sew)
            .map_err(|reason| SpecError::InvalidShape { kernel: self.kernel, reason })
    }
}

/// Map a `sew` bit count (8/16/32) to the element width.
pub fn sew_from_bits(bits: u64) -> Result<Sew, SpecError> {
    match bits {
        8 => Ok(Sew::E8),
        16 => Ok(Sew::E16),
        32 => Ok(Sew::E32),
        b => Err(SpecError::Bad { field: "sew", reason: format!("unknown sew {b}") }),
    }
}

/// Wire spelling of a family (round-trips through [`Family::parse`]).
pub fn family_slug(f: Family) -> &'static str {
    match f {
        Family::Xor => "xor",
        Family::Add => "add",
        Family::Mul => "mul",
        Family::Matmul => "matmul",
        Family::Gemm => "gemm",
        Family::Conv2d => "conv2d",
        Family::Relu => "relu",
        Family::LeakyRelu => "leakyrelu",
        Family::Maxpool => "maxpool",
    }
}

/// Wire spelling of a target (round-trips through [`Target::parse`]).
pub fn target_slug(t: Target) -> &'static str {
    match t {
        Target::Cpu => "cpu",
        Target::Caesar => "caesar",
        Target::Carus => "carus",
    }
}

/// Exact kernel reconstruction from (family, dims) — the inverse of
/// [`shape_of`]. Unlike [`Kernel::with_shape`] this never falls back to
/// paper defaults: a wire document reproduces *exactly* its shape.
pub fn kernel_from(family: Family, n: u32, p: u32, f: u32) -> Kernel {
    match family {
        Family::Xor => Kernel::Xor { n },
        Family::Add => Kernel::Add { n },
        Family::Mul => Kernel::Mul { n },
        Family::Matmul => Kernel::Matmul { p },
        Family::Gemm => Kernel::Gemm { p },
        Family::Conv2d => Kernel::Conv2d { n, f },
        Family::Relu => Kernel::Relu { n },
        Family::LeakyRelu => Kernel::LeakyRelu { n },
        Family::Maxpool => Kernel::Maxpool { n },
    }
}

/// `(n, p, f)` of a kernel, zeros for unused dims.
pub fn shape_of(k: Kernel) -> (u32, u32, u32) {
    match k {
        Kernel::Xor { n }
        | Kernel::Add { n }
        | Kernel::Mul { n }
        | Kernel::Relu { n }
        | Kernel::LeakyRelu { n }
        | Kernel::Maxpool { n } => (n, 0, 0),
        Kernel::Matmul { p } | Kernel::Gemm { p } => (0, p, 0),
        Kernel::Conv2d { n, f } => (n, 0, f),
    }
}

// ---------------------------------------------------------------------------
// Hand-rolled flat-JSON helpers (the repo is std-only: no serde). Shared
// by every wire surface; values are extracted positionally from the
// first occurrence of the key.
// ---------------------------------------------------------------------------

/// Escape a string for embedding in a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a `u32` slice as a JSON array.
pub fn json_list(xs: &[u32]) -> String {
    let items: Vec<String> = xs.iter().map(u32::to_string).collect();
    format!("[{}]", items.join(","))
}

/// Slice positioned at the raw value of `key` (after the colon).
pub fn json_raw<'a>(s: &'a str, key: &str) -> Result<&'a str, String> {
    let pat = format!("\"{key}\"");
    let at = s.find(&pat).ok_or_else(|| format!("missing key {key:?}"))?;
    let rest = &s[at + pat.len()..];
    let rest = rest.trim_start();
    let rest = rest.strip_prefix(':').ok_or_else(|| format!("malformed value for {key:?}"))?;
    Ok(rest.trim_start())
}

/// Extract an unsigned integer value.
pub fn json_u64(s: &str, key: &str) -> Result<u64, String> {
    let raw = json_raw(s, key)?;
    let end = raw.find(|c: char| !c.is_ascii_digit()).unwrap_or(raw.len());
    raw[..end].parse::<u64>().map_err(|_| format!("{key:?} is not a number"))
}

/// Extract a string value (no unescaping — wire slugs are plain).
pub fn json_str<'a>(s: &'a str, key: &str) -> Result<&'a str, String> {
    let raw = json_raw(s, key)?;
    let raw = raw.strip_prefix('"').ok_or_else(|| format!("{key:?} is not a string"))?;
    let end = raw.find('"').ok_or_else(|| format!("unterminated string for {key:?}"))?;
    Ok(&raw[..end])
}

/// Extract a boolean value.
pub fn json_bool(s: &str, key: &str) -> Result<bool, String> {
    let raw = json_raw(s, key)?;
    if raw.starts_with("true") {
        Ok(true)
    } else if raw.starts_with("false") {
        Ok(false)
    } else {
        Err(format!("{key:?} is not a bool"))
    }
}

/// Extract a `u32` array value.
pub fn json_u32_list(s: &str, key: &str) -> Result<Vec<u32>, String> {
    let raw = json_raw(s, key)?;
    let raw = raw.strip_prefix('[').ok_or_else(|| format!("{key:?} is not a list"))?;
    let end = raw.find(']').ok_or_else(|| format!("unterminated list for {key:?}"))?;
    let body = raw[..end].trim();
    if body.is_empty() {
        return Ok(Vec::new());
    }
    body.split(',')
        .map(|x| x.trim().parse::<u32>().map_err(|_| format!("bad element in {key:?}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_specs() -> Vec<JobSpec> {
        let mut out = Vec::new();
        for target in Target::ALL {
            for family in Family::ALL {
                for sew in Sew::ALL {
                    let kernel = Kernel::paper_default(family, target, sew);
                    out.push(JobSpec { target, kernel, sew, seed: 7 });
                }
            }
        }
        out
    }

    /// The serve-request surface: compact JSON, seed defaulted.
    #[test]
    fn json_roundtrip_request_surface() {
        let opt = JsonSpecOptions { seed_key: "seed", default_seed: Some(0), require_dims: false };
        for spec in all_specs() {
            let doc = format!("{{{}}}", spec.render_json(" ", "seed"));
            let back = JobSpec::parse_json(&doc, &opt).expect("round-trip parses");
            assert_eq!(back, spec, "{doc}");
        }
    }

    /// The repro-file surface: pretty JSON, exact dims required.
    #[test]
    fn json_roundtrip_repro_surface() {
        let opt =
            JsonSpecOptions { seed_key: "spec_seed", default_seed: None, require_dims: true };
        for spec in all_specs() {
            let doc = format!("{{\n  {}\n}}\n", spec.render_json("\n  ", "spec_seed"));
            let back = JobSpec::parse_json(&doc, &opt).expect("round-trip parses");
            assert_eq!(back, spec, "{doc}");
        }
    }

    /// The CLI-selector surface: slugs resolve back to the same spec.
    #[test]
    fn selector_roundtrip_cli_surface() {
        for spec in all_specs() {
            let (n, p, f) = shape_of(spec.kernel);
            let nz = |v: u32| (v != 0).then_some(v);
            let back = JobSpec::from_selectors(
                target_slug(spec.target),
                family_slug(spec.kernel.family()),
                spec.sew.bits(),
                nz(n),
                nz(p),
                nz(f),
                spec.seed,
            )
            .expect("selectors resolve");
            assert_eq!(back, spec);
        }
    }

    #[test]
    fn missing_seed_defaults_or_errors() {
        let doc = r#"{"target": "carus", "family": "relu", "sew": 8, "n": 256}"#;
        let with_default =
            JsonSpecOptions { seed_key: "seed", default_seed: Some(42), require_dims: false };
        assert_eq!(JobSpec::parse_json(doc, &with_default).unwrap().seed, 42);
        let strict = JsonSpecOptions { seed_key: "seed", default_seed: None, require_dims: false };
        assert!(matches!(
            JobSpec::parse_json(doc, &strict),
            Err(SpecError::Bad { field: "seed", .. })
        ));
    }

    #[test]
    fn require_dims_rejects_missing_shape() {
        let doc = r#"{"target": "carus", "family": "matmul", "sew": 8, "spec_seed": 1}"#;
        let strict =
            JsonSpecOptions { seed_key: "spec_seed", default_seed: None, require_dims: true };
        assert!(matches!(
            JobSpec::parse_json(doc, &strict),
            Err(SpecError::Bad { field: "n", .. })
        ));
        let lax =
            JsonSpecOptions { seed_key: "spec_seed", default_seed: None, require_dims: false };
        // Dims default to 0 and the shape surfaces through validate().
        let spec = JobSpec::parse_json(doc, &lax).unwrap();
        assert_eq!(spec.kernel, Kernel::Matmul { p: 0 });
        assert!(matches!(spec.validate(), Err(SpecError::InvalidShape { .. })));
    }

    #[test]
    fn schema_check_is_typed() {
        let ok = format!("{{\"schema\": \"{}\"}}", schemas::FUZZ_REPRO);
        assert!(schemas::check(&ok, schemas::FUZZ_REPRO, true).is_ok());
        let wrong = r#"{"schema": "something-else"}"#;
        match schemas::check(wrong, schemas::FUZZ_REPRO, true) {
            Err(SpecError::Schema { got, expected }) => {
                assert_eq!(got, "something-else");
                assert_eq!(expected, schemas::FUZZ_REPRO);
            }
            other => panic!("expected a typed schema error, got {other:?}"),
        }
        // Missing field: fatal only where the tag is mandatory.
        assert!(schemas::check("{}", schemas::FUZZ_REPRO, true).is_err());
        assert!(schemas::check("{}", schemas::SERVE_SUMMARY, false).is_ok());
    }

    #[test]
    fn selector_errors_name_the_field() {
        let e = JobSpec::from_selectors("tpu", "relu", 8, None, None, None, 0).unwrap_err();
        assert!(matches!(e, SpecError::Bad { field: "target", .. }), "{e}");
        let e = JobSpec::from_selectors("cpu", "blur", 8, None, None, None, 0).unwrap_err();
        assert!(matches!(e, SpecError::Bad { field: "family", .. }), "{e}");
        let e = JobSpec::from_selectors("cpu", "relu", 12, None, None, None, 0).unwrap_err();
        assert!(matches!(e, SpecError::Bad { field: "sew", .. }), "{e}");
    }

    #[test]
    fn kernel_from_inverts_shape_of_everywhere() {
        for family in Family::ALL {
            let k = Kernel::paper_default(family, Target::Carus, Sew::E16);
            let (n, p, f) = shape_of(k);
            assert_eq!(kernel_from(family, n, p, f), k);
        }
    }
}
