//! Event-driven timing control: the simulation clock's skip-ahead layer.
//!
//! The SoC supports two timing disciplines, selected by [`TimingMode`]:
//!
//! * **`Cycle`** — the legacy reference: `Soc::run` calls `Soc::step`
//!   once per simulated cycle, no matter how quiet the cycle is.
//! * **`Event`** — skip-ahead: between steps the SoC derives, from
//!   component state alone, a *monotonic event queue* of the next
//!   "interesting" cycles ([`EventKind`]) and jumps simulated time to
//!   one cycle before the earliest of them, updating cycle / energy /
//!   utilization counters in closed form for the skipped quiet span.
//!
//! The contract that makes the two modes interchangeable (and is locked
//! by `rust/tests/timing_equivalence.rs`) is **strict quietness**: a
//! cycle may only be skipped if it is provably linear — pure countdown
//! decrements with no state transition and no externally visible
//! change. Every transition (an instruction retiring, a stall
//! releasing, a DMA completion edge, a CPU fetch) still executes
//! through the *same* per-cycle `step` code at the span boundary, so
//! event mode produces byte-identical outputs and identical
//! cycle/energy/activity counters by construction.
//!
//! Mode selection, outermost first:
//!
//! 1. a scoped thread-local override ([`with_mode`]) — used by the
//!    differential tests to pin each half of a comparison;
//! 2. the process-wide default ([`set_global`]) — set once by the CLI's
//!    `--timing cycle|event` flag;
//! 3. the `SOC_TIMING` environment variable (`cycle` or `event`);
//! 4. [`TimingMode::Event`] — skip-ahead is the default discipline.

use std::cell::Cell;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::OnceLock;

/// Timing discipline for `Soc::run`. See the module docs for the
/// equivalence contract between the two.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TimingMode {
    /// Legacy per-cycle stepping: the differential reference.
    Cycle,
    /// Skip-ahead over strictly quiet spans (default).
    Event,
}

impl TimingMode {
    /// Parse a user-facing mode name (`"cycle"` / `"event"`).
    pub fn parse(s: &str) -> Option<TimingMode> {
        match s {
            "cycle" => Some(TimingMode::Cycle),
            "event" => Some(TimingMode::Event),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            TimingMode::Cycle => "cycle",
            TimingMode::Event => "event",
        }
    }
}

impl std::fmt::Display for TimingMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

static GLOBAL: OnceLock<TimingMode> = OnceLock::new();

thread_local! {
    static OVERRIDE: Cell<Option<TimingMode>> = const { Cell::new(None) };
}

fn global() -> TimingMode {
    *GLOBAL.get_or_init(|| {
        std::env::var("SOC_TIMING")
            .ok()
            .and_then(|v| TimingMode::parse(&v))
            .unwrap_or(TimingMode::Event)
    })
}

/// Install the process-wide default mode (first caller wins; later calls
/// are ignored, as is the `SOC_TIMING` env var once a default is set).
/// Used by the CLI's `--timing` flag before any simulation starts.
pub fn set_global(mode: TimingMode) {
    let _ = GLOBAL.set(mode);
}

/// The mode new `Soc` instances adopt on this thread right now.
pub fn mode() -> TimingMode {
    OVERRIDE.with(|o| o.get()).unwrap_or_else(global)
}

/// Run `f` with `mode` pinned for `Soc`s constructed on this thread —
/// scoped and re-entrant, so differential tests can run both timing
/// disciplines side by side without touching process state.
pub fn with_mode<R>(mode: TimingMode, f: impl FnOnce() -> R) -> R {
    let prev = OVERRIDE.with(|o| o.replace(Some(mode)));
    let r = f();
    OVERRIDE.with(|o| o.set(prev));
    r
}

/// Why a simulated cycle is "interesting" — i.e. must run through the
/// per-cycle `step` code instead of being skipped in closed form.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EventKind {
    /// The DMA is moving data (or its completion edge is pending): every
    /// such cycle does real per-word work and must be stepped.
    DmaDone,
    /// Tile `i`'s internal countdown (VPU instruction retire, eCPU stall
    /// release, completion handshake) expires.
    TileDone(usize),
    /// The host CPU's multi-cycle instruction stall releases.
    CpuStallRelease,
    /// The host CPU is awake and executing (e.g. polling firmware): the
    /// degenerate "next cycle" event.
    PollRetry,
}

/// A scheduled wake-up: `at` is the first simulated cycle that must be
/// stepped rather than skipped.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Event {
    pub at: u64,
    pub kind: EventKind,
}

/// Monotonic min-queue of pending [`Event`]s. The SoC rebuilds it from
/// component state at each skip decision (a stateless derivation — that
/// is what keeps the equivalence proof local), pops the earliest event,
/// and skips to one cycle before it.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, at: u64, kind: EventKind) {
        self.heap.push(Reverse(Event { at, kind }));
    }

    /// Earliest pending event, if any (ties broken by `EventKind` order).
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    pub fn peek(&self) -> Option<&Event> {
        self.heap.peek().map(|Reverse(e)| e)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_round_trip() {
        assert_eq!(TimingMode::parse("cycle"), Some(TimingMode::Cycle));
        assert_eq!(TimingMode::parse("event"), Some(TimingMode::Event));
        assert_eq!(TimingMode::parse("EVENT"), None);
        assert_eq!(TimingMode::parse(""), None);
        assert_eq!(TimingMode::Cycle.to_string(), "cycle");
        assert_eq!(TimingMode::Event.to_string(), "event");
    }

    #[test]
    fn with_mode_is_scoped_and_nests() {
        let outer = mode();
        with_mode(TimingMode::Cycle, || {
            assert_eq!(mode(), TimingMode::Cycle);
            with_mode(TimingMode::Event, || assert_eq!(mode(), TimingMode::Event));
            assert_eq!(mode(), TimingMode::Cycle);
        });
        assert_eq!(mode(), outer);
    }

    #[test]
    fn queue_pops_in_monotonic_order() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(30, EventKind::TileDone(1));
        q.push(10, EventKind::DmaDone);
        q.push(20, EventKind::CpuStallRelease);
        q.push(10, EventKind::PollRetry);
        assert_eq!(q.len(), 4);
        assert_eq!(q.peek().map(|e| e.at), Some(10));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|e| e.at).collect();
        assert_eq!(order, [10, 10, 20, 30]);
        q.push(5, EventKind::TileDone(0));
        q.clear();
        assert!(q.pop().is_none());
    }
}
