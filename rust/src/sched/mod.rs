//! Multi-tile batch scheduler: shard a batch of workloads (or one large
//! kernel) across N NMC tiles and co-simulate the whole orchestration
//! cycle by cycle.
//!
//! The paper's headline claim is *scalability*: NM-Caesar and NM-Carus
//! are drop-in memory-tile replacements, so an edge SoC can instantiate
//! several of them behind one bus ([`Soc::with_tiles`]) and shard work
//! across them. This module turns that claim into a measurable system:
//!
//! 1. [`plan`] validates a [`BatchSpec`] against a tile count — engine
//!    tileability ([`Engine::tile_program`]), per-shard shape limits
//!    ([`Kernel::validate`]), and SRAM staging capacity — and compiles
//!    the host firmware: a static round-robin schedule where workload
//!    `w` runs on tile `w % tiles` in round `w / tiles`.
//! 2. [`run_planned`] pre-stages every input image in system SRAM,
//!    then simulates: the host **sleeps** (`wfi`) on DMA-completion and
//!    tile-done interrupts — gated per wait through
//!    [`periph::IRQ_MASK`] so a done-but-undrained tile cannot spin a
//!    later sleep — while it DMA-stages the next workload's operands
//!    into an idle tile (and its predecessor's results out) *while the
//!    other tiles execute*; staging serializes on the single DMA,
//!    execution overlaps. For
//!    NM-Carus tiles execution is autonomous ([`TileExec::Autonomous`]);
//!    for NM-Caesar the micro-op stream *is* the DMA transfer
//!    ([`TileExec::Stream`]), so scale-out degenerates to serial
//!    execution — the honest architectural limit, visible in the report.
//! 3. Every canonical output is asserted byte-identical to the golden
//!    reference (and, in shard mode, the reassembled output to the
//!    *whole* kernel's golden output), so the tiled path can never drift
//!    from the single-tile engines.
//!
//! Two work decompositions:
//! - **batch** — `batch` independent workloads of one shape, seeds
//!   `seed..seed+batch`;
//! - **shard** — one large kernel split along its free dimension (the
//!   N elements of the element-wise families, the P columns of
//!   matmul/GEMM) into `tiles` word-aligned shards, one per tile.
//!
//! `heeperator scale` sweeps tile counts over this module and reports
//! the scaling curve; [`crate::sweep::SweepSession::scale`] memoizes one
//! co-simulation per `(spec, tiles)` point.

pub mod pipeline;

use crate::asm::{Asm, Program};
use crate::bus::{self, periph, BANK_SIZE, NMC_TILE_BASE, PERIPH_BASE};
use crate::carus::{ARG_OFFSET, CTL_OFFSET, CTL_START};
use crate::energy::Breakdown;
use crate::isa::reg::*;
use crate::isa::Sew;
use crate::kernels::golden::{self, WorkloadData};
use crate::kernels::{engine, run_timeout, Engine, Kernel, Target, TileExec, TileProgram};
use crate::soc::{Halt, Soc, TileKind};

/// Why a [`BatchSpec`] cannot be planned. Every failure the planner can
/// produce is a distinct variant, so callers (the differential fuzzer,
/// the CLI, tests) can match on the cause instead of grepping a string;
/// [`std::fmt::Display`] keeps the human-readable message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SchedError {
    /// Tile count outside `1..=`[`bus::MAX_TILES`].
    TileCount { got: usize },
    /// `--target cpu`: the CPU is the host, never a tile.
    HostTarget,
    /// `batch == 0` in batch mode.
    EmptyBatch,
    /// The kernel shape (or one shard of it) fails [`Kernel::validate`]
    /// for the target.
    InvalidShape { kernel: Kernel, reason: String },
    /// The kernel has no 1-D shard axis (2-D window kernels).
    Unshardable { kernel: Kernel },
    /// The shard axis does not split into word-aligned pieces.
    ShardSplit { kernel: Kernel, reason: String },
    /// The engine has no tiled execute path for this kernel. No built-in
    /// engine/kernel pair hits this today; the variant guards future
    /// backends behind the same `Err`-not-panic promise.
    NotTileable { target: Target, kernel: Kernel },
    /// A tile staging region or output span is not 32-bit word-aligned.
    /// The built-in engines only emit word-aligned IO for shapes that
    /// pass [`Kernel::validate`]; the variant keeps the DMA staging
    /// invariant an `Err` (not an `assert!`) for any future backend —
    /// a request-supplied shape must never crash the serve front-end.
    Misaligned { kernel: Kernel, what: &'static str },
    /// A coalesced group ([`plan_jobs`]) mixes kernel families. The tile
    /// setup image is shared across one batch, so one family per group.
    MixedBatch { first: Kernel, other: Kernel },
    /// A coalesced group places two different kernels on the same
    /// stream-executed tile slot (NM-Caesar replays one rendered
    /// micro-op stream per tile across rounds).
    StreamMismatch { expected: Kernel, got: Kernel },
    /// Input/output staging exceeds the SRAM pool.
    StagingOverflow,
    /// The compiled host firmware exceeds the code bank.
    FirmwareTooLarge { bytes: u32 },
    /// The firmware failed to assemble (an internal bug surfaced safely).
    Assemble(String),
}

impl std::fmt::Display for SchedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchedError::TileCount { got } => {
                write!(f, "tile count must be 1..={}, got {got}", bus::MAX_TILES)
            }
            SchedError::HostTarget => {
                write!(f, "the CPU is the host, not a tile — pick caesar or carus")
            }
            SchedError::EmptyBatch => write!(f, "batch must be at least 1"),
            SchedError::InvalidShape { kernel, reason } => write!(f, "{kernel:?}: {reason}"),
            SchedError::Unshardable { kernel } => write!(
                f,
                "{kernel:?} has no 1-D shard axis (2-D windows span the split) — use batch mode"
            ),
            SchedError::ShardSplit { kernel, reason } => {
                write!(f, "cannot shard {kernel:?}: {reason}")
            }
            SchedError::NotTileable { target, kernel } => write!(
                f,
                "{target:?} {kernel:?} has no tiled execute path (host-CPU phase required)"
            ),
            SchedError::Misaligned { kernel, what } => write!(
                f,
                "{kernel:?}: tile {what} is not word-aligned — the DMA staging path moves whole \
                 32-bit words"
            ),
            SchedError::MixedBatch { first, other } => write!(
                f,
                "cannot coalesce {other:?} with {first:?}: one kernel family per batch (the tile \
                 setup image is shared)"
            ),
            SchedError::StreamMismatch { expected, got } => write!(
                f,
                "cannot coalesce {got:?}: its tile slot already streams {expected:?} (stream \
                 tiles replay one rendered micro-op stream per tile)"
            ),
            SchedError::StagingOverflow => write!(
                f,
                "staging exceeds the {} KiB SRAM pool (batch/shape too large for the tile count)",
                (POOL_END - POOL_BASE) / 1024
            ),
            SchedError::FirmwareTooLarge { bytes } => write!(
                f,
                "scheduler firmware ({bytes} B) exceeds the 32 KiB code bank — reduce the batch"
            ),
            SchedError::Assemble(e) => write!(f, "scheduler firmware failed to assemble: {e}"),
        }
    }
}

impl std::error::Error for SchedError {}

/// One batched/sharded scale-out scenario (the memoization key of
/// [`crate::sweep::SweepSession::scale`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BatchSpec {
    pub target: Target,
    pub kernel: Kernel,
    pub sew: Sew,
    pub seed: u64,
    /// Batch mode: independent workloads, seeds `seed..seed+batch`.
    /// Ignored in shard mode (the shard count is the tile count).
    pub batch: u32,
    /// Shard one large kernel along N/P instead of batching.
    pub shard: bool,
}

/// Per-tile accounting of one co-simulated schedule.
#[derive(Debug, Clone, Copy)]
pub struct TileStats {
    pub kind: TileKind,
    /// Cycles the tile was computing (from [`Soc::tile_busy`]).
    pub busy_cycles: u64,
    /// Workloads the schedule placed on this tile.
    pub workloads: u32,
}

/// Result of one `(spec, tiles)` co-simulation.
#[derive(Debug, Clone)]
pub struct BatchRunResult {
    pub spec: BatchSpec,
    pub tiles: u32,
    /// Makespan of the whole schedule (setup + staging + execution).
    pub cycles: u64,
    pub energy: Breakdown,
    pub per_tile: Vec<TileStats>,
    pub dma_active_cycles: u64,
    pub dma_transfers: u64,
    pub bus_txns: u64,
    /// CPU wait-on-held-slave cycles + slave backpressure stalls — the
    /// bus-contention figure of the scale report.
    pub contention_cycles: u64,
    /// Canonical outputs: one per workload (batch mode) or the single
    /// reassembled output (shard mode). Each is asserted against the
    /// golden reference before this struct exists.
    pub outputs: Vec<Vec<u8>>,
}

impl BatchRunResult {
    /// Fraction of the makespan tile `i` spent computing. An
    /// out-of-range tile index answers 0.0 (a tile that does not exist
    /// never computed — the serve report may probe up to the configured
    /// tile count), and the zero-makespan denominator follows the same
    /// `.max(1)` convention as [`Self::speedup_vs`] so the two
    /// zero-cycle behaviors agree.
    pub fn utilization(&self, i: usize) -> f64 {
        self.per_tile
            .get(i)
            .map_or(0.0, |t| t.busy_cycles as f64 / self.cycles.max(1) as f64)
    }

    /// Mean utilization across tiles.
    pub fn mean_utilization(&self) -> f64 {
        if self.per_tile.is_empty() {
            return 0.0;
        }
        (0..self.per_tile.len()).map(|i| self.utilization(i)).sum::<f64>()
            / self.per_tile.len() as f64
    }

    /// Aggregate speedup of this run over a baseline run of the same spec.
    pub fn speedup_vs(&self, base: &BatchRunResult) -> f64 {
        base.cycles as f64 / self.cycles.max(1) as f64
    }
}

/// One workload as placed by the planner.
struct PlannedWork {
    kernel: Kernel,
    /// Golden canonical output (asserted post-run).
    expect: Vec<u8>,
    /// Input regions: (SRAM staging address, tile-window offset, bytes).
    inputs: Vec<(u32, u32, Vec<u8>)>,
    /// Output span: (SRAM staging address, tile-window offset, length).
    output: (u32, u32, u32),
    /// eMEM argument words (NM-Carus), written before each start.
    args: Vec<u32>,
}

/// A validated, fully-compiled schedule, ready to simulate.
pub struct Plan {
    pub spec: BatchSpec,
    pub tiles: usize,
    kind: TileKind,
    workloads: Vec<PlannedWork>,
    /// Config-mode tile setup image (NM-Carus eCPU kernel; may be empty),
    /// staged once in SRAM and DMA-uploaded to every tile.
    setup: (u32, Vec<u8>),
    /// Per-tile rendered micro-op streams (NM-Caesar): (SRAM address, bytes).
    streams: Vec<(u32, Vec<u8>)>,
    firmware: Program,
    /// Shard mode: the whole kernel's golden data for reassembly checks.
    whole: Option<WorkloadData>,
}

impl Plan {
    /// The tile kind this plan schedules onto — what a worker needs to
    /// know to pre-warm a matching [`Soc`] replica for
    /// [`run_planned_on`].
    pub fn kind(&self) -> TileKind {
        self.kind
    }
}

/// Staging pool: SRAM banks 1..6 (bank 0 holds the scheduler firmware).
const POOL_BASE: u32 = BANK_SIZE;
const POOL_END: u32 = NMC_TILE_BASE;

/// Test-only fault injection for the per-workload staging paths. The
/// built-in engines tile every kernel and emit word-aligned IO, so the
/// `NotTileable`/`Misaligned` guards inside [`plan`] are unreachable
/// through public inputs today; regression tests arm a fault to prove
/// each guard stays a typed `Err` — never a panic — for any future
/// backend. Thread-local, so an armed test cannot perturb planning on
/// concurrently-running test threads.
#[doc(hidden)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileFault {
    /// The stream loop's per-tile program lookup answers `None`.
    StreamProgram,
    /// [`Engine::tile_io`] answers `None` for a planned workload.
    Io,
    /// The per-workload argument-words program lookup answers `None`.
    ArgsProgram,
    /// An input staging region presents as word-misaligned.
    Misalign,
    /// The output span presents as word-misaligned.
    MisalignOut,
}

thread_local! {
    static TILE_FAULT: std::cell::Cell<Option<TileFault>> =
        const { std::cell::Cell::new(None) };
}

/// Arm (or clear, with `None`) a [`TileFault`] on the current thread.
#[doc(hidden)]
pub fn arm_tile_fault(fault: Option<TileFault>) {
    TILE_FAULT.with(|f| f.set(fault));
}

fn tile_fault() -> Option<TileFault> {
    TILE_FAULT.with(|f| f.get())
}

/// Index of `kernel`'s assembled [`TileProgram`] in `programs`,
/// assembling and caching it on first use (one assembly per distinct
/// kernel per plan). `None` if the engine has no tiled path for it.
fn program_idx(
    programs: &mut Vec<(Kernel, TileProgram)>,
    eng: &dyn Engine,
    kernel: Kernel,
    sew: Sew,
) -> Option<usize> {
    if let Some(i) = programs.iter().position(|(k, _)| *k == kernel) {
        return Some(i);
    }
    let prog = eng.tile_program(kernel, sew)?;
    programs.push((kernel, prog));
    Some(programs.len() - 1)
}

/// Resolve the tile kind for a scheduling request, rejecting bad tile
/// counts and the host target up front (shared by [`plan`] and
/// [`plan_jobs`]).
fn tile_kind(target: Target, tiles: usize) -> Result<TileKind, SchedError> {
    if tiles == 0 || tiles > bus::MAX_TILES {
        return Err(SchedError::TileCount { got: tiles });
    }
    match target {
        Target::Caesar => Ok(TileKind::Caesar),
        Target::Carus => Ok(TileKind::Carus),
        Target::Cpu => Err(SchedError::HostTarget),
    }
}

/// Validate `spec` on `tiles` tiles and compile the schedule.
pub fn plan(spec: &BatchSpec, tiles: usize) -> Result<Plan, SchedError> {
    let kind = tile_kind(spec.target, tiles)?;

    // ---- Work decomposition ------------------------------------------------
    // Shape validation runs here, BEFORE any tile program is assembled:
    // the engines' builders contain shape asserts, and `plan` promises
    // `Err`, never a panic, for an impossible request. In shard mode only
    // the *shards* must fit a tile's envelope — the whole kernel may
    // exceed it (that is the point of sharding).
    let (kernels_and_data, whole): (Vec<(Kernel, WorkloadData)>, Option<WorkloadData>) =
        if spec.shard {
            let shards = shard_kernel(spec.kernel, spec.sew, tiles as u32)?;
            for k in &shards {
                k.validate(spec.target, spec.sew)
                    .map_err(|e| SchedError::InvalidShape { kernel: *k, reason: e })?;
            }
            let whole = golden::generate(spec.kernel, spec.sew, spec.seed);
            let datas = shard_data(spec.kernel, spec.sew, &whole, &shards);
            (shards.into_iter().zip(datas).collect(), Some(whole))
        } else {
            if spec.batch == 0 {
                return Err(SchedError::EmptyBatch);
            }
            spec.kernel
                .validate(spec.target, spec.sew)
                .map_err(|e| SchedError::InvalidShape { kernel: spec.kernel, reason: e })?;
            let v = (0..spec.batch)
                .map(|w| {
                    (spec.kernel, golden::generate(spec.kernel, spec.sew, spec.seed + w as u64))
                })
                .collect();
            (v, None)
        };

    compile_plan(*spec, tiles, kind, kernels_and_data, whole)
}

/// Plan a *coalesced group* of same-family workloads with explicit
/// per-workload seeds — the entry point of the serve front-end
/// ([`crate::serve`]), whose coalescer batches queued requests that are
/// mutually schedulable. Unlike batch mode ([`plan`], seeds
/// `seed..seed+batch`), every job carries its own seed, and NM-Carus
/// groups may mix *shapes* within one family (the shape parameters
/// travel in the per-workload argument words, exactly as in shard
/// mode). Stream-executed tiles (NM-Caesar) replay one rendered
/// micro-op stream per tile, so their groups must keep one kernel per
/// tile slot — violations surface as [`SchedError::StreamMismatch`].
pub fn plan_jobs(
    target: Target,
    sew: Sew,
    jobs: &[(Kernel, u64)],
    tiles: usize,
) -> Result<Plan, SchedError> {
    let kind = tile_kind(target, tiles)?;
    if jobs.is_empty() {
        return Err(SchedError::EmptyBatch);
    }
    let first = jobs[0].0;
    for &(k, _) in jobs {
        if k.family() != first.family() {
            return Err(SchedError::MixedBatch { first, other: k });
        }
        k.validate(target, sew)
            .map_err(|e| SchedError::InvalidShape { kernel: k, reason: e })?;
    }
    let kernels_and_data: Vec<(Kernel, WorkloadData)> =
        jobs.iter().map(|&(k, s)| (k, golden::generate(k, sew, s))).collect();
    // The representative spec carried through results and error messages.
    let spec = BatchSpec {
        target,
        kernel: first,
        sew,
        seed: jobs[0].1,
        batch: jobs.len() as u32,
        shard: false,
    };
    compile_plan(spec, tiles, kind, kernels_and_data, None)
}

/// Shared back half of [`plan`]/[`plan_jobs`]: SRAM staging allocation,
/// tile-program assembly, per-workload IO derivation, and host-firmware
/// compilation. Every failure is a typed [`SchedError`] — the staging
/// paths were once `expect`/`assert!` sites, which a malformed service
/// request must never be able to reach.
fn compile_plan(
    spec: BatchSpec,
    tiles: usize,
    kind: TileKind,
    kernels_and_data: Vec<(Kernel, WorkloadData)>,
    whole: Option<WorkloadData>,
) -> Result<Plan, SchedError> {
    let eng = engine(spec.target);

    // ---- SRAM staging allocation ------------------------------------------
    let mut cursor = POOL_BASE;
    let mut take = |len: u32| -> Result<u32, SchedError> {
        let at = cursor;
        let len = len.div_ceil(4) * 4;
        cursor += len;
        if cursor > POOL_END {
            return Err(SchedError::StagingOverflow);
        }
        Ok(at)
    };

    // One assembled TileProgram per *distinct* kernel (batch mode has
    // exactly one; shard mode at most `tiles`) — setup image, streams,
    // and per-workload args below all read from this cache instead of
    // re-assembling the same eCPU binary per workload. The first probe
    // doubles as the tileability check, on a shape validate() accepted.
    let mut programs: Vec<(Kernel, TileProgram)> = Vec::new();
    let Some(first) = program_idx(&mut programs, eng, kernels_and_data[0].0, spec.sew) else {
        return Err(SchedError::NotTileable { target: spec.target, kernel: spec.kernel });
    };

    // Tile setup image (identical across workloads of one family — the
    // shape parameters travel in the argument words).
    let setup_image = programs[first].1.setup_image.clone();
    let setup_addr =
        if setup_image.is_empty() { 0 } else { take(setup_image.len() as u32)? };
    let setup = (setup_addr, setup_image);

    // Per-tile micro-op streams (NM-Caesar): tile t streams the program
    // of its first assigned workload, rendered against its bus window.
    // Later rounds reuse it, so every workload the round-robin places on
    // tile t must carry tile t's kernel — batch and shard mode satisfy
    // this by construction, a coalesced group ([`plan_jobs`]) may not.
    let mut streams: Vec<(u32, Vec<u8>)> = Vec::new();
    if matches!(programs[first].1.exec, TileExec::Stream(_)) {
        for (w, (k, _)) in kernels_and_data.iter().enumerate() {
            let expected = kernels_and_data[w % tiles].0;
            if *k != expected {
                return Err(SchedError::StreamMismatch { expected, got: *k });
            }
        }
        for t in 0..tiles.min(kernels_and_data.len()) {
            let i = (tile_fault() != Some(TileFault::StreamProgram))
                .then(|| program_idx(&mut programs, eng, kernels_and_data[t].0, spec.sew))
                .flatten()
                .ok_or(SchedError::NotTileable {
                    target: spec.target,
                    kernel: kernels_and_data[t].0,
                })?;
            let TileExec::Stream(p) = &programs[i].1.exec else {
                unreachable!("stream engines stay stream engines")
            };
            let bytes = p.to_stream(bus::tile_base(t));
            let addr = take(bytes.len() as u32)?;
            streams.push((addr, bytes));
        }
    }

    // Per-workload input/output staging. The lookups below were panic
    // sites (`expect`/`assert!`): a kernel that probes tileable for the
    // first workload but fails IO derivation for a later one — or
    // presents misaligned staging — now degrades to a typed `Err`.
    let mut workloads = Vec::with_capacity(kernels_and_data.len());
    for (kernel, data) in kernels_and_data {
        let io = (tile_fault() != Some(TileFault::Io))
            .then(|| eng.tile_io(kernel, spec.sew, &data))
            .flatten()
            .ok_or(SchedError::NotTileable { target: spec.target, kernel })?;
        let args = (tile_fault() != Some(TileFault::ArgsProgram))
            .then(|| program_idx(&mut programs, eng, kernel, spec.sew))
            .flatten()
            .map(|i| programs[i].1.args.clone())
            .ok_or(SchedError::NotTileable { target: spec.target, kernel })?;
        let mut inputs = Vec::with_capacity(io.inputs.len());
        for (off, bytes) in io.inputs {
            if tile_fault() == Some(TileFault::Misalign) || off % 4 != 0 || bytes.len() % 4 != 0
            {
                return Err(SchedError::Misaligned { kernel, what: "input staging region" });
            }
            let addr = take(bytes.len() as u32)?;
            inputs.push((addr, off, bytes));
        }
        let (out_off, out_len) = io.output;
        if tile_fault() == Some(TileFault::MisalignOut) || out_off % 4 != 0 || out_len % 4 != 0 {
            return Err(SchedError::Misaligned { kernel, what: "output span" });
        }
        let out_addr = take(out_len)?;
        workloads.push(PlannedWork {
            kernel,
            expect: data.expect.clone(),
            inputs,
            output: (out_addr, out_off, out_len),
            args,
        });
    }

    // ---- Host firmware -----------------------------------------------------
    let firmware = build_firmware(kind, tiles, &workloads, &setup, &streams)?;
    if firmware.size() > BANK_SIZE {
        return Err(SchedError::FirmwareTooLarge { bytes: firmware.size() });
    }

    Ok(Plan { spec, tiles, kind, workloads, setup, streams, firmware, whole })
}

/// Program the tile interrupt-enable mask. The scheduler narrows it per
/// wait: `0` while sleeping on the DMA (a *done-but-not-yet-drained*
/// tile's sticky IRQ must not turn the sleep into a spin), `1 << t`
/// while sleeping on tile `t`.
fn fw_irq_mask(a: &mut Asm, mask: u32) {
    a.li(T0, (PERIPH_BASE + periph::IRQ_MASK) as i32)
        .li(T1, mask as i32)
        .sw(T1, 0, T0);
}

/// Program one DMA transfer and sleep (`wfi`) until its completion
/// interrupt; the status read acknowledges it. Tiles keep executing
/// underneath the sleep. Caller keeps [`periph::IRQ_MASK`] at 0 so only
/// the DMA (always enabled) can wake the loop.
fn fw_dma(a: &mut Asm, lbl: &str, src: u32, dst: u32, len: u32, stream: bool) {
    debug_assert!(src % 4 == 0 && dst % 4 == 0 && len % 4 == 0);
    a.li(T0, (PERIPH_BASE + periph::DMA_SRC) as i32)
        .li(T1, src as i32)
        .sw(T1, 0, T0)
        .li(T0, (PERIPH_BASE + periph::DMA_DST) as i32)
        .li(T1, dst as i32)
        .sw(T1, 0, T0)
        .li(T0, (PERIPH_BASE + periph::DMA_LEN) as i32)
        .li(T1, len as i32)
        .sw(T1, 0, T0)
        .li(T0, (PERIPH_BASE + periph::DMA_CTL) as i32)
        .li(T1, if stream { 0b11 } else { 0b01 })
        .sw(T1, 0, T0)
        .li(T0, (PERIPH_BASE + periph::DMA_STATUS) as i32)
        .label(lbl)
        .wfi()
        .lw(T1, 0, T0)
        .bne(T1, ZERO, lbl);
}

/// Drive tile `t`'s mode pin through its peripheral register.
fn fw_tile_mode(a: &mut Asm, t: usize, on: bool) {
    a.li(T0, (PERIPH_BASE + periph::tile_mode(t)) as i32)
        .li(T1, on as i32)
        .sw(T1, 0, T0);
}

/// Spin on tile `t`'s status register until idle. Only used for
/// NM-Caesar tiles, which raise no interrupt: their residual pipeline
/// drain after the stream DMA is ≤ a few cycles, so the spin is bounded.
fn fw_poll_tile(a: &mut Asm, lbl: &str, t: usize) {
    a.li(T0, (PERIPH_BASE + periph::tile_status(t)) as i32)
        .label(lbl)
        .lw(T1, 0, T0)
        .bne(T1, ZERO, lbl);
}

/// Sleep until NM-Carus tile `t` completes. The done IRQ is sticky
/// (level-triggered, cleared when the tile is next started), so the
/// `wfi` falls straight through if the tile finished while the host was
/// busy elsewhere — no lost wake-up. The mask is restored to 0 after
/// the wait so the still-pending IRQ cannot spin later DMA sleeps.
fn fw_wait_tile(a: &mut Asm, lbl: &str, t: usize) {
    fw_irq_mask(a, 1 << t);
    a.li(T0, (PERIPH_BASE + periph::tile_status(t)) as i32)
        .label(lbl)
        .wfi()
        .lw(T1, 0, T0)
        .bne(T1, ZERO, lbl);
    fw_irq_mask(a, 0);
}

/// Compile the static round-robin schedule into host firmware.
fn build_firmware(
    kind: TileKind,
    tiles: usize,
    workloads: &[PlannedWork],
    setup: &(u32, Vec<u8>),
    streams: &[(u32, Vec<u8>)],
) -> Result<Program, SchedError> {
    let mut a = Asm::new(0);
    let mut nl = 0u32; // unique poll-label counter

    // Waits are interrupt-driven (`wfi`): only the DMA may wake the host
    // until a specific tile is being waited on.
    fw_irq_mask(&mut a, 0);
    let fw_wait = |a: &mut Asm, lbl: &str, t: usize| match kind {
        TileKind::Carus => fw_wait_tile(a, lbl, t),
        TileKind::Caesar => fw_poll_tile(a, lbl, t),
    };

    // One-time tile setup: upload the eCPU kernel image (config mode).
    if !setup.1.is_empty() {
        for t in 0..tiles.min(workloads.len()) {
            fw_tile_mode(&mut a, t, true);
            nl += 1;
            let len = setup.1.len() as u32;
            fw_dma(&mut a, &format!("s{nl}"), setup.0, bus::tile_base(t), len, false);
            fw_tile_mode(&mut a, t, false);
        }
    }

    for (w, work) in workloads.iter().enumerate() {
        let t = w % tiles;
        let tb = bus::tile_base(t);
        if w >= tiles {
            // The tile still runs round r-1: wait, then drain its result.
            nl += 1;
            fw_wait(&mut a, &format!("p{nl}"), t);
            let prev = &workloads[w - tiles];
            let (out_sram, out_off, out_len) = prev.output;
            nl += 1;
            fw_dma(&mut a, &format!("o{nl}"), tb + out_off, out_sram, out_len, false);
        }
        // Stage this workload's operands into the (idle) tile — the other
        // tiles keep computing while the DMA runs.
        for (in_sram, in_off, bytes) in &work.inputs {
            nl += 1;
            fw_dma(&mut a, &format!("i{nl}"), *in_sram, tb + in_off, bytes.len() as u32, false);
        }
        match kind {
            TileKind::Carus => {
                // Parameterize and start; the tile executes autonomously.
                fw_tile_mode(&mut a, t, true);
                for (i, &arg) in work.args.iter().enumerate() {
                    a.li(T0, (tb + ARG_OFFSET + 4 * i as u32) as i32)
                        .li(T1, arg as i32)
                        .sw(T1, 0, T0);
                }
                a.li(T0, (tb + CTL_OFFSET) as i32)
                    .li(T1, CTL_START as i32)
                    .sw(T1, 0, T0);
                fw_tile_mode(&mut a, t, false);
            }
            TileKind::Caesar => {
                // Execution is the stream itself: raise imc, stream, drop.
                let (saddr, sbytes) = &streams[t];
                fw_tile_mode(&mut a, t, true);
                nl += 1;
                fw_dma(&mut a, &format!("x{nl}"), *saddr, tb, sbytes.len() as u32, true);
                fw_tile_mode(&mut a, t, false);
            }
        }
    }

    // Drain the last round.
    let last_start = workloads.len().saturating_sub(tiles.min(workloads.len()));
    for (w, work) in workloads.iter().enumerate().skip(last_start) {
        let t = w % tiles;
        nl += 1;
        fw_wait(&mut a, &format!("f{nl}"), t);
        let (out_sram, out_off, out_len) = work.output;
        nl += 1;
        fw_dma(&mut a, &format!("e{nl}"), bus::tile_base(t) + out_off, out_sram, out_len, false);
    }
    a.ebreak();
    a.assemble().map_err(|e| SchedError::Assemble(format!("{e:?}")))
}

/// Simulate a compiled [`Plan`]. Panics on any modeling bug (timeout,
/// trap, output mismatch against the golden reference) — planning errors
/// were already surfaced as `Err` by [`plan`].
pub fn run_planned(plan: &Plan) -> BatchRunResult {
    let mut soc = Soc::scale_out(plan.kind, plan.tiles, 4);
    run_planned_on(&mut soc, plan)
}

/// Simulate a compiled [`Plan`] on a caller-owned [`Soc`] replica — the
/// serve worker pool's entry point. The SoC is [`Soc::recycle`]d first,
/// so the result is bit-identical to [`run_planned`]'s fresh-construction
/// path no matter what ran on the replica before; the borrow is
/// `Send`-clean (plain data on both sides), so independent workers can
/// execute independent plans on independent replicas in parallel.
/// Panics if `soc`'s tile configuration does not match the plan.
pub fn run_planned_on(soc: &mut Soc, plan: &Plan) -> BatchRunResult {
    soc.recycle();
    assert!(
        soc.tiles.len() == plan.tiles && soc.tiles.iter().all(|t| t.kind() == plan.kind),
        "worker SoC ({} tiles) does not match the plan ({} {:?} tiles)",
        soc.tiles.len(),
        plan.tiles,
        plan.kind
    );
    let eng = engine(plan.spec.target);

    // Host-side pre-staging of every image in system SRAM (uncounted, like
    // the single-tile engines' `stage_data`): what *is* measured is the
    // movement from SRAM into the tiles.
    if !plan.setup.1.is_empty() {
        soc.load_region(plan.setup.0, &plan.setup.1);
    }
    for (addr, bytes) in &plan.streams {
        soc.load_region(*addr, bytes);
    }
    for work in &plan.workloads {
        for (addr, _off, bytes) in &work.inputs {
            soc.load_region(*addr, bytes);
        }
    }

    soc.load_firmware(&plan.firmware, 0);
    soc.reset_stats();
    let budget = run_timeout();
    let (halt, cycles) = soc.run(budget);
    assert_eq!(
        halt,
        Halt::Done,
        "{:?} schedule ({} workloads on {} tiles) did not complete: {halt:?} after {cycles} \
         cycles (budget {budget}; raise SOC_RUN_TIMEOUT to extend)",
        plan.spec,
        plan.workloads.len(),
        plan.tiles
    );

    // Extract + verify every workload.
    let mut outputs = Vec::with_capacity(plan.workloads.len());
    for (w, work) in plan.workloads.iter().enumerate() {
        let (out_sram, _off, out_len) = work.output;
        let raw = soc.dump_region(out_sram, out_len);
        let out = eng.tile_extract(work.kernel, plan.spec.sew, &raw);
        assert_eq!(
            out, work.expect,
            "workload {w} ({:?}) output mismatch vs golden reference",
            work.kernel
        );
        outputs.push(out);
    }
    // Shard mode: the reassembled result must equal the *whole* kernel's
    // golden output byte for byte.
    if let Some(whole) = &plan.whole {
        let parts: Vec<(Kernel, &[u8])> = plan
            .workloads
            .iter()
            .zip(&outputs)
            .map(|(work, out)| (work.kernel, out.as_slice()))
            .collect();
        let merged = reassemble(plan.spec.kernel, plan.spec.sew, &parts);
        assert_eq!(
            merged, whole.expect,
            "sharded {:?} disagrees with the whole-kernel reference",
            plan.spec.kernel
        );
        outputs = vec![merged];
    }

    let per_tile: Vec<TileStats> = (0..plan.tiles)
        .map(|t| TileStats {
            kind: plan.kind,
            busy_cycles: soc.tile_busy[t],
            workloads: ((plan.workloads.len() + plan.tiles - 1 - t) / plan.tiles) as u32,
        })
        .collect();
    BatchRunResult {
        spec: plan.spec,
        tiles: plan.tiles as u32,
        cycles: soc.cycle,
        energy: soc.energy(),
        per_tile,
        dma_active_cycles: soc.dma.stats.active_cycles,
        dma_transfers: soc.dma.stats.transfers,
        bus_txns: soc.counters.bus_txns,
        contention_cycles: soc.counters.cpu_wait_cycles + soc.counters.slave_stall_cycles,
        outputs,
    }
}

/// Plan + simulate in one call (the CLI/session entry point).
pub fn run_batch(spec: &BatchSpec, tiles: usize) -> Result<BatchRunResult, SchedError> {
    Ok(run_planned(&plan(spec, tiles)?))
}

/// Split a kernel's free dimension into `t` word-aligned shards.
fn shard_kernel(kernel: Kernel, sew: Sew, t: u32) -> Result<Vec<Kernel>, SchedError> {
    let unit = 4 / sew.bytes(); // elements per 32-bit word
    let split = |total: u32, what: &str| -> Result<Vec<u32>, SchedError> {
        if total % unit != 0 {
            return Err(SchedError::ShardSplit {
                kernel,
                reason: format!("{what} = {total} is not word-aligned at {sew}"),
            });
        }
        let units = total / unit;
        if units < t {
            return Err(SchedError::ShardSplit {
                kernel,
                reason: format!("{what} = {total} < {t} word-aligned pieces at {sew}"),
            });
        }
        let (per, rem) = (units / t, units % t);
        Ok((0..t).map(|i| (per + u32::from(i < rem)) * unit).collect())
    };
    match kernel {
        Kernel::Xor { n } => Ok(split(n, "n")?.into_iter().map(|n| Kernel::Xor { n }).collect()),
        Kernel::Add { n } => Ok(split(n, "n")?.into_iter().map(|n| Kernel::Add { n }).collect()),
        Kernel::Mul { n } => Ok(split(n, "n")?.into_iter().map(|n| Kernel::Mul { n }).collect()),
        Kernel::Relu { n } => Ok(split(n, "n")?.into_iter().map(|n| Kernel::Relu { n }).collect()),
        Kernel::LeakyRelu { n } => {
            Ok(split(n, "n")?.into_iter().map(|n| Kernel::LeakyRelu { n }).collect())
        }
        Kernel::Matmul { p } => {
            Ok(split(p, "p")?.into_iter().map(|p| Kernel::Matmul { p }).collect())
        }
        Kernel::Gemm { p } => {
            Ok(split(p, "p")?.into_iter().map(|p| Kernel::Gemm { p }).collect())
        }
        Kernel::Conv2d { .. } | Kernel::Maxpool { .. } => {
            Err(SchedError::Unshardable { kernel })
        }
    }
}

/// Slice the whole kernel's golden data into per-shard [`WorkloadData`].
/// Output slices come from the whole golden output, so per-shard
/// verification and whole-kernel reassembly agree by construction.
fn shard_data(
    whole_kernel: Kernel,
    sew: Sew,
    whole: &WorkloadData,
    shards: &[Kernel],
) -> Vec<WorkloadData> {
    let sb = sew.bytes() as usize;
    // 8-row matrices sliced by a column range.
    let slice_rows = |bytes: &[u8], row_elems: usize, c0: usize, c1: usize| -> Vec<u8> {
        let mut v = Vec::with_capacity(8 * (c1 - c0) * sb);
        for r in 0..8usize {
            v.extend_from_slice(&bytes[(r * row_elems + c0) * sb..(r * row_elems + c1) * sb]);
        }
        v
    };
    let mut out = Vec::with_capacity(shards.len());
    let mut e0 = 0usize; // element cursor along the shard axis
    for shard in shards {
        let wd = match (whole_kernel, shard) {
            (
                Kernel::Xor { .. } | Kernel::Add { .. } | Kernel::Mul { .. },
                Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n },
            ) => {
                let (a0, a1) = (e0 * sb, (e0 + *n as usize) * sb);
                e0 += *n as usize;
                WorkloadData {
                    a: whole.a[a0..a1].to_vec(),
                    b: whole.b[a0..a1].to_vec(),
                    c: Vec::new(),
                    expect: whole.expect[a0..a1].to_vec(),
                }
            }
            (
                Kernel::Relu { .. } | Kernel::LeakyRelu { .. },
                Kernel::Relu { n } | Kernel::LeakyRelu { n },
            ) => {
                let (a0, a1) = (e0 * sb, (e0 + *n as usize) * sb);
                e0 += *n as usize;
                WorkloadData {
                    a: whole.a[a0..a1].to_vec(),
                    b: Vec::new(),
                    c: Vec::new(),
                    expect: whole.expect[a0..a1].to_vec(),
                }
            }
            (
                Kernel::Matmul { p } | Kernel::Gemm { p },
                Kernel::Matmul { p: pj } | Kernel::Gemm { p: pj },
            ) => {
                let (c0, c1) = (e0, e0 + *pj as usize);
                e0 += *pj as usize;
                let gemm = matches!(whole_kernel, Kernel::Gemm { .. });
                WorkloadData {
                    a: whole.a.clone(), // A is shared by every column shard
                    b: slice_rows(&whole.b, p as usize, c0, c1),
                    c: if gemm { slice_rows(&whole.c, p as usize, c0, c1) } else { Vec::new() },
                    expect: slice_rows(&whole.expect, p as usize, c0, c1),
                }
            }
            _ => unreachable!("shard_kernel never changes the kernel family"),
        };
        out.push(wd);
    }
    out
}

/// Merge per-shard canonical outputs back into the whole kernel's
/// canonical output layout.
fn reassemble(whole: Kernel, sew: Sew, parts: &[(Kernel, &[u8])]) -> Vec<u8> {
    let sb = sew.bytes() as usize;
    match whole {
        Kernel::Xor { .. }
        | Kernel::Add { .. }
        | Kernel::Mul { .. }
        | Kernel::Relu { .. }
        | Kernel::LeakyRelu { .. } => {
            let mut out = Vec::new();
            for (_, bytes) in parts {
                out.extend_from_slice(bytes);
            }
            out
        }
        Kernel::Matmul { .. } | Kernel::Gemm { .. } => {
            // Row r of the whole output is the concatenation of row r of
            // every column shard.
            let mut out = Vec::new();
            for r in 0..8usize {
                for (k, bytes) in parts {
                    let pj = match k {
                        Kernel::Matmul { p } | Kernel::Gemm { p } => *p as usize,
                        _ => unreachable!("matmul shards are matmuls"),
                    };
                    out.extend_from_slice(&bytes[r * pj * sb..(r + 1) * pj * sb]);
                }
            }
            out
        }
        Kernel::Conv2d { .. } | Kernel::Maxpool { .. } => {
            unreachable!("plan() rejects unshardable kernels")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(target: Target, kernel: Kernel, sew: Sew, batch: u32, shard: bool) -> BatchSpec {
        BatchSpec { target, kernel, sew, seed: 7, batch, shard }
    }

    #[test]
    fn plan_rejects_untileable_and_invalid_specs() {
        // The CPU is the host, never a tile.
        let e = plan(&spec(Target::Cpu, Kernel::Add { n: 64 }, Sew::E32, 2, false), 2).unwrap_err();
        assert_eq!(e, SchedError::HostTarget);
        assert!(e.to_string().contains("host"), "{e}");
        // NM-Caesar maxpool plans since the quadrant decomposition landed
        // (it was the one kernel with no tiled execute path).
        let mp = spec(Target::Caesar, Kernel::Maxpool { n: 64 }, Sew::E8, 2, false);
        assert!(plan(&mp, 2).is_ok());
        // Zero-sized batches and tile counts are errors, not panics.
        assert_eq!(
            plan(&spec(Target::Carus, Kernel::Add { n: 64 }, Sew::E32, 0, false), 2).unwrap_err(),
            SchedError::EmptyBatch
        );
        assert_eq!(
            plan(&spec(Target::Carus, Kernel::Add { n: 64 }, Sew::E32, 2, false), 0).unwrap_err(),
            SchedError::TileCount { got: 0 }
        );
        assert_eq!(
            plan(&spec(Target::Carus, Kernel::Add { n: 64 }, Sew::E32, 2, false), 99).unwrap_err(),
            SchedError::TileCount { got: 99 }
        );
    }

    #[test]
    fn plan_rejects_over_capacity_batches() {
        // 256 relu workloads of 16 KiB in-place data each can never fit
        // the 160 KiB staging pool.
        let e = plan(&spec(Target::Carus, Kernel::Relu { n: 16384 }, Sew::E8, 256, false), 2)
            .unwrap_err();
        assert_eq!(e, SchedError::StagingOverflow);
        assert!(e.to_string().contains("staging"), "{e}");
    }

    #[test]
    fn recycled_soc_results_are_bit_identical_to_fresh_construction() {
        // The serve worker pool reuses one SoC replica across batches via
        // `run_planned_on`; the whole determinism story rests on a
        // recycled SoC being indistinguishable from a fresh one. Run two
        // different plans back-to-back on one replica and compare every
        // observable against the fresh-construction path — bitwise, f64
        // energies included.
        let plans = [
            plan(&spec(Target::Carus, Kernel::Add { n: 64 }, Sew::E32, 3, false), 2).unwrap(),
            plan(&spec(Target::Carus, Kernel::Mul { n: 32 }, Sew::E16, 2, false), 2).unwrap(),
            plan(&spec(Target::Caesar, Kernel::Add { n: 64 }, Sew::E8, 2, false), 2).unwrap(),
        ];
        let mut carus_replica = Soc::scale_out(TileKind::Carus, 2, 4);
        let mut caesar_replica = Soc::scale_out(TileKind::Caesar, 2, 4);
        for p in &plans {
            let replica = match p.kind() {
                TileKind::Carus => &mut carus_replica,
                TileKind::Caesar => &mut caesar_replica,
            };
            let reused = run_planned_on(replica, p);
            let fresh = run_planned(p);
            assert_eq!(reused.cycles, fresh.cycles, "{:?}", p.spec);
            assert_eq!(reused.outputs, fresh.outputs, "{:?}", p.spec);
            assert_eq!(
                reused.energy.total().to_bits(),
                fresh.energy.total().to_bits(),
                "{:?}: energy must match bitwise",
                p.spec
            );
            assert_eq!(reused.dma_transfers, fresh.dma_transfers, "{:?}", p.spec);
            assert_eq!(reused.bus_txns, fresh.bus_txns, "{:?}", p.spec);
            assert_eq!(reused.contention_cycles, fresh.contention_cycles, "{:?}", p.spec);
            let busy = |r: &BatchRunResult| -> Vec<u64> {
                r.per_tile.iter().map(|t| t.busy_cycles).collect()
            };
            assert_eq!(busy(&reused), busy(&fresh), "{:?}", p.spec);
        }
    }

    #[test]
    fn error_paths_are_typed_and_never_simulate() {
        // Every rejection comes back as the exact `SchedError` variant,
        // and none of them reaches a simulation: a planning failure is a
        // pure function of the spec. `SweepSession::simulations()` is the
        // observable — it counts every co-simulation the session runs.
        let session = crate::sweep::SweepSession::new();
        let cases: Vec<(BatchSpec, usize, SchedError)> = vec![
            (
                spec(Target::Carus, Kernel::Add { n: 64 }, Sew::E32, 1, false),
                0,
                SchedError::TileCount { got: 0 },
            ),
            (
                spec(Target::Carus, Kernel::Add { n: 64 }, Sew::E32, 1, false),
                17,
                SchedError::TileCount { got: 17 },
            ),
            (
                spec(Target::Carus, Kernel::Relu { n: 16384 }, Sew::E8, 256, false),
                2,
                SchedError::StagingOverflow,
            ),
            // --shard on the 2-D window families: no 1-D shard axis.
            (
                spec(Target::Carus, Kernel::Conv2d { n: 64, f: 3 }, Sew::E8, 1, true),
                2,
                SchedError::Unshardable { kernel: Kernel::Conv2d { n: 64, f: 3 } },
            ),
            (
                spec(Target::Caesar, Kernel::Maxpool { n: 64 }, Sew::E8, 1, true),
                2,
                SchedError::Unshardable { kernel: Kernel::Maxpool { n: 64 } },
            ),
        ];
        for (s, tiles, want) in cases {
            assert_eq!(plan(&s, tiles).err(), Some(want.clone()), "{s:?} x{tiles}");
            assert_eq!(
                session.scale(&s, tiles as u32).err(),
                Some(want.to_string()),
                "{s:?} x{tiles}"
            );
        }
        // A shard axis too fine for the tile count is a split error.
        assert!(matches!(
            plan(&spec(Target::Carus, Kernel::Add { n: 8 }, Sew::E8, 1, true), 4).unwrap_err(),
            SchedError::ShardSplit { kernel: Kernel::Add { n: 8 }, .. }
        ));
        // A per-shard shape that breaks the target envelope names the shard.
        assert!(matches!(
            plan(&spec(Target::Carus, Kernel::Matmul { p: 16 }, Sew::E32, 1, true), 4)
                .unwrap_err(),
            SchedError::InvalidShape { kernel: Kernel::Matmul { p: 4 }, .. }
        ));
        assert_eq!(session.simulations(), 0, "rejections must not simulate");
    }

    #[test]
    fn caesar_maxpool_tiles_and_matches_golden() {
        // The quadrant-decomposed tiled maxpool: `run_planned` asserts
        // every workload's canonical output against the golden reference,
        // so a successful run *is* the correctness check.
        for sew in Sew::ALL {
            let s = spec(Target::Caesar, Kernel::Maxpool { n: 16 }, sew, 3, false);
            let res = run_batch(&s, 2).unwrap();
            assert_eq!(res.outputs.len(), 3);
            assert_eq!(res.outputs[0].len(), 8 * 8 * sew.bytes() as usize);
        }
    }

    #[test]
    fn shard_splitting_is_word_aligned_and_exhaustive() {
        let shards = shard_kernel(Kernel::Matmul { p: 100 }, Sew::E16, 3).unwrap();
        let total: u32 = shards
            .iter()
            .map(|k| match k {
                Kernel::Matmul { p } => *p,
                _ => unreachable!(),
            })
            .sum();
        assert_eq!(total, 100);
        for k in &shards {
            let Kernel::Matmul { p } = k else { unreachable!() };
            assert_eq!(p * 2 % 4, 0, "16-bit rows stay word-aligned");
        }
        // Unshardable kernels and over-fine splits are errors.
        assert!(shard_kernel(Kernel::Conv2d { n: 64, f: 3 }, Sew::E8, 2).is_err());
        assert!(shard_kernel(Kernel::Maxpool { n: 64 }, Sew::E8, 2).is_err());
        assert!(shard_kernel(Kernel::Add { n: 8 }, Sew::E8, 3).is_err());
        // Per-shard validation catches target limits (NM-Carus needs
        // p ≥ 8 per shard for its 8-element A columns).
        let e = plan(&spec(Target::Carus, Kernel::Matmul { p: 16 }, Sew::E32, 1, true), 4)
            .unwrap_err()
            .to_string();
        assert!(e.contains("NM-Carus") || e.contains("shard"), "{e}");
    }

    #[test]
    fn carus_batch_runs_and_overlaps() {
        let s = spec(Target::Carus, Kernel::Add { n: 256 }, Sew::E32, 4, false);
        let res = run_batch(&s, 2).unwrap();
        assert_eq!(res.tiles, 2);
        assert_eq!(res.outputs.len(), 4);
        assert_eq!(res.per_tile.len(), 2);
        assert_eq!(res.per_tile[0].workloads + res.per_tile[1].workloads, 4);
        assert!(res.cycles > 0);
        assert!(res.per_tile.iter().all(|t| t.busy_cycles > 0), "both tiles computed");
        assert!(res.dma_transfers >= 8, "staging transfers counted");
    }

    #[test]
    fn caesar_batch_runs_serially_but_correctly() {
        let s = spec(Target::Caesar, Kernel::Add { n: 64 }, Sew::E32, 2, false);
        let res = run_batch(&s, 2).unwrap();
        assert_eq!(res.outputs.len(), 2);
        // Stream-executed tiles backpressure the DMA write port — the
        // contention figure the scale report surfaces.
        assert!(res.contention_cycles > 0, "stream backpressure counted");
    }

    #[test]
    fn sharded_matmul_equals_whole_reference() {
        let s = spec(Target::Carus, Kernel::Matmul { p: 96 }, Sew::E8, 1, true);
        let res = run_batch(&s, 3).unwrap();
        // `run_planned` already asserted the reassembled output equals
        // the whole-kernel golden reference; spot-check shape here.
        assert_eq!(res.outputs.len(), 1);
        assert_eq!(res.outputs[0].len(), 8 * 96);
    }

    #[test]
    fn utilization_is_bounds_safe_and_shares_the_zero_cycle_convention() {
        // Synthetic result: no co-simulation needed to probe the
        // accessor's bounds and zero-cycle behavior.
        let mk = |cycles: u64| BatchRunResult {
            spec: spec(Target::Carus, Kernel::Add { n: 64 }, Sew::E8, 1, false),
            tiles: 1,
            cycles,
            energy: Breakdown::default(),
            per_tile: vec![TileStats { kind: TileKind::Carus, busy_cycles: 50, workloads: 1 }],
            dma_active_cycles: 0,
            dma_transfers: 0,
            bus_txns: 0,
            contention_cycles: 0,
            outputs: vec![],
        };
        let r = mk(100);
        assert!((r.utilization(0) - 0.5).abs() < 1e-12);
        // Out-of-range tile indices answer 0.0 instead of panicking —
        // the serve report probes up to the *configured* tile count,
        // which may exceed the tiles a small batch actually touched.
        assert_eq!(r.utilization(1), 0.0);
        assert_eq!(r.utilization(usize::MAX), 0.0);
        assert!((r.mean_utilization() - 0.5).abs() < 1e-12);
        // Zero-makespan results divide by `.max(1)`, the exact
        // convention of `speedup_vs` — both stay finite and agree on
        // the substituted denominator.
        let z = mk(0);
        assert!(z.utilization(0).is_finite());
        assert_eq!(z.utilization(0), z.per_tile[0].busy_cycles as f64);
        assert_eq!(z.speedup_vs(&r), r.cycles as f64);
    }

    #[test]
    fn injected_tile_faults_surface_as_typed_errors_never_panics() {
        // The three former panic sites (`expect("tileable")`,
        // `expect("same-family shards stay tileable")`, and the two
        // word-alignment `assert!`s) are unreachable with the built-in
        // engines on validated shapes, so each is forced via the
        // thread-local fault hook — exactly how the serve e2e test
        // feeds them through the server.
        let carus = spec(Target::Carus, Kernel::Add { n: 64 }, Sew::E32, 2, false);
        let caesar = spec(Target::Caesar, Kernel::Add { n: 64 }, Sew::E32, 2, false);

        // Per-tile stream rendering (NM-Caesar only — autonomous tiles
        // have no stream loop).
        arm_tile_fault(Some(TileFault::StreamProgram));
        assert!(matches!(
            plan(&caesar, 2).unwrap_err(),
            SchedError::NotTileable { target: Target::Caesar, .. }
        ));

        // Per-workload IO derivation and args-program lookup.
        arm_tile_fault(Some(TileFault::Io));
        assert!(matches!(
            plan(&carus, 2).unwrap_err(),
            SchedError::NotTileable { target: Target::Carus, .. }
        ));
        arm_tile_fault(Some(TileFault::ArgsProgram));
        assert!(matches!(
            plan(&carus, 2).unwrap_err(),
            SchedError::NotTileable { target: Target::Carus, .. }
        ));

        // Word-alignment of input staging regions and the output span.
        arm_tile_fault(Some(TileFault::Misalign));
        let e = plan(&carus, 2).unwrap_err();
        assert!(matches!(e, SchedError::Misaligned { what: "input staging region", .. }));
        assert!(e.to_string().contains("word-aligned"), "{e}");
        arm_tile_fault(Some(TileFault::MisalignOut));
        assert!(matches!(
            plan(&carus, 2).unwrap_err(),
            SchedError::Misaligned { what: "output span", .. }
        ));

        // Disarming restores plannability on this thread.
        arm_tile_fault(None);
        assert!(plan(&carus, 2).is_ok());
        assert!(plan(&caesar, 2).is_ok());
    }

    #[test]
    fn plan_jobs_coalesces_heterogeneous_carus_shapes_with_explicit_seeds() {
        // A homogeneous coalesced group with consecutive seeds is
        // indistinguishable from batch mode: same outputs, same makespan.
        let jobs = [
            (Kernel::Add { n: 64 }, 7u64),
            (Kernel::Add { n: 64 }, 8),
            (Kernel::Add { n: 64 }, 9),
        ];
        let coalesced = run_planned(&plan_jobs(Target::Carus, Sew::E32, &jobs, 2).unwrap());
        let batch =
            run_batch(&spec(Target::Carus, Kernel::Add { n: 64 }, Sew::E32, 3, false), 2).unwrap();
        assert_eq!(coalesced.outputs, batch.outputs);
        assert_eq!(coalesced.cycles, batch.cycles);

        // NM-Carus groups may mix *shapes* within one family (the shape
        // travels in the per-workload argument words) — `run_planned`
        // asserts every output against its golden reference, so a
        // successful run is the correctness check.
        let mixed = [
            (Kernel::Add { n: 64 }, 7u64),
            (Kernel::Add { n: 32 }, 11),
            (Kernel::Add { n: 64 }, 5),
        ];
        let res = run_planned(&plan_jobs(Target::Carus, Sew::E32, &mixed, 2).unwrap());
        assert_eq!(res.outputs.len(), 3);
        assert_eq!(res.outputs[0].len(), 64 * 4);
        assert_eq!(res.outputs[1].len(), 32 * 4);
        assert_eq!(res.outputs[2].len(), 64 * 4);
    }

    #[test]
    fn plan_jobs_rejects_mixed_families_and_stream_kernel_mismatch() {
        // One kernel family per coalesced group: the setup image is shared.
        let e = plan_jobs(
            Target::Carus,
            Sew::E32,
            &[(Kernel::Add { n: 64 }, 1), (Kernel::Relu { n: 64 }, 2)],
            2,
        )
        .unwrap_err();
        assert!(matches!(e, SchedError::MixedBatch { .. }));
        assert!(e.to_string().contains("coalesce"), "{e}");

        // Stream-executed tiles (NM-Caesar) replay one rendered stream
        // per tile: workload 2 lands on tile 0 (round-robin), which
        // streams Add{n:64} — a different shape is a mismatch...
        let shapes = [
            (Kernel::Add { n: 64 }, 1u64),
            (Kernel::Add { n: 64 }, 2),
            (Kernel::Add { n: 32 }, 3),
        ];
        assert_eq!(
            plan_jobs(Target::Caesar, Sew::E32, &shapes, 2).unwrap_err(),
            SchedError::StreamMismatch {
                expected: Kernel::Add { n: 64 },
                got: Kernel::Add { n: 32 },
            }
        );
        // ...while the same group coalesces fine on autonomous NM-Carus,
        assert!(plan_jobs(Target::Carus, Sew::E32, &shapes, 2).is_ok());
        // and a shape alternation that *matches* the round-robin period
        // is fine on NM-Caesar too.
        let alternating = [
            (Kernel::Add { n: 64 }, 1u64),
            (Kernel::Add { n: 32 }, 2),
            (Kernel::Add { n: 64 }, 3),
            (Kernel::Add { n: 32 }, 4),
        ];
        let res = run_planned(&plan_jobs(Target::Caesar, Sew::E32, &alternating, 2).unwrap());
        assert_eq!(res.outputs.len(), 4);

        // Degenerate groups keep the existing typed errors.
        assert_eq!(plan_jobs(Target::Carus, Sew::E32, &[], 2).unwrap_err(), SchedError::EmptyBatch);
        assert_eq!(
            plan_jobs(Target::Cpu, Sew::E32, &[(Kernel::Add { n: 64 }, 1)], 2).unwrap_err(),
            SchedError::HostTarget
        );
    }
}
