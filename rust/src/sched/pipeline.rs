//! Multi-layer pipeline executor: runs a compiled [`Schedule`] on NM-Carus
//! tiles, keeping inter-layer tensors resident in tile SRAM.
//!
//! Where the batch scheduler ([`super::plan_jobs`]) round-trips every
//! workload's output through the host staging pool, this executor moves an
//! inter-layer activation with a single tile-to-tile DMA when the producer
//! left it contiguous ([`Boundary::Resident`]) — or no DMA at all when the
//! producer wrote it exactly where the consumer reads (same tile, offset
//! 0, e.g. ReLU feeding maxpool in the batch pipeline). Only multi-chunk
//! outputs (maxpool, conv2d rows) fall back to repacking through host RAM
//! ([`Boundary::Staged`]); [`Residency::ForceStaged`] forces *every*
//! boundary down that path, which is how the CLI quantifies the
//! resident-tensor DMA savings on otherwise identical runs.
//!
//! Execution is phased: each layer step is its own host firmware program
//! (upload the layer kernel if the tile holds a different one, move the
//! activation, stage weights, start, wait), run to its `ebreak` so the
//! host can attribute cycle/DMA deltas to that layer. Loading the next
//! step's firmware un-halts the core in place — no recycle, so the VRF
//! state the residency optimization relies on survives between steps.
//! Outputs are asserted byte-identical to the CPU-golden chain
//! ([`crate::graph::Graph::golden_item`]) before any result is returned.

use super::{fw_dma, fw_irq_mask, fw_tile_mode, fw_wait_tile, POOL_BASE, POOL_END};
use crate::asm::{Asm, Program};
use crate::bus::{self, BANK_SIZE};
use crate::carus::{ARG_OFFSET, CTL_OFFSET, CTL_START};
use crate::energy::Breakdown;
use crate::graph::{Boundary, Pipeline, Schedule};
use crate::isa::reg::*;
use crate::kernels::carus::output_chunks;
use crate::kernels::{engine, run_timeout, Kernel, Target, TileProgram};
use crate::soc::{Halt, Soc, TileKind};

/// Inter-layer tensor placement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    /// Resident where the schedule allows it, staged where it does not.
    Auto,
    /// Every boundary through the host pool — the per-layer staging
    /// baseline the DMA-savings report compares against.
    ForceStaged,
}

impl Residency {
    pub fn name(self) -> &'static str {
        match self {
            Residency::Auto => "resident",
            Residency::ForceStaged => "staged",
        }
    }
}

/// Typed executor error (modeling bugs still panic, as in
/// [`super::run_planned_on`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ModelError {
    /// The pool cannot hold the model's images, weights, and activations.
    StagingOverflow,
    /// A staged input region is not word-aligned.
    Misaligned { layer: usize, off: u32, len: u32 },
    /// A step's firmware exceeds the 32 KiB code bank.
    FirmwareTooLarge { layer: usize, bytes: u32 },
    /// A step's firmware failed to assemble.
    Assemble(String),
    /// A layer step blew its cycle budget.
    Timeout { layer: usize },
    /// A layer step trapped.
    Trap { layer: usize },
}

impl std::fmt::Display for ModelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelError::StagingOverflow => write!(
                f,
                "model staging exceeds the {} KiB SRAM pool",
                (POOL_END - POOL_BASE) / 1024
            ),
            ModelError::Misaligned { layer, off, len } => {
                write!(f, "layer {layer}: input region ({off}, {len}) is not word-aligned")
            }
            ModelError::FirmwareTooLarge { layer, bytes } => write!(
                f,
                "layer {layer}: step firmware ({bytes} B) exceeds the 32 KiB code bank"
            ),
            ModelError::Assemble(e) => write!(f, "step firmware failed to assemble: {e}"),
            ModelError::Timeout { layer } => write!(
                f,
                "layer {layer} did not complete within the cycle budget (raise SOC_RUN_TIMEOUT)"
            ),
            ModelError::Trap { layer } => write!(f, "layer {layer} trapped"),
        }
    }
}

impl std::error::Error for ModelError {}

/// Per-layer accounting, aggregated across items.
#[derive(Debug, Clone, Copy)]
pub struct LayerRun {
    pub kernel: Kernel,
    /// The boundary that actually ran (under
    /// [`Residency::ForceStaged`], resident boundaries report as staged).
    pub boundary: Boundary,
    pub cycles: u64,
    pub dma_active_cycles: u64,
    pub dma_transfers: u64,
}

/// Result of one model execution.
#[derive(Debug, Clone)]
pub struct ModelRunResult {
    pub pipeline: Pipeline,
    pub residency: Residency,
    pub tiles: u32,
    /// Items executed (one per tile in both pipeline modes).
    pub items: u32,
    /// Makespan across all layer steps.
    pub cycles: u64,
    pub energy: Breakdown,
    pub dma_active_cycles: u64,
    pub dma_transfers: u64,
    pub bus_txns: u64,
    pub contention_cycles: u64,
    /// Busy cycles per tile — the serve path folds these into its
    /// utilization accounting alongside kernel-batch results.
    pub tile_busy: Vec<u64>,
    pub layers: Vec<LayerRun>,
    /// Boundaries that ran resident / staged (graph-level, not per item).
    pub resident_boundaries: u32,
    pub staged_boundaries: u32,
    /// Per-item final activations (packed SEW bytes), already asserted
    /// byte-identical to the CPU-golden chain.
    pub outputs: Vec<Vec<u8>>,
}

/// One (item, layer) execution on a concrete tile.
#[derive(Debug, Clone, Copy)]
struct Unit {
    item: u32,
    layer: usize,
    tile: usize,
}

/// A staged pool region headed for a tile window: (pool addr, tile
/// offset, length).
type StagedInput = (u32, u32, u32);

/// Everything the step firmware needs at fixed pool addresses.
struct PoolLayout {
    /// Per distinct kernel: (kernel, image addr, image len, arg words).
    images: Vec<(Kernel, u32, u32, Vec<u32>)>,
    /// Per layer: weight operands shared by every item (empty for entry).
    shared: Vec<Vec<StagedInput>>,
    /// Per item: the entry layer's full input set.
    entry: Vec<Vec<StagedInput>>,
    /// Repack scratch for staged boundaries (0 bytes if none run).
    scratch: u32,
    /// Per item: (output addr, output len).
    out: Vec<(u32, u32)>,
    /// Host-side pre-staging writes (addr, bytes).
    prestage: Vec<(u32, Vec<u8>)>,
}

fn effective(b: Boundary, residency: Residency) -> Boundary {
    match (b, residency) {
        (Boundary::Entry, _) => Boundary::Entry,
        (_, Residency::ForceStaged) => Boundary::Staged,
        (b, Residency::Auto) => b,
    }
}

/// Tile holding `layer`'s output for `item` under the schedule's
/// pipeline mode.
fn tile_of(sch: &Schedule, item: u32, layer: usize) -> usize {
    match sch.layers[layer].tile {
        Some(t) => t as usize,
        None => item as usize,
    }
}

/// Word-rounding bump allocator over the staging pool, collecting the
/// host-side pre-staging writes as regions are claimed.
struct PoolAlloc {
    cursor: u32,
    prestage: Vec<(u32, Vec<u8>)>,
}

impl PoolAlloc {
    fn new() -> Self {
        PoolAlloc { cursor: POOL_BASE, prestage: Vec::new() }
    }

    fn take(&mut self, len: u32) -> Result<u32, ModelError> {
        let at = self.cursor;
        self.cursor += len.div_ceil(4) * 4;
        if self.cursor > POOL_END {
            return Err(ModelError::StagingOverflow);
        }
        Ok(at)
    }

    /// Claim a region, record its bytes for pre-staging, and describe the
    /// tile-window destination — rejecting regions no DMA can move.
    fn stage_input(
        &mut self,
        layer: usize,
        (off, bytes): (u32, Vec<u8>),
    ) -> Result<StagedInput, ModelError> {
        let len = bytes.len() as u32;
        if off % 4 != 0 || len % 4 != 0 || len == 0 {
            return Err(ModelError::Misaligned { layer, off, len });
        }
        let addr = self.take(len)?;
        self.prestage.push((addr, bytes));
        Ok((addr, off, len))
    }
}

fn build_pool(
    sch: &Schedule,
    residency: Residency,
    items: u32,
    data: &[Vec<crate::kernels::golden::WorkloadData>],
) -> Result<PoolLayout, ModelError> {
    let eng = engine(Target::Carus);
    let sew = sch.graph.sew;
    let mut alloc = PoolAlloc::new();

    // Kernel images + argument words, one per distinct kernel.
    let mut images: Vec<(Kernel, u32, u32, Vec<u32>)> = Vec::new();
    for l in &sch.layers {
        if images.iter().any(|(k, ..)| *k == l.kernel) {
            continue;
        }
        let TileProgram { setup_image, args, .. } =
            eng.tile_program(l.kernel, sew).expect("carus tiles every kernel");
        let len = setup_image.len() as u32;
        let addr = alloc.take(len)?;
        alloc.prestage.push((addr, setup_image));
        images.push((l.kernel, addr, len, args));
    }

    // Layer weights (b/c operands) are item-independent: stage one copy.
    // The entry layer's inputs include the per-item activation (and, for
    // matmul, its transformed column image), so those stage per item.
    let mut shared: Vec<Vec<StagedInput>> = Vec::new();
    for (l, plan) in sch.layers.iter().enumerate() {
        let mut regions = Vec::new();
        if l > 0 {
            let io = eng.tile_io(plan.kernel, sew, &data[0][l]).expect("carus tiles every kernel");
            for input in io.inputs.into_iter().skip(1) {
                regions.push(alloc.stage_input(l, input)?);
            }
        }
        shared.push(regions);
    }
    let mut entry: Vec<Vec<StagedInput>> = Vec::new();
    for item in 0..items {
        let io = eng
            .tile_io(sch.layers[0].kernel, sew, &data[item as usize][0])
            .expect("carus tiles every kernel");
        let mut regions = Vec::new();
        for input in io.inputs {
            regions.push(alloc.stage_input(0, input)?);
        }
        entry.push(regions);
    }

    // Repack scratch: the largest staged activation. Steps run strictly
    // sequentially, so one region serves every item and layer.
    let sb = sew.bytes();
    let scratch_len = sch
        .layers
        .iter()
        .filter(|l| effective(l.boundary, residency) == Boundary::Staged)
        .map(|l| l.elems_in * sb)
        .max()
        .unwrap_or(0);
    let scratch = if scratch_len > 0 { alloc.take(scratch_len)? } else { 0 };

    let out_len = sch.graph.output_elems() * sb;
    let mut out = Vec::with_capacity(items as usize);
    for _ in 0..items {
        out.push((alloc.take(out_len)?, out_len));
    }

    Ok(PoolLayout { images, shared, entry, scratch, out, prestage: alloc.prestage })
}

/// Emit one unit: move the activation in, stage weights, parameterize,
/// start. `loaded` tracks which kernel image each tile holds so repeat
/// layers skip the upload.
#[allow(clippy::too_many_arguments)]
fn emit_unit(
    a: &mut Asm,
    nl: &mut u32,
    sch: &Schedule,
    pool: &PoolLayout,
    residency: Residency,
    unit: Unit,
    loaded: &mut [Option<Kernel>],
) {
    let mut lbl = |p: &str| {
        *nl += 1;
        format!("{p}{nl}")
    };
    let sew = sch.graph.sew;
    let plan = &sch.layers[unit.layer];
    let t = unit.tile;
    let tb = bus::tile_base(t);

    // Kernel upload (config mode maps the eMEM, so resident VRF data
    // survives it).
    if loaded[t] != Some(plan.kernel) {
        let (_, addr, len, _) =
            pool.images.iter().find(|(k, ..)| *k == plan.kernel).expect("image staged");
        fw_tile_mode(a, t, true);
        fw_dma(a, &lbl("k"), *addr, tb, *len, false);
        fw_tile_mode(a, t, false);
        loaded[t] = Some(plan.kernel);
    }

    // Activation movement.
    match effective(plan.boundary, residency) {
        Boundary::Entry => {
            for &(addr, off, len) in &pool.entry[unit.item as usize] {
                fw_dma(a, &lbl("i"), addr, tb + off, len, false);
            }
        }
        Boundary::Resident => {
            let src_t = tile_of(sch, unit.item, unit.layer - 1);
            let chunks = output_chunks(sch.layers[unit.layer - 1].kernel, sew);
            let (off, len) = chunks[0];
            let (src, dst) = (bus::tile_base(src_t) + off, tb);
            // Producer output already sits where the consumer reads it:
            // the zero-DMA case residency exists for.
            if src != dst {
                fw_dma(a, &lbl("r"), src, dst, len, false);
            }
        }
        Boundary::Staged => {
            let src_t = tile_of(sch, unit.item, unit.layer - 1);
            let src_tb = bus::tile_base(src_t);
            let mut pack = 0u32;
            for (off, len) in output_chunks(sch.layers[unit.layer - 1].kernel, sew) {
                fw_dma(a, &lbl("c"), src_tb + off, pool.scratch + pack, len, false);
                pack += len;
            }
            fw_dma(a, &lbl("u"), pool.scratch, tb, pack, false);
        }
    }
    // Layer weights.
    for &(addr, off, len) in &pool.shared[unit.layer] {
        fw_dma(a, &lbl("w"), addr, tb + off, len, false);
    }

    // Parameterize and start (autonomous execution).
    let (.., args) = pool.images.iter().find(|(k, ..)| *k == plan.kernel).expect("image staged");
    fw_tile_mode(a, t, true);
    for (i, &arg) in args.iter().enumerate() {
        a.li(T0, (tb + ARG_OFFSET + 4 * i as u32) as i32).li(T1, arg as i32).sw(T1, 0, T0);
    }
    a.li(T0, (tb + CTL_OFFSET) as i32).li(T1, CTL_START as i32).sw(T1, 0, T0);
    fw_tile_mode(a, t, false);
}

/// Build one step's firmware: all its units started, waited on, and — for
/// final-layer units — drained chunk-by-chunk into the item's packed
/// output region (chunk order is extraction order, so the packed bytes
/// are exactly the canonical output).
fn build_step(
    sch: &Schedule,
    pool: &PoolLayout,
    residency: Residency,
    units: &[Unit],
    loaded: &mut [Option<Kernel>],
) -> Result<Program, ModelError> {
    let mut a = Asm::new(0);
    let mut nl = 0u32;
    fw_irq_mask(&mut a, 0);
    for &unit in units {
        emit_unit(&mut a, &mut nl, sch, pool, residency, unit, loaded);
    }
    for &unit in units {
        nl += 1;
        fw_wait_tile(&mut a, &format!("p{nl}"), unit.tile);
    }
    let last = sch.layers.len() - 1;
    let sew = sch.graph.sew;
    for &unit in units.iter().filter(|u| u.layer == last) {
        let tb = bus::tile_base(unit.tile);
        let (out_addr, _) = pool.out[unit.item as usize];
        let mut pack = 0u32;
        for (off, len) in output_chunks(sch.layers[last].kernel, sew) {
            nl += 1;
            fw_dma(&mut a, &format!("d{nl}"), tb + off, out_addr + pack, len, false);
            pack += len;
        }
    }
    a.ebreak();
    let layer = units.first().map_or(0, |u| u.layer);
    let prog = a.assemble().map_err(|e| ModelError::Assemble(format!("{e:?}")))?;
    if prog.size() > BANK_SIZE {
        return Err(ModelError::FirmwareTooLarge { layer, bytes: prog.size() });
    }
    Ok(prog)
}

/// Execute a compiled model schedule on a fresh scale-out SoC.
pub fn run_model(sch: &Schedule, residency: Residency) -> Result<ModelRunResult, ModelError> {
    let mut soc = Soc::scale_out(TileKind::Carus, sch.tiles as usize, 4);
    run_model_on(&mut soc, sch, residency)
}

/// Execute a compiled model schedule on a caller-owned SoC replica (the
/// serve worker entry point). The SoC is recycled first; panics if its
/// tile configuration does not match the schedule.
pub fn run_model_on(
    soc: &mut Soc,
    sch: &Schedule,
    residency: Residency,
) -> Result<ModelRunResult, ModelError> {
    soc.recycle();
    assert!(
        soc.tiles.len() == sch.tiles as usize
            && soc.tiles.iter().all(|t| t.kind() == TileKind::Carus),
        "worker SoC ({} tiles) does not match the schedule ({} carus tiles)",
        soc.tiles.len(),
        sch.tiles
    );
    let items = sch.tiles; // one item per tile in both pipeline modes
    let data: Vec<_> = (0..items).map(|i| sch.graph.golden_item(i)).collect();
    let pool = build_pool(sch, residency, items, &data)?;
    for (addr, bytes) in &pool.prestage {
        soc.load_region(*addr, bytes);
    }

    // Step sequence: batch mode barriers every item per layer; layer mode
    // walks each item through the tile chain before admitting the next
    // (one item in flight — handoffs are tile-to-tile, not overlapped).
    let nlayers = sch.layers.len();
    let steps: Vec<Vec<Unit>> = match sch.pipeline {
        Pipeline::Batch => (0..nlayers)
            .map(|l| {
                (0..items).map(|i| Unit { item: i, layer: l, tile: tile_of(sch, i, l) }).collect()
            })
            .collect(),
        Pipeline::Layer => (0..items)
            .flat_map(|i| {
                (0..nlayers)
                    .map(move |l| vec![Unit { item: i, layer: l, tile: tile_of(sch, i, l) }])
            })
            .collect(),
    };

    let mut layers: Vec<LayerRun> = sch
        .layers
        .iter()
        .map(|l| LayerRun {
            kernel: l.kernel,
            boundary: effective(l.boundary, residency),
            cycles: 0,
            dma_active_cycles: 0,
            dma_transfers: 0,
        })
        .collect();
    let mut loaded: Vec<Option<Kernel>> = vec![None; sch.tiles as usize];

    soc.reset_stats();
    for units in &steps {
        let layer = units[0].layer;
        let prog = build_step(sch, &pool, residency, units, &mut loaded)?;
        let before =
            (soc.cycle, soc.dma.stats.active_cycles, soc.dma.stats.transfers);
        soc.load_firmware(&prog, 0);
        let (halt, _) = soc.run(run_timeout());
        match halt {
            Halt::Done => {}
            Halt::Timeout => return Err(ModelError::Timeout { layer }),
            Halt::Trap => return Err(ModelError::Trap { layer }),
        }
        layers[layer].cycles += soc.cycle - before.0;
        layers[layer].dma_active_cycles += soc.dma.stats.active_cycles - before.1;
        layers[layer].dma_transfers += soc.dma.stats.transfers - before.2;
    }

    // Drained outputs are packed valid bytes; assert them against the
    // CPU-golden chain before reporting anything.
    let mut outputs = Vec::with_capacity(items as usize);
    for item in 0..items {
        let (addr, len) = pool.out[item as usize];
        let got = soc.dump_region(addr, len);
        let expect = &data[item as usize].last().unwrap().expect;
        assert_eq!(
            &got, expect,
            "item {item} output mismatch vs the CPU-golden chain ({} pipeline, {} boundaries)",
            sch.pipeline.name(),
            residency.name()
        );
        outputs.push(got);
    }

    let (resident_boundaries, staged_boundaries) =
        layers.iter().skip(1).fold((0, 0), |(r, s), l| match l.boundary {
            Boundary::Resident => (r + 1, s),
            Boundary::Staged => (r, s + 1),
            Boundary::Entry => (r, s),
        });
    Ok(ModelRunResult {
        pipeline: sch.pipeline,
        residency,
        tiles: sch.tiles,
        items,
        cycles: soc.cycle,
        energy: soc.energy(),
        dma_active_cycles: soc.dma.stats.active_cycles,
        dma_transfers: soc.dma.stats.transfers,
        bus_txns: soc.counters.bus_txns,
        contention_cycles: soc.counters.cpu_wait_cycles + soc.counters.slave_stall_cycles,
        tile_busy: soc.tile_busy.clone(),
        layers,
        resident_boundaries,
        staged_boundaries,
        outputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{compile, Graph, CANONICAL};
    use crate::isa::Sew;

    #[test]
    fn canonical_chain_runs_resident_and_saves_dma() {
        let g = Graph::parse(CANONICAL, Sew::E8, 7).unwrap();
        for pipeline in Pipeline::ALL {
            let sch = compile(&g, 2, pipeline).unwrap();
            let resident = run_model(&sch, Residency::Auto).unwrap();
            let staged = run_model(&sch, Residency::ForceStaged).unwrap();
            assert_eq!(resident.outputs, staged.outputs, "{pipeline:?}");
            assert_eq!(resident.resident_boundaries, 3);
            assert_eq!(staged.resident_boundaries, 0);
            assert!(
                resident.dma_active_cycles < staged.dma_active_cycles,
                "{pipeline:?}: resident {} !< staged {}",
                resident.dma_active_cycles,
                staged.dma_active_cycles
            );
        }
    }

    #[test]
    fn staged_fallback_still_matches_golden() {
        // A mid-chain maxpool output is multi-chunk: its consumer must
        // take the host-staging fallback even under Residency::Auto.
        let g = Graph::parse("matmul:p=32,maxpool,relu", Sew::E8, 11).unwrap();
        let sch = compile(&g, 2, Pipeline::Layer).unwrap();
        let res = run_model(&sch, Residency::Auto).unwrap();
        assert_eq!(res.staged_boundaries, 1);
        assert_eq!(res.resident_boundaries, 1);
        assert_eq!(res.outputs[0], g.golden_item(0).last().unwrap().expect);
    }
}
