//! DMA engine model (X-HEEP-style) with the NM-Caesar streaming mode.
//!
//! The DMA has independent read and write manager ports into the crossbar
//! (one read + one write per cycle, to different slaves), with a small
//! internal FIFO — this is what lets it sustain the paper's NM-Caesar
//! micro-op issue rate of **one instruction every two cycles**: while the
//! write of pair *i* retires into the Caesar slave, the reads of pair
//! *i + 1* stream from the instruction-sequence bank.
//!
//! Two transfer modes:
//! - [`DmaMode::Copy`]: plain incrementing word copy (kernel upload to the
//!   NM-Carus eMEM, data staging, double-buffering).
//! - [`DmaMode::CaesarStream`]: the in-memory stream is a sequence of
//!   `(dest_addr, instr_word)` pairs produced by the NM-Caesar DSL
//!   compiler; the DMA writes `instr_word` to `dest_addr` (a Caesar bus
//!   address, whose *address* encodes the micro-op's destination operand —
//!   §III-A1). This is the "fetch the kernel micro-instructions and
//!   destination addresses from the system memory" traffic that Fig. 13
//!   attributes half of NM-Caesar's memory power to.

use std::collections::VecDeque;

/// Transfer mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DmaMode {
    Copy,
    CaesarStream,
}

/// DMA activity counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct DmaStats {
    pub words_read: u64,
    pub words_written: u64,
    pub active_cycles: u64,
    /// Transfers programmed since the last stats reset (the batch
    /// scheduler reports staging-transfer counts per run).
    pub transfers: u64,
}

/// Write-port action the DMA wants to perform this cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DmaWrite {
    pub addr: u32,
    pub data: u32,
}

const FIFO_DEPTH: usize = 8;

/// The DMA engine. Stepped by the SoC: each cycle the SoC asks for the
/// desired read ([`Dma::want_read`]) and write ([`Dma::want_write`]) and
/// reports completions back.
#[derive(Debug, Clone)]
pub struct Dma {
    mode: DmaMode,
    /// Next stream read address.
    src: u32,
    /// Next destination address (Copy mode only).
    dst: u32,
    /// Bytes left to read from the stream.
    read_remaining: u32,
    /// Writes left to retire (transfer complete when it reaches 0).
    writes_remaining: u32,
    /// Staged (addr, data) writes.
    fifo: VecDeque<DmaWrite>,
    /// CaesarStream: destination address word awaiting its data word.
    pending_addr: Option<u32>,
    /// Memory-mapped staging registers (DMA_SRC/DMA_DST/DMA_LEN), latched
    /// into the engine when DMA_CTL is written.
    pub staging: (u32, u32, u32),
    pub stats: DmaStats,
}

impl Default for Dma {
    fn default() -> Self {
        Self::new()
    }
}

impl Dma {
    pub fn new() -> Self {
        Dma {
            mode: DmaMode::Copy,
            src: 0,
            dst: 0,
            read_remaining: 0,
            writes_remaining: 0,
            fifo: VecDeque::with_capacity(FIFO_DEPTH),
            pending_addr: None,
            staging: (0, 0, 0),
            stats: DmaStats::default(),
        }
    }

    /// Program and start a transfer. `len` is the byte count of the
    /// *source* stream (must be word-aligned; CaesarStream requires an even
    /// word count since entries are pairs).
    pub fn start(&mut self, mode: DmaMode, src: u32, dst: u32, len: u32) {
        assert!(len % 4 == 0, "DMA length must be word aligned");
        if mode == DmaMode::CaesarStream {
            assert!(len % 8 == 0, "CaesarStream length must be a whole number of pairs");
        }
        self.stats.transfers += 1;
        self.mode = mode;
        self.src = src;
        self.dst = dst;
        self.read_remaining = len;
        self.writes_remaining = match mode {
            DmaMode::Copy => len / 4,
            DmaMode::CaesarStream => len / 8,
        };
        self.fifo.clear();
        self.pending_addr = None;
    }

    /// True while a transfer is in flight.
    pub fn busy(&self) -> bool {
        self.writes_remaining > 0
    }

    /// Read-port request for this cycle: address of the next stream word,
    /// if the FIFO has room.
    pub fn want_read(&self) -> Option<u32> {
        if self.read_remaining == 0 || self.fifo.len() >= FIFO_DEPTH {
            return None;
        }
        Some(self.src)
    }

    /// The SoC completed the read issued this cycle.
    pub fn complete_read(&mut self, data: u32) {
        debug_assert!(self.read_remaining >= 4);
        self.stats.words_read += 1;
        self.src += 4;
        self.read_remaining -= 4;
        match self.mode {
            DmaMode::Copy => {
                self.fifo.push_back(DmaWrite { addr: self.dst, data });
                self.dst += 4;
            }
            DmaMode::CaesarStream => match self.pending_addr.take() {
                None => self.pending_addr = Some(data),
                Some(addr) => self.fifo.push_back(DmaWrite { addr, data }),
            },
        }
    }

    /// Write-port request for this cycle.
    pub fn want_write(&self) -> Option<DmaWrite> {
        self.fifo.front().copied()
    }

    /// The SoC granted + retired the write (the target slave accepted it).
    pub fn complete_write(&mut self) {
        self.fifo.pop_front().expect("no staged write");
        self.stats.words_written += 1;
        self.writes_remaining -= 1;
    }

    /// Count an active cycle (for energy accounting).
    pub fn tick_active(&mut self) {
        if self.busy() {
            self.stats.active_cycles += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the DMA against a fake memory, one read + one write per cycle
    /// (the crossbar-overlap model), and count cycles to completion.
    fn run(dma: &mut Dma, mem: &mut [u32]) -> u32 {
        let mut cycles = 0;
        while dma.busy() {
            cycles += 1;
            // Write port first (drains FIFO), then read port — both happen
            // in the same cycle on different crossbar slaves.
            if let Some(w) = dma.want_write() {
                mem[(w.addr / 4) as usize] = w.data;
                dma.complete_write();
            }
            if let Some(addr) = dma.want_read() {
                let data = mem[(addr / 4) as usize];
                dma.complete_read(data);
            }
            assert!(cycles < 10_000, "DMA hung");
        }
        cycles
    }

    #[test]
    fn copy_sustains_one_word_per_cycle() {
        let mut mem = vec![0u32; 256];
        for i in 0..64 {
            mem[i] = i as u32 + 100;
        }
        let mut dma = Dma::new();
        dma.start(DmaMode::Copy, 0, 128 * 4, 64 * 4);
        let cycles = run(&mut dma, &mut mem);
        for i in 0..64 {
            assert_eq!(mem[128 + i], i as u32 + 100);
        }
        // 1 word/cycle sustained + 1 cycle pipeline fill.
        assert!(cycles <= 64 + 2, "copy took {cycles} cycles");
        assert_eq!(dma.stats.words_written, 64);
    }

    #[test]
    fn caesar_stream_two_cycles_per_op() {
        // 16 (addr, data) pairs targeting addresses 0x300.. — the model
        // must sustain one micro-op write per 2 cycles.
        let mut mem = vec![0u32; 512];
        for i in 0..16 {
            mem[2 * i] = (0x300 + 4 * i) as u32; // dest address
            mem[2 * i + 1] = 0xc0de_0000 + i as u32; // micro-op word
        }
        let mut dma = Dma::new();
        dma.start(DmaMode::CaesarStream, 0, 0, 16 * 8);
        let cycles = run(&mut dma, &mut mem);
        for i in 0..16 {
            assert_eq!(mem[(0x300 / 4) + i], 0xc0de_0000 + i as u32);
        }
        assert!(cycles <= 2 * 16 + 2, "stream took {cycles} cycles");
        assert_eq!(dma.stats.words_read, 32);
        assert_eq!(dma.stats.words_written, 16);
    }

    #[test]
    fn backpressure_holds_write() {
        // If the slave never accepts, the FIFO fills and reads stop.
        let mut dma = Dma::new();
        dma.start(DmaMode::Copy, 0, 0x1000, 64 * 4);
        let mut reads = 0;
        for _ in 0..100 {
            if let Some(_a) = dma.want_read() {
                dma.complete_read(0xab);
                reads += 1;
            }
        }
        assert_eq!(reads, FIFO_DEPTH as u32);
        assert!(dma.busy());
        assert_eq!(dma.want_write().unwrap().data, 0xab);
    }

    #[test]
    #[should_panic(expected = "word aligned")]
    fn misaligned_len_rejected() {
        Dma::new().start(DmaMode::Copy, 0, 0, 6);
    }
}
