//! RV32 instruction-set simulator with a CV32E40P-style cycle model.
//!
//! One core engine ([`CpuCore`]) serves every processor in the paper:
//!
//! | Paper CPU            | Config                      | Role |
//! |----------------------|-----------------------------|------|
//! | CV32E40P (RV32IMC)   | [`CpuConfig::CV32E40P`]     | HEEPerator host CPU (Table V baseline) |
//! | CV32E40P (RV32IMCXcv)| [`CpuConfig::cv32e40p_xcv`] | Table VI multi-core baseline |
//! | CV32E20 (RV32E)      | [`CpuConfig::CV32E20`]      | Tiny host for the NMC configs of Table VI |
//! | CV32E40X eCPU (RV32EC)| [`CpuConfig::ECPU`]        | NM-Carus controller (offloads xvnmc to the VPU) |
//!
//! Fidelity: instruction-level. Per-instruction costs mirror the CV32E40P
//! user manual (single-cycle ALU, 1-cycle `mul`, 3-cycle taken branches,
//! 2-cycle jumps, multi-cycle div), which reproduces the paper's measured
//! cycles/output for the Table V baselines within a few percent (see
//! `rust/tests/calibration.rs`). Pipeline-internal hazards are folded into
//! these costs, standard ISS practice. Bus contention is *not* folded: the
//! SoC charges wait cycles when the data port loses arbitration, and
//! instruction fetches are reported per-instruction for energy accounting.

use crate::isa::rv32::{AluOp, BranchOp, Instr, LoadOp, MulOp};
use crate::isa::xcv;
use crate::isa::xvnmc::VInstr;
use crate::isa::{sext, Reg};

/// Memory interface the core executes against. Implemented by the SoC (bus
/// dispatch, energy events) and by NM-Carus (private eMEM).
pub trait MemIf {
    /// Read `size` ∈ {1,2,4} bytes, zero-extended.
    fn read(&mut self, addr: u32, size: u32) -> u32;
    /// Write `size` ∈ {1,2,4} bytes.
    fn write(&mut self, addr: u32, size: u32, val: u32);
}

/// Static CPU feature configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CpuConfig {
    pub name: &'static str,
    /// RV32E: only x0..x15 (CV32E20, eCPU).
    pub rv32e: bool,
    /// M extension (mul/div).
    pub has_m: bool,
    /// Xcv DSP extension (CV32E40P option).
    pub has_xcv: bool,
    /// xvnmc offload (eCPU only): vector instructions are returned in
    /// [`Effect::vector`] instead of trapping.
    pub has_xvnmc: bool,
}

impl CpuConfig {
    /// X-HEEP host CPU: OpenHW CV32E40P, RV32IMC.
    pub const CV32E40P: CpuConfig =
        CpuConfig { name: "CV32E40P", rv32e: false, has_m: true, has_xcv: false, has_xvnmc: false };
    /// CV32E40P with the PULP DSP extension (Table VI baseline clusters).
    pub const CV32E40P_XCV: CpuConfig =
        CpuConfig { name: "CV32E40P+Xcv", rv32e: false, has_m: true, has_xcv: true, has_xvnmc: false };
    /// CV32E20 ("micro-riscy"): RV32E, no hardware mul/div.
    pub const CV32E20: CpuConfig =
        CpuConfig { name: "CV32E20", rv32e: true, has_m: false, has_xcv: false, has_xvnmc: false };
    /// NM-Carus embedded CPU: CV32E40X in RV32EC config + CORE-V-XIF
    /// offload of the xvnmc extension.
    pub const ECPU: CpuConfig =
        CpuConfig { name: "eCPU(CV32E40X)", rv32e: true, has_m: false, has_xcv: false, has_xvnmc: true };
}

/// Why instruction execution stopped or deviated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trap {
    IllegalInstr(u32),
    /// Register above x15 on an RV32E core.
    IllegalReg(Reg),
    /// Unaligned load/store (not supported by the modeled cores).
    Misaligned(u32),
}

impl std::fmt::Display for Trap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Trap::IllegalInstr(w) => write!(f, "illegal instruction {w:#010x}"),
            Trap::IllegalReg(r) => write!(f, "register x{r} unavailable on RV32E"),
            Trap::Misaligned(a) => write!(f, "misaligned access at {a:#010x}"),
        }
    }
}
impl std::error::Error for Trap {}

/// Result of executing one instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Effect {
    /// Base cycle cost (pipeline-internal; bus waits are charged by the SoC).
    pub cycles: u32,
    /// A data-memory access happened (addr, size, was_write).
    pub mem: Option<(u32, u32, bool)>,
    /// An xvnmc instruction to offload to the VPU (eCPU only). The core has
    /// already advanced `pc`; issue/stall policy is the caller's job.
    pub vector: Option<VInstr>,
    /// `ebreak` — the modeled firmware's "kernel done" convention.
    pub halted: bool,
    /// `wfi` — core sleeps until an interrupt (SoC handles wake-up).
    pub wfi: bool,
}

impl Effect {
    fn basic(cycles: u32) -> Effect {
        Effect { cycles, mem: None, vector: None, halted: false, wfi: false }
    }
}

/// Architectural state + execution engine.
#[derive(Debug, Clone)]
pub struct CpuCore {
    pub cfg: CpuConfig,
    pub regs: [u32; 32],
    pub pc: u32,
    /// Retired instruction count.
    pub instret: u64,
    /// Retired-instruction histogram inputs for the energy model.
    pub alu_ops: u64,
    pub mul_ops: u64,
    pub mem_ops: u64,
    pub branch_ops: u64,
}

impl CpuCore {
    pub fn new(cfg: CpuConfig, pc: u32) -> Self {
        CpuCore { cfg, regs: [0; 32], pc, instret: 0, alu_ops: 0, mul_ops: 0, mem_ops: 0, branch_ops: 0 }
    }

    #[inline]
    fn rd(&self, r: Reg) -> Result<u32, Trap> {
        if self.cfg.rv32e && r >= 16 {
            return Err(Trap::IllegalReg(r));
        }
        Ok(self.regs[r as usize])
    }

    #[inline]
    fn wr(&mut self, r: Reg, v: u32) -> Result<(), Trap> {
        if self.cfg.rv32e && r >= 16 {
            return Err(Trap::IllegalReg(r));
        }
        if r != 0 {
            self.regs[r as usize] = v;
        }
        Ok(())
    }

    /// Execute one decoded instruction against `mem`. Advances `pc`.
    pub fn exec(&mut self, i: &Instr, mem: &mut impl MemIf) -> Result<Effect, Trap> {
        self.instret += 1;
        let next = self.pc.wrapping_add(4);
        let eff = match *i {
            Instr::Lui { rd, imm } => {
                self.wr(rd, imm as u32)?;
                self.alu_ops += 1;
                Effect::basic(1)
            }
            Instr::Auipc { rd, imm } => {
                self.wr(rd, self.pc.wrapping_add(imm as u32))?;
                self.alu_ops += 1;
                Effect::basic(1)
            }
            Instr::Jal { rd, off } => {
                self.wr(rd, next)?;
                self.pc = self.pc.wrapping_add(off as u32);
                self.branch_ops += 1;
                self.instret_done();
                return Ok(Effect::basic(timing::JUMP));
            }
            Instr::Jalr { rd, rs1, off } => {
                let target = self.rd(rs1)?.wrapping_add(off as u32) & !1;
                self.wr(rd, next)?;
                self.pc = target;
                self.branch_ops += 1;
                self.instret_done();
                return Ok(Effect::basic(timing::JUMP));
            }
            Instr::Branch { op, rs1, rs2, off } => {
                let a = self.rd(rs1)?;
                let b = self.rd(rs2)?;
                let taken = match op {
                    BranchOp::Beq => a == b,
                    BranchOp::Bne => a != b,
                    BranchOp::Blt => (a as i32) < (b as i32),
                    BranchOp::Bge => (a as i32) >= (b as i32),
                    BranchOp::Bltu => a < b,
                    BranchOp::Bgeu => a >= b,
                };
                self.branch_ops += 1;
                self.pc = if taken { self.pc.wrapping_add(off as u32) } else { next };
                self.instret_done();
                return Ok(Effect::basic(if taken { timing::BRANCH_TAKEN } else { timing::BRANCH_NOT_TAKEN }));
            }
            Instr::Load { op, rd, rs1, off } => {
                let addr = self.rd(rs1)?.wrapping_add(off as u32);
                let size = op.size();
                if addr % size != 0 {
                    return Err(Trap::Misaligned(addr));
                }
                let raw = mem.read(addr, size);
                let val = match op {
                    LoadOp::Lb => sext(raw, 8) as u32,
                    LoadOp::Lh => sext(raw, 16) as u32,
                    _ => raw,
                };
                self.wr(rd, val)?;
                self.mem_ops += 1;
                Effect { mem: Some((addr, size, false)), ..Effect::basic(timing::LOAD) }
            }
            Instr::Store { op, rs2, rs1, off } => {
                let addr = self.rd(rs1)?.wrapping_add(off as u32);
                let size = op.size();
                if addr % size != 0 {
                    return Err(Trap::Misaligned(addr));
                }
                mem.write(addr, size, self.rd(rs2)?);
                self.mem_ops += 1;
                Effect { mem: Some((addr, size, true)), ..Effect::basic(timing::STORE) }
            }
            Instr::AluImm { op, rd, rs1, imm } => {
                let a = self.rd(rs1)?;
                self.wr(rd, alu(op, a, imm as u32))?;
                self.alu_ops += 1;
                Effect::basic(1)
            }
            Instr::Alu { op, rd, rs1, rs2 } => {
                let a = self.rd(rs1)?;
                let b = self.rd(rs2)?;
                self.wr(rd, alu(op, a, b))?;
                self.alu_ops += 1;
                Effect::basic(1)
            }
            Instr::MulDiv { op, rd, rs1, rs2 } => {
                if !self.cfg.has_m {
                    return Err(Trap::IllegalInstr(crate::isa::rv32::encode(i)));
                }
                let a = self.rd(rs1)?;
                let b = self.rd(rs2)?;
                let (v, cost) = muldiv(op, a, b);
                self.wr(rd, v)?;
                self.mul_ops += 1;
                Effect::basic(cost)
            }
            Instr::Csr { op, rd, rs1, csr: _ } => {
                // Minimal CSR file: reads return 0 (mcycle etc. live in the
                // peripheral space in this system); writes are absorbed.
                let _ = op;
                let _ = self.rd(rs1)?;
                self.wr(rd, 0)?;
                Effect::basic(timing::CSR)
            }
            Instr::Ecall | Instr::Ebreak => Effect { halted: true, ..Effect::basic(1) },
            Instr::Wfi => Effect { wfi: true, ..Effect::basic(1) },
            Instr::Fence => Effect::basic(1),
            Instr::Xcv(x) => {
                if !self.cfg.has_xcv {
                    return Err(Trap::IllegalInstr(crate::isa::rv32::encode(i)));
                }
                let a = self.rd(x.rs1)?;
                let b = self.rd(x.rs2)?;
                let acc = self.rd(x.rd)?;
                self.wr(x.rd, xcv::exec(x.op, x.sew, a, b, acc))?;
                self.alu_ops += 1;
                Effect::basic(1)
            }
            Instr::Xvnmc(v) => {
                if !self.cfg.has_xvnmc {
                    return Err(Trap::IllegalInstr(crate::isa::rv32::encode(i)));
                }
                // Offloaded through the CORE-V-XIF; issue cost is 1 cycle on
                // the scalar side, the VPU timing is modeled by the caller.
                Effect { vector: Some(v), ..Effect::basic(1) }
            }
        };
        self.pc = next;
        self.instret_done();
        Ok(eff)
    }

    #[inline]
    fn instret_done(&mut self) {}
}

/// Per-instruction cycle costs (CV32E40P user manual; see module docs).
pub mod timing {
    /// Taken conditional branch: 1 + 2-cycle IF/ID flush.
    pub const BRANCH_TAKEN: u32 = 3;
    pub const BRANCH_NOT_TAKEN: u32 = 1;
    /// jal/jalr: 2 cycles (target fetch bubble).
    pub const JUMP: u32 = 2;
    /// Loads/stores occupy the LSU for 1 cycle when the bus is free.
    pub const LOAD: u32 = 1;
    pub const STORE: u32 = 1;
    /// 32x32→32 single-cycle multiplier.
    pub const MUL: u32 = 1;
    /// mulh* take 5 cycles on CV32E40P.
    pub const MULH: u32 = 5;
    /// Serial divider, data-independent worst case modeled.
    pub const DIV: u32 = 35;
    pub const CSR: u32 = 2;
}

#[inline]
fn alu(op: AluOp, a: u32, b: u32) -> u32 {
    match op {
        AluOp::Add => a.wrapping_add(b),
        AluOp::Sub => a.wrapping_sub(b),
        AluOp::Sll => a << (b & 31),
        AluOp::Slt => ((a as i32) < (b as i32)) as u32,
        AluOp::Sltu => (a < b) as u32,
        AluOp::Xor => a ^ b,
        AluOp::Srl => a >> (b & 31),
        AluOp::Sra => ((a as i32) >> (b & 31)) as u32,
        AluOp::Or => a | b,
        AluOp::And => a & b,
    }
}

#[inline]
fn muldiv(op: MulOp, a: u32, b: u32) -> (u32, u32) {
    match op {
        MulOp::Mul => (a.wrapping_mul(b), timing::MUL),
        MulOp::Mulh => ((((a as i32 as i64) * (b as i32 as i64)) >> 32) as u32, timing::MULH),
        MulOp::Mulhsu => ((((a as i32 as i64) * (b as u64 as i64)) >> 32) as u32, timing::MULH),
        MulOp::Mulhu => ((((a as u64) * (b as u64)) >> 32) as u32, timing::MULH),
        MulOp::Div => {
            let v = if b == 0 {
                u32::MAX
            } else if a == 0x8000_0000 && b == u32::MAX {
                a
            } else {
                ((a as i32) / (b as i32)) as u32
            };
            (v, timing::DIV)
        }
        MulOp::Divu => (if b == 0 { u32::MAX } else { a / b }, timing::DIV),
        MulOp::Rem => {
            let v = if b == 0 {
                a
            } else if a == 0x8000_0000 && b == u32::MAX {
                0
            } else {
                ((a as i32) % (b as i32)) as u32
            };
            (v, timing::DIV)
        }
        MulOp::Remu => (if b == 0 { a } else { a % b }, timing::DIV),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::isa::reg::*;
    use crate::isa::rv32::decode;

    /// Flat test memory.
    struct Flat(Vec<u8>);
    impl MemIf for Flat {
        fn read(&mut self, addr: u32, size: u32) -> u32 {
            let a = addr as usize;
            match size {
                1 => self.0[a] as u32,
                2 => u16::from_le_bytes([self.0[a], self.0[a + 1]]) as u32,
                _ => u32::from_le_bytes([self.0[a], self.0[a + 1], self.0[a + 2], self.0[a + 3]]),
            }
        }
        fn write(&mut self, addr: u32, size: u32, val: u32) {
            let a = addr as usize;
            match size {
                1 => self.0[a] = val as u8,
                2 => self.0[a..a + 2].copy_from_slice(&(val as u16).to_le_bytes()),
                _ => self.0[a..a + 4].copy_from_slice(&val.to_le_bytes()),
            }
        }
    }

    /// Run an assembled program until ebreak; return (cycles, core).
    fn run(asm: &Asm, cfg: CpuConfig, mem_size: usize) -> (u64, CpuCore, Flat) {
        let prog = asm.assemble().unwrap();
        let mut mem = Flat(vec![0; mem_size]);
        for (i, w) in prog.words.iter().enumerate() {
            mem.write(prog.base + 4 * i as u32, 4, *w);
        }
        let mut cpu = CpuCore::new(cfg, prog.base);
        let mut cycles = 0u64;
        for _ in 0..1_000_000 {
            let w = mem.read(cpu.pc, 4);
            let instr = decode(w).unwrap();
            let eff = cpu.exec(&instr, &mut mem).unwrap();
            cycles += eff.cycles as u64;
            if eff.halted {
                return (cycles, cpu, mem);
            }
        }
        panic!("program did not halt");
    }

    #[test]
    fn fibonacci() {
        let mut a = Asm::new(0x100);
        // a0 = fib(10) iteratively.
        a.li(A0, 0).li(A1, 1).li(T0, 10).label("loop").add(T1, A0, A1).mv(A0, A1).mv(A1, T1)
            .addi(T0, T0, -1).bne(T0, ZERO, "loop").ebreak();
        let (_c, cpu, _m) = run(&a, CpuConfig::CV32E40P, 0x1000);
        assert_eq!(cpu.regs[A0 as usize], 55);
    }

    #[test]
    fn word_copy_loop_cpi_matches_cv32e40p() {
        // The Table V element-wise pattern: lw/lw/xor/sw + 3 addi + bne
        // must come out at 10 cycles/iteration (8 instrs, taken branch +2).
        let n = 16;
        let mut a = Asm::new(0x0);
        a.li(A0, 0x400) // src1
            .li(A1, 0x500) // src2
            .li(A2, 0x600) // dst
            .li(A3, n)
            .label("loop")
            .lw(T0, 0, A0)
            .lw(T1, 0, A1)
            .xor(T0, T0, T1)
            .sw(T0, 0, A2)
            .addi(A0, A0, 4)
            .addi(A1, A1, 4)
            .addi(A2, A2, 4)
            .addi(A3, A3, -1)
            .bne(A3, ZERO, "loop")
            .ebreak();
        let (cycles, _cpu, mem) = run(&a, CpuConfig::CV32E40P, 0x1000);
        // Per iteration: 8×1 + addi(1) + taken branch... our loop has 9
        // instructions: 4 mem/alu + 3 ptr addi + 1 count addi + bne(3) = 11.
        let per_iter = 11i64;
        let setup = 7i64; // li sequence + final ebreak, approximately
        assert!(
            (cycles as i64 - (n as i64 * per_iter + setup)).abs() <= 4,
            "cycles = {cycles}, expected ≈ {}",
            n as i64 * per_iter + setup
        );
        let _ = mem;
    }

    #[test]
    fn loads_sign_extend() {
        let mut a = Asm::new(0);
        a.li(A0, 0x200)
            .li(T0, -2) // 0xfffffffe
            .sb(T0, 0, A0)
            .lb(A1, 0, A0)
            .lbu(A2, 0, A0)
            .sh(T0, 4, A0)
            .lh(A3, 4, A0)
            .lhu(A4, 4, A0)
            .ebreak();
        let (_c, cpu, _m) = run(&a, CpuConfig::CV32E40P, 0x1000);
        assert_eq!(cpu.regs[A1 as usize] as i32, -2);
        assert_eq!(cpu.regs[A2 as usize], 0xfe);
        assert_eq!(cpu.regs[A3 as usize] as i32, -2);
        assert_eq!(cpu.regs[A4 as usize], 0xfffe);
    }

    #[test]
    fn rv32e_rejects_high_regs() {
        let mut cpu = CpuCore::new(CpuConfig::CV32E20, 0);
        let mut mem = Flat(vec![0; 16]);
        let i = Instr::Alu { op: AluOp::Add, rd: 20, rs1: 1, rs2: 2 };
        assert_eq!(cpu.exec(&i, &mut mem), Err(Trap::IllegalReg(20)));
    }

    #[test]
    fn m_extension_gated() {
        let mut cpu = CpuCore::new(CpuConfig::CV32E20, 0);
        let mut mem = Flat(vec![0; 16]);
        let i = Instr::MulDiv { op: MulOp::Mul, rd: 5, rs1: 5, rs2: 5 };
        assert!(matches!(cpu.exec(&i, &mut mem), Err(Trap::IllegalInstr(_))));
    }

    #[test]
    fn div_edge_cases() {
        assert_eq!(muldiv(MulOp::Div, 7, 0).0, u32::MAX);
        assert_eq!(muldiv(MulOp::Div, 0x8000_0000, u32::MAX).0, 0x8000_0000);
        assert_eq!(muldiv(MulOp::Rem, 7, 0).0, 7);
        assert_eq!(muldiv(MulOp::Rem, 0x8000_0000, u32::MAX).0, 0);
        assert_eq!(muldiv(MulOp::Divu, 10, 3).0, 3);
    }

    #[test]
    fn xcv_gating_and_exec() {
        let mut a = Asm::new(0);
        a.li(A0, 0x0102_0304u32 as i32).li(A1, 0x0101_0101u32 as i32).li(A2, 10)
            .cv_sdotsp_b(A2, A0, A1).ebreak();
        let (_c, cpu, _m) = run(&a, CpuConfig::CV32E40P_XCV, 0x1000);
        assert_eq!(cpu.regs[A2 as usize], 20); // 10 + (4+3+2+1)
    }

    #[test]
    fn xvnmc_offloads_on_ecpu() {
        let mut cpu = CpuCore::new(CpuConfig::ECPU, 0);
        let mut mem = Flat(vec![0; 16]);
        let v = VInstr::Emvv { vd: 1, idx: 2, rs1: 3 };
        let eff = cpu.exec(&Instr::Xvnmc(v), &mut mem).unwrap();
        assert_eq!(eff.vector, Some(v));
        // And traps on the host CPU.
        let mut host = CpuCore::new(CpuConfig::CV32E40P, 0);
        assert!(host.exec(&Instr::Xvnmc(v), &mut mem).is_err());
    }

    #[test]
    fn wfi_and_halt_reported() {
        let mut cpu = CpuCore::new(CpuConfig::CV32E40P, 0);
        let mut mem = Flat(vec![0; 16]);
        assert!(cpu.exec(&Instr::Wfi, &mut mem).unwrap().wfi);
        assert!(cpu.exec(&Instr::Ebreak, &mut mem).unwrap().halted);
    }
}
