//! NM-Carus: the autonomous, RISC-V-programmable NMC macro (§III-B).
//!
//! A minimal SoC inside a memory macro (Fig. 4): the **eCPU** (CV32E40X in
//! RV32EC configuration) fetches a kernel from the 512 B **eMEM**, executes
//! the scalar parts itself and offloads `xvnmc` vector instructions to the
//! **VPU** through the CORE-V-XIF. The **VRF** (the host-visible 32 KiB
//! memory) is the only data source of the VPU; the eCPU reaches it solely
//! through `emvv`/`emvx` element moves — there are no vector loads/stores.
//!
//! Host protocol (§III-B2):
//! - *memory mode* (`config_mode == false`): bus accesses read/write the
//!   VRF exactly like an SRAM — including **during** kernel execution
//!   (double buffering), with a 1-cycle penalty when the VPU holds the
//!   banks.
//! - *configuration mode*: bus accesses reach the controller: the eMEM
//!   (kernel upload, argument passing) and the control/status register
//!   ([`CTL_OFFSET`]) that starts the kernel and reports busy/done. The
//!   done bit is also routed to the interrupt pin ([`Carus::irq`]) so the
//!   host can WFI during computation.
//!
//! The kernel signals completion with `ebreak`.

pub mod vpu;
pub mod vrf;

use crate::cpu::{CpuConfig, CpuCore, MemIf};
use crate::isa::rv32::{decode, Instr};
use crate::isa::xvnmc::{unpack_indexes, VInstr, VSrc};
use crate::isa::Sew;
use crate::mem::{Bank, MacroKind};
use vpu::{Operand, VecCmd, Vpu, EMV_COST};
use vrf::Vrf;

/// eMEM size: 512 B register-file macro (§IV-B).
pub const EMEM_BYTES: u32 = 512;
/// Control/status register offset within the configuration space.
pub const CTL_OFFSET: u32 = 0x7ff0;
/// Argument scratch registers (kernel ABI): 4 words at the top of eMEM.
/// The host writes them in configuration mode; kernels read them with `lw`.
pub const ARG_OFFSET: u32 = EMEM_BYTES - 16;

/// Control-register bits.
pub const CTL_START: u32 = 1 << 0;
pub const STATUS_BUSY: u32 = 1 << 0;
pub const STATUS_DONE: u32 = 1 << 1;

/// Controller-side activity counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct CarusStats {
    pub ecpu_active_cycles: u64,
    pub ecpu_sleep_cycles: u64,
    pub emem_accesses: u64,
    /// Cycles the eCPU stalled waiting for a VPU slot / hazard.
    pub ecpu_vpu_stall_cycles: u64,
    /// Host accesses served in memory mode while the VPU was busy.
    pub host_conflicts: u64,
}

/// The NM-Carus macro model.
#[derive(Debug, Clone)]
pub struct Carus {
    pub vrf: Vrf,
    pub emem: Bank,
    pub ecpu: CpuCore,
    pub vpu: Vpu,
    pub stats: CarusStats,
    /// Host-driven mode pin: configuration mode when true.
    pub config_mode: bool,
    /// Kernel running (eCPU executing).
    running: bool,
    /// eCPU hit `ebreak`; completion is signalled once the VPU drains.
    ecpu_halted: bool,
    /// Kernel completed — eCPU halted *and* vector pipeline drained
    /// (sticky until acknowledged or next start).
    done: bool,
    /// Remaining stall cycles of the current scalar instruction.
    ecpu_stall: u32,
    /// Vector instruction waiting for a VPU slot or pipeline drain.
    pending: Option<VInstr>,
    /// Pre-decoded eMEM (invalidated on configuration writes).
    decoded: Vec<Option<Instr>>,
}

impl Carus {
    /// Create an NM-Carus instance with the given lane count (paper
    /// implementation: 4 lanes).
    pub fn new(lanes: u32) -> Self {
        Carus {
            vrf: Vrf::new(lanes),
            emem: Bank::new(MacroKind::RegFile512),
            ecpu: CpuCore::new(CpuConfig::ECPU, 0),
            vpu: Vpu::new(lanes),
            stats: CarusStats::default(),
            config_mode: false,
            running: false,
            ecpu_halted: false,
            done: false,
            ecpu_stall: 0,
            pending: None,
            decoded: vec![None; (EMEM_BYTES / 4) as usize],
        }
    }

    /// Interrupt pin: high while a completed kernel is unacknowledged.
    pub fn irq(&self) -> bool {
        self.done
    }

    /// Kernel in flight?
    pub fn busy(&self) -> bool {
        self.running || self.vpu.busy()
    }

    // ---- Host (bus slave) interface --------------------------------------

    /// Bus read. Memory mode → VRF; config mode → eMEM / status register.
    /// Returns (value, extra_wait_cycles).
    pub fn bus_read(&mut self, off: u32, size: u32) -> (u32, u32) {
        if self.config_mode {
            if off == CTL_OFFSET {
                let mut s = 0;
                if self.busy() {
                    s |= STATUS_BUSY;
                }
                if self.done {
                    s |= STATUS_DONE;
                }
                return (s, 0);
            }
            self.stats.emem_accesses += 1;
            return (self.emem.read(off % EMEM_BYTES, size), 0);
        }
        let penalty = if self.vpu.busy() {
            self.stats.host_conflicts += 1;
            1
        } else {
            0
        };
        (self.vrf.mem_read(off, size), penalty)
    }

    /// Bus write. Returns extra wait cycles.
    pub fn bus_write(&mut self, off: u32, size: u32, val: u32) -> u32 {
        if self.config_mode {
            if off == CTL_OFFSET {
                if val & CTL_START != 0 {
                    self.start();
                } else {
                    // Acknowledge/clear done.
                    self.done = false;
                }
                return 0;
            }
            self.stats.emem_accesses += 1;
            self.emem.write(off % EMEM_BYTES, size, val);
            self.decoded[((off % EMEM_BYTES) / 4) as usize] = None;
            return 0;
        }
        let penalty = if self.vpu.busy() {
            self.stats.host_conflicts += 1;
            1
        } else {
            0
        };
        self.vrf.mem_write(off, size, val);
        penalty
    }

    /// Start kernel execution (host wrote the start bit).
    pub fn start(&mut self) {
        self.running = true;
        self.ecpu_halted = false;
        self.done = false;
        self.ecpu = CpuCore::new(CpuConfig::ECPU, 0);
        // ABI: sp → top of eMEM (below the argument words).
        self.ecpu.regs[crate::isa::reg::SP as usize] = ARG_OFFSET;
        self.ecpu_stall = 0;
        self.pending = None;
    }

    /// Host-side helper: upload a kernel program into the eMEM
    /// (configuration-mode writes, typically DMA'd; accounting is done by
    /// the caller when it models the transfer).
    pub fn load_kernel(&mut self, words: &[u32]) {
        assert!(
            (words.len() as u32) * 4 <= ARG_OFFSET,
            "kernel does not fit the 512 B eMEM ({} words)",
            words.len()
        );
        for (i, w) in words.iter().enumerate() {
            self.emem.poke(4 * i as u32, 4, *w);
            self.decoded[i] = None;
        }
    }

    /// Host-side helper: set an argument word (ABI: eMEM top).
    pub fn set_arg(&mut self, idx: u32, val: u32) {
        assert!(idx < 4);
        self.emem.poke(ARG_OFFSET + 4 * idx, 4, val);
    }

    // ---- Internal execution ----------------------------------------------

    /// Promote eCPU-halt to `done` once the vector pipeline is drained.
    fn maybe_complete(&mut self) {
        if !self.running && self.ecpu_halted && self.vpu.empty() {
            self.ecpu_halted = false;
            self.done = true;
        }
    }

    /// Advance one cycle of the internal controller + VPU.
    #[inline]
    pub fn step(&mut self) {
        // Fast idle path: nothing running, nothing in flight (the common
        // state for Table V CPU/Caesar workloads — see EXPERIMENTS.md §Perf).
        if !self.running && !self.ecpu_halted && !self.vpu.busy() {
            self.vpu.stats.idle_cycles += 1;
            self.stats.ecpu_sleep_cycles += 1;
            return;
        }
        self.vpu.step(&mut self.vrf);
        if !self.running {
            // "Once the kernel terminates, a dedicated status bit is set":
            // termination = eCPU halted AND vector pipeline drained, so the
            // host can never observe a half-written result.
            self.maybe_complete();
            self.stats.ecpu_sleep_cycles += 1;
            return;
        }
        self.stats.ecpu_active_cycles += 1;
        self.step_ecpu();
        self.maybe_complete();
    }

    /// Skip-ahead support (`--timing=event`): number of upcoming cycles
    /// that are strictly quiet for this macro — every one of them would
    /// only decrement countdowns ([`Vpu::skip`](vpu::Vpu), `ecpu_stall`)
    /// and bump cycle counters. `u64::MAX` means no self-scheduled event
    /// (fully idle; only the host can change our state). The boundary
    /// cycle (VPU retire, stall release, completion handshake, any eCPU
    /// fetch) always runs through [`Carus::step`].
    pub fn quiet_horizon(&self) -> u64 {
        if !self.running {
            if self.ecpu_halted {
                // Draining: once the pipeline is empty the completion
                // handshake (`maybe_complete`) must run in `step`.
                if self.vpu.empty() {
                    0
                } else {
                    self.vpu.quiet_horizon()
                }
            } else if self.vpu.busy() {
                self.vpu.quiet_horizon()
            } else {
                u64::MAX
            }
        } else if self.pending.is_some() {
            // A stalled vector instruction retries every cycle, but every
            // dispatch-failure condition (queue slot, pipeline-empty,
            // scoreboard hazard) is constant until the executing
            // instruction retires — which the VPU horizon excludes.
            self.vpu.quiet_horizon()
        } else if self.ecpu_stall > 0 {
            (u64::from(self.ecpu_stall) - 1).min(self.vpu.quiet_horizon())
        } else {
            // Ready to fetch: the next cycle executes an instruction.
            0
        }
    }

    /// Advance `k` cycles in closed form; exactly equivalent to `k`
    /// calls of [`Carus::step`] provided `k <= self.quiet_horizon()`.
    pub fn skip(&mut self, k: u64) {
        debug_assert!(k <= self.quiet_horizon(), "skip past a Carus state transition");
        self.vpu.skip(k);
        if self.running {
            self.stats.ecpu_active_cycles += k;
            if self.pending.is_some() {
                self.stats.ecpu_vpu_stall_cycles += k;
            } else {
                self.ecpu_stall -= k as u32;
            }
        } else {
            self.stats.ecpu_sleep_cycles += k;
        }
    }

    fn step_ecpu(&mut self) {
        // Retry a stalled vector instruction first.
        if let Some(v) = self.pending {
            if self.try_dispatch(&v) {
                self.pending = None;
            } else {
                self.stats.ecpu_vpu_stall_cycles += 1;
            }
            return;
        }
        if self.ecpu_stall > 0 {
            self.ecpu_stall -= 1;
            return;
        }

        // Fetch + decode from eMEM (pre-decoded cache).
        let pc = self.ecpu.pc % EMEM_BYTES;
        let idx = (pc / 4) as usize;
        let instr = match self.decoded[idx] {
            Some(i) => i,
            None => {
                let w = self.emem.peek(pc, 4);
                match decode(w) {
                    Ok(i) => {
                        self.decoded[idx] = Some(i);
                        i
                    }
                    Err(_) => {
                        // Illegal instruction in a kernel is a firmware bug:
                        // halt and flag completion so the host does not hang.
                        self.running = false;
                        self.ecpu_halted = true;
                        return;
                    }
                }
            }
        };
        self.stats.emem_accesses += 1;

        let mut mem = EmemPort { emem: &mut self.emem, accesses: &mut self.stats.emem_accesses };
        match self.ecpu.exec(&instr, &mut mem) {
            Ok(eff) => {
                if let Some(v) = eff.vector {
                    if !self.try_dispatch(&v) {
                        self.pending = Some(v);
                    }
                    return;
                }
                if eff.halted {
                    self.running = false;
                    self.ecpu_halted = true;
                    return;
                }
                self.ecpu_stall = eff.cycles.saturating_sub(1);
            }
            Err(_) => {
                self.running = false;
                self.ecpu_halted = true;
            }
        }
    }

    /// Try to dispatch a vector instruction this cycle. Returns false if it
    /// must stall (scoreboard full, or drain required).
    fn try_dispatch(&mut self, v: &VInstr) -> bool {
        match *v {
            VInstr::VsetVli { rd, rs1, vtype } => {
                if !self.vpu.empty() {
                    return false;
                }
                let avl = self.ecpu.regs[(rs1 & 15) as usize];
                let sew = Sew::from_code((vtype as u32 >> 3) & 0x7).unwrap_or(Sew::E32);
                let vl = self.vpu.set_vtype(avl, sew);
                self.write_gpr(rd, vl);
                true
            }
            VInstr::VsetIVli { rd, avl, vtype } => {
                if !self.vpu.empty() {
                    return false;
                }
                let sew = Sew::from_code((vtype as u32 >> 3) & 0x7).unwrap_or(Sew::E32);
                let vl = self.vpu.set_vtype(avl as u32, sew);
                self.write_gpr(rd, vl);
                true
            }
            VInstr::VsetVl { rd, rs1, rs2 } => {
                if !self.vpu.empty() {
                    return false;
                }
                let avl = self.ecpu.regs[(rs1 & 15) as usize];
                let vtype = self.ecpu.regs[(rs2 & 15) as usize];
                let sew = Sew::from_code((vtype >> 3) & 0x7).unwrap_or(Sew::E32);
                let vl = self.vpu.set_vtype(avl, sew);
                self.write_gpr(rd, vl);
                true
            }
            VInstr::Emvx { rd, vs2, idx } => {
                // The only hazard-causing instruction (§III-B1): waits while
                // an in-flight vector instruction writes the register it
                // reads (precise scoreboard; unrelated registers proceed).
                if self.vpu.writes_reg_in_flight(vs2) {
                    return false;
                }
                let j = self.ecpu.regs[(idx & 15) as usize];
                let val = self.vpu.read_elem(&self.vrf, vs2, j);
                self.vpu.stats.vrf_reads += 1;
                self.write_gpr(rd, val);
                self.ecpu_stall = EMV_COST - 1;
                true
            }
            VInstr::Emvv { vd, idx, rs1 } => {
                if !self.vpu.can_accept() {
                    return false;
                }
                let j = self.ecpu.regs[(idx & 15) as usize];
                let value = self.ecpu.regs[(rs1 & 15) as usize];
                self.vpu.issue(VecCmd::InsertElem { vd, idx: j, value }, &mut self.vrf);
                true
            }
            VInstr::Op { op, vd, vs2, src, indirect, idx_gpr } => {
                if !self.vpu.can_accept() {
                    return false;
                }
                // Indirect register addressing: resolve logical register
                // indexes from the GPR at dispatch time (§III-B1).
                let (vd, vs2, vs1) = if indirect {
                    let packed = self.ecpu.regs[(idx_gpr & 15) as usize];
                    let (d, s2, s1) = unpack_indexes(packed);
                    (d, s2, s1)
                } else {
                    let s1 = match src {
                        VSrc::V(v1) => v1,
                        _ => 0,
                    };
                    (vd, vs2, s1)
                };
                let operand = match src {
                    VSrc::V(_) => Operand::V(vs1),
                    VSrc::X(rs1) => Operand::X(self.ecpu.regs[(rs1 & 15) as usize]),
                    VSrc::I(i) => Operand::I(i as i32),
                };
                self.vpu.issue(VecCmd::Op { op, vd, vs2, src: operand }, &mut self.vrf);
                true
            }
        }
    }

    #[inline]
    fn write_gpr(&mut self, rd: u8, val: u32) {
        let r = (rd & 15) as usize;
        if r != 0 {
            self.ecpu.regs[r] = val;
        }
    }

    pub fn reset_stats(&mut self) {
        self.stats = CarusStats::default();
        self.vpu.stats = Default::default();
        self.vrf.reset_stats();
        self.emem.reset_stats();
    }
}

/// eCPU load/store port into the private eMEM (addresses wrap mod 512 B —
/// the controller bus decodes only the eMEM in the kernel's data space).
struct EmemPort<'a> {
    emem: &'a mut Bank,
    accesses: &'a mut u64,
}

impl MemIf for EmemPort<'_> {
    fn read(&mut self, addr: u32, size: u32) -> u32 {
        *self.accesses += 1;
        self.emem.peek(addr % EMEM_BYTES, size)
    }
    fn write(&mut self, addr: u32, size: u32, val: u32) {
        *self.accesses += 1;
        self.emem.poke(addr % EMEM_BYTES, size, val);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::Asm;
    use crate::isa::reg::*;

    /// Run the macro until the kernel completes; returns cycles.
    fn run(c: &mut Carus, max: u64) -> u64 {
        let mut cycles = 0;
        while c.busy() {
            c.step();
            cycles += 1;
            assert!(cycles < max, "kernel did not complete in {max} cycles");
        }
        cycles
    }

    fn start(c: &mut Carus) {
        c.config_mode = true;
        c.bus_write(CTL_OFFSET, 4, CTL_START);
        c.config_mode = false;
    }

    #[test]
    fn vector_add_kernel() {
        let mut c = Carus::new(4);
        // v0 = [1..64], v1 = 100s; kernel: v2 = v0 + v1 (e32, vl=64).
        let vl = 64u32;
        for j in 0..vl {
            c.vrf.set_elem(0, j, vl, Sew::E32, j + 1);
            c.vrf.set_elem(1, j, vl, Sew::E32, 100);
        }
        let mut a = Asm::new(0);
        a.li(A0, vl as i32).vsetvli(T0, A0, Sew::E32).vadd_vv(2, 0, 1).ebreak();
        c.load_kernel(&a.assemble().unwrap().words);
        start(&mut c);
        assert!(c.busy());
        run(&mut c, 10_000);
        assert!(c.irq());
        for j in 0..vl {
            assert_eq!(c.vrf.elem_signed(2, j, vl, Sew::E32), (j + 101) as i32);
        }
        // Status protocol.
        c.config_mode = true;
        let (s, _) = c.bus_read(CTL_OFFSET, 4);
        assert_eq!(s & STATUS_DONE, STATUS_DONE);
        assert_eq!(s & STATUS_BUSY, 0);
        c.bus_write(CTL_OFFSET, 4, 0); // ack
        let (s, _) = c.bus_read(CTL_OFFSET, 4);
        assert_eq!(s, 0);
        assert!(!c.irq());
    }

    #[test]
    fn emvx_emvv_roundtrip() {
        let mut c = Carus::new(4);
        let vl = 16u32;
        for j in 0..vl {
            c.vrf.set_elem(0, j, vl, Sew::E32, 50 + j);
        }
        // Kernel: x = v0[3]; v1[5] = x + 7.
        let mut a = Asm::new(0);
        a.li(A0, vl as i32)
            .vsetvli(T0, A0, Sew::E32)
            .li(A1, 3)
            .emvx(A2, 0, A1) // a2 = v0[3] = 53
            .addi(A2, A2, 7)
            .li(A1, 5)
            .emvv(1, A1, A2) // v1[5] = 60
            .ebreak();
        c.load_kernel(&a.assemble().unwrap().words);
        start(&mut c);
        run(&mut c, 10_000);
        assert_eq!(c.vrf.elem_unsigned(1, 5, vl, Sew::E32), 60);
    }

    #[test]
    fn indirect_addressing_loop() {
        // The paper's key trick: one vmacc instruction reused across
        // iterations by bumping the packed-index GPR with a single addi.
        let mut c = Carus::new(4);
        let vl = 32u32;
        let sew = Sew::E8;
        // v8..v11 are four input rows; v16 accumulates.
        for r in 8..12u8 {
            for j in 0..vl {
                c.vrf.set_elem(r, j, vl, sew, (r as u32 + j) & 0x7f);
            }
        }
        for j in 0..vl {
            c.vrf.set_elem(16, j, vl, sew, 0);
        }
        // Kernel: for k in 0..4: v16 += 2 * v(8+k)  — vmaccr.vx with the
        // index GPR packing {vs1=0, vs2=8+k, vd=16} and scalar x=2.
        let mut a = Asm::new(0);
        a.li(A0, vl as i32)
            .vsetvli(T0, A0, Sew::E8)
            .li(A1, 2) // scalar multiplier
            .li(A2, crate::isa::xvnmc::pack_indexes(16, 8, 0) as i32)
            .li(A3, 4) // k counter
            .label("loop")
            .vmaccr_vx(A2, A1)
            .addi(A2, A2, 0x100) // bump vs2 byte
            .addi(A3, A3, -1)
            .bne(A3, ZERO, "loop")
            .ebreak();
        c.load_kernel(&a.assemble().unwrap().words);
        start(&mut c);
        run(&mut c, 100_000);
        for j in 0..vl {
            let expect: i32 = (8..12).map(|r| 2 * (((r + j) & 0x7f) as i8 as i32)).sum();
            let got = c.vrf.elem_signed(16, j, vl, sew);
            assert_eq!(got, (expect as i8) as i32, "element {j}");
        }
    }

    #[test]
    fn memory_mode_transparent_and_double_buffering() {
        let mut c = Carus::new(4);
        // Plain SRAM behaviour in memory mode.
        c.bus_write(0x123 & !3, 4, 0xfeed_cafe);
        let (v, p) = c.bus_read(0x120, 4);
        assert_eq!(v, 0xfeed_cafe);
        assert_eq!(p, 0, "no penalty when VPU idle");

        // Start a long kernel, then access memory mid-run: 1-cycle penalty.
        let mut a = Asm::new(0);
        a.li(A0, 1024).vsetvli(T0, A0, Sew::E8).vadd_vx(2, 1, ZERO).vadd_vx(3, 1, ZERO).ebreak();
        c.load_kernel(&a.assemble().unwrap().words);
        start(&mut c);
        for _ in 0..10 {
            c.step();
        }
        assert!(c.vpu.busy());
        let (_, p) = c.bus_read(0x7000, 4);
        assert_eq!(p, 1, "conflict penalty while VPU busy");
        run(&mut c, 100_000);
    }

    #[test]
    fn args_visible_to_kernel() {
        let mut c = Carus::new(4);
        c.set_arg(0, 42);
        // Kernel: a0 = arg0; v0[0] = a0 (e32).
        let mut a = Asm::new(0);
        a.li(A0, 16)
            .vsetvli(T0, A0, Sew::E32)
            .li(A1, ARG_OFFSET as i32)
            .lw(A2, 0, A1)
            .li(A3, 0)
            .emvv(0, A3, A2)
            .ebreak();
        c.load_kernel(&a.assemble().unwrap().words);
        start(&mut c);
        run(&mut c, 10_000);
        assert_eq!(c.vrf.elem_unsigned(0, 0, 16, Sew::E32), 42);
    }

    #[test]
    fn illegal_kernel_flags_done() {
        let mut c = Carus::new(4);
        c.load_kernel(&[0xffff_ffff]);
        start(&mut c);
        run(&mut c, 100);
        assert!(c.irq());
    }

    #[test]
    fn scalar_vector_overlap_hides_index_update() {
        // Fig. 5: scalar instructions execute while the VPU runs. A loop of
        // vmacc + index updates must cost ≈ the vector time alone.
        let mut c = Carus::new(4);
        let mut a = Asm::new(0);
        let n = 8;
        a.li(A0, 1024)
            .vsetvli(T0, A0, Sew::E8)
            .li(A1, 3)
            .li(A2, crate::isa::xvnmc::pack_indexes(20, 8, 0) as i32)
            .li(A3, n)
            .label("loop")
            .vmaccr_vx(A2, A1)
            .addi(A2, A2, 1)
            .addi(A3, A3, -1)
            .bne(A3, ZERO, "loop")
            .ebreak();
        c.load_kernel(&a.assemble().unwrap().words);
        start(&mut c);
        let cycles = run(&mut c, 100_000);
        // Vector time: n × (4 + 64×4) ≈ 2080 minus queue overlap; scalar
        // loop (5 cycles/iter) hides under it. Allow 5 % slack.
        let vec_time = n as u64 * (4 + 64 * 4);
        assert!(
            cycles < vec_time + vec_time / 20 + 20,
            "cycles = {cycles}, vector-only = {vec_time}"
        );
    }
}
