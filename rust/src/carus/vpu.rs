//! NM-Carus Vector Processing Unit (§III-B2).
//!
//! Single-issue vector machine with configurable hardware unrolling
//! (lanes). Pipeline: decode → {arithmetic unit | move/slide unit | CSR
//! unit} → commit, with a two-entry scoreboard (one executing + one queued
//! instruction) so the eCPU can run ahead by one vector instruction.
//!
//! # Timing model
//!
//! Each lane owns one single-port VRF bank and one serial ALU, so the
//! per-word cost of an instruction is the max of the ALU occupancy and the
//! VRF port occupancy (§III-B2: "the throughput of the arithmetic unit is
//! never lower than the slower unit between the ALU and the VRF"):
//!
//! * partitioned 16-bit **adder**: a 32-bit word every 2 cycles, any SEW;
//! * 16-bit **multiplier**: 4×8-bit in 4 cycles, 2×16-bit in 2, 1×32-bit in
//!   3 (three 16-bit passes + accumulation);
//! * `vmacc`: 4 cycles (e8), 3 (e16), 3 (e32) per word ⇒ the paper's
//!   1 / 0.67 / 0.33 MAC/cycle/lane;
//! * elementary **logic**: 1 cycle/word; serial 8-bit barrel **shifter**:
//!   4 cycles/word;
//! * VRF port: `vector_reads(op) + 1` accesses per word.
//!
//! Execution time of an instruction with `W` words on the busiest lane is
//! `ISSUE_OVERHEAD + W_lane · max(alu, vrf)`; back-to-back instructions
//! overlap decode, which is what makes the NM-Carus matmul saturate at
//! 0.48 output/cycle instead of the ideal 0.50 (Fig. 12).

use super::vrf::Vrf;
use crate::isa::xvnmc::{VOp, VSrcKind};
use crate::isa::Sew;
use crate::simd::swar;

/// Fixed per-instruction overhead (decode + commit handshake), partially
/// overlapped for queued instructions.
pub const ISSUE_OVERHEAD: u32 = 4;
/// Scalar↔vector element move cost once the pipeline is empty.
pub const EMV_COST: u32 = 3;

/// Current vector configuration (vtype CSR + vl).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Vtype {
    pub vl: u32,
    pub sew: Sew,
}

impl Vtype {
    /// VLMAX for a SEW under the 32-register architectural view.
    pub fn vlmax(sew: Sew) -> u32 {
        super::vrf::VREG_BYTES / sew.bytes()
    }
}

/// Resolved scalar operand of a vector instruction (GPR values are read at
/// issue time on the eCPU side).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Operand {
    V(u8),
    X(u32),
    I(i32),
}

impl Operand {
    pub fn kind(self) -> VSrcKind {
        match self {
            Operand::V(_) => VSrcKind::Vv,
            Operand::X(_) => VSrcKind::Vx,
            Operand::I(_) => VSrcKind::Vi,
        }
    }
}

/// A fully-resolved vector instruction ready for the execution units.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VecCmd {
    Op { op: VOp, vd: u8, vs2: u8, src: Operand },
    /// emvv: write `value` into element `idx` of `vd`.
    InsertElem { vd: u8, idx: u32, value: u32 },
}

/// VPU activity counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct VpuStats {
    pub instrs: u64,
    pub busy_cycles: u64,
    pub idle_cycles: u64,
    /// Word-granular VRF accesses charged by the timing model.
    pub vrf_reads: u64,
    pub vrf_writes: u64,
    /// Element ops by energy class.
    pub alu_light_elems: u64,
    pub alu_add_elems: u64,
    pub alu_mul_elems: u64,
}

/// The VPU: one executing instruction + one queued (scoreboard of 2).
#[derive(Debug, Clone)]
pub struct Vpu {
    pub lanes: u32,
    pub vt: Vtype,
    exec_remaining: u32,
    /// Destination register of the executing instruction (scoreboard entry).
    exec_vd: Option<u8>,
    queued: Option<VecCmd>,
    pub stats: VpuStats,
}

impl VecCmd {
    /// Destination logical register (scoreboard tracking).
    pub fn vd(&self) -> u8 {
        match *self {
            VecCmd::Op { vd, .. } => vd,
            VecCmd::InsertElem { vd, .. } => vd,
        }
    }
}

impl Vpu {
    pub fn new(lanes: u32) -> Self {
        Vpu {
            lanes,
            vt: Vtype { vl: Vtype::vlmax(Sew::E32), sew: Sew::E32 },
            exec_remaining: 0,
            exec_vd: None,
            queued: None,
            stats: VpuStats::default(),
        }
    }

    /// Scoreboard query: does any in-flight instruction write `r`?
    /// (`emvx` reading `r` must wait; reads of other registers proceed —
    /// the paper's precise-hazard behaviour that lets the eCPU prefetch
    /// scalar operands while unrelated vector instructions drain.)
    pub fn writes_reg_in_flight(&self, r: u8) -> bool {
        (self.exec_remaining > 0 && self.exec_vd == Some(r))
            || self.queued.as_ref().is_some_and(|q| q.vd() == r)
    }

    /// Any instruction in flight?
    pub fn busy(&self) -> bool {
        self.exec_remaining > 0 || self.queued.is_some()
    }

    /// Free slot in the scoreboard?
    pub fn can_accept(&self) -> bool {
        self.queued.is_none()
    }

    /// Pipeline completely drained (required by emvx / vsetvl)?
    pub fn empty(&self) -> bool {
        self.exec_remaining == 0 && self.queued.is_none()
    }

    /// Issue a resolved command. Caller must check [`Vpu::can_accept`].
    /// Functional effects apply when the command starts executing.
    pub fn issue(&mut self, cmd: VecCmd, vrf: &mut Vrf) {
        debug_assert!(self.can_accept());
        if self.exec_remaining == 0 {
            self.start(cmd, vrf);
        } else {
            self.queued = Some(cmd);
        }
    }

    fn start(&mut self, cmd: VecCmd, vrf: &mut Vrf) {
        self.stats.instrs += 1;
        self.exec_vd = Some(cmd.vd());
        let cost = self.apply(cmd, vrf);
        self.exec_remaining = cost;
    }

    /// Advance one cycle.
    #[inline]
    pub fn step(&mut self, vrf: &mut Vrf) {
        if self.exec_remaining > 0 {
            self.stats.busy_cycles += 1;
            self.exec_remaining -= 1;
            if self.exec_remaining == 0 {
                self.exec_vd = None;
                if let Some(cmd) = self.queued.take() {
                    // Queued instruction starts immediately: its decode
                    // overlapped with the tail of the previous one.
                    self.start(cmd, vrf);
                    self.exec_remaining = self.exec_remaining.saturating_sub(2);
                }
            }
        } else {
            self.stats.idle_cycles += 1;
        }
    }

    /// Skip-ahead support (`--timing=event`): number of upcoming cycles
    /// that are *strictly quiet* — pure countdown decrements with no
    /// state transition. An executing instruction with `r` cycles left
    /// yields `r - 1`: the retire cycle itself (scoreboard clear, queued
    /// promotion) must run through [`Vpu::step`]. An idle pipeline has
    /// no self-scheduled event (`u64::MAX`); the queue invariant
    /// (`queued` implies `exec_remaining > 0`) means an idle VPU stays
    /// idle until the eCPU acts.
    pub fn quiet_horizon(&self) -> u64 {
        if self.exec_remaining > 0 {
            u64::from(self.exec_remaining) - 1
        } else {
            u64::MAX
        }
    }

    /// Advance `k` cycles in closed form; exactly equivalent to `k`
    /// calls of [`Vpu::step`] provided `k <= self.quiet_horizon()`.
    pub fn skip(&mut self, k: u64) {
        debug_assert!(k <= self.quiet_horizon(), "skip past a VPU retire");
        if self.exec_remaining > 0 {
            self.stats.busy_cycles += k;
            self.exec_remaining -= k as u32;
        } else {
            self.stats.idle_cycles += k;
        }
    }

    /// Set vtype/vl (CSR unit; caller enforces pipeline-empty).
    /// Returns the granted `vl`.
    pub fn set_vtype(&mut self, avl: u32, sew: Sew) -> u32 {
        let vl = avl.min(Vtype::vlmax(sew));
        self.vt = Vtype { vl, sew };
        vl
    }

    /// Read element `idx` of `vs2` for emvx (caller enforces empty +
    /// charges [`EMV_COST`]).
    pub fn read_elem(&self, vrf: &Vrf, vs2: u8, idx: u32) -> u32 {
        vrf.elem_unsigned(vs2, idx.min(self.vt.vl - 1), self.vt.vl, self.vt.sew)
    }

    /// ALU occupancy per 32-bit word (§III-B2 datapath).
    pub fn alu_cycles_per_word(op: VOp, sew: Sew) -> u32 {
        match op {
            VOp::Add | VOp::Sub | VOp::Min | VOp::Minu | VOp::Max | VOp::Maxu => 2,
            VOp::And | VOp::Or | VOp::Xor => 1,
            VOp::Sll | VOp::Srl | VOp::Sra => 4,
            VOp::Mul => match sew {
                Sew::E8 => 4,
                Sew::E16 => 2,
                Sew::E32 => 3,
            },
            VOp::Macc => match sew {
                Sew::E8 => 4,
                Sew::E16 => 3,
                Sew::E32 => 3,
            },
            VOp::Mv => 1,
            VOp::SlideUp | VOp::SlideDown | VOp::Slide1Up | VOp::Slide1Down => 2,
        }
    }

    /// Total per-word occupancy: max(ALU, VRF single port).
    pub fn cycles_per_word(op: VOp, src: VSrcKind, sew: Sew) -> u32 {
        let vrf = op.vector_reads(src) + 1;
        Self::alu_cycles_per_word(op, sew).max(vrf)
    }

    /// Execution cycles for an element-wise op at the current vtype.
    pub fn op_cost(&self, op: VOp, src: VSrcKind) -> u32 {
        let bytes = self.vt.vl * self.vt.sew.bytes();
        let words = bytes.div_ceil(4);
        let words_per_lane = words.div_ceil(self.lanes);
        ISSUE_OVERHEAD + words_per_lane * Self::cycles_per_word(op, src, self.vt.sew)
    }

    /// Word-level SWAR execution for element-wise ops. Returns false when
    /// the op needs the element loop.
    fn word_fast_path(&self, op: VOp, vd: u8, vs2: u8, src: Operand, vrf: &mut Vrf) -> bool {
        use crate::simd::elem;
        let Vtype { vl, sew } = self.vt;
        let words = vl * sew.bytes() / 4;
        let vd_w = (vd as u32 * vl * sew.bytes()) / 4;
        let vs2_w = (vs2 as u32 * vl * sew.bytes()) / 4;
        // Scalar operand splatted to a word, or a second vector register.
        let (vs1_w, splat): (u32, Option<u32>) = match src {
            Operand::V(v1) => ((v1 as u32 * vl * sew.bytes()) / 4, None),
            Operand::X(x) => (0, Some(elem::splat(x, sew))),
            Operand::I(i) => (0, Some(elem::splat(i as u32, sew))),
        };
        let word_of_src = |vrf: &Vrf, w: u32| splat.unwrap_or_else(|| vrf.word(vs1_w + w));
        match op {
            VOp::Mv => {
                for w in 0..words {
                    let v = word_of_src(vrf, w);
                    vrf.set_word(vd_w + w, v);
                }
                true
            }
            VOp::Add | VOp::Sub | VOp::Mul | VOp::Macc | VOp::And | VOp::Or | VOp::Xor
            | VOp::Min | VOp::Minu | VOp::Max | VOp::Maxu | VOp::Sll | VOp::Srl | VOp::Sra => {
                for w in 0..words {
                    let a = vrf.word(vs2_w + w);
                    let b = word_of_src(vrf, w);
                    let r = match op {
                        VOp::Add => swar::add(a, b, sew),
                        VOp::Sub => swar::sub(a, b, sew),
                        VOp::Mul => swar::mul(a, b, sew),
                        VOp::Macc => swar::mac(vrf.word(vd_w + w), a, b, sew),
                        VOp::And => a & b,
                        VOp::Or => a | b,
                        VOp::Xor => a ^ b,
                        VOp::Min => swar::min_signed(a, b, sew),
                        VOp::Minu => swar::min_unsigned(a, b, sew),
                        VOp::Max => swar::max_signed(a, b, sew),
                        VOp::Maxu => swar::max_unsigned(a, b, sew),
                        VOp::Sll => swar::sll(a, b, sew),
                        VOp::Srl => swar::srl(a, b, sew),
                        VOp::Sra => swar::sra(a, b, sew),
                        _ => unreachable!(),
                    };
                    vrf.set_word(vd_w + w, r);
                }
                true
            }
            // Slides cross word boundaries: element loop.
            VOp::SlideUp | VOp::SlideDown | VOp::Slide1Up | VOp::Slide1Down => false,
        }
    }

    /// Apply a command functionally, count events, return its cost.
    fn apply(&mut self, cmd: VecCmd, vrf: &mut Vrf) -> u32 {
        match cmd {
            VecCmd::InsertElem { vd, idx, value } => {
                let Vtype { vl, sew } = self.vt;
                vrf.set_elem(vd, idx.min(vl - 1), vl, sew, value);
                self.stats.vrf_writes += 1;
                EMV_COST
            }
            VecCmd::Op { op, vd, vs2, src } => {
                let Vtype { vl, sew } = self.vt;
                let words = (vl * sew.bytes()).div_ceil(4) as u64;
                self.stats.vrf_reads += words * op.vector_reads(src.kind()) as u64;
                self.stats.vrf_writes += words;
                let elems = vl as u64;
                match op {
                    VOp::Mul | VOp::Macc => self.stats.alu_mul_elems += elems,
                    VOp::Add | VOp::Sub | VOp::Min | VOp::Minu | VOp::Max | VOp::Maxu => {
                        self.stats.alu_add_elems += elems
                    }
                    _ => self.stats.alu_light_elems += elems,
                }
                self.exec_op(op, vd, vs2, src, vrf);
                self.op_cost(op, src.kind())
            }
        }
    }

    /// Element-wise functional semantics (RVV-style operand order:
    /// `vd[i] = vs2[i] ⊙ src[i]`; `vmacc`: `vd[i] += src · vs2[i]`).
    fn exec_op(&self, op: VOp, vd: u8, vs2: u8, src: Operand, vrf: &mut Vrf) {
        let Vtype { vl, sew } = self.vt;
        // Word-level fast path: when register slices are word-aligned,
        // process 32-bit words through the shared SWAR algebra instead of
        // per-element loops (≈3× on the vmacc hot path; EXPERIMENTS.md
        // §Perf). Falls back to the element loop for slides and unaligned
        // geometries.
        let bytes = vl * sew.bytes();
        if bytes % 4 == 0 && self.word_fast_path(op, vd, vs2, src, vrf) {
            return;
        }
        let sget = |vrf: &Vrf, r: u8, j: u32| vrf.elem_signed(r, j, vl, sew);
        let uget = |vrf: &Vrf, r: u8, j: u32| vrf.elem_unsigned(r, j, vl, sew);
        // Straightforward per-element loop. Scalar operands are truncated
        // to SEW and sign-extended, as the hardware does.
        let scalar_s = |x: u32| -> i32 { crate::isa::sext(x, sew.bits()) };
        let scalar_u = |x: u32| -> u32 {
            match sew {
                Sew::E8 => x & 0xff,
                Sew::E16 => x & 0xffff,
                Sew::E32 => x,
            }
        };
        match op {
            VOp::SlideUp | VOp::SlideDown | VOp::Slide1Up | VOp::Slide1Down => {
                let off = match src {
                    Operand::X(x) => x,
                    Operand::I(i) => i as u32,
                    Operand::V(_) => unreachable!("slides have no vv form"),
                };
                // Read the source fully first (the move/slide unit buffers
                // through the lane ALUs), then write — safe for vd == vs2.
                let snapshot: Vec<u32> = (0..vl).map(|j| uget(vrf, vs2, j)).collect();
                match op {
                    VOp::SlideDown => {
                        for j in 0..vl {
                            let v = snapshot.get((j as usize) + (off as usize)).copied().unwrap_or(0);
                            vrf.set_elem(vd, j, vl, sew, v);
                        }
                    }
                    VOp::SlideUp => {
                        // Elements below `off` keep their old value (RVV).
                        for j in (off.min(vl))..vl {
                            vrf.set_elem(vd, j, vl, sew, snapshot[(j - off) as usize]);
                        }
                    }
                    VOp::Slide1Down => {
                        for j in 0..vl.saturating_sub(1) {
                            vrf.set_elem(vd, j, vl, sew, snapshot[j as usize + 1]);
                        }
                        vrf.set_elem(vd, vl - 1, vl, sew, off);
                    }
                    VOp::Slide1Up => {
                        for j in (1..vl).rev() {
                            vrf.set_elem(vd, j, vl, sew, snapshot[j as usize - 1]);
                        }
                        vrf.set_elem(vd, 0, vl, sew, off);
                    }
                    _ => unreachable!(),
                }
                return;
            }
            VOp::Mv => {
                for j in 0..vl {
                    let v = match src {
                        Operand::V(v1) => uget(vrf, v1, j),
                        Operand::X(x) => scalar_u(x),
                        Operand::I(i) => scalar_u(i as u32),
                    };
                    vrf.set_elem(vd, j, vl, sew, v);
                }
                return;
            }
            _ => {}
        }
        for j in 0..vl {
            let a = sget(vrf, vs2, j); // vs2 element
            let b_s: i32 = match src {
                Operand::V(v1) => sget(vrf, v1, j),
                Operand::X(x) => scalar_s(x),
                Operand::I(i) => i,
            };
            let a_u = uget(vrf, vs2, j);
            let b_u: u32 = match src {
                Operand::V(v1) => uget(vrf, v1, j),
                Operand::X(x) => scalar_u(x),
                Operand::I(i) => scalar_u(i as u32),
            };
            let shamt = b_u & (sew.bits() - 1);
            let r: u32 = match op {
                VOp::Add => (a.wrapping_add(b_s)) as u32,
                VOp::Sub => (a.wrapping_sub(b_s)) as u32,
                VOp::Mul => (a.wrapping_mul(b_s)) as u32,
                VOp::Macc => {
                    let acc = sget(vrf, vd, j);
                    acc.wrapping_add(b_s.wrapping_mul(a)) as u32
                }
                VOp::And => a_u & b_u,
                VOp::Or => a_u | b_u,
                VOp::Xor => a_u ^ b_u,
                VOp::Min => a.min(b_s) as u32,
                VOp::Max => a.max(b_s) as u32,
                VOp::Minu => a_u.min(b_u),
                VOp::Maxu => a_u.max(b_u),
                VOp::Sll => a_u << shamt,
                VOp::Srl => a_u >> shamt,
                VOp::Sra => (a >> shamt) as u32,
                VOp::Mv | VOp::SlideUp | VOp::SlideDown | VOp::Slide1Up | VOp::Slide1Down => {
                    unreachable!()
                }
            };
            vrf.set_elem(vd, j, vl, sew, r);
        }
    }
}

/// Reference semantics used by tests: packed-SIMD word ops must agree with
/// the shared SWAR algebra for whole words.
pub fn word_op_reference(op: VOp, a: u32, b: u32, sew: Sew) -> Option<u32> {
    Some(match op {
        VOp::Add => swar::add(a, b, sew),
        VOp::Sub => swar::sub(a, b, sew),
        VOp::Mul => swar::mul(a, b, sew),
        VOp::And => a & b,
        VOp::Or => a | b,
        VOp::Xor => a ^ b,
        VOp::Min => swar::min_signed(a, b, sew),
        VOp::Max => swar::max_signed(a, b, sew),
        VOp::Minu => swar::min_unsigned(a, b, sew),
        VOp::Maxu => swar::max_unsigned(a, b, sew),
        VOp::Sll => swar::sll(a, b, sew),
        VOp::Srl => swar::srl(a, b, sew),
        VOp::Sra => swar::sra(a, b, sew),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::xvnmc::VSrcKind;

    fn drain(vpu: &mut Vpu, vrf: &mut Vrf) -> u32 {
        let mut cycles = 0;
        while vpu.busy() {
            vpu.step(vrf);
            cycles += 1;
            assert!(cycles < 1_000_000);
        }
        cycles
    }

    #[test]
    fn macc_throughput_matches_paper() {
        // 1 / 0.67 / 0.33 MAC per cycle per lane (§III-B2).
        assert_eq!(Vpu::cycles_per_word(VOp::Macc, VSrcKind::Vx, Sew::E8), 4); // 4 MACs / 4 cyc
        assert_eq!(Vpu::cycles_per_word(VOp::Macc, VSrcKind::Vx, Sew::E16), 3); // 2 / 3
        assert_eq!(Vpu::cycles_per_word(VOp::Macc, VSrcKind::Vx, Sew::E32), 3); // 1 / 3
    }

    #[test]
    fn vrf_port_binds_light_ops() {
        // vadd.vv: ALU needs 2, VRF needs 3 accesses → 3.
        assert_eq!(Vpu::cycles_per_word(VOp::Add, VSrcKind::Vv, Sew::E8), 3);
        // vadd.vx: 2.
        assert_eq!(Vpu::cycles_per_word(VOp::Add, VSrcKind::Vx, Sew::E32), 2);
        // vxor.vv: ALU 1, VRF 3 → 3.
        assert_eq!(Vpu::cycles_per_word(VOp::Xor, VSrcKind::Vv, Sew::E16), 3);
        // vmax.vx: 2 (the ReLU op).
        assert_eq!(Vpu::cycles_per_word(VOp::Max, VSrcKind::Vx, Sew::E8), 2);
        // shifts are shifter-bound: 4.
        assert_eq!(Vpu::cycles_per_word(VOp::Sra, VSrcKind::Vx, Sew::E8), 4);
    }

    #[test]
    fn vadd_vv_functional() {
        let mut vrf = Vrf::new(4);
        let mut vpu = Vpu::new(4);
        let vl = vpu.set_vtype(64, Sew::E16);
        assert_eq!(vl, 64);
        for j in 0..64 {
            vrf.set_elem(1, j, 64, Sew::E16, j + 1);
            vrf.set_elem(2, j, 64, Sew::E16, 1000 + j);
        }
        vpu.issue(VecCmd::Op { op: VOp::Add, vd: 3, vs2: 1, src: Operand::V(2) }, &mut vrf);
        drain(&mut vpu, &mut vrf);
        for j in 0..64 {
            assert_eq!(vrf.elem_signed(3, j, 64, Sew::E16), (j + 1 + 1000 + j) as i32);
        }
    }

    #[test]
    fn vmacc_vx_accumulates() {
        let mut vrf = Vrf::new(4);
        let mut vpu = Vpu::new(4);
        vpu.set_vtype(16, Sew::E32);
        for j in 0..16 {
            vrf.set_elem(0, j, 16, Sew::E32, j); // vs2
            vrf.set_elem(1, j, 16, Sew::E32, 100); // vd (acc)
        }
        vpu.issue(VecCmd::Op { op: VOp::Macc, vd: 1, vs2: 0, src: Operand::X(3) }, &mut vrf);
        drain(&mut vpu, &mut vrf);
        for j in 0..16 {
            assert_eq!(vrf.elem_signed(1, j, 16, Sew::E32), 100 + 3 * j as i32);
        }
    }

    #[test]
    fn cost_model_scales_with_lanes_and_vl() {
        let mut v4 = Vpu::new(4);
        v4.set_vtype(1024, Sew::E8); // 256 words → 64 words/lane
        assert_eq!(v4.op_cost(VOp::Macc, VSrcKind::Vx), ISSUE_OVERHEAD + 64 * 4);
        let mut v8 = Vpu::new(8);
        v8.set_vtype(1024, Sew::E8);
        assert_eq!(v8.op_cost(VOp::Macc, VSrcKind::Vx), ISSUE_OVERHEAD + 32 * 4);
        let mut v1 = Vpu::new(1);
        v1.set_vtype(1024, Sew::E8);
        assert_eq!(v1.op_cost(VOp::Macc, VSrcKind::Vx), ISSUE_OVERHEAD + 256 * 4);
    }

    #[test]
    fn scoreboard_two_in_flight_overlaps_issue() {
        let mut vrf = Vrf::new(4);
        let mut vpu = Vpu::new(4);
        vpu.set_vtype(256, Sew::E8);
        let cmd = VecCmd::Op { op: VOp::Add, vd: 2, vs2: 1, src: Operand::X(1) };
        assert!(vpu.can_accept());
        vpu.issue(cmd, &mut vrf);
        assert!(vpu.busy());
        assert!(vpu.can_accept(), "one more slot");
        vpu.issue(cmd, &mut vrf);
        assert!(!vpu.can_accept());
        let single = vpu.op_cost(VOp::Add, VSrcKind::Vx);
        let total = drain(&mut vpu, &mut vrf);
        // Second instruction saves 2 cycles of issue overhead.
        assert_eq!(total, 2 * single - 2);
    }

    #[test]
    fn slides() {
        let mut vrf = Vrf::new(4);
        let mut vpu = Vpu::new(4);
        vpu.set_vtype(8, Sew::E32);
        for j in 0..8 {
            vrf.set_elem(0, j, 8, Sew::E32, 10 + j);
        }
        // slidedown by 2: vd[j] = vs2[j+2], tail zeros.
        vpu.issue(VecCmd::Op { op: VOp::SlideDown, vd: 1, vs2: 0, src: Operand::X(2) }, &mut vrf);
        drain(&mut vpu, &mut vrf);
        for j in 0..6 {
            assert_eq!(vrf.elem_unsigned(1, j, 8, Sew::E32), 12 + j);
        }
        assert_eq!(vrf.elem_unsigned(1, 6, 8, Sew::E32), 0);
        // slide1up pushes a scalar into element 0.
        vpu.issue(VecCmd::Op { op: VOp::Slide1Up, vd: 2, vs2: 0, src: Operand::X(99) }, &mut vrf);
        drain(&mut vpu, &mut vrf);
        assert_eq!(vrf.elem_unsigned(2, 0, 8, Sew::E32), 99);
        assert_eq!(vrf.elem_unsigned(2, 7, 8, Sew::E32), 16);
        // In-place slidedown (vd == vs2) must use the snapshot.
        vpu.issue(VecCmd::Op { op: VOp::SlideDown, vd: 0, vs2: 0, src: Operand::X(1) }, &mut vrf);
        drain(&mut vpu, &mut vrf);
        assert_eq!(vrf.elem_unsigned(0, 0, 8, Sew::E32), 11);
    }

    #[test]
    fn scalar_truncated_to_sew() {
        let mut vrf = Vrf::new(4);
        let mut vpu = Vpu::new(4);
        vpu.set_vtype(4, Sew::E8);
        for j in 0..4 {
            vrf.set_elem(0, j, 4, Sew::E8, 1);
        }
        // 0x1FF truncates to 0xFF = -1 (signed 8-bit).
        vpu.issue(VecCmd::Op { op: VOp::Add, vd: 1, vs2: 0, src: Operand::X(0x1ff) }, &mut vrf);
        drain(&mut vpu, &mut vrf);
        assert_eq!(vrf.elem_signed(1, 0, 4, Sew::E8), 0);
    }

    #[test]
    fn insert_elem_and_read_elem() {
        let mut vrf = Vrf::new(4);
        let mut vpu = Vpu::new(4);
        vpu.set_vtype(16, Sew::E8);
        vpu.issue(VecCmd::InsertElem { vd: 2, idx: 7, value: 0x5a }, &mut vrf);
        drain(&mut vpu, &mut vrf);
        assert_eq!(vpu.read_elem(&vrf, 2, 7), 0x5a);
    }
}
