//! NM-Carus Vector Register File (§III-B2, Fig. 6).
//!
//! The VRF doubles as the host-visible 32 KiB memory: it is implemented as
//! `lanes` single-port SRAM banks with **word interleaving** — words that
//! are contiguous in the host address space map to adjacent banks
//! (`bank = word_index % lanes`), so the elements with the same index of
//! naturally-aligned vectors land in the same bank and each lane ALU owns
//! exactly one bank.
//!
//! *Logical* vector registers (up to 256, §III-B1) are slices of this
//! space: with the current `vtype = (vl, sew)`, logical register `r` spans
//! bytes `[r·vl·sew, (r+1)·vl·sew)`. The standard 32-register view of the
//! direct-encoded instructions corresponds to `vl = VLMAX` where
//! `VLMAX · sew = 1 KiB` (32 × 1 KiB = 32 KiB).

use crate::isa::Sew;
use crate::mem::{Bank, MacroKind};

/// Total capacity (32 KiB — the drop-in replacement target).
pub const CAPACITY: u32 = 32 * 1024;

/// Architectural vector-register slice when using direct 5-bit encodings.
pub const VREG_BYTES: u32 = CAPACITY / 32;

/// The banked VRF.
#[derive(Debug, Clone)]
pub struct Vrf {
    pub banks: Vec<Bank>,
    pub lanes: u32,
    bank_bytes: u32,
}

impl Vrf {
    /// Build a VRF with `lanes` equal banks (lanes must divide 8 K words).
    pub fn new(lanes: u32) -> Self {
        assert!(lanes.is_power_of_two() && (1..=16).contains(&lanes));
        let bank_bytes = CAPACITY / lanes;
        let kind = match bank_bytes {
            16384 => MacroKind::Sram16k,
            8192 => MacroKind::Sram8k,
            // Smaller banks: account them with the 8 KiB energy constants
            // (conservative; only used in lane-scaling ablations).
            _ => MacroKind::Sram8k,
        };
        let mut banks = Vec::with_capacity(lanes as usize);
        for _ in 0..lanes {
            let mut b = Bank::new(kind);
            if bank_bytes != b.kind.capacity() {
                // Resize via a fresh bank of raw bytes.
                b = Bank::rom(vec![0; bank_bytes as usize]);
            }
            banks.push(b);
        }
        Vrf { banks, lanes, bank_bytes }
    }

    /// (bank, byte-offset-in-bank) of a global byte address.
    #[inline]
    fn locate(&self, byte_addr: u32) -> (usize, u32) {
        let word = (byte_addr / 4) % (CAPACITY / 4);
        let bank = (word % self.lanes) as usize;
        let row = word / self.lanes;
        (bank, row * 4 + byte_addr % 4)
    }

    /// Bank index that holds a global word (the lane that processes it).
    #[inline]
    pub fn bank_of_word(&self, word: u32) -> usize {
        (word % self.lanes) as usize
    }

    // ---- Host-side (bus) access: counted --------------------------------

    pub fn mem_read(&mut self, off: u32, size: u32) -> u32 {
        debug_assert!(off % size == 0);
        let (b, o) = self.locate(off);
        self.banks[b].read(o, size)
    }

    pub fn mem_write(&mut self, off: u32, size: u32, val: u32) {
        debug_assert!(off % size == 0);
        let (b, o) = self.locate(off);
        self.banks[b].write(o, size, val);
    }

    // ---- VPU functional access: NOT counted (the VPU timing model counts
    // word-granular accesses; see `VpuStats`) ------------------------------

    /// Read element `j` of logical register `r` under `(vl, sew)`,
    /// sign-extended.
    pub fn elem_signed(&self, r: u8, j: u32, vl: u32, sew: Sew) -> i32 {
        let addr = self.elem_addr(r, j, vl, sew);
        let (b, o) = self.locate(addr);
        let raw = self.banks[b].peek(o, sew.bytes());
        crate::isa::sext(raw, sew.bits())
    }

    /// Read element zero-extended.
    pub fn elem_unsigned(&self, r: u8, j: u32, vl: u32, sew: Sew) -> u32 {
        let addr = self.elem_addr(r, j, vl, sew);
        let (b, o) = self.locate(addr);
        self.banks[b].peek(o, sew.bytes())
    }

    /// Write element `j` of logical register `r`.
    pub fn set_elem(&mut self, r: u8, j: u32, vl: u32, sew: Sew, v: u32) {
        let addr = self.elem_addr(r, j, vl, sew);
        let (b, o) = self.locate(addr);
        self.banks[b].poke(o, sew.bytes(), v);
    }

    /// Byte address of a logical-register element.
    #[inline]
    pub fn elem_addr(&self, r: u8, j: u32, vl: u32, sew: Sew) -> u32 {
        debug_assert!(j < vl, "element {j} out of range (vl={vl})");
        let base = (r as u32) * vl * sew.bytes();
        let addr = base + j * sew.bytes();
        debug_assert!(
            addr + sew.bytes() <= CAPACITY,
            "logical reg v{r}[{j}] (vl={vl}, {sew}) beyond VRF capacity"
        );
        addr % CAPACITY
    }

    /// Whole-word fast accessors (global word index; non-counting). The
    /// VPU's word-level functional fast path uses these — see
    /// EXPERIMENTS.md §Perf.
    #[inline]
    pub fn word(&self, w: u32) -> u32 {
        let w = w % (CAPACITY / 4);
        self.banks[(w % self.lanes) as usize].peek((w / self.lanes) * 4, 4)
    }
    #[inline]
    pub fn set_word(&mut self, w: u32, v: u32) {
        let w = w % (CAPACITY / 4);
        self.banks[(w % self.lanes) as usize].poke((w / self.lanes) * 4, 4, v);
    }

    /// Non-counting debug/driver accessors at global byte addresses.
    pub fn peek(&self, off: u32, size: u32) -> u32 {
        let (b, o) = self.locate(off);
        self.banks[b].peek(o, size)
    }
    pub fn poke(&mut self, off: u32, size: u32, val: u32) {
        let (b, o) = self.locate(off);
        self.banks[b].poke(o, size, val);
    }
    /// Bulk load at a global byte offset (word-interleave aware).
    pub fn load(&mut self, off: u32, bytes: &[u8]) {
        for (i, &byte) in bytes.iter().enumerate() {
            self.poke(off + i as u32, 1, byte as u32);
        }
    }
    /// Bulk dump.
    pub fn dump(&self, off: u32, len: u32) -> Vec<u8> {
        (0..len).map(|i| self.peek(off + i, 1) as u8).collect()
    }

    pub fn reset_stats(&mut self) {
        for b in &mut self.banks {
            b.reset_stats();
        }
    }

    /// Total counted host accesses (reads, writes) across banks.
    pub fn host_accesses(&self) -> (u64, u64) {
        let mut r = 0;
        let mut w = 0;
        for b in &self.banks {
            r += b.stats.reads;
            w += b.stats.writes;
        }
        (r, w)
    }

    /// Bytes per bank.
    pub fn bank_bytes(&self) -> u32 {
        self.bank_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_interleaving() {
        let v = Vrf::new(4);
        // Consecutive words hit consecutive banks.
        for w in 0..16u32 {
            assert_eq!(v.bank_of_word(w), (w % 4) as usize);
        }
        let mut v = Vrf::new(4);
        v.poke(0, 4, 0x1111_1111);
        v.poke(4, 4, 0x2222_2222);
        v.poke(16, 4, 0x3333_3333);
        // Words 0 and 4 are both bank 0 (16 = word 4, 4 % 4 = 0).
        assert_eq!(v.banks[0].peek(0, 4), 0x1111_1111);
        assert_eq!(v.banks[1].peek(0, 4), 0x2222_2222);
        assert_eq!(v.banks[0].peek(4, 4), 0x3333_3333);
    }

    #[test]
    fn host_view_is_linear() {
        let mut v = Vrf::new(4);
        let data: Vec<u8> = (0..64).map(|i| i as u8).collect();
        v.load(0x100, &data);
        assert_eq!(v.dump(0x100, 64), data);
        // Sub-word host access.
        assert_eq!(v.mem_read(0x100, 1), 0);
        assert_eq!(v.mem_read(0x104, 4), 0x0706_0504);
        let (r, _w) = v.host_accesses();
        assert_eq!(r, 2);
    }

    #[test]
    fn logical_register_slicing() {
        let mut v = Vrf::new(4);
        let (vl, sew) = (256, Sew::E8);
        // reg 3 starts at byte 3*256.
        v.set_elem(3, 0, vl, sew, 0xab);
        assert_eq!(v.peek(768, 1), 0xab);
        v.set_elem(3, 255, vl, sew, 0x7f);
        assert_eq!(v.elem_signed(3, 255, vl, sew), 0x7f);
        // 16-bit elements sign-extend.
        let (vl, sew) = (128, Sew::E16);
        v.set_elem(0, 5, vl, sew, 0xffff);
        assert_eq!(v.elem_signed(0, 5, vl, sew), -1);
        assert_eq!(v.elem_unsigned(0, 5, vl, sew), 0xffff);
    }

    #[test]
    fn vlmax_view_covers_32_regs() {
        let v = Vrf::new(4);
        let sew = Sew::E32;
        let vlmax = VREG_BYTES / sew.bytes(); // 256
        assert_eq!(v.elem_addr(31, vlmax - 1, vlmax, sew), CAPACITY - 4);
    }

    #[test]
    fn lane_scaling_configs() {
        for lanes in [1u32, 2, 4, 8, 16] {
            let v = Vrf::new(lanes);
            assert_eq!(v.banks.len(), lanes as usize);
            assert_eq!(v.bank_bytes() * lanes, CAPACITY);
        }
    }
}
