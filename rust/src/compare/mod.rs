//! State-of-the-art comparison models: BLADE, C-SRAM, Vecim (§V-C).
//!
//! Tables VII and VIII compare NM-Caesar/NM-Carus against three published
//! CIM designs. The paper derives the comparator numbers from the
//! respective articles plus technology-scaling rules (28 nm / 22 nm →
//! 65 nm via SRAM-bitcell scaling factors, best-case for the comparators);
//! we encode those published/scaled values as data (they are measurements
//! of other people's silicon — not something a simulator can reproduce)
//! and compute **our two columns** from the validated microarchitecture
//! models.
//!
//! Throughput conventions (paper footnote e): one MAC = two elementary
//! operations; peak numbers are 8-bit MACs.

use crate::area;
use crate::carus::vpu::Vpu;
use crate::energy::params as ep;
use crate::isa::xvnmc::{VOp, VSrcKind};
use crate::isa::Sew;

/// Nominal NMC clock (the 65 nm post-layout 330 MHz of Table IV).
pub const F_NOM_MHZ: f64 = 330.0;

/// One design's Table VII row.
#[derive(Debug, Clone)]
pub struct SotaRow {
    pub name: &'static str,
    pub cim_type: &'static str,
    pub arrays: &'static str,
    pub bitcell_density_pct: f64,
    pub constraints: &'static str,
    pub technology: &'static str,
    pub area_um2: f64,
    pub freq_mhz: f64,
    pub peak_gops: f64,
    pub gops_per_w: f64,
    pub gops_per_mm2: f64,
}

/// Published + paper-scaled comparator rows (Table VII columns 1–3).
pub fn comparators() -> Vec<SotaRow> {
    vec![
        SotaRow {
            name: "BLADE (28nm)",
            cim_type: "IMC",
            arrays: "16 x 2 KiB",
            bitcell_density_pct: 53.5,
            constraints: "word alignment, local-group placement",
            technology: "28 nm",
            area_um2: 64.0e3,
            freq_mhz: 2200.0,
            peak_gops: 35.2,
            gops_per_w: 830.7,
            gops_per_mm2: 550.0,
        },
        SotaRow {
            name: "BLADE (65nm scaled)",
            cim_type: "IMC",
            arrays: "16 x 2 KiB",
            bitcell_density_pct: 53.5,
            constraints: "word alignment, local-group placement",
            technology: "65 nm (scaled)",
            area_um2: 580.0e3,
            freq_mhz: 330.0,
            peak_gops: 5.3,
            gops_per_w: 254.2,
            gops_per_mm2: 9.1,
        },
        SotaRow {
            name: "C-SRAM (22nm)",
            cim_type: "IMC+NMC",
            arrays: "4 x 8 KiB",
            bitcell_density_pct: 20.3,
            constraints: "word alignment, data replication",
            technology: "22 nm",
            area_um2: 17.5e3,
            freq_mhz: 1000.0,
            peak_gops: 10.7,
            gops_per_w: 52.0,
            gops_per_mm2: 611.0,
        },
        SotaRow {
            name: "C-SRAM (65nm scaled)",
            cim_type: "IMC+NMC",
            arrays: "4 x 8 KiB",
            bitcell_density_pct: 20.3,
            constraints: "word alignment, data replication",
            technology: "65 nm (scaled)",
            area_um2: f64::NAN, // paper: "N/A" (mixed IMC/NMC scaling untrivial)
            freq_mhz: 330.0,
            peak_gops: 3.5,
            gops_per_w: 13.2,
            gops_per_mm2: f64::NAN,
        },
        SotaRow {
            name: "Vecim (65nm)",
            cim_type: "IMC+NMC",
            arrays: "1 x 16 KiB (4 lanes)",
            bitcell_density_pct: 1.7,
            constraints: "vector alignment",
            technology: "65 nm",
            area_um2: 4.0e6,
            freq_mhz: 250.0,
            peak_gops: 31.8,
            gops_per_w: 289.1,
            gops_per_mm2: 8.0,
        },
    ]
}

/// Our NM-Caesar row, computed from the microarchitecture + energy model.
pub fn caesar_row() -> SotaRow {
    // Peak: one packed MAC micro-op (4 8-bit MACs) every 2 cycles.
    let macs_per_cycle = 4.0 / 2.0;
    let peak_gops = macs_per_cycle * 2.0 * F_NOM_MHZ / 1e3;
    // Macro-level power while streaming MACs: 2 bank reads + amortized
    // write + 4 mul-class element ops per 2 cycles + controller.
    let e_per_op = 2.0 * ep::E_SRAM16K_READ + 0.5 * ep::E_SRAM16K_WRITE
        + 4.0 * ep::E_ALU_MUL_ELEM
        + 2.0 * ep::E_CAESAR_CTL_CYCLE;
    let pj_per_cycle = e_per_op / 2.0;
    let gops_per_w = peak_gops / (pj_per_cycle * F_NOM_MHZ * 1e6 / 1e12); // GOPS / W
    let a = area::caesar().total();
    SotaRow {
        name: "NM-Caesar (this work)",
        cim_type: "NMC",
        arrays: "1 x 32 KiB",
        bitcell_density_pct: 54.0,
        constraints: "word alignment",
        technology: "65 nm",
        area_um2: a,
        freq_mhz: F_NOM_MHZ,
        peak_gops,
        gops_per_w,
        gops_per_mm2: peak_gops / (a / 1e6),
    }
}

/// Our NM-Carus row (4 lanes).
pub fn carus_row(lanes: u32) -> SotaRow {
    // Peak: 1 MAC/cycle/lane at 8 bit.
    let peak_gops = lanes as f64 * 2.0 * F_NOM_MHZ / 1e3;
    // Macro-level power: per lane per 4-cycle word step: 3 VRF accesses +
    // 4 mul-class ops, plus VPU control and (amortized) eCPU.
    let e_word = 3.0 * ep::E_SRAM8K_READ + 4.0 * ep::E_ALU_MUL_ELEM;
    let pj_per_cycle =
        lanes as f64 * e_word / 4.0 + ep::E_VPU_CTL_CYCLE + 0.2 * ep::E_ECPU_CYCLE;
    let gops_per_w = peak_gops / (pj_per_cycle * F_NOM_MHZ * 1e6 / 1e12);
    let a = area::carus(lanes).total();
    SotaRow {
        name: "NM-Carus (this work)",
        cim_type: "NMC",
        arrays: "1 x 32 KiB (4 lanes)",
        bitcell_density_pct: 33.0,
        constraints: "vector alignment",
        technology: "65 nm",
        area_um2: a,
        freq_mhz: F_NOM_MHZ,
        peak_gops,
        gops_per_w,
        gops_per_mm2: peak_gops / (a / 1e6),
    }
}

/// Table VIII: matmul A[10,10] × B[10,p] peak comparison.
///
/// Comparator cycle counts are the paper's best-case estimates (data
/// replication and structural hazards neglected); ours follow the validated
/// microarchitectural cost models.
#[derive(Debug, Clone)]
pub struct MatmulPerf {
    pub name: &'static str,
    /// (cycles, energy pJ/MAC) per bitwidth [e8, e16, e32].
    pub cycles: [f64; 3],
    pub pj_per_mac: [f64; 3],
    pub freq_mhz: f64,
}

/// Table VIII workload: p per width (footnotes d/e/f).
pub const T8_P: [u32; 3] = [1024, 512, 256];
const T8_MACS: [f64; 3] = [10.0 * 10.0 * 1024.0, 10.0 * 10.0 * 512.0, 10.0 * 10.0 * 256.0];

pub fn table8_comparators() -> Vec<MatmulPerf> {
    vec![
        MatmulPerf {
            name: "BLADE 16x2KiB (28nm)",
            cycles: [12.8e3, 25.6e3, 51.2e3],
            pj_per_mac: [2.4, 8.1, 31.1],
            freq_mhz: 2200.0,
        },
        MatmulPerf {
            name: "BLADE 16x2KiB (65nm)",
            cycles: [12.8e3, 25.6e3, 51.2e3],
            pj_per_mac: [7.9, 26.7, 103.0],
            freq_mhz: 330.0,
        },
        MatmulPerf {
            name: "BLADE 1x32KiB (28nm)",
            cycles: [204.8e3, 409.6e3, 819.2e3],
            pj_per_mac: [13.0, 29.4, 96.9],
            freq_mhz: 2200.0,
        },
        MatmulPerf {
            name: "BLADE 1x32KiB (65nm)",
            cycles: [204.8e3, 409.6e3, 819.2e3],
            pj_per_mac: [43.0, 97.1, 320.0],
            freq_mhz: 330.0,
        },
        MatmulPerf {
            name: "C-SRAM 8x4KiB (22nm)",
            cycles: [19.2e3, 38.4e3, 76.8e3],
            pj_per_mac: [38.8, 155.0, 621.0],
            freq_mhz: 1000.0,
        },
        MatmulPerf {
            name: "C-SRAM 8x4KiB (65nm)",
            cycles: [19.2e3, 38.4e3, 76.8e3],
            pj_per_mac: [150.0, 600.0, 2400.0],
            freq_mhz: 330.0,
        },
    ]
}

/// Our NM-Caesar Table VIII row: packed `MAC_*` streams, one micro-op per
/// word of the output row per k (2 cycles each).
pub fn table8_caesar() -> MatmulPerf {
    let mut cycles = [0.0; 3];
    let mut pj = [0.0; 3];
    for (i, sew) in [Sew::E8, Sew::E16, Sew::E32].iter().enumerate() {
        let p = T8_P[i];
        let lanes = sew.lanes();
        let chunks = (10 * p).div_ceil(lanes); // output words
        let ops = chunks as f64 * 10.0; // k = 10 per chunk
        cycles[i] = ops * 2.0;
        // Energy per op (macro level), spread over the MACs it performs.
        let e_op = 2.0 * ep::E_SRAM16K_READ + 0.5 * ep::E_SRAM16K_WRITE
            + lanes as f64 * ep::E_ALU_MUL_ELEM
            + 2.0 * ep::E_CAESAR_CTL_CYCLE;
        pj[i] = e_op * ops / T8_MACS[i];
    }
    MatmulPerf { name: "NM-Caesar (this work)", cycles, pj_per_mac: pj, freq_mhz: F_NOM_MHZ }
}

/// Our NM-Carus Table VIII row: the VPU cost model over 10 rows × 10
/// vmacc.vx (plus issue overhead), 4 lanes.
pub fn table8_carus(lanes: u32) -> MatmulPerf {
    let mut cycles = [0.0; 3];
    let mut pj = [0.0; 3];
    for (i, sew) in [Sew::E8, Sew::E16, Sew::E32].iter().enumerate() {
        let p = T8_P[i];
        let words = (p * sew.bytes()).div_ceil(4);
        let wpl = words.div_ceil(lanes);
        let cpw = Vpu::cycles_per_word(VOp::Macc, VSrcKind::Vx, *sew);
        let per_vmacc = (crate::carus::vpu::ISSUE_OVERHEAD + wpl * cpw) as f64;
        // 10 output rows × 10 k-steps, emvx hidden, minus queue overlap.
        cycles[i] = 100.0 * (per_vmacc - 2.0) + 50.0 /* boot + row control */;
        let e_vmacc = words as f64
            * (3.0 * ep::E_SRAM8K_READ + sew.lanes() as f64 * ep::E_ALU_MUL_ELEM)
            + per_vmacc * ep::E_VPU_CTL_CYCLE;
        pj[i] = (100.0 * e_vmacc) / T8_MACS[i];
    }
    MatmulPerf { name: "NM-Carus (this work)", cycles, pj_per_mac: pj, freq_mhz: F_NOM_MHZ }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_throughput_matches_paper() {
        // Paper Table VII: NM-Caesar 1.32 GOPS, NM-Carus 2.64 GOPS.
        assert!((caesar_row().peak_gops - 1.32).abs() < 0.01);
        assert!((carus_row(4).peak_gops - 2.64).abs() < 0.01);
    }

    #[test]
    fn carus_beats_caesar_in_efficiency() {
        // The paper's qualitative ordering (Table VII): NM-Carus peak
        // efficiency above NM-Caesar's.
        assert!(carus_row(4).gops_per_w > caesar_row().gops_per_w);
    }

    #[test]
    fn table8_caesar_cycles_match_paper() {
        // Paper: 51.2e3 cycles at every width.
        let r = table8_caesar();
        for (i, &c) in r.cycles.iter().enumerate() {
            assert!((c - 51.2e3).abs() < 1.0, "width {i}: {c}");
        }
    }

    #[test]
    fn table8_carus_cycles_close_to_paper() {
        // Paper: 26.6e3 / 19.5e3 / 26.0e3. Our model: exact for e8/e16;
        // e32 comes out faster (19.2e3) because our 32-bit MAC costs 3
        // cycles/word vs. the paper's apparent 4 — documented deviation.
        let r = table8_carus(4);
        assert!((r.cycles[0] - 26.6e3).abs() / 26.6e3 < 0.05, "e8: {}", r.cycles[0]);
        assert!((r.cycles[1] - 19.5e3).abs() / 19.5e3 < 0.05, "e16: {}", r.cycles[1]);
        assert!(r.cycles[2] < 27.0e3, "e32: {}", r.cycles[2]);
    }

    #[test]
    fn carus_energy_ordering_vs_comparators_scaled() {
        // Paper: NM-Carus is the most energy-efficient design at 65 nm on
        // 32-bit data (beats BLADE-65 by ≈3×).
        let carus = table8_carus(4);
        let blade65 = &table8_comparators()[1];
        assert!(carus.pj_per_mac[2] < blade65.pj_per_mac[2]);
    }

    #[test]
    fn lane_scaling_monotonic() {
        // Throughput scales ~linearly with lanes; area overhead contained
        // ("a similar performance density is expected from NM-Carus
        // instances with a higher lane count").
        let g4 = carus_row(4);
        let g8 = carus_row(8);
        assert!((g8.peak_gops / g4.peak_gops - 2.0).abs() < 0.01);
        assert!(g8.gops_per_mm2 > g4.gops_per_mm2 * 1.3);
    }
}
