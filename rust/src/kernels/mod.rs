//! Benchmark kernel suite (Table V / Fig. 11 / Fig. 12 workloads).
//!
//! Nine kernels × three element widths × three execution targets:
//!
//! | Kernel | CPU (RV32IMC, -O3 style) | NM-Caesar | NM-Carus |
//! |---|---|---|---|
//! | bitwise XOR | word-packed loop | `XOR` stream | `vxor[r].vv` |
//! | element-wise add | SWAR (8-bit) / scalar | `ADD` stream | `vadd[r].vv` |
//! | element-wise mul | scalar loop | `MUL` stream | `vmul[r].vv` |
//! | matmul A[8,8]×B[8,p] | k-loop MACs | `DOT_*` stream | `vmacc.vx` + `emvx` |
//! | GEMM α(AB)+βC | + scale/add | + `MUL`/`ADD` | + `vmul.vx`/`vadd.vv` |
//! | 2D conv A[8,n]⊛F[f,f] | MAC loops | `DOT_*` on rows | `vmacc.vx` + slides |
//! | ReLU | branchy loop | `MAX` vs 0 | `vmax.vx` |
//! | leaky ReLU (shift slope) | branchy + `sra` | `MAX`+`SLR`-based | `vsra` + `vmax.vv` |
//! | max pooling 2×2/s2 | window loops | `MAX` rows + CPU horiz. | `vmax.vv`+slide+eCPU |
//!
//! Every target runs on the *same* deterministic inputs (seeded generator in
//! [`golden`]) and is checked against the same golden reference — which is
//! itself cross-checked against the AOT-compiled JAX/Pallas artifacts by
//! `rust/tests/golden_runtime.rs`. Output canonical form: little-endian
//! elements of the kernel's SEW, wrapping 2's-complement semantics
//! (accumulations mod 2^sew, matching the packed hardware datapaths).

pub mod caesar;
pub mod cpu;
pub mod carus;
pub mod golden;

use crate::energy::Breakdown;
use crate::isa::Sew;
use crate::soc::{Halt, Soc};
use self::golden::WorkloadData;
use std::any::Any;
use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

/// SoC cycle budget for one kernel run; exceeding it is a hang, not a
/// slow workload (the largest Table V point is two orders of magnitude
/// below this).
pub const SOC_RUN_TIMEOUT: u64 = 200_000_000;

/// The effective cycle budget: [`SOC_RUN_TIMEOUT`] unless overridden by
/// the `SOC_RUN_TIMEOUT` environment variable (every CLI simulation
/// path honors it — useful for deliberately huge workloads, or for
/// tightening the leash when bisecting a hang).
pub fn run_timeout() -> u64 {
    run_timeout_or(SOC_RUN_TIMEOUT)
}

/// [`run_timeout`] with a caller-specific default for paths whose
/// nominal budget is smaller (e.g. the AD application).
pub fn run_timeout_or(default: u64) -> u64 {
    std::env::var("SOC_RUN_TIMEOUT").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Execution target.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Target {
    Cpu,
    Caesar,
    Carus,
}

impl Target {
    pub const ALL: [Target; 3] = [Target::Cpu, Target::Caesar, Target::Carus];
    pub fn name(self) -> &'static str {
        match self {
            Target::Cpu => "CPU (RV32IMC)",
            Target::Caesar => "NM-Caesar",
            Target::Carus => "NM-Carus",
        }
    }

    /// Parse a CLI spelling (`cpu`, `caesar`, `carus`).
    pub fn parse(s: &str) -> Option<Target> {
        match s.to_ascii_lowercase().as_str() {
            "cpu" => Some(Target::Cpu),
            "caesar" | "nm-caesar" => Some(Target::Caesar),
            "carus" | "nm-carus" => Some(Target::Carus),
            _ => None,
        }
    }
}

/// Kernel + shape. Sizes are free parameters; [`Kernel::paper_default`]
/// yields the Table V footnote sizes for a given target.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kernel {
    /// Element-wise bitwise XOR over `n` elements.
    Xor { n: u32 },
    /// Element-wise addition.
    Add { n: u32 },
    /// Element-wise multiplication.
    Mul { n: u32 },
    /// A[8,8] × B[8,p] (row-major B, accumulate mod 2^sew).
    Matmul { p: u32 },
    /// α(A[8,8]×B[8,p]) + βC[8,p] with α=2, β=3.
    Gemm { p: u32 },
    /// Valid 2D convolution A[8,n] ⊛ F[f,f].
    Conv2d { n: u32, f: u32 },
    /// max(x, 0) over `n` elements.
    Relu { n: u32 },
    /// x ≥ 0 ? x : x >> 3 (slope 1/8, §V footnote f).
    LeakyRelu { n: u32 },
    /// 2×2 max pooling, stride 2, over a 16-row × `n`-col image.
    Maxpool { n: u32 },
}

/// Kernel families (size-independent identity, used by the harness).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    Xor,
    Add,
    Mul,
    Matmul,
    Gemm,
    Conv2d,
    Relu,
    LeakyRelu,
    Maxpool,
}

impl Family {
    pub const ALL: [Family; 9] = [
        Family::Xor,
        Family::Add,
        Family::Mul,
        Family::Matmul,
        Family::Gemm,
        Family::Conv2d,
        Family::Relu,
        Family::LeakyRelu,
        Family::Maxpool,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Family::Xor => "Bitwise XOR",
            Family::Add => "Element-wise addition",
            Family::Mul => "Element-wise multiplication",
            Family::Matmul => "Matrix multiplication",
            Family::Gemm => "GEMM",
            Family::Conv2d => "2D convolution",
            Family::Relu => "ReLU",
            Family::LeakyRelu => "Leaky ReLU",
            Family::Maxpool => "Max pooling",
        }
    }

    /// Parse a CLI spelling (`xor`, `add`, `mul`, `matmul`, `gemm`,
    /// `conv2d`, `relu`, `leakyrelu`, `maxpool`).
    pub fn parse(s: &str) -> Option<Family> {
        match s.to_ascii_lowercase().as_str() {
            "xor" => Some(Family::Xor),
            "add" => Some(Family::Add),
            "mul" => Some(Family::Mul),
            "matmul" => Some(Family::Matmul),
            "gemm" => Some(Family::Gemm),
            "conv2d" | "conv" => Some(Family::Conv2d),
            "relu" => Some(Family::Relu),
            "leakyrelu" | "leaky-relu" | "leaky_relu" => Some(Family::LeakyRelu),
            "maxpool" => Some(Family::Maxpool),
            _ => None,
        }
    }
}

impl Kernel {
    pub fn family(self) -> Family {
        match self {
            Kernel::Xor { .. } => Family::Xor,
            Kernel::Add { .. } => Family::Add,
            Kernel::Mul { .. } => Family::Mul,
            Kernel::Matmul { .. } => Family::Matmul,
            Kernel::Gemm { .. } => Family::Gemm,
            Kernel::Conv2d { .. } => Family::Conv2d,
            Kernel::Relu { .. } => Family::Relu,
            Kernel::LeakyRelu { .. } => Family::LeakyRelu,
            Kernel::Maxpool { .. } => Family::Maxpool,
        }
    }

    /// The paper's Table V footnote sizes for (family, target, sew).
    pub fn paper_default(family: Family, target: Target, sew: Sew) -> Kernel {
        let small = target == Target::Caesar;
        match family {
            // footnote a: 8 KiB (Caesar) / 10 KiB (CPU, Carus) of input,
            // split across the two operands.
            Family::Xor | Family::Add | Family::Mul => {
                let total_bytes = if small { 8 * 1024 } else { 10 * 1024 };
                let n = total_bytes / 2 / sew.bytes();
                match family {
                    Family::Xor => Kernel::Xor { n },
                    Family::Add => Kernel::Add { n },
                    _ => Kernel::Mul { n },
                }
            }
            // footnote b/c: p = {128,256,512} (Caesar), {256,512,1024}
            // (CPU/Carus) for {32,16,8} bits.
            Family::Matmul | Family::Gemm => {
                let p = match (small, sew) {
                    (true, Sew::E32) => 128,
                    (true, Sew::E16) => 256,
                    (true, Sew::E8) => 512,
                    (false, Sew::E32) => 256,
                    (false, Sew::E16) => 512,
                    (false, Sew::E8) => 1024,
                };
                if family == Family::Matmul {
                    Kernel::Matmul { p }
                } else {
                    Kernel::Gemm { p }
                }
            }
            // footnote d: n={64,64,128}, f={3,4,4} (Caesar);
            // n={256,512,1024}, f=3 (CPU/Carus) for {32,16,8} bits.
            Family::Conv2d => {
                let (n, f) = match (small, sew) {
                    (true, Sew::E32) => (64, 3),
                    (true, Sew::E16) => (64, 4),
                    (true, Sew::E8) => (128, 4),
                    (false, Sew::E32) => (256, 3),
                    (false, Sew::E16) => (512, 3),
                    (false, Sew::E8) => (1024, 3),
                };
                Kernel::Conv2d { n, f }
            }
            // footnote e: 8 KiB (Caesar) / 16 KiB (CPU, Carus).
            Family::Relu | Family::LeakyRelu => {
                let n = if small { 8 * 1024 } else { 16 * 1024 } / sew.bytes();
                if family == Family::Relu {
                    Kernel::Relu { n }
                } else {
                    Kernel::LeakyRelu { n }
                }
            }
            // footnote g: 8 KiB (Caesar) / 16 KiB (CPU, Carus); 16 rows.
            Family::Maxpool => {
                let bytes = if small { 8 * 1024 } else { 16 * 1024 };
                Kernel::Maxpool { n: bytes / 16 / sew.bytes() }
            }
        }
    }

    /// Build a kernel of `family` with explicit free dimensions, falling
    /// back to the paper's Table V shape for `(target, sew)` for any
    /// dimension not given — the CLI `sweep` entry point for arbitrary,
    /// non-paper workload shapes.
    pub fn with_shape(
        family: Family,
        target: Target,
        sew: Sew,
        n: Option<u32>,
        p: Option<u32>,
        f: Option<u32>,
    ) -> Kernel {
        match Kernel::paper_default(family, target, sew) {
            Kernel::Xor { n: dn } => Kernel::Xor { n: n.unwrap_or(dn) },
            Kernel::Add { n: dn } => Kernel::Add { n: n.unwrap_or(dn) },
            Kernel::Mul { n: dn } => Kernel::Mul { n: n.unwrap_or(dn) },
            Kernel::Matmul { p: dp } => Kernel::Matmul { p: p.unwrap_or(dp) },
            Kernel::Gemm { p: dp } => Kernel::Gemm { p: p.unwrap_or(dp) },
            Kernel::Conv2d { n: dn, f: df } => {
                Kernel::Conv2d { n: n.unwrap_or(dn), f: f.unwrap_or(df) }
            }
            Kernel::Relu { n: dn } => Kernel::Relu { n: n.unwrap_or(dn) },
            Kernel::LeakyRelu { n: dn } => Kernel::LeakyRelu { n: n.unwrap_or(dn) },
            Kernel::Maxpool { n: dn } => Kernel::Maxpool { n: n.unwrap_or(dn) },
        }
    }

    /// Validate a scenario against `target`'s staging envelope, so an
    /// impossible CLI shape becomes an error message instead of a panic
    /// deep inside an engine. Encodes the same limits the engines assert
    /// (which remain as backstops): word-aligned operand staging, the
    /// 8-row matrix layout, NM-Caesar's bank regions, and NM-Carus's
    /// 1 KiB logical registers.
    pub fn validate(self, target: Target, sew: Sew) -> Result<(), String> {
        use crate::bus::BANK_SIZE;
        let sb = sew.bytes();
        match self {
            Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n } => {
                let bytes = n * sb;
                if n == 0 || bytes % 4 != 0 {
                    return Err(format!("n = {n} must be positive and word-aligned at {sew}"));
                }
                // Per-operand staging regions: one SRAM bank (CPU), the
                // 2048-word NM-Caesar src region, NM-Carus v0..v9.
                let limit = match target {
                    Target::Cpu => BANK_SIZE,
                    Target::Caesar => 8 * 1024,
                    Target::Carus => 10 * 1024,
                };
                if bytes > limit {
                    return Err(format!("n = {n} exceeds {target:?} capacity ({limit} B per operand)"));
                }
            }
            Kernel::Relu { n } | Kernel::LeakyRelu { n } => {
                let bytes = n * sb;
                if n == 0 || bytes % 4 != 0 {
                    return Err(format!("n = {n} must be positive and word-aligned at {sew}"));
                }
                // In-place regions: bank (CPU), NM-Caesar bank 0, v0..v15.
                let limit = match target {
                    Target::Cpu => BANK_SIZE,
                    Target::Caesar | Target::Carus => 16 * 1024,
                };
                if bytes > limit {
                    return Err(format!("n = {n} exceeds {target:?} capacity ({limit} B)"));
                }
            }
            Kernel::Matmul { p } | Kernel::Gemm { p } => {
                let row_bytes = p * sb;
                if p == 0 || row_bytes % 4 != 0 {
                    return Err(format!("p = {p} must be positive and word-aligned at {sew}"));
                }
                match target {
                    // B = 8 rows of p elements in one bank.
                    Target::Cpu if 8 * row_bytes > BANK_SIZE => {
                        return Err(format!("p = {p} exceeds the CPU bank (8·p·sew ≤ {BANK_SIZE} B)"));
                    }
                    Target::Caesar => {
                        // GEMM shares bank 1 with the C rows and α-splat
                        // (B region ends at MM_C = word 5120 ⇒ 512 B
                        // rows); plain matmul only needs B below the bank
                        // end and OUT below MM area of bank 0 (the Fig. 12
                        // saturation point p = 1024 at 8 bit is valid).
                        let limit = if matches!(self, Kernel::Gemm { .. }) { 512 } else { 2016 };
                        if row_bytes > limit {
                            return Err(format!(
                                "p = {p} exceeds NM-Caesar's B region (p·sew ≤ {limit} B)"
                            ));
                        }
                    }
                    // vl = p: the row must fill ≥ the 8-element A columns
                    // and fit one 1 KiB logical register.
                    Target::Carus if p < 8 || row_bytes > 1024 => {
                        return Err(format!("p = {p} out of NM-Carus range (8 ≤ p, p·sew ≤ 1024 B)"));
                    }
                    _ => {}
                }
            }
            Kernel::Conv2d { n, f } => {
                if n == 0 || f == 0 || f > 8 || f > n {
                    return Err(format!("conv2d needs 0 < f ≤ 8 and f ≤ n (got n = {n}, f = {f})"));
                }
                let row_bytes = n * sb;
                match target {
                    Target::Cpu if 8 * row_bytes > BANK_SIZE => {
                        return Err(format!("n = {n} exceeds the CPU bank (8·n·sew ≤ {BANK_SIZE} B)"));
                    }
                    Target::Caesar => {
                        // Element-shifted image copies must fit bank 0.
                        let copy_words = 8 * (row_bytes.div_ceil(4) + 1);
                        if sew.lanes() * copy_words > 4096 {
                            return Err(format!(
                                "n = {n} exceeds NM-Caesar's shifted-copy region at {sew}"
                            ));
                        }
                        // f·f filter splat words must stay below the conv
                        // output region (CV_OUT − CV_FSPLAT = 32 words).
                        if f * f > 32 {
                            return Err(format!(
                                "f = {f} exceeds NM-Caesar's filter-splat region (f·f ≤ 32)"
                            ));
                        }
                    }
                    Target::Carus if row_bytes > 1024 => {
                        return Err(format!("n = {n} exceeds an NM-Carus register (n·sew ≤ 1024 B)"));
                    }
                    _ => {}
                }
            }
            Kernel::Maxpool { n } => {
                let row_bytes = n * sb;
                if n == 0 || n % 2 != 0 || row_bytes % 4 != 0 {
                    return Err(format!("n = {n} must be positive, even, and word-aligned at {sew}"));
                }
                let limit = match target {
                    // 16 image rows in one bank.
                    Target::Cpu => BANK_SIZE / 16,
                    // 8 even/odd rows below the vmax region / one register.
                    Target::Caesar | Target::Carus => 1024,
                };
                if row_bytes > limit {
                    return Err(format!("n = {n} exceeds {target:?} capacity (n·sew ≤ {limit} B)"));
                }
            }
        }
        Ok(())
    }

    /// Number of output elements (the "output" of cycles/output).
    pub fn outputs(self) -> u64 {
        match self {
            Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n } => n as u64,
            Kernel::Matmul { p } | Kernel::Gemm { p } => 8 * p as u64,
            Kernel::Conv2d { n, f } => (8 - f as u64 + 1) * (n as u64 - f as u64 + 1),
            Kernel::Relu { n } | Kernel::LeakyRelu { n } => n as u64,
            Kernel::Maxpool { n } => 8 * (n as u64 / 2),
        }
    }
}

/// Result of one kernel run on one target.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub kernel: Kernel,
    pub sew: Sew,
    pub target: Target,
    /// Cycles of the measured region (kernel only, like the paper).
    pub cycles: u64,
    /// Output elements produced.
    pub outputs: u64,
    /// Energy of the measured region.
    pub energy: Breakdown,
    /// Canonical output bytes (little-endian sew elements).
    pub output: Vec<u8>,
    /// Full activity (Fig. 13 power breakdowns).
    pub activity: crate::energy::Activity,
}

impl RunResult {
    pub fn cycles_per_output(&self) -> f64 {
        self.cycles as f64 / self.outputs as f64
    }
    pub fn energy_per_output_pj(&self) -> f64 {
        self.energy.total() / self.outputs as f64
    }
    /// Average power in mW.
    pub fn avg_power_mw(&self) -> f64 {
        self.energy.avg_power_mw(self.cycles)
    }
}

// ---------------------------------------------------------------------------
// Engine layer: firmware assembly separated from execution
// ---------------------------------------------------------------------------

/// A fully-assembled, data-independent program for one engine: everything
/// derivable from `(kernel, sew)` alone — host firmware, micro-op streams,
/// eCPU binaries. Produced by [`Engine::prepare`], cached process-wide by
/// [`prepared`], consumed (any number of times) by [`Engine::execute`].
///
/// The payload is engine-private: each engine stores whatever its driver
/// needs and downcasts it back in `execute`, so new near-memory backends
/// can plug in without touching this type.
pub struct EngineProgram {
    pub target: Target,
    pub kernel: Kernel,
    pub sew: Sew,
    payload: Box<dyn Any + Send + Sync>,
}

impl EngineProgram {
    /// Wrap an engine-private payload.
    pub fn new(
        target: Target,
        kernel: Kernel,
        sew: Sew,
        payload: impl Any + Send + Sync,
    ) -> Self {
        EngineProgram { target, kernel, sew, payload: Box::new(payload) }
    }

    /// Recover the engine-private payload; panics if `self` was prepared
    /// by a different engine (a caller bug, not a data error).
    pub fn payload<T: 'static>(&self) -> &T {
        self.payload
            .downcast_ref::<T>()
            .unwrap_or_else(|| panic!("{:?} program handed to the wrong engine", self.target))
    }
}

/// How an NMC tile executes a staged workload (the scale-out seam used by
/// [`crate::sched`]).
pub enum TileExec {
    /// The tile computes autonomously after `CTL_START` (NM-Carus): the
    /// host starts the kernel through the tile's control register and
    /// polls the tile's status peripheral register, free to stage the
    /// next tile meanwhile.
    Autonomous,
    /// Execution *is* a DMA micro-op stream (NM-Caesar): the compiled
    /// program is rendered against each tile's bus window
    /// ([`crate::caesar::compiler::CaesarProgram::to_stream`]) and issued
    /// in `CaesarStream` mode while the tile's mode pin is high — which
    /// occupies the single DMA for the whole execution.
    Stream(crate::caesar::compiler::CaesarProgram),
}

/// Data-independent tile recipe for one `(kernel, sew)`: what the batch
/// scheduler uploads once per tile, and how the tile then executes.
pub struct TileProgram {
    /// Setup image DMA'd to the tile window in configuration mode (the
    /// NM-Carus eCPU kernel binary; empty for NM-Caesar).
    pub setup_image: Vec<u8>,
    /// Argument words written to the tile's eMEM ABI slots (NM-Carus).
    pub args: Vec<u32>,
    pub exec: TileExec,
}

/// Per-workload staging descriptor: input byte images DMA'd into the tile
/// window before execution, and the raw output span DMA'd back after.
/// All offsets and lengths are word-aligned (DMA granularity).
pub struct TileIo {
    /// (window offset, bytes) input regions.
    pub inputs: Vec<(u32, Vec<u8>)>,
    /// (window offset, byte length) of the raw output span; canonicalized
    /// by [`Engine::tile_extract`].
    pub output: (u32, u32),
}

/// An execution backend: one simulated system that can run the kernel
/// grid. `prepare` assembles everything that depends only on the workload
/// *shape*; `execute` stages one concrete [`WorkloadData`], simulates, and
/// extracts the canonical output. The split is what makes program caching
/// ([`prepared`]) and result memoization ([`crate::sweep::SweepSession`])
/// possible — and it is the seam new near-memory targets plug into.
///
/// The `tile_*` methods are the **tiled execute path**: instead of owning
/// a whole fresh SoC, the engine describes how its kernel runs behind one
/// tile window of a multi-tile system, and [`crate::sched`] drives any
/// number of such tiles from one host. Backends that cannot sit behind a
/// tile window (the CPU engine *is* the host) keep the `None` defaults.
pub trait Engine: Send + Sync {
    /// The target identity this engine simulates (carried into every
    /// [`RunResult`] it produces).
    fn target(&self) -> Target;
    /// Assemble the data-independent program for `(kernel, sew)`.
    fn prepare(&self, kernel: Kernel, sew: Sew) -> EngineProgram;
    /// Build a fresh SoC, stage `data`, run `prog`, extract the output.
    fn execute(&self, prog: &EngineProgram, data: &WorkloadData) -> RunResult;
    /// Tile recipe for `(kernel, sew)`, or `None` if this backend cannot
    /// run the kernel behind a tile window (both built-in NMC engines
    /// tile every kernel; the CPU engine *is* the host).
    fn tile_program(&self, _kernel: Kernel, _sew: Sew) -> Option<TileProgram> {
        None
    }
    /// Per-workload staging descriptor; `Some` exactly when
    /// [`Engine::tile_program`] is.
    fn tile_io(&self, _kernel: Kernel, _sew: Sew, _data: &WorkloadData) -> Option<TileIo> {
        None
    }
    /// Canonicalize the raw output span dumped from a tile window (strip
    /// row padding, pick packed sub-rows, …). Identity by default.
    fn tile_extract(&self, _kernel: Kernel, _sew: Sew, span: &[u8]) -> Vec<u8> {
        span.to_vec()
    }
}

/// The engine registry: every built-in execution backend.
pub fn engines() -> [&'static dyn Engine; 3] {
    [&cpu::CpuEngine, &caesar::CaesarEngine, &carus::CarusEngine]
}

/// Look up the engine for a target.
pub fn engine(target: Target) -> &'static dyn Engine {
    match target {
        Target::Cpu => &cpu::CpuEngine,
        Target::Caesar => &caesar::CaesarEngine,
        Target::Carus => &carus::CarusEngine,
    }
}

type ProgramKey = (Target, Kernel, Sew);

/// The prepared-program cache is read-mostly: after warm-up, the serve
/// worker pool hits it from every worker on every batch, so warm hits
/// take a shared `read` lock and run concurrently — only a cold miss
/// takes the `write` lock, briefly, to insert.
fn program_cache() -> &'static RwLock<HashMap<ProgramKey, Arc<EngineProgram>>> {
    static CACHE: OnceLock<RwLock<HashMap<ProgramKey, Arc<EngineProgram>>>> = OnceLock::new();
    CACHE.get_or_init(Default::default)
}

/// Memoized [`Engine::prepare`]: each `(target, family, shape, sew)`
/// program is assembled exactly once per process, no matter how many
/// sweep points, report threads, or serve workers consume it.
pub fn prepared(target: Target, kernel: Kernel, sew: Sew) -> Arc<EngineProgram> {
    let key = (target, kernel, sew);
    if let Some(p) = program_cache().read().expect("program cache poisoned").get(&key) {
        return Arc::clone(p);
    }
    // Assemble outside any lock (it is pure); a racing thread may do the
    // same work once more, but the first insert wins and both share it.
    let prog = Arc::new(engine(target).prepare(kernel, sew));
    Arc::clone(
        program_cache()
            .write()
            .expect("program cache poisoned")
            .entry(key)
            .or_insert(prog),
    )
}

/// Run a kernel on a target with seeded inputs; panics on a functional
/// mismatch against the golden reference (the simulator is expected to be
/// bit-exact). Firmware assembly is served from the [`prepared`] cache;
/// the simulation itself always runs (memoize *results* with
/// [`crate::sweep::SweepSession`]).
pub fn run(target: Target, kernel: Kernel, sew: Sew, seed: u64) -> RunResult {
    let data = golden::generate(kernel, sew, seed);
    let prog = prepared(target, kernel, sew);
    let res = engine(target).execute(&prog, &data);
    assert_eq!(
        res.output, data.expect,
        "{target:?} {kernel:?} {sew} output mismatch vs golden reference"
    );
    res
}

/// Common driver plumbing shared by the three engines. The engine passes
/// its own target identity — a `RunResult` is born labeled, there is no
/// placeholder to overwrite.
pub(crate) fn finish_run(
    soc: &mut Soc,
    halt: Halt,
    target: Target,
    kernel: Kernel,
    sew: Sew,
) -> RunResult {
    assert_eq!(
        halt,
        Halt::Done,
        "{target:?} {kernel:?} {sew} did not complete: {halt:?} after {} cycles (budget {}; \
         raise SOC_RUN_TIMEOUT to extend)",
        soc.cycle,
        run_timeout()
    );
    RunResult {
        kernel,
        sew,
        target,
        cycles: soc.cycle,
        outputs: kernel.outputs(),
        energy: soc.energy(),
        output: Vec::new(),
        activity: soc.activity(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warm_cache_hits_do_not_serialize_concurrent_readers() {
        // The serve worker pool hits `prepared` from every worker on
        // every batch; a warm hit must be a shared `read` lock, not an
        // exclusive one. Each thread holds its cache read guard open at a
        // rendezvous until every thread has arrived — possible only if
        // all the guards coexist. Under the old `Mutex` cache the readers
        // would serialize, at most one could reach the rendezvous at a
        // time, and no attempt could ever succeed. A cold miss from an
        // unrelated concurrently-running test can queue a writer and
        // legitimately stall one attempt, so the rendezvous is retried.
        use std::sync::{Condvar, Mutex};
        use std::time::Duration;
        const READERS: usize = 4;
        prepared(Target::Cpu, Kernel::Add { n: 64 }, Sew::E32); // warm the key
        let attempt = || {
            let arrived = Mutex::new(0usize);
            let cv = Condvar::new();
            std::thread::scope(|s| {
                let (arrived, cv) = (&arrived, &cv);
                let handles: Vec<_> = (0..READERS)
                    .map(|_| {
                        s.spawn(move || {
                            let cache = program_cache().read().expect("cache poisoned");
                            assert!(cache.contains_key(&(
                                Target::Cpu,
                                Kernel::Add { n: 64 },
                                Sew::E32
                            )));
                            let mut n = arrived.lock().unwrap();
                            *n += 1;
                            cv.notify_all();
                            let mut timed_out = false;
                            while *n < READERS && !timed_out {
                                let (g, t) =
                                    cv.wait_timeout(n, Duration::from_millis(200)).unwrap();
                                n = g;
                                timed_out = t.timed_out();
                            }
                            // The cache read guard is still held here;
                            // seeing every other reader arrive proves the
                            // guards overlapped.
                            let all_overlapped = *n == READERS;
                            drop(n);
                            drop(cache);
                            all_overlapped
                        })
                    })
                    .collect();
                handles.into_iter().all(|h| h.join().expect("reader thread"))
            })
        };
        assert!(
            (0..20).any(|_| attempt()),
            "concurrent warm-cache readers serialized (cache lock is exclusive?)"
        );
    }

    #[test]
    fn paper_default_sizes() {
        // Matmul p per footnote b.
        assert_eq!(
            Kernel::paper_default(Family::Matmul, Target::Carus, Sew::E8),
            Kernel::Matmul { p: 1024 }
        );
        assert_eq!(
            Kernel::paper_default(Family::Matmul, Target::Caesar, Sew::E32),
            Kernel::Matmul { p: 128 }
        );
        // Element-wise input sizes: 10 KiB → 5120 e8 elements per operand.
        assert_eq!(Kernel::paper_default(Family::Add, Target::Cpu, Sew::E8), Kernel::Add { n: 5120 });
        assert_eq!(
            Kernel::paper_default(Family::Relu, Target::Carus, Sew::E16),
            Kernel::Relu { n: 8192 }
        );
        // Conv2d shapes.
        assert_eq!(
            Kernel::paper_default(Family::Conv2d, Target::Caesar, Sew::E8),
            Kernel::Conv2d { n: 128, f: 4 }
        );
    }

    #[test]
    fn output_counts() {
        assert_eq!(Kernel::Matmul { p: 512 }.outputs(), 8 * 512);
        assert_eq!(Kernel::Conv2d { n: 256, f: 3 }.outputs(), 6 * 254);
        assert_eq!(Kernel::Maxpool { n: 512 }.outputs(), 8 * 256);
    }

    #[test]
    fn with_shape_overrides_and_defaults() {
        // Explicit dimension wins.
        assert_eq!(
            Kernel::with_shape(Family::Matmul, Target::Carus, Sew::E8, None, Some(96), None),
            Kernel::Matmul { p: 96 }
        );
        // Missing dimensions fall back to the paper shape per (target, sew).
        assert_eq!(
            Kernel::with_shape(Family::Matmul, Target::Carus, Sew::E8, None, None, None),
            Kernel::paper_default(Family::Matmul, Target::Carus, Sew::E8)
        );
        // Conv2d mixes: explicit f, paper n.
        assert_eq!(
            Kernel::with_shape(Family::Conv2d, Target::Cpu, Sew::E16, None, None, Some(5)),
            Kernel::Conv2d { n: 512, f: 5 }
        );
        // n applies to the element-wise families; p/f are ignored there.
        assert_eq!(
            Kernel::with_shape(Family::Relu, Target::Cpu, Sew::E8, Some(64), Some(7), Some(7)),
            Kernel::Relu { n: 64 }
        );
    }

    #[test]
    fn validate_rejects_impossible_shapes() {
        // Every paper-default grid point is valid on its own target.
        for family in Family::ALL {
            for target in Target::ALL {
                for sew in Sew::ALL {
                    let k = Kernel::paper_default(family, target, sew);
                    assert_eq!(k.validate(target, sew), Ok(()), "{family:?} {target:?} {sew}");
                }
            }
        }
        // Filter larger than the 8-row image: would underflow `8 - f + 1`.
        assert!(Kernel::Conv2d { n: 64, f: 12 }.validate(Target::Cpu, Sew::E8).is_err());
        // NM-Caesar's filter-splat region holds 32 words: f = 5 fits,
        // f = 6 would overrun into the conv output region.
        assert!(Kernel::Conv2d { n: 128, f: 5 }.validate(Target::Caesar, Sew::E8).is_ok());
        assert!(Kernel::Conv2d { n: 128, f: 6 }.validate(Target::Caesar, Sew::E8).is_err());
        // NM-Carus B row must fit a 1 KiB logical register.
        assert!(Kernel::Matmul { p: 1024 }.validate(Target::Carus, Sew::E32).is_err());
        assert!(Kernel::Matmul { p: 4 }.validate(Target::Carus, Sew::E8).is_err());
        // NM-Caesar: the Fig. 12 saturation matmul (p = 1024, 8-bit) is
        // valid — only GEMM shares bank 1 with C and tightens to 512 B.
        assert!(Kernel::Matmul { p: 1024 }.validate(Target::Caesar, Sew::E8).is_ok());
        assert!(Kernel::Gemm { p: 1024 }.validate(Target::Caesar, Sew::E8).is_err());
        assert!(Kernel::Gemm { p: 512 }.validate(Target::Caesar, Sew::E8).is_ok());
        // Misaligned element-wise staging.
        assert!(Kernel::Add { n: 129 }.validate(Target::Cpu, Sew::E8).is_err());
        // Odd maxpool width has no 2x2 tiling.
        assert!(Kernel::Maxpool { n: 30 }.validate(Target::Cpu, Sew::E16).is_ok());
        assert!(Kernel::Maxpool { n: 31 }.validate(Target::Cpu, Sew::E16).is_err());
        // Zero-sized workloads are rejected everywhere.
        assert!(Kernel::Relu { n: 0 }.validate(Target::Caesar, Sew::E32).is_err());
    }

    #[test]
    fn cli_spellings_parse() {
        assert_eq!(Target::parse("carus"), Some(Target::Carus));
        assert_eq!(Target::parse("NM-Caesar"), Some(Target::Caesar));
        assert_eq!(Target::parse("gpu"), None);
        assert_eq!(Family::parse("leakyrelu"), Some(Family::LeakyRelu));
        assert_eq!(Family::parse("conv2d"), Some(Family::Conv2d));
        assert_eq!(Family::parse("fft"), None);
    }

    #[test]
    fn registry_covers_every_target_with_matching_identity() {
        for (i, target) in Target::ALL.iter().enumerate() {
            assert_eq!(engines()[i].target(), *target);
            assert_eq!(engine(*target).target(), *target);
        }
    }

    #[test]
    fn prepared_programs_are_cached_and_shared() {
        let kernel = Kernel::Relu { n: 128 };
        let a = prepared(Target::Cpu, kernel, Sew::E8);
        let b = prepared(Target::Cpu, kernel, Sew::E8);
        assert!(Arc::ptr_eq(&a, &b), "same grid point must share one program");
        assert_eq!(a.target, Target::Cpu);
        assert_eq!(a.kernel, kernel);
        // A different shape is a different program.
        let c = prepared(Target::Cpu, Kernel::Relu { n: 256 }, Sew::E8);
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    #[should_panic(expected = "wrong engine")]
    fn payload_downcast_guards_cross_engine_programs() {
        let prog = cpu::CpuEngine.prepare(Kernel::Xor { n: 64 }, Sew::E32);
        let data = golden::generate(Kernel::Xor { n: 64 }, Sew::E32, 1);
        carus::CarusEngine.execute(&prog, &data);
    }
}
