//! Benchmark kernel suite (Table V / Fig. 11 / Fig. 12 workloads).
//!
//! Nine kernels × three element widths × three execution targets:
//!
//! | Kernel | CPU (RV32IMC, -O3 style) | NM-Caesar | NM-Carus |
//! |---|---|---|---|
//! | bitwise XOR | word-packed loop | `XOR` stream | `vxor[r].vv` |
//! | element-wise add | SWAR (8-bit) / scalar | `ADD` stream | `vadd[r].vv` |
//! | element-wise mul | scalar loop | `MUL` stream | `vmul[r].vv` |
//! | matmul A[8,8]×B[8,p] | k-loop MACs | `DOT_*` stream | `vmacc.vx` + `emvx` |
//! | GEMM α(AB)+βC | + scale/add | + `MUL`/`ADD` | + `vmul.vx`/`vadd.vv` |
//! | 2D conv A[8,n]⊛F[f,f] | MAC loops | `DOT_*` on rows | `vmacc.vx` + slides |
//! | ReLU | branchy loop | `MAX` vs 0 | `vmax.vx` |
//! | leaky ReLU (shift slope) | branchy + `sra` | `MAX`+`SLR`-based | `vsra` + `vmax.vv` |
//! | max pooling 2×2/s2 | window loops | `MAX` rows + CPU horiz. | `vmax.vv`+slide+eCPU |
//!
//! Every target runs on the *same* deterministic inputs (seeded generator in
//! [`golden`]) and is checked against the same golden reference — which is
//! itself cross-checked against the AOT-compiled JAX/Pallas artifacts by
//! `rust/tests/golden_runtime.rs`. Output canonical form: little-endian
//! elements of the kernel's SEW, wrapping 2's-complement semantics
//! (accumulations mod 2^sew, matching the packed hardware datapaths).

pub mod caesar;
pub mod cpu;
pub mod carus;
pub mod golden;

use crate::energy::Breakdown;
use crate::isa::Sew;
use crate::soc::{Halt, Soc};

/// Execution target.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Target {
    Cpu,
    Caesar,
    Carus,
}

impl Target {
    pub const ALL: [Target; 3] = [Target::Cpu, Target::Caesar, Target::Carus];
    pub fn name(self) -> &'static str {
        match self {
            Target::Cpu => "CPU (RV32IMC)",
            Target::Caesar => "NM-Caesar",
            Target::Carus => "NM-Carus",
        }
    }
}

/// Kernel + shape. Sizes are free parameters; [`Kernel::paper_default`]
/// yields the Table V footnote sizes for a given target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Element-wise bitwise XOR over `n` elements.
    Xor { n: u32 },
    /// Element-wise addition.
    Add { n: u32 },
    /// Element-wise multiplication.
    Mul { n: u32 },
    /// A[8,8] × B[8,p] (row-major B, accumulate mod 2^sew).
    Matmul { p: u32 },
    /// α(A[8,8]×B[8,p]) + βC[8,p] with α=2, β=3.
    Gemm { p: u32 },
    /// Valid 2D convolution A[8,n] ⊛ F[f,f].
    Conv2d { n: u32, f: u32 },
    /// max(x, 0) over `n` elements.
    Relu { n: u32 },
    /// x ≥ 0 ? x : x >> 3 (slope 1/8, §V footnote f).
    LeakyRelu { n: u32 },
    /// 2×2 max pooling, stride 2, over a 16-row × `n`-col image.
    Maxpool { n: u32 },
}

/// Kernel families (size-independent identity, used by the harness).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    Xor,
    Add,
    Mul,
    Matmul,
    Gemm,
    Conv2d,
    Relu,
    LeakyRelu,
    Maxpool,
}

impl Family {
    pub const ALL: [Family; 9] = [
        Family::Xor,
        Family::Add,
        Family::Mul,
        Family::Matmul,
        Family::Gemm,
        Family::Conv2d,
        Family::Relu,
        Family::LeakyRelu,
        Family::Maxpool,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Family::Xor => "Bitwise XOR",
            Family::Add => "Element-wise addition",
            Family::Mul => "Element-wise multiplication",
            Family::Matmul => "Matrix multiplication",
            Family::Gemm => "GEMM",
            Family::Conv2d => "2D convolution",
            Family::Relu => "ReLU",
            Family::LeakyRelu => "Leaky ReLU",
            Family::Maxpool => "Max pooling",
        }
    }
}

impl Kernel {
    pub fn family(self) -> Family {
        match self {
            Kernel::Xor { .. } => Family::Xor,
            Kernel::Add { .. } => Family::Add,
            Kernel::Mul { .. } => Family::Mul,
            Kernel::Matmul { .. } => Family::Matmul,
            Kernel::Gemm { .. } => Family::Gemm,
            Kernel::Conv2d { .. } => Family::Conv2d,
            Kernel::Relu { .. } => Family::Relu,
            Kernel::LeakyRelu { .. } => Family::LeakyRelu,
            Kernel::Maxpool { .. } => Family::Maxpool,
        }
    }

    /// The paper's Table V footnote sizes for (family, target, sew).
    pub fn paper_default(family: Family, target: Target, sew: Sew) -> Kernel {
        let small = target == Target::Caesar;
        match family {
            // footnote a: 8 KiB (Caesar) / 10 KiB (CPU, Carus) of input,
            // split across the two operands.
            Family::Xor | Family::Add | Family::Mul => {
                let total_bytes = if small { 8 * 1024 } else { 10 * 1024 };
                let n = total_bytes / 2 / sew.bytes();
                match family {
                    Family::Xor => Kernel::Xor { n },
                    Family::Add => Kernel::Add { n },
                    _ => Kernel::Mul { n },
                }
            }
            // footnote b/c: p = {128,256,512} (Caesar), {256,512,1024}
            // (CPU/Carus) for {32,16,8} bits.
            Family::Matmul | Family::Gemm => {
                let p = match (small, sew) {
                    (true, Sew::E32) => 128,
                    (true, Sew::E16) => 256,
                    (true, Sew::E8) => 512,
                    (false, Sew::E32) => 256,
                    (false, Sew::E16) => 512,
                    (false, Sew::E8) => 1024,
                };
                if family == Family::Matmul {
                    Kernel::Matmul { p }
                } else {
                    Kernel::Gemm { p }
                }
            }
            // footnote d: n={64,64,128}, f={3,4,4} (Caesar);
            // n={256,512,1024}, f=3 (CPU/Carus) for {32,16,8} bits.
            Family::Conv2d => {
                let (n, f) = match (small, sew) {
                    (true, Sew::E32) => (64, 3),
                    (true, Sew::E16) => (64, 4),
                    (true, Sew::E8) => (128, 4),
                    (false, Sew::E32) => (256, 3),
                    (false, Sew::E16) => (512, 3),
                    (false, Sew::E8) => (1024, 3),
                };
                Kernel::Conv2d { n, f }
            }
            // footnote e: 8 KiB (Caesar) / 16 KiB (CPU, Carus).
            Family::Relu | Family::LeakyRelu => {
                let n = if small { 8 * 1024 } else { 16 * 1024 } / sew.bytes();
                if family == Family::Relu {
                    Kernel::Relu { n }
                } else {
                    Kernel::LeakyRelu { n }
                }
            }
            // footnote g: 8 KiB (Caesar) / 16 KiB (CPU, Carus); 16 rows.
            Family::Maxpool => {
                let bytes = if small { 8 * 1024 } else { 16 * 1024 };
                Kernel::Maxpool { n: bytes / 16 / sew.bytes() }
            }
        }
    }

    /// Number of output elements (the "output" of cycles/output).
    pub fn outputs(self) -> u64 {
        match self {
            Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n } => n as u64,
            Kernel::Matmul { p } | Kernel::Gemm { p } => 8 * p as u64,
            Kernel::Conv2d { n, f } => (8 - f as u64 + 1) * (n as u64 - f as u64 + 1),
            Kernel::Relu { n } | Kernel::LeakyRelu { n } => n as u64,
            Kernel::Maxpool { n } => 8 * (n as u64 / 2),
        }
    }
}

/// Result of one kernel run on one target.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub kernel: Kernel,
    pub sew: Sew,
    pub target: Target,
    /// Cycles of the measured region (kernel only, like the paper).
    pub cycles: u64,
    /// Output elements produced.
    pub outputs: u64,
    /// Energy of the measured region.
    pub energy: Breakdown,
    /// Canonical output bytes (little-endian sew elements).
    pub output: Vec<u8>,
    /// Full activity (Fig. 13 power breakdowns).
    pub activity: crate::energy::Activity,
}

impl RunResult {
    pub fn cycles_per_output(&self) -> f64 {
        self.cycles as f64 / self.outputs as f64
    }
    pub fn energy_per_output_pj(&self) -> f64 {
        self.energy.total() / self.outputs as f64
    }
    /// Average power in mW.
    pub fn avg_power_mw(&self) -> f64 {
        self.energy.avg_power_mw(self.cycles)
    }
}

/// Run a kernel on a target with seeded inputs; panics on a functional
/// mismatch against the golden reference (the simulator is expected to be
/// bit-exact).
pub fn run(target: Target, kernel: Kernel, sew: Sew, seed: u64) -> RunResult {
    let data = golden::generate(kernel, sew, seed);
    let mut res = match target {
        Target::Cpu => cpu::run(kernel, sew, &data),
        Target::Caesar => caesar::run(kernel, sew, &data),
        Target::Carus => carus::run(kernel, sew, &data),
    };
    assert_eq!(
        res.output, data.expect,
        "{target:?} {kernel:?} {sew} output mismatch vs golden reference"
    );
    res.kernel = kernel;
    res.sew = sew;
    res.target = target;
    res
}

/// Common driver plumbing shared by the three target modules.
pub(crate) fn finish_run(soc: &mut Soc, halt: Halt, kernel: Kernel, sew: Sew) -> RunResult {
    assert_eq!(halt, Halt::Done, "{kernel:?} {sew} did not complete");
    RunResult {
        kernel,
        sew,
        target: Target::Cpu, // overwritten by `run`
        cycles: soc.cycle,
        outputs: kernel.outputs(),
        energy: soc.energy(),
        output: Vec::new(),
        activity: soc.activity(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_sizes() {
        // Matmul p per footnote b.
        assert_eq!(
            Kernel::paper_default(Family::Matmul, Target::Carus, Sew::E8),
            Kernel::Matmul { p: 1024 }
        );
        assert_eq!(
            Kernel::paper_default(Family::Matmul, Target::Caesar, Sew::E32),
            Kernel::Matmul { p: 128 }
        );
        // Element-wise input sizes: 10 KiB → 5120 e8 elements per operand.
        assert_eq!(Kernel::paper_default(Family::Add, Target::Cpu, Sew::E8), Kernel::Add { n: 5120 });
        assert_eq!(
            Kernel::paper_default(Family::Relu, Target::Carus, Sew::E16),
            Kernel::Relu { n: 8192 }
        );
        // Conv2d shapes.
        assert_eq!(
            Kernel::paper_default(Family::Conv2d, Target::Caesar, Sew::E8),
            Kernel::Conv2d { n: 128, f: 4 }
        );
    }

    #[test]
    fn output_counts() {
        assert_eq!(Kernel::Matmul { p: 512 }.outputs(), 8 * 512);
        assert_eq!(Kernel::Conv2d { n: 256, f: 3 }.outputs(), 6 * 254);
        assert_eq!(Kernel::Maxpool { n: 512 }.outputs(), 8 * 256);
    }
}
