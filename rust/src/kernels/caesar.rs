//! NM-Caesar benchmark kernels: DSL-compiled micro-op streams, DMA-issued.
//!
//! Driver pattern (§V-A2): the kernel's micro-op stream (compiled offline
//! by [`crate::caesar::compiler`]) is embedded in system SRAM; the host CPU
//! raises `imc`, programs the DMA in [`crate::dma::DmaMode::CaesarStream`]
//! mode, and sleeps (`wfi`) until the DMA completion interrupt. The DMA
//! sustains one micro-op per two cycles, exactly matching the Caesar
//! pipeline issue rate.
//!
//! Data placement: operands are staged so that every micro-op's two
//! sources live in *different* internal banks (bank 0 = words 0..4095,
//! bank 1 = 4096..8191) — the layout freedom the paper credits NM-Caesar
//! with ("no data placement constraints exist in NM-Caesar" beyond word
//! alignment). For sub-word convolution windows, element-shifted copies of
//! the image are staged up-front (the word-alignment requirement of a
//! word-wise datapath; setup is host-side data layout, not kernel time —
//! the same best-case treatment the paper gives BLADE/C-SRAM replication).
//!
//! Matmul/GEMM use the element-wise `MAC_*` family with splatted A
//! coefficients (one instruction per word of the output row per k), which
//! matches the paper's measured 2 instructions (4 cycles) per 8-bit output.
//!
//! Engine split: [`CaesarEngine::prepare`] compiles the micro-op stream
//! and assembles the host driver (both pure functions of `(kernel, sew)`);
//! [`CaesarEngine::execute`] stages one concrete workload into the macro
//! and simulates.

use super::golden::{pack, unpack, WorkloadData, LEAKY_SHIFT};
use super::{finish_run, run_timeout, Engine, EngineProgram, Kernel, RunResult, Target};
use crate::asm::{Asm, Program};
use crate::bus::{periph, BANK_SIZE, CAESAR_BASE, PERIPH_BASE};
use crate::caesar::compiler::CaesarProgram;
use crate::isa::reg::*;
use crate::isa::Sew;
use crate::simd::elem;
use crate::soc::Soc;

/// Word offsets of the staging areas (bank 0: 0..4095, bank 1: 4096..8191).
mod layout {
    /// Element-wise: src1 (bank 0), src2 (bank 1), out (bank 0).
    pub const EW_SRC1: u32 = 0;
    pub const EW_OUT: u32 = 2048;
    pub const EW_SRC2: u32 = 4096;
    /// ReLU/leaky: input in-place (bank 0), constants (bank 1).
    pub const RELU_SRC: u32 = 0;
    pub const RELU_CONST: u32 = 4096;
    /// Matmul/GEMM: splatted A (bank 0), out (bank 0), B/C (bank 1).
    pub const MM_ASPLAT: u32 = 0; // 64 words
    pub const MM_OUT: u32 = 64;
    pub const MM_B: u32 = 4096;
    pub const MM_C: u32 = 5120;
    pub const MM_SPLAT2: u32 = 6144; // α=2 splat (bank 1)
    pub const MM_SPLAT3: u32 = 4000; // β=3 splat (bank 0)
    pub const MM_CTMP: u32 = 6145; // scratch (bank 1)
    /// Conv2d: shifted image copies (bank 0), filter splats + out (bank 1).
    pub const CV_COPIES: u32 = 0;
    pub const CV_FSPLAT: u32 = 4096;
    pub const CV_OUT: u32 = 4128;
    /// Maxpool: even rows (bank 0), odd rows (bank 1), vmax rows (bank 0).
    pub const MP_EVEN: u32 = 0;
    pub const MP_VMAX: u32 = 2048;
    pub const MP_ODD: u32 = 4096;
    /// Tiled maxpool (quadrant decomposition, no CPU phase): the four
    /// 2×2-window corners as densely-packed 8×(n/2) quadrant images.
    /// A/C/temp/out in bank 0; B/D/temp2 in bank 1, so every MAX is a
    /// cross-bank (2-cycle) micro-op. Each region holds ≤ 1024 words
    /// (`Kernel::validate` caps n·sew ≤ 1024 B ⇒ quadrant ≤ 1024 words).
    pub const MPQ_A: u32 = 0;
    pub const MPQ_C: u32 = 1024;
    pub const MPQ_T: u32 = 2048;
    pub const MPQ_OUT: u32 = 3072;
    pub const MPQ_B: u32 = 4096;
    pub const MPQ_D: u32 = 5120;
    pub const MPQ_T2: u32 = 6144;
}

/// Stream staging address in system memory (bank 1 onward).
const STREAM_BASE: u32 = BANK_SIZE;
/// CPU-phase output area (maxpool horizontal reduction).
const OUT_BASE: u32 = 4 * BANK_SIZE;

/// The NM-Caesar backend (DMA-streamed micro-op sequences).
pub struct CaesarEngine;

/// Engine-private prepared program: the rendered micro-op stream plus the
/// assembled host driver that issues it (and, for maxpool, performs the
/// horizontal CPU phase).
struct CaesarPrepared {
    stream: Vec<u8>,
    driver: Program,
}

impl Engine for CaesarEngine {
    fn target(&self) -> Target {
        Target::Caesar
    }

    fn prepare(&self, kernel: Kernel, sew: Sew) -> EngineProgram {
        let program = build_program(kernel, sew);
        let stream = program.to_stream(CAESAR_BASE);

        // Host firmware: imc=1 → DMA stream → wfi → imc=0 → optional CPU
        // phase.
        let mut a = Asm::new(0);
        a.li(T0, (PERIPH_BASE + periph::CAESAR_IMC) as i32)
            .li(T1, 1)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_SRC) as i32)
            .li(T1, STREAM_BASE as i32)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_LEN) as i32)
            .li(T1, program.stream_len() as i32)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_CTL) as i32)
            .li(T1, 0b11) // start | CaesarStream
            .sw(T1, 0, T0)
            .wfi()
            .li(T0, (PERIPH_BASE + periph::DMA_STATUS) as i32)
            .lw(T1, 0, T0) // ack irq
            .li(T0, (PERIPH_BASE + periph::CAESAR_IMC) as i32)
            .sw(ZERO, 0, T0);
        if let Kernel::Maxpool { n } = kernel {
            maxpool_cpu_phase(&mut a, n, sew);
        }
        a.ebreak();
        let driver = a.assemble().expect("caesar driver assembles");
        EngineProgram::new(Target::Caesar, kernel, sew, CaesarPrepared { stream, driver })
    }

    fn execute(&self, prog: &EngineProgram, data: &WorkloadData) -> RunResult {
        let prepared: &CaesarPrepared = prog.payload();
        let (kernel, sew) = (prog.kernel, prog.sew);
        let mut soc = Soc::heeperator();
        stage_data(&mut soc, kernel, sew, data);

        // Stage the micro-op stream in system SRAM (may span banks).
        soc.load_region(STREAM_BASE, &prepared.stream);

        soc.load_firmware(&prepared.driver, 0);
        soc.reset_stats();
        let (halt, _) = soc.run(run_timeout());
        let mut res = finish_run(&mut soc, halt, Target::Caesar, kernel, sew);
        res.output = extract(&soc, kernel, sew);
        res
    }

    // --- Tiled execute path (see `crate::sched`) --------------------------

    fn tile_program(&self, kernel: Kernel, sew: Sew) -> Option<super::TileProgram> {
        // Maxpool's single-engine path keeps the paper's host-CPU
        // horizontal phase; behind a tile window there is no per-tile CPU,
        // so the tiled path restages the image as four 2×2-corner
        // quadrants and reduces them with three element-wise MAX streams.
        let program = match kernel {
            Kernel::Maxpool { n } => build_maxpool_tile_program(n, sew),
            _ => build_program(kernel, sew),
        };
        Some(super::TileProgram {
            setup_image: Vec::new(),
            args: Vec::new(),
            exec: super::TileExec::Stream(program),
        })
    }

    fn tile_io(&self, kernel: Kernel, sew: Sew, data: &WorkloadData) -> Option<super::TileIo> {
        let sb = sew.bytes();
        let splat_bytes = |v: u32| elem::splat(v, sew).to_le_bytes().to_vec();
        let mut inputs: Vec<(u32, Vec<u8>)> = Vec::new();
        let output = match kernel {
            Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n } => {
                inputs.push((layout::EW_SRC1 * 4, data.a.clone()));
                inputs.push((layout::EW_SRC2 * 4, data.b.clone()));
                (layout::EW_OUT * 4, n * sb)
            }
            Kernel::Relu { n } | Kernel::LeakyRelu { n } => {
                inputs.push((layout::RELU_SRC * 4, data.a.clone()));
                let c = if matches!(kernel, Kernel::LeakyRelu { .. }) { LEAKY_SHIFT } else { 0 };
                inputs.push((layout::RELU_CONST * 4, splat_bytes(c)));
                (layout::RELU_SRC * 4, n * sb)
            }
            Kernel::Matmul { p } | Kernel::Gemm { p } => {
                let av = unpack(&data.a, sew);
                let mut asplat = Vec::with_capacity(64 * 4);
                for &v in &av {
                    asplat.extend(splat_bytes(v as u32));
                }
                inputs.push((layout::MM_ASPLAT * 4, asplat));
                inputs.push((layout::MM_B * 4, data.b.clone()));
                if matches!(kernel, Kernel::Gemm { .. }) {
                    inputs.push((layout::MM_C * 4, data.c.clone()));
                    inputs.push((layout::MM_SPLAT2 * 4, splat_bytes(2)));
                    inputs.push((layout::MM_SPLAT3 * 4, splat_bytes(3)));
                }
                (layout::MM_OUT * 4, 8 * p * sb)
            }
            Kernel::Conv2d { n, f } => {
                let lanes = sew.lanes();
                let img = unpack(&data.a, sew);
                let filt = unpack(&data.b, sew);
                // Element-shifted image copies (see `stage_data`), as one
                // zero-padded byte image including the per-row guard words.
                let row_words = (n * sb).div_ceil(4) + 1;
                let copy_words = 8 * row_words;
                let mut copies = vec![0u8; (lanes * copy_words * 4) as usize];
                for s in 0..lanes {
                    for r in 0..8u32 {
                        let vals: Vec<i64> = (0..n)
                            .map(|c| {
                                let cc = c + s;
                                if cc < n { img[(r * n + cc) as usize] } else { 0 }
                            })
                            .collect();
                        let at = ((s * copy_words + r * row_words) * 4) as usize;
                        let bytes = pack(&vals, sew);
                        copies[at..at + bytes.len()].copy_from_slice(&bytes);
                    }
                }
                inputs.push((layout::CV_COPIES * 4, copies));
                let mut fsplat = Vec::with_capacity(filt.len() * 4);
                for &w in &filt {
                    fsplat.extend(splat_bytes(w as u32));
                }
                inputs.push((layout::CV_FSPLAT * 4, fsplat));
                let (orows, ocols) = (8 - f + 1, n - f + 1);
                let out_row_words = (ocols * sb).div_ceil(4) + 1;
                (layout::CV_OUT * 4, orows * out_row_words * 4)
            }
            Kernel::Maxpool { n } => {
                // Four packed quadrant images; the stream's MAX reduction
                // leaves the canonical 8×(n/2) output at MPQ_OUT.
                let img = unpack(&data.a, sew);
                let half = n / 2;
                let quad = |dr: u32, dc: u32| -> Vec<u8> {
                    let mut vals = Vec::with_capacity((8 * half) as usize);
                    for r in 0..8u32 {
                        for c in 0..half {
                            vals.push(img[((2 * r + dr) * n + 2 * c + dc) as usize]);
                        }
                    }
                    pack(&vals, sew)
                };
                inputs.push((layout::MPQ_A * 4, quad(0, 0)));
                inputs.push((layout::MPQ_B * 4, quad(0, 1)));
                inputs.push((layout::MPQ_C * 4, quad(1, 0)));
                inputs.push((layout::MPQ_D * 4, quad(1, 1)));
                (layout::MPQ_OUT * 4, 8 * half * sb)
            }
        };
        Some(super::TileIo { inputs, output })
    }

    fn tile_extract(&self, kernel: Kernel, sew: Sew, span: &[u8]) -> Vec<u8> {
        match kernel {
            Kernel::Conv2d { n, f } => {
                // Strip the per-row guard padding.
                let sb = sew.bytes();
                let (orows, ocols) = ((8 - f + 1) as usize, ((n - f + 1) * sb) as usize);
                let stride = (((n - f + 1) * sb).div_ceil(4) + 1) as usize * 4;
                let mut out = Vec::with_capacity(orows * ocols);
                for r in 0..orows {
                    out.extend_from_slice(&span[r * stride..r * stride + ocols]);
                }
                out
            }
            _ => span.to_vec(),
        }
    }
}

/// Build + run an NM-Caesar kernel (uncached prepare + execute).
pub fn run(kernel: Kernel, sew: Sew, data: &WorkloadData) -> RunResult {
    CaesarEngine.execute(&CaesarEngine.prepare(kernel, sew), data)
}

/// Compile the micro-op stream — a pure function of the workload *shape*
/// (all operands are fixed [`layout`] word addresses).
fn build_program(kernel: Kernel, sew: Sew) -> CaesarProgram {
    let mut p = CaesarProgram::new();
    p.csrw(sew);
    match kernel {
        Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n } => {
            let words = (n * sew.bytes()).div_ceil(4);
            for w in 0..words {
                let (d, s1, s2) = (layout::EW_OUT + w, layout::EW_SRC1 + w, layout::EW_SRC2 + w);
                match kernel {
                    Kernel::Xor { .. } => p.xor(d, s1, s2),
                    Kernel::Add { .. } => p.add(d, s1, s2),
                    _ => p.mul(d, s1, s2),
                };
            }
        }
        Kernel::Relu { n } | Kernel::LeakyRelu { n } => {
            let words = (n * sew.bytes()).div_ceil(4);
            let leaky = matches!(kernel, Kernel::LeakyRelu { .. });
            for w in 0..words {
                let x = layout::RELU_SRC + w;
                if leaky {
                    // t = SRA(x, 3); x = MAX(x, t). t lives in bank 1.
                    p.sra(layout::RELU_CONST + 1, x, layout::RELU_CONST);
                    p.max(x, x, layout::RELU_CONST + 1);
                } else {
                    p.max(x, x, layout::RELU_CONST);
                }
            }
        }
        Kernel::Matmul { p: pp } | Kernel::Gemm { p: pp } => {
            let gemm = matches!(kernel, Kernel::Gemm { .. });
            let row_words = pp * sew.bytes() / 4; // B/C/OUT row length in words
            for i in 0..8u32 {
                for w in 0..row_words {
                    let out = layout::MM_OUT + i * row_words + w;
                    // MAC_INIT + 6×MAC + MAC_STORE over k = 0..8.
                    p.mac_init(layout::MM_ASPLAT + i * 8, layout::MM_B + w);
                    for k in 1..7u32 {
                        p.mac(layout::MM_ASPLAT + i * 8 + k, layout::MM_B + k * row_words + w);
                    }
                    p.mac_store(out, layout::MM_ASPLAT + i * 8 + 7, layout::MM_B + 7 * row_words + w);
                    if gemm {
                        // out = out*2 ; ctmp = C*3 ; out += ctmp.
                        p.mul(out, out, layout::MM_SPLAT2);
                        p.mul(layout::MM_CTMP, layout::MM_C + i * row_words + w, layout::MM_SPLAT3);
                        p.add(out, out, layout::MM_CTMP);
                    }
                }
            }
        }
        Kernel::Conv2d { n, f } => {
            let lanes = sew.lanes();
            let row_words = (n * sew.bytes()).div_ceil(4) + 1;
            let copy_words = 8 * row_words;
            let (orows, ocols) = (8 - f + 1, n - f + 1);
            let out_row_words = (ocols * sew.bytes()).div_ceil(4) + 1;
            // Chunked MAC accumulation.
            for r in 0..orows {
                let chunks = ocols.div_ceil(lanes);
                for ch in 0..chunks {
                    let c0 = ch * lanes;
                    let out = layout::CV_OUT + r * out_row_words + ch;
                    let mut first = true;
                    for dy in 0..f {
                        for dx in 0..f {
                            let s = dx % lanes;
                            let word = c0 / lanes + dx / lanes;
                            let src = layout::CV_COPIES + s * copy_words + (r + dy) * row_words + word;
                            let fw = layout::CV_FSPLAT + dy * f + dx;
                            let last = dy == f - 1 && dx == f - 1;
                            if first {
                                p.mac_init(src, fw);
                                first = false;
                            } else if last {
                                p.mac_store(out, src, fw);
                            } else {
                                p.mac(src, fw);
                            }
                        }
                    }
                }
            }
        }
        Kernel::Maxpool { n } => {
            let row_words = (n * sew.bytes()).div_ceil(4);
            // Vertical MAX of row pairs; horizontal reduction runs on the
            // host CPU (see `maxpool_cpu_phase`).
            for r in 0..8u32 {
                for w in 0..row_words {
                    p.max(
                        layout::MP_VMAX + r * row_words + w,
                        layout::MP_EVEN + r * row_words + w,
                        layout::MP_ODD + r * row_words + w,
                    );
                }
            }
        }
    }
    p
}

/// Tiled maxpool stream (quadrant decomposition): with the 2×2-window
/// corners staged as four identically-packed quadrant images, the pooling
/// reduction is three element-wise MAX passes — max(A,B), max(C,D), then
/// the max of the two temporaries, landing the canonical output at
/// `MPQ_OUT`. Sources of every micro-op sit in opposite banks (2 cycles).
fn build_maxpool_tile_program(n: u32, sew: Sew) -> CaesarProgram {
    let mut p = CaesarProgram::new();
    p.csrw(sew);
    let qwords = n * sew.bytes(); // 8·(n/2)·sew bytes = n·sew words
    for w in 0..qwords {
        p.max(layout::MPQ_T + w, layout::MPQ_A + w, layout::MPQ_B + w);
        p.max(layout::MPQ_T2 + w, layout::MPQ_C + w, layout::MPQ_D + w);
        p.max(layout::MPQ_OUT + w, layout::MPQ_T + w, layout::MPQ_T2 + w);
    }
    p
}

/// Stage one concrete workload into the macro's banks per the [`layout`]
/// contract the compiled stream expects.
fn stage_data(soc: &mut Soc, kernel: Kernel, sew: Sew, data: &WorkloadData) {
    let caesar = soc.caesar_mut();
    match kernel {
        Kernel::Xor { .. } | Kernel::Add { .. } | Kernel::Mul { .. } => {
            caesar.load(layout::EW_SRC1 * 4, &data.a);
            caesar.load(layout::EW_SRC2 * 4, &data.b);
        }
        Kernel::Relu { .. } | Kernel::LeakyRelu { .. } => {
            caesar.load(layout::RELU_SRC * 4, &data.a);
            caesar.sew = sew;
            if matches!(kernel, Kernel::LeakyRelu { .. }) {
                // const word = splat(shift amount); scratch at CONST+1.
                caesar.splat_word(layout::RELU_CONST, LEAKY_SHIFT);
            } else {
                caesar.splat_word(layout::RELU_CONST, 0);
            }
        }
        Kernel::Matmul { .. } | Kernel::Gemm { .. } => {
            // Stage splat(A[i][k]) words.
            let av = unpack(&data.a, sew);
            caesar.sew = sew;
            for (i, &v) in av.iter().enumerate() {
                caesar.poke_word(layout::MM_ASPLAT + i as u32, elem::splat(v as u32, sew));
            }
            caesar.load(layout::MM_B * 4, &data.b); // row-major B
            if matches!(kernel, Kernel::Gemm { .. }) {
                caesar.load(layout::MM_C * 4, &data.c);
                caesar.splat_word(layout::MM_SPLAT2, 2);
                caesar.splat_word(layout::MM_SPLAT3, 3);
            }
        }
        Kernel::Conv2d { n, f: _ } => {
            let lanes = sew.lanes();
            let img = unpack(&data.a, sew);
            let filt = unpack(&data.b, sew);
            caesar.sew = sew;
            // Shifted copies: copy s has img[row][col + s], one guard word
            // per row against chunk overreach.
            let row_words = (n * sew.bytes()).div_ceil(4) + 1;
            let copy_words = 8 * row_words;
            for s in 0..lanes {
                for r in 0..8u32 {
                    let vals: Vec<i64> = (0..n)
                        .map(|c| {
                            let cc = c + s;
                            if cc < n {
                                img[(r * n + cc) as usize]
                            } else {
                                0
                            }
                        })
                        .collect();
                    let base = (layout::CV_COPIES + s * copy_words + r * row_words) * 4;
                    caesar.load(base, &pack(&vals, sew));
                }
            }
            // Filter splats.
            for (i, &w) in filt.iter().enumerate() {
                caesar.poke_word(layout::CV_FSPLAT + i as u32, elem::splat(w as u32, sew));
            }
        }
        Kernel::Maxpool { n } => {
            // Stage even rows in bank 0, odd rows in bank 1.
            let row_bytes = n * sew.bytes();
            let row_words = row_bytes.div_ceil(4);
            for r in 0..16u32 {
                let src = &data.a[(r * row_bytes) as usize..((r + 1) * row_bytes) as usize];
                let base = if r % 2 == 0 {
                    layout::MP_EVEN + (r / 2) * row_words
                } else {
                    layout::MP_ODD + (r / 2) * row_words
                };
                caesar.load(base * 4, src);
            }
        }
    }
}

/// Extract the canonical output — a pure function of the shape and the
/// finished SoC state.
fn extract(soc: &Soc, kernel: Kernel, sew: Sew) -> Vec<u8> {
    match kernel {
        Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n } => {
            soc.dump(CAESAR_BASE + layout::EW_OUT * 4, n * sew.bytes())
        }
        Kernel::Relu { n } | Kernel::LeakyRelu { n } => {
            soc.dump(CAESAR_BASE + layout::RELU_SRC * 4, n * sew.bytes())
        }
        Kernel::Matmul { p } | Kernel::Gemm { p } => {
            soc.dump(CAESAR_BASE + layout::MM_OUT * 4, 8 * p * sew.bytes())
        }
        Kernel::Conv2d { n, f } => {
            // Reassemble padded rows.
            let (orows, ocols) = (8 - f + 1, n - f + 1);
            let out_row_words = (ocols * sew.bytes()).div_ceil(4) + 1;
            let mut out = Vec::new();
            for r in 0..orows {
                let base = CAESAR_BASE + (layout::CV_OUT + r * out_row_words) * 4;
                out.extend(soc.dump(base, ocols * sew.bytes()));
            }
            out
        }
        Kernel::Maxpool { n } => soc.dump(OUT_BASE, 8 * (n / 2) * sew.bytes()),
    }
}

/// Host-CPU phase of maxpool: horizontal max of adjacent pairs, reading the
/// vertically-maxed rows from NM-Caesar in memory mode (the paper: "the
/// lack of subword reduction operations in NM-Caesar requires horizontal
/// pooling to be implemented in software in the system CPU").
fn maxpool_cpu_phase(a: &mut Asm, n: u32, sew: Sew) {
    let sb = sew.bytes() as i32;
    let row_words = (n * sew.bytes()).div_ceil(4);
    let vmax_base = CAESAR_BASE + layout::MP_VMAX * 4;
    let total_in_bytes = (8 * row_words * 4) as i32;
    a.li(A0, vmax_base as i32)
        .li(A2, OUT_BASE as i32)
        .li(A3, vmax_base as i32 + total_in_bytes)
        .label("mp_loop")
        .lx(sew, T0, 0, A0)
        .lx(sew, T1, sb, A0)
        .bge(T0, T1, "mp_keep")
        .mv(T0, T1)
        .label("mp_keep")
        .sx(sew, T0, 0, A2)
        .addi(A0, A0, 2 * sb)
        .addi(A2, A2, sb)
        .bne(A0, A3, "mp_loop");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::golden;

    fn check(kernel: Kernel, sew: Sew) -> RunResult {
        let data = golden::generate(kernel, sew, 1234);
        let res = run(kernel, sew, &data);
        assert_eq!(res.output, data.expect, "{kernel:?} {sew}");
        res
    }

    #[test]
    fn elementwise_all_widths() {
        for sew in Sew::ALL {
            // ≈2 cycles per word sustained (+ small driver overhead).
            let res = check(Kernel::Xor { n: 512 / sew.bytes() }, sew);
            let words = 512 / 4;
            let cpw = res.cycles as f64 / words as f64;
            assert!((2.0..3.0).contains(&cpw), "{sew}: {cpw:.2} c/word");
            check(Kernel::Add { n: 256 / sew.bytes() }, sew);
            check(Kernel::Mul { n: 256 / sew.bytes() }, sew);
        }
    }

    #[test]
    fn matmul_timing_matches_paper() {
        // 8-bit: 2 micro-ops (4 cycles) per output.
        let res = check(Kernel::Matmul { p: 64 }, Sew::E8);
        let cpo = res.cycles_per_output();
        assert!((3.9..5.0).contains(&cpo), "8-bit matmul: {cpo:.2} c/out (paper 4.0)");
        // 32-bit: 8 ops → 16 cycles per output.
        let res = check(Kernel::Matmul { p: 16 }, Sew::E32);
        let cpo = res.cycles_per_output();
        assert!((15.0..18.5).contains(&cpo), "32-bit matmul: {cpo:.2} c/out (paper ≈16)");
        check(Kernel::Matmul { p: 32 }, Sew::E16);
    }

    #[test]
    fn gemm_all_widths() {
        for sew in Sew::ALL {
            check(Kernel::Gemm { p: 16 }, sew);
        }
    }

    #[test]
    fn relu_and_leaky() {
        for sew in Sew::ALL {
            let res = check(Kernel::Relu { n: 256 }, sew);
            // 1 op / word → 2 cycles/word.
            let words = (256 * sew.bytes() / 4) as f64;
            let cpw = res.cycles as f64 / words;
            assert!((2.0..3.2).contains(&cpw), "{sew} relu: {cpw:.2} c/word");
            check(Kernel::LeakyRelu { n: 256 }, sew);
        }
    }

    #[test]
    fn conv2d_paper_shapes() {
        check(Kernel::Conv2d { n: 32, f: 3 }, Sew::E32);
        check(Kernel::Conv2d { n: 32, f: 4 }, Sew::E16);
        let res = check(Kernel::Conv2d { n: 64, f: 4 }, Sew::E8);
        // 16 MACs / 4 outputs → 4 ops → 8 cycles per output.
        let cpo = res.cycles_per_output();
        assert!((7.0..11.0).contains(&cpo), "8-bit conv f=4: {cpo:.2} c/out (paper 8)");
    }

    #[test]
    fn maxpool_with_cpu_phase() {
        for sew in Sew::ALL {
            check(Kernel::Maxpool { n: 64 / sew.bytes() }, sew);
        }
    }

    #[test]
    fn tile_io_image_matches_direct_staging() {
        // The tiled execute path stages byte images over DMA; they must
        // land exactly where `stage_data` places the operands.
        let cases = [
            (Kernel::Add { n: 256 }, Sew::E16),
            (Kernel::LeakyRelu { n: 256 }, Sew::E8),
            (Kernel::Gemm { p: 16 }, Sew::E32),
            (Kernel::Conv2d { n: 32, f: 3 }, Sew::E16),
        ];
        for (kernel, sew) in cases {
            let data = golden::generate(kernel, sew, 99);
            let mut direct = Soc::heeperator();
            stage_data(&mut direct, kernel, sew, &data);
            let mut tiled = Soc::heeperator();
            let io = CaesarEngine.tile_io(kernel, sew, &data).unwrap();
            for (off, bytes) in &io.inputs {
                assert_eq!(*off % 4, 0, "word-aligned staging offset");
                assert_eq!(bytes.len() % 4, 0, "word-aligned staging length");
                tiled.caesar_mut().load(*off, bytes);
            }
            assert_eq!(
                direct.dump(CAESAR_BASE, 32 * 1024),
                tiled.dump(CAESAR_BASE, 32 * 1024),
                "{kernel:?} {sew}"
            );
        }
    }

    #[test]
    fn maxpool_tiles_via_quadrant_decomposition() {
        // The single-engine path keeps the paper's host-CPU horizontal
        // phase; the tiled path restages the image as four quadrants and
        // needs no CPU at all. (End-to-end correctness is locked by the
        // sched test `caesar_maxpool_tiles_and_matches_golden`.)
        for sew in Sew::ALL {
            let kernel = Kernel::Maxpool { n: 16 };
            let prog = CaesarEngine.tile_program(kernel, sew).expect("tileable");
            assert!(matches!(prog.exec, crate::kernels::TileExec::Stream(_)));
            let data = golden::generate(kernel, sew, 1);
            let io = CaesarEngine.tile_io(kernel, sew, &data).expect("tileable");
            assert_eq!(io.inputs.len(), 4, "one image per 2x2 corner");
            for (off, bytes) in &io.inputs {
                assert_eq!(*off % 4, 0, "word-aligned staging offset");
                assert_eq!(bytes.len() % 4, 0, "word-aligned staging length");
            }
            let (out_off, out_len) = io.output;
            assert_eq!(out_off, 3072 * 4);
            assert_eq!(out_len, data.expect.len() as u32, "output span is canonical");
        }
    }

    #[test]
    fn prepared_program_is_reusable_across_workloads() {
        // One prepared program, two different workloads: the program is
        // data-independent by construction.
        let kernel = Kernel::Add { n: 128 };
        let prog = CaesarEngine.prepare(kernel, Sew::E16);
        for seed in [1u64, 2] {
            let data = golden::generate(kernel, Sew::E16, seed);
            let res = CaesarEngine.execute(&prog, &data);
            assert_eq!(res.output, data.expect, "seed {seed}");
            assert_eq!(res.target, Target::Caesar);
        }
    }
}
