//! NM-Carus benchmark kernels: RV32EC + xvnmc programs running on the eCPU.
//!
//! Driver pattern (§V-A2): the xvnmc kernel (assembled by the extended
//! assembler) is staged in system SRAM, DMA-copied into the eMEM through
//! the configuration interface, parameterized through the argument words at
//! the top of the eMEM, and started via the control register. The host
//! sleeps (`wfi`) on the NM-Carus completion interrupt. All of this —
//! upload, bootstrap, execution — is inside the measured region, which is
//! exactly the controller overhead Fig. 12 shows hurting NM-Carus on small
//! workloads.
//!
//! Every loop body uses the indirect-register-addressing (`[r]`) variants
//! with a single packed-index GPR bumped by one `addi` per iteration — the
//! paper's code-size trick (§III-B1) that keeps all nine kernels within the
//! 512 B eMEM.
//!
//! VRF layouts (logical registers of `vl·sew` bytes, `vl = VLMAX` ⇒ 1 KiB):
//!
//! | kernel | inputs | outputs | scratch |
//! |---|---|---|---|
//! | element-wise | src1 v0.., src2 v10.. | v20.. | — |
//! | matmul | B rows v0–7, A columns v16–23 | v8–15 | — |
//! | GEMM | + C rows v24–31 | v8–15 | — |
//! | conv2d | image rows v0–7, filter v14 | v8–13 | v15 (slide) |
//! | relu/leaky | v0..15 (in place) | v0..15 | v16 |
//! | maxpool | rows v0–15 | v0–7 (packed by eCPU) | v16–24 |
//!
//! Engine split: [`CarusEngine::prepare`] assembles the eCPU kernel and
//! the host driver (pure functions of `(kernel, sew)` — the argument words
//! are shape parameters); [`CarusEngine::execute`] stages one concrete
//! workload into the VRF and simulates.

use super::golden::{unpack, WorkloadData, LEAKY_SHIFT};
use super::{finish_run, run_timeout, Engine, EngineProgram, Kernel, RunResult, Target};
use crate::asm::{Asm, Program};
use crate::bus::{periph, BANK_SIZE, CARUS_BASE, PERIPH_BASE};
use crate::carus::{ARG_OFFSET, CTL_OFFSET, CTL_START};
use crate::isa::reg::*;
use crate::isa::xvnmc::{pack_indexes, VOp, VSrc};
use crate::isa::Sew;
use crate::soc::Soc;

/// Kernel staging address in system memory.
const KERNEL_BASE: u32 = BANK_SIZE;
/// 1 KiB logical registers (vl = VLMAX).
const REG_BYTES: u32 = 1024;

/// The NM-Carus backend (eCPU-sequenced xvnmc kernels).
pub struct CarusEngine;

/// Engine-private prepared program: the eCPU kernel image (bytes, staged
/// in system SRAM and DMA-uploaded by the driver) plus the assembled host
/// driver that uploads, parameterizes, and starts it.
struct CarusPrepared {
    kernel_bytes: Vec<u8>,
    driver: Program,
}

impl Engine for CarusEngine {
    fn target(&self) -> Target {
        Target::Carus
    }

    fn prepare(&self, kernel: Kernel, sew: Sew) -> EngineProgram {
        let (kprog, args) = build_kernel(kernel, sew);
        let kernel_bytes: Vec<u8> =
            kprog.words.iter().flat_map(|w| w.to_le_bytes()).collect();

        // Host firmware: config mode → DMA kernel upload → args → start →
        // wfi.
        let mut a = Asm::new(0);
        a.li(T0, (PERIPH_BASE + periph::CARUS_MODE) as i32)
            .li(T1, 1)
            .sw(T1, 0, T0) // configuration mode
            .li(T0, (PERIPH_BASE + periph::DMA_SRC) as i32)
            .li(T1, KERNEL_BASE as i32)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_DST) as i32)
            .li(T1, CARUS_BASE as i32)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_LEN) as i32)
            .li(T1, kernel_bytes.len() as i32)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_CTL) as i32)
            .li(T1, 0b01) // start | copy
            .sw(T1, 0, T0)
            .wfi() // until DMA done
            .li(T0, (PERIPH_BASE + periph::DMA_STATUS) as i32)
            .lw(T1, 0, T0); // ack
        // Argument words.
        for (i, &arg) in args.iter().enumerate() {
            a.li(T0, (CARUS_BASE + ARG_OFFSET + 4 * i as u32) as i32)
                .li(T1, arg as i32)
                .sw(T1, 0, T0);
        }
        a.li(A0, (CARUS_BASE + CTL_OFFSET) as i32)
            .li(T1, CTL_START as i32)
            .sw(T1, 0, A0) // start the kernel
            .wfi() // until NM-Carus IRQ
            .lw(A1, 0, A0) // status
            .sw(ZERO, 0, A0) // ack done
            .li(T0, (PERIPH_BASE + periph::CARUS_MODE) as i32)
            .sw(ZERO, 0, T0) // back to memory mode
            .ebreak();
        let driver = a.assemble().expect("carus driver assembles");
        EngineProgram::new(Target::Carus, kernel, sew, CarusPrepared { kernel_bytes, driver })
    }

    fn execute(&self, prog: &EngineProgram, data: &WorkloadData) -> RunResult {
        let prepared: &CarusPrepared = prog.payload();
        let (kernel, sew) = (prog.kernel, prog.sew);
        let mut soc = Soc::heeperator();
        stage_data(&mut soc, kernel, sew, data);

        // Stage the kernel binary in system SRAM.
        soc.load_data(KERNEL_BASE, &prepared.kernel_bytes);

        soc.load_firmware(&prepared.driver, 0);
        soc.reset_stats();
        let (halt, _) = soc.run(run_timeout());
        let mut res = finish_run(&mut soc, halt, Target::Carus, kernel, sew);
        res.output = extract(&soc, kernel, sew);
        res
    }

    // --- Tiled execute path (see `crate::sched`) --------------------------

    fn tile_program(&self, kernel: Kernel, sew: Sew) -> Option<super::TileProgram> {
        let (kprog, args) = build_kernel(kernel, sew);
        let setup_image: Vec<u8> = kprog.words.iter().flat_map(|w| w.to_le_bytes()).collect();
        Some(super::TileProgram { setup_image, args, exec: super::TileExec::Autonomous })
    }

    fn tile_io(&self, kernel: Kernel, sew: Sew, data: &WorkloadData) -> Option<super::TileIo> {
        let sb = sew.bytes();
        let mut inputs: Vec<(u32, Vec<u8>)> = Vec::new();
        let output = match kernel {
            Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n } => {
                inputs.push((0, data.a.clone())); // v0..
                inputs.push((10 * REG_BYTES, data.b.clone())); // v10..
                (20 * REG_BYTES, n * sb)
            }
            Kernel::Relu { n } | Kernel::LeakyRelu { n } => {
                inputs.push((0, data.a.clone())); // in place
                (0, n * sb)
            }
            Kernel::Matmul { p } | Kernel::Gemm { p } => {
                let rb = p * sb;
                inputs.push((0, data.b.clone())); // B rows v0–7
                // A *columns* image (v16–23): element i of column register
                // 16+k is A[i][k] — the byte-image twin of the
                // `vrf.set_elem` staging in `stage_data`.
                let av = unpack(&data.a, sew);
                let mut cols = vec![0u8; (8 * rb) as usize];
                for k in 0..8u32 {
                    for i in 0..8u32 {
                        let at = (k * rb + i * sb) as usize;
                        let bytes = super::golden::pack(&[av[(i * 8 + k) as usize]], sew);
                        cols[at..at + sb as usize].copy_from_slice(&bytes);
                    }
                }
                inputs.push((16 * rb, cols));
                if matches!(kernel, Kernel::Gemm { .. }) {
                    inputs.push((24 * rb, data.c.clone())); // C rows v24–31
                }
                (8 * rb, 8 * rb)
            }
            Kernel::Conv2d { n, f } => {
                let rb = n * sb;
                inputs.push((0, data.a.clone())); // image rows v0–7
                let mut filt = data.b.clone(); // filter flat in v14
                while filt.len() % 4 != 0 {
                    filt.push(0); // word-pad (spills into unused v14 tail)
                }
                inputs.push((14 * rb, filt));
                (8 * rb, (8 - f + 1) * rb)
            }
            Kernel::Maxpool { n } => {
                let rb = n * sb;
                inputs.push((0, data.a.clone())); // rows v0–15
                (0, 8 * rb) // packed output rows v0–7
            }
        };
        Some(super::TileIo { inputs, output })
    }

    fn tile_extract(&self, kernel: Kernel, sew: Sew, span: &[u8]) -> Vec<u8> {
        let sb = sew.bytes();
        match kernel {
            Kernel::Conv2d { n, f } => {
                let rb = (n * sb) as usize;
                let (orows, ocols) = ((8 - f + 1) as usize, ((n - f + 1) * sb) as usize);
                let mut out = Vec::with_capacity(orows * ocols);
                for r in 0..orows {
                    out.extend_from_slice(&span[r * rb..r * rb + ocols]);
                }
                out
            }
            Kernel::Maxpool { n } => {
                let rb = (n * sb) as usize;
                let half = ((n / 2) * sb) as usize;
                let mut out = Vec::with_capacity(8 * half);
                for r in 0..8usize {
                    out.extend_from_slice(&span[r * rb..r * rb + half]);
                }
                out
            }
            _ => span.to_vec(),
        }
    }
}

/// Build + run an NM-Carus kernel (uncached prepare + execute).
pub fn run(kernel: Kernel, sew: Sew, data: &WorkloadData) -> RunResult {
    CarusEngine.execute(&CarusEngine.prepare(kernel, sew), data)
}

/// Valid-data spans of a kernel's output inside the tile window, as
/// `(offset, len)` chunks in extraction order — the DMA-addressable twin
/// of [`Engine::tile_extract`]. Contiguous-output kernels return the one
/// chunk `tile_io().output` describes; kernels whose output interleaves a
/// valid prefix with stale bytes per row (conv2d, maxpool) return one
/// chunk per output row. The graph pipeline uses this to decide whether
/// an inter-layer tensor can stay resident (single chunk → one tile-to-
/// tile DMA) or must be repacked through host staging.
pub fn output_chunks(kernel: Kernel, sew: Sew) -> Vec<(u32, u32)> {
    let sb = sew.bytes();
    match kernel {
        Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n } => {
            vec![(20 * REG_BYTES, n * sb)]
        }
        Kernel::Relu { n } | Kernel::LeakyRelu { n } => vec![(0, n * sb)],
        Kernel::Matmul { p } | Kernel::Gemm { p } => vec![(8 * p * sb, 8 * p * sb)],
        Kernel::Conv2d { n, f } => {
            let rb = n * sb;
            (0..8 - f + 1).map(|r| (8 * rb + r * rb, (n - f + 1) * sb)).collect()
        }
        Kernel::Maxpool { n } => {
            let rb = n * sb;
            (0..8).map(|r| (r * rb, (n / 2) * sb)).collect()
        }
    }
}

/// Assemble an eCPU kernel (base 0 = eMEM).
fn kasm(build: impl FnOnce(&mut Asm)) -> Program {
    let mut a = Asm::new(0);
    build(&mut a);
    let p = a.assemble().expect("carus kernel assembles");
    assert!(
        p.size() <= ARG_OFFSET,
        "kernel does not fit the eMEM: {} bytes",
        p.size()
    );
    p
}

/// Assemble the eCPU program and its argument words — pure functions of
/// the workload shape.
fn build_kernel(kernel: Kernel, sew: Sew) -> (Program, Vec<u32>) {
    let vlmax = REG_BYTES / sew.bytes();
    match kernel {
        Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n } => {
            let bytes = n * sew.bytes();
            let nregs = bytes.div_ceil(REG_BYTES);
            let op = match kernel {
                Kernel::Xor { .. } => VOp::Xor,
                Kernel::Add { .. } => VOp::Add,
                _ => VOp::Mul,
            };
            // loop k: v(20+k) = v(0+k) ⊙ v(10+k), indirect, one addi bump.
            let k = kasm(|a| {
                a.li(T0, ARG_OFFSET as i32)
                    .lw(S0, 0, T0) // nregs
                    .li(A0, vlmax as i32)
                    .vsetvli(T0, A0, sew)
                    .li(S1, pack_indexes(20, 0, 10) as i32)
                    .label("loop")
                    .v_opr(op, S1, VSrc::V(0))
                    .li(T1, 0x010101)
                    .add(S1, S1, T1)
                    .addi(S0, S0, -1)
                    .bne(S0, ZERO, "loop")
                    .ebreak();
            });
            (k, vec![nregs])
        }
        Kernel::Relu { n } | Kernel::LeakyRelu { n } => {
            let bytes = n * sew.bytes();
            let nregs = bytes.div_ceil(REG_BYTES);
            let leaky = matches!(kernel, Kernel::LeakyRelu { .. });
            let k = kasm(|a| {
                a.li(T0, ARG_OFFSET as i32)
                    .lw(S0, 0, T0)
                    .li(A0, vlmax as i32)
                    .vsetvli(T0, A0, sew)
                    .li(S1, pack_indexes(0, 0, 16) as i32) // {vd=k, vs2=k, vs1=16}
                    .li(A1, LEAKY_SHIFT as i32)
                    .label("loop");
                if leaky {
                    // v16 = v(k) >> 3 ; v(k) = max(v(k), v16).
                    a.andi(T2, S1, 0xff) // k (low byte of the packed index)
                        .slli(T2, T2, 8)
                        .ori(T2, T2, 16) // {vd=16, vs2=k}
                        .v_opr(VOp::Sra, T2, VSrc::X(A1))
                        .v_opr(VOp::Max, S1, VSrc::V(0)); // vs1=16 from packed
                } else {
                    a.v_opr(VOp::Max, S1, VSrc::X(ZERO));
                }
                a.li(T1, 0x000101) // bump vd and vs2, keep vs1=16
                    .add(S1, S1, T1)
                    .addi(S0, S0, -1)
                    .bne(S0, ZERO, "loop")
                    .ebreak();
            });
            (k, vec![nregs])
        }
        Kernel::Matmul { p } | Kernel::Gemm { p } => {
            let gemm = matches!(kernel, Kernel::Gemm { .. });
            assert!(p >= 8, "vl = p must hold the 8-element A columns");
            assert!(p * sew.bytes() <= REG_BYTES, "B row must fit one register");
            // vl = p ⇒ logical registers are row-sized. Layout: B rows
            // v0–7, output rows v8–15, A *columns* v16–23 (column k in
            // v(16+k): emvx's direct vs2 field stays constant per unrolled
            // k-slot while the element index i is a GPR), C rows v24–31.
            let k = kasm(|a| {
                a.li(T0, ARG_OFFSET as i32)
                    .lw(A0, 0, T0) // p (AVL)
                    .vsetvli(T0, A0, sew)
                    .li(S0, 0) // i
                    .li(A4, pack_indexes(8, 8, 0) as i32) // vsll {vd=8+i, vs2=8+i}
                    .li(A5, pack_indexes(8, 24, 0) as i32) // β-vmacc {vd=8+i, vs2=24+i}
                    .label("iloop")
                    .addi(S1, S0, 8) // packed {vd=8+i, vs2=0}
                    .v_opr(VOp::Mv, S1, VSrc::I(0)); // acc row = 0
                for k in 0..8u8 {
                    // a = A[i][k] (element i of the column register), then
                    // acc += a · B[k] — the emvx never hazards (v16+k is
                    // read-only), so it hides under the previous vmacc.
                    a.emvx(A2, 16 + k, S0);
                    if k > 0 {
                        a.addi(S1, S1, 0x100); // vs2 = k
                    }
                    a.v_opr(VOp::Macc, S1, VSrc::X(A2));
                }
                if gemm {
                    a.v_opr(VOp::Sll, A4, VSrc::I(1)) // out <<= 1 (α=2)
                        .li(T1, 3)
                        .v_opr(VOp::Macc, A5, VSrc::X(T1)) // out += 3·C
                        .li(T1, 0x101)
                        .add(A4, A4, T1)
                        .add(A5, A5, T1);
                }
                a.addi(S0, S0, 1)
                    .li(T2, 8)
                    .bne(S0, T2, "iloop")
                    .ebreak();
            });
            (k, vec![p])
        }
        Kernel::Conv2d { n, f } => {
            assert!(n * sew.bytes() <= REG_BYTES);
            let orows = 8 - f + 1;
            let k = kasm(|a| {
                a.li(T0, ARG_OFFSET as i32)
                    .lw(A0, 0, T0) // n (AVL)
                    .lw(A5, 4, T0) // f
                    .lw(S0, 8, T0) // orows
                    .vsetvli(T0, A0, sew)
                    .li(S1, 0) // r
                    .label("rloop")
                    // acc row: {vd=8+r}
                    .addi(T1, S1, 8)
                    .v_opr(VOp::Mv, T1, VSrc::I(0))
                    .li(A3, 0) // flat filter index dy*f+dx
                    .li(T2, 0) // dy
                    .label("dyloop")
                    .li(A4, 0) // dx
                    .label("dxloop")
                    .emvx(A1, 14, A3) // w = F[dy*f+dx]
                    // source row index = r + dy
                    .add(A2, S1, T2)
                    .beq(A4, ZERO, "noslide")
                    // v15 = slidedown(v(r+dy), dx); src ← v15
                    .slli(A2, A2, 8)
                    .addi(A2, A2, 15) // {vd=15, vs2=r+dy}
                    .v_opr(VOp::SlideDown, A2, VSrc::X(A4))
                    .li(A2, 15)
                    .label("noslide")
                    // acc {vd=8+r, vs2=src}
                    .slli(A2, A2, 8)
                    .add(A2, A2, S1)
                    .addi(A2, A2, 8)
                    .v_opr(VOp::Macc, A2, VSrc::X(A1))
                    .addi(A3, A3, 1)
                    .addi(A4, A4, 1)
                    .bne(A4, A5, "dxloop")
                    .addi(T2, T2, 1)
                    .bne(T2, A5, "dyloop")
                    .addi(S1, S1, 1)
                    .bne(S1, S0, "rloop")
                    .ebreak();
            });
            (k, vec![n, f, orows])
        }
        Kernel::Maxpool { n } => {
            assert!(n * sew.bytes() <= REG_BYTES);
            let half = n / 2;
            let k = kasm(|a| {
                a.li(T0, ARG_OFFSET as i32)
                    .lw(A0, 0, T0) // n (AVL)
                    .lw(A5, 4, T0) // n/2
                    .vsetvli(T0, A0, sew)
                    // Phase 1+2: per output row r: v(16+r) = vmax(v2r, v2r+1);
                    // v24 = slidedown(v(16+r), 1); v(16+r) = vmax(v16+r, v24).
                    .li(S0, 0) // r
                    .li(S1, pack_indexes(16, 0, 1) as i32)
                    .label("vloop")
                    .v_opr(VOp::Max, S1, VSrc::V(0))
                    // slide: {vd=24, vs2=16+r}
                    .addi(T1, S0, 16)
                    .slli(T1, T1, 8)
                    .addi(T1, T1, 24)
                    .li(T2, 1)
                    .v_opr(VOp::SlideDown, T1, VSrc::X(T2))
                    // max: {vd=16+r, vs2=16+r, vs1=24}
                    .addi(T1, S0, 16)
                    .slli(T2, T1, 8)
                    .add(T1, T1, T2)
                    .li(T2, 24 << 16)
                    .add(T1, T1, T2)
                    .v_opr(VOp::Max, T1, VSrc::V(0))
                    .li(T1, 0x20201) // vd += 1, vs2 += 2, vs1 += 2
                    .add(S1, S1, T1)
                    .addi(S0, S0, 1)
                    .li(T1, 8)
                    .bne(S0, T1, "vloop");
                // Phase 3: eCPU compaction — unrolled over the 8 output rows
                // (emvv's destination register is a direct field).
                for r in 0..8u8 {
                    let row = format!("cp{r}");
                    a.li(T1, 0) // source element index (even)
                        .li(T2, 0) // dest element index
                        .label(&row)
                        .emvx(A2, 16 + r, T1)
                        .emvv(r, T2, A2)
                        .addi(T1, T1, 2)
                        .addi(T2, T2, 1)
                        .bne(T2, A5, &row);
                }
                a.ebreak();
            });
            (k, vec![n, half])
        }
    }
}

/// Stage one concrete workload into the VRF per the layout the kernel
/// expects.
fn stage_data(soc: &mut Soc, kernel: Kernel, sew: Sew, data: &WorkloadData) {
    let vrf = &mut soc.carus_mut().vrf;
    match kernel {
        Kernel::Xor { .. } | Kernel::Add { .. } | Kernel::Mul { .. } => {
            vrf.load(0, &data.a); // v0..
            vrf.load(10 * REG_BYTES, &data.b); // v10..
        }
        Kernel::Relu { .. } | Kernel::LeakyRelu { .. } => {
            vrf.load(0, &data.a);
        }
        Kernel::Matmul { p } | Kernel::Gemm { p } => {
            let row_bytes = p * sew.bytes();
            let av = unpack(&data.a, sew);
            for r in 0..8u32 {
                vrf.load(
                    r * row_bytes,
                    &data.b[(r * row_bytes) as usize..((r + 1) * row_bytes) as usize],
                );
            }
            for k in 0..8u32 {
                for i in 0..8u32 {
                    vrf.set_elem(
                        (16 + k) as u8,
                        i,
                        p,
                        sew,
                        av[(i * 8 + k) as usize] as u32,
                    );
                }
            }
            if matches!(kernel, Kernel::Gemm { .. }) {
                for r in 0..8u32 {
                    vrf.load(
                        (24 + r) * row_bytes,
                        &data.c[(r * row_bytes) as usize..((r + 1) * row_bytes) as usize],
                    );
                }
            }
        }
        Kernel::Conv2d { n, .. } => {
            let row_bytes = n * sew.bytes();
            for r in 0..8u32 {
                vrf.load(
                    r * row_bytes,
                    &data.a[(r * row_bytes) as usize..((r + 1) * row_bytes) as usize],
                );
            }
            vrf.load(14 * row_bytes, &data.b); // filter flat in v14
        }
        Kernel::Maxpool { n } => {
            let row_bytes = n * sew.bytes();
            for r in 0..16u32 {
                vrf.load(
                    r * row_bytes,
                    &data.a[(r * row_bytes) as usize..((r + 1) * row_bytes) as usize],
                );
            }
        }
    }
}

/// Extract the canonical output from the VRF byte view.
fn extract(soc: &Soc, kernel: Kernel, sew: Sew) -> Vec<u8> {
    match kernel {
        Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n } => {
            soc.dump(CARUS_BASE + 20 * REG_BYTES, n * sew.bytes())
        }
        Kernel::Relu { n } | Kernel::LeakyRelu { n } => soc.dump(CARUS_BASE, n * sew.bytes()),
        Kernel::Matmul { p } | Kernel::Gemm { p } => {
            let row_bytes = p * sew.bytes();
            soc.dump(CARUS_BASE + 8 * row_bytes, 8 * row_bytes)
        }
        Kernel::Conv2d { n, f } => {
            let row_bytes = n * sew.bytes();
            let (orows, ocols) = (8 - f + 1, n - f + 1);
            let mut out = Vec::new();
            for r in 0..orows {
                out.extend(soc.dump(CARUS_BASE + (8 + r) * row_bytes, ocols * sew.bytes()));
            }
            out
        }
        Kernel::Maxpool { n } => {
            let row_bytes = n * sew.bytes();
            let half = n / 2;
            let mut out = Vec::new();
            for r in 0..8u32 {
                out.extend(soc.dump(CARUS_BASE + r * row_bytes, half * sew.bytes()));
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::golden;

    fn check(kernel: Kernel, sew: Sew) -> RunResult {
        let data = golden::generate(kernel, sew, 777);
        let res = run(kernel, sew, &data);
        assert_eq!(res.output, data.expect, "{kernel:?} {sew}");
        res
    }

    #[test]
    fn elementwise_all_widths() {
        for sew in Sew::ALL {
            check(Kernel::Xor { n: 2048 / sew.bytes() }, sew);
            check(Kernel::Add { n: 2048 / sew.bytes() }, sew);
            check(Kernel::Mul { n: 2048 / sew.bytes() }, sew);
        }
    }

    #[test]
    fn matmul_saturates_near_half_output_per_cycle() {
        let res = check(Kernel::Matmul { p: 1024 }, Sew::E8);
        let cpo = res.cycles_per_output();
        // Paper Fig. 12: saturates at 0.48 output/cycle → ≈2.1 c/out.
        assert!((1.9..2.6).contains(&cpo), "8-bit matmul: {cpo:.2} c/out (paper 2.08)");
        check(Kernel::Matmul { p: 512 }, Sew::E16);
        check(Kernel::Matmul { p: 256 }, Sew::E32);
    }

    #[test]
    fn gemm_all_widths() {
        check(Kernel::Gemm { p: 256 }, Sew::E8);
        check(Kernel::Gemm { p: 128 }, Sew::E16);
        check(Kernel::Gemm { p: 64 }, Sew::E32);
    }

    #[test]
    fn conv2d() {
        check(Kernel::Conv2d { n: 256, f: 3 }, Sew::E8);
        check(Kernel::Conv2d { n: 128, f: 3 }, Sew::E16);
        check(Kernel::Conv2d { n: 64, f: 4 }, Sew::E32);
    }

    #[test]
    fn relu_and_leaky() {
        for sew in Sew::ALL {
            let res = check(Kernel::Relu { n: 4096 / sew.bytes() }, sew);
            // vmax.vx: 2 c/word on 4 lanes → 0.5 c/word overall.
            let words = (4096 / 4) as f64;
            let cpw = res.cycles as f64 / words;
            assert!(cpw < 1.2, "{sew} relu: {cpw:.2} c/word overall");
            check(Kernel::LeakyRelu { n: 2048 / sew.bytes() }, sew);
        }
    }

    #[test]
    fn maxpool() {
        for sew in Sew::ALL {
            check(Kernel::Maxpool { n: 256 / sew.bytes() }, sew);
        }
    }

    #[test]
    fn tile_io_image_matches_direct_staging() {
        // The tiled execute path stages byte images over DMA; they must
        // place every operand exactly where `stage_data` does.
        let cases = [
            (Kernel::Matmul { p: 64 }, Sew::E8),
            (Kernel::Gemm { p: 32 }, Sew::E16),
            (Kernel::Conv2d { n: 64, f: 3 }, Sew::E16),
            (Kernel::Add { n: 512 }, Sew::E32),
            (Kernel::Maxpool { n: 64 }, Sew::E8),
        ];
        for (kernel, sew) in cases {
            let data = golden::generate(kernel, sew, 42);
            let mut direct = Soc::heeperator();
            stage_data(&mut direct, kernel, sew, &data);
            let mut tiled = Soc::heeperator();
            let io = CarusEngine.tile_io(kernel, sew, &data).unwrap();
            for (off, bytes) in &io.inputs {
                assert_eq!(*off % 4, 0, "word-aligned staging offset");
                assert_eq!(bytes.len() % 4, 0, "word-aligned staging length");
                tiled.carus_mut().vrf.load(*off, bytes);
            }
            assert_eq!(io.output.1 % 4, 0, "word-aligned output span");
            assert_eq!(
                direct.carus().vrf.dump(0, 32 * 1024),
                tiled.carus().vrf.dump(0, 32 * 1024),
                "{kernel:?} {sew}"
            );
        }
    }

    #[test]
    fn prepared_program_is_reusable_across_workloads() {
        let kernel = Kernel::Relu { n: 512 };
        let prog = CarusEngine.prepare(kernel, Sew::E8);
        for seed in [10u64, 11] {
            let data = golden::generate(kernel, Sew::E8, seed);
            let res = CarusEngine.execute(&prog, &data);
            assert_eq!(res.output, data.expect, "seed {seed}");
            assert_eq!(res.target, Target::Carus);
        }
    }
}
