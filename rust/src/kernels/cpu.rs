//! CPU-only baseline kernels (RV32IMC, GCC 11 -O3 idioms), §V-A2.
//!
//! These firmware builders emulate what the paper's baseline compiler
//! produces: word-packed loops where auto-vectorization applies (bitwise
//! XOR at any width, SWAR addition at 8-bit), pointer-strength-reduced
//! element loops elsewhere, non-unrolled reduction loops for matmul/conv
//! (the measured 10–14 cycles/MAC of the paper's baselines), and
//! data-dependent branches for ReLU/pooling (the paper calls these out as
//! the CPU's weakness vs. the NMC min/max instructions).
//!
//! Memory map: firmware in SRAM bank 0; A/B/C/OUT in banks 1/2/3/4.

use super::golden::{WorkloadData, GEMM_BETA, LEAKY_SHIFT};
use super::{finish_run, run_timeout, Engine, EngineProgram, Kernel, RunResult, Target};
use crate::asm::{Asm, Program};
use crate::bus::BANK_SIZE;
use crate::isa::reg::*;
use crate::isa::Sew;
use crate::soc::Soc;

pub const A_BASE: u32 = BANK_SIZE;
pub const B_BASE: u32 = 2 * BANK_SIZE;
pub const C_BASE: u32 = 3 * BANK_SIZE;
pub const OUT_BASE: u32 = 4 * BANK_SIZE;

/// The CPU-only baseline backend (RV32IMC host, no NMC macro).
pub struct CpuEngine;

/// Engine-private prepared program: the assembled baseline firmware.
struct CpuPrepared {
    firmware: Program,
}

impl Engine for CpuEngine {
    fn target(&self) -> Target {
        Target::Cpu
    }

    fn prepare(&self, kernel: Kernel, sew: Sew) -> EngineProgram {
        let mut a = Asm::new(0);
        build(&mut a, kernel, sew);
        let firmware = a.assemble().expect("cpu kernel assembles");
        EngineProgram::new(Target::Cpu, kernel, sew, CpuPrepared { firmware })
    }

    fn execute(&self, prog: &EngineProgram, data: &WorkloadData) -> RunResult {
        let prepared: &CpuPrepared = prog.payload();
        let (kernel, sew) = (prog.kernel, prog.sew);
        let mut soc = Soc::heeperator();
        soc.load_data(A_BASE, &data.a);
        if !data.b.is_empty() {
            soc.load_data(B_BASE, &data.b);
        }
        if !data.c.is_empty() {
            soc.load_data(C_BASE, &data.c);
        }
        soc.load_firmware(&prepared.firmware, 0);
        soc.reset_stats();
        let (halt, _) = soc.run(run_timeout());
        let mut res = finish_run(&mut soc, halt, Target::Cpu, kernel, sew);
        res.output = soc.dump(OUT_BASE, (kernel.outputs() * sew.bytes() as u64) as u32);
        res
    }
}

/// Build + run a CPU kernel (uncached prepare + execute); returns the
/// measured result with the canonical output extracted from the OUT bank.
pub fn run(kernel: Kernel, sew: Sew, data: &WorkloadData) -> RunResult {
    CpuEngine.execute(&CpuEngine.prepare(kernel, sew), data)
}

fn build(a: &mut Asm, kernel: Kernel, sew: Sew) {
    match kernel {
        Kernel::Xor { n } => xor_kernel(a, n, sew),
        Kernel::Add { n } => add_kernel(a, n, sew),
        Kernel::Mul { n } => mul_kernel(a, n, sew),
        Kernel::Matmul { p } => matmul_kernel(a, p, sew, false),
        Kernel::Gemm { p } => matmul_kernel(a, p, sew, true),
        Kernel::Conv2d { n, f } => conv2d_kernel(a, n, f, sew),
        Kernel::Relu { n } => relu_kernel(a, n, sew, false),
        Kernel::LeakyRelu { n } => relu_kernel(a, n, sew, true),
        Kernel::Maxpool { n } => maxpool_kernel(a, n, sew),
    }
}

/// Bitwise XOR: -O3 packs any width into word operations (4/2/1 elements
/// per iteration — the linear sub-word scaling the paper observes).
fn xor_kernel(a: &mut Asm, n: u32, sew: Sew) {
    let bytes = n * sew.bytes();
    assert!(bytes % 4 == 0);
    a.li(A0, A_BASE as i32)
        .li(A1, B_BASE as i32)
        .li(A2, OUT_BASE as i32)
        .li(A3, (A_BASE + bytes) as i32)
        .label("loop")
        .lw(T0, 0, A0)
        .lw(T1, 0, A1)
        .xor(T0, T0, T1)
        .sw(T0, 0, A2)
        .addi(A0, A0, 4)
        .addi(A1, A1, 4)
        .addi(A2, A2, 4)
        .bne(A0, A3, "loop")
        .ebreak();
}

/// Element-wise addition: 8-bit uses the classic SWAR trick (what the paper
/// attributes to compiler auto-vectorization); 16/32-bit run element loops.
fn add_kernel(a: &mut Asm, n: u32, sew: Sew) {
    match sew {
        Sew::E8 => {
            let bytes = n;
            a.li(A0, A_BASE as i32)
                .li(A1, B_BASE as i32)
                .li(A2, OUT_BASE as i32)
                .li(A3, (A_BASE + bytes) as i32)
                .li(S2, 0x7f7f7f7fu32 as i32)
                .li(S3, 0x80808080u32 as i32)
                .label("loop")
                .lw(T0, 0, A0)
                .lw(T1, 0, A1)
                .and(T2, T0, S2)
                .and(T3, T1, S2)
                .add(T2, T2, T3)
                .xor(T3, T0, T1)
                .and(T3, T3, S3)
                .xor(T2, T2, T3)
                .sw(T2, 0, A2)
                .addi(A0, A0, 4)
                .addi(A1, A1, 4)
                .addi(A2, A2, 4)
                .bne(A0, A3, "loop")
                .ebreak();
        }
        Sew::E16 | Sew::E32 => {
            let sb = sew.bytes() as i32;
            a.li(A0, A_BASE as i32)
                .li(A1, B_BASE as i32)
                .li(A2, OUT_BASE as i32)
                .li(A3, (A_BASE + n * sew.bytes()) as i32)
                .label("loop");
            a.lx(sew, T0, 0, A0);
            a.lx(sew, T1, 0, A1);
            a.add(T0, T0, T1);
            a.sx(sew, T0, 0, A2);
            a.addi(A0, A0, sb)
                .addi(A1, A1, sb)
                .addi(A2, A2, sb)
                .bne(A0, A3, "loop")
                .ebreak();
        }
    }
}

/// Element-wise multiplication: no SWAR possible → element loop at every
/// width (the paper's flat ≈11 cycles/element baseline).
fn mul_kernel(a: &mut Asm, n: u32, sew: Sew) {
    let sb = sew.bytes() as i32;
    a.li(A0, A_BASE as i32)
        .li(A1, B_BASE as i32)
        .li(A2, OUT_BASE as i32)
        .li(A3, (A_BASE + n * sew.bytes()) as i32)
        .label("loop");
    a.lx(sew, T0, 0, A0);
    a.lx(sew, T1, 0, A1);
    a.mul(T0, T0, T1);
    a.sx(sew, T0, 0, A2);
    a.addi(A0, A0, sb)
        .addi(A1, A1, sb)
        .addi(A2, A2, sb)
        .bne(A0, A3, "loop")
        .ebreak();
}

/// Matmul A[8,8]×B[8,p] (k-loop reduction, pointer strength reduction).
/// GEMM adds α/β scaling (α=2 → slli; β=3 → slli+add).
fn matmul_kernel(a: &mut Asm, p: u32, sew: Sew, gemm: bool) {
    let sb = sew.bytes() as i32;
    let row_stride = (p * sew.bytes()) as i32; // B row stride in bytes
    a.li(S0, A_BASE as i32) // A row pointer
        .li(S1, B_BASE as i32) // B base
        .li(S7, OUT_BASE as i32) // OUT pointer
        .li(S3, 8) // i counter
        .li(S6, row_stride); // B row stride (may exceed addi range)
    if gemm {
        a.li(S8, C_BASE as i32); // C pointer
    }
    a.label("iloop")
        .mv(T4, S1) // column pointer = B + j*sb
        .li(S5, p as i32) // j counter
        .label("jloop")
        .mv(T0, S0) // A[i] walker
        .mv(T1, T4) // B[.][j] walker
        .li(T2, 0) // acc
        .li(T3, 8) // k counter
        .label("kloop");
    a.lx(sew, T5, 0, T0);
    a.lx(sew, T6, 0, T1);
    a.mul(T5, T5, T6)
        .add(T2, T2, T5)
        .addi(T0, T0, sb)
        .add(T1, T1, S6)
        .addi(T3, T3, -1)
        .bne(T3, ZERO, "kloop");
    if gemm {
        // out = (acc << 1) + 3*C[i][j]
        a.slli(T2, T2, 1);
        a.lx(sew, T5, 0, S8);
        a.slli(T6, T5, 1).add(T5, T5, T6); // 3*c
        debug_assert_eq!(GEMM_BETA, 3);
        a.add(T2, T2, T5).addi(S8, S8, sb);
    }
    a.sx(sew, T2, 0, S7);
    a.addi(S7, S7, sb)
        .addi(T4, T4, sb)
        .addi(S5, S5, -1)
        .bne(S5, ZERO, "jloop")
        .addi(S0, S0, 8 * sb)
        .addi(S3, S3, -1)
        .bne(S3, ZERO, "iloop")
        .ebreak();
}

/// Valid 2D convolution A[8,n] ⊛ F[f,f] with non-unrolled filter loops.
fn conv2d_kernel(a: &mut Asm, n: u32, f: u32, sew: Sew) {
    let sb = sew.bytes() as i32;
    let rowb = (n * sew.bytes()) as i32;
    let orows = 8 - f as i32 + 1;
    let ocols = n as i32 - f as i32 + 1;
    a.li(S0, A_BASE as i32) // image row-0 pointer for output row r
        .li(S1, B_BASE as i32) // filter base
        .li(S7, OUT_BASE as i32) // out pointer
        .li(S3, orows) // r counter
        .li(S6, rowb) // image row stride
        .label("rloop")
        .mv(S4, S0) // window column pointer
        .li(S5, ocols) // c counter
        .label("cloop")
        .li(T2, 0) // acc
        .mv(S9, S1) // filter walker
        .mv(S10, S4) // window row pointer
        .li(T3, f as i32) // dy counter
        .label("dyloop")
        .mv(T0, S10) // window element walker
        .li(T6, f as i32) // dx counter
        .label("dxloop");
    a.lx(sew, T5, 0, T0);
    a.lx(sew, T1, 0, S9);
    a.mul(T5, T5, T1)
        .add(T2, T2, T5)
        .addi(T0, T0, sb)
        .addi(S9, S9, sb)
        .addi(T6, T6, -1)
        .bne(T6, ZERO, "dxloop")
        .add(S10, S10, S6)
        .addi(T3, T3, -1)
        .bne(T3, ZERO, "dyloop");
    a.sx(sew, T2, 0, S7);
    a.addi(S7, S7, sb)
        .addi(S4, S4, sb)
        .addi(S5, S5, -1)
        .bne(S5, ZERO, "cloop")
        .add(S0, S0, S6)
        .addi(S3, S3, -1)
        .bne(S3, ZERO, "rloop")
        .ebreak();
}

/// ReLU / leaky ReLU with the data-dependent branch the paper attributes
/// the CPU's poor showing to.
fn relu_kernel(a: &mut Asm, n: u32, sew: Sew, leaky: bool) {
    let sb = sew.bytes() as i32;
    a.li(A0, A_BASE as i32)
        .li(A2, OUT_BASE as i32)
        .li(A3, (A_BASE + n * sew.bytes()) as i32)
        .label("loop");
    a.lx(sew, T0, 0, A0);
    a.bge(T0, ZERO, "store");
    if leaky {
        a.srai(T0, T0, LEAKY_SHIFT as i32);
    } else {
        a.li(T0, 0);
    }
    a.label("store");
    a.sx(sew, T0, 0, A2);
    a.addi(A0, A0, sb)
        .addi(A2, A2, sb)
        .bne(A0, A3, "loop")
        .ebreak();
}

/// 2×2/stride-2 max pooling over a 16×n image, generic window loops with
/// compare-and-branch max (the paper's baseline idiom).
fn maxpool_kernel(a: &mut Asm, n: u32, sew: Sew) {
    let sb = sew.bytes() as i32;
    let rowb = (n * sew.bytes()) as i32;
    let min_val = match sew {
        Sew::E8 => -128,
        Sew::E16 => -32768,
        Sew::E32 => i32::MIN,
    };
    a.li(S0, A_BASE as i32) // window row-0 base for output row r
        .li(S7, OUT_BASE as i32)
        .li(S3, 8) // r counter (16/2)
        .li(S6, rowb)
        .label("rloop")
        .mv(S4, S0) // window pointer
        .li(S5, (n / 2) as i32) // c counter
        .label("cloop")
        .li(T2, min_val) // acc = min
        .mv(S10, S4) // window row pointer
        .li(T3, 2) // dy
        .label("dyloop")
        .mv(T0, S10)
        .li(T6, 2) // dx
        .label("dxloop");
    a.lx(sew, T5, 0, T0);
    a.bge(T2, T5, "skip") // keep acc if acc >= x
        .mv(T2, T5)
        .label("skip")
        .addi(T0, T0, sb)
        .addi(T6, T6, -1)
        .bne(T6, ZERO, "dxloop")
        .add(S10, S10, S6)
        .addi(T3, T3, -1)
        .bne(T3, ZERO, "dyloop");
    a.sx(sew, T2, 0, S7);
    a.addi(S7, S7, sb)
        .addi(S4, S4, 2 * sb)
        .addi(S5, S5, -1)
        .bne(S5, ZERO, "cloop")
        .add(S0, S0, S6)
        .add(S0, S0, S6) // advance two image rows
        .addi(S3, S3, -1)
        .bne(S3, ZERO, "rloop")
        .ebreak();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::golden;

    fn check(kernel: Kernel, sew: Sew) -> RunResult {
        let data = golden::generate(kernel, sew, 99);
        let res = run(kernel, sew, &data);
        assert_eq!(res.output, data.expect, "{kernel:?} {sew}");
        res
    }

    #[test]
    fn xor_all_widths_correct_and_timed() {
        for sew in Sew::ALL {
            let res = check(Kernel::Xor { n: 256 }, sew);
            // ≈10 cycles per word.
            let words = (256 * sew.bytes() / 4) as f64;
            let cpw = res.cycles as f64 / words;
            assert!((9.0..11.5).contains(&cpw), "{sew}: {cpw:.2} c/word");
        }
    }

    #[test]
    fn add_swar_8bit() {
        let res = check(Kernel::Add { n: 512 }, Sew::E8);
        let cpe = res.cycles_per_output();
        assert!((3.0..4.6).contains(&cpe), "8-bit add: {cpe:.2} c/el (paper: 4.0)");
        check(Kernel::Add { n: 128 }, Sew::E16);
        check(Kernel::Add { n: 128 }, Sew::E32);
    }

    #[test]
    fn mul_element_loops() {
        for sew in Sew::ALL {
            let res = check(Kernel::Mul { n: 128 }, sew);
            let cpe = res.cycles_per_output();
            assert!((9.0..12.5).contains(&cpe), "{sew} mul: {cpe:.2} c/el (paper ≈11)");
        }
    }

    #[test]
    fn matmul_and_gemm() {
        for sew in Sew::ALL {
            let res = check(Kernel::Matmul { p: 16 }, sew);
            let cpe = res.cycles_per_output();
            assert!((75.0..120.0).contains(&cpe), "{sew} matmul: {cpe:.2} c/out (paper 89–112)");
        }
        check(Kernel::Gemm { p: 16 }, Sew::E8);
        check(Kernel::Gemm { p: 8 }, Sew::E32);
    }

    #[test]
    fn conv2d_small() {
        for (sew, f) in [(Sew::E8, 3), (Sew::E16, 4), (Sew::E32, 3)] {
            let res = check(Kernel::Conv2d { n: 32, f }, sew);
            let cpe = res.cycles_per_output();
            assert!(cpe > 60.0 && cpe < 260.0, "{sew} conv f={f}: {cpe:.2} c/out");
        }
    }

    #[test]
    fn relu_and_leaky() {
        for sew in Sew::ALL {
            check(Kernel::Relu { n: 256 }, sew);
            check(Kernel::LeakyRelu { n: 256 }, sew);
        }
    }

    #[test]
    fn maxpool() {
        for sew in Sew::ALL {
            let res = check(Kernel::Maxpool { n: 32 }, sew);
            let cpe = res.cycles_per_output();
            assert!((35.0..75.0).contains(&cpe), "{sew} maxpool: {cpe:.2} c/out (paper 50–65)");
        }
    }
}
