//! Deterministic input generation + golden reference semantics for the
//! benchmark kernels.
//!
//! All three execution targets consume the byte arrays produced here, and
//! their outputs must match [`WorkloadData::expect`] bit-exactly. The same
//! semantics are implemented in pure-jnp in `python/compile/kernels/ref.py`
//! and AOT-compiled through JAX/Pallas; `rust/tests/golden_runtime.rs`
//! closes the loop by executing the HLO artifacts via PJRT and comparing.
//!
//! Arithmetic convention: elements are 2's-complement of the kernel SEW;
//! accumulating kernels (matmul/GEMM/conv) accumulate **mod 2^sew** — the
//! natural semantics of the packed datapaths, and identical to truncating
//! an int32 accumulation at the end.

use super::Kernel;
use crate::isa::Sew;

// The splitmix64 generator lives with the rest of the random-generation
// machinery in `fuzz::gen`; re-exported here because every consumer of
// golden data reaches for `golden::Rng`.
pub use crate::fuzz::gen::Rng;

/// Pack an element array (sign-agnostic, low bits) into little-endian bytes.
pub fn pack(vals: &[i64], sew: Sew) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * sew.bytes() as usize);
    for &v in vals {
        match sew {
            Sew::E8 => out.push(v as u8),
            Sew::E16 => out.extend_from_slice(&(v as u16).to_le_bytes()),
            Sew::E32 => out.extend_from_slice(&(v as u32).to_le_bytes()),
        }
    }
    out
}

/// Unpack little-endian bytes into sign-extended elements.
pub fn unpack(bytes: &[u8], sew: Sew) -> Vec<i64> {
    let sz = sew.bytes() as usize;
    bytes
        .chunks(sz)
        .map(|c| match sew {
            Sew::E8 => c[0] as i8 as i64,
            Sew::E16 => i16::from_le_bytes([c[0], c[1]]) as i64,
            Sew::E32 => i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as i64,
        })
        .collect()
}

/// Truncate to SEW (mod 2^sew) and sign-extend back — the wrap semantics.
#[inline]
pub fn wrap(v: i64, sew: Sew) -> i64 {
    match sew {
        Sew::E8 => v as i8 as i64,
        Sew::E16 => v as i16 as i64,
        Sew::E32 => v as i32 as i64,
    }
}

/// Inputs + expected output of one kernel instance.
#[derive(Debug, Clone)]
pub struct WorkloadData {
    /// First operand (A / input image / x).
    pub a: Vec<u8>,
    /// Second operand (B / filter), empty when unused.
    pub b: Vec<u8>,
    /// Third operand (GEMM C), empty when unused.
    pub c: Vec<u8>,
    /// Expected canonical output.
    pub expect: Vec<u8>,
}

/// GEMM constants (powers of two / small so every target can compute them
/// without a hardware multiplier: α·x = x<<1, β·x = (x<<1)+x).
pub const GEMM_ALPHA: i64 = 2;
pub const GEMM_BETA: i64 = 3;
/// Leaky-ReLU negative-slope shift (slope 1/8).
pub const LEAKY_SHIFT: u32 = 3;

/// The golden semantics of one kernel over sign-extended element arrays:
/// `a`/`b`/`c` are the operands in [`generate`]'s layout (unused ones
/// empty), the return value is the canonical output. Factored out of
/// [`generate`] so multi-layer chains ([`crate::graph`]) can feed one
/// kernel's output into the next without re-deriving operands from a seed.
pub fn compute(kernel: Kernel, sew: Sew, a: &[i64], b: &[i64], c: &[i64]) -> Vec<i64> {
    match kernel {
        Kernel::Xor { .. } | Kernel::Add { .. } | Kernel::Mul { .. } => a
            .iter()
            .zip(b)
            .map(|(&x, &y)| match kernel {
                Kernel::Xor { .. } => wrap(x ^ y, sew),
                Kernel::Add { .. } => wrap(x + y, sew),
                _ => wrap(x * y, sew),
            })
            .collect(),
        Kernel::Matmul { p } | Kernel::Gemm { p } => {
            let is_gemm = matches!(kernel, Kernel::Gemm { .. });
            let mut out = vec![0i64; 8 * p as usize];
            for i in 0..8usize {
                for j in 0..p as usize {
                    let mut acc: i64 = 0;
                    for k in 0..8usize {
                        acc = wrap(acc + wrap(a[i * 8 + k] * b[k * p as usize + j], sew), sew);
                    }
                    out[i * p as usize + j] = if is_gemm {
                        wrap(
                            wrap(GEMM_ALPHA * acc, sew) + wrap(GEMM_BETA * c[i * p as usize + j], sew),
                            sew,
                        )
                    } else {
                        acc
                    };
                }
            }
            out
        }
        Kernel::Conv2d { n, f } => {
            let rows = 8usize;
            let (n, f) = (n as usize, f as usize);
            let (orows, ocols) = (rows - f + 1, n - f + 1);
            let mut out = vec![0i64; orows * ocols];
            for r in 0..orows {
                for c in 0..ocols {
                    let mut acc = 0i64;
                    for dy in 0..f {
                        for dx in 0..f {
                            acc = wrap(acc + wrap(a[(r + dy) * n + c + dx] * b[dy * f + dx], sew), sew);
                        }
                    }
                    out[r * ocols + c] = acc;
                }
            }
            out
        }
        Kernel::Relu { .. } | Kernel::LeakyRelu { .. } => a
            .iter()
            .map(|&x| {
                if x >= 0 {
                    x
                } else if matches!(kernel, Kernel::Relu { .. }) {
                    0
                } else {
                    x >> LEAKY_SHIFT
                }
            })
            .collect(),
        Kernel::Maxpool { n } => {
            let rows = 16usize;
            let n = n as usize;
            let (orows, ocols) = (rows / 2, n / 2);
            let mut out = vec![0i64; orows * ocols];
            for r in 0..orows {
                for c in 0..ocols {
                    let m = a[2 * r * n + 2 * c]
                        .max(a[2 * r * n + 2 * c + 1])
                        .max(a[(2 * r + 1) * n + 2 * c])
                        .max(a[(2 * r + 1) * n + 2 * c + 1]);
                    out[r * ocols + c] = m;
                }
            }
            out
        }
    }
}

/// Generate inputs and the expected output for a kernel instance.
pub fn generate(kernel: Kernel, sew: Sew, seed: u64) -> WorkloadData {
    let mut rng = Rng(seed ^ 0xabcd_ef01_2345_6789);
    let (a, b, c): (Vec<i64>, Vec<i64>, Vec<i64>) = match kernel {
        Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n } => (
            (0..n).map(|_| rng.elem(sew)).collect(),
            (0..n).map(|_| rng.elem(sew)).collect(),
            vec![],
        ),
        Kernel::Matmul { p } | Kernel::Gemm { p } => {
            let a = (0..64).map(|_| rng.elem(sew)).collect(); // A[8,8]
            let b = (0..8 * p).map(|_| rng.elem(sew)).collect(); // B[8,p] row-major
            let c = if matches!(kernel, Kernel::Gemm { .. }) {
                (0..8 * p).map(|_| rng.elem(sew)).collect()
            } else {
                vec![]
            };
            (a, b, c)
        }
        Kernel::Conv2d { n, f } => (
            (0..8 * n).map(|_| rng.elem(sew)).collect(),
            (0..f * f).map(|_| rng.elem(sew)).collect(),
            vec![],
        ),
        Kernel::Relu { n } | Kernel::LeakyRelu { n } => {
            ((0..n).map(|_| rng.elem(sew)).collect(), vec![], vec![])
        }
        Kernel::Maxpool { n } => ((0..16 * n).map(|_| rng.elem(sew)).collect(), vec![], vec![]),
    };
    let out = compute(kernel, sew, &a, &b, &c);
    WorkloadData { a: pack(&a, sew), b: pack(&b, sew), c: pack(&c, sew), expect: pack(&out, sew) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let d1 = generate(Kernel::Add { n: 64 }, Sew::E16, 7);
        let d2 = generate(Kernel::Add { n: 64 }, Sew::E16, 7);
        assert_eq!(d1.a, d2.a);
        assert_eq!(d1.expect, d2.expect);
        let d3 = generate(Kernel::Add { n: 64 }, Sew::E16, 8);
        assert_ne!(d1.a, d3.a);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        for sew in Sew::ALL {
            let vals: Vec<i64> = vec![-1, 0, 1, 127, -128];
            let bytes = pack(&vals, sew);
            assert_eq!(unpack(&bytes, sew), vals.iter().map(|&v| wrap(v, sew)).collect::<Vec<_>>());
        }
    }

    #[test]
    fn add_wraps() {
        // 8-bit: 127 + 1 = -128.
        assert_eq!(wrap(127 + 1, Sew::E8), -128);
        assert_eq!(wrap(0x7fff + 1, Sew::E16), -0x8000);
    }

    #[test]
    fn matmul_small_by_hand() {
        // Identity-like check with controlled inputs via a fixed seed: just
        // verify shape and mod-arithmetic consistency with i32 accumulation.
        let d = generate(Kernel::Matmul { p: 4 }, Sew::E8, 42);
        let a = unpack(&d.a, Sew::E8);
        let b = unpack(&d.b, Sew::E8);
        let out = unpack(&d.expect, Sew::E8);
        assert_eq!(out.len(), 32);
        // Recompute one element with i64 accumulation then wrap: must match
        // (wrap-at-each-step == wrap-at-end for mod-2^k arithmetic).
        let mut acc = 0i64;
        for k in 0..8 {
            acc += a[k] * b[k * 4];
        }
        assert_eq!(wrap(acc, Sew::E8), out[0]);
    }

    #[test]
    fn maxpool_shape() {
        let d = generate(Kernel::Maxpool { n: 8 }, Sew::E32, 1);
        assert_eq!(unpack(&d.expect, Sew::E32).len(), 8 * 4);
    }
}
