//! End-to-end tests for `heeperator serve` (DESIGN.md §12): the
//! virtual-time selftest path must be byte-deterministic and its
//! percentiles sane; admission control must reject overload with typed
//! responses instead of dropping or panicking; the three scheduler
//! staging paths that used to panic must now surface as per-request
//! error responses that the service survives; and the threaded live
//! path (in-process pipes and a real TCP socket) must answer every
//! request line exactly once.

use nmc::isa::Sew;
use nmc::kernels::{Kernel, Target};
use nmc::sched::{arm_tile_fault, TileFault};
use nmc::serve::{
    self, load, parse_request, render_request, run_trace, selftest, summary_json, Request,
    Response, ServeConfig,
};

fn req(id: u64, target: Target, kernel: Kernel, sew: Sew) -> Request {
    Request { id, target, kernel, sew, seed: id }
}

fn render_all(responses: &[Response]) -> String {
    let mut s = String::new();
    for r in responses {
        s.push_str(&r.render());
        s.push('\n');
    }
    s
}

#[test]
fn selftest_is_byte_deterministic_across_runs() {
    let cfg = ServeConfig::default();
    for kind in [load::TraceKind::Poisson, load::TraceKind::Bursty, load::TraceKind::Mixed] {
        let (stats_a, resp_a) = selftest(&cfg, kind, 7, 48);
        let (stats_b, resp_b) = selftest(&cfg, kind, 7, 48);
        assert_eq!(render_all(&resp_a), render_all(&resp_b), "{kind:?}: response bytes");
        assert_eq!(
            summary_json(&stats_a, &cfg, kind.slug(), 7),
            summary_json(&stats_b, &cfg, kind.slug(), 7),
            "{kind:?}: summary bytes"
        );
    }
}

#[test]
fn selftest_percentiles_are_monotonic_and_counts_add_up() {
    let cfg = ServeConfig::default();
    for kind in [load::TraceKind::Poisson, load::TraceKind::Bursty, load::TraceKind::Mixed] {
        let (stats, responses) = selftest(&cfg, kind, 3, 48);
        let p50 = stats.latency_percentile(0.50);
        let p95 = stats.latency_percentile(0.95);
        let p99 = stats.latency_percentile(0.99);
        assert!(p50 <= p95 && p95 <= p99 && p99 <= stats.latency_max(), "{kind:?}");
        assert_eq!(
            stats.completed + stats.rejected + stats.errored,
            stats.requests,
            "{kind:?}: every request answered exactly once"
        );
        assert_eq!(responses.len() as u64, stats.requests, "{kind:?}");
        // Every generated id comes back exactly once.
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id()).collect();
        ids.sort_unstable();
        assert_eq!(ids, (1..=48).collect::<Vec<u64>>(), "{kind:?}");
        // The generated traces are all well-formed, so nothing errors.
        assert_eq!(stats.errored, 0, "{kind:?}");
        assert!(stats.mean_batch_size() >= 1.0, "{kind:?}");
    }
}

#[test]
fn no_rejections_when_the_queue_can_hold_the_whole_trace() {
    // Admission control can only fire when arrivals outrun the queue;
    // with capacity >= the request count a drop is impossible.
    let cfg = ServeConfig { queue_cap: 256, ..Default::default() };
    let (stats, responses) = selftest(&cfg, load::TraceKind::Bursty, 5, 64);
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.completed, 64);
    assert!(responses.iter().all(|r| matches!(r, Response::Ok { .. })));
}

#[test]
fn overload_yields_typed_rejections_never_panics() {
    // 12 coalescible requests land on the same cycle with room for 4:
    // exactly 8 must bounce with the overload response, and the 4
    // admitted ones must still complete.
    let cfg = ServeConfig { tiles: 2, queue_cap: 4, ..Default::default() };
    let trace: Vec<(u64, Request)> = (1..=12)
        .map(|id| (0, req(id, Target::Carus, Kernel::Add { n: 64 }, Sew::E32)))
        .collect();
    let mut responses = Vec::new();
    let stats = run_trace(&cfg, &trace, |r| responses.push(r.clone()));
    assert_eq!(stats.rejected, 8, "requests beyond the queue cap are rejected");
    assert_eq!(stats.completed, 4);
    assert_eq!(stats.errored, 0);
    let rejects: Vec<&Response> =
        responses.iter().filter(|r| matches!(r, Response::Rejected { .. })).collect();
    assert_eq!(rejects.len(), 8);
    for r in rejects {
        let line = r.render();
        assert!(line.contains("\"reason\":\"overload\""), "{line}");
        assert!(line.contains("\"queue_depth\":4"), "{line}");
    }
}

#[test]
fn former_scheduler_panic_paths_surface_as_error_responses() {
    // Each of these faults hits a staging path that used to `.expect` or
    // `assert!` inside the planner; the service must answer with a typed
    // error response and keep running. Faults are thread-local and
    // `run_trace` executes on the calling thread, so the injection is
    // visible and cannot leak into parallel tests.
    let carus = [(0u64, req(1, Target::Carus, Kernel::Add { n: 64 }, Sew::E32))];
    let caesar = [(0u64, req(1, Target::Caesar, Kernel::Add { n: 64 }, Sew::E32))];
    let cases: [(&[(u64, Request)], TileFault, &str); 5] = [
        (&caesar, TileFault::StreamProgram, "no tiled execute path"),
        (&carus, TileFault::Io, "no tiled execute path"),
        (&carus, TileFault::ArgsProgram, "no tiled execute path"),
        (&carus, TileFault::Misalign, "not word-aligned"),
        (&carus, TileFault::MisalignOut, "not word-aligned"),
    ];
    let cfg = ServeConfig { tiles: 2, ..Default::default() };
    for (trace, fault, needle) in cases {
        arm_tile_fault(Some(fault));
        let mut responses = Vec::new();
        let stats = run_trace(&cfg, trace, |r| responses.push(r.clone()));
        arm_tile_fault(None);
        assert_eq!(stats.errored, 1, "{fault:?}");
        assert_eq!(stats.completed, 0, "{fault:?}");
        assert_eq!(responses.len(), 1, "{fault:?}");
        let line = responses[0].render();
        assert!(line.contains("\"status\":\"error\""), "{fault:?}: {line}");
        assert!(line.contains(needle), "{fault:?}: {line}");
        // The service survives: the same trace runs clean once disarmed.
        let clean = run_trace(&cfg, trace, |_| {});
        assert_eq!(clean.completed, 1, "{fault:?}: service must recover");
    }
}

#[test]
fn serve_stream_answers_every_line_over_an_in_process_pipe() {
    let cfg = ServeConfig { tiles: 2, queue_cap: 256, ..Default::default() };
    let mut input = String::new();
    for id in 1..=6u64 {
        let r = req(id, Target::Carus, Kernel::Add { n: 32 * id as u32 }, Sew::E8);
        input.push_str(&render_request(&r));
        input.push('\n');
    }
    // A malformed line must come back as a typed error, not kill the
    // listener (the CPU is never a serve target).
    input.push_str("{\"id\":99,\"target\":\"cpu\",\"family\":\"add\",\"sew\":8,\"n\":64}\n");
    let mut output: Vec<u8> = Vec::new();
    let stats = serve::serve_stream(&cfg, std::io::Cursor::new(input.into_bytes()), &mut output);
    assert_eq!(stats.requests, 7);
    assert_eq!(stats.completed, 6);
    assert_eq!(stats.errored, 1);
    assert_eq!(stats.rejected, 0);
    let text = String::from_utf8(output).expect("responses are UTF-8 JSONL");
    assert_eq!(text.lines().count(), 7, "one response per line:\n{text}");
    for id in 1..=6u64 {
        assert!(
            text.lines().any(|l| l.contains(&format!("\"id\":{id},\"status\":\"ok\""))),
            "id {id} answered ok:\n{text}"
        );
    }
    assert!(text.contains("\"id\":99,\"status\":\"error\""), "{text}");
}

#[test]
fn serve_one_tcp_round_trips_a_real_socket() {
    use std::io::{BufRead, BufReader, Write};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().unwrap();
    let cfg = ServeConfig { tiles: 2, ..Default::default() };
    let server = std::thread::spawn(move || serve::serve_one_tcp(&cfg, &listener));

    let mut client = std::net::TcpStream::connect(addr).expect("connect");
    for id in 1..=3u64 {
        let r = req(id, Target::Caesar, Kernel::Add { n: 64 }, Sew::E32);
        writeln!(client, "{}", render_request(&r)).expect("send request");
    }
    client.shutdown(std::net::Shutdown::Write).expect("half-close");
    let mut lines = Vec::new();
    for line in BufReader::new(&client).lines() {
        lines.push(line.expect("read response"));
    }
    let stats = server.join().expect("server thread").expect("tcp session");
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.completed, 3);
    assert_eq!(lines.len(), 3, "{lines:?}");
    for id in 1..=3u64 {
        assert!(
            lines.iter().any(|l| l.contains(&format!("\"id\":{id},\"status\":\"ok\""))),
            "id {id} answered: {lines:?}"
        );
    }
}

#[test]
fn request_lines_round_trip_through_the_wire_format() {
    // The load generator feeds the live path through render_request, so
    // the inverse property is part of the serve contract, not just a
    // unit detail.
    for kind in [load::TraceKind::Poisson, load::TraceKind::Mixed] {
        for (_, r) in load::gen_trace(kind, 11, 32) {
            let line = render_request(&r);
            assert_eq!(parse_request(&line), Ok(r), "{line}");
        }
    }
}
