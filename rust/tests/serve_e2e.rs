//! End-to-end tests for `heeperator serve` (DESIGN.md §12): the
//! virtual-time selftest path must be byte-deterministic and its
//! percentiles sane; admission control must reject overload with typed
//! responses instead of dropping or panicking; the three scheduler
//! staging paths that used to panic must now surface as per-request
//! error responses that the service survives; and the threaded live
//! path (in-process pipes and a real TCP socket) must answer every
//! request line exactly once.

use nmc::isa::Sew;
use nmc::kernels::{Kernel, Target};
use nmc::sched::{arm_tile_fault, TileFault};
use nmc::serve::{
    self, load, parse_request, render_request, run_closed, run_trace, selftest, summary_json,
    Request, Response, ServeConfig,
};

fn req(id: u64, target: Target, kernel: Kernel, sew: Sew) -> Request {
    Request { id, target, kernel, sew, seed: id, model: None }
}

fn render_all(responses: &[Response]) -> String {
    let mut s = String::new();
    for r in responses {
        s.push_str(&r.render());
        s.push('\n');
    }
    s
}

#[test]
fn selftest_is_byte_deterministic_across_runs() {
    let cfg = ServeConfig::default();
    for kind in [load::TraceKind::Poisson, load::TraceKind::Bursty, load::TraceKind::Mixed] {
        let (stats_a, resp_a) = selftest(&cfg, kind, 7, 48);
        let (stats_b, resp_b) = selftest(&cfg, kind, 7, 48);
        assert_eq!(render_all(&resp_a), render_all(&resp_b), "{kind:?}: response bytes");
        assert_eq!(
            summary_json(&stats_a, &cfg, kind.slug(), 7),
            summary_json(&stats_b, &cfg, kind.slug(), 7),
            "{kind:?}: summary bytes"
        );
    }
}

#[test]
fn selftest_percentiles_are_monotonic_and_counts_add_up() {
    let cfg = ServeConfig::default();
    for kind in [load::TraceKind::Poisson, load::TraceKind::Bursty, load::TraceKind::Mixed] {
        let (stats, responses) = selftest(&cfg, kind, 3, 48);
        let p50 = stats.latency_percentile(0.50);
        let p95 = stats.latency_percentile(0.95);
        let p99 = stats.latency_percentile(0.99);
        assert!(p50 <= p95 && p95 <= p99 && p99 <= stats.latency_max(), "{kind:?}");
        assert_eq!(
            stats.completed + stats.rejected + stats.errored,
            stats.requests,
            "{kind:?}: every request answered exactly once"
        );
        assert_eq!(responses.len() as u64, stats.requests, "{kind:?}");
        // Every generated id comes back exactly once.
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id()).collect();
        ids.sort_unstable();
        assert_eq!(ids, (1..=48).collect::<Vec<u64>>(), "{kind:?}");
        // The generated traces are all well-formed, so nothing errors.
        assert_eq!(stats.errored, 0, "{kind:?}");
        assert!(stats.mean_batch_size() >= 1.0, "{kind:?}");
    }
}

#[test]
fn no_rejections_when_the_queue_can_hold_the_whole_trace() {
    // Admission control can only fire when arrivals outrun the queue;
    // with capacity >= the request count a drop is impossible.
    let cfg = ServeConfig { queue_cap: 256, ..Default::default() };
    let (stats, responses) = selftest(&cfg, load::TraceKind::Bursty, 5, 64);
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.completed, 64);
    assert!(responses.iter().all(|r| matches!(r, Response::Ok { .. })));
}

#[test]
fn overload_yields_typed_rejections_never_panics() {
    // 12 coalescible requests land on the same cycle with room for 4:
    // exactly 8 must bounce with the overload response, and the 4
    // admitted ones must still complete.
    let cfg = ServeConfig { tiles: 2, queue_cap: 4, ..Default::default() };
    let trace: Vec<(u64, Request)> = (1..=12)
        .map(|id| (0, req(id, Target::Carus, Kernel::Add { n: 64 }, Sew::E32)))
        .collect();
    let mut responses = Vec::new();
    let stats = run_trace(&cfg, &trace, |r| responses.push(r.clone()));
    assert_eq!(stats.rejected, 8, "requests beyond the queue cap are rejected");
    assert_eq!(stats.completed, 4);
    assert_eq!(stats.errored, 0);
    let rejects: Vec<&Response> =
        responses.iter().filter(|r| matches!(r, Response::Rejected { .. })).collect();
    assert_eq!(rejects.len(), 8);
    for r in rejects {
        let line = r.render();
        assert!(line.contains("\"reason\":\"overload\""), "{line}");
        assert!(line.contains("\"queue_depth\":4"), "{line}");
    }
}

#[test]
fn former_scheduler_panic_paths_surface_as_error_responses() {
    // Each of these faults hits a staging path that used to `.expect` or
    // `assert!` inside the planner; the service must answer with a typed
    // error response and keep running. Faults are thread-local and
    // `run_trace` executes on the calling thread, so the injection is
    // visible and cannot leak into parallel tests.
    let carus = [(0u64, req(1, Target::Carus, Kernel::Add { n: 64 }, Sew::E32))];
    let caesar = [(0u64, req(1, Target::Caesar, Kernel::Add { n: 64 }, Sew::E32))];
    let cases: [(&[(u64, Request)], TileFault, &str); 5] = [
        (&caesar, TileFault::StreamProgram, "no tiled execute path"),
        (&carus, TileFault::Io, "no tiled execute path"),
        (&carus, TileFault::ArgsProgram, "no tiled execute path"),
        (&carus, TileFault::Misalign, "not word-aligned"),
        (&carus, TileFault::MisalignOut, "not word-aligned"),
    ];
    let cfg = ServeConfig { tiles: 2, ..Default::default() };
    for (trace, fault, needle) in cases {
        arm_tile_fault(Some(fault));
        let mut responses = Vec::new();
        let stats = run_trace(&cfg, trace, |r| responses.push(r.clone()));
        arm_tile_fault(None);
        assert_eq!(stats.errored, 1, "{fault:?}");
        assert_eq!(stats.completed, 0, "{fault:?}");
        assert_eq!(responses.len(), 1, "{fault:?}");
        let line = responses[0].render();
        assert!(line.contains("\"status\":\"error\""), "{fault:?}: {line}");
        assert!(line.contains(needle), "{fault:?}: {line}");
        // The service survives: the same trace runs clean once disarmed.
        let clean = run_trace(&cfg, trace, |_| {});
        assert_eq!(clean.completed, 1, "{fault:?}: service must recover");
    }
}

#[test]
fn serve_stream_answers_every_line_over_an_in_process_pipe() {
    let cfg = ServeConfig { tiles: 2, queue_cap: 256, ..Default::default() };
    let mut input = String::new();
    for id in 1..=6u64 {
        let r = req(id, Target::Carus, Kernel::Add { n: 32 * id as u32 }, Sew::E8);
        input.push_str(&render_request(&r));
        input.push('\n');
    }
    // A malformed line must come back as a typed error, not kill the
    // listener (the CPU is never a serve target).
    input.push_str("{\"id\":99,\"target\":\"cpu\",\"family\":\"add\",\"sew\":8,\"n\":64}\n");
    let mut output: Vec<u8> = Vec::new();
    let stats = serve::serve_stream(&cfg, std::io::Cursor::new(input.into_bytes()), &mut output);
    assert_eq!(stats.requests, 7);
    assert_eq!(stats.completed, 6);
    assert_eq!(stats.errored, 1);
    assert_eq!(stats.rejected, 0);
    let text = String::from_utf8(output).expect("responses are UTF-8 JSONL");
    assert_eq!(text.lines().count(), 7, "one response per line:\n{text}");
    for id in 1..=6u64 {
        assert!(
            text.lines().any(|l| l.contains(&format!("\"id\":{id},\"status\":\"ok\""))),
            "id {id} answered ok:\n{text}"
        );
    }
    assert!(text.contains("\"id\":99,\"status\":\"error\""), "{text}");
}

#[test]
fn serve_stream_answers_model_requests_with_per_layer_breakdowns() {
    // `{"model": ...}` lines ride the same admission queue and worker
    // pool as kernel requests, never coalesce with them, and answer with
    // the per-layer cycle breakdown. A malformed graph is a typed error.
    let cfg = ServeConfig { tiles: 2, queue_cap: 256, ..Default::default() };
    let input = concat!(
        "{\"id\":1,\"model\":\"matmul:p=32,add,relu,maxpool\",\"sew\":8}\n",
        "{\"id\":2,\"target\":\"carus\",\"family\":\"add\",\"sew\":8,\"n\":64}\n",
        "{\"id\":3,\"model\":\"matmul:p=32,relu\",\"pipeline\":\"batch\",\"seed\":5}\n",
        "{\"id\":4,\"model\":\"relu,matmul:p=32\"}\n",
    );
    let mut output: Vec<u8> = Vec::new();
    let stats =
        serve::serve_stream(&cfg, std::io::Cursor::new(input.as_bytes().to_vec()), &mut output);
    assert_eq!(stats.requests, 4);
    assert_eq!(stats.completed, 3);
    assert_eq!(stats.errored, 1);
    let text = String::from_utf8(output).expect("responses are UTF-8 JSONL");
    assert_eq!(text.lines().count(), 4, "{text}");
    for id in [1u64, 3] {
        let line = text.lines().find(|l| l.contains(&format!("\"id\":{id},"))).unwrap();
        assert!(line.contains("\"kind\":\"model\""), "{line}");
        assert!(line.contains("\"layers\":[{\"kernel\":\"matmul\""), "{line}");
        assert!(line.contains("\"resident_boundaries\""), "{line}");
    }
    let kernel_line = text.lines().find(|l| l.contains("\"id\":2,")).unwrap();
    assert!(kernel_line.contains("\"status\":\"ok\"") && !kernel_line.contains("\"kind\""));
    let bad = text.lines().find(|l| l.contains("\"id\":4,")).unwrap();
    assert!(bad.contains("\"status\":\"error\"") && bad.contains("bad model"), "{bad}");
}

#[test]
fn serve_one_tcp_round_trips_a_real_socket() {
    use std::io::{BufRead, BufReader, Write};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().unwrap();
    let cfg = ServeConfig { tiles: 2, ..Default::default() };
    let server = std::thread::spawn(move || serve::serve_one_tcp(&cfg, &listener));

    let mut client = std::net::TcpStream::connect(addr).expect("connect");
    for id in 1..=3u64 {
        let r = req(id, Target::Caesar, Kernel::Add { n: 64 }, Sew::E32);
        writeln!(client, "{}", render_request(&r)).expect("send request");
    }
    client.shutdown(std::net::Shutdown::Write).expect("half-close");
    let mut lines = Vec::new();
    for line in BufReader::new(&client).lines() {
        lines.push(line.expect("read response"));
    }
    let stats = server.join().expect("server thread").expect("tcp session");
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.completed, 3);
    assert_eq!(lines.len(), 3, "{lines:?}");
    for id in 1..=3u64 {
        assert!(
            lines.iter().any(|l| l.contains(&format!("\"id\":{id},\"status\":\"ok\""))),
            "id {id} answered: {lines:?}"
        );
    }
}

#[test]
fn serve_tcp_answers_concurrent_clients_exactly_once_and_in_order() {
    use std::io::{BufRead, BufReader, Write};
    const CLIENTS: usize = 4;
    const PER_CLIENT: u64 = 8;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().unwrap();
    let cfg = ServeConfig {
        tiles: 2,
        queue_cap: 256,
        workers: 2,
        conns: CLIENTS,
        ..Default::default()
    };
    let server =
        std::thread::spawn(move || serve::serve_tcp(&cfg, &listener, Some(CLIENTS)));

    let mut clients = Vec::new();
    for c in 0..CLIENTS {
        clients.push(std::thread::spawn(move || -> Vec<String> {
            let mut stream = std::net::TcpStream::connect(addr).expect("connect");
            let reader = BufReader::new(stream.try_clone().expect("clone socket"));
            for id in 1..=PER_CLIENT {
                // Vary shape and family per client so batches mix targets
                // arriving from different connections.
                let kernel = if c % 2 == 0 {
                    Kernel::Add { n: 32 * (1 + (id as u32 % 3)) }
                } else {
                    Kernel::Mul { n: 64 }
                };
                let r = req(id, Target::Carus, kernel, Sew::E32);
                writeln!(stream, "{}", render_request(&r)).expect("send request");
            }
            stream.shutdown(std::net::Shutdown::Write).expect("half-close");
            reader.lines().map(|l| l.expect("read response")).collect()
        }));
    }
    let per_client_lines: Vec<Vec<String>> =
        clients.into_iter().map(|c| c.join().expect("client thread")).collect();
    let stats = server.join().expect("server thread").expect("tcp serve");

    // Answered exactly once, globally...
    assert_eq!(stats.requests, (CLIENTS as u64) * PER_CLIENT);
    assert_eq!(stats.completed + stats.rejected + stats.errored, stats.requests);
    assert_eq!(stats.errored, 0, "well-formed requests never error");
    assert_eq!(stats.rejected, 0, "queue cap 256 holds the whole load");
    // ...and per connection, in that connection's request order.
    for (c, lines) in per_client_lines.iter().enumerate() {
        assert_eq!(lines.len(), PER_CLIENT as usize, "client {c}: {lines:?}");
        for (i, line) in lines.iter().enumerate() {
            assert!(
                line.contains(&format!("\"id\":{},\"status\":\"ok\"", i as u64 + 1)),
                "client {c} line {i} out of order: {line}"
            );
        }
    }
}

#[test]
fn serve_tcp_turns_away_the_connection_past_the_cap_with_a_typed_busy_line() {
    use std::io::{BufRead, BufReader, Write};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().unwrap();
    let cfg = ServeConfig { tiles: 2, conns: 1, ..Default::default() };
    let server = std::thread::spawn(move || serve::serve_tcp(&cfg, &listener, Some(2)));

    // Client A takes the only slot and proves it by completing a request.
    let mut a = std::net::TcpStream::connect(addr).expect("connect A");
    let mut a_reader = BufReader::new(a.try_clone().expect("clone A"));
    writeln!(a, "{}", render_request(&req(1, Target::Caesar, Kernel::Add { n: 64 }, Sew::E32)))
        .expect("A sends");
    let mut first = String::new();
    a_reader.read_line(&mut first).expect("A's first response");
    assert!(first.contains("\"id\":1,\"status\":\"ok\""), "{first}");

    // Client B arrives past the cap: exactly one typed busy line, then EOF.
    let b = std::net::TcpStream::connect(addr).expect("connect B");
    let b_lines: Vec<String> =
        BufReader::new(b).lines().map(|l| l.expect("read B")).collect();
    assert_eq!(b_lines.len(), 1, "{b_lines:?}");
    assert!(b_lines[0].contains("\"status\":\"rejected\""), "{b_lines:?}");
    assert!(b_lines[0].contains("\"reason\":\"busy\""), "{b_lines:?}");
    assert!(b_lines[0].contains("\"conns\":1"), "{b_lines:?}");

    // A is unaffected and finishes its session normally.
    writeln!(a, "{}", render_request(&req(2, Target::Caesar, Kernel::Add { n: 64 }, Sew::E32)))
        .expect("A sends again");
    a.shutdown(std::net::Shutdown::Write).expect("half-close A");
    let rest: Vec<String> = a_reader.lines().map(|l| l.expect("read A")).collect();
    assert_eq!(rest.len(), 1, "{rest:?}");
    assert!(rest[0].contains("\"id\":2,\"status\":\"ok\""), "{rest:?}");

    let stats = server.join().expect("server thread").expect("tcp serve");
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.completed, 2);
}

#[test]
fn closed_loop_selftest_is_deterministic_and_answers_every_attempt() {
    let cfg = ServeConfig::default();
    let (stats_a, resp_a) = run_closed(&cfg, 11, 96);
    let (stats_b, resp_b) = run_closed(&cfg, 11, 96);
    assert_eq!(render_all(&resp_a), render_all(&resp_b), "response bytes");
    assert_eq!(
        summary_json(&stats_a, &cfg, "closed", 11),
        summary_json(&stats_b, &cfg, "closed", 11),
        "summary bytes"
    );
    // Every issued attempt (first try or backoff retry) gets exactly one
    // terminal response.
    assert_eq!(stats_a.requests, 96);
    assert_eq!(stats_a.completed + stats_a.rejected + stats_a.errored, 96);
    assert_eq!(stats_a.errored, 0, "generated requests are well-formed");
    assert_eq!(resp_a.len(), 96);
    // Closed loop: never more outstanding than clients, so queue depth is
    // bounded by the fleet size.
    assert!(stats_a.queue_depth_max() as usize <= cfg.conns);
}

#[test]
fn closed_loop_clients_back_off_and_retry_after_rejections() {
    // A one-slot queue under an 8-client fleet guarantees overload: the
    // rejected clients must come back via the backoff path and the budget
    // must still be answered exactly once per attempt.
    let cfg = ServeConfig { tiles: 2, queue_cap: 1, max_batch: 4, conns: 8, ..Default::default() };
    let (stats, responses) = run_closed(&cfg, 3, 64);
    assert_eq!(stats.requests, 64);
    assert_eq!(stats.completed + stats.rejected + stats.errored, 64);
    assert!(stats.rejected > 0, "one queue slot under 8 clients must overload");
    assert!(stats.completed > 0, "backoff retries must eventually land");
    // Retries are new ids: every id 1..=64 answered exactly once.
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id()).collect();
    ids.sort_unstable();
    assert_eq!(ids, (1..=64).collect::<Vec<u64>>());
}

#[test]
fn request_lines_round_trip_through_the_wire_format() {
    // The load generator feeds the live path through render_request, so
    // the inverse property is part of the serve contract, not just a
    // unit detail.
    for kind in [load::TraceKind::Poisson, load::TraceKind::Mixed] {
        for (_, r) in load::gen_trace(kind, 11, 32) {
            let line = render_request(&r);
            assert_eq!(parse_request(&line), Ok(r), "{line}");
        }
    }
}
