//! Golden-runtime cross-checks: the simulated hardware vs the AOT-compiled
//! JAX/Pallas artifacts, executed via PJRT.
//!
//! The chain verified here:
//!   simulator (cycle model, packed datapaths)
//!     == Rust golden reference (kernels::golden)
//!     == Pallas kernels (python, AOT-lowered)
//! Each test generates a workload, runs the artifact through the PJRT CPU
//! client, and compares bit-exactly with the Rust golden expectation — the
//! same expectation every simulator target is asserted against in
//! `kernels::run`. Requires `make artifacts`; tests skip gracefully when
//! the artifacts have not been built.

use nmc::isa::Sew;
use nmc::kernels::golden::{self, unpack};
use nmc::kernels::{Family, Kernel, Target};
use nmc::runtime::{artifacts_available, Runtime, TensorI32};

fn sew_name(sew: Sew) -> &'static str {
    match sew {
        Sew::E8 => "e8",
        Sew::E16 => "e16",
        Sew::E32 => "e32",
    }
}

/// Graceful-skip gate: `None` (and a note on stderr) when the HLO
/// artifacts have not been built (`make artifacts`) **or** when the crate
/// was built without a PJRT execution backend (the offline, std-only
/// vendor set). Neither condition is a test failure — the simulator's own
/// golden references in `kernels::golden` stay authoritative.
fn need_runtime() -> Option<Runtime> {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    match Runtime::new() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: golden runtime unavailable ({e})");
            None
        }
    }
}

fn elems(bytes: &[u8], sew: Sew) -> Vec<i64> {
    unpack(bytes, sew)
}

#[test]
fn elementwise_artifacts_match_golden() {
    let Some(mut rt) = need_runtime() else { return };
    for sew in Sew::ALL {
        for (fam, name) in [(Family::Xor, "xor"), (Family::Add, "add"), (Family::Mul, "mul")] {
            let kernel = Kernel::paper_default(fam, Target::Cpu, sew);
            let (Kernel::Xor { n } | Kernel::Add { n } | Kernel::Mul { n }) = kernel else {
                unreachable!()
            };
            let data = golden::generate(kernel, sew, 42);
            let a = TensorI32::from_elems(&elems(&data.a, sew), &[n as i64]);
            let b = TensorI32::from_elems(&elems(&data.b, sew), &[n as i64]);
            let out = rt
                .execute(&format!("{name}_{}", sew_name(sew)), &[a, b])
                .expect("artifact executes");
            let want: Vec<i32> = elems(&data.expect, sew).iter().map(|&v| v as i32).collect();
            assert_eq!(out, want, "{name} {sew}");
        }
    }
}

#[test]
fn matmul_and_gemm_artifacts_match_golden() {
    let Some(mut rt) = need_runtime() else { return };
    for sew in Sew::ALL {
        let kernel = Kernel::paper_default(Family::Matmul, Target::Cpu, sew);
        let Kernel::Matmul { p } = kernel else { unreachable!() };
        let data = golden::generate(kernel, sew, 7);
        let a = TensorI32::from_elems(&elems(&data.a, sew), &[8, 8]);
        let b = TensorI32::from_elems(&elems(&data.b, sew), &[8, p as i64]);
        let out = rt.execute(&format!("matmul_{}", sew_name(sew)), &[a, b]).unwrap();
        let want: Vec<i32> = elems(&data.expect, sew).iter().map(|&v| v as i32).collect();
        assert_eq!(out, want, "matmul {sew}");

        let kernel = Kernel::paper_default(Family::Gemm, Target::Cpu, sew);
        let Kernel::Gemm { p } = kernel else { unreachable!() };
        let data = golden::generate(kernel, sew, 8);
        let a = TensorI32::from_elems(&elems(&data.a, sew), &[8, 8]);
        let b = TensorI32::from_elems(&elems(&data.b, sew), &[8, p as i64]);
        let c = TensorI32::from_elems(&elems(&data.c, sew), &[8, p as i64]);
        let out = rt.execute(&format!("gemm_{}", sew_name(sew)), &[a, b, c]).unwrap();
        let want: Vec<i32> = elems(&data.expect, sew).iter().map(|&v| v as i32).collect();
        assert_eq!(out, want, "gemm {sew}");
    }
}

#[test]
fn conv_relu_maxpool_artifacts_match_golden() {
    let Some(mut rt) = need_runtime() else { return };
    for sew in Sew::ALL {
        // conv2d (CPU shapes: f = 3).
        let kernel = Kernel::paper_default(Family::Conv2d, Target::Cpu, sew);
        let Kernel::Conv2d { n, f } = kernel else { unreachable!() };
        assert_eq!(f, 3);
        let data = golden::generate(kernel, sew, 9);
        let img = TensorI32::from_elems(&elems(&data.a, sew), &[8, n as i64]);
        let filt = TensorI32::from_elems(&elems(&data.b, sew), &[3, 3]);
        let out = rt.execute(&format!("conv2d_{}", sew_name(sew)), &[img, filt]).unwrap();
        let want: Vec<i32> = elems(&data.expect, sew).iter().map(|&v| v as i32).collect();
        assert_eq!(out, want, "conv2d {sew}");

        // relu / leaky.
        for (fam, name) in [(Family::Relu, "relu"), (Family::LeakyRelu, "leaky_relu")] {
            let kernel = Kernel::paper_default(fam, Target::Cpu, sew);
            let (Kernel::Relu { n } | Kernel::LeakyRelu { n }) = kernel else { unreachable!() };
            let data = golden::generate(kernel, sew, 10);
            let a = TensorI32::from_elems(&elems(&data.a, sew), &[n as i64]);
            let out = rt.execute(&format!("{name}_{}", sew_name(sew)), &[a]).unwrap();
            let want: Vec<i32> = elems(&data.expect, sew).iter().map(|&v| v as i32).collect();
            assert_eq!(out, want, "{name} {sew}");
        }

        // maxpool.
        let kernel = Kernel::paper_default(Family::Maxpool, Target::Cpu, sew);
        let Kernel::Maxpool { n } = kernel else { unreachable!() };
        let data = golden::generate(kernel, sew, 11);
        let img = TensorI32::from_elems(&elems(&data.a, sew), &[16, n as i64]);
        let out = rt.execute(&format!("maxpool_{}", sew_name(sew)), &[img]).unwrap();
        let want: Vec<i32> = elems(&data.expect, sew).iter().map(|&v| v as i32).collect();
        assert_eq!(out, want, "maxpool {sew}");
    }
}

#[test]
fn ad_autoencoder_artifact_matches_simulator_and_golden() {
    let Some(mut rt) = need_runtime() else { return };
    use nmc::apps::anomaly;
    let m = anomaly::model(2);
    // Inputs as i32 tensors.
    let mut inputs =
        vec![TensorI32::new(m.input.iter().map(|&v| v as i32).collect(), &[640])];
    for (l, &(ins, outs, _)) in anomaly::network().iter().enumerate() {
        inputs.push(TensorI32::new(
            m.weights[l].iter().map(|&v| v as i32).collect(),
            &[outs as i64, ins as i64],
        ));
    }
    let xla_out = rt.execute("ad_autoencoder", &inputs).expect("AD artifact");
    let golden: Vec<i32> = anomaly::golden_forward(&m).iter().map(|&v| v as i32).collect();
    assert_eq!(xla_out, golden, "XLA vs Rust golden");

    // And the full simulated NM-Carus system produces the same bits.
    let sim = anomaly::run_carus(&m);
    let sim_out: Vec<i32> = sim.output.iter().map(|&v| v as i32).collect();
    assert_eq!(sim_out, xla_out, "simulator vs XLA artifact");
}

#[test]
fn simulator_outputs_equal_artifacts_for_random_matmuls() {
    // Property-style: several random seeds; simulator (all three targets)
    // vs the XLA artifact on the paper matmul shape.
    let Some(mut rt) = need_runtime() else { return };
    let sew = Sew::E8;
    let kernel = Kernel::paper_default(Family::Matmul, Target::Cpu, sew);
    let Kernel::Matmul { p } = kernel else { unreachable!() };
    for seed in [1u64, 99, 12345] {
        let data = golden::generate(kernel, sew, seed);
        let a = TensorI32::from_elems(&elems(&data.a, sew), &[8, 8]);
        let b = TensorI32::from_elems(&elems(&data.b, sew), &[8, p as i64]);
        let xla_out = rt.execute("matmul_e8", &[a, b]).unwrap();
        // CPU + Carus targets run the same shape (Caesar uses smaller p —
        // covered by its own golden checks in kernels::caesar tests).
        for target in [Target::Cpu, Target::Carus] {
            let res = nmc::kernels::run(target, kernel, sew, seed);
            let sim: Vec<i32> = elems(&res.output, sew).iter().map(|&v| v as i32).collect();
            assert_eq!(sim, xla_out, "{target:?} seed {seed}");
        }
    }
}
