//! Self-verification of the differential fuzzer (DESIGN.md §11): inject a
//! known decode bug behind the test-only hook and assert the fuzzer
//! *catches* it, *shrinks* it to a handful of instructions, and emits a
//! repro file that replays to the same failure — plus a clean fixed-seed
//! run proving the oracle is divergence-free on the real simulator.

use nmc::fuzz;
use std::sync::Mutex;

/// The decode-fault hook is process-global; serialize the tests that
/// touch it (and any clean run that must see it disarmed).
static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// RAII arm/disarm so a failing assert can't leave the fault armed for
/// the other tests.
struct ArmedFault;

impl ArmedFault {
    fn new() -> ArmedFault {
        fuzz::arm_decode_fault(true);
        ArmedFault
    }
}

impl Drop for ArmedFault {
    fn drop(&mut self) {
        fuzz::arm_decode_fault(false);
    }
}

#[test]
fn injected_decode_bug_is_caught_shrunk_and_replayable() {
    let _serial = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let shrunk = {
        let _armed = ArmedFault::new();
        let report = fuzz::run(0xfa_017, 50, 64);
        let failure = report
            .failure
            .expect("an armed Max→Min decode fault must diverge within 50 cases");

        // The divergence is on the xvnmc roundtrip axis.
        match &failure.divergence {
            fuzz::Divergence::IsaRoundtrip { surface, detail, .. } => {
                assert_eq!(*surface, "xvnmc", "the fault lives in the xvnmc decoder");
                assert!(detail.contains("Max"), "names the mis-decoded op: {detail}");
            }
            other => panic!("expected an ISA roundtrip divergence, got: {other}"),
        }

        // Shrinking converged: a decode fault needs exactly one
        // instruction to witness (acceptance bound: ≤ 8).
        assert!(
            failure.case.kept_insns() <= 8,
            "shrunk case still carries {} instructions",
            failure.case.kept_insns()
        );
        assert!(failure.case.xvnmc_keep.len() == 1, "one xvnmc witness survives");
        assert!(failure.case.xcv_keep.is_empty(), "unrelated surfaces are emptied");
        assert!(failure.case.caesar_keep.is_empty());

        // The repro file reproduces the exact case…
        let json = fuzz::to_json(&failure.case, &failure.divergence.to_string());
        let back = fuzz::from_json(&json).expect("repro parses");
        assert_eq!(back, failure.case);

        // …and replaying it re-detects the fault while armed.
        let replayed = fuzz::replay(&back).expect_err("armed replay must still diverge");
        assert_eq!(replayed.stage(), fuzz::Stage::Isa);
        failure.case
    };

    // Disarmed, the very same case is clean across every oracle axis —
    // the divergence was the injected bug, not the case.
    assert!(
        fuzz::replay(&shrunk).is_ok(),
        "disarmed replay of the shrunk case must pass"
    );
}

#[test]
fn fixed_seed_smoke_run_is_divergence_free() {
    let _serial = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let report = fuzz::run(7, 3, 32);
    assert_eq!(report.cases, 3);
    if let Some(f) = &report.failure {
        panic!("unexpected divergence: {} (case {:?})", f.divergence, f.case);
    }
}
