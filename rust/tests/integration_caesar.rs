//! NM-Caesar integration: the full Table V column at paper sizes, issue
//! strategy ablation (host-driven vs DMA-streamed), and code-size metrics.

use nmc::isa::Sew;
use nmc::kernels::{golden, run, Family, Kernel, Target};

#[test]
fn full_table5_caesar_column_correct() {
    // Every kernel family × width at paper sizes completes and matches the
    // golden reference bit-exactly (the inner `run` asserts equality).
    for family in Family::ALL {
        for sew in Sew::ALL {
            let k = Kernel::paper_default(family, Target::Caesar, sew);
            let res = run(Target::Caesar, k, sew, 21);
            assert!(res.cycles > 0 && res.outputs > 0, "{family:?} {sew}");
        }
    }
}

#[test]
fn caesar_speedups_within_band_of_paper() {
    // Spot-check improvement factors at full size (paper ±40 % band — our
    // CPU baseline is slightly better than GCC's, see EXPERIMENTS.md).
    let cases = [
        (Family::Xor, Sew::E8, 5.0),
        (Family::Mul, Sew::E8, 22.0),
        (Family::Matmul, Sew::E8, 28.0),
        (Family::Relu, Sew::E8, 26.0),
        (Family::Conv2d, Sew::E32, 6.4),
    ];
    for (family, sew, paper) in cases {
        let cpu = run(Target::Cpu, Kernel::paper_default(family, Target::Cpu, sew), sew, 3);
        let czr = run(Target::Caesar, Kernel::paper_default(family, Target::Caesar, sew), sew, 3);
        let spd = cpu.cycles_per_output() / czr.cycles_per_output();
        assert!(
            spd > paper * 0.6 && spd < paper * 1.4,
            "{family:?} {sew}: {spd:.1}x vs paper {paper}x"
        );
    }
}

#[test]
fn caesar_offload_overhead_is_small_and_constant() {
    // Fig. 12 insight: NM-Caesar's offload overhead is a small constant
    // (the paper quotes 5 cycles for the bare trigger; our measured region
    // additionally includes DMA programming + wfi + mode toggles ≈ 100
    // cycles of driver code), so the gain holds even for short tasks.
    let r4 = run(Target::Caesar, Kernel::Matmul { p: 4 }, Sew::E8, 9);
    let r8 = run(Target::Caesar, Kernel::Matmul { p: 8 }, Sew::E8, 9);
    let r16 = run(Target::Caesar, Kernel::Matmul { p: 16 }, Sew::E8, 9);
    // Compute scales linearly with P; the constant driver overhead is the
    // intercept and must stay under ~120 cycles.
    let per_p = (r16.cycles - r8.cycles) as f64 / 8.0;
    let overhead = r4.cycles as f64 - 4.0 * per_p;
    assert!(
        (0.0..=120.0).contains(&overhead),
        "offload overhead ≈ {overhead:.0} cycles (r4 = {})",
        r4.cycles
    );
    // And tiny offloads still beat the CPU.
    let cpu = run(Target::Cpu, Kernel::Matmul { p: 4 }, Sew::E8, 9);
    assert!(r4.cycles < cpu.cycles, "caesar {} vs cpu {}", r4.cycles, cpu.cycles);
}

#[test]
fn same_bank_penalty_visible_end_to_end() {
    // Build two identical XOR streams, one with both operands in bank 0:
    // the same-bank version must take ~1.5× the cycles.
    use nmc::caesar::Caesar;
    use nmc::caesar::isa::{encode, MicroOp, Op};
    let mk = |same_bank: bool| -> u64 {
        let mut c = Caesar::new();
        let ops = 256;
        for i in 0..ops {
            while !c.ready() {
                c.step();
            }
            let (s1, s2) = if same_bank { (i as u16, i as u16 + 1024) } else { (i as u16, 4096 + i as u16) };
            c.issue(2048 + i, encode(&MicroOp { op: Op::Xor, src1: s1, src2: s2 }));
            c.step();
        }
        while !c.ready() {
            c.step();
        }
        c.stats.busy_cycles
    };
    let cross = mk(false);
    let same = mk(true);
    assert_eq!(cross, 512);
    assert_eq!(same, 768);
}

#[test]
fn stream_code_size_matches_model() {
    // The DMA stream costs 8 bytes per micro-op — the code-size overhead
    // the paper attributes to predefined command sequences (§I).
    use nmc::caesar::compiler::CaesarProgram;
    let mut p = CaesarProgram::new();
    p.csrw(Sew::E8);
    for i in 0..100 {
        p.add(2048 + i, i, 4096 + i);
    }
    assert_eq!(p.code_bytes(), 101 * 8);
}

#[test]
fn caesar_output_exact_across_seeds() {
    for seed in [1u64, 2, 3, 4, 5] {
        let k = Kernel::Gemm { p: 32 };
        let data = golden::generate(k, Sew::E16, seed);
        let res = nmc::kernels::caesar::run(k, Sew::E16, &data);
        assert_eq!(res.output, data.expect, "seed {seed}");
    }
}
