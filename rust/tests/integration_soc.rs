//! System-level integration: bus contention, DMA/CPU interleavings, mode
//! transparency, and failure injection.

use nmc::asm::Asm;
use nmc::bus::{periph, BANK_SIZE, CAESAR_BASE, CARUS_BASE, PERIPH_BASE};
use nmc::isa::reg::*;
use nmc::soc::{Halt, Soc};

fn firmware(build: impl FnOnce(&mut Asm)) -> nmc::asm::Program {
    let mut a = Asm::new(0);
    build(&mut a);
    a.assemble().unwrap()
}

#[test]
fn nmc_macros_are_transparent_srams_in_memory_mode() {
    // The paper's requirement (1): "functionally, it is part of the host
    // system's memory space and should operate like a conventional memory".
    // Write/read byte/half/word patterns over both macros and a real bank;
    // results must be identical.
    let mut soc = Soc::heeperator();
    let bases = [BANK_SIZE, CAESAR_BASE, CARUS_BASE];
    let fw = firmware(|a| {
        for (i, &b) in bases.iter().enumerate() {
            a.li(A0, b as i32)
                .li(T0, 0x1234_5678)
                .sw(T0, 0, A0)
                .li(T0, 0xab)
                .sb(T0, 1, A0)
                .li(T0, 0xcdef_u32 as i32)
                .sh(T0, 6, A0)
                .lw(A1, 0, A0)
                .sw(A1, 64 + 8 * i as i32, A0) // store readback nearby
                .lhu(A2, 6, A0)
                .sw(A2, 68 + 8 * i as i32, A0);
        }
        a.ebreak();
    });
    soc.load_firmware(&fw, 0);
    let (halt, _) = soc.run(100_000);
    assert_eq!(halt, Halt::Done);
    let expect_word = 0x1234_ab78u32;
    for &b in &bases {
        let i = bases.iter().position(|&x| x == b).unwrap() as u32;
        let w = u32::from_le_bytes(soc.dump(b + 64 + 8 * i, 4).try_into().unwrap());
        let h = u32::from_le_bytes(soc.dump(b + 68 + 8 * i, 4).try_into().unwrap());
        assert_eq!(w, expect_word, "word at {b:#x}");
        assert_eq!(h, 0xcdef, "half at {b:#x}");
    }
}

#[test]
fn dma_and_cpu_contend_on_the_same_bank() {
    // CPU hammers bank 1 while the DMA copies within bank 1: the CPU must
    // observe wait cycles (crossbar: one transaction per slave per cycle).
    let mut soc = Soc::heeperator();
    soc.load_data(BANK_SIZE, &vec![7u8; 4096]);
    let fw = firmware(|a| {
        // Program a long DMA copy bank1 → bank1 (src/dst both in bank 1).
        a.li(T0, (PERIPH_BASE + periph::DMA_SRC) as i32)
            .li(T1, BANK_SIZE as i32)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_DST) as i32)
            .li(T1, (BANK_SIZE + 0x1000) as i32)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_LEN) as i32)
            .li(T1, 0x800)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_CTL) as i32)
            .li(T1, 1)
            .sw(T1, 0, T0)
            // Poll data in the same bank while the DMA runs.
            .li(A0, BANK_SIZE as i32)
            .li(A2, 300)
            .label("loop")
            .lw(T2, 0, A0)
            .addi(A2, A2, -1)
            .bne(A2, ZERO, "loop")
            .ebreak();
    });
    soc.load_firmware(&fw, 0);
    soc.reset_stats();
    let (halt, _) = soc.run(100_000);
    assert_eq!(halt, Halt::Done);
    assert!(soc.counters.cpu_wait_cycles > 50, "wait cycles = {}", soc.counters.cpu_wait_cycles);
}

#[test]
fn cpu_unaffected_when_dma_hits_other_banks() {
    // Same loop, but the DMA works in bank 2 — near-zero contention.
    let mut soc = Soc::heeperator();
    soc.load_data(2 * BANK_SIZE, &vec![7u8; 4096]);
    let fw = firmware(|a| {
        a.li(T0, (PERIPH_BASE + periph::DMA_SRC) as i32)
            .li(T1, (2 * BANK_SIZE) as i32)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_DST) as i32)
            .li(T1, (2 * BANK_SIZE + 0x1000) as i32)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_LEN) as i32)
            .li(T1, 0x800)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_CTL) as i32)
            .li(T1, 1)
            .sw(T1, 0, T0)
            .li(A0, BANK_SIZE as i32)
            .li(A2, 300)
            .label("loop")
            .lw(T2, 0, A0)
            .addi(A2, A2, -1)
            .bne(A2, ZERO, "loop")
            .ebreak();
    });
    soc.load_firmware(&fw, 0);
    soc.reset_stats();
    soc.run(100_000);
    assert!(soc.counters.cpu_wait_cycles <= 4, "wait cycles = {}", soc.counters.cpu_wait_cycles);
}

#[test]
fn runaway_firmware_times_out() {
    // Failure injection: an infinite loop must hit the cycle limit, not hang.
    let mut soc = Soc::heeperator();
    let fw = firmware(|a| {
        a.label("spin").j("spin");
    });
    soc.load_firmware(&fw, 0);
    let (halt, cycles) = soc.run(10_000);
    assert_eq!(halt, Halt::Timeout);
    assert!(cycles >= 10_000);
}

#[test]
fn falling_off_program_traps() {
    // Failure injection: missing ebreak → trap, reported as such.
    let mut soc = Soc::heeperator();
    let fw = firmware(|a| {
        a.nop().nop();
    });
    soc.load_firmware(&fw, 0);
    let (halt, _) = soc.run(1_000);
    assert_eq!(halt, Halt::Trap);
}

#[test]
fn wfi_without_pending_irq_sleeps_until_dma() {
    let mut soc = Soc::heeperator();
    soc.load_data(BANK_SIZE, &vec![1u8; 1024]);
    let fw = firmware(|a| {
        a.li(T0, (PERIPH_BASE + periph::DMA_SRC) as i32)
            .li(T1, BANK_SIZE as i32)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_DST) as i32)
            .li(T1, (2 * BANK_SIZE) as i32)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_LEN) as i32)
            .li(T1, 0x400)
            .sw(T1, 0, T0)
            .li(T0, (PERIPH_BASE + periph::DMA_CTL) as i32)
            .li(T1, 1)
            .sw(T1, 0, T0)
            .wfi()
            .ebreak();
    });
    soc.load_firmware(&fw, 0);
    soc.reset_stats();
    let (halt, _) = soc.run(100_000);
    assert_eq!(halt, Halt::Done);
    // The CPU slept for most of the ≈256-cycle transfer.
    assert!(soc.counters.cpu_sleep > 150, "slept {} cycles", soc.counters.cpu_sleep);
}

#[test]
fn caesar_backpressure_stalls_host_issue() {
    // Host-driven compute back-to-back: the 2-cycle pipeline must throttle
    // the store stream (the paper's §III-A2 contention note).
    use nmc::caesar::isa::{encode, MicroOp, Op};
    let mut soc = Soc::heeperator();
    let op = encode(&MicroOp { op: Op::Add, src1: 0, src2: 4096 });
    let fw = firmware(|a| {
        a.li(T0, (PERIPH_BASE + periph::CAESAR_IMC) as i32)
            .li(T1, 1)
            .sw(T1, 0, T0)
            .li(A0, CAESAR_BASE as i32)
            .li(A1, op as i32)
            .li(A2, 64);
        a.label("loop");
        // Two stores back-to-back per iteration: the second must wait.
        a.sw(A1, 0x2000, A0)
            .sw(A1, 0x2004, A0)
            .addi(A2, A2, -1)
            .bne(A2, ZERO, "loop")
            .ebreak();
    });
    soc.load_firmware(&fw, 0);
    soc.reset_stats();
    let (halt, _) = soc.run(100_000);
    assert_eq!(halt, Halt::Done);
    assert!(soc.counters.cpu_wait_cycles > 30, "stall cycles = {}", soc.counters.cpu_wait_cycles);
    assert_eq!(soc.caesar().stats.instrs, 128);
}

#[test]
fn mcycle_monotone_and_matches_simulation() {
    let mut soc = Soc::heeperator();
    let fw = firmware(|a| {
        a.li(T0, (PERIPH_BASE + periph::MCYCLE) as i32)
            .lw(A0, 0, T0)
            .li(A2, 50)
            .label("l")
            .addi(A2, A2, -1)
            .bne(A2, ZERO, "l")
            .lw(A1, 0, T0)
            .ebreak();
    });
    soc.load_firmware(&fw, 0);
    soc.run(100_000);
    let delta = soc.cpu.regs[A1 as usize] - soc.cpu.regs[A0 as usize];
    // 50 iterations × (addi 1 + taken bne 3) ≈ 200 (+ final not-taken).
    assert!((190..215).contains(&delta), "mcycle delta = {delta}");
}
