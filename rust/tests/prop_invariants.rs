//! Property-based invariants (the shared splitmix64 generator of
//! `nmc::fuzz::gen` — proptest is not in the offline vendor set; same
//! methodology: randomized cases with fixed seeds for reproducibility,
//! shrinking delegated to the differential fuzzer, `heeperator fuzz`).
//!
//! Invariants covered (DESIGN.md §7):
//! 1. ISA encode ∘ decode = id for random valid instructions (RV32IM, Xcv,
//!    xvnmc, NM-Caesar micro-ops).
//! 2. Packed-SIMD word ops ≡ per-element scalar reference at every SEW.
//! 3. VRF logical-register addressing is a bijection onto the host view.
//! 4. NM-Caesar pipeline conservation: every issued op retires exactly
//!    once; busy cycles = Σ per-op occupancy.
//! 5. Energy accounting: total = Σ components, non-negative, monotone in
//!    activity.
//! 6. Randomized straight-line RV32 programs execute identically through
//!    the decoded-instruction path and a re-encoded round trip.

use nmc::caesar::isa as cisa;
use nmc::fuzz::gen::{rand_reg, rand_rv32_instr, Rng};
use nmc::isa::rv32::{decode, encode, Instr};
use nmc::isa::xvnmc::{self, VInstr, VOp, VSrc};
use nmc::isa::Sew;
use nmc::simd::{elem, swar};

const CASES: usize = 2000;

#[test]
fn prop_rv32_encode_decode_roundtrip() {
    let mut rng = Rng(0x1);
    for i in 0..CASES {
        let instr = rand_rv32_instr(&mut rng);
        let w = encode(&instr);
        let back = decode(w).unwrap_or_else(|e| panic!("case {i}: {e} for {instr:?}"));
        assert_eq!(back, instr, "case {i} word {w:#010x}");
    }
}

#[test]
fn prop_xvnmc_encode_decode_roundtrip() {
    let mut rng = Rng(0x2);
    let ops = [
        VOp::Add, VOp::Sub, VOp::Mul, VOp::Macc, VOp::And, VOp::Or, VOp::Xor, VOp::Min,
        VOp::Minu, VOp::Max, VOp::Maxu, VOp::Sll, VOp::Srl, VOp::Sra, VOp::Mv,
        VOp::SlideUp, VOp::SlideDown, VOp::Slide1Up, VOp::Slide1Down,
    ];
    for i in 0..CASES {
        let op = ops[(rng.next_u32() as usize) % ops.len()];
        let srcs = [
            VSrc::V((rng.next_u32() % 32) as u8),
            VSrc::X(rand_reg(&mut rng)),
            VSrc::I((rng.next_u32() as i32 % 16) as i8),
        ];
        let src = srcs[(rng.next_u32() as usize) % 3];
        if !op.allows(src.kind()) {
            continue;
        }
        let indirect = rng.next_u32() % 2 == 1;
        let v = VInstr::Op {
            op,
            vd: if indirect { 0 } else { (rng.next_u32() % 32) as u8 },
            vs2: if indirect { 0 } else { (rng.next_u32() % 32) as u8 },
            src,
            indirect,
            idx_gpr: if indirect { rand_reg(&mut rng) } else { 0 },
        };
        let w = xvnmc::encode(&v);
        assert_eq!(xvnmc::decode(w), Some(v), "case {i}");
    }
}

#[test]
fn prop_caesar_microop_roundtrip() {
    let mut rng = Rng(0x3);
    for _ in 0..CASES {
        let op = cisa::Op::ALL[(rng.next_u32() as usize) % cisa::Op::ALL.len()];
        let m = cisa::MicroOp {
            op,
            src1: (rng.next_u32() % 8192) as u16,
            src2: (rng.next_u32() % 8192) as u16,
        };
        assert_eq!(cisa::decode(cisa::encode(&m)), Some(m));
    }
}

#[test]
fn prop_swar_equals_scalar_reference() {
    let mut rng = Rng(0x4);
    for _ in 0..CASES {
        let a = rng.next_u32();
        let b = rng.next_u32();
        for sew in Sew::ALL {
            // Every packed op vs an element loop.
            let lanes = sew.lanes();
            let per_elem = |f: &dyn Fn(i64, i64) -> i64| -> u32 {
                let mut out = 0u32;
                for i in 0..lanes {
                    let x = elem::get_signed(a, i, sew) as i64;
                    let y = elem::get_signed(b, i, sew) as i64;
                    out = elem::set(out, i, sew, f(x, y) as u32);
                }
                out
            };
            assert_eq!(swar::add(a, b, sew), per_elem(&|x, y| x + y), "add {a:#x} {b:#x} {sew}");
            assert_eq!(swar::sub(a, b, sew), per_elem(&|x, y| x - y), "sub");
            assert_eq!(swar::mul(a, b, sew), per_elem(&|x, y| x.wrapping_mul(y)), "mul");
            assert_eq!(swar::min_signed(a, b, sew), per_elem(&|x, y| x.min(y)), "min");
            assert_eq!(swar::max_signed(a, b, sew), per_elem(&|x, y| x.max(y)), "max");
            // Dot product vs scalar sum.
            let mut dot = 0i64;
            for i in 0..lanes {
                dot += elem::get_signed(a, i, sew) as i64 * elem::get_signed(b, i, sew) as i64;
            }
            assert_eq!(swar::dotp_signed(a, b, sew), dot as i32, "dot {sew}");
        }
    }
}

#[test]
fn prop_vrf_logical_addressing_bijective() {
    use nmc::carus::vrf::Vrf;
    let mut rng = Rng(0x5);
    for _ in 0..200 {
        let lanes = [1u32, 2, 4, 8][(rng.next_u32() % 4) as usize];
        let mut vrf = Vrf::new(lanes);
        let sew = Sew::ALL[(rng.next_u32() % 3) as usize];
        let vl = [16u32, 64, 256][(rng.next_u32() % 3) as usize];
        // Write elements via logical addressing, read via host bytes.
        let r = (rng.next_u32() % (32768 / (vl * sew.bytes()))).min(255) as u8;
        let j = rng.next_u32() % vl;
        let val = rng.next_u32();
        vrf.set_elem(r, j, vl, sew, val);
        let addr = r as u32 * vl * sew.bytes() + j * sew.bytes();
        assert_eq!(vrf.peek(addr, sew.bytes()), val & (u32::MAX >> (32 - sew.bits())), "lanes={lanes} {sew} vl={vl}");
    }
}

#[test]
fn prop_caesar_pipeline_conservation() {
    use nmc::caesar::Caesar;
    let mut rng = Rng(0x6);
    for _ in 0..50 {
        let mut c = Caesar::new();
        let n_ops = 20 + (rng.next_u32() % 100) as u64;
        let mut expected_busy = 0u64;
        let mut issued = 0u64;
        for _ in 0..n_ops {
            while !c.ready() {
                c.step();
            }
            let same_bank = rng.next_u32() % 2 == 0;
            let (s1, s2) = if same_bank { (0u16, 1u16) } else { (0u16, 4096u16) };
            let m = cisa::MicroOp { op: cisa::Op::Add, src1: s1, src2: s2 };
            c.issue((rng.next_u32() % 2048) + 2048, cisa::encode(&m));
            issued += 1;
            expected_busy += if same_bank { 3 } else { 2 };
            c.step();
        }
        while !c.ready() {
            c.step();
        }
        assert_eq!(c.stats.instrs, issued, "every op retires exactly once");
        assert_eq!(c.stats.busy_cycles, expected_busy, "busy = Σ occupancy");
    }
}

#[test]
fn prop_energy_accounting_consistent() {
    use nmc::energy::{energy, Activity};
    let mut rng = Rng(0x7);
    for _ in 0..300 {
        let act = Activity {
            cycles: (rng.next_u32() % 100_000) as u64 + 1,
            cpu_active: (rng.next_u32() % 50_000) as u64,
            cpu_sleep: (rng.next_u32() % 50_000) as u64,
            cpu_fetches: (rng.next_u32() % 50_000) as u64,
            bus_txns: (rng.next_u32() % 10_000) as u64,
            dma_active: (rng.next_u32() % 10_000) as u64,
            ..Default::default()
        };
        let b = energy(&act);
        assert!(b.total() >= 0.0);
        let sum = b.cpu + b.memory + b.nmc_logic + b.interconnect + b.other;
        assert!((b.total() - sum).abs() < 1e-9);
        // Monotone: adding fetches can only increase memory energy.
        let mut act2 = act.clone();
        act2.cpu_fetches += 100;
        assert!(energy(&act2).memory > b.memory);
    }
}

#[test]
fn prop_random_straight_line_programs_roundtrip_through_encoding() {
    // Execute a random arithmetic-only program twice: once from the
    // original decoded instructions, once from decode(encode(i)) — the
    // architectural state must be identical.
    use nmc::cpu::{CpuConfig, CpuCore, MemIf};
    struct NullMem;
    impl MemIf for NullMem {
        fn read(&mut self, _a: u32, _s: u32) -> u32 {
            0xabad_1dea
        }
        fn write(&mut self, _a: u32, _s: u32, _v: u32) {}
    }
    let mut rng = Rng(0x8);
    for case in 0..200 {
        let prog: Vec<Instr> = (0..50)
            .map(|_| loop {
                let i = rand_rv32_instr(&mut rng);
                // Straight-line: no control flow.
                match i {
                    Instr::Branch { .. } | Instr::Jal { .. } | Instr::Jalr { .. } => continue,
                    _ => break i,
                }
            })
            .collect();
        let run = |instrs: &[Instr]| -> [u32; 32] {
            let mut cpu = CpuCore::new(CpuConfig::CV32E40P, 0);
            for (i, r) in cpu.regs.iter_mut().enumerate() {
                *r = (i as u32).wrapping_mul(0x9e37_79b9);
            }
            cpu.regs[0] = 0;
            let mut mem = NullMem;
            for inst in instrs {
                // Random loads/stores may be misaligned and trap: the trap
                // (and any partial state) must be identical on both paths.
                let _ = cpu.exec(inst, &mut mem);
            }
            cpu.regs
        };
        let reencoded: Vec<Instr> = prog.iter().map(|i| decode(encode(i)).unwrap()).collect();
        assert_eq!(run(&prog), run(&reencoded), "case {case}");
    }
}
