//! Differential contract of the event-driven timing core: the skip-ahead
//! `event` mode must be **indistinguishable** from the per-cycle `cycle`
//! reference on everything the simulator reports — output bytes,
//! simulated cycles, the full activity record (every counter the energy
//! model reads), and the energy breakdown itself. Wall-clock speed is the
//! only permitted difference.
//!
//! The grid here samples every target and element width plus the kernels
//! with distinct timing structure (pure compute, DMA-heavy, eCPU-looping,
//! multi-round), and the multi-tile scheduler in batch and shard mode.
//! A full-grid sweep runs under `--ignored` (CI quick job runs the
//! default set).
//!
//! Tests run the two modes on the *same thread* via `clock::with_mode` —
//! deliberately below the `SweepSession` cache, so both runs really
//! simulate.

use nmc::clock::{self, TimingMode};
use nmc::isa::Sew;
use nmc::kernels::{self, Kernel, RunResult, Target};
use nmc::sched::{self, BatchSpec};

/// Run one kernel point under both timing modes and assert equivalence.
fn assert_point_equivalent(target: Target, kernel: Kernel, sew: Sew, seed: u64) {
    let ctx = format!("{target:?} {kernel:?} {sew} seed={seed}");
    let cyc: RunResult =
        clock::with_mode(TimingMode::Cycle, || kernels::run(target, kernel, sew, seed));
    let evt: RunResult =
        clock::with_mode(TimingMode::Event, || kernels::run(target, kernel, sew, seed));
    assert_eq!(evt.output, cyc.output, "{ctx}: output bytes diverged");
    assert_eq!(evt.cycles, cyc.cycles, "{ctx}: simulated cycles diverged");
    assert_eq!(evt.outputs, cyc.outputs, "{ctx}: output count diverged");
    // The activity record carries every counter the energy model reads
    // (cpu active/sleep, fetches, per-macro accesses, DMA, tile
    // busy/idle, ALU ops...): Debug-format equality pins all of them.
    assert_eq!(
        format!("{:?}", evt.activity),
        format!("{:?}", cyc.activity),
        "{ctx}: activity counters diverged"
    );
    assert_eq!(evt.energy, cyc.energy, "{ctx}: energy breakdown diverged");
}

/// Run one batch spec under both timing modes and assert equivalence.
fn assert_batch_equivalent(spec: &BatchSpec, tiles: usize) {
    let ctx = format!("{:?} x{tiles}", spec);
    let cyc = clock::with_mode(TimingMode::Cycle, || sched::run_batch(spec, tiles))
        .unwrap_or_else(|e| panic!("{ctx}: cycle-mode run failed: {e}"));
    let evt = clock::with_mode(TimingMode::Event, || sched::run_batch(spec, tiles))
        .unwrap_or_else(|e| panic!("{ctx}: event-mode run failed: {e}"));
    assert_eq!(evt.outputs, cyc.outputs, "{ctx}: output bytes diverged");
    assert_eq!(evt.cycles, cyc.cycles, "{ctx}: simulated cycles diverged");
    assert_eq!(evt.dma_active_cycles, cyc.dma_active_cycles, "{ctx}: dma activity diverged");
    assert_eq!(evt.dma_transfers, cyc.dma_transfers, "{ctx}: dma transfers diverged");
    assert_eq!(evt.bus_txns, cyc.bus_txns, "{ctx}: bus transactions diverged");
    assert_eq!(
        evt.contention_cycles, cyc.contention_cycles,
        "{ctx}: contention cycles diverged"
    );
    for (i, (e, c)) in evt.per_tile.iter().zip(cyc.per_tile.iter()).enumerate() {
        assert_eq!(e.busy_cycles, c.busy_cycles, "{ctx}: tile {i} busy cycles diverged");
        assert_eq!(e.workloads, c.workloads, "{ctx}: tile {i} workload count diverged");
    }
    assert_eq!(evt.energy, cyc.energy, "{ctx}: energy breakdown diverged");
}

/// Kernels with structurally distinct timing: element-wise (DMA-bound on
/// NM-Caesar), matmul (multi-instruction eCPU loop on NM-Carus, µop
/// stream on NM-Caesar), conv2d (strided staging), maxpool (packed
/// output rows).
fn sampled_kernels(sew: Sew) -> Vec<Kernel> {
    let sb = sew.bytes();
    vec![
        Kernel::Add { n: 512 / sb },
        Kernel::Matmul { p: 64 / sb },
        Kernel::Conv2d { n: 128 / sb, f: 3 },
        Kernel::Maxpool { n: 128 / sb },
    ]
}

#[test]
fn kernel_grid_is_timing_equivalent() {
    for target in Target::ALL {
        for sew in Sew::ALL {
            for kernel in sampled_kernels(sew) {
                if kernel.validate(target, sew).is_err() {
                    continue;
                }
                assert_point_equivalent(target, kernel, sew, 7);
            }
        }
    }
}

#[test]
fn seeds_do_not_break_equivalence() {
    // Data-dependent control flow would show up here (it must not: the
    // timing model is data-independent, and skip-ahead preserves it).
    for seed in [1, 2, 99] {
        assert_point_equivalent(Target::Carus, Kernel::Matmul { p: 32 }, Sew::E8, seed);
        assert_point_equivalent(Target::Caesar, Kernel::Add { n: 256 }, Sew::E8, seed);
    }
}

#[test]
fn batch_scheduler_is_timing_equivalent_across_tiles() {
    let spec = BatchSpec {
        target: Target::Carus,
        kernel: Kernel::Matmul { p: 128 },
        sew: Sew::E8,
        seed: 3,
        batch: 8,
        shard: false,
    };
    for tiles in [1, 4] {
        assert_batch_equivalent(&spec, tiles);
    }
}

#[test]
fn caesar_batch_is_timing_equivalent() {
    // NM-Caesar tiles keep the bounded spin-poll wait (no completion IRQ
    // line): the poll loop itself must skip identically.
    let spec = BatchSpec {
        target: Target::Caesar,
        kernel: Kernel::Add { n: 512 },
        sew: Sew::E8,
        seed: 5,
        batch: 6,
        shard: false,
    };
    for tiles in [1, 3] {
        assert_batch_equivalent(&spec, tiles);
    }
}

#[test]
fn sharded_batch_is_timing_equivalent() {
    let spec = BatchSpec {
        target: Target::Carus,
        kernel: Kernel::Matmul { p: 128 },
        sew: Sew::E8,
        seed: 3,
        batch: 4,
        shard: true,
    };
    assert_batch_equivalent(&spec, 4);
}

/// Full paper-shaped grid — expensive; run with `cargo test -- --ignored`.
#[test]
#[ignore = "full grid: minutes of cycle-mode simulation; the sampled grid covers CI"]
fn full_paper_grid_is_timing_equivalent() {
    use nmc::kernels::Family;
    for target in Target::ALL {
        for family in Family::ALL {
            for sew in Sew::ALL {
                let kernel = Kernel::paper_default(family, target, sew);
                if kernel.validate(target, sew).is_err() {
                    continue;
                }
                assert_point_equivalent(target, kernel, sew, 5);
            }
        }
    }
}
