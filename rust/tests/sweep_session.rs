//! Cache-transparency and at-most-once contracts of `sweep::SweepSession`
//! (ISSUE 4 acceptance criteria):
//!
//! 1. session results are byte-identical to direct, uncached
//!    `kernels::run` calls — the cache stores, it never alters;
//! 2. each `(target, kernel, sew, seed)` point is simulated at most once
//!    per session, even under concurrent consumers;
//! 3. `heeperator all --jobs N` output is byte-identical to `--jobs 1`
//!    through the shared cache.

use nmc::harness;
use nmc::isa::Sew;
use nmc::kernels::{self, Kernel, Target};
use nmc::sweep::SweepSession;
use std::sync::Arc;

#[test]
fn session_results_byte_identical_to_uncached_runs() {
    let session = SweepSession::new();
    for (target, kernel, sew, seed) in [
        (Target::Cpu, Kernel::Add { n: 128 }, Sew::E16, 5),
        (Target::Caesar, Kernel::Relu { n: 256 }, Sew::E8, 5),
        (Target::Carus, Kernel::Xor { n: 512 }, Sew::E8, 7),
        (Target::Carus, Kernel::Matmul { p: 64 }, Sew::E32, 6),
    ] {
        let cached = session.run(target, kernel, sew, seed);
        let direct = kernels::run(target, kernel, sew, seed);
        assert_eq!(cached.output, direct.output, "{target:?} {kernel:?} {sew} output");
        assert_eq!(cached.cycles, direct.cycles, "{target:?} {kernel:?} {sew} cycles");
        assert_eq!(cached.outputs, direct.outputs);
        assert_eq!(cached.target, direct.target);
        assert_eq!(cached.energy.total(), direct.energy.total(), "{target:?} {kernel:?} energy");
        // Re-asking the session returns the identical result without
        // another simulation.
        let again = session.run(target, kernel, sew, seed);
        assert!(Arc::ptr_eq(&cached, &again));
    }
    assert_eq!(session.simulations(), 4);
}

#[test]
fn concurrent_consumers_simulate_each_point_once() {
    let session = Arc::new(SweepSession::new());
    // 8 threads hammer the same two points; the per-point OnceLock must
    // serialize initialization, not duplicate it.
    let handles: Vec<_> = (0..8)
        .map(|i| {
            let s = Arc::clone(&session);
            std::thread::spawn(move || {
                let kernel = if i % 2 == 0 { Kernel::Relu { n: 256 } } else { Kernel::Mul { n: 64 } };
                s.run(Target::Cpu, kernel, Sew::E8, 3).cycles
            })
        })
        .collect();
    let cycles: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(session.simulations(), 2, "two distinct points, two simulations");
    // Every consumer of the same point observed the same result
    // (even-index threads share one point, odd-index the other).
    let evens: Vec<u64> = cycles.iter().step_by(2).copied().collect();
    let odds: Vec<u64> = cycles.iter().skip(1).step_by(2).copied().collect();
    assert!(evens.windows(2).all(|w| w[0] == w[1]), "{evens:?}");
    assert!(odds.windows(2).all(|w| w[0] == w[1]), "{odds:?}");
}

#[test]
fn anomaly_runs_are_cached_per_target() {
    let session = SweepSession::new();
    let a = session.anomaly(Target::Cpu, 2);
    let b = session.anomaly(Target::Cpu, 2);
    assert!(Arc::ptr_eq(&a, &b));
    assert_eq!(session.simulations(), 1);
    // A different model seed is a different workload.
    let c = session.anomaly(Target::Cpu, 3);
    assert_eq!(session.simulations(), 2);
    assert_eq!(a.cycles, c.cycles, "cycle count is data-independent for the AD net");
}

#[test]
fn all_quick_output_byte_identical_across_job_counts() {
    // The `heeperator all` acceptance contract: the parallel report set,
    // drained through a shared session, renders byte-identically to the
    // sequential baseline (same report ids, same text, same CSVs).
    let seq = harness::all_with_jobs(true, 1);
    let par = harness::all_with_jobs(true, 4);
    assert_eq!(seq.len(), par.len());
    for (s, p) in seq.iter().zip(&par) {
        assert_eq!(s.id, p.id);
        assert_eq!(s.text, p.text, "{} text diverged between --jobs 1 and --jobs 4", s.id);
        assert_eq!(s.csv, p.csv, "{} csv diverged between --jobs 1 and --jobs 4", s.id);
    }
}
