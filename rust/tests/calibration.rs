//! Calibration lock: the simulator must reproduce the paper's anchor
//! numbers within the documented tolerances (DESIGN.md §5).
//!
//! These tests are the contract behind every table: if a model change
//! drifts the calibration, they fail loudly with the paper value attached.

use nmc::energy::params::CYCLE_NS;
use nmc::isa::Sew;
use nmc::kernels::{run, Family, Kernel, Target};

fn rel_err(measured: f64, paper: f64) -> f64 {
    (measured - paper).abs() / paper
}

#[test]
fn cpu_elementwise_baselines_match_paper_cycles() {
    // Table V baseline columns (cycles/output).
    let cases = [
        (Family::Xor, Sew::E8, 2.5, 0.08),
        (Family::Xor, Sew::E32, 10.0, 0.05),
        (Family::Add, Sew::E8, 4.0, 0.15),
        (Family::Add, Sew::E32, 10.0, 0.05),
        (Family::Mul, Sew::E16, 11.0, 0.12),
    ];
    for (fam, sew, paper, tol) in cases {
        let k = Kernel::paper_default(fam, Target::Cpu, sew);
        let res = run(Target::Cpu, k, sew, 1);
        let cpo = res.cycles_per_output();
        assert!(
            rel_err(cpo, paper) < tol,
            "{fam:?} {sew}: {cpo:.2} c/out vs paper {paper}"
        );
    }
}

#[test]
fn cpu_add32_energy_anchor() {
    // The master energy anchor: 32-bit element-wise add ≈ 278 pJ/output.
    let res = run(Target::Cpu, Kernel::Add { n: 1280 }, Sew::E32, 2);
    let pj = res.energy_per_output_pj();
    assert!(rel_err(pj, 278.0) < 0.2, "add32: {pj:.1} pJ/out vs paper 278");
}

#[test]
fn caesar_matmul_cycles_match_paper() {
    // Paper: 4 cycles/output at 8 bit (2 micro-ops), 16 at 32 bit.
    let res = run(Target::Caesar, Kernel::Matmul { p: 512 }, Sew::E8, 3);
    assert!(rel_err(res.cycles_per_output(), 4.0) < 0.1, "{}", res.cycles_per_output());
    let res = run(Target::Caesar, Kernel::Matmul { p: 128 }, Sew::E32, 3);
    assert!(rel_err(res.cycles_per_output(), 16.0) < 0.1, "{}", res.cycles_per_output());
}

#[test]
fn carus_matmul_saturation_matches_fig12() {
    // Fig. 12: NM-Carus saturates at 0.48 output/cycle (8-bit, large P);
    // NM-Caesar at 0.25.
    let carus = run(Target::Carus, Kernel::Matmul { p: 1024 }, Sew::E8, 4);
    let opc = carus.outputs as f64 / carus.cycles as f64;
    assert!(rel_err(opc, 0.48) < 0.07, "carus: {opc:.3} out/cycle vs paper 0.48");
    let caesar = run(Target::Caesar, Kernel::Matmul { p: 512 }, Sew::E8, 4);
    let opc = caesar.outputs as f64 / caesar.cycles as f64;
    assert!(rel_err(opc, 0.25) < 0.05, "caesar: {opc:.3} out/cycle vs paper 0.25");
}

#[test]
fn carus_macs_per_cycle_per_lane() {
    // §III-B2: 1 / 0.67 / 0.33 MAC/cycle/lane. Measured end-to-end on the
    // saturated matmul (8 MACs per output).
    for (sew, p, paper, tol) in [
        (Sew::E8, 1024u32, 1.0, 0.1),
        (Sew::E16, 512, 0.67, 0.1),
        (Sew::E32, 256, 0.33, 0.35), // our 32-bit MAC is 3 cyc/word vs paper's 4 (documented)
    ] {
        let res = run(Target::Carus, Kernel::Matmul { p }, sew, 4);
        let macs = res.outputs as f64 * 8.0;
        let mpc = macs / res.cycles as f64 / 4.0; // 4 lanes
        assert!(
            rel_err(mpc, paper) < tol,
            "{sew}: {mpc:.2} MAC/cycle/lane vs paper {paper}"
        );
    }
}

#[test]
fn fig13_breakdown_shapes() {
    // CPU case: memory ≈ CPU. Caesar case: memory dominates (half of it
    // the micro-op stream). Carus case: VRF dominates the macro.
    let cpu = run(Target::Cpu, Kernel::paper_default(Family::Conv2d, Target::Cpu, Sew::E8), Sew::E8, 5);
    let b = &cpu.energy;
    let ratio = b.memory / b.cpu;
    assert!((0.6..1.6).contains(&ratio), "cpu conv: mem/cpu = {ratio:.2}");

    let czr = run(
        Target::Caesar,
        Kernel::paper_default(Family::Conv2d, Target::Caesar, Sew::E8),
        Sew::E8,
        5,
    );
    let b = &czr.energy;
    let mem_share = b.memory / b.total();
    assert!(
        (0.45..0.85).contains(&mem_share),
        "caesar conv: memory share = {mem_share:.2} (paper ~0.7)"
    );
}

#[test]
fn ad_single_core_cycles_match_paper() {
    // Table VI: 561e3 cycles (CV32E40P, RV32IMCXcv), ±12 %.
    let m = nmc::apps::anomaly::model(2);
    let res = nmc::apps::anomaly::run_cpu(&m);
    assert!(
        rel_err(res.cycles as f64, 561.0e3) < 0.12,
        "AD single-core: {} cycles vs paper 561e3",
        res.cycles
    );
}

#[test]
fn ad_nmc_ratios_match_paper_shape() {
    let m = nmc::apps::anomaly::model(2);
    let single = nmc::apps::anomaly::run_cpu(&m);
    let caesar = nmc::apps::anomaly::run_caesar(&m);
    let carus = nmc::apps::anomaly::run_carus(&m);
    let czr_spd = single.cycles as f64 / caesar.cycles as f64;
    let carus_spd = single.cycles as f64 / carus.cycles as f64;
    // Paper: 1.29x and 3.55x. Shape requirements: Caesar between 1x and
    // 2x (slower than dual-core); Carus between 2.8x and 5.2x.
    assert!((1.0..2.0).contains(&czr_spd), "caesar: {czr_spd:.2}x (paper 1.29x)");
    assert!((2.8..5.2).contains(&carus_spd), "carus: {carus_spd:.2}x (paper 3.55x)");
    // Energy ordering: Carus < Caesar < single (Table VI).
    assert!(carus.energy_uj < caesar.energy_uj);
    assert!(caesar.energy_uj < single.energy_uj);
}

#[test]
fn system_power_in_plausible_mw_range() {
    // Sanity: an edge MCU at 250 MHz burns single-digit mW in this class.
    let res = run(Target::Cpu, Kernel::Add { n: 1280 }, Sew::E32, 6);
    let mw = res.energy.total() / (res.cycles as f64 * CYCLE_NS);
    assert!((3.0..15.0).contains(&mw), "avg power = {mw:.2} mW");
}

#[test]
fn headline_conclusion_ratios() {
    // §VI: "timing speed-up of up to 25.8x and 50.0x, energy reduction of
    // 23.2x and 33.1x ... in a matrix multiplication kernel". Our baselines
    // are slightly faster than GCC's, so we accept >=70 % of the headline.
    let cpu = run(Target::Cpu, Kernel::Matmul { p: 1024 }, Sew::E8, 7);
    let czr = run(Target::Caesar, Kernel::Matmul { p: 512 }, Sew::E8, 7);
    let car = run(Target::Carus, Kernel::Matmul { p: 1024 }, Sew::E8, 7);
    let czr_spd = cpu.cycles_per_output() / czr.cycles_per_output();
    let car_spd = cpu.cycles_per_output() / car.cycles_per_output();
    assert!(czr_spd > 0.7 * 25.8, "caesar matmul speedup {czr_spd:.1}");
    assert!(car_spd > 0.7 * 50.0, "carus matmul speedup {car_spd:.1}");
    let czr_e = cpu.energy_per_output_pj() / czr.energy_per_output_pj();
    let car_e = cpu.energy_per_output_pj() / car.energy_per_output_pj();
    assert!(czr_e > 0.6 * 23.2, "caesar matmul energy gain {czr_e:.1}");
    assert!(car_e > 0.6 * 33.1, "carus matmul energy gain {car_e:.1}");
}
