//! Integration contracts of the multi-tile batch scheduler (`sched` +
//! `heeperator scale`):
//!
//! 1. **Speedup** — a batched NM-Carus matmul reaches >1.5× aggregate
//!    speedup at 4 tiles vs 1 tile (the acceptance bar of the scale-out
//!    PR; the measured point sits well above it).
//! 2. **Byte identity** — tiled results are byte-identical to the
//!    single-tile reference, for batches and for column shards.
//! 3. **Determinism** — the scale report is byte-identical for every
//!    `--jobs` value.
//! 4. **Rejection paths** — capacity and shardability violations surface
//!    as `Err`, never as panics deep inside an engine.

use nmc::harness;
use nmc::isa::Sew;
use nmc::kernels::{Kernel, Target};
use nmc::sched::{self, BatchSpec};
use nmc::sweep::SweepSession;
use std::sync::Arc;

fn matmul_spec(batch: u32) -> BatchSpec {
    BatchSpec {
        target: Target::Carus,
        kernel: Kernel::Matmul { p: 256 },
        sew: Sew::E8,
        seed: 1,
        batch,
        shard: false,
    }
}

#[test]
fn batched_matmul_scales_past_1_5x_at_4_tiles() {
    let session = SweepSession::new();
    let spec = matmul_spec(8);
    let t1 = session.scale(&spec, 1).unwrap();
    let t4 = session.scale(&spec, 4).unwrap();
    // Byte identity: every workload's output matches the single-tile run
    // (each was already asserted against the golden reference).
    assert_eq!(t1.outputs, t4.outputs, "tiled outputs must match the single-tile reference");
    // The acceptance bar with margin: staging serializes on the DMA,
    // execution overlaps, so 4 tiles on an execution-dominated matmul
    // land far above 1.5x.
    let speedup = t4.speedup_vs(&t1);
    assert!(speedup > 1.5, "4-tile speedup {speedup:.2}x <= 1.5x (t1 {} / t4 {})", t1.cycles, t4.cycles);
    // All four tiles did real work and the report figures are populated.
    assert_eq!(t4.per_tile.len(), 4);
    for i in 0..4 {
        assert!(t4.per_tile[i].busy_cycles > 0, "tile {i} idle");
        assert_eq!(t4.per_tile[i].workloads, 2, "8 workloads round-robin onto 4 tiles");
    }
    assert!(t4.mean_utilization() > 0.3, "utilization {:.2}", t4.mean_utilization());
    assert!(t4.dma_active_cycles > 0 && t4.dma_transfers > 0);
    // More tiles add static power but the batch finishes sooner — energy
    // stays within sanity bounds (same event work, extra idle overhead).
    let (e1, e4) = (t1.energy.total(), t4.energy.total());
    assert!(e4 > 0.0 && e4 < 2.0 * e1, "energy exploded: {e1:.0} -> {e4:.0} pJ");
}

#[test]
fn scale_report_is_deterministic_across_jobs() {
    let spec = BatchSpec {
        target: Target::Carus,
        kernel: Kernel::Add { n: 512 },
        sew: Sew::E32,
        seed: 5,
        batch: 4,
        shard: false,
    };
    let run = |jobs: usize| {
        let session = Arc::new(SweepSession::new());
        let (rep, points) = harness::scale_report(&session, spec, &[1, 2], jobs).unwrap();
        (rep.text, rep.csv, points.iter().map(|p| p.cycles).collect::<Vec<_>>())
    };
    let (text1, csv1, cycles1) = run(1);
    let (text4, csv4, cycles4) = run(4);
    assert_eq!(text1, text4, "report text must be byte-identical for any --jobs");
    assert_eq!(csv1, csv4);
    assert_eq!(cycles1, cycles4, "simulated cycles are deterministic");
}

#[test]
fn sharded_matmul_matches_whole_kernel_reference() {
    // One large matmul split along P across 4 tiles: `run_planned`
    // asserts the reassembled output equals the *whole* kernel's golden
    // output; here we additionally pin the shard accounting.
    let spec = BatchSpec { shard: true, ..matmul_spec(1) };
    let res = sched::run_batch(&spec, 4).unwrap();
    assert_eq!(res.outputs.len(), 1, "shard mode reassembles to one output");
    assert_eq!(res.outputs[0].len(), 8 * 256, "full 8x256 8-bit product");
    assert_eq!(res.per_tile.len(), 4);
    assert!(res.per_tile.iter().all(|t| t.workloads == 1), "one shard per tile");
    // Sharding a single kernel also beats the unsharded single tile.
    let whole = sched::run_batch(&matmul_spec(1), 1).unwrap();
    assert_eq!(whole.outputs[0], res.outputs[0], "shard result == whole-kernel result");
    assert!(res.cycles < whole.cycles, "4-way sharding must not be slower");
}

#[test]
fn capacity_and_shard_rejections_are_errors_not_panics() {
    // Staging pool exhaustion: 200 x 16 KiB in-place workloads.
    let e = sched::run_batch(
        &BatchSpec {
            target: Target::Carus,
            kernel: Kernel::Relu { n: 16384 },
            sew: Sew::E8,
            seed: 1,
            batch: 200,
            shard: false,
        },
        2,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("staging"), "{e}");
    // Conv2d has no 1-D shard axis.
    let e = sched::run_batch(
        &BatchSpec {
            target: Target::Carus,
            kernel: Kernel::Conv2d { n: 64, f: 3 },
            sew: Sew::E8,
            seed: 1,
            batch: 1,
            shard: true,
        },
        2,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("shard axis"), "{e}");
    // Shards that violate a tile's shape envelope (NM-Carus matmul needs
    // p >= 8 per shard).
    let e = sched::run_batch(
        &BatchSpec {
            target: Target::Carus,
            kernel: Kernel::Matmul { p: 16 },
            sew: Sew::E32,
            seed: 1,
            batch: 1,
            shard: true,
        },
        4,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("shard"), "{e}");
}
